//! Offline stand-in for the `anyhow` crate: the API subset this workspace
//! uses (`Result`, `Error`, `Context`, `anyhow!`, `bail!`, `ensure!`),
//! implemented over a single flattened message string. The registry is
//! unreachable from the build environment, so this path crate keeps the
//! call sites source-compatible with upstream anyhow.

use std::fmt;

/// Drop-in for `anyhow::Error`: an opaque error carrying a human-readable
/// message with any `.context(..)` layers pre-joined (outermost first),
/// matching how upstream renders with the `{:#}` format.
pub struct Error {
    msg: String,
}

impl Error {
    pub fn msg<M: fmt::Display>(msg: M) -> Self {
        Self { msg: msg.to_string() }
    }

    /// Prepend a context layer (upstream keeps a chain; we flatten).
    pub fn context<C: fmt::Display>(self, context: C) -> Self {
        Self { msg: format!("{context}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// Like upstream anyhow, `Error` deliberately does NOT implement
// `std::error::Error`; that is what makes this blanket conversion (and
// therefore `?` on any std error type) coherent.
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Self {
        Self::msg(e)
    }
}

/// `anyhow::Result<T>` — `Result` with `Error` as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Context-attachment extension, implemented for both `Result` and
/// `Option` like upstream.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T, Error>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error::msg(e).context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T, Error> {
        self.map_err(|e| Error::msg(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($msg:expr $(,)?) => {
        $crate::Error::msg($msg)
    };
}

/// Early-return with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Early-return with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            return ::std::result::Result::Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse_failure() -> Result<i32> {
        let n: i32 = "not a number".parse()?; // via the blanket From
        Ok(n)
    }

    #[test]
    fn question_mark_converts_std_errors() {
        assert!(parse_failure().is_err());
    }

    #[test]
    fn context_layers_prepend() {
        let e: Result<()> = Err(anyhow!("inner {}", 7));
        let e = e.context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer: inner 7");
    }

    #[test]
    fn option_context() {
        let v: Option<u8> = None;
        assert!(v.context("missing").is_err());
        assert_eq!(Some(3u8).context("missing").unwrap(), 3);
    }

    #[test]
    fn bail_and_ensure() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x >= 0, "negative: {x}");
            if x == 0 {
                bail!("zero");
            }
            Ok(x)
        }
        assert!(f(-1).is_err());
        assert!(f(0).is_err());
        assert_eq!(f(2).unwrap(), 2);
    }
}
