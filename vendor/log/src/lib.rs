//! Offline stand-in for the `log` facade: levels, the `Log` trait, a
//! global logger slot and the five logging macros — the subset
//! `util::logging` and the coordinator use. Implemented over std atomics
//! only, so it builds with no dependencies.

use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Verbosity of one log record (ordered: `Error` is most severe).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Level {
    Error = 1,
    Warn,
    Info,
    Debug,
    Trace,
}

/// Global verbosity ceiling (`Off` disables everything).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum LevelFilter {
    Off = 0,
    Error,
    Warn,
    Info,
    Debug,
    Trace,
}

impl PartialEq<LevelFilter> for Level {
    fn eq(&self, other: &LevelFilter) -> bool {
        *self as usize == *other as usize
    }
}

impl PartialOrd<LevelFilter> for Level {
    fn partial_cmp(&self, other: &LevelFilter) -> Option<std::cmp::Ordering> {
        (*self as usize).partial_cmp(&(*other as usize))
    }
}

/// Record metadata (level + target), borrowed by [`Log::enabled`].
#[derive(Debug, Clone)]
pub struct Metadata<'a> {
    level: Level,
    target: &'a str,
}

impl<'a> Metadata<'a> {
    pub fn level(&self) -> Level {
        self.level
    }

    pub fn target(&self) -> &'a str {
        self.target
    }
}

/// One log record: metadata plus the pre-formatted arguments.
pub struct Record<'a> {
    metadata: Metadata<'a>,
    args: fmt::Arguments<'a>,
}

impl<'a> Record<'a> {
    pub fn metadata(&self) -> &Metadata<'a> {
        &self.metadata
    }

    pub fn level(&self) -> Level {
        self.metadata.level
    }

    pub fn target(&self) -> &'a str {
        self.metadata.target
    }

    pub fn args(&self) -> &fmt::Arguments<'a> {
        &self.args
    }
}

/// A log sink; install one with [`set_logger`].
pub trait Log: Send + Sync {
    fn enabled(&self, metadata: &Metadata) -> bool;
    fn log(&self, record: &Record);
    fn flush(&self);
}

static LOGGER: OnceLock<&'static dyn Log> = OnceLock::new();
static MAX_LEVEL: AtomicUsize = AtomicUsize::new(LevelFilter::Off as usize);

/// Error returned when a logger is already installed.
#[derive(Debug)]
pub struct SetLoggerError(());

impl fmt::Display for SetLoggerError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str("a logger is already installed")
    }
}

impl std::error::Error for SetLoggerError {}

pub fn set_logger(logger: &'static dyn Log) -> Result<(), SetLoggerError> {
    LOGGER.set(logger).map_err(|_| SetLoggerError(()))
}

pub fn set_max_level(level: LevelFilter) {
    MAX_LEVEL.store(level as usize, Ordering::Relaxed);
}

pub fn max_level() -> LevelFilter {
    match MAX_LEVEL.load(Ordering::Relaxed) {
        1 => LevelFilter::Error,
        2 => LevelFilter::Warn,
        3 => LevelFilter::Info,
        4 => LevelFilter::Debug,
        5 => LevelFilter::Trace,
        _ => LevelFilter::Off,
    }
}

/// Macro back-end: dispatch one record to the installed logger.
pub fn __log(level: Level, target: &str, args: fmt::Arguments<'_>) {
    if level > max_level() {
        return;
    }
    if let Some(logger) = LOGGER.get() {
        let record = Record { metadata: Metadata { level, target }, args };
        if logger.enabled(record.metadata()) {
            logger.log(&record);
        }
    }
}

#[macro_export]
macro_rules! log {
    ($lvl:expr, $($arg:tt)+) => {
        $crate::__log($lvl, module_path!(), format_args!($($arg)+))
    };
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Error, $($arg)+) };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Warn, $($arg)+) };
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Info, $($arg)+) };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Debug, $($arg)+) };
}

#[macro_export]
macro_rules! trace {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Trace, $($arg)+) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_vs_filter_ordering() {
        assert!(Level::Error <= LevelFilter::Info);
        assert!(Level::Info <= LevelFilter::Info);
        assert!(Level::Debug > LevelFilter::Info);
        assert!(Level::Trace > LevelFilter::Off);
    }

    // one test for all global-state behaviour: the level slot is shared,
    // so concurrent #[test]s poking it would race each other
    #[test]
    fn max_level_roundtrip_and_silent_dispatch() {
        set_max_level(LevelFilter::Debug);
        assert_eq!(max_level(), LevelFilter::Debug);
        info!("nobody listening: {}", 42); // no logger installed: no-op
        set_max_level(LevelFilter::Off);
        assert_eq!(max_level(), LevelFilter::Off);
    }
}
