//! Scale oracles for the event-driven worker execution refactor: a
//! dp=256 local training run multiplexes its 768 worker state machines
//! (3 stages × 256 replicas) over the shared bounded executor, so the
//! process needs O(cores) OS threads — not the historical two threads
//! per FlowPool plus one per worker — and a dp-scale scenario replay
//! stays byte-identical across fully independent runs.

use funcpipe::config::ExperimentConfig;
use funcpipe::experiment::{Experiment, Format, Report, TrainOverrides};
use funcpipe::runtime::BUILTIN_TINY;
use funcpipe::simcore::ScenarioSpec;

fn base_cfg() -> ExperimentConfig {
    ExperimentConfig {
        artifacts_dir: BUILTIN_TINY.into(),
        platform: "local".into(),
        steps: 1,
        ..ExperimentConfig::default()
    }
}

/// Current OS-thread count of this process (the `Threads:` line of
/// `/proc/self/status`).
#[cfg(target_os = "linux")]
fn current_threads() -> usize {
    std::fs::read_to_string("/proc/self/status")
        .unwrap()
        .lines()
        .find_map(|l| l.strip_prefix("Threads:"))
        .and_then(|v| v.trim().parse().ok())
        .expect("Threads: line in /proc/self/status")
}

#[cfg(target_os = "linux")]
#[test]
fn dp256_train_runs_on_o_cores_threads() {
    use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
    use std::sync::Arc;

    let stop = Arc::new(AtomicBool::new(false));
    let peak = Arc::new(AtomicUsize::new(0));
    let sampler = {
        let (stop, peak) = (stop.clone(), peak.clone());
        std::thread::spawn(move || {
            while !stop.load(Ordering::Relaxed) {
                peak.fetch_max(current_threads(), Ordering::Relaxed);
                std::thread::sleep(std::time::Duration::from_millis(2));
            }
        })
    };

    let ov = TrainOverrides { dp: Some(256), ..TrainOverrides::default() };
    let report = Experiment::new(base_cfg())
        .unwrap()
        .train(None, &ov)
        .unwrap();
    stop.store(true, Ordering::Relaxed);
    sampler.join().unwrap();

    assert_eq!(report.dp, 256);
    assert!(report.logs.iter().all(|l| l.loss.is_finite()));

    let pool = funcpipe::exec::pool_size();
    let peak = peak.load(Ordering::Relaxed);
    // executor pool + timer thread + test harness + sampler + slack —
    // far below the 768 worker tasks the run multiplexes (the old
    // implementation needed >1500 threads here)
    assert!(
        peak <= pool + 12,
        "dp=256 run peaked at {peak} OS threads (executor pool {pool}); \
         worker execution is no longer O(cores)"
    );
}

#[test]
fn dp64_scenario_replay_is_byte_identical() {
    // the determinism invariant at data-parallel scale: per-generation
    // lens draws, replica-slot-ordered loss aggregation and the virtual
    // clock survive the executor multiplexing 192 concurrent workers
    let mut cfg = base_cfg();
    cfg.steps = 2;
    cfg.scenario = ScenarioSpec::parse("cold-start+straggler").unwrap();
    cfg.seed = 11;
    let ov = TrainOverrides { dp: Some(64), ..TrainOverrides::default() };
    // two fully independent sessions — nothing shared but the inputs
    let rep_a = Experiment::new(cfg.clone())
        .unwrap()
        .train(None, &ov)
        .unwrap();
    let rep_b = Experiment::new(cfg).unwrap().train(None, &ov).unwrap();
    assert_eq!(rep_a.dp, 64);
    assert_eq!(
        rep_a.render(Format::Json),
        rep_b.render(Format::Json),
        "dp=64 scenario replay drifted across identical sessions"
    );
    assert_eq!(rep_a.render(Format::Table), rep_b.render(Format::Table));
}
