//! The three faces of every collective agree: analytic closed form,
//! flow-level simulation, and the real threaded implementation — for the
//! classic whole-split transfers and for the chunked multi-flow engine.

use std::sync::Arc;
use std::time::Duration;

use funcpipe::collective::sim::{
    simulate_pipelined_scatter_reduce,
    simulate_pipelined_scatter_reduce_chunked, simulate_scatter_reduce,
    simulate_scatter_reduce_chunked,
};
use funcpipe::collective::{
    pipelined::{pipelined_scatter_reduce, pipelined_scatter_reduce_chunked},
    scatter_reduce::{scatter_reduce, scatter_reduce_chunked},
    sync_time, sync_time_chunked, Chunking, SyncAlgorithm,
};
use funcpipe::platform::network::BandwidthModel;
use funcpipe::platform::{MemStore, ObjectStore};

#[test]
fn analytic_vs_flowsim_across_sizes() {
    for n in [2usize, 4, 8, 16] {
        for mb in [50.0e6, 280.0e6, 1000.0e6] {
            let net = BandwidthModel::uniform(n, 70.0e6, 0.0);
            let sim = simulate_pipelined_scatter_reduce(n, mb, &net);
            let formula = sync_time(
                SyncAlgorithm::PipelinedScatterReduce, mb, n, 70.0e6, 0.0,
            );
            let err = (sim - formula).abs() / formula;
            assert!(err < 0.15, "n={n} s={mb}: {sim} vs {formula}");

            let sim = simulate_scatter_reduce(n, mb, &net);
            let formula =
                sync_time(SyncAlgorithm::ScatterReduce, mb, n, 70.0e6, 0.0);
            let err = (sim - formula).abs() / formula;
            assert!(err < 0.15, "plain n={n} s={mb}: {sim} vs {formula}");
        }
    }
}

#[test]
fn real_implementations_agree_bitwise() {
    // plain and pipelined must produce the identical all-reduced vector
    for n in [2usize, 3, 4, 6] {
        let len = 10_000 + n; // non-divisible
        let gen = |rank: usize| -> Vec<f32> {
            (0..len).map(|i| ((rank * 7919 + i * 13) % 101) as f32).collect()
        };
        let mut results = Vec::new();
        for pipelined in [false, true] {
            let store: Arc<dyn ObjectStore> = Arc::new(MemStore::new());
            let handles: Vec<_> = (0..n)
                .map(|rank| {
                    let store = store.clone();
                    let mut g = gen(rank);
                    std::thread::spawn(move || {
                        if pipelined {
                            pipelined_scatter_reduce(
                                &store, "x", 0, rank, n, &mut g, None,
                                Duration::from_secs(30),
                            )
                            .unwrap();
                        } else {
                            scatter_reduce(
                                &store, "x", 0, rank, n, &mut g, None,
                                Duration::from_secs(30),
                            )
                            .unwrap();
                        }
                        g
                    })
                })
                .collect();
            let out: Vec<Vec<f32>> =
                handles.into_iter().map(|h| h.join().unwrap()).collect();
            // all ranks identical
            for r in &out[1..] {
                assert_eq!(r, &out[0]);
            }
            results.push(out[0].clone());
        }
        assert_eq!(results[0], results[1], "plain != pipelined at n={n}");
    }
}

/// The chunked engine is represented in all three forms, and in each form
/// it agrees with the unchunked baseline where it must:
/// * analytic — identical at zero latency (chunking only adds per-op
///   latency), exactly equal with `chunk_bytes == 0`;
/// * FlowSim — the plain chunked schedule reproduces the unchunked
///   makespan at zero latency within 1e-5, the pipelined chunked
///   schedule is never slower (finer fill) and never beats the link
///   occupancy bound;
/// * real — the summed gradients are identical (asserted elementwise to
///   1e-5 and, for the integer-valued inputs used here, bitwise).
#[test]
fn chunked_forms_agree_with_unchunked() {
    let w = 70.0e6;
    for n in [2usize, 4, 8] {
        let s = 280.0e6;
        // analytic
        for alg in [
            SyncAlgorithm::ScatterReduce,
            SyncAlgorithm::PipelinedScatterReduce,
        ] {
            let a = sync_time(alg, s, n, w, 0.0);
            let b = sync_time_chunked(alg, s, n, w, 0.0, 4 << 20);
            assert!(
                (a - b).abs() / a < 1e-5,
                "analytic {alg:?} n={n}: {a} vs {b}"
            );
            assert_eq!(sync_time_chunked(alg, s, n, w, 0.04, 0), sync_time(alg, s, n, w, 0.04));
        }
        // FlowSim
        let net = BandwidthModel::uniform(n, w, 0.0);
        let plain = simulate_scatter_reduce(n, s, &net);
        let plain_chunked =
            simulate_scatter_reduce_chunked(n, s, &net, 4.0e6);
        assert!(
            (plain - plain_chunked).abs() / plain < 1e-5,
            "flowsim plain n={n}: {plain} vs {plain_chunked}"
        );
        let piped = simulate_pipelined_scatter_reduce(n, s, &net);
        let piped_chunked =
            simulate_pipelined_scatter_reduce_chunked(n, s, &net, 4.0e6);
        assert!(piped_chunked <= piped * (1.0 + 1e-9));
        assert!(piped_chunked >= s / w * (1.0 - 1e-9));
    }
}

/// Real path: chunked == unchunked for both scatter-reduce variants, over
/// uneven lengths (len not divisible by n, split not divisible by chunk)
/// and several window depths.
#[test]
fn real_chunked_matches_unchunked_for_all_algorithms() {
    for n in [2usize, 3, 5] {
        let len = 10_007; // prime: nothing divides evenly
        let gen = |rank: usize| -> Vec<f32> {
            (0..len).map(|i| ((rank * 31 + i * 7) % 127) as f32).collect()
        };
        let run = |pipelined: bool, chunking: Chunking| -> Vec<Vec<f32>> {
            let store: Arc<dyn ObjectStore> = Arc::new(MemStore::new());
            let handles: Vec<_> = (0..n)
                .map(|rank| {
                    let store = store.clone();
                    let mut g = gen(rank);
                    std::thread::spawn(move || {
                        if pipelined {
                            pipelined_scatter_reduce_chunked(
                                &store, "c", 0, rank, n, &mut g, None,
                                Duration::from_secs(30), chunking,
                            )
                            .unwrap();
                        } else {
                            scatter_reduce_chunked(
                                &store, "c", 0, rank, n, &mut g, None,
                                Duration::from_secs(30), chunking,
                            )
                            .unwrap();
                        }
                        g
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        };
        for pipelined in [false, true] {
            let baseline = run(pipelined, Chunking::NONE);
            for chunking in [Chunking::new(100, 1), Chunking::new(1024, 3)] {
                let chunked = run(pipelined, chunking);
                for (a, b) in baseline.iter().zip(&chunked) {
                    for (x, y) in a.iter().zip(b) {
                        assert!(
                            (x - y).abs() < 1e-5,
                            "pipelined={pipelined} n={n} chunk={}: {x} vs {y}",
                            chunking.chunk_bytes
                        );
                    }
                }
                assert_eq!(&baseline, &chunked, "bitwise for integer inputs");
            }
        }
    }
}

#[test]
fn sum_matches_scalar_reference() {
    let n = 5;
    let len = 257;
    let store: Arc<dyn ObjectStore> = Arc::new(MemStore::new());
    let handles: Vec<_> = (0..n)
        .map(|rank| {
            let store = store.clone();
            std::thread::spawn(move || {
                let mut g: Vec<f32> =
                    (0..len).map(|i| (rank * len + i) as f32 * 0.25).collect();
                pipelined_scatter_reduce(
                    &store, "s", 9, rank, n, &mut g, None,
                    Duration::from_secs(30),
                )
                .unwrap();
                g
            })
        })
        .collect();
    let out = handles
        .into_iter()
        .map(|h| h.join().unwrap())
        .collect::<Vec<_>>();
    for i in 0..len {
        let want: f32 = (0..n).map(|r| (r * len + i) as f32 * 0.25).sum();
        assert!((out[0][i] - want).abs() < 1e-3, "i={i}");
    }
}
