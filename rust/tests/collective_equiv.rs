//! The three faces of every collective agree: analytic closed form,
//! flow-level simulation, and the real threaded implementation.

use std::sync::Arc;
use std::time::Duration;

use funcpipe::collective::sim::{
    simulate_pipelined_scatter_reduce, simulate_scatter_reduce,
};
use funcpipe::collective::{
    pipelined::pipelined_scatter_reduce, scatter_reduce::scatter_reduce,
    sync_time, SyncAlgorithm,
};
use funcpipe::platform::network::BandwidthModel;
use funcpipe::platform::{MemStore, ObjectStore};

#[test]
fn analytic_vs_flowsim_across_sizes() {
    for n in [2usize, 4, 8, 16] {
        for mb in [50.0e6, 280.0e6, 1000.0e6] {
            let net = BandwidthModel::uniform(n, 70.0e6, 0.0);
            let sim = simulate_pipelined_scatter_reduce(n, mb, &net);
            let formula = sync_time(
                SyncAlgorithm::PipelinedScatterReduce, mb, n, 70.0e6, 0.0,
            );
            let err = (sim - formula).abs() / formula;
            assert!(err < 0.15, "n={n} s={mb}: {sim} vs {formula}");

            let sim = simulate_scatter_reduce(n, mb, &net);
            let formula =
                sync_time(SyncAlgorithm::ScatterReduce, mb, n, 70.0e6, 0.0);
            let err = (sim - formula).abs() / formula;
            assert!(err < 0.15, "plain n={n} s={mb}: {sim} vs {formula}");
        }
    }
}

#[test]
fn real_implementations_agree_bitwise() {
    // plain and pipelined must produce the identical all-reduced vector
    for n in [2usize, 3, 4, 6] {
        let len = 10_000 + n; // non-divisible
        let gen = |rank: usize| -> Vec<f32> {
            (0..len).map(|i| ((rank * 7919 + i * 13) % 101) as f32).collect()
        };
        let mut results = Vec::new();
        for pipelined in [false, true] {
            let store: Arc<dyn ObjectStore> = Arc::new(MemStore::new());
            let handles: Vec<_> = (0..n)
                .map(|rank| {
                    let store = store.clone();
                    let mut g = gen(rank);
                    std::thread::spawn(move || {
                        if pipelined {
                            pipelined_scatter_reduce(
                                &store, "x", 0, rank, n, &mut g, None,
                                Duration::from_secs(30),
                            )
                            .unwrap();
                        } else {
                            scatter_reduce(
                                &store, "x", 0, rank, n, &mut g, None,
                                Duration::from_secs(30),
                            )
                            .unwrap();
                        }
                        g
                    })
                })
                .collect();
            let out: Vec<Vec<f32>> =
                handles.into_iter().map(|h| h.join().unwrap()).collect();
            // all ranks identical
            for r in &out[1..] {
                assert_eq!(r, &out[0]);
            }
            results.push(out[0].clone());
        }
        assert_eq!(results[0], results[1], "plain != pipelined at n={n}");
    }
}

#[test]
fn sum_matches_scalar_reference() {
    let n = 5;
    let len = 257;
    let store: Arc<dyn ObjectStore> = Arc::new(MemStore::new());
    let handles: Vec<_> = (0..n)
        .map(|rank| {
            let store = store.clone();
            std::thread::spawn(move || {
                let mut g: Vec<f32> =
                    (0..len).map(|i| (rank * len + i) as f32 * 0.25).collect();
                pipelined_scatter_reduce(
                    &store, "s", 9, rank, n, &mut g, None,
                    Duration::from_secs(30),
                )
                .unwrap();
                g
            })
        })
        .collect();
    let out = handles
        .into_iter()
        .map(|h| h.join().unwrap())
        .collect::<Vec<_>>();
    for i in 0..len {
        let want: f32 = (0..n).map(|r| (r * len + i) as f32 * 0.25).sum();
        assert!((out[0][i] - want).abs() < 1e-3, "i={i}");
    }
}
