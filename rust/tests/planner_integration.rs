//! Planner end-to-end: optimizer vs baselines vs MIQP certification on
//! every zoo model.

use funcpipe::model::{merge_layers, zoo, MergeCriterion};
use funcpipe::planner::bayes::BayesOpt;
use funcpipe::planner::miqp::MiqpSolver;
use funcpipe::planner::tpdmp::Tpdmp;
use funcpipe::planner::CoOptimizer;
use funcpipe::platform::PlatformSpec;

#[test]
fn optimizer_dominates_baseline_searchers_on_objective() {
    let p = PlatformSpec::aws_lambda();
    let alpha = (1.0, 2e-4);
    for name in zoo::MODEL_NAMES {
        let m = merge_layers(
            &zoo::by_name(name, &p).unwrap(),
            6,
            MergeCriterion::Compute,
        );
        let (_, co, _) = CoOptimizer::new(&m, &p).solve(16, alpha).unwrap();
        let j_co = alpha.0 * co.c_iter + alpha.1 * co.t_iter;
        if let Some((_, tp)) = Tpdmp::new(&m, &p).solve(16, alpha) {
            let j = alpha.0 * tp.c_iter + alpha.1 * tp.t_iter;
            assert!(j_co <= j + 1e-12, "{name}: co {j_co} > tpdmp {j}");
        }
        if let Some((_, by)) = BayesOpt::new(&m, &p).solve(16, alpha) {
            let j = alpha.0 * by.c_iter + alpha.1 * by.t_iter;
            assert!(j_co <= j + 1e-9, "{name}: co {j_co} > bayes {j}");
        }
    }
}

#[test]
fn miqp_certifies_all_models_small() {
    let p = PlatformSpec::aws_lambda();
    let alpha = (1.0, 1e-4);
    for name in zoo::MODEL_NAMES {
        let m = merge_layers(
            &zoo::by_name(name, &p).unwrap(),
            4,
            MergeCriterion::Compute,
        );
        let mut co = CoOptimizer::new(&m, &p);
        co.dp_options = vec![1, 2];
        let mut miqp = MiqpSolver::new(&m, &p);
        miqp.dp_options = vec![1, 2];
        let (_, perf, _) = co.solve(8, alpha).unwrap();
        let j_co = alpha.0 * perf.c_iter + alpha.1 * perf.t_iter;
        let sol = miqp.solve(8, alpha).unwrap();
        assert!(
            (sol.objective - j_co).abs() < 1e-9 * j_co.max(1.0),
            "{name}: {} vs {}",
            sol.objective,
            j_co
        );
    }
}

#[test]
fn solution_times_are_minute_level() {
    // §5.6: minute-level solution time; ours should be far under
    let p = PlatformSpec::aws_lambda();
    let m = merge_layers(
        &zoo::bert_large(&p),
        12,
        MergeCriterion::Compute,
    );
    let t0 = std::time::Instant::now();
    let (_, _, stats) = CoOptimizer::new(&m, &p).solve(64, (1.0, 2e-4)).unwrap();
    assert!(t0.elapsed().as_secs_f64() < 120.0, "{stats:?}");
}
