//! Deterministic scenario replay: the same `--seed` + `--scenario`
//! must produce a bit-identical `SimReport` (JSON and table), and
//! different seeds must produce different draws — the contract that
//! makes the simulator a scenario *lab* instead of a noise source
//! (and that closes the latent nondeterminism risk of the old inline
//! `simulate_iteration_noisy`).

use funcpipe::config::ExperimentConfig;
use funcpipe::experiment::{Experiment, Format, Report};
use funcpipe::simcore::ScenarioSpec;

fn cfg_with(scenario: &str, seed: u64) -> ExperimentConfig {
    ExperimentConfig {
        model: "resnet101".into(),
        global_batch: 16,
        merge_layers: 4,
        scenario: ScenarioSpec::parse(scenario).unwrap(),
        seed,
        ..ExperimentConfig::default()
    }
}

#[test]
fn same_seed_and_scenario_is_bit_identical() {
    for scenario in [
        "cold-start",
        "straggler",
        "bandwidth-jitter",
        "flaky-network",
        "cold-start+jitter",
        "flaky-network+cold-start",
        "cold-start+straggler+bandwidth-jitter",
    ] {
        // two fully independent sessions — nothing shared but the inputs
        let a = Experiment::new(cfg_with(scenario, 7)).unwrap();
        let b = Experiment::new(cfg_with(scenario, 7)).unwrap();
        let plan_a = a.plan().unwrap();
        let plan_b = b.plan().unwrap();
        let rep_a = a.simulate(&plan_a.recommended().unwrap().artifact).unwrap();
        let rep_b = b.simulate(&plan_b.recommended().unwrap().artifact).unwrap();
        assert_eq!(
            rep_a.render(Format::Json),
            rep_b.render(Format::Json),
            "{scenario}: JSON reports differ across identical replays"
        );
        assert_eq!(rep_a.render(Format::Table), rep_b.render(Format::Table));
        let (sa, sb) = (
            rep_a.scenario_sim.as_ref().unwrap(),
            rep_b.scenario_sim.as_ref().unwrap(),
        );
        assert_eq!(sa.t_iter.to_bits(), sb.t_iter.to_bits());
        assert_eq!(sa.c_iter.to_bits(), sb.c_iter.to_bits());
    }
}

#[test]
fn different_seeds_draw_differently() {
    for scenario in
        ["cold-start", "straggler", "bandwidth-jitter", "cold-start+jitter"]
    {
        let a = Experiment::new(cfg_with(scenario, 7)).unwrap();
        let b = Experiment::new(cfg_with(scenario, 8)).unwrap();
        let artifact_a = a.plan().unwrap().recommended().unwrap().artifact.clone();
        // the plan itself is seed-independent (planning is closed-form):
        // simulate the SAME artifact under both seeds
        let rep_a = a.simulate(&artifact_a).unwrap();
        let rep_b = b.simulate(&artifact_a).unwrap();
        let (sa, sb) = (
            rep_a.scenario_sim.as_ref().unwrap(),
            rep_b.scenario_sim.as_ref().unwrap(),
        );
        assert_ne!(
            sa.t_iter.to_bits(),
            sb.t_iter.to_bits(),
            "{scenario}: seeds 7 and 8 drew identical timelines"
        );
        // the deterministic reference pass is seed-independent
        assert_eq!(rep_a.sim.t_iter.to_bits(), rep_b.sim.t_iter.to_bits());
        assert_eq!(
            rep_a.predicted.t_iter.to_bits(),
            rep_b.predicted.t_iter.to_bits()
        );
    }
}

#[test]
fn deterministic_scenario_has_no_scenario_pass() {
    let exp = Experiment::new(cfg_with("deterministic", 0)).unwrap();
    let artifact = exp.plan().unwrap().recommended().unwrap().artifact.clone();
    let rep = exp.simulate(&artifact).unwrap();
    assert!(rep.scenario_sim.is_none());
    assert!(rep.scenario_overhead_pct().is_none());
    // and the JSON still names the lens so downstream tooling need not
    // special-case its absence
    let json = rep.render(Format::Json);
    assert!(json.contains("\"scenario\""), "{json}");
    assert!(json.contains("deterministic"), "{json}");
}

#[test]
fn scenario_lens_does_not_invalidate_artifacts() {
    // an artifact planned under the deterministic default must be
    // simulatable by a session whose only difference is the lens —
    // the `simulate --plan p.json --scenario straggler --seed 7` flow
    let base = Experiment::new(cfg_with("deterministic", 0)).unwrap();
    let artifact = base.plan().unwrap().recommended().unwrap().artifact.clone();
    let lens = Experiment::new(cfg_with("straggler", 7)).unwrap();
    let rep = lens.simulate(&artifact).unwrap();
    assert_eq!(rep.scenario.name(), "straggler");
    assert_eq!(rep.seed, 7);
    assert!(rep.scenario_sim.is_some());
    // any *other* config drift still fails loudly
    let mut drifted = artifact.clone();
    drifted.config.merge_layers += 1;
    assert!(lens.simulate(&drifted).is_err());
}
