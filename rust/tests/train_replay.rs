//! Deterministic scenario replay on the **train** path — the mirror of
//! `scenario_replay.rs` for the real trainer: the same `--scenario` +
//! `--seed` must reproduce identical restart counts, generations and
//! scenario columns (in fact the byte-identical report, since scenario
//! runs use the injector's virtual clock), and different seeds must
//! draw different lenses. Runs on the built-in native model
//! (`builtin:tiny`), so the full coordinator/storage/collective stack
//! executes in the default offline build.

use funcpipe::config::ExperimentConfig;
use funcpipe::experiment::{Experiment, Format, Report, TrainOverrides};
use funcpipe::runtime::BUILTIN_TINY;
use funcpipe::simcore::ScenarioSpec;
use funcpipe::util::json::Json;

fn cfg_with(scenario: &str, seed: u64) -> ExperimentConfig {
    ExperimentConfig {
        artifacts_dir: BUILTIN_TINY.into(),
        platform: "local".into(),
        steps: 4,
        // virtual tick is 1.0 (planless) and the default checkpoint
        // margin is 2.0: with this lifetime every worker restarts a
        // lens-dependent number of times within 4 steps
        lifetime_s: 4.5,
        scenario: ScenarioSpec::parse(scenario).unwrap(),
        seed,
        ..ExperimentConfig::default()
    }
}

fn train_report(cfg: &ExperimentConfig) -> funcpipe::experiment::TrainReport {
    Experiment::new(cfg.clone())
        .unwrap()
        .train(None, &TrainOverrides::default())
        .unwrap()
}

#[test]
fn same_seed_and_scenario_replays_byte_identically() {
    for scenario in
        ["straggler", "cold-start+jitter", "flaky-network+cold-start"]
    {
        let cfg = cfg_with(scenario, 7);
        // two fully independent sessions — nothing shared but the inputs
        let rep_a = train_report(&cfg);
        let rep_b = train_report(&cfg);
        assert_eq!(rep_a.restarts, rep_b.restarts, "{scenario}");
        assert_eq!(rep_a.workers.len(), rep_b.workers.len());
        for (a, b) in rep_a.workers.iter().zip(&rep_b.workers) {
            assert_eq!(a.generations, b.generations, "{scenario}");
            assert_eq!(a.restarts, b.restarts);
            assert_eq!(a.cold_start_s.to_bits(), b.cold_start_s.to_bits());
        }
        assert_eq!(
            rep_a.render(Format::Json),
            rep_b.render(Format::Json),
            "{scenario}: JSON reports differ across identical replays"
        );
        assert_eq!(rep_a.render(Format::Table), rep_b.render(Format::Table));
    }
}

#[test]
fn different_seeds_draw_different_lenses() {
    let rep_a = train_report(&cfg_with("straggler", 7));
    let rep_b = train_report(&cfg_with("straggler", 8));
    // per-worker lens factors are continuous draws: distinct seeds
    // produce distinct multipliers almost surely
    let differs = rep_a
        .workers
        .iter()
        .zip(&rep_b.workers)
        .any(|(a, b)| {
            a.lens.compute_mult.to_bits() != b.lens.compute_mult.to_bits()
        });
    assert!(differs, "seeds 7 and 8 drew identical lenses");
    assert_ne!(
        rep_a.render(Format::Json),
        rep_b.render(Format::Json),
        "seeds 7 and 8 produced identical reports"
    );
    // both carry their own seed column
    assert_eq!(rep_a.seed, 7);
    assert_eq!(rep_b.seed, 8);
}

#[test]
fn deterministic_scenario_keeps_wall_clock_and_names_the_lens() {
    let cfg = cfg_with("deterministic", 0);
    let rep = train_report(&cfg);
    assert!(rep.scenario.is_deterministic());
    assert_eq!(rep.virtual_iter_s, None);
    assert!(rep.scenario_overhead_pct().is_none());
    // the JSON still names the lens so downstream tooling need not
    // special-case its absence — same contract as SimReport
    let json = rep.render(Format::Json);
    assert!(json.contains("\"scenario\""), "{json}");
    assert!(json.contains("deterministic"), "{json}");
}

#[test]
fn one_plan_replays_under_sim_and_train_with_identical_columns() {
    // the acceptance flow: freeze ONE plan, replay it under `simulate`
    // and `train` with the same --scenario/--seed, and read the same
    // scenario kind/seed columns from both reports
    let cfg = ExperimentConfig {
        model: "resnet101".into(),
        global_batch: 16,
        merge_layers: 4,
        artifacts_dir: BUILTIN_TINY.into(),
        steps: 3,
        scenario: ScenarioSpec::parse("straggler").unwrap(),
        seed: 7,
        ..ExperimentConfig::default()
    };
    let exp = Experiment::new(cfg).unwrap();
    let artifact = exp.plan().unwrap().recommended().unwrap().artifact.clone();

    let sim = exp.simulate(&artifact).unwrap();
    let train = exp
        .train(Some(&artifact), &TrainOverrides::default())
        .unwrap();

    // identical lens columns on both reports
    assert_eq!(sim.scenario.name(), train.scenario.name());
    assert_eq!(sim.seed, train.seed);
    let sim_json = Json::parse(sim.render(Format::Json).trim()).unwrap();
    let train_json = Json::parse(train.render(Format::Json).trim()).unwrap();
    let col = |j: &Json| -> (String, f64) {
        let s = j.field("scenario").unwrap();
        (
            s.field_str("kind").unwrap().to_string(),
            s.field_f64("seed").unwrap(),
        )
    };
    assert_eq!(col(&sim_json), col(&train_json));
    assert_eq!(col(&train_json), ("straggler".to_string(), 7.0));

    // the trainer ran the plan's dp/μ and ticked at its predicted t_iter
    assert_eq!(train.dp, artifact.plan.dp);
    assert_eq!(train.virtual_iter_s, Some(artifact.predicted_t_iter));

    // and the train replay is deterministic: run it again, byte for byte
    let again = exp
        .train(Some(&artifact), &TrainOverrides::default())
        .unwrap();
    assert_eq!(
        train.render(Format::Json),
        again.render(Format::Json),
        "train --plan replay drifted"
    );
}

#[test]
fn flaky_network_exercises_the_retry_path_deterministically() {
    // the injected get_blocking drops must be absorbed by the retry
    // middleware (the run completes with real losses), be observable in
    // the report, and replay byte-identically per seed. Drop decisions
    // are per-(worker, key): with ~30+ distinct boundary keys per run
    // at prob 0.15, at least one of a handful of seeds must observe a
    // drop (all-zero across 5 seeds would be a ~1e-11 event) — and
    // whichever seed does is then deterministic forever.
    let mut observed = None;
    for seed in 1..=5u64 {
        let rep = train_report(&cfg_with("flaky-network", seed));
        assert!(rep.logs.iter().all(|l| l.loss.is_finite()));
        assert_eq!(rep.scenario.name(), "flaky-network");
        if rep.flaky_timeouts_total() > 0 {
            observed = Some((seed, rep));
            break;
        }
    }
    let (seed, rep) = observed.expect("no seed in 1..=5 injected a drop");
    // byte-identical replay, including the per-worker flaky columns
    let again = train_report(&cfg_with("flaky-network", seed));
    assert_eq!(
        rep.render(Format::Json),
        again.render(Format::Json),
        "flaky-network replay drifted (seed {seed})"
    );
    assert_eq!(rep.flaky_timeouts_total(), again.flaky_timeouts_total());
    // the report's JSON names the observed drops
    let json = Json::parse(rep.render(Format::Json).trim()).unwrap();
    let scen = json.field("scenario").unwrap();
    assert_eq!(
        scen.field_f64("flaky_timeouts").unwrap(),
        rep.flaky_timeouts_total() as f64
    );
    // flaky alone leaves every timing lens at identity
    for w in &rep.workers {
        assert_eq!(w.lens.compute_mult, 1.0);
        assert_eq!(w.lens.bandwidth_mult, 1.0);
    }
}

#[test]
fn scenario_overhead_is_observed_in_the_report() {
    // stragglers stretch the virtual timeline, and the report says so
    let rep = train_report(&cfg_with("straggler", 7));
    let pct = rep.scenario_overhead_pct().expect("virtual clock active");
    assert!(pct > 0.0, "straggler overhead not observed: {pct}");
    assert!(rep.cold_start_total_s > 0.0, "cold starts never charged");
    // generations reconcile with restarts: one launch per worker plus
    // one per restart
    let gens: u64 = rep.workers.iter().map(|w| w.generations as u64).sum();
    assert_eq!(gens, rep.workers.len() as u64 + rep.restarts as u64);
}
