//! Deterministic fleet replay — the multi-tenant tier's mirror of
//! `serve_replay.rs`: the same (roster, scenario, seed) must render the
//! byte-identical `FleetReport` (JSON and table) across two fully
//! independent sessions under each time-varying lens, admission must be
//! strict FIFO by (submit, config order), cross-tenant bandwidth
//! sharing must never hand a concurrent tenant more than its solo
//! bandwidth, and `spot-revocation` must force queued re-admissions
//! that show up in the audit trail.

use funcpipe::config::ExperimentConfig;
use funcpipe::experiment::{Experiment, Format, PlanArtifact, Report};
use funcpipe::fleet::{FleetSpec, TenantKind, TenantSpec};
use funcpipe::serve::TrafficSpec;
use funcpipe::simcore::ScenarioSpec;

fn artifact(batch: usize) -> PlanArtifact {
    let cfg = ExperimentConfig {
        model: "resnet101".into(),
        global_batch: batch,
        merge_layers: 4,
        ..ExperimentConfig::default()
    };
    let exp = Experiment::new(cfg).unwrap();
    exp.plan().unwrap().recommended().unwrap().artifact.clone()
}

fn train(name: &str, steps: usize, batch: usize, submit_s: f64) -> TenantSpec {
    TenantSpec {
        name: name.to_string(),
        kind: TenantKind::Train { steps },
        artifact: artifact(batch),
        submit_s,
    }
}

fn serve(name: &str, rpm: &str, submit_s: f64) -> TenantSpec {
    TenantSpec {
        name: name.to_string(),
        kind: TenantKind::Serve {
            traffic: TrafficSpec::parse(rpm).unwrap(),
            duration_s: 15.0,
            seed: 7,
        },
        artifact: artifact(16),
        submit_s,
    }
}

/// The ISSUE acceptance roster: two training tenants and one serving
/// deployment, staggered submits, one shared platform.
fn mixed_fleet() -> FleetSpec {
    FleetSpec {
        tenants: vec![
            train("alpha", 25, 16, 0.0),
            train("beta", 15, 64, 2.0),
            serve("gamma", "poisson:600", 4.0),
        ],
        max_concurrency: None,
    }
}

fn lens(name: &str) -> ScenarioSpec {
    ScenarioSpec::parse(name).unwrap()
}

#[test]
fn mixed_fleet_replays_byte_identically_under_each_time_varying_lens() {
    for l in ["bandwidth-decay", "cold-start-storm", "spot-revocation"] {
        // two fully independent sessions — nothing shared but the
        // config-file-equivalent inputs (plans re-planned from scratch)
        let ra = Experiment::fleet(&mixed_fleet(), &lens(l), 7).unwrap();
        let rb = Experiment::fleet(&mixed_fleet(), &lens(l), 7).unwrap();
        assert_eq!(
            ra.render(Format::Json),
            rb.render(Format::Json),
            "{l}: JSON drifted"
        );
        assert_eq!(
            ra.render(Format::Table),
            rb.render(Format::Table),
            "{l}: table drifted"
        );
        let out = &ra.outcome;
        assert_eq!(out.scenario, l);
        assert_eq!(out.tenants.len(), 3);
        assert!(out.makespan_s > 0.0, "{l}: empty run");
        for t in &out.tenants {
            assert!(t.units > 0, "{l}: {} ran no units", t.name);
            assert!(t.finish_s > t.admit_s, "{l}: {} never ran", t.name);
            assert!(t.busy_s > 0.0 && t.cost_usd > 0.0, "{l}: {}", t.name);
            assert!(t.mean_contention >= 1.0, "{l}: {}", t.name);
        }
        assert!(out.total_cost_usd > 0.0, "{l}");
    }
    // a different seed draws a different decay wobble
    let r7 = Experiment::fleet(&mixed_fleet(), &lens("bandwidth-decay"), 7)
        .unwrap();
    let r8 = Experiment::fleet(&mixed_fleet(), &lens("bandwidth-decay"), 8)
        .unwrap();
    assert_ne!(
        r7.render(Format::Json),
        r8.render(Format::Json),
        "seed 8 replayed seed 7's draws"
    );
}

#[test]
fn admission_is_fifo_by_submit_then_config_order() {
    let det = lens("deterministic");
    // staggered submits admit in submit order (capacity is ample)
    let r = Experiment::fleet(&mixed_fleet(), &det, 7).unwrap();
    assert_eq!(r.outcome.admissions, ["alpha", "beta", "gamma"]);
    assert_eq!(r.outcome.tenants.iter().map(|t| t.admissions).sum::<usize>(), 3);
    // equal submit times tie-break by config order, not by name or size
    let tie = FleetSpec {
        tenants: vec![
            train("zeta", 5, 64, 1.0),
            train("alpha", 5, 16, 1.0),
        ],
        max_concurrency: None,
    };
    let r = Experiment::fleet(&tie, &det, 7).unwrap();
    assert_eq!(r.outcome.admissions, ["zeta", "alpha"]);
}

#[test]
fn a_tight_pool_queues_the_second_tenant_behind_the_first() {
    let det = lens("deterministic");
    let a = train("alpha", 10, 16, 0.0);
    let b = train("beta", 10, 64, 0.0);
    // each tenant fits the pool alone, but never both at once
    let pool = a.artifact.plan.n_workers().max(b.artifact.plan.n_workers());
    let spec = FleetSpec {
        tenants: vec![a, b],
        max_concurrency: Some(pool),
    };
    let r = Experiment::fleet(&spec, &det, 7).unwrap();
    let out = &r.outcome;
    assert_eq!(out.max_concurrency, pool);
    assert!(out.peak_workers <= pool, "admission overshot the pool");
    assert_eq!(out.admissions, ["alpha", "beta"], "FIFO broke");
    let alpha = &out.tenants[0];
    let beta = &out.tenants[1];
    assert!(alpha.wait_s == 0.0, "head tenant waited {}", alpha.wait_s);
    assert!(beta.wait_s > 0.0, "beta never queued");
    assert!(
        beta.admit_s >= alpha.finish_s,
        "beta admitted at {} before alpha finished at {}",
        beta.admit_s,
        alpha.finish_s
    );
}

#[test]
fn concurrent_tenants_each_observe_at_most_solo_bandwidth() {
    let det = lens("deterministic");
    // solo: the tenant only ever shares the platform with itself
    let solo =
        Experiment::fleet(
            &FleetSpec {
                tenants: vec![train("alpha", 10, 16, 0.0)],
                max_concurrency: None,
            },
            &det,
            7,
        )
        .unwrap();
    let solo_alpha = &solo.outcome.tenants[0];
    assert!(
        (solo_alpha.mean_contention - 1.0).abs() < 1e-12,
        "solo tenant saw contention {}",
        solo_alpha.mean_contention
    );
    // concurrent: same alpha plus an overlapping beta
    let both = Experiment::fleet(
        &FleetSpec {
            tenants: vec![
                train("alpha", 10, 16, 0.0),
                train("beta", 10, 64, 0.0),
            ],
            max_concurrency: None,
        },
        &det,
        7,
    )
    .unwrap();
    for t in &both.outcome.tenants {
        assert!(
            t.mean_contention >= 1.0,
            "{}: contention {} < 1 — a tenant got more than its solo \
             bandwidth",
            t.name,
            t.mean_contention
        );
    }
    let alpha = &both.outcome.tenants[0];
    let beta = &both.outcome.tenants[1];
    assert!(
        alpha.busy_s >= solo_alpha.busy_s - 1e-9,
        "contention made alpha faster: {} vs solo {}",
        alpha.busy_s,
        solo_alpha.busy_s
    );
    // the per-worker degradation factor is tier-independent, so the
    // stretch is strict whenever the combined count sits below the
    // platform's contention floor knee
    let p = funcpipe::platform::PlatformSpec::aws_lambda();
    let factor = |n: usize| {
        (1.0 - p.contention_slope * n.saturating_sub(1) as f64)
            .max(p.contention_floor)
    };
    if factor(alpha.workers) > factor(alpha.workers + beta.workers) {
        assert!(
            alpha.mean_contention > solo_alpha.mean_contention,
            "overlap did not stretch alpha's communication"
        );
        assert!(both.outcome.mean_contention > 1.0);
    }
}

#[test]
fn spot_revocation_forces_queued_readmission() {
    let spec = FleetSpec {
        tenants: vec![
            train("alpha", 30, 16, 0.0),
            train("beta", 20, 64, 0.0),
        ],
        max_concurrency: None,
    };
    // the lens draws are deterministic per seed; scan a few seeds so the
    // test does not hinge on one seed's draw pattern
    let hit = (1..=5)
        .map(|seed| {
            Experiment::fleet(&spec, &lens("spot-revocation"), seed).unwrap()
        })
        .find(|r| r.outcome.tenants.iter().any(|t| t.revocations > 0))
        .expect("no revocation fired across seeds 1..=5");
    let out = &hit.outcome;
    for t in &out.tenants {
        // every revocation forced exactly one queued re-admission
        assert_eq!(
            t.admissions,
            1 + t.revocations,
            "{}: {} admissions for {} revocations",
            t.name,
            t.admissions,
            t.revocations
        );
        // ...and each shows up in the FIFO audit trail by name
        let granted =
            out.admissions.iter().filter(|n| *n == &t.name).count();
        assert_eq!(granted, t.admissions, "{}: audit trail", t.name);
    }
    assert!(
        out.admissions.len() > out.tenants.len(),
        "re-admissions missing from the audit trail"
    );
    // the run still replays byte-identically under revocations
    let again =
        Experiment::fleet(&spec, &lens("spot-revocation"), out.seed).unwrap();
    assert_eq!(
        hit.render(Format::Json),
        again.render(Format::Json),
        "revocation replay drifted"
    );
}
