//! Equivalence suite for the unified simcore engine: the refactored
//! paths (collective FlowGraph emitters, pipeline translation, FlowSim
//! facade) must reproduce the behaviour the three historical engines
//! pinned down, across a fixture matrix of all sync algorithms ×
//! chunked/unchunked × dp ∈ {1, 2, 4} × uneven splits — chunked exact
//! against unchunked at zero latency, event-loop against closed form
//! within the existing `rel_err_pct` tolerance.

use funcpipe::collective::sim::{
    emit_parameter_server, emit_pipelined_scatter_reduce,
    emit_scatter_reduce, simulate_parameter_server,
    simulate_pipelined_scatter_reduce,
    simulate_pipelined_scatter_reduce_chunked, simulate_scatter_reduce,
    simulate_scatter_reduce_chunked,
};
use funcpipe::collective::{ps_sync_time, sync_time, SyncAlgorithm};
use funcpipe::model::{merge_layers, zoo, MergeCriterion, Plan};
use funcpipe::pipeline::{rel_err_pct, simulate_iteration};
use funcpipe::planner::PerfModel;
use funcpipe::platform::network::{BandwidthModel, Dir, FlowSim};
use funcpipe::platform::PlatformSpec;
use funcpipe::simcore::execute;

const MB: f64 = 1.0e6;

/// All sync algorithms × chunked/unchunked × group size ∈ {2, 4} ×
/// uneven byte totals: the flow schedule agrees with the closed form
/// (zero latency), and every chunked variant is exact against its
/// unchunked schedule (same bytes, same links, same barriers).
#[test]
fn collective_matrix_matches_closed_forms() {
    // deliberately uneven: neither divisible by the group size nor by
    // the chunk size
    for grad in [97.3 * MB, 281.7 * MB] {
        for n in [2usize, 4] {
            let net = BandwidthModel::uniform(n, 70.0 * MB, 0.0);
            for alg in [
                SyncAlgorithm::ScatterReduce,
                SyncAlgorithm::PipelinedScatterReduce,
            ] {
                let unchunked = match alg {
                    SyncAlgorithm::ScatterReduce => {
                        simulate_scatter_reduce(n, grad, &net)
                    }
                    SyncAlgorithm::PipelinedScatterReduce => {
                        simulate_pipelined_scatter_reduce(n, grad, &net)
                    }
                };
                let formula = sync_time(alg, grad, n, 70.0 * MB, 0.0);
                assert!(
                    rel_err_pct(unchunked, formula) < 15.0,
                    "{alg:?} n={n} grad={grad}: sim {unchunked} vs {formula}"
                );
                for chunk in [3.3 * MB, 16.0 * MB] {
                    let chunked = match alg {
                        SyncAlgorithm::ScatterReduce => {
                            simulate_scatter_reduce_chunked(
                                n, grad, &net, chunk,
                            )
                        }
                        SyncAlgorithm::PipelinedScatterReduce => {
                            simulate_pipelined_scatter_reduce_chunked(
                                n, grad, &net, chunk,
                            )
                        }
                    };
                    // chunked exact: at zero latency granularity is free
                    // (the pipelined fill can only shrink, never grow)
                    let tol = 1e-5 * unchunked;
                    assert!(
                        chunked <= unchunked + tol,
                        "{alg:?} n={n} chunk={chunk}: {chunked} > {unchunked}"
                    );
                    match alg {
                        SyncAlgorithm::ScatterReduce => assert!(
                            (chunked - unchunked).abs() <= tol,
                            "{alg:?} n={n} chunk={chunk}: {chunked} vs {unchunked}"
                        ),
                        SyncAlgorithm::PipelinedScatterReduce => assert!(
                            chunked >= grad / (70.0 * MB) * (1.0 - 1e-9),
                            "beats the occupancy floor"
                        ),
                    }
                }
            }
        }
    }
}

/// The historical `simulate_*` entry points are delegating wrappers:
/// emit + execute produces the identical number, bit for bit.
#[test]
fn wrappers_delegate_to_emitted_graphs() {
    let net = BandwidthModel::uniform(4, 70.0 * MB, 0.01);
    let grad = 123.4 * MB;
    assert_eq!(
        simulate_scatter_reduce(4, grad, &net),
        execute(&emit_scatter_reduce(4, grad, &net, 0.0)).makespan
    );
    assert_eq!(
        simulate_pipelined_scatter_reduce(4, grad, &net),
        execute(&emit_pipelined_scatter_reduce(4, grad, &net, 0.0)).makespan
    );
    assert_eq!(
        simulate_scatter_reduce_chunked(4, grad, &net, 8.0 * MB),
        execute(&emit_scatter_reduce(4, grad, &net, 8.0 * MB)).makespan
    );
    assert_eq!(
        simulate_pipelined_scatter_reduce_chunked(4, grad, &net, 8.0 * MB),
        execute(&emit_pipelined_scatter_reduce(4, grad, &net, 8.0 * MB))
            .makespan
    );
    let mut ps_net = BandwidthModel::uniform(5, 70.0 * MB, 0.0);
    ps_net.up_bps[4] = 1.25e9;
    ps_net.down_bps[4] = 1.25e9;
    assert_eq!(
        simulate_parameter_server(4, grad, &ps_net),
        execute(&emit_parameter_server(4, grad, &ps_net)).makespan
    );
}

/// The parameter-server schedule still tracks its closed form on the
/// unified engine (two-endpoint direct flows, max-min shared).
#[test]
fn parameter_server_matches_formula() {
    let n = 8;
    let mut net = BandwidthModel::uniform(n + 1, 70.0 * MB, 0.0);
    net.up_bps[n] = 1.25e9;
    net.down_bps[n] = 1.25e9;
    let sim = simulate_parameter_server(n, 100.0 * MB, &net);
    let agg = n as f64 * 100.0 * MB
        / funcpipe::collective::analytic::PS_SERVER_PROC_BPS;
    let formula =
        ps_sync_time(100.0 * MB, n, 70.0 * MB, 1.25e9, 0.0) - agg;
    assert!(
        rel_err_pct(sim, formula) < 15.0,
        "sim {sim} vs formula {formula}"
    );
}

/// Pipeline DES vs closed-form model across the plan matrix:
/// dp ∈ {1, 2, 4} × even and uneven partitions × both sync algorithms,
/// within the historical 20% tolerance (exact for the 1-worker plan).
#[test]
fn pipeline_matrix_tracks_perf_model() {
    let p = PlatformSpec::aws_lambda();
    let m = merge_layers(&zoo::amoebanet_d18(&p), 6, MergeCriterion::Compute);
    let mut checked = 0;
    for alg in [
        SyncAlgorithm::ScatterReduce,
        SyncAlgorithm::PipelinedScatterReduce,
    ] {
        let pm = PerfModel::new(&m, &p).with_sync(alg);
        // cuts chosen to produce uneven layer splits of the 6 merged
        // layers: [1] → 2+4, [1, 3] → 2+2+2, [0, 1] → 1+1+4
        for cuts in [vec![], vec![1], vec![1, 3], vec![0, 1]] {
            for dp in [1usize, 2, 4] {
                let s = cuts.len() + 1;
                let plan = Plan {
                    cuts: cuts.clone(),
                    dp,
                    stage_tiers: vec![p.max_tier(); s],
                    n_micro_global: 4 * dp,
                };
                if plan.validate(&m, &p).is_err() {
                    continue;
                }
                let sim = simulate_iteration(&m, &p, &plan, alg);
                let perf = pm.evaluate(&plan);
                let err = rel_err_pct(perf.t_iter, sim.t_iter);
                let tol = if s == 1 && dp == 1 { 1e-4 } else { 20.0 };
                assert!(
                    err < tol,
                    "{alg:?} {plan:?}: sim {} model {} err {err:.2}%",
                    sim.t_iter,
                    perf.t_iter
                );
                checked += 1;
            }
        }
    }
    assert!(checked >= 12, "only {checked} feasible matrix points");
}

/// The FlowSim facade (kept for its public API) delegates to the same
/// engine: a hand-built flow set behaves exactly as the direct graph.
#[test]
fn flowsim_facade_is_the_unified_engine() {
    let model = BandwidthModel::uniform(2, 100.0, 0.5);
    let mut sim = FlowSim::new(model);
    let a = sim.add_flow(0, Dir::Up, 100.0, 0.0);
    let b = sim.add_flow_after(1, Dir::Down, 100.0, vec![a], 0.0);
    let c = sim.add_direct_flow_after(0, 1, 50.0, vec![b], 0.0);
    let makespan = sim.run();
    // a: 0.5 latency + 1 s; b: 1.5 + 0.5 + 1 s = 3.0; c: 3.5 + 0.5
    assert!((sim.finish_time(a) - 1.5).abs() < 1e-9);
    assert!((sim.finish_time(b) - 3.0).abs() < 1e-9);
    assert!((sim.finish_time(c) - 4.0).abs() < 1e-9);
    assert_eq!(makespan, sim.finish_time(c));
    assert_eq!(sim.bytes(c), 50.0);
}
