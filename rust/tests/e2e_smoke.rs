//! End-to-end smoke: full three-layer stack (AOT artifacts through PJRT,
//! pipeline over storage, sync, SGD, checkpoint/restart) in one short run.
//! Skipped if `make artifacts` has not been run.

use std::path::PathBuf;

use funcpipe::collective::SyncAlgorithm;
use funcpipe::trainer::{train, TrainConfig};

fn artifacts() -> Option<PathBuf> {
    let d = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    d.join("manifest.json").exists().then_some(d)
}

#[test]
fn pipelined_and_plain_sync_learn_identically() {
    let Some(dir) = artifacts() else {
        eprintln!("skipping: run `make artifacts`");
        return;
    };
    let run = |alg| {
        let mut cfg = TrainConfig::new(dir.clone());
        cfg.steps = 4;
        cfg.dp = 2;
        cfg.mu = 1;
        cfg.sync_alg = alg;
        train(&cfg).unwrap().logs.iter().map(|l| l.loss).collect::<Vec<f32>>()
    };
    let a = run(SyncAlgorithm::PipelinedScatterReduce);
    let b = run(SyncAlgorithm::ScatterReduce);
    // same data + same deterministic init -> identical loss trajectories
    for (x, y) in a.iter().zip(&b) {
        assert!((x - y).abs() < 1e-4, "{a:?} vs {b:?}");
    }
}

#[test]
fn throttled_run_is_slower_but_learns() {
    let Some(dir) = artifacts() else {
        return;
    };
    let mut fast = TrainConfig::new(dir.clone());
    fast.steps = 4;
    fast.mu = 1;
    let rf = train(&fast).unwrap();

    let mut slow = fast.clone();
    slow.throttle = Some((0.5e6, 0.01)); // 0.5 MB/s per worker + 10 ms lat
    let rs = train(&slow).unwrap();
    // compare steady-state iterations (step 0 includes PJRT compilation),
    // which are dominated by the ~65 ms-per-transfer throttle
    let steady = |r: &funcpipe::trainer::TrainReport| {
        r.logs[1..].iter().map(|l| l.iter_s).sum::<f64>()
            / (r.logs.len() - 1) as f64
    };
    assert!(
        steady(&rs) > steady(&rf) * 1.5,
        "throttle had no effect: {} vs {}",
        steady(&rs),
        steady(&rf)
    );
    // identical numerics regardless of bandwidth
    for (a, b) in rf.logs.iter().zip(&rs.logs) {
        assert!((a.loss - b.loss).abs() < 1e-4);
    }
}

#[test]
fn dp_and_single_worker_equal_gradients() {
    // dp=2 with half the micro-batches each must equal dp=1 numerics
    let Some(dir) = artifacts() else {
        return;
    };
    let mut one = TrainConfig::new(dir.clone());
    one.steps = 3;
    one.dp = 1;
    one.mu = 2;
    let mut two = one.clone();
    two.dp = 2;
    two.mu = 1;
    let r1 = train(&one).unwrap();
    let r2 = train(&two).unwrap();
    // the same global batch is split differently, so losses differ, but
    // both runs must be finite and decreasing-ish
    assert!(r1.logs.iter().all(|l| l.loss.is_finite()));
    assert!(r2.logs.iter().all(|l| l.loss.is_finite()));
}
