//! Property-based tests on the coordinator-side invariants, using the
//! in-tree quickcheck harness (proptest is unavailable offline).

use funcpipe::collective::split_ranges;
use funcpipe::model::{merge_layers, zoo, MergeCriterion, Plan};
use funcpipe::pipeline::build_schedule;
use funcpipe::planner::PerfModel;
use funcpipe::platform::network::{max_min_rates, BandwidthModel, Dir};
use funcpipe::platform::PlatformSpec;
use funcpipe::util::quickcheck::{check, check_with, Config, Gen, PairOf, UsizeRange};
use funcpipe::util::rng::Rng;

/// Generator for random valid plans on a merged zoo model.
struct PlanGen {
    l: usize,
    n_tiers: usize,
}

impl Gen for PlanGen {
    type Value = Plan;

    fn generate(&self, rng: &mut Rng) -> Plan {
        let n_cuts = rng.index(self.l.min(4));
        let mut cuts: Vec<usize> = (0..n_cuts)
            .map(|_| rng.index(self.l - 1))
            .collect();
        cuts.sort_unstable();
        cuts.dedup();
        let s = cuts.len() + 1;
        let dp = [1usize, 2, 4][rng.index(3)];
        Plan {
            cuts,
            dp,
            stage_tiers: (0..s).map(|_| rng.index(self.n_tiers)).collect(),
            n_micro_global: dp * (1 + rng.index(8)),
        }
    }

    fn shrink(&self, v: &Plan) -> Vec<Plan> {
        let mut out = Vec::new();
        if !v.cuts.is_empty() {
            let mut p = v.clone();
            p.cuts.pop();
            p.stage_tiers.pop();
            out.push(p);
        }
        if v.dp > 1 {
            let mut p = v.clone();
            p.n_micro_global /= p.dp;
            p.dp = 1;
            out.push(p);
        }
        out
    }
}

#[test]
fn prop_schedule_dag_is_valid_for_all_plans() {
    let p = PlatformSpec::aws_lambda();
    let m = merge_layers(&zoo::resnet101(&p), 8, MergeCriterion::Compute);
    check_with(
        Config { cases: 200, ..Config::default() },
        &PlanGen { l: m.n_layers(), n_tiers: p.n_tiers() },
        |plan| build_schedule(plan).validate().is_ok(),
    );
}

#[test]
fn prop_perf_model_outputs_positive_and_consistent() {
    let p = PlatformSpec::aws_lambda();
    let m = merge_layers(&zoo::bert_large(&p), 8, MergeCriterion::Compute);
    let pm = PerfModel::new(&m, &p);
    check_with(
        Config { cases: 300, ..Config::default() },
        &PlanGen { l: m.n_layers(), n_tiers: p.n_tiers() },
        |plan| {
            let perf = pm.evaluate(plan);
            perf.t_iter > 0.0
                && perf.c_iter > 0.0
                && perf.t_iter.is_finite()
                && (perf.compute_s + perf.flush_s + perf.sync_s - perf.t_iter)
                    .abs()
                    < 1e-6 * perf.t_iter
        },
    );
}

#[test]
fn prop_more_bandwidth_never_hurts() {
    let p1 = PlatformSpec::aws_lambda();
    let p4 = PlatformSpec::aws_lambda().with_bandwidth_scale(4.0);
    let m = merge_layers(&zoo::amoebanet_d18(&p1), 8, MergeCriterion::Compute);
    let pm1 = PerfModel::new(&m, &p1);
    let pm4 = PerfModel::new(&m, &p4);
    check_with(
        Config { cases: 200, ..Config::default() },
        &PlanGen { l: m.n_layers(), n_tiers: p1.n_tiers() },
        |plan| pm4.evaluate(plan).t_iter <= pm1.evaluate(plan).t_iter + 1e-9,
    );
}

#[test]
fn prop_split_ranges_partition_exactly() {
    check(&PairOf(UsizeRange(1, 100_000), UsizeRange(1, 64)), |&(n, k)| {
        let r = split_ranges(n, k);
        r.len() == k
            && r[0].0 == 0
            && r[k - 1].1 == n
            && r.windows(2).all(|w| w[0].1 == w[1].0)
    });
}

/// Random flow sets: max-min allocation never exceeds any link capacity
/// and gives every flow a positive rate.
struct FlowsGen;

impl Gen for FlowsGen {
    type Value = (usize, Vec<(usize, Dir)>);

    fn generate(&self, rng: &mut Rng) -> Self::Value {
        let n = 1 + rng.index(6);
        let nf = 1 + rng.index(12);
        let flows = (0..nf)
            .map(|_| {
                (
                    rng.index(n),
                    if rng.chance(0.5) { Dir::Up } else { Dir::Down },
                )
            })
            .collect();
        (n, flows)
    }
}

#[test]
fn prop_max_min_rates_respect_capacities() {
    check_with(Config { cases: 300, ..Config::default() }, &FlowsGen, |(n, flows)| {
        let model = BandwidthModel::uniform(*n, 100.0, 0.0);
        let eps = 1e-6;
        let fl: Vec<Vec<(usize, Dir)>> =
            flows.iter().map(|&e| vec![e]).collect();
        let rates = max_min_rates(&model, &fl);
        if rates.iter().any(|&r| r <= 0.0) {
            return false;
        }
        for w in 0..*n {
            for dir in [Dir::Up, Dir::Down] {
                let used: f64 = flows
                    .iter()
                    .zip(&rates)
                    .filter(|((fw, fd), _)| *fw == w && *fd == dir)
                    .map(|(_, r)| *r)
                    .sum();
                if used > 100.0 + eps {
                    return false;
                }
            }
        }
        true
    });
}

#[test]
fn prop_plan_memory_check_monotone_in_mu() {
    // increasing μ can only increase memory demand (3b)
    let p = PlatformSpec::aws_lambda();
    let m = merge_layers(&zoo::amoebanet_d36(&p), 8, MergeCriterion::Compute);
    check_with(
        Config { cases: 200, ..Config::default() },
        &PlanGen { l: m.n_layers(), n_tiers: p.n_tiers() },
        |plan| {
            let mut bigger = plan.clone();
            bigger.n_micro_global = plan.n_micro_global * 2;
            (0..plan.n_stages()).all(|s| {
                plan.stage_mem_bytes(&m, &p, s)
                    <= bigger.stage_mem_bytes(&m, &p, s)
            })
        },
    );
}
