//! Pipeline DES vs closed-form model across a plan grid — the substance
//! behind Table 3 (model accuracy).

use funcpipe::collective::SyncAlgorithm;
use funcpipe::model::{merge_layers, zoo, MergeCriterion, Plan};
use funcpipe::pipeline::{build_schedule, simulate_iteration};
use funcpipe::planner::PerfModel;
use funcpipe::platform::PlatformSpec;

#[test]
fn model_within_20pct_of_sim_across_grid() {
    let p = PlatformSpec::aws_lambda();
    for name in ["resnet101", "bert-large"] {
        let m = merge_layers(
            &zoo::by_name(name, &p).unwrap(),
            6,
            MergeCriterion::Compute,
        );
        let pm = PerfModel::new(&m, &p);
        let mut checked = 0;
        for cuts in [vec![], vec![2], vec![1, 3]] {
            for dp in [1usize, 2, 4] {
                let s = cuts.len() + 1;
                let plan = Plan {
                    cuts: cuts.clone(),
                    dp,
                    stage_tiers: vec![p.max_tier(); s],
                    n_micro_global: 8 * dp,
                };
                if plan.validate(&m, &p).is_err() {
                    continue;
                }
                let sim = simulate_iteration(
                    &m, &p, &plan, SyncAlgorithm::PipelinedScatterReduce,
                );
                let perf = pm.evaluate(&plan);
                let err = (sim.t_iter - perf.t_iter).abs() / sim.t_iter;
                assert!(
                    err < 0.20,
                    "{name} {plan:?}: sim {} model {} err {err:.3}",
                    sim.t_iter,
                    perf.t_iter
                );
                checked += 1;
            }
        }
        assert!(checked >= 4, "{name}: too few feasible plans");
    }
}

#[test]
fn schedule_scales_with_all_dimensions() {
    for s in [1usize, 2, 4] {
        for d in [1usize, 2] {
            for mu in [1usize, 4] {
                let plan = Plan {
                    cuts: (0..s - 1).collect(),
                    dp: d,
                    stage_tiers: vec![0; s],
                    n_micro_global: mu * d,
                };
                let sched = build_schedule(&plan);
                sched.validate().unwrap();
                assert_eq!(sched.n_workers(), s * d);
                // every worker computes 2*mu tasks
                for w in 0..sched.n_workers() {
                    let computes = sched
                        .worker_tasks(w)
                        .iter()
                        .filter(|t| {
                            matches!(
                                t.kind,
                                funcpipe::pipeline::TaskKind::FwdCompute { .. }
                                    | funcpipe::pipeline::TaskKind::BwdCompute { .. }
                            )
                        })
                        .count();
                    assert_eq!(computes, 2 * mu);
                }
            }
        }
    }
}

#[test]
fn pipelining_amortizes_micro_batches() {
    // t(2µ) << 2*t(µ) for multi-stage plans (the point of the pipeline)
    let p = PlatformSpec::aws_lambda();
    let m = merge_layers(
        &zoo::amoebanet_d18(&p),
        6,
        MergeCriterion::Compute,
    );
    let mk = |mm: usize| Plan {
        cuts: vec![1, 3],
        dp: 1,
        stage_tiers: vec![p.max_tier(); 3],
        n_micro_global: mm,
    };
    let t4 = simulate_iteration(&m, &p, &mk(4), SyncAlgorithm::PipelinedScatterReduce).t_iter;
    let t8 = simulate_iteration(&m, &p, &mk(8), SyncAlgorithm::PipelinedScatterReduce).t_iter;
    assert!(t8 < 1.7 * t4, "no pipelining amortization: {t4} -> {t8}");
}
