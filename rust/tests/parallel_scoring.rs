//! PR 8 determinism suite: the parallel scoring work-queue and the
//! work-sharing branch-and-bound must be *invisible* in every rendered
//! byte — parallel robust/SLO scores are bit-identical to the serial
//! reference, `solve_parallel` recommends the exact plan `solve_with`
//! does, two independent sessions render byte-identical race reports,
//! and the sharded `StageCache` still earns its hit rate under a full
//! registry race.

use funcpipe::config::ExperimentConfig;
use funcpipe::experiment::{Experiment, Format, Report};
use funcpipe::model::{merge_layers, zoo, MergeCriterion, ModelProfile};
use funcpipe::pipeline::simulate_iteration_scenario;
use funcpipe::planner::{
    optimizer, race, robust_scores, slo_scores, PerfModel, PlanRequest,
    RobustRank, RobustSpec, SloSpec, DEFAULT_WEIGHTS, STRATEGIES,
};
use funcpipe::platform::PlatformSpec;
use funcpipe::serve::TrafficSpec;
use funcpipe::simcore::ScenarioSpec;

fn small_model(name: &str, p: &PlatformSpec) -> ModelProfile {
    merge_layers(&zoo::by_name(name, p).unwrap(), 4, MergeCriterion::Compute)
}

fn finalists(perf: &PerfModel<'_>) -> Vec<funcpipe::model::Plan> {
    let mut req = PlanRequest::new(16);
    req.dp_options = vec![1, 2];
    let mut plans = Vec::new();
    for name in STRATEGIES {
        let out =
            funcpipe::planner::solve_request(name, perf, &req).unwrap();
        for c in out.candidates {
            if !plans.contains(&c.plan) {
                plans.push(c.plan);
            }
        }
    }
    plans
}

/// The work-queue scorers reproduce the historical serial loops bit for
/// bit on a realistic finalist set (every distinct plan the whole
/// registry produces), for both the robust DES replays and the SLO
/// serving replays.
#[test]
fn parallel_scoring_is_bit_identical_to_the_serial_reference() {
    let p = PlatformSpec::aws_lambda();
    let m = small_model("resnet101", &p);
    let perf = PerfModel::new(&m, &p);
    let plans = finalists(&perf);
    assert!(plans.len() >= 2, "need a real finalist set");

    let rspec = RobustSpec {
        scenario: ScenarioSpec::parse("straggler+jitter").unwrap(),
        seeds: 8,
        rank: RobustRank::Worst,
    };
    let scores = robust_scores(&perf, &plans, &rspec);
    assert_eq!(scores.len(), plans.len());
    for (plan, score) in plans.iter().zip(&scores) {
        let (mut worst_t, mut worst_c) = (0.0f64, 0.0f64);
        let (mut sum_t, mut sum_c) = (0.0f64, 0.0f64);
        for seed in 1..=rspec.seeds as u64 {
            let sim = simulate_iteration_scenario(
                &m,
                &p,
                plan,
                perf.sync_alg,
                &rspec.scenario,
                seed,
            );
            worst_t = worst_t.max(sim.t_iter);
            worst_c = worst_c.max(sim.c_iter);
            sum_t += sim.t_iter;
            sum_c += sim.c_iter;
        }
        let n = rspec.seeds as f64;
        assert_eq!(score.worst_t.to_bits(), worst_t.to_bits());
        assert_eq!(score.worst_c.to_bits(), worst_c.to_bits());
        assert_eq!(score.mean_t.to_bits(), (sum_t / n).to_bits());
        assert_eq!(score.mean_c.to_bits(), (sum_c / n).to_bits());
    }

    let sspec = SloSpec {
        p99_ms: 120_000.0,
        traffic: TrafficSpec::parse("poisson:300").unwrap(),
        seeds: 2,
    };
    let scores = slo_scores(&perf, &plans, &sspec).unwrap();
    assert_eq!(scores.len(), plans.len());
    for (plan, score) in plans.iter().zip(&scores) {
        let mut worst_p99 = 0.0f64;
        let mut sum_cost = 0.0f64;
        let mut all_served = true;
        for seed in 1..=sspec.seeds as u64 {
            let mut opts = funcpipe::serve::ServeOptions::new(
                sspec.traffic.clone(),
                seed,
            );
            opts.duration_s = funcpipe::planner::strategy::SLO_REPLAY_DURATION_S;
            let out =
                funcpipe::serve::serve_plan(&perf, plan, &opts).unwrap();
            worst_p99 = worst_p99.max(out.p99_ms);
            sum_cost += out.cost_per_1k_usd;
            all_served &= out.completed > 0;
        }
        assert_eq!(score.p99_ms.to_bits(), worst_p99.to_bits());
        assert_eq!(
            score.cost_per_1k_usd.to_bits(),
            (sum_cost / sspec.seeds as f64).to_bits()
        );
        assert_eq!(
            score.feasible,
            all_served && worst_p99 <= sspec.p99_ms
        );
    }
}

/// Two *independent* sessions (fresh `Experiment`, fresh `PerfModel`,
/// fresh caches, fresh thread pools) running the full `--strategy all`
/// race with robust AND SLO scoring render byte-identical JSON — the
/// in-process form of the CI two-run `cmp`.
#[test]
fn two_sessions_render_byte_identical_robust_slo_race_reports() {
    let run = || {
        let cfg = ExperimentConfig {
            model: "resnet101".into(),
            global_batch: 16,
            merge_layers: 4,
            ..ExperimentConfig::default()
        };
        let exp = Experiment::new(cfg).unwrap();
        let mut req = exp.plan_request();
        req.dp_options = vec![1, 2];
        req.robust = Some(RobustSpec {
            scenario: ScenarioSpec::parse("straggler+jitter").unwrap(),
            seeds: 4,
            rank: RobustRank::Worst,
        });
        req.slo = Some(SloSpec {
            p99_ms: 300_000.0,
            traffic: TrafficSpec::parse("poisson:240").unwrap(),
            seeds: 2,
        });
        exp.plan_race(&req).unwrap().render(Format::Json)
    };
    let a = run();
    let b = run();
    assert!(a.contains("\"strategies\""), "{a}");
    assert_eq!(a, b, "race JSON drifted between independent sessions");
}

/// The work-sharing branch-and-bound returns the exact plan (and the
/// exact evaluated perf bits) of the serial DFS for every default
/// weight pair on three zoo models — the packet ordering + strict
/// shared-bound pruning argument, exercised end to end.
#[test]
fn parallel_bnb_recommends_the_serial_plan_everywhere() {
    let p = PlatformSpec::aws_lambda();
    for name in ["resnet101", "bert-large", "amoebanet-d18"] {
        let m = small_model(name, &p);
        let perf = PerfModel::new(&m, &p);
        for &alpha in &DEFAULT_WEIGHTS {
            let serial = optimizer::solve_with(
                &perf,
                &[1, 2, 4],
                50_000_000,
                16,
                alpha,
            );
            let parallel = optimizer::solve_parallel(
                &perf,
                &[1, 2, 4],
                50_000_000,
                16,
                alpha,
            );
            match (serial, parallel) {
                (Some((ps, perf_s, _)), Some((pp, perf_p, _))) => {
                    assert_eq!(ps, pp, "{name} α={alpha:?}");
                    assert_eq!(
                        perf_s.t_iter.to_bits(),
                        perf_p.t_iter.to_bits(),
                        "{name} α={alpha:?}"
                    );
                    assert_eq!(
                        perf_s.c_iter.to_bits(),
                        perf_p.c_iter.to_bits(),
                        "{name} α={alpha:?}"
                    );
                }
                (None, None) => {}
                (s, q) => panic!(
                    "{name} α={alpha:?}: feasibility diverged \
                     (serial {:?}, parallel {:?})",
                    s.is_some(),
                    q.is_some()
                ),
            }
        }
    }
}

/// The hash-sharded `StageCache` keeps memoization effective under a
/// full registry race: five strategies hammering the one shared model
/// from parallel threads still hit warm entries most of the time.
#[test]
fn sharded_cache_keeps_its_hit_rate_under_a_full_race() {
    let p = PlatformSpec::aws_lambda();
    let m = small_model("resnet101", &p);
    let perf = PerfModel::new(&m, &p);
    let mut req = PlanRequest::new(16);
    req.dp_options = vec![1, 2];
    let outcomes = race(&perf, &req, &STRATEGIES).unwrap();
    assert_eq!(outcomes.len(), STRATEGIES.len());
    let cache = perf.cache();
    assert!(!cache.is_empty());
    assert!(
        cache.hit_rate() > 0.5,
        "sharded cache hit rate collapsed: {:.3} over {} entries",
        cache.hit_rate(),
        cache.len()
    );
}
