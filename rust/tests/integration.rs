//! Cross-module integration: config -> planner -> simulator -> tables.

use funcpipe::config::ExperimentConfig;
use funcpipe::planner::{pareto_front, recommend, sweep, CoOptimizer};

#[test]
fn config_to_plan_to_recommendation() {
    let cfg = ExperimentConfig::from_json_text(
        r#"{"model": "amoebanet-d18", "global_batch": 64, "merge_layers": 6}"#,
    )
    .unwrap();
    let platform = cfg.resolve_platform().unwrap();
    let model = cfg.resolve_model(&platform).unwrap();
    let opt = CoOptimizer::new(&model, &platform);
    let points = sweep(&cfg.weights, |w| {
        opt.solve(cfg.n_micro_global(), w).map(|(p, perf, _)| (p, perf))
    });
    assert!(!points.is_empty());
    let front = pareto_front(&points);
    assert!(!front.is_empty());
    let rec = recommend(&front).unwrap();
    rec.plan.validate(&model, &platform).unwrap();
    // the recommendation is on the frontier
    assert!(front.iter().any(|p| p.plan == rec.plan));
}

#[test]
fn alibaba_aggregate_cap_changes_plans() {
    // with a shared 10 Gb/s OSS cap, large-dp plans lose value (§5.7)
    let mk = |platform: &str| {
        let cfg = ExperimentConfig::from_json_text(&format!(
            r#"{{"model": "amoebanet-d36", "platform": "{platform}",
                "global_batch": 256, "merge_layers": 6}}"#
        ))
        .unwrap();
        let p = cfg.resolve_platform().unwrap();
        let m = cfg.resolve_model(&p).unwrap();
        let opt = CoOptimizer::new(&m, &p);
        let (_plan, perf, _) = opt.solve(cfg.n_micro_global(), (1.0, 2e-4)).unwrap();
        perf
    };
    let aws = mk("aws");
    let ali = mk("alibaba");
    assert!(aws.t_iter > 0.0 && ali.t_iter > 0.0);
}

#[test]
fn weights_trace_monotone_tradeoffs() {
    // larger time-weight never yields a slower plan
    let cfg = ExperimentConfig::default();
    let platform = cfg.resolve_platform().unwrap();
    let model = cfg.resolve_model(&platform).unwrap();
    let opt = CoOptimizer::new(&model, &platform);
    let mut prev_t = f64::INFINITY;
    for w in [(1.0, 0.0), (1.0, 2e-4), (1.0, 2e-2), (0.0, 1.0)] {
        let (_, perf, _) = opt.solve(cfg.n_micro_global(), w).unwrap();
        assert!(
            perf.t_iter <= prev_t + 1e-9,
            "time-weight {w:?} gave slower plan: {} > {prev_t}",
            perf.t_iter
        );
        prev_t = perf.t_iter;
    }
}

#[test]
fn headline_shape_funcpipe_vs_lambdaml() {
    // Fig 5 shape: growing advantage with model size and batch
    let small = funcpipe::bench::headline_comparison("resnet101", 64).unwrap();
    let large = funcpipe::bench::headline_comparison("bert-large", 256).unwrap();
    let sp_small = small.0 / small.2;
    let sp_large = large.0 / large.2;
    assert!(sp_large > sp_small, "speedup should grow: {sp_small} -> {sp_large}");
    assert!(sp_large > 1.3, "paper band is 1.3x-2.2x, got {sp_large:.2}");
}
