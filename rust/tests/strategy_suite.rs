//! Cross-strategy agreement suite for the `Planner` registry: the two
//! exact solvers must agree on every default weight pair, every
//! strategy must return memory-feasible plans from the one shared
//! request, the dp search space is honored uniformly, and racing is
//! deterministic.

use funcpipe::model::{merge_layers, zoo, MergeCriterion, ModelProfile};
use funcpipe::planner::{
    race, solve_request, PerfModel, PlanRequest, RobustRank, RobustSpec,
    DEFAULT_WEIGHTS, STRATEGIES,
};
use funcpipe::platform::PlatformSpec;
use funcpipe::simcore::ScenarioSpec;

fn small_model(name: &str, p: &PlatformSpec) -> ModelProfile {
    merge_layers(&zoo::by_name(name, p).unwrap(), 4, MergeCriterion::Compute)
}

/// `miqp` and `bnb` are both exact on the same program, so for EVERY
/// default weight pair they must reach the same optimal objective on
/// the small zoo models (the suite-level form of the in-module
/// certification tests).
#[test]
fn miqp_and_bnb_agree_on_every_default_weight() {
    let p = PlatformSpec::aws_lambda();
    for name in ["resnet101", "bert-large", "amoebanet-d18"] {
        let m = small_model(name, &p);
        let perf = PerfModel::new(&m, &p);
        for &alpha in &DEFAULT_WEIGHTS {
            let mut req = PlanRequest::new(8);
            req.weights = vec![alpha];
            req.dp_options = vec![1, 2];
            let bnb = solve_request("bnb", &perf, &req).unwrap();
            let miqp = solve_request("miqp", &perf, &req).unwrap();
            let (b, q) = (
                bnb.candidates.first().expect("bnb feasible"),
                miqp.candidates.first().expect("miqp feasible"),
            );
            let jb = alpha.0 * b.perf.c_iter + alpha.1 * b.perf.t_iter;
            let jq = alpha.0 * q.perf.c_iter + alpha.1 * q.perf.t_iter;
            assert!(
                (jb - jq).abs() < 1e-9 * jb.max(1.0),
                "{name} α={alpha:?}: bnb {jb} vs miqp {jq}"
            );
        }
    }
}

/// Every registry strategy returns plans that validate against the
/// model/platform (memory constraint (3b) included) and stay inside the
/// requested dp space.
#[test]
fn every_strategy_returns_memory_feasible_plans() {
    let p = PlatformSpec::aws_lambda();
    for model_name in ["resnet101", "amoebanet-d18"] {
        let m = merge_layers(
            &zoo::by_name(model_name, &p).unwrap(),
            6,
            MergeCriterion::Compute,
        );
        let perf = PerfModel::new(&m, &p);
        let mut req = PlanRequest::new(16);
        req.dp_options = vec![1, 2, 4];
        for strategy in STRATEGIES {
            let out = solve_request(strategy, &perf, &req).unwrap();
            assert!(
                !out.candidates.is_empty(),
                "{strategy} on {model_name}: nothing feasible"
            );
            for c in &out.candidates {
                c.plan.validate(&m, &p).unwrap_or_else(|e| {
                    panic!("{strategy} on {model_name}: infeasible plan {e:#}")
                });
                assert!(req.dp_options.contains(&c.plan.dp), "{strategy}");
                assert!(c.perf.t_iter.is_finite() && c.perf.t_iter > 0.0);
                assert!(c.perf.c_iter.is_finite() && c.perf.c_iter > 0.0);
            }
        }
    }
}

/// Constraining the dp space constrains EVERY strategy the same way —
/// the historical bug class this suite exists for (each solver carried
/// its own hardcoded `vec![1, 2, 4, 8, 16, 32]`).
#[test]
fn strategies_search_the_shared_dp_space() {
    let p = PlatformSpec::aws_lambda();
    let m = small_model("resnet101", &p);
    let perf = PerfModel::new(&m, &p);
    let mut req = PlanRequest::new(16);
    req.dp_options = vec![2];
    for strategy in STRATEGIES {
        let out = solve_request(strategy, &perf, &req).unwrap();
        assert!(!out.candidates.is_empty(), "{strategy}");
        for c in &out.candidates {
            assert_eq!(c.plan.dp, 2, "{strategy} ignored dp_options");
        }
    }
}

/// The exact strategies dominate the baselines on the shared objective
/// (their search spaces contain the baselines') — through the one API.
#[test]
fn exact_strategies_dominate_baselines_on_objective() {
    let p = PlatformSpec::aws_lambda();
    let m = merge_layers(
        &zoo::by_name("amoebanet-d18", &p).unwrap(),
        6,
        MergeCriterion::Compute,
    );
    let perf = PerfModel::new(&m, &p);
    let alpha = (1.0, 2e-4);
    let mut req = PlanRequest::new(16);
    req.weights = vec![alpha];
    req.dp_options = vec![1, 2, 4];
    let j = |name: &str| -> Option<f64> {
        solve_request(name, &perf, &req)
            .unwrap()
            .candidates
            .first()
            .map(|c| alpha.0 * c.perf.c_iter + alpha.1 * c.perf.t_iter)
    };
    let j_bnb = j("bnb").expect("bnb feasible");
    for baseline in ["tpdmp", "bayes", "sweep"] {
        if let Some(jb) = j(baseline) {
            assert!(
                j_bnb <= jb + 1e-9,
                "bnb {j_bnb} worse than {baseline} {jb}"
            );
        }
    }
}

/// Racing the whole registry twice over one shared `PerfModel` yields
/// bit-identical outcomes in registry order — what makes the
/// `plan --strategy all` report byte-replayable.
#[test]
fn race_is_deterministic_with_and_without_robustness() {
    let p = PlatformSpec::aws_lambda();
    let m = small_model("resnet101", &p);
    let perf = PerfModel::new(&m, &p);
    let mut req = PlanRequest::new(16);
    req.dp_options = vec![1, 2];
    req.robust = Some(RobustSpec {
        scenario: ScenarioSpec::parse("straggler+jitter").unwrap(),
        seeds: 4,
        rank: RobustRank::Worst,
    });
    let a = race(&perf, &req, &STRATEGIES).unwrap();
    let b = race(&perf, &req, &STRATEGIES).unwrap();
    for (oa, ob) in a.iter().zip(&b) {
        assert_eq!(oa.strategy, ob.strategy);
        // (no stats.nodes comparison: node counts under the parallel
        // branch-and-bound depend on shared-bound timing and are
        // diagnostics only — plans and scores below are the contract)
        assert_eq!(oa.candidates.len(), ob.candidates.len());
        for (ca, cb) in oa.candidates.iter().zip(&ob.candidates) {
            assert_eq!(ca.plan, cb.plan);
            assert_eq!(ca.perf.t_iter.to_bits(), cb.perf.t_iter.to_bits());
            let (ra, rb) = (ca.robust.unwrap(), cb.robust.unwrap());
            assert_eq!(ra.worst_t.to_bits(), rb.worst_t.to_bits());
            assert_eq!(ra.mean_t.to_bits(), rb.mean_t.to_bits());
        }
        assert_eq!(oa.recommend_idx(), ob.recommend_idx());
    }
}

/// Robust ranking can legitimately change which frontier point the
/// δ-rule picks; whatever it picks must carry robust scores and sit on
/// the robust frontier.
#[test]
fn robust_recommendation_is_scored_and_on_frontier() {
    let p = PlatformSpec::aws_lambda();
    let m = small_model("resnet101", &p);
    let perf = PerfModel::new(&m, &p);
    for rank in [RobustRank::Worst, RobustRank::Mean] {
        let mut req = PlanRequest::new(16);
        req.dp_options = vec![1, 2, 4];
        req.robust = Some(RobustSpec {
            scenario: ScenarioSpec::parse("cold-start+straggler").unwrap(),
            seeds: 6,
            rank,
        });
        let out = solve_request("bnb", &perf, &req).unwrap();
        let idx = out.recommend_idx().expect("recommendation");
        assert!(out.frontier_flags()[idx]);
        let rec = &out.candidates[idx];
        let score = rec.robust.expect("robust score");
        assert!(score.mean_t <= score.worst_t + 1e-12);
        // the ranking metric is the robust one, not the deterministic
        let (mt, _) = rec.metric(Some(rank));
        match rank {
            RobustRank::Worst => assert_eq!(mt.to_bits(), score.worst_t.to_bits()),
            RobustRank::Mean => assert_eq!(mt.to_bits(), score.mean_t.to_bits()),
        }
    }
}
