//! Deterministic serving replay — the serve tier's mirror of
//! `scenario_replay.rs`: the same (plan, traffic, seed) must render the
//! byte-identical `ServeReport` (JSON and table), different seeds must
//! draw different arrivals, a frozen artifact must compose with the
//! scenario lens, and the autoscaler must respect its bounds under the
//! bursty Alibaba trace. Also pins the ISSUE acceptance floor: a
//! 10^5 req/min deployment completes and replays byte-identically, and
//! SLO-aware planning recommends a feasible plan whenever one exists.

use funcpipe::config::ExperimentConfig;
use funcpipe::experiment::{Experiment, Format, PlanArtifact, Report};
use funcpipe::planner::SloSpec;
use funcpipe::serve::{ServeOptions, TrafficSpec};
use funcpipe::simcore::ScenarioSpec;

fn session() -> (Experiment, PlanArtifact) {
    let cfg = ExperimentConfig {
        model: "resnet101".into(),
        global_batch: 16,
        merge_layers: 4,
        ..ExperimentConfig::default()
    };
    let exp = Experiment::new(cfg).unwrap();
    let artifact =
        exp.plan().unwrap().recommended().unwrap().artifact.clone();
    (exp, artifact)
}

fn opts(traffic: &str, seed: u64, duration_s: f64) -> ServeOptions {
    let mut o =
        ServeOptions::new(TrafficSpec::parse(traffic).unwrap(), seed);
    o.duration_s = duration_s;
    o
}

#[test]
fn same_trace_and_seed_is_byte_identical() {
    for traffic in ["poisson:1200", "diurnal:900:0.6:60", "alibaba:1500"] {
        // two fully independent sessions — nothing shared but the inputs
        let (a, art_a) = session();
        let (b, art_b) = session();
        let ra = a.serve(&art_a, &opts(traffic, 7, 20.0)).unwrap();
        let rb = b.serve(&art_b, &opts(traffic, 7, 20.0)).unwrap();
        assert_eq!(
            ra.render(Format::Json),
            rb.render(Format::Json),
            "{traffic}: JSON drifted"
        );
        assert_eq!(
            ra.render(Format::Table),
            rb.render(Format::Table),
            "{traffic}: table drifted"
        );
        assert!(ra.outcome.completed > 0, "{traffic}: nothing served");
        // a different seed draws a different arrival stream
        let rc = a.serve(&art_a, &opts(traffic, 8, 20.0)).unwrap();
        assert_ne!(
            ra.render(Format::Json),
            rc.render(Format::Json),
            "{traffic}: seed 8 replayed seed 7's draws"
        );
    }
}

#[test]
fn scenario_lens_composes_with_a_frozen_artifact() {
    let (exp, artifact) = session();
    let base = exp.serve(&artifact, &opts("poisson:900", 11, 15.0)).unwrap();
    let mut lensed_opts = opts("poisson:900", 11, 15.0);
    lensed_opts.scenario =
        ScenarioSpec::parse("cold-start+straggler").unwrap();
    let lensed = exp.serve(&artifact, &lensed_opts).unwrap();
    let again = exp.serve(&artifact, &lensed_opts).unwrap();
    // the lensed replay is just as deterministic...
    assert_eq!(lensed.render(Format::Json), again.render(Format::Json));
    assert_eq!(lensed.render(Format::Table), again.render(Format::Table));
    // ...and actually perturbs the deterministic outcome
    assert_ne!(base.render(Format::Json), lensed.render(Format::Json));
    assert_eq!(lensed.scenario, "cold-start+straggler");
    // the deployment still drains fully under the lens
    assert_eq!(lensed.outcome.requests, lensed.outcome.completed);
}

#[test]
fn autoscaler_bounds_hold_under_the_burst_trace() {
    let (exp, artifact) = session();
    // the authored Alibaba trace peaks near 2.85x its mean — at this
    // mean rate the bursts force scale-up, and the tight ceiling forces
    // queueing instead of unbounded launches
    let mut o = opts("alibaba:20000", 3, 10.0);
    o.max_instances = 3;
    let r = exp.serve(&artifact, &o).unwrap();
    let out = &r.outcome;
    assert_eq!(out.requests, out.completed, "deployment did not drain");
    assert!(out.requests > 100, "trace generated too few arrivals");
    for s in &out.stages {
        assert!(
            (1..=3).contains(&s.peak_instances),
            "stage {} peaked at {} instances (ceiling 3)",
            s.stage,
            s.peak_instances
        );
        assert!(
            s.launches >= s.peak_instances,
            "stage {}: fewer launches than peak",
            s.stage
        );
        assert!(
            (0.0..=1.0 + 1e-9).contains(&s.utilization),
            "stage {}: utilization {} out of range",
            s.stage,
            s.utilization
        );
        assert!(s.batches > 0 && s.mean_batch >= 1.0, "stage {}", s.stage);
    }
    assert!(
        out.stages.iter().any(|s| s.peak_instances > 1),
        "the burst never forced a scale-up: {:?}",
        out.stages
    );
    // idle scale-down fired once arrivals stopped: every launched
    // instance was eventually retired and billed
    assert!(out.cost_usd > 0.0);
}

#[test]
fn a_hundred_thousand_rpm_deployment_replays_byte_identically() {
    let (exp, artifact) = session();
    let o = opts("poisson:100000", 5, 3.0);
    let a = exp.serve(&artifact, &o).unwrap();
    let b = exp.serve(&artifact, &o).unwrap();
    assert_eq!(a, b);
    assert_eq!(a.render(Format::Json), b.render(Format::Json));
    let out = &a.outcome;
    // ~5000 arrivals in the 3 s window at 10^5 req/min
    assert!(out.requests > 3000, "only {} arrivals", out.requests);
    assert_eq!(out.requests, out.completed, "deployment did not drain");
    assert!(out.p50_ms <= out.p95_ms && out.p95_ms <= out.p99_ms);
    assert!(out.cost_usd > 0.0 && out.cost_per_1k_usd > 0.0);
    assert!(out.achieved_rpm > 0.0);
}

#[test]
fn slo_planning_recommends_a_feasible_plan_when_one_exists() {
    for model in ["resnet101", "bert-large"] {
        let cfg = ExperimentConfig {
            model: model.into(),
            global_batch: 16,
            merge_layers: 4,
            dp_options: vec![1, 2],
            ..ExperimentConfig::default()
        };
        let exp = Experiment::new(cfg).unwrap();
        let mut req = exp.plan_request();
        req.slo = Some(SloSpec {
            p99_ms: 300_000.0,
            traffic: TrafficSpec::parse("poisson:240").unwrap(),
            seeds: 2,
        });
        let report = exp.plan_with("bnb", &req).unwrap();
        let rec = report.recommended().expect("a recommendation");
        let score = rec.slo.expect("the recommendation is replay-scored");
        let feasible_exists =
            report.points.iter().any(|p| p.slo.unwrap().feasible);
        if feasible_exists {
            // the acceptance criterion: the selected plan's replayed
            // p99 meets the SLO, at the lowest $/1k among those that do
            assert!(
                score.feasible,
                "{model}: recommended an SLO-missing plan over a \
                 feasible one"
            );
            assert!(score.p99_ms <= 300_000.0, "{model}");
            for p in &report.points {
                let s = p.slo.unwrap();
                if s.feasible {
                    assert!(
                        score.cost_per_1k_usd <= s.cost_per_1k_usd + 1e-12,
                        "{model}: a cheaper feasible plan was passed over"
                    );
                }
            }
        }
        // the spec is echoed so the selection is reconstructible
        assert_eq!(report.slo.as_ref(), req.slo.as_ref());
    }
}
