//! Elastic re-planning determinism suite — the `train --replan` mirror
//! of `train_replay.rs`: the drift pre-pass, the adoption decision and
//! the two-segment migrated run are all pure functions of
//! `(config, scenario, seed, spec)`, so a re-planned run must replay
//! byte-identically, a seeded straggler run must finish strictly faster
//! than the static run it re-plans away from, and the layer-addressed
//! migration shards must round-trip bit-exactly across arbitrary
//! (old partition → new partition) pairs while staying consume-once.
//! Runs on the built-in native model (`builtin:tiny`), so the full
//! coordinator/storage/migration stack executes in the offline build.

use std::sync::Arc;

use funcpipe::collective::{bytes_to_f32s, f32s_to_bytes};
use funcpipe::config::ExperimentConfig;
use funcpipe::experiment::{Experiment, Format, Report, TrainOverrides};
use funcpipe::platform::{MemStore, ObjectStore};
use funcpipe::replan::{
    even_groups, migration_key, validate_groups, ReplanSpec,
};
use funcpipe::runtime::BUILTIN_TINY;
use funcpipe::scenario::Injector;
use funcpipe::simcore::ScenarioSpec;
use funcpipe::trainer::{train_with_store, TrainConfig};
use funcpipe::util::json::Json;

fn straggler_cfg(steps: usize, seed: u64) -> ExperimentConfig {
    ExperimentConfig {
        artifacts_dir: BUILTIN_TINY.into(),
        steps,
        scenario: ScenarioSpec::parse("straggler").unwrap(),
        seed,
        ..ExperimentConfig::default()
    }
}

fn replan_report(
    cfg: &ExperimentConfig,
    spec: &ReplanSpec,
) -> funcpipe::experiment::TrainReport {
    Experiment::new(cfg.clone())
        .unwrap()
        .train_replan(None, &TrainOverrides::default(), spec)
        .unwrap()
}

/// The planless virtual tick is 1.0 and builtin:tiny runs 3 stages at
/// dp=1, so the drift detector's input is exactly the worst worker's
/// straggler multiplier. Recomputing it here keeps the tests honest
/// about *why* a seed does or does not trigger.
fn gated_tick(cfg: &ExperimentConfig) -> f64 {
    Injector::new(&cfg.scenario, cfg.seed, 3).max_iter_virtual_s(1.0)
}

#[test]
fn replan_requires_a_scenario_lens() {
    let cfg = ExperimentConfig {
        artifacts_dir: BUILTIN_TINY.into(),
        steps: 4,
        ..ExperimentConfig::default()
    };
    assert!(cfg.scenario.is_deterministic());
    let err = Experiment::new(cfg)
        .unwrap()
        .train_replan(
            None,
            &TrainOverrides::default(),
            &ReplanSpec::default(),
        )
        .unwrap_err();
    assert!(
        format!("{err:#}").contains("--scenario"),
        "unhelpful rejection: {err:#}"
    );
}

#[test]
fn straggler_replan_beats_the_static_run() {
    // seed 7 draws a straggler above the default 1.2 threshold on one
    // of the three builtin:tiny workers — assert the premise first so a
    // future lens change fails with a readable message
    let cfg = straggler_cfg(16, 7);
    let spec = ReplanSpec::default();
    assert!(
        gated_tick(&cfg) > spec.threshold,
        "seed 7 no longer draws a straggler above the threshold; \
         pick a triggering seed for this suite"
    );

    let exp = Experiment::new(cfg).unwrap();
    let fixed = exp.train(None, &TrainOverrides::default()).unwrap();
    let elastic = exp
        .train_replan(None, &TrainOverrides::default(), &spec)
        .unwrap();

    // exactly one re-plan decision, triggered by the sustained drift:
    // the EWMA sits above threshold from the first step, so the K=3
    // window fires at step 2
    assert!(elastic.replan_enabled);
    assert_eq!(elastic.replan.len(), 1, "{:?}", elastic.replan);
    let event = &elastic.replan[0];
    assert_eq!(event.trigger_step, 2);
    assert!(
        event.observed_iter_s > spec.threshold * event.predicted_iter_s,
        "trigger recorded without drift: {event:?}"
    );
    assert!(event.adopted, "migration not adopted: {event:?}");
    assert!(
        event.new_iter_s < event.observed_iter_s,
        "adopted a plan that is not faster: {event:?}"
    );
    assert!(event.migration_s > 0.0);

    // the acceptance bar: the migrated run finishes strictly earlier on
    // the shared virtual clock than the run that kept the drifted plan
    assert!(
        elastic.wall_s < fixed.wall_s,
        "re-plan did not pay off: {} !< {}",
        elastic.wall_s,
        fixed.wall_s
    );

    // the step timeline is continuous across the migration...
    assert_eq!(elastic.logs.len(), 16);
    for (i, l) in elastic.logs.iter().enumerate() {
        assert_eq!(l.step, i, "step numbering broke at the boundary");
        assert!(l.loss.is_finite());
    }
    // ...and the report carries both plan generations' workers
    assert!(elastic.workers.iter().any(|w| w.plan_generation == 0));
    assert!(
        elastic.workers.iter().any(|w| w.plan_generation == 1),
        "no second-generation workers despite adoption"
    );
    assert_eq!(
        elastic.workers.len(),
        3 + event.new_stages * event.new_dp
    );

    // the event log reaches the JSON surface
    let json = Json::parse(elastic.render(Format::Json).trim()).unwrap();
    let events = json.field("replan").unwrap().as_arr().unwrap();
    assert_eq!(events.len(), 1);
    assert_eq!(
        events[0].field("adopted").unwrap().as_bool(),
        Some(true)
    );
    assert!(!events[0].field_str("strategy").unwrap().is_empty());
    assert_eq!(
        events[0].field_usize("trigger_step").unwrap(),
        event.trigger_step
    );
}

#[test]
fn replan_run_replays_byte_identically() {
    let cfg = straggler_cfg(16, 7);
    let spec = ReplanSpec::default();
    // two fully independent sessions — nothing shared but the inputs
    let rep_a = replan_report(&cfg, &spec);
    let rep_b = replan_report(&cfg, &spec);
    assert_eq!(rep_a.restarts, rep_b.restarts);
    assert_eq!(rep_a.wall_s.to_bits(), rep_b.wall_s.to_bits());
    assert_eq!(rep_a.replan.len(), rep_b.replan.len());
    assert_eq!(
        rep_a.render(Format::Json),
        rep_b.render(Format::Json),
        "re-planned run drifted across identical replays"
    );
    assert_eq!(rep_a.render(Format::Table), rep_b.render(Format::Table));
}

#[test]
fn undrifted_seed_records_no_event_and_matches_the_static_run() {
    // find a seed whose worst straggler draw stays under the threshold:
    // the detector must never fire, and the run must BE the static run
    let spec = ReplanSpec::default();
    let seed = (1..=64u64)
        .find(|&s| gated_tick(&straggler_cfg(6, s)) <= spec.threshold)
        .expect("no quiet seed in 1..=64");
    let cfg = straggler_cfg(6, seed);
    let exp = Experiment::new(cfg.clone()).unwrap();
    let elastic = exp
        .train_replan(None, &TrainOverrides::default(), &spec)
        .unwrap();
    assert!(elastic.replan_enabled);
    assert!(
        elastic.replan.is_empty(),
        "drift fired under the threshold: {:?}",
        elastic.replan
    );
    let fixed = exp.train(None, &TrainOverrides::default()).unwrap();
    assert_eq!(elastic.wall_s.to_bits(), fixed.wall_s.to_bits());
    assert_eq!(elastic.restarts, fixed.restarts);
    // replays byte-identically too
    let again = replan_report(&cfg, &spec);
    assert_eq!(elastic.render(Format::Json), again.render(Format::Json));
    // enabled-but-quiet still shows up on the JSON surface
    let json = Json::parse(elastic.render(Format::Json).trim()).unwrap();
    assert_eq!(
        json.field("replan").unwrap().as_arr().map(<[Json]>::len),
        Some(0)
    );
}

// ---- layer-addressed migration shards ---------------------------------

fn xorshift(state: &mut u64) -> u64 {
    let mut x = *state;
    x ^= x << 13;
    x ^= x >> 7;
    x ^= x << 17;
    *state = x;
    x
}

/// A random contiguous partition of `n_layers` layers into `n_groups`
/// non-empty groups (random boundaries, not just the even split).
fn random_groups(
    n_layers: usize,
    n_groups: usize,
    rng: &mut u64,
) -> Vec<(usize, usize)> {
    let mut cuts: Vec<usize> = Vec::new();
    while cuts.len() < n_groups - 1 {
        let c = 1 + (xorshift(rng) as usize) % (n_layers - 1);
        if !cuts.contains(&c) {
            cuts.push(c);
        }
    }
    cuts.sort_unstable();
    cuts.push(n_layers);
    let mut lo = 0;
    cuts.iter()
        .map(|&hi| {
            let g = (lo, hi);
            lo = hi;
            g
        })
        .collect()
}

#[test]
fn migration_shards_round_trip_across_random_partitions() {
    let mut rng = 0x3c6e_f372_fe94_f82au64;
    for trial in 0..40 {
        let n_layers = 2 + (xorshift(&mut rng) as usize) % 7;
        let old_n = 1 + (xorshift(&mut rng) as usize) % n_layers;
        let new_n = 1 + (xorshift(&mut rng) as usize) % n_layers;
        let old = random_groups(n_layers, old_n, &mut rng);
        let new = random_groups(n_layers, new_n, &mut rng);
        validate_groups(&old, n_layers).unwrap();
        validate_groups(&new, n_layers).unwrap();

        // arbitrary per-layer parameter vectors, varied lengths
        let layers: Vec<Vec<f32>> = (0..n_layers)
            .map(|l| {
                let len = 1 + (xorshift(&mut rng) as usize) % 17;
                (0..len)
                    .map(|i| {
                        ((xorshift(&mut rng) % 4096) as f32 - 2048.0)
                            * 0.037
                            + (l + i) as f32
                    })
                    .collect()
            })
            .collect();

        // quiesce: each OLD stage writes its layers' shards
        let store = MemStore::new();
        for &(lo, hi) in &old {
            for l in lo..hi {
                store
                    .put(&migration_key(3, l), f32s_to_bytes(&layers[l]))
                    .unwrap();
            }
        }
        assert_eq!(store.list("ckpt/").len(), n_layers);

        // restore: each NEW stage reads its layers — bit-exact — and
        // consumes the shard (consume-once, whatever the re-grouping)
        for &(lo, hi) in &new {
            for l in lo..hi {
                let key = migration_key(3, l);
                let bytes = store.get(&key).unwrap_or_else(|| {
                    panic!("trial {trial}: missing shard {key}")
                });
                let got = bytes_to_f32s(&bytes);
                assert_eq!(got.len(), layers[l].len());
                for (a, b) in got.iter().zip(&layers[l]) {
                    assert_eq!(
                        a.to_bits(),
                        b.to_bits(),
                        "trial {trial}: layer {l} corrupted in transit"
                    );
                }
                store.delete(&key);
            }
        }
        assert!(
            store.list("").is_empty(),
            "trial {trial}: shards leaked: {:?}",
            store.list("")
        );
    }
}

/// Satellite regression: a chain of migrations over ONE shared bucket
/// must consume each generation's shards on restore — the high-water
/// mark must not grow with the number of re-plans, and the bucket must
/// drain completely at the end.
#[test]
fn repeated_migrations_do_not_grow_the_bucket() {
    fn run_chain(n_segments: usize) -> (u64, Arc<MemStore>) {
        let store = Arc::new(MemStore::new());
        for seg in 0..n_segments {
            let mut tc = TrainConfig::new(BUILTIN_TINY);
            tc.steps = 3;
            tc.mu = 1;
            tc.virtual_iter_s = Some(1.0);
            tc.step_offset = seg * 3;
            tc.plan_generation = seg as u64;
            // alternate 3-stage / 2-stage partitions of the 3 layers
            tc.layer_groups = if seg % 2 == 0 {
                Vec::new()
            } else {
                even_groups(3, 2)
            };
            tc.migrate_out = seg + 1 < n_segments;
            let rep = train_with_store(&tc, store.clone()).unwrap();
            assert_eq!(rep.logs.len(), 3);
            assert!(rep.logs.iter().all(|l| l.loss.is_finite()));
            if tc.migrate_out {
                // exactly the current generation's shards — every
                // superseded generation was consumed on restore
                let shards = store.list("ckpt/");
                assert_eq!(shards.len(), 3, "{shards:?}");
                let prefix = format!("ckpt/g{seg}/");
                assert!(
                    shards.iter().all(|k| k.starts_with(&prefix)),
                    "superseded shards survived into segment {seg}: \
                     {shards:?}"
                );
            }
        }
        (store.high_water_bytes(), store)
    }

    let (hw_short, store_short) = run_chain(3);
    let (hw_long, store_long) = run_chain(6);
    assert!(store_short.list("").is_empty(), "bucket did not drain");
    assert!(store_long.list("").is_empty(), "bucket did not drain");
    assert!(hw_short > 0);
    assert!(
        hw_long <= hw_short,
        "high water grew with the number of migrations: \
         {hw_long} > {hw_short}"
    );
}

#[test]
fn migrated_segments_keep_the_global_step_schedule() {
    // the same 6-step corpus schedule, run once monolithically and once
    // as two migrated 3-step segments over a shared store, must produce
    // the same losses where the partitioning matches (segment A runs
    // the identity grouping, as does the monolithic run)
    let mut mono = TrainConfig::new(BUILTIN_TINY);
    mono.steps = 6;
    mono.mu = 1;
    mono.virtual_iter_s = Some(1.0);
    let store_m = Arc::new(MemStore::new());
    let rep_m = train_with_store(&mono, store_m).unwrap();

    let store = Arc::new(MemStore::new());
    let mut seg_a = mono.clone();
    seg_a.steps = 3;
    seg_a.migrate_out = true;
    let rep_a = train_with_store(&seg_a, store.clone()).unwrap();
    let mut seg_b = mono.clone();
    seg_b.steps = 3;
    seg_b.step_offset = 3;
    seg_b.plan_generation = 1;
    seg_b.layer_groups = even_groups(3, 2);
    seg_b.calibrated_tick = true;
    let rep_b = train_with_store(&seg_b, store.clone()).unwrap();

    // segment A is step-for-step the monolithic prefix (same grouping,
    // same global steps, same seeds)
    for (a, m) in rep_a.logs.iter().zip(&rep_m.logs) {
        assert_eq!(a.step, m.step);
        assert_eq!(
            a.loss.to_bits(),
            m.loss.to_bits(),
            "segment A diverged from the monolithic prefix"
        );
    }
    // segment B continues the global numbering and trains on restored
    // parameters (finite losses, no restarts needed to restore)
    assert_eq!(
        rep_b.logs.iter().map(|l| l.step).collect::<Vec<_>>(),
        vec![3, 4, 5]
    );
    assert!(rep_b.logs.iter().all(|l| l.loss.is_finite()));
    assert!(store.list("").is_empty(), "bucket did not drain");
}
