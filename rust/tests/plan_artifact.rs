//! Plan-artifact serde + `Experiment` session-API integration tests:
//! the `plan --out plan.json` → `simulate|train --plan plan.json` flow
//! must be byte-identical to staying in process.

use funcpipe::config::ExperimentConfig;
use funcpipe::experiment::{
    Experiment, Format, PlanArtifact, Report, TrainOverrides,
};
use funcpipe::model::Plan;
use funcpipe::util::json::Json;
use funcpipe::util::quickcheck::{check_with, Config as QcConfig, Gen};
use funcpipe::util::rng::Rng;

// ---------------------------------------------------------------------------
// property: serialize → parse → re-serialize is the identity
// ---------------------------------------------------------------------------

struct ArtifactGen;

impl Gen for ArtifactGen {
    type Value = PlanArtifact;

    fn generate(&self, rng: &mut Rng) -> PlanArtifact {
        let models =
            ["amoebanet-d18", "amoebanet-d36", "bert-large", "resnet101"];
        let micro_batch = [1usize, 2, 4][rng.index(3)];
        let mut cfg = ExperimentConfig {
            model: models[rng.index(models.len())].to_string(),
            platform: ["aws-lambda", "alibaba-fc"][rng.index(2)].to_string(),
            micro_batch,
            global_batch: micro_batch * (1 + rng.index(64)),
            merge_layers: 1 + rng.index(12),
            bandwidth_scale: rng.uniform(0.25, 8.0),
            chunk_bytes: [0usize, 65536, 1 << 20][rng.index(3)],
            chunks_in_flight: 1 + rng.index(8),
            steps: 1 + rng.index(100),
            lr: rng.uniform(0.01, 1.0),
            ..ExperimentConfig::default()
        };
        if rng.chance(0.3) {
            cfg.lifetime_s = rng.uniform(1.0, 1000.0);
        }
        if rng.chance(0.3) {
            cfg.throttle =
                Some((rng.uniform(1e5, 1e8), rng.uniform(0.0, 0.05)));
        }
        if rng.chance(0.5) {
            let lenses = [
                "cold-start",
                "straggler",
                "bandwidth-jitter",
                "flaky-network",
                "cold-start+jitter",
                "straggler+bandwidth-jitter",
                "flaky-network+cold-start",
                "cold-start+straggler+bandwidth-jitter",
            ];
            cfg.scenario = funcpipe::simcore::ScenarioSpec::parse(
                lenses[rng.index(lenses.len())],
            )
            .unwrap();
            cfg.seed = rng.next_u64() & ((1u64 << 53) - 1);
        }
        if rng.chance(0.5) {
            // a strictly-increasing subset of the default dp space
            cfg.dp_options = funcpipe::planner::DEFAULT_DP_OPTIONS
                .iter()
                .copied()
                .filter(|_| rng.chance(0.6))
                .collect();
            if cfg.dp_options.is_empty() {
                cfg.dp_options = vec![1 + rng.index(8)];
            }
        }

        // structurally plausible plan (serde is shape-only; semantic
        // feasibility is Experiment::from_artifact's job)
        let n_cuts = rng.index(4);
        let mut cuts: Vec<usize> =
            (0..n_cuts).map(|_| rng.index(23)).collect();
        cuts.sort_unstable();
        cuts.dedup();
        let dp = [1usize, 2, 4, 8][rng.index(4)];
        let plan = Plan {
            stage_tiers: (0..cuts.len() + 1).map(|_| rng.index(8)).collect(),
            cuts,
            dp,
            n_micro_global: dp * (1 + rng.index(16)),
        };
        // strategy provenance: any registry key, or a foreign-but-valid
        // string (loaders keep provenance open for future strategies)
        let strategies =
            ["bnb", "miqp", "bayes", "tpdmp", "sweep", "custom-solver"];
        PlanArtifact::new(
            cfg,
            plan,
            (1.0, rng.uniform(0.0, 1e-3)),
            rng.uniform(0.1, 100.0),
            rng.uniform(1e-6, 1.0),
            strategies[rng.index(strategies.len())],
        )
    }
}

#[test]
fn artifact_json_roundtrip_is_identity() {
    check_with(
        QcConfig { cases: 200, ..Default::default() },
        &ArtifactGen,
        |a| match PlanArtifact::from_json_text(&a.to_json_text()) {
            Ok(parsed) => {
                parsed == *a && parsed.to_json_text() == a.to_json_text()
            }
            Err(_) => false,
        },
    );
}

#[test]
fn v_old_artifacts_parse_with_default_provenance() {
    // downgrade freshly-generated artifacts to the version-1 on-disk
    // shape (no strategy key) and check the back-compat parse: loads,
    // defaults provenance to "bnb", re-serializes as the current schema
    check_with(
        QcConfig { cases: 60, ..Default::default() },
        &ArtifactGen,
        |a| {
            let Json::Obj(mut obj) = a.to_json() else { return false };
            obj.insert("version".into(), Json::Num(1.0));
            obj.remove("strategy");
            let v1_text = Json::Obj(obj).pretty();
            let Ok(parsed) = PlanArtifact::from_json_text(&v1_text) else {
                return false;
            };
            parsed.strategy == "bnb"
                && parsed.version
                    == funcpipe::experiment::PLAN_SCHEMA_VERSION
                && parsed.plan == a.plan
                && parsed.config == a.config
                // and the upgraded form round-trips like any current one
                && PlanArtifact::from_json_text(&parsed.to_json_text())
                    .map(|p| p == parsed)
                    .unwrap_or(false)
        },
    );
}

#[test]
fn provenance_survives_the_file_flow() {
    let exp = Experiment::new(small_cfg()).unwrap();
    let report = exp
        .plan_with("tpdmp", &exp.plan_request())
        .unwrap();
    let rec = report.recommended().expect("feasible plan");
    assert_eq!(rec.artifact.strategy, "tpdmp");
    let path = std::env::temp_dir().join(format!(
        "funcpipe-strategy-plan-{}.json",
        std::process::id()
    ));
    rec.artifact.save(&path).unwrap();
    let loaded = PlanArtifact::load(&path).unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(loaded.strategy, "tpdmp");
    assert_eq!(loaded, rec.artifact);
    // a strategy-planned artifact drives simulate/train sessions like
    // any other — provenance is metadata, not behaviour
    let exp2 = Experiment::from_artifact(&loaded).unwrap();
    exp2.simulate(&loaded).unwrap();
}

// ---------------------------------------------------------------------------
// integration: the file flow equals the in-process flow exactly
// ---------------------------------------------------------------------------

fn small_cfg() -> ExperimentConfig {
    ExperimentConfig {
        model: "resnet101".into(),
        global_batch: 16,
        merge_layers: 4,
        ..ExperimentConfig::default()
    }
}

#[test]
fn plan_out_simulate_plan_matches_in_process() {
    let exp = Experiment::new(small_cfg()).unwrap();
    let report = exp.plan().unwrap();
    let rec = report.recommended().expect("feasible plan");

    // in-process: plan → simulate
    let direct = exp.simulate(&rec.artifact).unwrap();

    // file flow: plan --out plan.json → simulate --plan plan.json
    let path = std::env::temp_dir()
        .join(format!("funcpipe-plan-{}.json", std::process::id()));
    rec.artifact.save(&path).unwrap();
    let loaded = PlanArtifact::load(&path).unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(loaded, rec.artifact, "artifact changed across the file");
    let exp2 = Experiment::from_artifact(&loaded).unwrap();
    let via_file = exp2.simulate(&loaded).unwrap();

    // bit-exact agreement, not approximate
    assert_eq!(direct.predicted.t_iter, via_file.predicted.t_iter);
    assert_eq!(direct.predicted.c_iter, via_file.predicted.c_iter);
    assert_eq!(direct.sim.t_iter, via_file.sim.t_iter);
    assert_eq!(direct.sim.c_iter, via_file.sim.c_iter);
    // the rendered reports agree byte-for-byte in both formats
    assert_eq!(
        direct.render(Format::Json),
        via_file.render(Format::Json)
    );
    assert_eq!(
        direct.render(Format::Table),
        via_file.render(Format::Table)
    );
}

#[test]
fn train_derives_dp_mu_from_the_loaded_plan() {
    let exp = Experiment::new(small_cfg()).unwrap();
    let rec = exp.plan().unwrap().recommended().unwrap().artifact.clone();

    let path = std::env::temp_dir()
        .join(format!("funcpipe-train-plan-{}.json", std::process::id()));
    rec.save(&path).unwrap();
    let loaded = PlanArtifact::load(&path).unwrap();
    std::fs::remove_file(&path).ok();

    let exp2 = Experiment::from_artifact(&loaded).unwrap();
    let tc = exp2
        .train_config(Some(&loaded), &TrainOverrides::default())
        .unwrap();
    assert_eq!(tc.dp, loaded.plan.dp, "dp must come from the plan");
    assert_eq!(tc.mu, loaded.plan.mu(), "mu must come from the plan");
    assert_eq!(tc.sync_alg, loaded.config.sync_alg);
    assert_eq!(tc.chunking, loaded.config.chunking());
    assert_eq!(tc.steps, loaded.config.steps);

    // explicit flags stay available as overrides
    let ov = TrainOverrides { dp: Some(1), steps: Some(2), ..Default::default() };
    let tc = exp2.train_config(Some(&loaded), &ov).unwrap();
    assert_eq!((tc.dp, tc.steps), (1, 2));
    assert_eq!(tc.mu, loaded.plan.mu());
}

#[test]
fn artifact_validation_catches_drift() {
    let exp = Experiment::new(small_cfg()).unwrap();
    let rec = exp.plan().unwrap().recommended().unwrap().artifact.clone();

    // a hand-edited artifact whose plan no longer matches its config
    let mut drifted = rec.clone();
    drifted.plan.n_micro_global += 1;
    assert!(Experiment::from_artifact(&drifted).is_err());

    // a tier index out of range
    let mut bad_tier = rec.clone();
    bad_tier.plan.stage_tiers[0] = 999;
    assert!(Experiment::from_artifact(&bad_tier).is_err());
}

// ---------------------------------------------------------------------------
// every report's JSON form parses back (what the CI smoke step checks
// end-to-end through the binary)
// ---------------------------------------------------------------------------

#[test]
fn report_json_renders_parse() {
    let exp = Experiment::new(small_cfg()).unwrap();
    let plan_report = exp.plan().unwrap();
    Json::parse(plan_report.render(Format::Json).trim()).unwrap();

    let rec = plan_report.recommended().unwrap();
    let sim_report = exp.simulate(&rec.artifact).unwrap();
    Json::parse(sim_report.render(Format::Json).trim()).unwrap();

    let base_report = exp.baselines().unwrap();
    Json::parse(base_report.render(Format::Json).trim()).unwrap();
}
