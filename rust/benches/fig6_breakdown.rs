//! Bench harness for the paper's fig6 — regenerates the rows/series
//! through the shared Report tables and reports wall time (criterion is
//! unavailable offline; harness = false).
fn main() {
    let t0 = std::time::Instant::now();
    for t in funcpipe::bench::fig6() {
        t.print();
    }
    println!(
        "\n[bench] fig6 regenerated in {:.2}s",
        t0.elapsed().as_secs_f64()
    );
}
