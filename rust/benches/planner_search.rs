//! planner_search — throughput of the co-optimizer's B&B search and
//! effectiveness of the PerfModel StageCache on the `solve_weights`
//! sweep (the planner hot loop): candidate plans (leaves) and DFS nodes
//! per second, plus the cache hit rate, for a parameter-heavy-tail CNN
//! (vgg16) and a Table-1 resnet-class model. A second table runs EVERY
//! registry strategy through the one `Planner` API on a small model and
//! reports per-strategy plans/sec — the cross-strategy cost picture
//! behind `plan --strategy all`. A third table measures the PR 8
//! re-planning path: robust-scoring replays/sec serial vs through the
//! parallel work-queue (with a ≥2× speedup bar on ≥4-core runners) and
//! the work-sharing B&B vs the serial DFS (with a plan-equality bar).
//! Wired into CI next to `perf_hotpath`; the acceptance bar is a
//! reported hit rate > 50% on the vgg16 sweep.

use std::time::Instant;

use funcpipe::model::{merge_layers, zoo, MergeCriterion, Plan};
use funcpipe::pipeline::simulate_iteration_scenario;
use funcpipe::planner::{
    optimizer, robust_scores, solve_request, CoOptimizer, PerfModel,
    PlanRequest, RobustRank, RobustSpec, DEFAULT_WEIGHTS, STRATEGIES,
};
use funcpipe::platform::PlatformSpec;
use funcpipe::simcore::ScenarioSpec;

fn main() {
    let p = PlatformSpec::aws_lambda();
    println!(
        "{:<12} {:>8} {:>10} {:>12} {:>12} {:>12} {:>10}",
        "model", "plans", "nodes", "plans/s", "nodes/s", "cache hits", "hit rate"
    );
    for name in ["vgg16", "resnet101"] {
        let m = merge_layers(
            &zoo::by_name(name, &p).expect("zoo model"),
            8,
            MergeCriterion::Compute,
        );
        let opt = CoOptimizer::new(&m, &p);
        opt.perf.cache().clear();

        let t0 = Instant::now();
        let mut leaves = 0u64;
        let mut nodes = 0u64;
        let mut found = 0usize;
        for &w in &DEFAULT_WEIGHTS {
            if let Some((_, _, stats)) = opt.solve(16, w) {
                leaves += stats.leaves;
                nodes += stats.nodes;
                found += 1;
            }
        }
        let dt = t0.elapsed().as_secs_f64();
        let cache = opt.perf.cache();
        println!(
            "{:<12} {:>8} {:>10} {:>12.0} {:>12.0} {:>12} {:>9.1}%",
            name,
            leaves,
            nodes,
            leaves as f64 / dt,
            nodes as f64 / dt,
            cache.hits(),
            cache.hit_rate() * 100.0
        );
        assert!(found > 0, "{name}: no feasible plan in the sweep");
        assert!(
            cache.hit_rate() > 0.5,
            "{name}: StageCache hit rate {:.2} below the 50% bar",
            cache.hit_rate()
        );
    }

    // -- per-strategy rows: the whole registry on one shared PerfModel --
    let m = merge_layers(
        &zoo::by_name("resnet101", &p).expect("zoo model"),
        5,
        MergeCriterion::Compute,
    );
    let perf = PerfModel::new(&m, &p);
    let mut req = PlanRequest::new(16);
    req.dp_options = vec![1, 2, 4];
    println!();
    println!(
        "{:<12} {:>8} {:>10} {:>12} {:>12} {:>10}",
        "strategy", "plans", "nodes", "solve s", "plans/s", "hit rate"
    );
    let mut finalists: Vec<Plan> = Vec::new();
    for name in STRATEGIES {
        let t0 = Instant::now();
        let outcome =
            solve_request(name, &perf, &req).expect("registry strategy");
        let dt = t0.elapsed().as_secs_f64();
        for c in &outcome.candidates {
            if !finalists.contains(&c.plan) {
                finalists.push(c.plan.clone());
            }
        }
        println!(
            "{:<12} {:>8} {:>10} {:>12.4} {:>12.1} {:>9.1}%",
            name,
            outcome.candidates.len(),
            outcome.stats.nodes,
            dt,
            outcome.candidates.len() as f64 / dt.max(1e-9),
            perf.cache().hit_rate() * 100.0
        );
        assert!(
            !outcome.candidates.is_empty(),
            "{name}: no feasible plan on resnet101"
        );
    }
    // after the whole registry ran over ONE shared model, the cache
    // must be hot — the property `plan --strategy all` relies on
    assert!(
        perf.cache().hit_rate() > 0.5,
        "shared StageCache hit rate {:.2} below the 50% bar",
        perf.cache().hit_rate()
    );

    // -- dp=1024 row: the 10^3-replica point through the closed-form
    // scorer. The platform cap is lifted to let the planner price it
    // (aws-lambda sells 1000 concurrent functions; the row is about
    // scorer throughput at scale, not the purchasable envelope).
    let mut p1024 = PlatformSpec::aws_lambda();
    p1024.max_concurrency = 1024;
    let m = merge_layers(
        &zoo::by_name("resnet101", &p1024).expect("zoo model"),
        8,
        MergeCriterion::Compute,
    );
    let perf = PerfModel::new(&m, &p1024);
    let mut req = PlanRequest::new(2048); // mu = 2 per replica at dp=1024
    req.dp_options = vec![1024];
    let t0 = Instant::now();
    let outcome = solve_request("bnb", &perf, &req).expect("bnb at dp=1024");
    let dt = t0.elapsed().as_secs_f64();
    println!();
    println!(
        "{:<12} {:>8} {:>10} {:>12.4} {:>12.1} {:>9.1}%",
        "bnb dp=1024",
        outcome.candidates.len(),
        outcome.stats.nodes,
        dt,
        outcome.candidates.len() as f64 / dt.max(1e-9),
        perf.cache().hit_rate() * 100.0
    );
    assert!(
        !outcome.candidates.is_empty(),
        "no feasible resnet101 plan at dp=1024"
    );
    assert!(
        outcome.candidates.iter().all(|c| c.plan.dp == 1024),
        "dp space was [1024]; every candidate must sit on it"
    );

    // -- robust scoring: the mid-run re-planning hot loop. The same
    // finalist set (union of every registry strategy's candidates)
    // scored under 8 seeded straggler+jitter replays, once by the
    // historical serial loop and once through the score work-queue. On
    // a runner with ≥ 4 cores the parallel path must clear 2× — the PR 8
    // acceptance bar; below that the row is informational (CI runners
    // with 2 cores can't amortize the fan-out).
    // (re-derive the finalists' model: `m`/`perf` were shadowed by the
    // dp=1024 fixtures above)
    let m = merge_layers(
        &zoo::by_name("resnet101", &p).expect("zoo model"),
        5,
        MergeCriterion::Compute,
    );
    let perf = PerfModel::new(&m, &p);
    let spec = RobustSpec {
        scenario: ScenarioSpec::parse("straggler+jitter").expect("scenario"),
        seeds: 8,
        rank: RobustRank::Worst,
    };
    let replays = (finalists.len() * spec.seeds) as f64;
    let t0 = Instant::now();
    let mut serial = Vec::with_capacity(finalists.len());
    for plan in &finalists {
        let mut worst_t = 0.0f64;
        for seed in 1..=spec.seeds as u64 {
            let sim = simulate_iteration_scenario(
                &m,
                &p,
                plan,
                perf.sync_alg,
                &spec.scenario,
                seed,
            );
            worst_t = worst_t.max(sim.t_iter);
        }
        serial.push(worst_t);
    }
    let dt_serial = t0.elapsed().as_secs_f64();
    let t0 = Instant::now();
    let parallel = robust_scores(&perf, &finalists, &spec);
    let dt_parallel = t0.elapsed().as_secs_f64();
    let speedup = dt_serial / dt_parallel.max(1e-9);
    println!();
    println!(
        "{:<16} {:>8} {:>12} {:>14} {:>10}",
        "robust scoring", "plans", "replays", "replays/s", "speedup"
    );
    println!(
        "{:<16} {:>8} {:>12} {:>14.1} {:>10}",
        "serial",
        finalists.len(),
        replays as u64,
        replays / dt_serial.max(1e-9),
        "1.0x"
    );
    println!(
        "{:<16} {:>8} {:>12} {:>14.1} {:>9.1}x",
        "parallel",
        finalists.len(),
        replays as u64,
        replays / dt_parallel.max(1e-9),
        speedup
    );
    for (s, score) in serial.iter().zip(&parallel) {
        assert_eq!(
            s.to_bits(),
            score.worst_t.to_bits(),
            "parallel robust score drifted from the serial reference"
        );
    }
    if funcpipe::exec::pool_size() >= 4 {
        assert!(
            speedup >= 2.0,
            "parallel robust scoring {speedup:.2}x below the 2x bar on a \
             {}-thread pool",
            funcpipe::exec::pool_size()
        );
    }

    // -- B&B: serial DFS vs the work-sharing parallel search on the
    // same weight. Wall-clock is informational (packet overhead can eat
    // the win on tiny models); the bar is the determinism contract —
    // both sides reach the identical plan.
    let t0 = Instant::now();
    let s = optimizer::solve_with(&perf, &[1, 2, 4], 50_000_000, 16, (1.0, 2e-4));
    let dt_serial = t0.elapsed().as_secs_f64();
    let t0 = Instant::now();
    let q = optimizer::solve_parallel(
        &perf,
        &[1, 2, 4],
        50_000_000,
        16,
        (1.0, 2e-4),
    );
    let dt_parallel = t0.elapsed().as_secs_f64();
    println!();
    println!(
        "{:<16} {:>12} {:>12}",
        "bnb search", "solve s", "same plan"
    );
    let same = match (&s, &q) {
        (Some((ps, _, _)), Some((pq, _, _))) => ps == pq,
        (None, None) => true,
        _ => false,
    };
    println!("{:<16} {:>12.4} {:>12}", "serial", dt_serial, "-");
    println!(
        "{:<16} {:>12.4} {:>12}",
        "parallel",
        dt_parallel,
        if same { "yes" } else { "NO" }
    );
    assert!(same, "parallel bnb diverged from the serial plan");
}
