//! planner_search — throughput of the co-optimizer's B&B search and
//! effectiveness of the PerfModel StageCache on the `solve_weights`
//! sweep (the planner hot loop): candidate plans (leaves) and DFS nodes
//! per second, plus the cache hit rate, for a parameter-heavy-tail CNN
//! (vgg16) and a Table-1 resnet-class model. A second table runs EVERY
//! registry strategy through the one `Planner` API on a small model and
//! reports per-strategy plans/sec — the cross-strategy cost picture
//! behind `plan --strategy all`. Wired into CI next to `perf_hotpath`;
//! the acceptance bar is a reported hit rate > 50% on the vgg16 sweep.

use std::time::Instant;

use funcpipe::model::{merge_layers, zoo, MergeCriterion};
use funcpipe::planner::{
    solve_request, CoOptimizer, PerfModel, PlanRequest, DEFAULT_WEIGHTS,
    STRATEGIES,
};
use funcpipe::platform::PlatformSpec;

fn main() {
    let p = PlatformSpec::aws_lambda();
    println!(
        "{:<12} {:>8} {:>10} {:>12} {:>12} {:>12} {:>10}",
        "model", "plans", "nodes", "plans/s", "nodes/s", "cache hits", "hit rate"
    );
    for name in ["vgg16", "resnet101"] {
        let m = merge_layers(
            &zoo::by_name(name, &p).expect("zoo model"),
            8,
            MergeCriterion::Compute,
        );
        let opt = CoOptimizer::new(&m, &p);
        opt.perf.cache().clear();

        let t0 = Instant::now();
        let mut leaves = 0u64;
        let mut nodes = 0u64;
        let mut found = 0usize;
        for &w in &DEFAULT_WEIGHTS {
            if let Some((_, _, stats)) = opt.solve(16, w) {
                leaves += stats.leaves;
                nodes += stats.nodes;
                found += 1;
            }
        }
        let dt = t0.elapsed().as_secs_f64();
        let cache = opt.perf.cache();
        println!(
            "{:<12} {:>8} {:>10} {:>12.0} {:>12.0} {:>12} {:>9.1}%",
            name,
            leaves,
            nodes,
            leaves as f64 / dt,
            nodes as f64 / dt,
            cache.hits(),
            cache.hit_rate() * 100.0
        );
        assert!(found > 0, "{name}: no feasible plan in the sweep");
        assert!(
            cache.hit_rate() > 0.5,
            "{name}: StageCache hit rate {:.2} below the 50% bar",
            cache.hit_rate()
        );
    }

    // -- per-strategy rows: the whole registry on one shared PerfModel --
    let m = merge_layers(
        &zoo::by_name("resnet101", &p).expect("zoo model"),
        5,
        MergeCriterion::Compute,
    );
    let perf = PerfModel::new(&m, &p);
    let mut req = PlanRequest::new(16);
    req.dp_options = vec![1, 2, 4];
    println!();
    println!(
        "{:<12} {:>8} {:>10} {:>12} {:>12} {:>10}",
        "strategy", "plans", "nodes", "solve s", "plans/s", "hit rate"
    );
    for name in STRATEGIES {
        let t0 = Instant::now();
        let outcome =
            solve_request(name, &perf, &req).expect("registry strategy");
        let dt = t0.elapsed().as_secs_f64();
        println!(
            "{:<12} {:>8} {:>10} {:>12.4} {:>12.1} {:>9.1}%",
            name,
            outcome.candidates.len(),
            outcome.stats.nodes,
            dt,
            outcome.candidates.len() as f64 / dt.max(1e-9),
            perf.cache().hit_rate() * 100.0
        );
        assert!(
            !outcome.candidates.is_empty(),
            "{name}: no feasible plan on resnet101"
        );
    }
    // after the whole registry ran over ONE shared model, the cache
    // must be hot — the property `plan --strategy all` relies on
    assert!(
        perf.cache().hit_rate() > 0.5,
        "shared StageCache hit rate {:.2} below the 50% bar",
        perf.cache().hit_rate()
    );

    // -- dp=1024 row: the 10^3-replica point through the closed-form
    // scorer. The platform cap is lifted to let the planner price it
    // (aws-lambda sells 1000 concurrent functions; the row is about
    // scorer throughput at scale, not the purchasable envelope).
    let mut p1024 = PlatformSpec::aws_lambda();
    p1024.max_concurrency = 1024;
    let m = merge_layers(
        &zoo::by_name("resnet101", &p1024).expect("zoo model"),
        8,
        MergeCriterion::Compute,
    );
    let perf = PerfModel::new(&m, &p1024);
    let mut req = PlanRequest::new(2048); // mu = 2 per replica at dp=1024
    req.dp_options = vec![1024];
    let t0 = Instant::now();
    let outcome = solve_request("bnb", &perf, &req).expect("bnb at dp=1024");
    let dt = t0.elapsed().as_secs_f64();
    println!();
    println!(
        "{:<12} {:>8} {:>10} {:>12.4} {:>12.1} {:>9.1}%",
        "bnb dp=1024",
        outcome.candidates.len(),
        outcome.stats.nodes,
        dt,
        outcome.candidates.len() as f64 / dt.max(1e-9),
        perf.cache().hit_rate() * 100.0
    );
    assert!(
        !outcome.candidates.is_empty(),
        "no feasible resnet101 plan at dp=1024"
    );
    assert!(
        outcome.candidates.iter().all(|c| c.plan.dp == 1024),
        "dp space was [1024]; every candidate must sit on it"
    );
}
