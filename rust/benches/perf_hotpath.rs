//! Micro-benchmarks of the L3 hot paths (EXPERIMENTS.md §Perf):
//! planner solve, perf-model evaluation, DES iteration, schedule build,
//! max-min allocator, and the real threaded collectives over an
//! in-process store.
use std::sync::Arc;
use std::time::{Duration, Instant};

use funcpipe::collective::sim::{
    simulate_pipelined_scatter_reduce, simulate_scatter_reduce,
};
use funcpipe::collective::Chunking;
use funcpipe::collective::{
    pipelined::{pipelined_scatter_reduce, pipelined_scatter_reduce_chunked},
    scatter_reduce::scatter_reduce,
};
use funcpipe::model::{merge_layers, zoo, MergeCriterion, Plan};
use funcpipe::pipeline::{build_schedule, simulate_iteration};
use funcpipe::planner::{CoOptimizer, PerfModel};
use funcpipe::platform::network::BandwidthModel;
use funcpipe::platform::{MemStore, ObjectStore, PlatformSpec, ThrottledStore};

fn time<F: FnMut()>(name: &str, iters: usize, mut f: F) {
    // warmup
    f();
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    let per = t0.elapsed().as_secs_f64() / iters as f64;
    println!("{name:<44} {:>12.3} µs/iter   ({iters} iters)", per * 1e6);
}

fn main() {
    let p = PlatformSpec::aws_lambda();
    let m = merge_layers(&zoo::amoebanet_d36(&p), 8, MergeCriterion::Compute);
    let plan = Plan {
        cuts: vec![2, 5],
        dp: 4,
        stage_tiers: vec![7, 7, 7],
        n_micro_global: 16,
    };
    let pm = PerfModel::new(&m, &p);

    time("perf_model::evaluate", 20_000, || {
        std::hint::black_box(pm.evaluate(&plan));
    });
    time("schedule::build (3 stages, d=4, mu=4)", 5_000, || {
        std::hint::black_box(build_schedule(&plan));
    });
    time("pipeline DES iteration", 200, || {
        std::hint::black_box(simulate_iteration(
            &m,
            &p,
            &plan,
            funcpipe::collective::SyncAlgorithm::PipelinedScatterReduce,
        ));
    });
    time("co-optimizer solve (L=8, batch 64)", 5, || {
        let opt = CoOptimizer::new(&m, &p);
        std::hint::black_box(opt.solve(16, (1.0, 2e-4)));
    });
    let net = BandwidthModel::uniform(8, 70.0e6, 0.04);
    time("flowsim scatter-reduce n=8", 200, || {
        std::hint::black_box(simulate_scatter_reduce(8, 300e6, &net));
    });
    time("flowsim pipelined scatter-reduce n=8", 200, || {
        std::hint::black_box(simulate_pipelined_scatter_reduce(8, 300e6, &net));
    });

    // real threaded collectives, 4 workers x 1M f32
    for (name, pipelined) in [
        ("real scatter-reduce 4x1M f32", false),
        ("real pipelined scatter-reduce 4x1M f32", true),
    ] {
        time(name, 5, || {
            let store: Arc<dyn ObjectStore> = Arc::new(MemStore::new());
            let handles: Vec<_> = (0..4)
                .map(|rank| {
                    let store = store.clone();
                    std::thread::spawn(move || {
                        let mut g = vec![rank as f32; 1_000_000];
                        let timeout = Duration::from_secs(30);
                        if pipelined {
                            pipelined_scatter_reduce(
                                &store, "b", 0, rank, 4, &mut g, None, timeout,
                            )
                            .unwrap();
                        } else {
                            scatter_reduce(
                                &store, "b", 0, rank, 4, &mut g, None, timeout,
                            )
                            .unwrap();
                        }
                    })
                })
                .collect();
            for h in handles {
                h.join().unwrap();
            }
        });
    }

    // chunked engine: same transfer, bounded store occupancy
    for (name, chunk_kb, in_flight) in [
        ("real chunked pipelined s-r 4x1M f32 (256KBx4)", 256usize, 4usize),
        ("real chunked pipelined s-r 4x1M f32 (64KBx8)", 64, 8),
    ] {
        time(name, 5, || {
            let store: Arc<dyn ObjectStore> = Arc::new(MemStore::new());
            let chunking = Chunking::new(chunk_kb << 10, in_flight);
            let handles: Vec<_> = (0..4)
                .map(|rank| {
                    let store = store.clone();
                    std::thread::spawn(move || {
                        let mut g = vec![rank as f32; 1_000_000];
                        pipelined_scatter_reduce_chunked(
                            &store,
                            "bc",
                            0,
                            rank,
                            4,
                            &mut g,
                            None,
                            Duration::from_secs(30),
                            chunking,
                        )
                        .unwrap();
                    })
                })
                .collect();
            for h in handles {
                h.join().unwrap();
            }
        });
    }

    // chunked duplex on a throttled store: the wall-clock comparison the
    // bounded-memory engine must win or tie (reported, not asserted)
    let throttled = |label: &str, chunking: Option<Chunking>| {
        let n = 4;
        let len = 200_000; // 800 KB per worker
        let bw = 40.0e6;
        let inner: Arc<dyn ObjectStore> = Arc::new(MemStore::new());
        let t0 = Instant::now();
        let handles: Vec<_> = (0..n)
            .map(|rank| {
                let store: Arc<dyn ObjectStore> = Arc::new(
                    ThrottledStore::new(
                        inner.clone(),
                        bw,
                        bw,
                        Duration::from_millis(1),
                    ),
                );
                std::thread::spawn(move || {
                    let mut g = vec![rank as f32; len];
                    let timeout = Duration::from_secs(60);
                    match chunking {
                        Some(c) => pipelined_scatter_reduce_chunked(
                            &store, "t", 0, rank, n, &mut g, None, timeout, c,
                        )
                        .unwrap(),
                        None => pipelined_scatter_reduce(
                            &store, "t", 0, rank, n, &mut g, None, timeout,
                        )
                        .unwrap(),
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        println!(
            "{label:<44} {:>10.3} s wall   (peak store {} KB)",
            t0.elapsed().as_secs_f64(),
            inner.high_water_bytes() >> 10
        );
    };
    throttled("throttled pipelined (unchunked)", None);
    throttled(
        "throttled pipelined chunked 64KBx4",
        Some(Chunking::new(64 << 10, 4)),
    );
}
