//! Micro-benchmarks of the L3 hot paths (EXPERIMENTS.md §Perf):
//! planner solve, perf-model evaluation, DES iteration, schedule build,
//! max-min allocator, and the real threaded collectives over an
//! in-process store.
use std::sync::Arc;
use std::time::{Duration, Instant};

use funcpipe::collective::sim::{
    simulate_pipelined_scatter_reduce, simulate_scatter_reduce,
};
use funcpipe::collective::Chunking;
use funcpipe::collective::{
    pipelined::{pipelined_scatter_reduce, pipelined_scatter_reduce_chunked},
    scatter_reduce::scatter_reduce,
};
use funcpipe::model::{merge_layers, zoo, MergeCriterion, Plan};
use funcpipe::pipeline::{build_schedule, simulate_iteration};
use funcpipe::planner::{CoOptimizer, PerfModel};
use funcpipe::platform::network::BandwidthModel;
use funcpipe::platform::{MemStore, ObjectStore, PlatformSpec, ThrottledStore};
use funcpipe::simcore::{execute, execute_full, FlowGraph, Node};

/// Synthetic dp-scale DES input: `n_workers` independent
/// compute → upload → download chains, `rounds` deep — the shape a
/// 10³-replica iteration puts through the engine. Works are slightly
/// de-tied per node so completions arrive one at a time (the worst
/// case for a full re-solve on every event).
fn worker_chains(n_workers: usize, rounds: usize) -> FlowGraph {
    let mut g = FlowGraph::new();
    for w in 0..n_workers {
        let mut prev: Option<usize> = None;
        for r in 0..rounds {
            let jitter = 1.0 + ((w * 31 + r * 7) % 1009) as f64 * 1e-4;
            let mut c = Node::compute(w, jitter);
            if let Some(p) = prev {
                c = c.after(vec![p]);
            }
            let c = g.add(c);
            let u = g.add(Node::transfer(w, true, 0.6 * jitter).after(vec![c]));
            prev =
                Some(g.add(Node::transfer(w, false, 0.4 * jitter).after(vec![u])));
        }
    }
    g
}

fn time<F: FnMut()>(name: &str, iters: usize, mut f: F) {
    // warmup
    f();
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    let per = t0.elapsed().as_secs_f64() / iters as f64;
    println!("{name:<44} {:>12.3} µs/iter   ({iters} iters)", per * 1e6);
}

fn main() {
    let p = PlatformSpec::aws_lambda();
    let m = merge_layers(&zoo::amoebanet_d36(&p), 8, MergeCriterion::Compute);
    let plan = Plan {
        cuts: vec![2, 5],
        dp: 4,
        stage_tiers: vec![7, 7, 7],
        n_micro_global: 16,
    };
    let pm = PerfModel::new(&m, &p);

    time("perf_model::evaluate", 20_000, || {
        std::hint::black_box(pm.evaluate(&plan));
    });
    time("schedule::build (3 stages, d=4, mu=4)", 5_000, || {
        std::hint::black_box(build_schedule(&plan));
    });
    time("pipeline DES iteration", 200, || {
        std::hint::black_box(simulate_iteration(
            &m,
            &p,
            &plan,
            funcpipe::collective::SyncAlgorithm::PipelinedScatterReduce,
        ));
    });
    time("co-optimizer solve (L=8, batch 64)", 5, || {
        let opt = CoOptimizer::new(&m, &p);
        std::hint::black_box(opt.solve(16, (1.0, 2e-4)));
    });
    // -- 1024-worker rows: the incremental event-driven engine vs the
    // full re-solve reference on the same graph. The ISSUE-6 scale
    // target: an event at one worker must not cost a whole-graph
    // re-solve once dp reaches 10^3.
    {
        let g = worker_chains(1024, 3);
        let inc = execute(&g);
        let full = execute_full(&g);
        assert!(
            (inc.makespan - full.makespan).abs()
                <= 1e-6 * full.makespan.max(1.0),
            "engines disagree at 1024 workers: incremental {} vs full {}",
            inc.makespan,
            full.makespan
        );

        let inc_iters = 20;
        let t0 = Instant::now();
        for _ in 0..inc_iters {
            std::hint::black_box(execute(&g));
        }
        let inc_per = t0.elapsed().as_secs_f64() / inc_iters as f64;

        let full_iters = 2;
        let t0 = Instant::now();
        for _ in 0..full_iters {
            std::hint::black_box(execute_full(&g));
        }
        let full_per = t0.elapsed().as_secs_f64() / full_iters as f64;

        println!(
            "{:<44} {:>12.3} ms/run   ({:.1} plans/s)",
            format!("simcore execute 1024x3 chains ({} nodes)", g.len()),
            inc_per * 1e3,
            1.0 / inc_per
        );
        println!(
            "{:<44} {:>12.3} ms/run   ({:.1} plans/s)",
            "simcore execute_full (reference)",
            full_per * 1e3,
            1.0 / full_per
        );
        let speedup = full_per / inc_per;
        println!(
            "{:<44} {:>11.1}x",
            "incremental speedup at 1024 workers", speedup
        );
        assert!(
            speedup >= 10.0,
            "incremental engine at 1024 workers is only {speedup:.1}x the \
             full re-solve path (bar: 10x)"
        );
    }

    let net = BandwidthModel::uniform(8, 70.0e6, 0.04);
    time("flowsim scatter-reduce n=8", 200, || {
        std::hint::black_box(simulate_scatter_reduce(8, 300e6, &net));
    });
    time("flowsim pipelined scatter-reduce n=8", 200, || {
        std::hint::black_box(simulate_pipelined_scatter_reduce(8, 300e6, &net));
    });

    // real threaded collectives, 4 workers x 1M f32
    for (name, pipelined) in [
        ("real scatter-reduce 4x1M f32", false),
        ("real pipelined scatter-reduce 4x1M f32", true),
    ] {
        time(name, 5, || {
            let store: Arc<dyn ObjectStore> = Arc::new(MemStore::new());
            let handles: Vec<_> = (0..4)
                .map(|rank| {
                    let store = store.clone();
                    std::thread::spawn(move || {
                        let mut g = vec![rank as f32; 1_000_000];
                        let timeout = Duration::from_secs(30);
                        if pipelined {
                            pipelined_scatter_reduce(
                                &store, "b", 0, rank, 4, &mut g, None, timeout,
                            )
                            .unwrap();
                        } else {
                            scatter_reduce(
                                &store, "b", 0, rank, 4, &mut g, None, timeout,
                            )
                            .unwrap();
                        }
                    })
                })
                .collect();
            for h in handles {
                h.join().unwrap();
            }
        });
    }

    // chunked engine: same transfer, bounded store occupancy
    for (name, chunk_kb, in_flight) in [
        ("real chunked pipelined s-r 4x1M f32 (256KBx4)", 256usize, 4usize),
        ("real chunked pipelined s-r 4x1M f32 (64KBx8)", 64, 8),
    ] {
        time(name, 5, || {
            let store: Arc<dyn ObjectStore> = Arc::new(MemStore::new());
            let chunking = Chunking::new(chunk_kb << 10, in_flight);
            let handles: Vec<_> = (0..4)
                .map(|rank| {
                    let store = store.clone();
                    std::thread::spawn(move || {
                        let mut g = vec![rank as f32; 1_000_000];
                        pipelined_scatter_reduce_chunked(
                            &store,
                            "bc",
                            0,
                            rank,
                            4,
                            &mut g,
                            None,
                            Duration::from_secs(30),
                            chunking,
                        )
                        .unwrap();
                    })
                })
                .collect();
            for h in handles {
                h.join().unwrap();
            }
        });
    }

    // chunked duplex on a throttled store: the wall-clock comparison the
    // bounded-memory engine must win or tie (reported, not asserted)
    let throttled = |label: &str, chunking: Option<Chunking>| {
        let n = 4;
        let len = 200_000; // 800 KB per worker
        let bw = 40.0e6;
        let inner: Arc<dyn ObjectStore> = Arc::new(MemStore::new());
        let t0 = Instant::now();
        let handles: Vec<_> = (0..n)
            .map(|rank| {
                let store: Arc<dyn ObjectStore> = Arc::new(
                    ThrottledStore::new(
                        inner.clone(),
                        bw,
                        bw,
                        Duration::from_millis(1),
                    ),
                );
                std::thread::spawn(move || {
                    let mut g = vec![rank as f32; len];
                    let timeout = Duration::from_secs(60);
                    match chunking {
                        Some(c) => pipelined_scatter_reduce_chunked(
                            &store, "t", 0, rank, n, &mut g, None, timeout, c,
                        )
                        .unwrap(),
                        None => pipelined_scatter_reduce(
                            &store, "t", 0, rank, n, &mut g, None, timeout,
                        )
                        .unwrap(),
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        println!(
            "{label:<44} {:>10.3} s wall   (peak store {} KB)",
            t0.elapsed().as_secs_f64(),
            inner.high_water_bytes() >> 10
        );
    };
    throttled("throttled pipelined (unchunked)", None);
    throttled(
        "throttled pipelined chunked 64KBx4",
        Some(Chunking::new(64 << 10, 4)),
    );
}
