//! Bandwidth sharing model: per-worker uplink/downlink capacities plus an
//! optional aggregate storage-side cap (Alibaba OSS, §5.7), allocated
//! max-min fairly among concurrent transfers (progressive filling).
//!
//! This is the substrate under both the collective simulations (§3.3) and
//! the pipeline discrete-event simulator; the closed-form performance
//! model (§3.4.2) is validated against it in Table 3's reproduction.

/// Direction of a transfer relative to the worker.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Dir {
    Up,
    Down,
}

/// Static description of the network around a set of workers.
#[derive(Debug, Clone)]
pub struct BandwidthModel {
    /// Per-worker uplink capacity, bytes/s.
    pub up_bps: Vec<f64>,
    /// Per-worker downlink capacity, bytes/s.
    pub down_bps: Vec<f64>,
    /// Aggregate cap across *all* transfers (storage-side NIC), bytes/s.
    pub aggregate_cap_bps: Option<f64>,
    /// Per-operation storage access latency, seconds.
    pub latency_s: f64,
}

impl BandwidthModel {
    /// Uniform-bandwidth model for `n` workers.
    pub fn uniform(n: usize, bps: f64, latency_s: f64) -> Self {
        Self {
            up_bps: vec![bps; n],
            down_bps: vec![bps; n],
            aggregate_cap_bps: None,
            latency_s,
        }
    }

    pub fn with_aggregate_cap(mut self, cap: f64) -> Self {
        self.aggregate_cap_bps = Some(cap);
        self
    }

    pub fn n_workers(&self) -> usize {
        self.up_bps.len()
    }
}

/// Max-min fair rate allocation by progressive filling.
///
/// `flows[i]` is the list of (worker, dir) link endpoints the flow
/// occupies — one endpoint for worker↔storage transfers, two for direct
/// worker↔VM transfers (HybridPS). Returns bytes/s for each flow.
/// Constraints: each worker's up/down link and the optional aggregate cap.
pub fn max_min_rates(model: &BandwidthModel, flows: &[Vec<(usize, Dir)>]) -> Vec<f64> {
    let nf = flows.len();
    let mut rates = vec![0.0f64; nf];
    if nf == 0 {
        return rates;
    }

    // Build constraint list: (capacity, member flow indices)
    let mut constraints: Vec<(f64, Vec<usize>)> = Vec::new();
    for w in 0..model.n_workers() {
        let ups: Vec<usize> = (0..nf)
            .filter(|&i| flows[i].contains(&(w, Dir::Up)))
            .collect();
        if !ups.is_empty() {
            constraints.push((model.up_bps[w], ups));
        }
        let downs: Vec<usize> = (0..nf)
            .filter(|&i| flows[i].contains(&(w, Dir::Down)))
            .collect();
        if !downs.is_empty() {
            constraints.push((model.down_bps[w], downs));
        }
    }
    if let Some(cap) = model.aggregate_cap_bps {
        constraints.push((cap, (0..nf).collect()));
    }

    let mut active = vec![true; nf];
    let mut used: Vec<f64> = vec![0.0; constraints.len()];
    let mut n_active = nf;

    while n_active > 0 {
        // find the bottleneck: smallest equal increment that saturates a
        // constraint containing at least one active flow
        let mut best_inc = f64::INFINITY;
        for (ci, (cap, members)) in constraints.iter().enumerate() {
            let k = members.iter().filter(|&&i| active[i]).count();
            if k == 0 {
                continue;
            }
            let inc = (cap - used[ci]) / k as f64;
            if inc < best_inc {
                best_inc = inc;
            }
        }
        if !best_inc.is_finite() {
            break; // no binding constraint: unbounded (shouldn't happen)
        }
        let best_inc = best_inc.max(0.0);

        // raise all active flows by best_inc
        for i in 0..nf {
            if active[i] {
                rates[i] += best_inc;
            }
        }
        for (ci, (_, members)) in constraints.iter().enumerate() {
            let k = members.iter().filter(|&&i| active[i]).count();
            used[ci] += best_inc * k as f64;
        }

        // freeze flows in saturated constraints
        let mut froze = false;
        for (ci, (cap, members)) in constraints.iter().enumerate() {
            if used[ci] >= cap - 1e-9 {
                for &i in members {
                    if active[i] {
                        active[i] = false;
                        n_active -= 1;
                        froze = true;
                    }
                }
            }
        }
        if !froze {
            break; // numerical safety
        }
    }
    rates
}

/// Continuous-time flow simulator with dependencies.
///
/// Flows are added with either an absolute ready time or a dependency list
/// (they start `latency_s` after the last dependency finishes — modelling
/// `t_lat` per storage operation). `run()` advances time, re-running the
/// max-min allocation whenever the active set changes, and records each
/// flow's finish time.
pub struct FlowSim {
    model: BandwidthModel,
    flows: Vec<FlowState>,
}

struct FlowState {
    endpoints: Vec<(usize, Dir)>,
    bytes: f64,
    remaining: f64,
    /// Absolute ready time (for root flows) — refined as deps complete.
    ready: f64,
    deps: Vec<usize>,
    extra_delay: f64,
    finish: Option<f64>,
}

impl FlowSim {
    pub fn new(model: BandwidthModel) -> Self {
        Self { model, flows: Vec::new() }
    }

    /// Flow with no dependencies, ready at `ready` (storage latency is
    /// added automatically).
    pub fn add_flow(&mut self, worker: usize, dir: Dir, bytes: f64, ready: f64) -> usize {
        self.add(vec![(worker, dir)], bytes, ready, Vec::new(), 0.0)
    }

    /// Flow that starts `latency` after all `deps` finish.
    pub fn add_flow_after(
        &mut self,
        worker: usize,
        dir: Dir,
        bytes: f64,
        deps: Vec<usize>,
        extra_delay: f64,
    ) -> usize {
        self.add(vec![(worker, dir)], bytes, 0.0, deps, extra_delay)
    }

    /// Direct worker→worker flow (occupies src uplink AND dst downlink) —
    /// the HybridPS worker↔VM path.
    pub fn add_direct_flow_after(
        &mut self,
        src: usize,
        dst: usize,
        bytes: f64,
        deps: Vec<usize>,
        ready: f64,
    ) -> usize {
        self.add(vec![(src, Dir::Up), (dst, Dir::Down)], bytes, ready, deps, 0.0)
    }

    fn add(
        &mut self,
        endpoints: Vec<(usize, Dir)>,
        bytes: f64,
        ready: f64,
        deps: Vec<usize>,
        extra_delay: f64,
    ) -> usize {
        for &(w, _) in &endpoints {
            assert!(w < self.model.n_workers());
        }
        let id = self.flows.len();
        self.flows.push(FlowState {
            endpoints,
            bytes: bytes.max(0.0),
            remaining: bytes.max(0.0),
            ready: ready + self.model.latency_s,
            deps,
            extra_delay,
            finish: None,
        });
        id
    }

    /// Simulate to completion of all flows; returns the makespan.
    pub fn run(&mut self) -> f64 {
        let n = self.flows.len();
        let mut resolved_ready: Vec<Option<f64>> = (0..n)
            .map(|i| {
                if self.flows[i].deps.is_empty() {
                    Some(self.flows[i].ready)
                } else {
                    None
                }
            })
            .collect();
        let mut t = 0.0f64;
        let mut done = 0usize;
        let mut makespan = 0.0f64;

        while done < n {
            // active set: ready and unfinished
            let active: Vec<usize> = (0..n)
                .filter(|&i| {
                    self.flows[i].finish.is_none()
                        && resolved_ready[i].map(|r| r <= t + 1e-12).unwrap_or(false)
                })
                .collect();

            // zero-byte active flows complete instantly
            let mut finished_now = Vec::new();
            for &i in &active {
                if self.flows[i].remaining <= 1e-9 {
                    self.flows[i].finish = Some(t);
                    finished_now.push(i);
                }
            }
            if !finished_now.is_empty() {
                done += finished_now.len();
                makespan = makespan.max(t);
                Self::resolve_deps(
                    &self.flows,
                    &mut resolved_ready,
                    &finished_now,
                    self.model.latency_s,
                );
                continue;
            }

            // next activation among not-yet-ready flows with known ready
            let next_ready = (0..n)
                .filter(|&i| self.flows[i].finish.is_none())
                .filter_map(|i| resolved_ready[i])
                .filter(|&r| r > t + 1e-12)
                .fold(f64::INFINITY, f64::min);

            if active.is_empty() {
                assert!(
                    next_ready.is_finite(),
                    "deadlock: {} unfinished flows but none ready",
                    n - done
                );
                t = next_ready;
                continue;
            }

            let pairs: Vec<Vec<(usize, Dir)>> = active
                .iter()
                .map(|&i| self.flows[i].endpoints.clone())
                .collect();
            let rates = max_min_rates(&self.model, &pairs);

            // earliest completion among active flows at these rates
            let mut dt = f64::INFINITY;
            for (k, &i) in active.iter().enumerate() {
                if rates[k] > 1e-12 {
                    dt = dt.min(self.flows[i].remaining / rates[k]);
                }
            }
            if next_ready.is_finite() {
                dt = dt.min(next_ready - t);
            }
            assert!(dt.is_finite(), "no progress possible");

            // advance
            for (k, &i) in active.iter().enumerate() {
                self.flows[i].remaining -= rates[k] * dt;
            }
            t += dt;

            let newly: Vec<usize> = active
                .iter()
                .copied()
                .filter(|&i| self.flows[i].remaining <= 1e-6)
                .collect();
            for &i in &newly {
                self.flows[i].remaining = 0.0;
                self.flows[i].finish = Some(t);
            }
            if !newly.is_empty() {
                done += newly.len();
                makespan = makespan.max(t);
                Self::resolve_deps(
                    &self.flows,
                    &mut resolved_ready,
                    &newly,
                    self.model.latency_s,
                );
            }
        }
        makespan
    }

    fn resolve_deps(
        flows: &[FlowState],
        resolved_ready: &mut [Option<f64>],
        _finished: &[usize],
        latency: f64,
    ) {
        for i in 0..flows.len() {
            if resolved_ready[i].is_some() || flows[i].deps.is_empty() {
                continue;
            }
            let mut all = true;
            let mut latest: f64 = 0.0;
            for &d in &flows[i].deps {
                match flows[d].finish {
                    Some(f) => latest = latest.max(f),
                    None => {
                        all = false;
                        break;
                    }
                }
            }
            if all {
                resolved_ready[i] =
                    Some(latest + flows[i].extra_delay + latency);
            }
        }
    }

    pub fn finish_time(&self, id: usize) -> f64 {
        self.flows[id].finish.expect("flow not finished; call run() first")
    }

    pub fn bytes(&self, id: usize) -> f64 {
        self.flows[id].bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn close(a: f64, b: f64) -> bool {
        (a - b).abs() < 1e-6 * b.abs().max(1.0)
    }

    #[test]
    fn single_flow_time_is_bytes_over_bw() {
        let m = BandwidthModel::uniform(1, 100.0, 0.0);
        let mut sim = FlowSim::new(m);
        let f = sim.add_flow(0, Dir::Up, 1000.0, 0.0);
        sim.run();
        assert!(close(sim.finish_time(f), 10.0));
    }

    #[test]
    fn uplink_shared_fairly() {
        let m = BandwidthModel::uniform(1, 100.0, 0.0);
        let mut sim = FlowSim::new(m);
        let a = sim.add_flow(0, Dir::Up, 500.0, 0.0);
        let b = sim.add_flow(0, Dir::Up, 500.0, 0.0);
        sim.run();
        // two equal flows share 100 B/s: each finishes at 10 s
        assert!(close(sim.finish_time(a), 10.0));
        assert!(close(sim.finish_time(b), 10.0));
    }

    #[test]
    fn duplex_links_are_independent() {
        // The core assumption behind pipelined scatter-reduce (§3.3):
        // uplink and downlink proceed simultaneously.
        let m = BandwidthModel::uniform(1, 100.0, 0.0);
        let mut sim = FlowSim::new(m);
        let up = sim.add_flow(0, Dir::Up, 1000.0, 0.0);
        let down = sim.add_flow(0, Dir::Down, 1000.0, 0.0);
        sim.run();
        assert!(close(sim.finish_time(up), 10.0));
        assert!(close(sim.finish_time(down), 10.0));
    }

    #[test]
    fn aggregate_cap_binds() {
        let m = BandwidthModel::uniform(4, 100.0, 0.0).with_aggregate_cap(200.0);
        let mut sim = FlowSim::new(m);
        let ids: Vec<usize> =
            (0..4).map(|w| sim.add_flow(w, Dir::Up, 500.0, 0.0)).collect();
        sim.run();
        // 4 flows share 200 B/s aggregate → 50 B/s each → 10 s
        for id in ids {
            assert!(close(sim.finish_time(id), 10.0));
        }
    }

    #[test]
    fn dependencies_and_latency() {
        let m = BandwidthModel::uniform(2, 100.0, 0.5);
        let mut sim = FlowSim::new(m);
        let a = sim.add_flow(0, Dir::Up, 100.0, 0.0); // ready 0.5, done 1.5
        let b = sim.add_flow_after(1, Dir::Down, 100.0, vec![a], 0.0);
        sim.run();
        assert!(close(sim.finish_time(a), 1.5));
        // b starts at 1.5 + 0.5 latency, takes 1 s
        assert!(close(sim.finish_time(b), 3.0));
    }

    #[test]
    fn max_min_heterogeneous() {
        let m = BandwidthModel {
            up_bps: vec![100.0, 10.0],
            down_bps: vec![100.0, 100.0],
            aggregate_cap_bps: None,
            latency_s: 0.0,
        };
        let rates = max_min_rates(
            &m,
            &[vec![(0, Dir::Up)], vec![(1, Dir::Up)]],
        );
        assert!(close(rates[0], 100.0));
        assert!(close(rates[1], 10.0));
    }

    #[test]
    fn zero_byte_flows_finish_at_ready() {
        let m = BandwidthModel::uniform(1, 100.0, 0.25);
        let mut sim = FlowSim::new(m);
        let f = sim.add_flow(0, Dir::Up, 0.0, 1.0);
        sim.run();
        assert!(close(sim.finish_time(f), 1.25));
    }
}
