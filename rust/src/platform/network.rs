//! Bandwidth sharing model: per-worker uplink/downlink capacities plus an
//! optional aggregate storage-side cap (Alibaba OSS, §5.7), allocated
//! max-min fairly among concurrent transfers (progressive filling).
//!
//! This is the substrate under both the collective simulations (§3.3) and
//! the pipeline discrete-event simulator; the closed-form performance
//! model (§3.4.2) is validated against it in Table 3's reproduction.

/// Direction of a transfer relative to the worker.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Dir {
    Up,
    Down,
}

/// Static description of the network around a set of workers.
#[derive(Debug, Clone)]
pub struct BandwidthModel {
    /// Per-worker uplink capacity, bytes/s.
    pub up_bps: Vec<f64>,
    /// Per-worker downlink capacity, bytes/s.
    pub down_bps: Vec<f64>,
    /// Aggregate cap across *all* transfers (storage-side NIC), bytes/s.
    pub aggregate_cap_bps: Option<f64>,
    /// Per-operation storage access latency, seconds.
    pub latency_s: f64,
}

impl BandwidthModel {
    /// Uniform-bandwidth model for `n` workers.
    pub fn uniform(n: usize, bps: f64, latency_s: f64) -> Self {
        Self {
            up_bps: vec![bps; n],
            down_bps: vec![bps; n],
            aggregate_cap_bps: None,
            latency_s,
        }
    }

    pub fn with_aggregate_cap(mut self, cap: f64) -> Self {
        self.aggregate_cap_bps = Some(cap);
        self
    }

    pub fn n_workers(&self) -> usize {
        self.up_bps.len()
    }
}

/// Max-min fair rate allocation by progressive filling.
///
/// `flows[i]` is the list of (worker, dir) link endpoints the flow
/// occupies — one endpoint for worker↔storage transfers, two for direct
/// worker↔VM transfers (HybridPS). Returns bytes/s for each flow.
/// Constraints: each worker's up/down link and the optional aggregate cap.
///
/// An adapter over the unified engine's allocator
/// ([`simcore::allocate_rates`](crate::simcore::allocate_rates)) — the
/// exact code that times every simulation, so the property tests on
/// this entry point exercise the production path.
pub fn max_min_rates(model: &BandwidthModel, flows: &[Vec<(usize, Dir)>]) -> Vec<f64> {
    use crate::simcore::{FlowGraph, Node, OpKind, Resource};
    let mut g = FlowGraph::with_network(model);
    let ids: Vec<usize> = flows
        .iter()
        .map(|endpoints| {
            g.add(Node {
                kind: OpKind::Transfer,
                worker: endpoints.first().map_or(0, |e| e.0),
                resources: endpoints
                    .iter()
                    .map(|&(w, d)| match d {
                        Dir::Up => Resource::Up(w),
                        Dir::Down => Resource::Down(w),
                    })
                    .collect(),
                // only the instantaneous rate is asked for; the work
                // amount never enters the allocation
                work: 1.0,
                deps: Vec::new(),
                ready: 0.0,
                delay: 0.0,
            })
        })
        .collect();
    crate::simcore::allocate_rates(&g, &ids)
}

/// Continuous-time flow simulator with dependencies — a thin
/// compatibility facade over the unified [`simcore`](crate::simcore)
/// engine (it used to carry its own event loop; simcore's is the same
/// algorithm, shared with the pipeline DES).
///
/// Flows are added with either an absolute ready time or a dependency list
/// (they start `latency_s` after the last dependency finishes — modelling
/// `t_lat` per storage operation). `run()` executes the accumulated graph
/// and records each flow's finish time.
pub struct FlowSim {
    n_workers: usize,
    graph: crate::simcore::FlowGraph,
    outcome: Option<crate::simcore::SimOutcome>,
}

impl FlowSim {
    pub fn new(model: BandwidthModel) -> Self {
        Self {
            n_workers: model.n_workers(),
            graph: crate::simcore::FlowGraph::with_network(&model),
            outcome: None,
        }
    }

    /// Flow with no dependencies, ready at `ready` (storage latency is
    /// added automatically).
    pub fn add_flow(&mut self, worker: usize, dir: Dir, bytes: f64, ready: f64) -> usize {
        assert!(worker < self.n_workers);
        self.graph.add(
            crate::simcore::Node::transfer(worker, dir == Dir::Up, bytes)
                .ready_at(ready),
        )
    }

    /// Flow that starts `latency` after all `deps` finish.
    pub fn add_flow_after(
        &mut self,
        worker: usize,
        dir: Dir,
        bytes: f64,
        deps: Vec<usize>,
        extra_delay: f64,
    ) -> usize {
        assert!(worker < self.n_workers);
        self.graph.add(
            crate::simcore::Node::transfer(worker, dir == Dir::Up, bytes)
                .after(deps)
                .lag(extra_delay),
        )
    }

    /// Direct worker→worker flow (occupies src uplink AND dst downlink) —
    /// the HybridPS worker↔VM path.
    pub fn add_direct_flow_after(
        &mut self,
        src: usize,
        dst: usize,
        bytes: f64,
        deps: Vec<usize>,
        ready: f64,
    ) -> usize {
        assert!(src < self.n_workers && dst < self.n_workers);
        self.graph.add(
            crate::simcore::Node::direct(src, dst, bytes)
                .after(deps)
                .ready_at(ready),
        )
    }

    /// Simulate to completion of all flows; returns the makespan.
    pub fn run(&mut self) -> f64 {
        let outcome = crate::simcore::execute(&self.graph);
        let makespan = outcome.makespan;
        self.outcome = Some(outcome);
        makespan
    }

    pub fn finish_time(&self, id: usize) -> f64 {
        self.outcome
            .as_ref()
            .expect("flow not finished; call run() first")
            .finish[id]
    }

    pub fn bytes(&self, id: usize) -> f64 {
        self.graph.nodes[id].work
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn close(a: f64, b: f64) -> bool {
        (a - b).abs() < 1e-6 * b.abs().max(1.0)
    }

    #[test]
    fn single_flow_time_is_bytes_over_bw() {
        let m = BandwidthModel::uniform(1, 100.0, 0.0);
        let mut sim = FlowSim::new(m);
        let f = sim.add_flow(0, Dir::Up, 1000.0, 0.0);
        sim.run();
        assert!(close(sim.finish_time(f), 10.0));
    }

    #[test]
    fn uplink_shared_fairly() {
        let m = BandwidthModel::uniform(1, 100.0, 0.0);
        let mut sim = FlowSim::new(m);
        let a = sim.add_flow(0, Dir::Up, 500.0, 0.0);
        let b = sim.add_flow(0, Dir::Up, 500.0, 0.0);
        sim.run();
        // two equal flows share 100 B/s: each finishes at 10 s
        assert!(close(sim.finish_time(a), 10.0));
        assert!(close(sim.finish_time(b), 10.0));
    }

    #[test]
    fn duplex_links_are_independent() {
        // The core assumption behind pipelined scatter-reduce (§3.3):
        // uplink and downlink proceed simultaneously.
        let m = BandwidthModel::uniform(1, 100.0, 0.0);
        let mut sim = FlowSim::new(m);
        let up = sim.add_flow(0, Dir::Up, 1000.0, 0.0);
        let down = sim.add_flow(0, Dir::Down, 1000.0, 0.0);
        sim.run();
        assert!(close(sim.finish_time(up), 10.0));
        assert!(close(sim.finish_time(down), 10.0));
    }

    #[test]
    fn aggregate_cap_binds() {
        let m = BandwidthModel::uniform(4, 100.0, 0.0).with_aggregate_cap(200.0);
        let mut sim = FlowSim::new(m);
        let ids: Vec<usize> =
            (0..4).map(|w| sim.add_flow(w, Dir::Up, 500.0, 0.0)).collect();
        sim.run();
        // 4 flows share 200 B/s aggregate → 50 B/s each → 10 s
        for id in ids {
            assert!(close(sim.finish_time(id), 10.0));
        }
    }

    #[test]
    fn dependencies_and_latency() {
        let m = BandwidthModel::uniform(2, 100.0, 0.5);
        let mut sim = FlowSim::new(m);
        let a = sim.add_flow(0, Dir::Up, 100.0, 0.0); // ready 0.5, done 1.5
        let b = sim.add_flow_after(1, Dir::Down, 100.0, vec![a], 0.0);
        sim.run();
        assert!(close(sim.finish_time(a), 1.5));
        // b starts at 1.5 + 0.5 latency, takes 1 s
        assert!(close(sim.finish_time(b), 3.0));
    }

    #[test]
    fn max_min_heterogeneous() {
        let m = BandwidthModel {
            up_bps: vec![100.0, 10.0],
            down_bps: vec![100.0, 100.0],
            aggregate_cap_bps: None,
            latency_s: 0.0,
        };
        let rates = max_min_rates(
            &m,
            &[vec![(0, Dir::Up)], vec![(1, Dir::Up)]],
        );
        assert!(close(rates[0], 100.0));
        assert!(close(rates[1], 10.0));
    }

    #[test]
    fn zero_byte_flows_finish_at_ready() {
        let m = BandwidthModel::uniform(1, 100.0, 0.25);
        let mut sim = FlowSim::new(m);
        let f = sim.add_flow(0, Dir::Up, 0.0, 1.0);
        sim.run();
        assert!(close(sim.finish_time(f), 1.25));
    }
}
