//! Memory tiers and platform presets (AWS Lambda, Alibaba Function
//! Compute), calibrated to the constants the paper reports (§2.1, §5.1).

/// One configurable memory size with its derived resources.
///
/// On real platforms "users decide the memory allocation; other resources
/// like CPU and network bandwidth are allocated accordingly" (§2.1) — so a
/// tier is the single resource knob everywhere in FuncPipe.
#[derive(Debug, Clone, PartialEq)]
pub struct MemoryTier {
    /// Allocated memory in MB (binary MB, as billed).
    pub mem_mb: u64,
    /// Sustained per-function bandwidth, bytes/s, each direction.
    pub bandwidth_bps: f64,
    /// Relative compute speed (1.0 == one reference vCPU).
    pub compute_speed: f64,
    /// Cold-start latency when provisioning a container of this tier,
    /// seconds. The Function Manager's checkpoint/restart path charges
    /// it once per generation (§3.1 step 8); uniform across tiers on
    /// today's presets (the platform's measured base).
    pub cold_start_s: f64,
}

impl MemoryTier {
    pub fn mem_bytes(&self) -> u64 {
        self.mem_mb * 1024 * 1024
    }

    pub fn mem_gb(&self) -> f64 {
        self.mem_mb as f64 / 1024.0
    }
}

/// Cloud-storage behaviour relevant to storage-relayed communication.
#[derive(Debug, Clone, PartialEq)]
pub struct StorageSpec {
    /// Access latency per operation, seconds (`t_lat`; ~40 ms on S3).
    pub latency_s: f64,
    /// Aggregate concurrent bandwidth cap in bytes/s (OSS: 10 Gb/s for a
    /// normal customer, §5.1). `None` == effectively unlimited (S3).
    pub aggregate_cap_bps: Option<f64>,
}

/// Everything the planner/simulator needs to know about a platform.
#[derive(Debug, Clone)]
pub struct PlatformSpec {
    pub name: String,
    pub tiers: Vec<MemoryTier>,
    /// $ per GB-second of allocated function memory.
    pub price_per_gb_s: f64,
    pub storage: StorageSpec,
    /// Maximum function lifetime in seconds (15 min on Lambda).
    pub function_lifetime_s: f64,
    /// Cold-start latency when launching a function, seconds.
    pub cold_start_s: f64,
    /// Base memory consumed by the framework on each worker, MB (`s_0`).
    pub base_mem_mb: u64,
    /// Average compute slowdown when compute and communication overlap
    /// (`β ≥ 1` in eq. (8); measured by the Model Profiler).
    pub beta: f64,
    /// Per-worker bandwidth degradation slope with total worker count
    /// (§5.4: co-scheduled functions share host NICs). Effective
    /// bandwidth = W * max(floor, 1 - slope*(n-1)).
    pub contention_slope: f64,
    pub contention_floor: f64,
    /// Maximum concurrently running functions the platform sells (§2.1:
    /// providers cap per-account concurrency — 1000 on Lambda, 300 on
    /// Function Compute by default). The planner rejects data-parallel
    /// degrees beyond it: the platform cannot price replicas it will
    /// not launch.
    pub max_concurrency: usize,
}

impl PlatformSpec {
    /// AWS Lambda + S3 (§5.1): 10 GB memory cap, ~70 MB/s function
    /// bandwidth, unlimited aggregate S3 bandwidth, $/GB-s pricing.
    pub fn aws_lambda() -> Self {
        // One vCPU per 1769 MB (AWS documentation); bandwidth ramps with
        // memory and saturates at the measured ~70 MB/s [36, 70].
        let mems = [512u64, 1024, 2048, 3072, 4096, 6144, 8192, 10240];
        let tiers = mems
            .iter()
            .map(|&m| MemoryTier {
                mem_mb: m,
                bandwidth_bps: 70.0e6 * (m as f64 / 1769.0).min(1.0),
                compute_speed: m as f64 / 1769.0,
                cold_start_s: 1.5,
            })
            .collect();
        Self {
            name: "aws-lambda".into(),
            tiers,
            price_per_gb_s: 0.0000166667,
            storage: StorageSpec { latency_s: 0.040, aggregate_cap_bps: None },
            function_lifetime_s: 900.0,
            cold_start_s: 1.5,
            base_mem_mb: 300,
            beta: 1.15,
            contention_slope: 0.008,
            contention_floor: 0.45,
            max_concurrency: 1000,
        }
    }

    /// Alibaba Function Compute + OSS (§5.1, §5.7): 32 GB memory cap and a
    /// 10 Gb/s *aggregate* OSS bandwidth limit shared by all workers.
    pub fn alibaba_fc() -> Self {
        let mems = [512u64, 1024, 2048, 4096, 8192, 16384, 32768];
        let tiers = mems
            .iter()
            .map(|&m| MemoryTier {
                mem_mb: m,
                bandwidth_bps: 100.0e6 * (m as f64 / 2048.0).min(1.0),
                compute_speed: m as f64 / 1769.0,
                cold_start_s: 1.0,
            })
            .collect();
        Self {
            name: "alibaba-fc".into(),
            tiers,
            price_per_gb_s: 0.000016384,
            storage: StorageSpec {
                latency_s: 0.030,
                aggregate_cap_bps: Some(10.0e9 / 8.0), // 10 Gb/s
            },
            function_lifetime_s: 86_400.0,
            cold_start_s: 1.0,
            base_mem_mb: 300,
            beta: 1.15,
            contention_slope: 0.006,
            contention_floor: 0.5,
            max_concurrency: 300,
        }
    }

    /// A "local" platform used by the real-execution trainer and tests:
    /// generous bandwidth, tiny latency, short lifetime so the
    /// checkpoint/restart path is exercised quickly.
    pub fn local_sim() -> Self {
        let mems = [512u64, 1024, 2048, 4096];
        let tiers = mems
            .iter()
            .map(|&m| MemoryTier {
                mem_mb: m,
                bandwidth_bps: 400.0e6,
                compute_speed: 1.0,
                cold_start_s: 0.01,
            })
            .collect();
        Self {
            name: "local-sim".into(),
            tiers,
            price_per_gb_s: 0.0000166667,
            storage: StorageSpec { latency_s: 0.0005, aggregate_cap_bps: None },
            function_lifetime_s: 20.0,
            cold_start_s: 0.01,
            base_mem_mb: 0,
            beta: 1.05,
            contention_slope: 0.0,
            contention_floor: 1.0,
            max_concurrency: 256,
        }
    }

    /// Scale every tier's bandwidth by `factor` (Fig. 11's 1×..20× sweep).
    pub fn with_bandwidth_scale(mut self, factor: f64) -> Self {
        for t in &mut self.tiers {
            t.bandwidth_bps *= factor;
        }
        self
    }

    pub fn tier(&self, idx: usize) -> &MemoryTier {
        &self.tiers[idx]
    }

    pub fn n_tiers(&self) -> usize {
        self.tiers.len()
    }

    pub fn max_tier(&self) -> usize {
        self.tiers.len() - 1
    }

    pub fn max_mem_mb(&self) -> u64 {
        self.tiers.iter().map(|t| t.mem_mb).max().unwrap_or(0)
    }

    /// Effective per-worker bandwidth with `n` workers active (§5.4).
    pub fn effective_bandwidth(&self, tier: usize, n_workers: usize) -> f64 {
        let w = self.tiers[tier].bandwidth_bps;
        let factor = (1.0 - self.contention_slope * (n_workers.saturating_sub(1)) as f64)
            .max(self.contention_floor);
        let per = w * factor;
        match self.storage.aggregate_cap_bps {
            Some(cap) if n_workers > 0 => per.min(cap / n_workers as f64),
            _ => per,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aws_tier_constants_match_paper() {
        let p = PlatformSpec::aws_lambda();
        assert_eq!(p.tiers.len(), 8); // §5.1: 8 discrete choices
        assert_eq!(p.max_mem_mb(), 10240); // 10 GB cap
        let top = p.tier(p.max_tier());
        assert!((top.bandwidth_bps - 70.0e6).abs() < 1.0); // ~70 MB/s
        assert!((p.function_lifetime_s - 900.0).abs() < 1e-9); // 15 min
    }

    #[test]
    fn bandwidth_monotone_in_memory() {
        let p = PlatformSpec::aws_lambda();
        for w in p.tiers.windows(2) {
            assert!(w[0].bandwidth_bps <= w[1].bandwidth_bps);
            assert!(w[0].compute_speed < w[1].compute_speed);
        }
    }

    #[test]
    fn alibaba_has_aggregate_cap() {
        let p = PlatformSpec::alibaba_fc();
        assert_eq!(p.max_mem_mb(), 32768); // 32 GB cap
        let cap = p.storage.aggregate_cap_bps.unwrap();
        assert!((cap - 1.25e9).abs() < 1.0); // 10 Gb/s
        // with many workers, the cap binds:
        let few = p.effective_bandwidth(p.max_tier(), 2);
        let many = p.effective_bandwidth(p.max_tier(), 64);
        assert!(many < few);
        assert!(many <= cap / 64.0 + 1.0);
    }

    #[test]
    fn contention_reduces_bandwidth() {
        let p = PlatformSpec::aws_lambda();
        let alone = p.effective_bandwidth(7, 1);
        let crowded = p.effective_bandwidth(7, 32);
        assert!(crowded < alone);
        assert!(crowded >= alone * p.contention_floor - 1.0);
    }

    #[test]
    fn tier_cold_starts_match_platform_base() {
        for p in [
            PlatformSpec::aws_lambda(),
            PlatformSpec::alibaba_fc(),
            PlatformSpec::local_sim(),
        ] {
            for t in &p.tiers {
                assert!(
                    (t.cold_start_s - p.cold_start_s).abs() < 1e-12,
                    "{}: tier {}MB cold start drifted from the base",
                    p.name,
                    t.mem_mb
                );
            }
        }
    }

    #[test]
    fn concurrency_caps_cover_the_default_dp_space() {
        for p in [
            PlatformSpec::aws_lambda(),
            PlatformSpec::alibaba_fc(),
            PlatformSpec::local_sim(),
        ] {
            assert!(p.max_concurrency > 0);
            // every default dp degree is launchable on every platform
            for d in crate::planner::DEFAULT_DP_OPTIONS {
                assert!(d <= p.max_concurrency, "{}: dp {d}", p.name);
            }
        }
    }

    #[test]
    fn bandwidth_scaling() {
        let p = PlatformSpec::aws_lambda().with_bandwidth_scale(20.0);
        let top = p.tier(p.max_tier());
        assert!((top.bandwidth_bps - 1.4e9).abs() < 10.0);
    }
}
