//! Object storage for the real-execution path.
//!
//! FuncPipe functions cannot talk to each other directly; every byte is
//! relayed through object storage (§2.1). `MemStore` is the in-process
//! equivalent of an S3 bucket: blocking `get` with condition-variable
//! wake-ups plays the role of the paper's "workers periodically query the
//! bucket" polling (§4) without the poll latency. `ThrottledStore` wraps a
//! store with per-handle uplink/downlink rate limits + access latency so
//! the wall-clock behaviour of the e2e trainer resembles a serverless
//! worker's 70 MB/s world (scaled up so demos finish quickly).

use std::collections::HashMap;
use std::future::Future;
use std::pin::Pin;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::task::{Poll, Waker};
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

/// Boxed future the async store methods return (`Arc<dyn ObjectStore>`
/// stays object-safe).
pub type StoreFuture<'a, T> = Pin<Box<dyn Future<Output = T> + Send + 'a>>;

/// S3/OSS-like blob interface. Keys are flat strings; metadata (sender,
/// step, micro-batch id) is encoded in the key like the paper does (§4).
pub trait ObjectStore: Send + Sync {
    /// Upload an object (overwrites).
    fn put(&self, key: &str, data: Vec<u8>) -> Result<()>;

    /// Non-blocking fetch.
    fn get(&self, key: &str) -> Option<Arc<Vec<u8>>>;

    /// Blocking fetch with timeout — the download side of send/recv.
    fn get_blocking(&self, key: &str, timeout: Duration) -> Result<Arc<Vec<u8>>>;

    /// Delete an object (idempotent).
    fn delete(&self, key: &str);

    /// List keys with a prefix (used by sync barriers and the monitor).
    fn list(&self, prefix: &str) -> Vec<String>;

    /// Total bytes currently stored (tests/metrics).
    fn total_bytes(&self) -> u64;

    /// Peak of `total_bytes` over the store's lifetime — the memory the
    /// relay bucket would have needed. The chunked collectives bound this
    /// by `workers × chunks_in_flight × chunk_bytes`; stores that do not
    /// track it report 0.
    fn high_water_bytes(&self) -> u64 {
        0
    }

    /// Async twin of [`get_blocking`](Self::get_blocking): resolves when
    /// the key appears (or the deadline passes) *without* pinning an OS
    /// thread — the primitive the pooled executor's worker state
    /// machines are built on. Counter semantics are identical to the
    /// blocking path (one `gets` bump, on success only), so replay
    /// byte-compares see the same `store_put_gets` either way.
    ///
    /// The default simply runs the blocking fetch eagerly and wraps the
    /// result — correct for any store, but it blocks the polling thread;
    /// the in-repo stores all override it with real wakeups.
    fn get_async<'a>(
        &'a self,
        key: &'a str,
        timeout: Duration,
    ) -> StoreFuture<'a, Result<Arc<Vec<u8>>>> {
        let r = self.get_blocking(key, timeout);
        Box::pin(async move { r })
    }

    /// Async twin of [`put`](Self::put). The default runs the blocking
    /// put eagerly (fine for instant stores like [`MemStore`]); throttled
    /// stores override it to sleep on the executor's timer instead of
    /// the OS clock.
    fn put_async<'a>(&'a self, key: &'a str, data: Vec<u8>) -> StoreFuture<'a, Result<()>> {
        let r = self.put(key, data);
        Box::pin(async move { r })
    }
}

#[derive(Default)]
struct StoreInner {
    map: HashMap<String, Arc<Vec<u8>>>,
    puts: u64,
    gets: u64,
    cur_bytes: u64,
    high_water_bytes: u64,
    /// Async fetch wakers, woken (all of them) on every put — the task
    /// equivalent of the `Condvar::notify_all` the blocking path uses.
    waiters: Vec<Waker>,
}

/// In-memory object store shared by all workers in a process.
pub struct MemStore {
    inner: Mutex<StoreInner>,
    cond: Condvar,
}

impl Default for MemStore {
    fn default() -> Self {
        Self::new()
    }
}

impl MemStore {
    pub fn new() -> Self {
        Self { inner: Mutex::new(StoreInner::default()), cond: Condvar::new() }
    }

    pub fn stats(&self) -> (u64, u64) {
        let g = self.inner.lock().unwrap();
        (g.puts, g.gets)
    }
}

impl ObjectStore for MemStore {
    fn put(&self, key: &str, data: Vec<u8>) -> Result<()> {
        let mut g = self.inner.lock().unwrap();
        g.puts += 1;
        let added = data.len() as u64;
        if let Some(old) = g.map.insert(key.to_string(), Arc::new(data)) {
            g.cur_bytes -= old.len() as u64;
        }
        g.cur_bytes += added;
        g.high_water_bytes = g.high_water_bytes.max(g.cur_bytes);
        let waiters = std::mem::take(&mut g.waiters);
        drop(g);
        self.cond.notify_all();
        for w in waiters {
            w.wake();
        }
        Ok(())
    }

    fn get(&self, key: &str) -> Option<Arc<Vec<u8>>> {
        let mut g = self.inner.lock().unwrap();
        g.gets += 1;
        g.map.get(key).cloned()
    }

    fn get_blocking(&self, key: &str, timeout: Duration) -> Result<Arc<Vec<u8>>> {
        let deadline = Instant::now() + timeout;
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(v) = g.map.get(key).cloned() {
                g.gets += 1;
                return Ok(v);
            }
            let now = Instant::now();
            if now >= deadline {
                bail!("get_blocking timed out waiting for {key:?}");
            }
            let (guard, res) = self
                .cond
                .wait_timeout(g, deadline - now)
                .unwrap();
            g = guard;
            if res.timed_out() && !g.map.contains_key(key) {
                bail!("get_blocking timed out waiting for {key:?}");
            }
        }
    }

    fn delete(&self, key: &str) {
        let mut g = self.inner.lock().unwrap();
        if let Some(old) = g.map.remove(key) {
            g.cur_bytes -= old.len() as u64;
        }
    }

    fn list(&self, prefix: &str) -> Vec<String> {
        let g = self.inner.lock().unwrap();
        let mut keys: Vec<String> = g
            .map
            .keys()
            .filter(|k| k.starts_with(prefix))
            .cloned()
            .collect();
        keys.sort();
        keys
    }

    fn total_bytes(&self) -> u64 {
        let g = self.inner.lock().unwrap();
        debug_assert_eq!(
            g.cur_bytes,
            g.map.values().map(|v| v.len() as u64).sum::<u64>()
        );
        g.cur_bytes
    }

    fn high_water_bytes(&self) -> u64 {
        self.inner.lock().unwrap().high_water_bytes
    }

    fn get_async<'a>(
        &'a self,
        key: &'a str,
        timeout: Duration,
    ) -> StoreFuture<'a, Result<Arc<Vec<u8>>>> {
        let deadline = Instant::now() + timeout;
        let mut deadline_armed = false;
        Box::pin(std::future::poll_fn(move |cx| {
            let mut g = self.inner.lock().unwrap();
            if let Some(v) = g.map.get(key).cloned() {
                g.gets += 1; // success-only bump, like the blocking path
                return Poll::Ready(Ok(v));
            }
            if Instant::now() >= deadline {
                return Poll::Ready(Err(anyhow::anyhow!(
                    "get_blocking timed out waiting for {key:?}"
                )));
            }
            g.waiters.push(cx.waker().clone());
            drop(g);
            if !deadline_armed {
                // one timer entry per fetch so the deadline fires even
                // if no put ever wakes us
                crate::exec::timer::register(deadline, cx.waker().clone());
                deadline_armed = true;
            }
            Poll::Pending
        }))
    }
}

/// Per-worker throttled view of a store: sleeps `len/bandwidth + latency`
/// on put (uplink) and on the fetch side of get (downlink), emulating the
/// per-function bandwidth limit. One handle per worker so transfers from
/// different workers proceed concurrently like real NICs.
pub struct ThrottledStore {
    inner: Arc<dyn ObjectStore>,
    pub uplink_bps: f64,
    pub downlink_bps: f64,
    pub latency: Duration,
}

impl ThrottledStore {
    pub fn new(
        inner: Arc<dyn ObjectStore>,
        uplink_bps: f64,
        downlink_bps: f64,
        latency: Duration,
    ) -> Self {
        Self { inner, uplink_bps, downlink_bps, latency }
    }

    /// Scale this handle by a scenario lens: bandwidth multiplied (so a
    /// multiplier < 1 slows the worker), latency multiplied. How the
    /// [`Injector`](crate::scenario::Injector) gives each worker its own
    /// perturbed "NIC" over the shared bucket.
    pub fn scaled(mut self, bandwidth_mult: f64, latency_mult: f64) -> Self {
        self.uplink_bps *= bandwidth_mult;
        self.downlink_bps *= bandwidth_mult;
        self.latency = Duration::from_secs_f64(
            self.latency.as_secs_f64() * latency_mult,
        );
        self
    }

    /// Simulated duration of moving `bytes` through a `bps` link.
    fn transfer_time(&self, bytes: usize, bps: f64) -> Duration {
        if bps.is_finite() && bps > 0.0 {
            self.latency + Duration::from_secs_f64(bytes as f64 / bps)
        } else {
            self.latency
        }
    }

    fn transfer_sleep(&self, bytes: usize, bps: f64) {
        std::thread::sleep(self.transfer_time(bytes, bps));
    }
}

impl ObjectStore for ThrottledStore {
    fn put(&self, key: &str, data: Vec<u8>) -> Result<()> {
        self.transfer_sleep(data.len(), self.uplink_bps);
        self.inner.put(key, data)
    }

    fn get(&self, key: &str) -> Option<Arc<Vec<u8>>> {
        let v = self.inner.get(key)?;
        self.transfer_sleep(v.len(), self.downlink_bps);
        Some(v)
    }

    fn get_blocking(&self, key: &str, timeout: Duration) -> Result<Arc<Vec<u8>>> {
        // Budget the simulated transfer *inside* the caller's deadline:
        // historically the inner store could consume the full timeout
        // and the transfer sleep then stacked on top, so the effective
        // deadline overshot by up to latency + len/bps. Now the wait and
        // the transfer share one deadline, and exceeding it fails with
        // the same timeout error class the inner store uses.
        let start = Instant::now();
        let v = self.inner.get_blocking(key, timeout)?;
        let transfer = self.transfer_time(v.len(), self.downlink_bps);
        let remaining = timeout.saturating_sub(start.elapsed());
        if transfer > remaining {
            std::thread::sleep(remaining);
            bail!(
                "get_blocking timed out mid-transfer of {key:?} \
                 ({transfer:?} needed, {remaining:?} left in the deadline)"
            );
        }
        std::thread::sleep(transfer);
        Ok(v)
    }

    fn delete(&self, key: &str) {
        self.inner.delete(key)
    }

    fn list(&self, prefix: &str) -> Vec<String> {
        self.inner.list(prefix)
    }

    fn total_bytes(&self) -> u64 {
        self.inner.total_bytes()
    }

    fn high_water_bytes(&self) -> u64 {
        self.inner.high_water_bytes()
    }

    fn put_async<'a>(&'a self, key: &'a str, data: Vec<u8>) -> StoreFuture<'a, Result<()>> {
        Box::pin(async move {
            crate::exec::sleep(self.transfer_time(data.len(), self.uplink_bps)).await;
            self.inner.put_async(key, data).await
        })
    }

    fn get_async<'a>(
        &'a self,
        key: &'a str,
        timeout: Duration,
    ) -> StoreFuture<'a, Result<Arc<Vec<u8>>>> {
        Box::pin(async move {
            // same single-deadline budget as the blocking path: the wait
            // and the simulated transfer share one timeout
            let start = Instant::now();
            let v = self.inner.get_async(key, timeout).await?;
            let transfer = self.transfer_time(v.len(), self.downlink_bps);
            let remaining = timeout.saturating_sub(start.elapsed());
            if transfer > remaining {
                crate::exec::sleep(remaining).await;
                bail!(
                    "get_blocking timed out mid-transfer of {key:?} \
                     ({transfer:?} needed, {remaining:?} left in the deadline)"
                );
            }
            crate::exec::sleep(transfer).await;
            Ok(v)
        })
    }
}

/// Marker every transient (retry-safe) storage error message carries —
/// the contract between failure injectors ([`FlakyStore`]'s drops) and
/// this middleware. Deliberately NOT the generic "timed out" class:
/// genuine deadline exhaustion (a peer that died, a transfer larger
/// than its budget) must surface immediately, not after `max_retries`
/// more full-timeout waits — the deadline-overshoot class
/// `ThrottledStore::get_blocking` exists to prevent.
///
/// [`FlakyStore`]: crate::scenario::FlakyStore
pub const TRANSIENT_ERROR_MARKER: &str = "transient";

/// Bounded-retry middleware over a store's blocking fetches: a
/// `get_blocking` that fails with a [`TRANSIENT_ERROR_MARKER`]-class
/// error is re-attempted up to `max_retries` more times, absorbing
/// transient storage failures — the retry path the `flaky-network`
/// scenario exercises deterministically (its injected drops fail
/// instantly and can hit a key at most once, so a single retry always
/// clears them). Every other error, genuine timeouts included,
/// propagates at once; every other operation passes through untouched.
pub struct RetryStore {
    inner: Arc<dyn ObjectStore>,
    max_retries: u32,
    retries: Arc<AtomicU64>,
}

impl RetryStore {
    pub fn new(inner: Arc<dyn ObjectStore>, max_retries: u32) -> Self {
        Self { inner, max_retries, retries: Arc::new(AtomicU64::new(0)) }
    }

    /// Shared handle on the retry counter (readable after the store has
    /// been type-erased behind `Arc<dyn ObjectStore>`).
    pub fn retry_counter(&self) -> Arc<AtomicU64> {
        self.retries.clone()
    }
}

impl ObjectStore for RetryStore {
    fn put(&self, key: &str, data: Vec<u8>) -> Result<()> {
        self.inner.put(key, data)
    }

    fn get(&self, key: &str) -> Option<Arc<Vec<u8>>> {
        self.inner.get(key)
    }

    fn get_blocking(&self, key: &str, timeout: Duration) -> Result<Arc<Vec<u8>>> {
        let mut attempt = 0u32;
        loop {
            match self.inner.get_blocking(key, timeout) {
                Ok(v) => return Ok(v),
                Err(e) => {
                    let transient =
                        e.to_string().contains(TRANSIENT_ERROR_MARKER);
                    if !transient || attempt >= self.max_retries {
                        return Err(e);
                    }
                    attempt += 1;
                    self.retries.fetch_add(1, Ordering::Relaxed);
                }
            }
        }
    }

    fn delete(&self, key: &str) {
        self.inner.delete(key)
    }

    fn list(&self, prefix: &str) -> Vec<String> {
        self.inner.list(prefix)
    }

    fn total_bytes(&self) -> u64 {
        self.inner.total_bytes()
    }

    fn high_water_bytes(&self) -> u64 {
        self.inner.high_water_bytes()
    }

    fn put_async<'a>(&'a self, key: &'a str, data: Vec<u8>) -> StoreFuture<'a, Result<()>> {
        self.inner.put_async(key, data)
    }

    fn get_async<'a>(
        &'a self,
        key: &'a str,
        timeout: Duration,
    ) -> StoreFuture<'a, Result<Arc<Vec<u8>>>> {
        Box::pin(async move {
            let mut attempt = 0u32;
            loop {
                match self.inner.get_async(key, timeout).await {
                    Ok(v) => return Ok(v),
                    Err(e) => {
                        let transient =
                            e.to_string().contains(TRANSIENT_ERROR_MARKER);
                        if !transient || attempt >= self.max_retries {
                            return Err(e);
                        }
                        attempt += 1;
                        self.retries.fetch_add(1, Ordering::Relaxed);
                    }
                }
            }
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn put_get_roundtrip() {
        let s = MemStore::new();
        s.put("a/b", vec![1, 2, 3]).unwrap();
        assert_eq!(*s.get("a/b").unwrap(), vec![1, 2, 3]);
        assert!(s.get("missing").is_none());
        assert_eq!(s.total_bytes(), 3);
    }

    #[test]
    fn blocking_get_wakes_on_put() {
        let s = Arc::new(MemStore::new());
        let s2 = s.clone();
        let t = std::thread::spawn(move || {
            s2.get_blocking("late", Duration::from_secs(5)).unwrap()
        });
        std::thread::sleep(Duration::from_millis(30));
        s.put("late", vec![9]).unwrap();
        assert_eq!(*t.join().unwrap(), vec![9]);
    }

    #[test]
    fn blocking_get_times_out() {
        let s = MemStore::new();
        let err = s.get_blocking("never", Duration::from_millis(40));
        assert!(err.is_err());
    }

    #[test]
    fn list_and_delete() {
        let s = MemStore::new();
        s.put("grad/0/1", vec![0]).unwrap();
        s.put("grad/0/2", vec![0]).unwrap();
        s.put("act/0", vec![0]).unwrap();
        assert_eq!(s.list("grad/"), vec!["grad/0/1", "grad/0/2"]);
        s.delete("grad/0/1");
        assert_eq!(s.list("grad/").len(), 1);
    }

    #[test]
    fn high_water_mark_tracks_peak_not_current() {
        let s = MemStore::new();
        s.put("a", vec![0u8; 100]).unwrap();
        s.put("b", vec![0u8; 50]).unwrap();
        assert_eq!(s.total_bytes(), 150);
        assert_eq!(s.high_water_bytes(), 150);
        s.delete("a");
        assert_eq!(s.total_bytes(), 50);
        assert_eq!(s.high_water_bytes(), 150, "peak is sticky");
        // overwrite replaces, not accumulates
        s.put("b", vec![0u8; 200]).unwrap();
        assert_eq!(s.total_bytes(), 200);
        assert_eq!(s.high_water_bytes(), 200);
    }

    #[test]
    fn throttled_get_blocking_respects_the_deadline() {
        let inner = Arc::new(MemStore::new());
        inner.put("big", vec![0u8; 30_000]).unwrap(); // 0.3s at 0.1 MB/s
        let t = ThrottledStore::new(
            inner,
            f64::INFINITY,
            100_000.0,
            Duration::from_millis(0),
        );
        let start = Instant::now();
        let err = t.get_blocking("big", Duration::from_millis(50));
        let dt = start.elapsed().as_secs_f64();
        assert!(err.is_err(), "a transfer larger than the deadline must fail");
        assert!(
            dt < 0.25,
            "deadline overshot: waited {dt}s for a 50ms timeout"
        );
        // a transfer that fits the deadline still succeeds
        let got = t.get_blocking("big", Duration::from_secs(30)).unwrap();
        assert_eq!(got.len(), 30_000);
    }

    #[test]
    fn scaled_lens_slows_the_handle() {
        let inner = Arc::new(MemStore::new());
        inner.put("x", vec![0u8; 100_000]).unwrap();
        let t = ThrottledStore::new(
            inner,
            2_000_000.0,
            2_000_000.0,
            Duration::from_millis(2),
        )
        .scaled(0.5, 3.0); // half the bandwidth, triple the latency
        assert!((t.uplink_bps - 1_000_000.0).abs() < 1e-6);
        assert!((t.downlink_bps - 1_000_000.0).abs() < 1e-6);
        assert_eq!(t.latency, Duration::from_millis(6));
        let start = Instant::now();
        let _ = t.get("x").unwrap(); // 0.1s at the scaled 1 MB/s
        assert!(start.elapsed().as_secs_f64() >= 0.09);
    }

    /// A store whose blocking fetches fail with a timeout-class error a
    /// fixed number of times before succeeding.
    struct FailNTimes {
        inner: MemStore,
        fails_left: Mutex<u32>,
    }

    impl ObjectStore for FailNTimes {
        fn put(&self, key: &str, data: Vec<u8>) -> Result<()> {
            self.inner.put(key, data)
        }
        fn get(&self, key: &str) -> Option<Arc<Vec<u8>>> {
            self.inner.get(key)
        }
        fn get_blocking(
            &self,
            key: &str,
            timeout: Duration,
        ) -> Result<Arc<Vec<u8>>> {
            let mut left = self.fails_left.lock().unwrap();
            if *left > 0 {
                *left -= 1;
                bail!("transient fault: get_blocking timed out waiting for {key:?}");
            }
            drop(left);
            self.inner.get_blocking(key, timeout)
        }
        fn delete(&self, key: &str) {
            self.inner.delete(key)
        }
        fn list(&self, prefix: &str) -> Vec<String> {
            self.inner.list(prefix)
        }
        fn total_bytes(&self) -> u64 {
            self.inner.total_bytes()
        }
    }

    fn flaky_inner(fails: u32) -> Arc<FailNTimes> {
        let inner = MemStore::new();
        inner.put("k", vec![7]).unwrap();
        Arc::new(FailNTimes { inner, fails_left: Mutex::new(fails) })
    }

    #[test]
    fn retry_store_absorbs_transient_timeouts() {
        let r = RetryStore::new(flaky_inner(2), 2);
        let counter = r.retry_counter();
        let got = r.get_blocking("k", Duration::from_secs(1)).unwrap();
        assert_eq!(*got, vec![7]);
        assert_eq!(counter.load(Ordering::Relaxed), 2);
        // a clean fetch costs no retries
        r.get_blocking("k", Duration::from_secs(1)).unwrap();
        assert_eq!(counter.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn retry_store_gives_up_past_its_budget() {
        let r = RetryStore::new(flaky_inner(3), 2);
        let err = r.get_blocking("k", Duration::from_secs(1));
        assert!(err.is_err(), "3 faults must exhaust 2 retries");
    }

    #[test]
    fn retry_store_never_retries_genuine_timeouts() {
        // a genuinely missing key exhausts ONE deadline, not
        // (1 + max_retries) of them: real timeouts are not transient
        let r = RetryStore::new(Arc::new(MemStore::new()), 2);
        let start = Instant::now();
        let err = r.get_blocking("never", Duration::from_millis(50));
        let dt = start.elapsed().as_secs_f64();
        assert!(err.is_err());
        assert!(
            dt < 0.12,
            "genuine timeout was retried: waited {dt}s on a 50ms deadline"
        );
    }

    #[test]
    fn throttled_store_delays() {
        let inner = Arc::new(MemStore::new());
        let t = ThrottledStore::new(
            inner,
            1_000_000.0, // 1 MB/s
            f64::INFINITY,
            Duration::from_millis(0),
        );
        let start = Instant::now();
        t.put("x", vec![0u8; 100_000]).unwrap(); // 0.1s at 1 MB/s
        let dt = start.elapsed().as_secs_f64();
        assert!(dt >= 0.09, "upload not throttled: {dt}");
    }
}
