//! Monetary cost accounting (eq. (5)/(6) and the baselines' VM costs).

use super::tiers::PlatformSpec;

/// Cost model: serverless functions bill allocated-GB × seconds; VMs (used
/// by the HybridPS baseline's parameter server) bill per hour.
#[derive(Debug, Clone)]
pub struct CostModel {
    pub price_per_gb_s: f64,
}

impl CostModel {
    pub fn from_platform(p: &PlatformSpec) -> Self {
        Self { price_per_gb_s: p.price_per_gb_s }
    }

    /// Cost of `n_workers` functions of `mem_mb` each running `secs`.
    pub fn function_cost(&self, mem_mb: u64, n_workers: usize, secs: f64) -> f64 {
        self.price_per_gb_s * (mem_mb as f64 / 1024.0) * n_workers as f64 * secs
    }

    /// Eq. (6): c_iter = P * t_iter * c_mem, where c_mem is the summed
    /// allocated memory (GB) of all workers.
    pub fn iteration_cost(&self, total_mem_gb: f64, t_iter: f64) -> f64 {
        self.price_per_gb_s * total_mem_gb * t_iter
    }
}

/// VM instance types used by the HybridPS baseline (§5.1).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct VmType {
    pub name: &'static str,
    pub price_per_hour: f64,
    /// NIC bandwidth in bytes/s.
    pub bandwidth_bps: f64,
}

/// c5.9xlarge: the PS host on AWS (10 Gb/s guaranteed, $1.53/h).
pub const C5_9XLARGE: VmType = VmType {
    name: "c5.9xlarge",
    price_per_hour: 1.53,
    bandwidth_bps: 10.0e9 / 8.0,
};

/// r7.2xlarge-equivalent: the PS host on Alibaba.
pub const R7_2XLARGE: VmType = VmType {
    name: "r7.2xlarge",
    price_per_hour: 1.05,
    bandwidth_bps: 10.0e9 / 8.0,
};

/// p3.2xlarge (V100) — the GPU comparison point in Fig. 11.
pub const P3_2XLARGE: VmType = VmType {
    name: "p3.2xlarge",
    price_per_hour: 3.06,
    bandwidth_bps: 10.0e9 / 8.0,
};

impl VmType {
    pub fn cost(&self, secs: f64) -> f64 {
        self.price_per_hour / 3600.0 * secs
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::tiers::PlatformSpec;

    #[test]
    fn function_cost_scales_linearly() {
        let m = CostModel::from_platform(&PlatformSpec::aws_lambda());
        let c1 = m.function_cost(1024, 1, 10.0);
        let c2 = m.function_cost(2048, 2, 10.0);
        assert!((c2 - 4.0 * c1).abs() < 1e-12);
        // 1 GB for 1s at AWS price:
        assert!((m.function_cost(1024, 1, 1.0) - 0.0000166667).abs() < 1e-12);
    }

    #[test]
    fn iteration_cost_is_eq6() {
        let m = CostModel { price_per_gb_s: 2e-5 };
        // 8 workers x 4 GB for 3 s
        assert!((m.iteration_cost(32.0, 3.0) - 2e-5 * 32.0 * 3.0).abs() < 1e-15);
    }

    #[test]
    fn vm_cost() {
        assert!((C5_9XLARGE.cost(3600.0) - 1.53).abs() < 1e-12);
    }
}
