//! Serverless-platform substrate.
//!
//! The paper's testbeds are AWS Lambda + S3 and Alibaba Function Compute +
//! OSS. Neither is reachable here, so this module reproduces exactly the
//! knobs FuncPipe's design depends on (DESIGN.md §3):
//!
//!   * memory tiers and the tier→(vCPU share, bandwidth) maps,
//!   * GB-second pricing,
//!   * storage access latency `t_lat` and (for OSS) an aggregate
//!     concurrent-bandwidth cap,
//!   * function lifetime (checkpoint/restart) and cold-start latency,
//!   * per-worker bandwidth degradation as worker count grows (§5.4).

pub mod function;
pub mod network;
pub mod pricing;
pub mod storage;
pub mod tiers;

pub use function::{FunctionInstance, FunctionState};
pub use network::{BandwidthModel, FlowSim};
pub use pricing::CostModel;
pub use storage::{
    MemStore, ObjectStore, RetryStore, StoreFuture, ThrottledStore,
    TRANSIENT_ERROR_MARKER,
};
pub use tiers::{MemoryTier, PlatformSpec, StorageSpec};
