//! Serverless function instance lifecycle.
//!
//! Functions have bounded lifetimes (15 min on Lambda); FuncPipe's
//! *Function Manager* checkpoints and restarts workers before expiry
//! (§3.1, step 8). This module tracks per-instance lifecycle state for
//! both the simulator and the real-execution coordinator.

use std::time::Instant;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FunctionState {
    /// Cold-starting (container being provisioned).
    Starting,
    /// Executing user code.
    Running,
    /// Persisted state and exited voluntarily (before timeout).
    Checkpointed,
    /// Hit the platform lifetime limit.
    Expired,
}

/// One running serverless function ("worker" in the paper).
#[derive(Debug, Clone)]
pub struct FunctionInstance {
    pub id: usize,
    /// Pipeline stage this worker serves.
    pub stage: usize,
    /// Data-parallel replica index within the stage.
    pub replica: usize,
    /// Memory tier index into `PlatformSpec::tiers`.
    pub tier: usize,
    pub state: FunctionState,
    /// Generation counter: bumped on each checkpoint/restart cycle.
    pub generation: u32,
    started: Instant,
    lifetime_s: f64,
    /// Deterministic age since this generation started, seconds. `None`
    /// = wall-clock mode (the historical behaviour); advancing the
    /// clock via [`FunctionInstance::advance_virtual`] switches the
    /// instance into virtual mode, where the scenario Injector owns
    /// time and replays are exact.
    virtual_age_s: Option<f64>,
}

impl FunctionInstance {
    pub fn launch(
        id: usize,
        stage: usize,
        replica: usize,
        tier: usize,
        lifetime_s: f64,
    ) -> Self {
        Self {
            id,
            stage,
            replica,
            tier,
            state: FunctionState::Starting,
            generation: 0,
            started: Instant::now(),
            lifetime_s,
            virtual_age_s: None,
        }
    }

    pub fn mark_running(&mut self) {
        self.state = FunctionState::Running;
    }

    /// Advance the deterministic virtual clock by `dt` seconds. The
    /// first call switches the instance from wall-clock to virtual
    /// aging for the rest of its life (a mixed clock would make the
    /// checkpoint schedule depend on the host again).
    pub fn advance_virtual(&mut self, dt: f64) {
        *self.virtual_age_s.get_or_insert(0.0) += dt;
    }

    pub fn is_virtual(&self) -> bool {
        self.virtual_age_s.is_some()
    }

    pub fn age_s(&self) -> f64 {
        self.virtual_age_s
            .unwrap_or_else(|| self.started.elapsed().as_secs_f64())
    }

    pub fn remaining_s(&self) -> f64 {
        (self.lifetime_s - self.age_s()).max(0.0)
    }

    /// Should the Function Manager checkpoint now? Uses a safety margin so
    /// the checkpoint upload completes before the platform kills us.
    pub fn should_checkpoint(&self, margin_s: f64) -> bool {
        self.state == FunctionState::Running && self.remaining_s() <= margin_s
    }

    pub fn expired(&self) -> bool {
        self.remaining_s() <= 0.0
    }

    /// Restart as a fresh instance (new container, same role). A
    /// virtual-mode instance stays virtual with its new generation's
    /// age reset to zero.
    pub fn restart(&mut self) {
        self.generation += 1;
        self.started = Instant::now();
        self.state = FunctionState::Starting;
        if self.virtual_age_s.is_some() {
            self.virtual_age_s = Some(0.0);
        }
    }

    /// Unique key prefix for this worker's objects in storage.
    pub fn key_prefix(&self) -> String {
        format!("w{}/s{}/r{}", self.id, self.stage, self.replica)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lifecycle() {
        let mut f = FunctionInstance::launch(0, 1, 0, 3, 0.05);
        assert_eq!(f.state, FunctionState::Starting);
        f.mark_running();
        assert!(!f.expired());
        std::thread::sleep(std::time::Duration::from_millis(60));
        assert!(f.expired());
        assert!(f.should_checkpoint(0.01));
        f.restart();
        assert_eq!(f.generation, 1);
        assert_eq!(f.state, FunctionState::Starting);
        assert!(!f.expired());
    }

    #[test]
    fn checkpoint_margin() {
        let mut f = FunctionInstance::launch(0, 0, 0, 0, 100.0);
        f.mark_running();
        assert!(!f.should_checkpoint(1.0));
        assert!(f.should_checkpoint(200.0));
    }

    #[test]
    fn virtual_clock_is_deterministic_and_resets_on_restart() {
        let mut f = FunctionInstance::launch(0, 0, 0, 0, 10.0);
        f.mark_running();
        assert!(!f.is_virtual());
        f.advance_virtual(4.0);
        assert!(f.is_virtual());
        assert_eq!(f.age_s(), 4.0);
        assert_eq!(f.remaining_s(), 6.0);
        assert!(!f.should_checkpoint(5.0));
        f.advance_virtual(1.5);
        assert!(f.should_checkpoint(5.0));
        f.restart();
        assert!(f.is_virtual(), "restart keeps the virtual clock");
        assert_eq!(f.age_s(), 0.0);
        assert_eq!(f.generation, 1);
        // wall time passing does not age a virtual instance
        std::thread::sleep(std::time::Duration::from_millis(20));
        assert_eq!(f.age_s(), 0.0);
    }

    #[test]
    fn key_prefix_is_unique_per_role() {
        let a = FunctionInstance::launch(1, 2, 0, 0, 10.0);
        let b = FunctionInstance::launch(1, 2, 1, 0, 10.0);
        assert_ne!(a.key_prefix(), b.key_prefix());
    }
}
