//! Serverless function instance lifecycle.
//!
//! Functions have bounded lifetimes (15 min on Lambda); FuncPipe's
//! *Function Manager* checkpoints and restarts workers before expiry
//! (§3.1, step 8). This module tracks per-instance lifecycle state for
//! both the simulator and the real-execution coordinator.

use std::time::Instant;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FunctionState {
    /// Cold-starting (container being provisioned).
    Starting,
    /// Executing user code.
    Running,
    /// Persisted state and exited voluntarily (before timeout).
    Checkpointed,
    /// Hit the platform lifetime limit.
    Expired,
}

/// One running serverless function ("worker" in the paper).
#[derive(Debug, Clone)]
pub struct FunctionInstance {
    pub id: usize,
    /// Pipeline stage this worker serves.
    pub stage: usize,
    /// Data-parallel replica index within the stage.
    pub replica: usize,
    /// Memory tier index into `PlatformSpec::tiers`.
    pub tier: usize,
    pub state: FunctionState,
    /// Generation counter: bumped on each checkpoint/restart cycle.
    pub generation: u32,
    started: Instant,
    lifetime_s: f64,
}

impl FunctionInstance {
    pub fn launch(
        id: usize,
        stage: usize,
        replica: usize,
        tier: usize,
        lifetime_s: f64,
    ) -> Self {
        Self {
            id,
            stage,
            replica,
            tier,
            state: FunctionState::Starting,
            generation: 0,
            started: Instant::now(),
            lifetime_s,
        }
    }

    pub fn mark_running(&mut self) {
        self.state = FunctionState::Running;
    }

    pub fn age_s(&self) -> f64 {
        self.started.elapsed().as_secs_f64()
    }

    pub fn remaining_s(&self) -> f64 {
        (self.lifetime_s - self.age_s()).max(0.0)
    }

    /// Should the Function Manager checkpoint now? Uses a safety margin so
    /// the checkpoint upload completes before the platform kills us.
    pub fn should_checkpoint(&self, margin_s: f64) -> bool {
        self.state == FunctionState::Running && self.remaining_s() <= margin_s
    }

    pub fn expired(&self) -> bool {
        self.remaining_s() <= 0.0
    }

    /// Restart as a fresh instance (new container, same role).
    pub fn restart(&mut self) {
        self.generation += 1;
        self.started = Instant::now();
        self.state = FunctionState::Starting;
    }

    /// Unique key prefix for this worker's objects in storage.
    pub fn key_prefix(&self) -> String {
        format!("w{}/s{}/r{}", self.id, self.stage, self.replica)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lifecycle() {
        let mut f = FunctionInstance::launch(0, 1, 0, 3, 0.05);
        assert_eq!(f.state, FunctionState::Starting);
        f.mark_running();
        assert!(!f.expired());
        std::thread::sleep(std::time::Duration::from_millis(60));
        assert!(f.expired());
        assert!(f.should_checkpoint(0.01));
        f.restart();
        assert_eq!(f.generation, 1);
        assert_eq!(f.state, FunctionState::Starting);
        assert!(!f.expired());
    }

    #[test]
    fn checkpoint_margin() {
        let mut f = FunctionInstance::launch(0, 0, 0, 0, 100.0);
        f.mark_running();
        assert!(!f.should_checkpoint(1.0));
        assert!(f.should_checkpoint(200.0));
    }

    #[test]
    fn key_prefix_is_unique_per_role() {
        let a = FunctionInstance::launch(1, 2, 0, 0, 10.0);
        let b = FunctionInstance::launch(1, 2, 1, 0, 10.0);
        assert_ne!(a.key_prefix(), b.key_prefix());
    }
}
