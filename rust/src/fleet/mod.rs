//! Fleet tier: multi-tenant scheduling over ONE shared simulated
//! platform.
//!
//! FuncPipe sizes a single job against a platform's concurrency and
//! bandwidth caps; real serverless platforms run many tenants at once,
//! and characterization work ("Towards Demystifying Serverless ML
//! Training") shows storage-bandwidth contention dominates exactly when
//! jobs overlap. This module runs N *frozen* experiments — training
//! jobs and MOPAR-style serving deployments, each a [`PlanArtifact`] +
//! steps/traffic spec from one fleet config — against one shared
//! [`PlatformSpec`] on a single virtual clock:
//!
//! * **Admission control** against `PlatformSpec::max_concurrency`
//!   (optionally shrunk to a reserved pool via the fleet config's
//!   `max_concurrency`): a tenant whose worker count exceeds the
//!   remaining headroom waits in a FIFO queue (head-blocking — a big
//!   job at the head is never starved by small jobs behind it). Ties
//!   break deterministically by `(submit_s, config order)`. A tenant
//!   that could never admit even on an empty platform is a hard config
//!   error, not an infinite wait (see [`FleetSpec::validate`]).
//! * **Cross-tenant storage contention**: every tenant's transfers run
//!   through the platform's one shared bandwidth model —
//!   [`PlatformSpec::effective_bandwidth`] evaluated at the *fleet's*
//!   total active worker count, not the tenant's own. The
//!   communication share of each unit stretches by
//!   `eff(tier, own) / eff(tier, total_active)` (≥ 1, monotone in the
//!   number of co-running workers), so two concurrent tenants each
//!   observe at most the solo tenant's effective bandwidth.
//! * **Per-tenant accounting** rolled into a typed
//!   [`FleetReport`](crate::experiment::FleetReport): $ (GB-seconds
//!   actually held × platform price), wall clock, wait-in-queue,
//!   revocation count — plus platform-level peak concurrency,
//!   worker-second utilization and mean contention.
//!
//! The time-varying scenario lenses (`bandwidth-decay`,
//! `cold-start-storm`, `spot-revocation`) drive the fleet through the
//! [`Injector`]'s per-step methods: every draw is a pure function of
//! the `(tenant, worker, step)` coordinate (plus seed and lens tag),
//! so draws are consumed in strict (tenant, worker, step) order no
//! matter how the scheduler interleaves tenants, and a `fleet` run
//! replays byte-identically. Static lenses compose: each tenant views
//! them through its own tenant-mixed stream, and `cold-start-storm` in
//! particular draws its step window from the seed *alone*, so the
//! burst hits all tenants in the same window (that is the
//! correlation).
//!
//! Execution model (deliberately coarser than the per-op `simcore`
//! DES): a training tenant is a sequence of `steps` units of its
//! plan's predicted `t_iter`, split into compute and communication by
//! the perf model's own breakdown (`(flush_s + sync_s) / t_iter`); a
//! serving tenant replays its deployment *solo* once (the existing
//! byte-deterministic [`serve_plan`] path, static lenses composed) and
//! then occupies its replayed peak instance count for its makespan,
//! sliced into 1 s units with a fixed activation hand-off share
//! ([`ACT_HANDOFF_SHARE`]) charged to the shared store. Contention is
//! sampled at each unit's dispatch. `spot-revocation` fires at unit
//! granularity: the tenant releases its workers, re-enters the FIFO
//! queue at the tail, pays a fresh (generation-keyed) cold start on
//! re-admission and re-runs the interrupted unit — each `(tenant,
//! unit)` coordinate revokes at most once, which bounds the chain and
//! keeps the run terminating.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashSet, VecDeque};

use anyhow::{bail, Context, Result};

use crate::config::validate_seed;
use crate::experiment::{Experiment, PlanArtifact};
use crate::platform::PlatformSpec;
use crate::scenario::Injector;
use crate::serve::{serve_plan, ServeOptions, TrafficSpec};
use crate::simcore::ScenarioSpec;
use crate::util::json::Json;

/// Length of one serving occupancy slice on the fleet clock, seconds.
pub const SLICE_S: f64 = 1.0;

/// Share of a serving slice charged to the shared store (activation
/// hand-off between pipeline stages); the rest is stage compute, which
/// cross-tenant storage contention cannot stretch.
pub const ACT_HANDOFF_SHARE: f64 = 0.25;

/// Default arrival horizon of a serving tenant, seconds.
pub const DEFAULT_SERVE_DURATION_S: f64 = 30.0;

/// What a tenant runs: a fixed-step training job or a traffic-driven
/// serving deployment.
#[derive(Debug, Clone)]
pub enum TenantKind {
    Train { steps: usize },
    Serve { traffic: TrafficSpec, duration_s: f64, seed: u64 },
}

impl TenantKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            TenantKind::Train { .. } => "train",
            TenantKind::Serve { .. } => "serve",
        }
    }
}

/// One tenant of the fleet: a frozen plan plus its workload spec.
#[derive(Debug, Clone)]
pub struct TenantSpec {
    pub name: String,
    pub kind: TenantKind,
    pub artifact: PlanArtifact,
    /// Virtual submission time, seconds. Admission is FIFO by
    /// `(submit_s, config order)`.
    pub submit_s: f64,
}

/// The whole fleet: every tenant shares one platform (cross-checked by
/// [`FleetSpec::validate`]).
#[derive(Debug, Clone)]
pub struct FleetSpec {
    pub tenants: Vec<TenantSpec>,
    /// Optional reserved-pool cap: admission control runs against
    /// `min(platform.max_concurrency, pool)`. Real accounts rarely see
    /// the platform's headline concurrency; this models a reserved
    /// slice of it (and makes queueing observable in small fleets).
    pub max_concurrency: Option<usize>,
}

const TENANT_KEYS: [&str; 8] = [
    "name",
    "kind",
    "plan",
    "steps",
    "traffic",
    "duration_s",
    "seed",
    "submit_s",
];

impl FleetSpec {
    /// Parse a fleet config file: `{"tenants": [{"name": ..., "kind":
    /// "train"|"serve", "plan": "plan.json", ...}]}`. Plan paths are
    /// resolved relative to the working directory; unknown keys are
    /// rejected like unknown CLI flags.
    pub fn from_json_text(text: &str) -> Result<Self> {
        let j = Json::parse(text).context("parsing fleet config JSON")?;
        j.check_keys(&["tenants", "max_concurrency"])
            .context("fleet config")?;
        let max_concurrency = match j.get("max_concurrency") {
            None => None,
            Some(v) => {
                let n = v
                    .as_f64()
                    .context("fleet max_concurrency must be a number")?;
                if n < 1.0 || n.fract() != 0.0 {
                    bail!(
                        "fleet max_concurrency must be a positive integer (got {n})"
                    );
                }
                Some(n as usize)
            }
        };
        let raw = j.field_arr("tenants").context("fleet config")?;
        if raw.is_empty() {
            bail!("fleet config has no tenants");
        }
        let mut tenants = Vec::with_capacity(raw.len());
        for (i, tj) in raw.iter().enumerate() {
            tenants.push(
                Self::tenant_from_json(tj)
                    .with_context(|| format!("fleet tenant #{i}"))?,
            );
        }
        Ok(Self { tenants, max_concurrency })
    }

    fn tenant_from_json(j: &Json) -> Result<TenantSpec> {
        j.check_keys(&TENANT_KEYS)?;
        let name = j.field_str("name")?.to_string();
        let kind_s = j.field_str("kind")?;
        let plan_path = j.field_str("plan")?;
        let submit_s = match j.get("submit_s") {
            None => 0.0,
            Some(v) => v
                .as_f64()
                .context("fleet tenant submit_s must be a number")?,
        };
        let kind = match kind_s {
            "train" => {
                for k in ["traffic", "duration_s", "seed"] {
                    if j.get(k).is_some() {
                        bail!("fleet tenant {name:?}: {k:?} only applies to kind \"serve\"");
                    }
                }
                TenantKind::Train { steps: j.field_usize("steps")? }
            }
            "serve" => {
                if j.get("steps").is_some() {
                    bail!(
                        "fleet tenant {name:?}: \"steps\" only applies to kind \"train\""
                    );
                }
                let traffic = TrafficSpec::parse(j.field_str("traffic")?)?;
                let duration_s = match j.get("duration_s") {
                    None => DEFAULT_SERVE_DURATION_S,
                    Some(v) => v
                        .as_f64()
                        .context("fleet tenant duration_s must be a number")?,
                };
                let seed = match j.get("seed") {
                    None => 0,
                    Some(v) => {
                        let s = v
                            .as_f64()
                            .context("fleet tenant seed must be a number")?;
                        if s < 0.0 || s.fract() != 0.0 {
                            bail!("fleet tenant {name:?}: seed must be a non-negative integer");
                        }
                        let s = s as u64;
                        validate_seed(s)?;
                        s
                    }
                };
                TenantKind::Serve { traffic, duration_s, seed }
            }
            other => bail!(
                "fleet tenant {name:?}: unknown kind {other:?} (expected \"train\" or \"serve\")"
            ),
        };
        let artifact = PlanArtifact::load(plan_path)
            .with_context(|| format!("fleet tenant {name:?}"))?;
        Ok(TenantSpec { name, kind, artifact, submit_s })
    }

    /// Structural validation; returns the one shared [`PlatformSpec`].
    ///
    /// Beyond shape checks (non-empty fleet, unique non-empty names,
    /// finite submit times, positive steps/durations, one platform
    /// across all tenants), this is where the admission-control
    /// truncation hazard is closed: a training tenant whose worker
    /// count exceeds `max_concurrency` could never admit even on an
    /// empty platform, so it is rejected here *by name* instead of
    /// waiting in the queue forever. (Serving tenants get the same
    /// check in [`run`], once their replayed peak concurrency is
    /// known.)
    pub fn validate(&self) -> Result<PlatformSpec> {
        if self.tenants.is_empty() {
            bail!("fleet config has no tenants");
        }
        let mut seen = HashSet::new();
        for t in &self.tenants {
            if t.name.is_empty() {
                bail!("fleet tenant with empty name");
            }
            if !seen.insert(t.name.as_str()) {
                bail!("duplicate fleet tenant name {:?}", t.name);
            }
            if !t.submit_s.is_finite() || t.submit_s < 0.0 {
                bail!(
                    "fleet tenant {:?}: submit_s must be finite and >= 0 (got {})",
                    t.name,
                    t.submit_s
                );
            }
            match &t.kind {
                TenantKind::Train { steps } => {
                    if *steps == 0 {
                        bail!("fleet tenant {:?}: steps must be >= 1", t.name);
                    }
                }
                TenantKind::Serve { duration_s, .. } => {
                    if !duration_s.is_finite() || *duration_s <= 0.0 {
                        bail!(
                            "fleet tenant {:?}: duration_s must be finite and > 0 (got {duration_s})",
                            t.name
                        );
                    }
                }
            }
        }
        let mut platform = self.tenants[0]
            .artifact
            .config
            .resolve_platform()
            .with_context(|| {
                format!("fleet tenant {:?}", self.tenants[0].name)
            })?;
        if let Some(pool) = self.max_concurrency {
            if pool == 0 {
                bail!("fleet max_concurrency must be >= 1");
            }
            // A reserved pool can only shrink the platform's cap.
            platform.max_concurrency = platform.max_concurrency.min(pool);
        }
        for t in &self.tenants[1..] {
            let p = t
                .resolve_platform()
                .with_context(|| format!("fleet tenant {:?}", t.name))?;
            if p.name != platform.name {
                bail!(
                    "fleet tenants disagree on the platform: {:?} runs on {} but {:?} runs on {}",
                    self.tenants[0].name,
                    platform.name,
                    t.name,
                    p.name
                );
            }
        }
        for t in &self.tenants {
            if let TenantKind::Train { .. } = t.kind {
                let workers = t.artifact.plan.n_workers();
                check_admittable(&t.name, workers, &platform)?;
            }
        }
        Ok(platform)
    }
}

impl TenantSpec {
    fn resolve_platform(&self) -> Result<PlatformSpec> {
        self.artifact.config.resolve_platform()
    }
}

/// The satellite-2 hard error: never-admittable tenants are config
/// errors naming the tenant, not an infinite queue wait.
fn check_admittable(
    name: &str,
    workers: usize,
    platform: &PlatformSpec,
) -> Result<()> {
    if workers > platform.max_concurrency {
        bail!(
            "fleet tenant {name:?} needs {workers} concurrent workers but platform {} admits at most {} — it could never leave the admission queue",
            platform.name,
            platform.max_concurrency
        );
    }
    Ok(())
}

/// One tenant's accounting after a fleet run. Every value lives on the
/// virtual clock (no wall-clock anywhere), so the whole outcome is a
/// pure function of `(spec, scenario, seed)`.
#[derive(Debug, Clone, PartialEq)]
pub struct TenantOutcome {
    pub name: String,
    /// `"train"` or `"serve"`.
    pub kind: String,
    /// Concurrent workers the tenant holds while admitted (plan workers
    /// for training; replayed peak instances for serving).
    pub workers: usize,
    /// Scheduling units: training steps, or 1 s serving slices.
    pub units: usize,
    pub submit_s: f64,
    /// First admission time.
    pub admit_s: f64,
    /// Total time spent in the admission queue (including re-queues
    /// after revocations).
    pub wait_s: f64,
    /// Time actually holding workers (billed time).
    pub busy_s: f64,
    pub finish_s: f64,
    /// Admissions granted (1 + re-admissions after revocations).
    pub admissions: usize,
    /// `spot-revocation` hits that forced a queued re-admission.
    pub revocations: usize,
    /// Mean communication stretch from cross-tenant bandwidth sharing
    /// (≥ 1; exactly 1 when the tenant only ever ran alone).
    pub mean_contention: f64,
    /// GB-seconds held × platform price (serving: the solo replay's
    /// cost scaled to the time actually held).
    pub cost_usd: f64,
    /// Units completed per busy second.
    pub units_per_s: f64,
}

/// Raw numbers of one fleet run; the typed
/// [`FleetReport`](crate::experiment::FleetReport) renders these.
#[derive(Debug, Clone, PartialEq)]
pub struct FleetOutcome {
    pub platform: String,
    pub scenario: String,
    pub seed: u64,
    pub max_concurrency: usize,
    /// High-water mark of simultaneously admitted workers.
    pub peak_workers: usize,
    /// Worker-seconds held / (makespan × max_concurrency).
    pub utilization: f64,
    /// Dispatch-weighted mean contention stretch across all tenants.
    pub mean_contention: f64,
    /// First submission to last completion, seconds.
    pub makespan_s: f64,
    pub total_cost_usd: f64,
    /// Every admission grant in order (re-admissions repeat the name) —
    /// the FIFO audit trail the replay tests pin.
    pub admissions: Vec<String>,
    /// Per-tenant accounting, in config order.
    pub tenants: Vec<TenantOutcome>,
}

// ---- the scheduler ------------------------------------------------------

#[derive(Debug, Clone, Copy, PartialEq)]
enum Ev {
    /// Tenant `i` reaches the admission queue.
    Submit(usize),
    /// Tenant `i`'s in-flight unit completes.
    UnitDone(usize),
}

#[derive(Debug, Clone, Copy)]
struct Scheduled {
    t: f64,
    seq: u64,
    ev: Ev,
}

impl PartialEq for Scheduled {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == std::cmp::Ordering::Equal
    }
}

impl Eq for Scheduled {}

impl PartialOrd for Scheduled {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Scheduled {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.t
            .partial_cmp(&other.t)
            .expect("event times are never NaN")
            .then(self.seq.cmp(&other.seq))
    }
}

/// Per-tenant runtime state, derived once at prepare time.
struct TenantRt {
    name: String,
    kind: &'static str,
    workers: usize,
    units: usize,
    submit_s: f64,
    /// Base seconds of one unit: the plan's `t_iter` (training) or the
    /// slice length (serving; the last slice is the remainder).
    unit_base: UnitBase,
    /// Communication share of a unit — the part shared contention and
    /// `bandwidth-decay` stretch.
    comm_frac: f64,
    /// The plan's bandwidth-bottleneck tier (smallest `bandwidth_bps`
    /// among its stage tiers) — where shared contention is evaluated.
    tier: usize,
    /// Worst-worker static lens stretch (straggler/jitter), from this
    /// tenant's own tenant-mixed stream.
    static_mult: f64,
    /// $ per busy second.
    cost_per_s: f64,
    /// This tenant's static-lens injector (generation-keyed cold-start
    /// draws for admissions and re-admissions).
    injector: Injector,
    // -- dynamic state --
    next_unit: usize,
    admitted: bool,
    enqueue_t: f64,
    admit_t: f64,
    wait_s: f64,
    busy_s: f64,
    finish_t: f64,
    admissions: usize,
    revocations: usize,
    revoked_units: HashSet<usize>,
    pending_cold: bool,
    contention_sum: f64,
    dispatches: usize,
}

enum UnitBase {
    Train { t_iter: f64 },
    Serve { makespan_s: f64 },
}

impl TenantRt {
    fn unit_s(&self, unit: usize) -> f64 {
        match self.unit_base {
            UnitBase::Train { t_iter } => t_iter,
            UnitBase::Serve { makespan_s } => {
                if unit + 1 < self.units {
                    SLICE_S
                } else {
                    (makespan_s - (self.units - 1) as f64 * SLICE_S).max(0.0)
                }
            }
        }
    }

    /// Cold-start seconds of admission number `generation` (0-based):
    /// the worst worker's generation-keyed draw over the platform base.
    fn cold_s(&self, generation: u32, base_s: f64) -> f64 {
        (0..self.workers)
            .map(|w| self.injector.cold_start_s(w, generation, base_s))
            .fold(base_s, f64::max)
    }
}

/// Mix a tenant index into a static-lens stream so concurrent tenants
/// draw distinct straggler/jitter/cold-start patterns while one
/// tenant's draws stay independent of every other tenant's existence.
fn tenant_seed(seed: u64, tenant: usize) -> u64 {
    seed ^ (tenant as u64).wrapping_mul(0xA24B_AED4_963E_E407)
}

struct FleetSim {
    platform: PlatformSpec,
    /// The fleet-level injector: per-step time-varying draws keyed on
    /// the full (tenant, worker, step) coordinate, and the seed-only
    /// storm window shared by every tenant.
    injector: Injector,
    tenants: Vec<TenantRt>,
    heap: BinaryHeap<Reverse<Scheduled>>,
    queue: VecDeque<usize>,
    now: f64,
    seq: u64,
    active: usize,
    peak: usize,
    /// ∫ active dt, for the utilization figure.
    area: f64,
    last_t: f64,
    admissions: Vec<String>,
}

impl FleetSim {
    fn push(&mut self, t: f64, ev: Ev) {
        let seq = self.seq;
        self.seq += 1;
        self.heap.push(Reverse(Scheduled { t, seq, ev }));
    }

    fn accrue(&mut self) {
        self.area += self.active as f64 * (self.now - self.last_t);
        self.last_t = self.now;
    }

    fn release(&mut self, i: usize) {
        self.accrue();
        self.active -= self.tenants[i].workers;
    }

    /// Dispatch tenant `i`'s next unit. Returns `false` when
    /// `spot-revocation` fires instead: the tenant has released its
    /// workers and re-entered the queue at the tail.
    fn dispatch(&mut self, i: usize) -> bool {
        let unit = self.tenants[i].next_unit;
        let workers = self.tenants[i].workers;
        let revoked = !self.tenants[i].revoked_units.contains(&unit)
            && (0..workers).any(|w| self.injector.step_revoked(i, w, unit));
        if revoked {
            let now = self.now;
            self.release(i);
            let t = &mut self.tenants[i];
            t.revoked_units.insert(unit);
            t.revocations += 1;
            t.pending_cold = true;
            t.enqueue_t = now;
            self.queue.push_back(i);
            return false;
        }
        let eff_solo = self.platform.effective_bandwidth(
            self.tenants[i].tier,
            workers,
        );
        let eff_shared = self
            .platform
            .effective_bandwidth(self.tenants[i].tier, self.active);
        let contention = if eff_shared > 0.0 && eff_solo.is_finite() {
            (eff_solo / eff_shared).max(1.0)
        } else {
            1.0
        };
        let (tv_mult, storm_extra) = self.injector.step_stretch(i, workers, unit);
        let base_cold = self.platform.cold_start_s;
        let t = &mut self.tenants[i];
        let base = t.unit_s(unit);
        let mut d = base
            * t.static_mult
            * ((1.0 - t.comm_frac) + t.comm_frac * contention * tv_mult)
            + storm_extra;
        if t.pending_cold {
            t.pending_cold = false;
            d += t.cold_s(t.admissions.saturating_sub(1) as u32, base_cold);
        }
        t.busy_s += d;
        t.contention_sum += contention;
        t.dispatches += 1;
        let due = self.now + d;
        self.push(due, Ev::UnitDone(i));
        true
    }

    /// Admit from the queue head while headroom lasts — strict FIFO
    /// with head-blocking.
    fn try_admit(&mut self) {
        while let Some(&head) = self.queue.front() {
            let workers = self.tenants[head].workers;
            if self.active + workers > self.platform.max_concurrency {
                break;
            }
            self.queue.pop_front();
            self.accrue();
            self.active += workers;
            self.peak = self.peak.max(self.active);
            let now = self.now;
            let t = &mut self.tenants[head];
            t.wait_s += now - t.enqueue_t;
            if !t.admitted {
                t.admitted = true;
                t.admit_t = now;
            }
            t.admissions += 1;
            t.pending_cold = true;
            self.admissions.push(self.tenants[head].name.clone());
            // the first unit may itself be revoked, in which case the
            // tenant is already back at the queue tail — keep admitting
            // either way
            self.dispatch(head);
        }
    }

    fn handle(&mut self, ev: Ev) {
        match ev {
            Ev::Submit(i) => {
                self.tenants[i].enqueue_t = self.now;
                self.queue.push_back(i);
            }
            Ev::UnitDone(i) => {
                self.tenants[i].next_unit += 1;
                if self.tenants[i].next_unit >= self.tenants[i].units {
                    self.tenants[i].finish_t = self.now;
                    self.release(i);
                } else {
                    self.dispatch(i);
                }
            }
        }
    }
}

/// Run the fleet: a pure function of `(spec, scenario, seed)` — same
/// inputs, byte-identical [`FleetOutcome`].
pub fn run(
    spec: &FleetSpec,
    scenario: &ScenarioSpec,
    seed: u64,
) -> Result<FleetOutcome> {
    validate_seed(seed)?;
    let platform = spec.validate()?;
    let mut tenants = Vec::with_capacity(spec.tenants.len());
    for (i, ts) in spec.tenants.iter().enumerate() {
        tenants.push(
            prepare_tenant(ts, i, &platform, scenario, seed)
                .with_context(|| format!("fleet tenant {:?}", ts.name))?,
        );
    }
    let mut sim = FleetSim {
        platform,
        injector: Injector::new(scenario, seed, 0),
        tenants,
        heap: BinaryHeap::new(),
        queue: VecDeque::new(),
        now: 0.0,
        seq: 0,
        active: 0,
        peak: 0,
        area: 0.0,
        last_t: 0.0,
        admissions: Vec::new(),
    };
    for i in 0..sim.tenants.len() {
        let at = sim.tenants[i].submit_s;
        sim.push(at, Ev::Submit(i));
    }
    while let Some(Reverse(sch)) = sim.heap.pop() {
        sim.now = sch.t;
        sim.handle(sch.ev);
        sim.try_admit();
    }
    debug_assert!(sim.queue.is_empty(), "queued tenants at drain");
    debug_assert_eq!(sim.active, 0, "active workers at drain");

    let makespan_s = sim.tenants.iter().map(|t| t.finish_t).fold(0.0, f64::max);
    let denom = makespan_s * sim.platform.max_concurrency as f64;
    let utilization = if denom > 0.0 { sim.area / denom } else { 0.0 };
    let (mut contention_sum, mut dispatches) = (0.0, 0usize);
    let mut total_cost_usd = 0.0;
    let outcomes = sim
        .tenants
        .iter()
        .map(|t| {
            contention_sum += t.contention_sum;
            dispatches += t.dispatches;
            let cost_usd = t.cost_per_s * t.busy_s;
            total_cost_usd += cost_usd;
            TenantOutcome {
                name: t.name.clone(),
                kind: t.kind.to_string(),
                workers: t.workers,
                units: t.units,
                submit_s: t.submit_s,
                admit_s: t.admit_t,
                wait_s: t.wait_s,
                busy_s: t.busy_s,
                finish_s: t.finish_t,
                admissions: t.admissions,
                revocations: t.revocations,
                mean_contention: if t.dispatches > 0 {
                    t.contention_sum / t.dispatches as f64
                } else {
                    1.0
                },
                cost_usd,
                units_per_s: if t.busy_s > 0.0 {
                    t.units as f64 / t.busy_s
                } else {
                    0.0
                },
            }
        })
        .collect();
    Ok(FleetOutcome {
        platform: sim.platform.name.clone(),
        scenario: scenario.name(),
        seed,
        max_concurrency: sim.platform.max_concurrency,
        peak_workers: sim.peak,
        utilization,
        mean_contention: if dispatches > 0 {
            contention_sum / dispatches as f64
        } else {
            1.0
        },
        makespan_s,
        total_cost_usd,
        admissions: sim.admissions,
        tenants: outcomes,
    })
}

/// Derive a tenant's runtime invariants: perf-model breakdown for
/// training, one solo serving replay for serving, static-lens stretch
/// and the per-tenant injector.
fn prepare_tenant(
    ts: &TenantSpec,
    idx: usize,
    platform: &PlatformSpec,
    scenario: &ScenarioSpec,
    seed: u64,
) -> Result<TenantRt> {
    let exp = Experiment::from_artifact(&ts.artifact)?;
    let perf = exp.perf_model();
    let plan = &ts.artifact.plan;
    // bandwidth bottleneck: the stage tier with the smallest link
    let tier = plan
        .stage_tiers
        .iter()
        .copied()
        .min_by(|&a, &b| {
            platform.tiers[a]
                .bandwidth_bps
                .partial_cmp(&platform.tiers[b].bandwidth_bps)
                .expect("tier bandwidths are never NaN")
        })
        .unwrap_or(0);
    let (workers, units, unit_base, comm_frac, cost_per_s) = match &ts.kind {
        TenantKind::Train { steps } => {
            let pp = perf.evaluate(plan);
            if !pp.t_iter.is_finite() || pp.t_iter <= 0.0 {
                bail!("plan evaluates to a non-positive iteration time");
            }
            let comm_frac =
                ((pp.flush_s + pp.sync_s) / pp.t_iter).clamp(0.0, 1.0);
            (
                plan.n_workers(),
                *steps,
                UnitBase::Train { t_iter: pp.t_iter },
                comm_frac,
                pp.total_mem_gb * platform.price_per_gb_s,
            )
        }
        TenantKind::Serve { traffic, duration_s, seed: serve_seed } => {
            let mut opts = ServeOptions::new(traffic.clone(), *serve_seed);
            opts.duration_s = *duration_s;
            opts.scenario = scenario.clone();
            let solo = serve_plan(&perf, plan, &opts)?;
            let workers = solo
                .stages
                .iter()
                .map(|s| s.peak_instances)
                .sum::<usize>()
                .max(1);
            let units = (solo.makespan_s / SLICE_S).ceil().max(1.0) as usize;
            let cost_per_s = if solo.makespan_s > 0.0 {
                solo.cost_usd / solo.makespan_s
            } else {
                0.0
            };
            (
                workers,
                units,
                UnitBase::Serve { makespan_s: solo.makespan_s },
                ACT_HANDOFF_SHARE,
                cost_per_s,
            )
        }
    };
    check_admittable(&ts.name, workers, platform)?;
    let injector = Injector::new(scenario, tenant_seed(seed, idx), workers);
    let static_mult = injector.max_iter_virtual_s(1.0);
    Ok(TenantRt {
        name: ts.name.clone(),
        kind: ts.kind.as_str(),
        workers,
        units,
        submit_s: ts.submit_s,
        unit_base,
        comm_frac,
        tier,
        static_mult,
        cost_per_s,
        injector,
        next_unit: 0,
        admitted: false,
        enqueue_t: 0.0,
        admit_t: 0.0,
        wait_s: 0.0,
        busy_s: 0.0,
        finish_t: 0.0,
        admissions: 0,
        revocations: 0,
        revoked_units: HashSet::new(),
        pending_cold: false,
        contention_sum: 0.0,
        dispatches: 0,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentConfig;
    use crate::model::Plan;

    fn artifact_with_dp(dp: usize) -> PlanArtifact {
        let cfg = ExperimentConfig::default();
        let plan = Plan::data_parallel(dp, 0, 2 * dp);
        PlanArtifact::new(cfg, plan, (1.0, 0.0), 1.0, 0.001, "bnb")
    }

    fn train_tenant(name: &str, dp: usize) -> TenantSpec {
        TenantSpec {
            name: name.to_string(),
            kind: TenantKind::Train { steps: 4 },
            artifact: artifact_with_dp(dp),
            submit_s: 0.0,
        }
    }

    fn fleet_of(tenants: Vec<TenantSpec>) -> FleetSpec {
        FleetSpec { tenants, max_concurrency: None }
    }

    #[test]
    fn validate_rejects_never_admittable_tenant_by_name() {
        // aws-lambda admits 1000 concurrent functions; a dp=2000 plan
        // could never leave the queue
        let spec = fleet_of(vec![
            train_tenant("ok", 2),
            train_tenant("whale", 2000),
        ]);
        let err = spec.validate().unwrap_err().to_string();
        assert!(err.contains("whale"), "{err}");
        assert!(err.contains("1000"), "{err}");
        assert!(!err.contains("\"ok\""), "{err}");
        // the small fleet passes
        fleet_of(vec![train_tenant("ok", 2)]).validate().unwrap();
    }

    #[test]
    fn pool_override_shrinks_admission_cap() {
        // a dp=8 tenant fits aws-lambda (1000) but not a 4-worker pool
        let mut spec = fleet_of(vec![train_tenant("pooled", 8)]);
        spec.max_concurrency = Some(4);
        let err = spec.validate().unwrap_err().to_string();
        assert!(err.contains("pooled"), "{err}");
        assert!(err.contains("at most 4"), "{err}");
        // a pool larger than the platform cap is clamped, not an error
        spec.max_concurrency = Some(5000);
        let p = spec.validate().unwrap();
        assert_eq!(p.max_concurrency, 1000);
    }

    #[test]
    fn validate_rejects_shape_errors() {
        assert!(fleet_of(vec![]).validate().is_err());
        let dup = fleet_of(vec![train_tenant("a", 1), train_tenant("a", 1)]);
        assert!(dup.validate().unwrap_err().to_string().contains("duplicate"));
        let mut bad_submit = train_tenant("a", 1);
        bad_submit.submit_s = -1.0;
        assert!(fleet_of(vec![bad_submit]).validate().is_err());
        let mut zero_steps = train_tenant("a", 1);
        zero_steps.kind = TenantKind::Train { steps: 0 };
        assert!(fleet_of(vec![zero_steps]).validate().is_err());
    }

    #[test]
    fn validate_rejects_platform_mismatch() {
        let mut other = train_tenant("b", 1);
        other.artifact.config.platform = "alibaba".to_string();
        let spec = fleet_of(vec![train_tenant("a", 1), other]);
        let err = spec.validate().unwrap_err().to_string();
        assert!(err.contains("disagree"), "{err}");
    }

    #[test]
    fn config_parsing_is_strict() {
        // unknown root key
        assert!(FleetSpec::from_json_text(r#"{"tenant": []}"#).is_err());
        // degenerate pool cap
        assert!(FleetSpec::from_json_text(
            r#"{"max_concurrency": 0, "tenants": []}"#
        )
        .is_err());
        assert!(FleetSpec::from_json_text(
            r#"{"max_concurrency": 2.5, "tenants": []}"#
        )
        .is_err());
        // empty fleet
        assert!(FleetSpec::from_json_text(r#"{"tenants": []}"#).is_err());
        // unknown tenant key fails before any file I/O
        let err = FleetSpec::from_json_text(
            r#"{"tenants": [{"name": "a", "kind": "train",
                "plan": "nope.json", "steps": 2, "stepz": 3}]}"#,
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("stepz"), "{err}");
        // serve-only keys are rejected on a train tenant
        let err = FleetSpec::from_json_text(
            r#"{"tenants": [{"name": "a", "kind": "train",
                "plan": "nope.json", "steps": 2, "traffic": "poisson:60"}]}"#,
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("traffic"), "{err}");
        // unknown kind
        let err = FleetSpec::from_json_text(
            r#"{"tenants": [{"name": "a", "kind": "batch",
                "plan": "nope.json"}]}"#,
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("batch"), "{err}");
    }

    #[test]
    fn scheduled_orders_by_time_then_seq() {
        let a = Scheduled { t: 1.0, seq: 5, ev: Ev::Submit(0) };
        let b = Scheduled { t: 1.0, seq: 6, ev: Ev::Submit(1) };
        let c = Scheduled { t: 0.5, seq: 9, ev: Ev::Submit(2) };
        assert!(c < a && a < b);
        let mut heap = BinaryHeap::new();
        for s in [a, b, c] {
            heap.push(Reverse(s));
        }
        assert_eq!(heap.pop().unwrap().0.ev, Ev::Submit(2));
        assert_eq!(heap.pop().unwrap().0.ev, Ev::Submit(0));
        assert_eq!(heap.pop().unwrap().0.ev, Ev::Submit(1));
    }

    #[test]
    fn tenant_seed_mixing_separates_tenants() {
        assert_ne!(tenant_seed(7, 0), tenant_seed(7, 1));
        assert_eq!(tenant_seed(7, 0), 7);
        assert_ne!(tenant_seed(7, 2), tenant_seed(8, 2));
    }
}
