//! Inter-stage activation/gradient transfer via storage — the *upload* /
//! *download* pipeline tasks of §3.2. Partition boundaries exchange
//! per-micro-batch tensors through uniquely-keyed objects.

use std::sync::Arc;
use std::time::Duration;

use anyhow::{Context, Result};

use super::{bytes_to_f32s, f32s_to_bytes};
use crate::platform::ObjectStore;

/// Key for the activation flowing stage→stage+1 (forward) or the gradient
/// flowing stage→stage−1 (backward) of micro-batch `mb` in round `round`.
/// `replica` disambiguates data-parallel lanes.
pub fn boundary_key(
    dir: &str,
    round: u64,
    from_stage: usize,
    replica: usize,
    mb: usize,
) -> String {
    format!("act/{dir}/r{round}/s{from_stage}/d{replica}/mb{mb}")
}

/// Upload a boundary tensor.
pub fn send(
    store: &Arc<dyn ObjectStore>,
    key: &str,
    data: &[f32],
) -> Result<()> {
    store.put(key, f32s_to_bytes(data)).context("send")
}

/// Blocking receive of a boundary tensor.
pub fn recv(
    store: &Arc<dyn ObjectStore>,
    key: &str,
    timeout: Duration,
) -> Result<Vec<f32>> {
    let bytes = store.get_blocking(key, timeout).context("recv")?;
    Ok(bytes_to_f32s(&bytes))
}

/// Receive then delete (boundary tensors are consumed exactly once, so the
/// store does not grow over training).
pub fn recv_consume(
    store: &Arc<dyn ObjectStore>,
    key: &str,
    timeout: Duration,
) -> Result<Vec<f32>> {
    let v = recv(store, key, timeout)?;
    store.delete(key);
    Ok(v)
}

/// Raw-bytes variants for non-f32 payloads (int32 token batches).
pub fn send_bytes(
    store: &Arc<dyn ObjectStore>,
    key: &str,
    data: Vec<u8>,
) -> Result<()> {
    store.put(key, data).context("send_bytes")
}

pub fn recv_bytes_consume(
    store: &Arc<dyn ObjectStore>,
    key: &str,
    timeout: Duration,
) -> Result<Vec<u8>> {
    let bytes = store.get_blocking(key, timeout).context("recv_bytes")?;
    store.delete(key);
    Ok(bytes.as_ref().clone())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::MemStore;

    #[test]
    fn send_recv_roundtrip() {
        let store: Arc<dyn ObjectStore> = Arc::new(MemStore::new());
        let k = boundary_key("fwd", 3, 1, 0, 2);
        send(&store, &k, &[1.0, -2.0, 3.5]).unwrap();
        let got = recv(&store, &k, Duration::from_secs(1)).unwrap();
        assert_eq!(got, vec![1.0, -2.0, 3.5]);
    }

    #[test]
    fn consume_deletes() {
        let store: Arc<dyn ObjectStore> = Arc::new(MemStore::new());
        send(&store, "k", &[7.0]).unwrap();
        let got = recv_consume(&store, "k", Duration::from_secs(1)).unwrap();
        assert_eq!(got, vec![7.0]);
        assert!(store.get("k").is_none());
    }

    #[test]
    fn keys_distinguish_direction_round_replica() {
        let keys = [
            boundary_key("fwd", 0, 1, 0, 0),
            boundary_key("bwd", 0, 1, 0, 0),
            boundary_key("fwd", 1, 1, 0, 0),
            boundary_key("fwd", 0, 2, 0, 0),
            boundary_key("fwd", 0, 1, 1, 0),
            boundary_key("fwd", 0, 1, 0, 1),
        ];
        for i in 0..keys.len() {
            for j in (i + 1)..keys.len() {
                assert_ne!(keys[i], keys[j]);
            }
        }
    }

    #[test]
    fn cross_thread_handoff() {
        let store: Arc<dyn ObjectStore> = Arc::new(MemStore::new());
        let s2 = store.clone();
        let consumer = std::thread::spawn(move || {
            recv_consume(&s2, "late", Duration::from_secs(5)).unwrap()
        });
        std::thread::sleep(Duration::from_millis(20));
        send(&store, "late", &[42.0]).unwrap();
        assert_eq!(consumer.join().unwrap(), vec![42.0]);
    }
}
