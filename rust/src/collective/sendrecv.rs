//! Inter-stage activation/gradient transfer via storage — the *upload* /
//! *download* pipeline tasks of §3.2. Partition boundaries exchange
//! per-micro-batch tensors through uniquely-keyed objects.
//!
//! Every operation comes in two forms: a blocking one (called from plain
//! OS threads — tests, external drivers) and an `_async` twin used by the
//! pooled worker state machines, which must never park an executor thread
//! on a store wait.

use std::sync::Arc;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use super::{bytes_to_f32s, chunk_ranges, f32s_to_bytes, Chunking};
use crate::platform::ObjectStore;

/// Key for the activation flowing stage→stage+1 (forward) or the gradient
/// flowing stage→stage−1 (backward) of micro-batch `mb` in round `round`.
/// `replica` disambiguates data-parallel lanes.
pub fn boundary_key(
    dir: &str,
    round: u64,
    from_stage: usize,
    replica: usize,
    mb: usize,
) -> String {
    format!("act/{dir}/r{round}/s{from_stage}/d{replica}/mb{mb}")
}

/// Upload a boundary tensor.
pub fn send(
    store: &Arc<dyn ObjectStore>,
    key: &str,
    data: &[f32],
) -> Result<()> {
    store.put(key, f32s_to_bytes(data)).context("send")
}

/// Blocking receive of a boundary tensor.
pub fn recv(
    store: &Arc<dyn ObjectStore>,
    key: &str,
    timeout: Duration,
) -> Result<Vec<f32>> {
    let bytes = store.get_blocking(key, timeout).context("recv")?;
    Ok(bytes_to_f32s(&bytes))
}

/// Receive then delete (boundary tensors are consumed exactly once, so the
/// store does not grow over training).
pub fn recv_consume(
    store: &Arc<dyn ObjectStore>,
    key: &str,
    timeout: Duration,
) -> Result<Vec<f32>> {
    let v = recv(store, key, timeout)?;
    store.delete(key);
    Ok(v)
}

/// Chunked upload of a boundary tensor: the payload travels as
/// independent `{key}/c{i}` objects behind a `{key}/meta` chunk count, so
/// large activations never materialize as one blob on either side of the
/// relay. The receiver needs no chunking knowledge — it reads the meta.
pub fn send_chunked(
    store: &Arc<dyn ObjectStore>,
    key: &str,
    data: &[f32],
    chunking: Chunking,
) -> Result<()> {
    let chunks = chunk_ranges(0, data.len(), chunking.chunk_elems());
    store
        .put(
            &format!("{key}/meta"),
            (chunks.len() as u64).to_le_bytes().to_vec(),
        )
        .context("send_chunked meta")?;
    for (i, &(lo, hi)) in chunks.iter().enumerate() {
        store
            .put(&format!("{key}/c{i}"), f32s_to_bytes(&data[lo..hi]))
            .context("send_chunked")?;
    }
    Ok(())
}

/// Blocking chunked receive; consumes the chunk objects and the meta.
pub fn recv_chunked_consume(
    store: &Arc<dyn ObjectStore>,
    key: &str,
    timeout: Duration,
) -> Result<Vec<f32>> {
    let meta_key = format!("{key}/meta");
    let meta = store
        .get_blocking(&meta_key, timeout)
        .context("recv_chunked meta")?;
    if meta.len() != 8 {
        bail!("bad chunk meta for {key:?}: {} bytes", meta.len());
    }
    let n_chunks = u64::from_le_bytes(meta[..8].try_into().unwrap()) as usize;
    let mut out = Vec::new();
    for i in 0..n_chunks {
        let ck = format!("{key}/c{i}");
        let bytes = store
            .get_blocking(&ck, timeout)
            .context("recv_chunked")?;
        out.extend_from_slice(&bytes_to_f32s(&bytes));
        store.delete(&ck);
    }
    store.delete(&meta_key);
    Ok(out)
}

/// Raw-bytes variants for non-f32 payloads (int32 token batches).
pub fn send_bytes(
    store: &Arc<dyn ObjectStore>,
    key: &str,
    data: Vec<u8>,
) -> Result<()> {
    store.put(key, data).context("send_bytes")
}

pub fn recv_bytes_consume(
    store: &Arc<dyn ObjectStore>,
    key: &str,
    timeout: Duration,
) -> Result<Vec<u8>> {
    let bytes = store.get_blocking(key, timeout).context("recv_bytes")?;
    store.delete(key);
    Ok(bytes.as_ref().clone())
}

// ---------------------------------------------------------------- async
// Twins of the blocking operations for the pooled worker state machines.
// Control flow mirrors the blocking forms exactly (same keys, same
// consume order) so replay transcripts cannot tell them apart.

/// Async upload of a boundary tensor.
pub async fn send_async(
    store: &Arc<dyn ObjectStore>,
    key: &str,
    data: &[f32],
) -> Result<()> {
    store.put_async(key, f32s_to_bytes(data)).await.context("send")
}

/// Async receive then delete.
pub async fn recv_consume_async(
    store: &Arc<dyn ObjectStore>,
    key: &str,
    timeout: Duration,
) -> Result<Vec<f32>> {
    let bytes = store.get_async(key, timeout).await.context("recv")?;
    store.delete(key);
    Ok(bytes_to_f32s(&bytes))
}

/// Async chunked upload (same wire format as [`send_chunked`]).
pub async fn send_chunked_async(
    store: &Arc<dyn ObjectStore>,
    key: &str,
    data: &[f32],
    chunking: Chunking,
) -> Result<()> {
    let chunks = chunk_ranges(0, data.len(), chunking.chunk_elems());
    store
        .put_async(
            &format!("{key}/meta"),
            (chunks.len() as u64).to_le_bytes().to_vec(),
        )
        .await
        .context("send_chunked meta")?;
    for (i, &(lo, hi)) in chunks.iter().enumerate() {
        store
            .put_async(&format!("{key}/c{i}"), f32s_to_bytes(&data[lo..hi]))
            .await
            .context("send_chunked")?;
    }
    Ok(())
}

/// Async chunked receive; consumes the chunk objects and the meta.
pub async fn recv_chunked_consume_async(
    store: &Arc<dyn ObjectStore>,
    key: &str,
    timeout: Duration,
) -> Result<Vec<f32>> {
    let meta_key = format!("{key}/meta");
    let meta = store
        .get_async(&meta_key, timeout)
        .await
        .context("recv_chunked meta")?;
    if meta.len() != 8 {
        bail!("bad chunk meta for {key:?}: {} bytes", meta.len());
    }
    let n_chunks = u64::from_le_bytes(meta[..8].try_into().unwrap()) as usize;
    let mut out = Vec::new();
    for i in 0..n_chunks {
        let ck = format!("{key}/c{i}");
        let bytes = store
            .get_async(&ck, timeout)
            .await
            .context("recv_chunked")?;
        out.extend_from_slice(&bytes_to_f32s(&bytes));
        store.delete(&ck);
    }
    store.delete(&meta_key);
    Ok(out)
}

/// Async raw-bytes upload.
pub async fn send_bytes_async(
    store: &Arc<dyn ObjectStore>,
    key: &str,
    data: Vec<u8>,
) -> Result<()> {
    store.put_async(key, data).await.context("send_bytes")
}

/// Async raw-bytes receive then delete.
pub async fn recv_bytes_consume_async(
    store: &Arc<dyn ObjectStore>,
    key: &str,
    timeout: Duration,
) -> Result<Vec<u8>> {
    let bytes = store.get_async(key, timeout).await.context("recv_bytes")?;
    store.delete(key);
    Ok(bytes.as_ref().clone())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::MemStore;

    #[test]
    fn send_recv_roundtrip() {
        let store: Arc<dyn ObjectStore> = Arc::new(MemStore::new());
        let k = boundary_key("fwd", 3, 1, 0, 2);
        send(&store, &k, &[1.0, -2.0, 3.5]).unwrap();
        let got = recv(&store, &k, Duration::from_secs(1)).unwrap();
        assert_eq!(got, vec![1.0, -2.0, 3.5]);
    }

    #[test]
    fn consume_deletes() {
        let store: Arc<dyn ObjectStore> = Arc::new(MemStore::new());
        send(&store, "k", &[7.0]).unwrap();
        let got = recv_consume(&store, "k", Duration::from_secs(1)).unwrap();
        assert_eq!(got, vec![7.0]);
        assert!(store.get("k").is_none());
    }

    #[test]
    fn keys_distinguish_direction_round_replica() {
        let keys = [
            boundary_key("fwd", 0, 1, 0, 0),
            boundary_key("bwd", 0, 1, 0, 0),
            boundary_key("fwd", 1, 1, 0, 0),
            boundary_key("fwd", 0, 2, 0, 0),
            boundary_key("fwd", 0, 1, 1, 0),
            boundary_key("fwd", 0, 1, 0, 1),
        ];
        for i in 0..keys.len() {
            for j in (i + 1)..keys.len() {
                assert_ne!(keys[i], keys[j]);
            }
        }
    }

    #[test]
    fn chunked_send_recv_roundtrip() {
        let store: Arc<dyn ObjectStore> = Arc::new(MemStore::new());
        let data: Vec<f32> = (0..103).map(|i| i as f32 * 0.5).collect();
        for chunking in [Chunking::NONE, Chunking::new(16, 2), Chunking::new(64, 4)] {
            let k = format!("chunky/{}", chunking.chunk_bytes);
            send_chunked(&store, &k, &data, chunking).unwrap();
            let got =
                recv_chunked_consume(&store, &k, Duration::from_secs(1))
                    .unwrap();
            assert_eq!(got, data);
            assert!(store.list(&k).is_empty(), "chunks consumed");
        }
    }

    #[test]
    fn chunked_empty_tensor() {
        let store: Arc<dyn ObjectStore> = Arc::new(MemStore::new());
        send_chunked(&store, "empty", &[], Chunking::new(16, 2)).unwrap();
        let got =
            recv_chunked_consume(&store, "empty", Duration::from_secs(1))
                .unwrap();
        assert!(got.is_empty());
    }

    #[test]
    fn async_twins_match_blocking_wire_format() {
        use crate::exec::block_on;
        let store: Arc<dyn ObjectStore> = Arc::new(MemStore::new());
        let data: Vec<f32> = (0..57).map(|i| i as f32 - 3.0).collect();
        block_on(async {
            send_async(&store, "a/plain", &data).await.unwrap();
            send_chunked_async(&store, "a/ch", &data, Chunking::new(32, 2))
                .await
                .unwrap();
            send_bytes_async(&store, "a/raw", vec![1, 2, 3]).await.unwrap();
        });
        // the blocking readers consume what the async writers produced
        let got = recv_consume(&store, "a/plain", Duration::from_secs(1))
            .unwrap();
        assert_eq!(got, data);
        let got =
            recv_chunked_consume(&store, "a/ch", Duration::from_secs(1))
                .unwrap();
        assert_eq!(got, data);
        // and vice versa
        send(&store, "b/plain", &data).unwrap();
        send_chunked(&store, "b/ch", &data, Chunking::new(32, 2)).unwrap();
        block_on(async {
            let got = recv_consume_async(
                &store,
                "b/plain",
                Duration::from_secs(1),
            )
            .await
            .unwrap();
            assert_eq!(got, data);
            let got = recv_chunked_consume_async(
                &store,
                "b/ch",
                Duration::from_secs(1),
            )
            .await
            .unwrap();
            assert_eq!(got, data);
            let raw = recv_bytes_consume_async(
                &store,
                "a/raw",
                Duration::from_secs(1),
            )
            .await
            .unwrap();
            assert_eq!(raw, vec![1, 2, 3]);
        });
        assert!(store.list("a/").is_empty());
        assert!(store.list("b/").is_empty());
    }

    #[test]
    fn cross_thread_handoff() {
        let store: Arc<dyn ObjectStore> = Arc::new(MemStore::new());
        let s2 = store.clone();
        let consumer = std::thread::spawn(move || {
            recv_consume(&s2, "late", Duration::from_secs(5)).unwrap()
        });
        std::thread::sleep(Duration::from_millis(20));
        send(&store, "late", &[42.0]).unwrap();
        assert_eq!(consumer.join().unwrap(), vec![42.0]);
    }
}
