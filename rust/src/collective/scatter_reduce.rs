//! LambdaML's 3-phase storage-based scatter-reduce (Fig. 4(a)) — the
//! baseline algorithm, real implementation over an [`ObjectStore`].
//!
//! Every replica of a stage calls [`scatter_reduce`] with its local
//! gradient vector; all return the elementwise sum. Phases:
//!   1. upload the n−1 splits owned by other workers;
//!   2. download the n−1 foreign copies of the own split and merge;
//!   3. upload the merged split, download the other merged splits.
//!
//! Keys embed (group, round, phase, split, sender) so concurrent rounds
//! and stages never collide — the paper's filename-metadata scheme (§4).

use std::sync::Arc;
use std::time::Duration;

use anyhow::{Context, Result};

use super::{add_assign, bytes_to_f32s, f32s_to_bytes, split_ranges};
use crate::platform::ObjectStore;

/// Merge operator: `acc += delta`. Injected so the trainer can route the
/// reduction through the AOT `merge2` executable (L1 Pallas kernel).
pub type MergeFn<'a> = dyn Fn(&mut [f32], &[f32]) + 'a;

pub(crate) fn native_merge(acc: &mut [f32], delta: &[f32]) {
    add_assign(acc, delta);
}

fn key(group: &str, round: u64, phase: u8, split: usize, from: usize) -> String {
    format!("{group}/r{round}/p{phase}/s{split}/f{from}")
}

/// Non-pipelined (LambdaML) scatter-reduce. Blocking; returns when this
/// worker holds the full summed gradient in `grads`.
pub fn scatter_reduce(
    store: &Arc<dyn ObjectStore>,
    group: &str,
    round: u64,
    rank: usize,
    n: usize,
    grads: &mut [f32],
    merge: Option<&MergeFn>,
    timeout: Duration,
) -> Result<()> {
    assert!(rank < n);
    if n == 1 {
        return Ok(());
    }
    let ranges = split_ranges(grads.len(), n);
    let native: &MergeFn = &native_merge;
    let merge = merge.unwrap_or(native);

    // phase 1: upload foreign splits
    for j in 0..n {
        if j == rank {
            continue;
        }
        let (lo, hi) = ranges[j];
        store
            .put(&key(group, round, 1, j, rank), f32s_to_bytes(&grads[lo..hi]))
            .context("phase-1 upload")?;
    }

    // phase 2: merge foreign copies of our own split
    let (mylo, myhi) = ranges[rank];
    let mut merged = grads[mylo..myhi].to_vec();
    for j in 0..n {
        if j == rank {
            continue;
        }
        let bytes = store
            .get_blocking(&key(group, round, 1, rank, j), timeout)
            .context("phase-2 download")?;
        let delta = bytes_to_f32s(&bytes);
        merge(&mut merged, &delta);
    }

    // phase 3: publish merged split, gather the others
    store
        .put(&key(group, round, 3, rank, rank), f32s_to_bytes(&merged))
        .context("phase-3 upload")?;
    grads[mylo..myhi].copy_from_slice(&merged);
    for j in 0..n {
        if j == rank {
            continue;
        }
        let bytes = store
            .get_blocking(&key(group, round, 3, j, j), timeout)
            .context("phase-3 download")?;
        let (lo, hi) = ranges[j];
        grads[lo..hi].copy_from_slice(&bytes_to_f32s(&bytes));
    }
    Ok(())
}

/// Remove this round's objects (called by rank 0 after a barrier, or lazily
/// by the Function Manager's garbage collection).
pub fn cleanup(store: &Arc<dyn ObjectStore>, group: &str, round: u64) {
    for k in store.list(&format!("{group}/r{round}/")) {
        store.delete(&k);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::MemStore;

    fn run_n(n: usize, len: usize) -> Vec<Vec<f32>> {
        let store: Arc<dyn ObjectStore> = Arc::new(MemStore::new());
        let mut handles = Vec::new();
        for rank in 0..n {
            let store = store.clone();
            handles.push(std::thread::spawn(move || {
                let mut grads: Vec<f32> =
                    (0..len).map(|i| (rank * len + i) as f32).collect();
                scatter_reduce(
                    &store,
                    "g",
                    0,
                    rank,
                    n,
                    &mut grads,
                    None,
                    Duration::from_secs(10),
                )
                .unwrap();
                grads
            }));
        }
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    }

    #[test]
    fn all_workers_get_the_sum() {
        for n in [2usize, 3, 4, 8] {
            let len = 103; // not divisible by n
            let results = run_n(n, len);
            let expect: Vec<f32> = (0..len)
                .map(|i| {
                    (0..n).map(|r| (r * len + i) as f32).sum::<f32>()
                })
                .collect();
            for (r, res) in results.iter().enumerate() {
                assert_eq!(res, &expect, "rank {r} of n={n}");
            }
        }
    }

    #[test]
    fn single_worker_is_identity() {
        let store: Arc<dyn ObjectStore> = Arc::new(MemStore::new());
        let mut g = vec![1.0f32, 2.0];
        scatter_reduce(&store, "g", 0, 0, 1, &mut g, None, Duration::from_secs(1))
            .unwrap();
        assert_eq!(g, vec![1.0, 2.0]);
        assert_eq!(store.total_bytes(), 0);
    }

    #[test]
    fn rounds_do_not_collide() {
        let store: Arc<dyn ObjectStore> = Arc::new(MemStore::new());
        let mut handles = Vec::new();
        for round in 0..3u64 {
            for rank in 0..2usize {
                let store = store.clone();
                handles.push(std::thread::spawn(move || {
                    let mut g = vec![(round as f32) + 1.0; 10];
                    scatter_reduce(
                        &store,
                        "g",
                        round,
                        rank,
                        2,
                        &mut g,
                        None,
                        Duration::from_secs(10),
                    )
                    .unwrap();
                    (round, g)
                }));
            }
        }
        for h in handles {
            let (round, g) = h.join().unwrap();
            let want = 2.0 * (round as f32 + 1.0);
            assert!(g.iter().all(|&x| (x - want).abs() < 1e-6));
        }
    }

    #[test]
    fn cleanup_removes_round_objects() {
        let store: Arc<dyn ObjectStore> = Arc::new(MemStore::new());
        let _ = {
            let store = store.clone();
            let t0 = std::thread::spawn({
                let store = store.clone();
                move || {
                    let mut g = vec![1.0f32; 8];
                    scatter_reduce(&store, "x", 5, 0, 2, &mut g, None, Duration::from_secs(10)).unwrap();
                }
            });
            let t1 = std::thread::spawn({
                let store = store.clone();
                move || {
                    let mut g = vec![2.0f32; 8];
                    scatter_reduce(&store, "x", 5, 1, 2, &mut g, None, Duration::from_secs(10)).unwrap();
                }
            });
            t0.join().unwrap();
            t1.join().unwrap();
        };
        assert!(store.total_bytes() > 0);
        cleanup(&store, "x", 5);
        assert_eq!(store.total_bytes(), 0);
    }
}
