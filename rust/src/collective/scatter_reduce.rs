//! LambdaML's 3-phase storage-based scatter-reduce (Fig. 4(a)) — the
//! baseline algorithm, rebuilt on the unified chunked engine.
//!
//! Every replica of a stage calls [`scatter_reduce`] with its local
//! gradient vector; all return the elementwise sum. Phases:
//!   1. upload the n−1 splits owned by other workers (chunk-wise);
//!   2. download the n−1 foreign copies of the own split and merge,
//!      consuming (deleting) each single-reader chunk;
//!   3. upload the merged split, download the other merged splits.
//!
//! The phases stay strictly serialized per worker — the inefficiency the
//! paper identifies, preserved here so eq. (1) remains the right model —
//! which is also why this algorithm never window-gates its uploads:
//! nobody consumes phase-1 chunks until every worker reaches phase 2, so
//! a store-occupancy window would deadlock.
//!
//! Keys embed (group, round, phase, split, sender, chunk) so concurrent
//! rounds and stages never collide — the paper's filename-metadata scheme
//! (§4). Each rank posts a `done` marker after its final download;
//! [`cleanup`] waits for all markers before deleting the round's prefix,
//! so a straggler can never lose a phase-3 object it still needs.

use std::sync::Arc;
use std::time::Duration;

use anyhow::{Context, Result};

use super::flow::PutJob;
use super::{
    bytes_to_f32s, done_key, f32s_to_bytes, merged_chunk_key, native_merge,
    split_ranges, ChunkPlan, Chunking, Collective, CollectiveCtx,
    CollectiveFuture, MergeFn,
};
use crate::exec::block_on;
use crate::platform::ObjectStore;

pub(crate) fn p1_key(
    group: &str,
    round: u64,
    split: usize,
    from: usize,
    chunk: usize,
) -> String {
    format!("{group}/r{round}/p1/s{split}/f{from}/c{chunk}")
}

/// The plain (LambdaML) scatter-reduce on the unified engine.
pub struct PlainScatterReduce;

impl Collective for PlainScatterReduce {
    fn name(&self) -> &'static str {
        "scatter-reduce"
    }

    fn all_reduce<'a>(
        &'a self,
        ctx: &'a CollectiveCtx,
        round: u64,
        grads: &'a mut [f32],
        merge: Option<&'a MergeFn<'a>>,
    ) -> CollectiveFuture<'a> {
        Box::pin(run(ctx, round, grads, merge))
    }
}

async fn run(
    ctx: &CollectiveCtx,
    round: u64,
    grads: &mut [f32],
    merge: Option<&MergeFn<'_>>,
) -> Result<()> {
    let (n, rank) = (ctx.n, ctx.rank);
    if n == 1 {
        return Ok(());
    }
    let native: &MergeFn = &native_merge;
    let merge = merge.unwrap_or(native);
    let ranges = split_ranges(grads.len(), n);
    let plan = ChunkPlan::new(&ranges, &ctx.chunking);
    let group = ctx.group.as_str();
    let pool = ctx.pool();

    // phase 1: upload foreign splits chunk-wise (uplink only)
    for j in 0..n {
        if j == rank {
            continue;
        }
        for (c, &(lo, hi)) in plan.chunks[j].iter().enumerate() {
            pool.put(PutJob {
                key: p1_key(group, round, j, rank, c),
                data: f32s_to_bytes(&grads[lo..hi]),
                gate: None,
            })
            .await?;
        }
    }
    pool.flush().await.context("phase-1 upload")?;

    // phase 2: merge the foreign copies of our own split, consuming
    // each chunk (we are its only reader)
    let (mylo, myhi) = ranges[rank];
    let mut merged = grads[mylo..myhi].to_vec();
    let mut keys = Vec::new();
    let mut spans = Vec::new();
    for j in 0..n {
        if j == rank {
            continue;
        }
        for (c, &(lo, hi)) in plan.chunks[rank].iter().enumerate() {
            keys.push(p1_key(group, round, rank, j, c));
            spans.push((lo, hi));
        }
    }
    let mut rx = pool.stream(keys.clone(), ctx.timeout);
    for (key, &(lo, hi)) in keys.iter().zip(&spans) {
        let bytes = rx.recv().await.context("phase-2 stream closed")??;
        merge(&mut merged[lo - mylo..hi - mylo], &bytes_to_f32s(&bytes));
        ctx.store.delete(key);
    }

    // phase 3: publish merged chunks, gather the other merged splits
    for (c, &(lo, hi)) in plan.chunks[rank].iter().enumerate() {
        pool.put(PutJob {
            key: merged_chunk_key(group, round, rank, c),
            data: f32s_to_bytes(&merged[lo - mylo..hi - mylo]),
            gate: None,
        })
        .await?;
    }
    pool.flush().await.context("phase-3 upload")?;
    grads[mylo..myhi].copy_from_slice(&merged);

    let mut keys = Vec::new();
    let mut spans = Vec::new();
    for j in 0..n {
        if j == rank {
            continue;
        }
        for (c, &(lo, hi)) in plan.chunks[j].iter().enumerate() {
            keys.push(merged_chunk_key(group, round, j, c));
            spans.push((lo, hi));
        }
    }
    let mut rx = pool.stream(keys, ctx.timeout);
    for &(lo, hi) in &spans {
        let bytes = rx.recv().await.context("phase-3 stream closed")??;
        grads[lo..hi].copy_from_slice(&bytes_to_f32s(&bytes));
    }
    ctx.mark_done(round).await
}

/// Non-pipelined (LambdaML) scatter-reduce. Blocking; returns when this
/// worker holds the full summed gradient in `grads`.
pub fn scatter_reduce(
    store: &Arc<dyn ObjectStore>,
    group: &str,
    round: u64,
    rank: usize,
    n: usize,
    grads: &mut [f32],
    merge: Option<&MergeFn>,
    timeout: Duration,
) -> Result<()> {
    scatter_reduce_chunked(
        store,
        group,
        round,
        rank,
        n,
        grads,
        merge,
        timeout,
        Chunking::NONE,
    )
}

/// Chunked variant: splits additionally travel as `chunking.chunk_bytes`
/// objects (uploaded/downloaded as independent flows).
#[allow(clippy::too_many_arguments)]
pub fn scatter_reduce_chunked(
    store: &Arc<dyn ObjectStore>,
    group: &str,
    round: u64,
    rank: usize,
    n: usize,
    grads: &mut [f32],
    merge: Option<&MergeFn>,
    timeout: Duration,
    chunking: Chunking,
) -> Result<()> {
    let ctx = CollectiveCtx::new(store.clone(), group, rank, n, timeout)
        .with_chunking(chunking);
    block_on(run(&ctx, round, grads, merge))
}

/// Async form of [`cleanup`] — what the pooled worker state machines
/// call between rounds.
pub async fn cleanup_async(
    store: &Arc<dyn ObjectStore>,
    group: &str,
    round: u64,
    n: usize,
    timeout: Duration,
) -> Result<()> {
    for rank in 0..n {
        store
            .get_async(&done_key(group, round, rank), timeout)
            .await
            .with_context(|| format!("cleanup barrier: rank {rank} not done"))?;
    }
    for k in store.list(&format!("{group}/r{round}/")) {
        store.delete(&k);
    }
    Ok(())
}

/// Remove this round's objects. Waits for every rank's `done` marker
/// first (the end-of-round barrier each collective posts), so a straggler
/// still downloading phase-3 objects can never have them deleted from
/// under it. Called by rank 0 once a later round's barrier implies the
/// markers exist, or lazily by the Function Manager's garbage collection.
pub fn cleanup(
    store: &Arc<dyn ObjectStore>,
    group: &str,
    round: u64,
    n: usize,
    timeout: Duration,
) -> Result<()> {
    block_on(cleanup_async(store, group, round, n, timeout))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::{MemStore, ThrottledStore};

    fn run_n(n: usize, len: usize, chunking: Chunking) -> Vec<Vec<f32>> {
        let store: Arc<dyn ObjectStore> = Arc::new(MemStore::new());
        let mut handles = Vec::new();
        for rank in 0..n {
            let store = store.clone();
            handles.push(std::thread::spawn(move || {
                let mut grads: Vec<f32> =
                    (0..len).map(|i| (rank * len + i) as f32).collect();
                scatter_reduce_chunked(
                    &store,
                    "g",
                    0,
                    rank,
                    n,
                    &mut grads,
                    None,
                    Duration::from_secs(10),
                    chunking,
                )
                .unwrap();
                grads
            }));
        }
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    }

    #[test]
    fn all_workers_get_the_sum() {
        for n in [2usize, 3, 4, 8] {
            let len = 103; // not divisible by n
            let results = run_n(n, len, Chunking::NONE);
            let expect: Vec<f32> = (0..len)
                .map(|i| {
                    (0..n).map(|r| (r * len + i) as f32).sum::<f32>()
                })
                .collect();
            for (r, res) in results.iter().enumerate() {
                assert_eq!(res, &expect, "rank {r} of n={n}");
            }
        }
    }

    #[test]
    fn chunked_matches_unchunked() {
        for n in [2usize, 3, 5] {
            let len = 257; // odd, not divisible by n or the chunk size
            let plain = run_n(n, len, Chunking::NONE);
            for chunk_bytes in [16usize, 64, 4096] {
                let chunked = run_n(n, len, Chunking::new(chunk_bytes, 3));
                assert_eq!(
                    plain, chunked,
                    "n={n} chunk={chunk_bytes}: chunked deviates"
                );
            }
        }
    }

    #[test]
    fn single_worker_is_identity() {
        let store: Arc<dyn ObjectStore> = Arc::new(MemStore::new());
        let mut g = vec![1.0f32, 2.0];
        scatter_reduce(&store, "g", 0, 0, 1, &mut g, None, Duration::from_secs(1))
            .unwrap();
        assert_eq!(g, vec![1.0, 2.0]);
        assert_eq!(store.total_bytes(), 0);
    }

    #[test]
    fn rounds_do_not_collide() {
        let store: Arc<dyn ObjectStore> = Arc::new(MemStore::new());
        let mut handles = Vec::new();
        for round in 0..3u64 {
            for rank in 0..2usize {
                let store = store.clone();
                handles.push(std::thread::spawn(move || {
                    let mut g = vec![(round as f32) + 1.0; 10];
                    scatter_reduce(
                        &store,
                        "g",
                        round,
                        rank,
                        2,
                        &mut g,
                        None,
                        Duration::from_secs(10),
                    )
                    .unwrap();
                    (round, g)
                }));
            }
        }
        for h in handles {
            let (round, g) = h.join().unwrap();
            let want = 2.0 * (round as f32 + 1.0);
            assert!(g.iter().all(|&x| (x - want).abs() < 1e-6));
        }
    }

    #[test]
    fn cleanup_removes_round_objects() {
        let store: Arc<dyn ObjectStore> = Arc::new(MemStore::new());
        let mk = |rank: usize| {
            let store = store.clone();
            std::thread::spawn(move || {
                let mut g = vec![(rank + 1) as f32; 8];
                scatter_reduce(
                    &store,
                    "x",
                    5,
                    rank,
                    2,
                    &mut g,
                    None,
                    Duration::from_secs(10),
                )
                .unwrap();
            })
        };
        let (t0, t1) = (mk(0), mk(1));
        t0.join().unwrap();
        t1.join().unwrap();
        assert!(store.total_bytes() > 0); // merged splits await cleanup
        cleanup(&store, "x", 5, 2, Duration::from_secs(5)).unwrap();
        assert_eq!(store.total_bytes(), 0);
        assert!(store.list("x/r5/").is_empty());
    }

    /// Regression for the cleanup race: rank 1 sits behind a throttled
    /// store and is still blocking-downloading phase-3 objects when rank 0
    /// finishes and fires cleanup. The done-marker barrier must make
    /// cleanup wait instead of deleting objects the straggler needs.
    #[test]
    fn cleanup_waits_for_stragglers() {
        let inner: Arc<dyn ObjectStore> = Arc::new(MemStore::new());
        let fast = inner.clone();
        let slow: Arc<dyn ObjectStore> = Arc::new(ThrottledStore::new(
            inner.clone(),
            f64::INFINITY,
            50.0e3, // 50 KB/s downlink: phase 2+3 take a while
            Duration::from_millis(5),
        ));
        let len = 4000; // 16 KB of gradient, phase-3 split = 4 x 2 KB chunks
        let chunking = Chunking::new(2048, 2);
        let t0 = std::thread::spawn({
            let fast = fast.clone();
            move || {
                let mut g = vec![1.0f32; len];
                scatter_reduce_chunked(
                    &fast,
                    "rc",
                    0,
                    0,
                    2,
                    &mut g,
                    None,
                    Duration::from_secs(30),
                    chunking,
                )
                .unwrap();
                // rank 0 immediately garbage-collects the round while the
                // straggler still has several chunk downloads to request
                cleanup(&fast, "rc", 0, 2, Duration::from_secs(30)).unwrap();
            }
        });
        let t1 = std::thread::spawn(move || {
            let mut g = vec![2.0f32; len];
            scatter_reduce_chunked(
                &slow,
                "rc",
                0,
                1,
                2,
                &mut g,
                None,
                Duration::from_secs(30),
                chunking,
            )
            .unwrap();
            g
        });
        t0.join().unwrap();
        let g = t1.join().unwrap();
        assert!(g.iter().all(|&x| (x - 3.0).abs() < 1e-6));
        assert_eq!(inner.total_bytes(), 0, "cleanup ran after the barrier");
    }
}
