//! Centralized parameter-server synchronization — the communication core
//! of the HybridPS baseline (Cirrus-style, §2.2/§5.1). A dedicated server
//! thread (standing in for the VM) aggregates worker gradients and
//! publishes the merged result.

use std::sync::Arc;
use std::time::Duration;

use anyhow::{Context, Result};

use super::scatter_reduce::{native_merge, MergeFn};
use super::{bytes_to_f32s, f32s_to_bytes};
use crate::platform::ObjectStore;

fn push_key(group: &str, round: u64, from: usize) -> String {
    format!("{group}/ps/r{round}/push/f{from}")
}

fn merged_key(group: &str, round: u64) -> String {
    format!("{group}/ps/r{round}/merged")
}

/// Worker side: push local gradients, wait for the merged result.
pub fn ps_sync_worker(
    store: &Arc<dyn ObjectStore>,
    group: &str,
    round: u64,
    rank: usize,
    grads: &mut [f32],
    timeout: Duration,
) -> Result<()> {
    store
        .put(&push_key(group, round, rank), f32s_to_bytes(grads))
        .context("ps push")?;
    let merged = store
        .get_blocking(&merged_key(group, round), timeout)
        .context("ps pull")?;
    grads.copy_from_slice(&bytes_to_f32s(&merged));
    Ok(())
}

/// Server side: gather `n` pushes, merge, publish. Returns the merged
/// gradient (the real PS would also apply the optimizer step here).
pub fn ps_sync_server(
    store: &Arc<dyn ObjectStore>,
    group: &str,
    round: u64,
    n: usize,
    len: usize,
    merge: Option<&MergeFn>,
    timeout: Duration,
) -> Result<Vec<f32>> {
    let native: &MergeFn = &native_merge;
    let merge = merge.unwrap_or(native);
    let mut acc = vec![0.0f32; len];
    for rank in 0..n {
        let bytes = store
            .get_blocking(&push_key(group, round, rank), timeout)
            .context("ps gather")?;
        merge(&mut acc, &bytes_to_f32s(&bytes));
        store.delete(&push_key(group, round, rank));
    }
    store
        .put(&merged_key(group, round), f32s_to_bytes(&acc))
        .context("ps publish")?;
    Ok(acc)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::MemStore;

    #[test]
    fn ps_roundtrip_sums_gradients() {
        let n = 5;
        let len = 33;
        let store: Arc<dyn ObjectStore> = Arc::new(MemStore::new());
        let server = {
            let store = store.clone();
            std::thread::spawn(move || {
                ps_sync_server(&store, "g", 0, n, len, None, Duration::from_secs(10)).unwrap()
            })
        };
        let mut workers = Vec::new();
        for rank in 0..n {
            let store = store.clone();
            workers.push(std::thread::spawn(move || {
                let mut g = vec![(rank + 1) as f32; len];
                ps_sync_worker(&store, "g", 0, rank, &mut g, Duration::from_secs(10)).unwrap();
                g
            }));
        }
        let merged = server.join().unwrap();
        let want = (1..=n).sum::<usize>() as f32;
        assert!(merged.iter().all(|&x| (x - want).abs() < 1e-5));
        for w in workers {
            let g = w.join().unwrap();
            assert!(g.iter().all(|&x| (x - want).abs() < 1e-5));
        }
    }

    #[test]
    fn server_consumes_pushes() {
        let store: Arc<dyn ObjectStore> = Arc::new(MemStore::new());
        let server = {
            let store = store.clone();
            std::thread::spawn(move || {
                ps_sync_server(&store, "h", 1, 2, 4, None, Duration::from_secs(10)).unwrap()
            })
        };
        for rank in 0..2 {
            let store = store.clone();
            std::thread::spawn(move || {
                let mut g = vec![1.0f32; 4];
                ps_sync_worker(&store, "h", 1, rank, &mut g, Duration::from_secs(10)).unwrap();
            });
        }
        server.join().unwrap();
        assert!(store.list("h/ps/r1/push").is_empty());
    }
}
