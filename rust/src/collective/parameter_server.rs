//! Centralized parameter-server synchronization — the communication core
//! of the HybridPS baseline (Cirrus-style, §2.2/§5.1). A dedicated server
//! thread (standing in for the VM) aggregates worker gradients and
//! publishes the merged result.
//!
//! The PS topology is asymmetric (workers push, one server merges), so it
//! does not implement the symmetric [`Collective`](super::Collective)
//! trait; it shares the engine's [`Chunking`] policy instead: with
//! chunking enabled, pushes and the published result travel as
//! independent chunk objects and the server merges/consumes chunk-wise,
//! so its resident overhead beyond the accumulator is one chunk.

use std::sync::Arc;
use std::time::Duration;

use anyhow::{Context, Result};

use super::{
    bytes_to_f32s, chunk_ranges, f32s_to_bytes, native_merge, Chunking,
    MergeFn,
};
use crate::platform::ObjectStore;

fn push_key(group: &str, round: u64, from: usize, chunk: usize) -> String {
    format!("{group}/ps/r{round}/push/f{from}/c{chunk}")
}

fn merged_key(group: &str, round: u64, chunk: usize) -> String {
    format!("{group}/ps/r{round}/merged/c{chunk}")
}

/// Worker side: push local gradients, wait for the merged result.
pub fn ps_sync_worker(
    store: &Arc<dyn ObjectStore>,
    group: &str,
    round: u64,
    rank: usize,
    grads: &mut [f32],
    timeout: Duration,
) -> Result<()> {
    ps_sync_worker_chunked(
        store,
        group,
        round,
        rank,
        grads,
        timeout,
        Chunking::NONE,
    )
}

/// Chunked worker push/pull. `chunking` must match the server's.
pub fn ps_sync_worker_chunked(
    store: &Arc<dyn ObjectStore>,
    group: &str,
    round: u64,
    rank: usize,
    grads: &mut [f32],
    timeout: Duration,
    chunking: Chunking,
) -> Result<()> {
    crate::exec::block_on(ps_sync_worker_async(
        store, group, round, rank, grads, timeout, chunking,
    ))
}

/// Async worker push/pull — the state-machine form of
/// [`ps_sync_worker_chunked`]; identical keys and ordering.
pub async fn ps_sync_worker_async(
    store: &Arc<dyn ObjectStore>,
    group: &str,
    round: u64,
    rank: usize,
    grads: &mut [f32],
    timeout: Duration,
    chunking: Chunking,
) -> Result<()> {
    let chunks = chunk_ranges(0, grads.len(), chunking.chunk_elems());
    for (c, &(lo, hi)) in chunks.iter().enumerate() {
        store
            .put_async(
                &push_key(group, round, rank, c),
                f32s_to_bytes(&grads[lo..hi]),
            )
            .await
            .context("ps push")?;
    }
    for (c, &(lo, hi)) in chunks.iter().enumerate() {
        let merged = store
            .get_async(&merged_key(group, round, c), timeout)
            .await
            .context("ps pull")?;
        grads[lo..hi].copy_from_slice(&bytes_to_f32s(&merged));
    }
    Ok(())
}

/// Server side: gather `n` pushes, merge, publish. Returns the merged
/// gradient (the real PS would also apply the optimizer step here).
pub fn ps_sync_server(
    store: &Arc<dyn ObjectStore>,
    group: &str,
    round: u64,
    n: usize,
    len: usize,
    merge: Option<&MergeFn>,
    timeout: Duration,
) -> Result<Vec<f32>> {
    ps_sync_server_chunked(
        store,
        group,
        round,
        n,
        len,
        merge,
        timeout,
        Chunking::NONE,
    )
}

/// Chunked server: merges each push chunk-wise (consuming the pushes) and
/// publishes the merged result chunk-wise, so chunks become available to
/// workers as soon as every replica's copy of that range has arrived.
#[allow(clippy::too_many_arguments)]
pub fn ps_sync_server_chunked(
    store: &Arc<dyn ObjectStore>,
    group: &str,
    round: u64,
    n: usize,
    len: usize,
    merge: Option<&MergeFn>,
    timeout: Duration,
    chunking: Chunking,
) -> Result<Vec<f32>> {
    crate::exec::block_on(ps_sync_server_async(
        store, group, round, n, len, merge, timeout, chunking,
    ))
}

/// Async server gather/merge/publish — the state-machine form of
/// [`ps_sync_server_chunked`]; identical keys and ordering.
#[allow(clippy::too_many_arguments)]
pub async fn ps_sync_server_async(
    store: &Arc<dyn ObjectStore>,
    group: &str,
    round: u64,
    n: usize,
    len: usize,
    merge: Option<&MergeFn<'_>>,
    timeout: Duration,
    chunking: Chunking,
) -> Result<Vec<f32>> {
    let native: &MergeFn = &native_merge;
    let merge = merge.unwrap_or(native);
    let chunks = chunk_ranges(0, len, chunking.chunk_elems());
    let mut acc = vec![0.0f32; len];
    for (c, &(lo, hi)) in chunks.iter().enumerate() {
        for rank in 0..n {
            let key = push_key(group, round, rank, c);
            let bytes = store
                .get_async(&key, timeout)
                .await
                .context("ps gather")?;
            merge(&mut acc[lo..hi], &bytes_to_f32s(&bytes));
            store.delete(&key);
        }
        store
            .put_async(&merged_key(group, round, c), f32s_to_bytes(&acc[lo..hi]))
            .await
            .context("ps publish")?;
    }
    Ok(acc)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::MemStore;

    fn roundtrip(n: usize, len: usize, chunking: Chunking) -> Vec<Vec<f32>> {
        let store: Arc<dyn ObjectStore> = Arc::new(MemStore::new());
        let server = {
            let store = store.clone();
            std::thread::spawn(move || {
                ps_sync_server_chunked(
                    &store,
                    "g",
                    0,
                    n,
                    len,
                    None,
                    Duration::from_secs(10),
                    chunking,
                )
                .unwrap()
            })
        };
        let mut workers = Vec::new();
        for rank in 0..n {
            let store = store.clone();
            workers.push(std::thread::spawn(move || {
                let mut g = vec![(rank + 1) as f32; len];
                ps_sync_worker_chunked(
                    &store,
                    "g",
                    0,
                    rank,
                    &mut g,
                    Duration::from_secs(10),
                    chunking,
                )
                .unwrap();
                g
            }));
        }
        let mut out = vec![server.join().unwrap()];
        for w in workers {
            out.push(w.join().unwrap());
        }
        out
    }

    #[test]
    fn ps_roundtrip_sums_gradients() {
        let n = 5;
        let len = 33;
        let want = (1..=n).sum::<usize>() as f32;
        for res in roundtrip(n, len, Chunking::NONE) {
            assert!(res.iter().all(|&x| (x - want).abs() < 1e-5));
        }
    }

    #[test]
    fn chunked_ps_matches_unchunked() {
        let n = 3;
        let len = 103; // not chunk-aligned
        let plain = roundtrip(n, len, Chunking::NONE);
        for chunk_bytes in [16usize, 64, 1024] {
            let chunked = roundtrip(n, len, Chunking::new(chunk_bytes, 2));
            assert_eq!(plain, chunked, "chunk={chunk_bytes}");
        }
    }

    #[test]
    fn server_consumes_pushes() {
        let store: Arc<dyn ObjectStore> = Arc::new(MemStore::new());
        let server = {
            let store = store.clone();
            std::thread::spawn(move || {
                ps_sync_server(&store, "h", 1, 2, 4, None, Duration::from_secs(10)).unwrap()
            })
        };
        for rank in 0..2 {
            let store = store.clone();
            std::thread::spawn(move || {
                let mut g = vec![1.0f32; 4];
                ps_sync_worker(&store, "h", 1, rank, &mut g, Duration::from_secs(10)).unwrap();
            });
        }
        server.join().unwrap();
        assert!(store.list("h/ps/r1/push").is_empty());
    }
}
