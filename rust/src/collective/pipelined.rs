//! FuncPipe's **pipelined scatter-reduce** (§3.3, Fig. 4(b)) — the paper's
//! second contribution, real implementation over an [`ObjectStore`].
//!
//! The 3-phase algorithm wastes bandwidth because phase-1 uploads and
//! phase-2 downloads are serial; this version runs them in duplex:
//!
//! * step 1:          worker *i* uploads split *i+1*;
//! * step k (2..n−1): worker *i* uploads split *i+k* **while** downloading
//!                    split *i* uploaded by worker *i−(k−1)* at step k−1;
//! * step n:          worker *i* downloads split *i* from worker *i+1*.
//!
//! (indices mod n). Each worker then owns the fully-merged split *i* and
//! the final exchange (upload merged split, fetch the others) completes
//! the all-reduce. Transfer time drops from `3·s/w − 2s/(n·w)` to `2·s/w`
//! — eq. (1) vs eq. (2).
//!
//! Duplex is realized with a dedicated uploader thread per worker: uploads
//! of steps 1..n−1 are queued in order while the caller thread performs
//! the (blocking) downloads and merges, so uplink and downlink genuinely
//! overlap in the real path just as in the flow model.

use std::sync::mpsc;
use std::sync::Arc;
use std::time::Duration;

use anyhow::{Context, Result};

use super::scatter_reduce::{native_merge, MergeFn};
use super::{bytes_to_f32s, f32s_to_bytes, split_ranges};
use crate::platform::ObjectStore;

fn key(group: &str, round: u64, split: usize, from: usize) -> String {
    format!("{group}/r{round}/ps{split}/f{from}")
}

fn merged_key(group: &str, round: u64, split: usize) -> String {
    format!("{group}/r{round}/m{split}")
}

/// Pipelined scatter-reduce. Blocking; on return `grads` holds the
/// elementwise sum across all `n` replicas.
pub fn pipelined_scatter_reduce(
    store: &Arc<dyn ObjectStore>,
    group: &str,
    round: u64,
    rank: usize,
    n: usize,
    grads: &mut [f32],
    merge: Option<&MergeFn>,
    timeout: Duration,
) -> Result<()> {
    assert!(rank < n);
    if n == 1 {
        return Ok(());
    }
    let ranges = split_ranges(grads.len(), n);
    let native: &MergeFn = &native_merge;
    let merge = merge.unwrap_or(native);

    // Uploader thread: streams the n-1 uploads of steps 1..=n-1 in order,
    // concurrently with the downloads below (the duplex).
    let (tx, rx) = mpsc::channel::<(String, Vec<u8>)>();
    let up_store = store.clone();
    let uploader = std::thread::spawn(move || -> Result<()> {
        while let Ok((k, data)) = rx.recv() {
            up_store.put(&k, data).context("pipelined upload")?;
        }
        Ok(())
    });
    for k in 1..n {
        let split = (rank + k) % n;
        let (lo, hi) = ranges[split];
        tx.send((
            key(group, round, split, rank),
            f32s_to_bytes(&grads[lo..hi]),
        ))
        .expect("uploader alive");
    }
    drop(tx);

    // Downloads of steps 2..=n: merge foreign copies of our split while
    // the uploader drains.
    let (mylo, myhi) = ranges[rank];
    let mut merged = grads[mylo..myhi].to_vec();
    for k in 2..=n {
        let src = (rank + n - (k - 1)) % n;
        let bytes = store
            .get_blocking(&key(group, round, rank, src), timeout)
            .context("pipelined download")?;
        merge(&mut merged, &bytes_to_f32s(&bytes));
    }
    uploader
        .join()
        .expect("uploader panicked")
        .context("uploader failed")?;

    // Final exchange (same as phase 3 of the baseline).
    store
        .put(&merged_key(group, round, rank), f32s_to_bytes(&merged))
        .context("merged upload")?;
    grads[mylo..myhi].copy_from_slice(&merged);
    for j in 0..n {
        if j == rank {
            continue;
        }
        let bytes = store
            .get_blocking(&merged_key(group, round, j), timeout)
            .context("merged download")?;
        let (lo, hi) = ranges[j];
        grads[lo..hi].copy_from_slice(&bytes_to_f32s(&bytes));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::{MemStore, ThrottledStore};

    fn run_n(n: usize, len: usize) -> Vec<Vec<f32>> {
        let store: Arc<dyn ObjectStore> = Arc::new(MemStore::new());
        let mut handles = Vec::new();
        for rank in 0..n {
            let store = store.clone();
            handles.push(std::thread::spawn(move || {
                let mut grads: Vec<f32> =
                    (0..len).map(|i| ((rank + 1) * (i + 1)) as f32).collect();
                pipelined_scatter_reduce(
                    &store,
                    "pg",
                    0,
                    rank,
                    n,
                    &mut grads,
                    None,
                    Duration::from_secs(10),
                )
                .unwrap();
                grads
            }));
        }
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    }

    #[test]
    fn all_workers_get_the_sum() {
        for n in [2usize, 3, 5, 8] {
            let len = 97;
            let results = run_n(n, len);
            let expect: Vec<f32> = (0..len)
                .map(|i| {
                    (0..n).map(|r| ((r + 1) * (i + 1)) as f32).sum::<f32>()
                })
                .collect();
            for (r, res) in results.iter().enumerate() {
                assert_eq!(res, &expect, "rank {r} of n={n}");
            }
        }
    }

    #[test]
    fn agrees_with_plain_scatter_reduce() {
        use crate::collective::scatter_reduce::scatter_reduce;
        let n = 4;
        let len = 64;
        let mk = |rank: usize| -> Vec<f32> {
            (0..len).map(|i| ((rank * 31 + i * 7) % 13) as f32).collect()
        };
        let store_a: Arc<dyn ObjectStore> = Arc::new(MemStore::new());
        let store_b: Arc<dyn ObjectStore> = Arc::new(MemStore::new());
        let mut ha = Vec::new();
        let mut hb = Vec::new();
        for rank in 0..n {
            let (sa, sb) = (store_a.clone(), store_b.clone());
            let (ga, gb) = (mk(rank), mk(rank));
            ha.push(std::thread::spawn(move || {
                let mut g = ga;
                scatter_reduce(&sa, "a", 0, rank, n, &mut g, None, Duration::from_secs(10)).unwrap();
                g
            }));
            hb.push(std::thread::spawn(move || {
                let mut g = gb;
                pipelined_scatter_reduce(&sb, "b", 0, rank, n, &mut g, None, Duration::from_secs(10)).unwrap();
                g
            }));
        }
        let ra: Vec<_> = ha.into_iter().map(|h| h.join().unwrap()).collect();
        let rb: Vec<_> = hb.into_iter().map(|h| h.join().unwrap()).collect();
        assert_eq!(ra, rb);
    }

    /// The wall-clock benefit exists in the *real* implementation too:
    /// with symmetric per-worker throttling, duplex beats serial phases.
    #[test]
    fn pipelined_is_faster_on_throttled_store() {
        use crate::collective::scatter_reduce::scatter_reduce;
        let n = 4;
        let len = 40_000; // 160 KB per worker
        let bw = 2.0e6; // 2 MB/s each direction
        let run = |pipelined: bool| -> f64 {
            let inner: Arc<dyn ObjectStore> = Arc::new(MemStore::new());
            let start = std::time::Instant::now();
            let mut handles = Vec::new();
            for rank in 0..n {
                let store: Arc<dyn ObjectStore> = Arc::new(ThrottledStore::new(
                    inner.clone(),
                    bw,
                    bw,
                    Duration::from_millis(1),
                ));
                handles.push(std::thread::spawn(move || {
                    let mut g = vec![rank as f32; len];
                    if pipelined {
                        pipelined_scatter_reduce(&store, "t", 0, rank, n, &mut g, None, Duration::from_secs(30)).unwrap();
                    } else {
                        scatter_reduce(&store, "t", 0, rank, n, &mut g, None, Duration::from_secs(30)).unwrap();
                    }
                }));
            }
            for h in handles {
                h.join().unwrap();
            }
            start.elapsed().as_secs_f64()
        };
        let t_plain = run(false);
        let t_piped = run(true);
        assert!(
            t_piped < t_plain,
            "pipelined {t_piped:.3}s !< plain {t_plain:.3}s"
        );
    }
}
