//! FuncPipe's **pipelined scatter-reduce** (§3.3, Fig. 4(b)) — the paper's
//! second contribution, rebuilt on the unified chunked engine.
//!
//! The 3-phase algorithm wastes bandwidth because phase-1 uploads and
//! phase-2 downloads are serial; this version runs them in duplex:
//!
//! * step 1:          worker *i* uploads split *i+1*;
//! * step k (2..n−1): worker *i* uploads split *i+k* **while** downloading
//!                    split *i* uploaded by worker *i−(k−1)* at step k−1;
//! * step n:          worker *i* downloads split *i* from worker *i+1*.
//!
//! (indices mod n). Each worker then owns the fully-merged split *i* and
//! the final exchange (upload merged split, fetch the others) completes
//! the all-reduce. Transfer time drops from `3·s/w − 2s/(n·w)` to `2·s/w`
//! — eq. (1) vs eq. (2).
//!
//! Duplex runs on the context's persistent [`flow::FlowPool`]: uploads
//! stream chunk-wise through the uploader task while this state machine
//! merges the downloads the downloader prefetches, so uplink and downlink
//! genuinely overlap in the real path just as in the flow model — now at
//! *chunk* granularity.
//!
//! With chunking enabled the engine also bounds storage occupancy: every
//! consumed chunk is deleted (reduce phase) or ack-counted and deleted by
//! its producer (merged-split broadcast), and the uploader window-gates
//! chunk `q` on the consumption of chunk `q − in_flight`, capping the
//! store's high-water mark at `n × in_flight × chunk_bytes` plus epsilon.
//!
//! [`flow::FlowPool`]: super::flow::FlowPool

use std::sync::Arc;
use std::time::Duration;

use anyhow::{Context, Result};

use super::flow::{Gate, PutJob};
use super::{
    ack_key, bytes_to_f32s, f32s_to_bytes, merged_chunk_key, native_merge,
    split_ranges, ChunkPlan, Chunking, Collective, CollectiveCtx,
    CollectiveFuture, MergeFn,
};
use crate::exec::block_on;
use crate::platform::ObjectStore;

pub(crate) fn reduce_key(
    group: &str,
    round: u64,
    split: usize,
    from: usize,
    chunk: usize,
) -> String {
    format!("{group}/r{round}/ps/s{split}/f{from}/c{chunk}")
}

/// What one planned upload carries and who must acknowledge it.
struct Planned {
    key: String,
    /// Element range, absolute in `grads` coords (reduce phase) or
    /// relative to the merged buffer (broadcast phase).
    lo: usize,
    hi: usize,
    /// Consumer ranks whose acks close this chunk's window slot.
    ackers: Vec<usize>,
    /// Broadcast chunks are deleted by the producer once all acks are in;
    /// reduce chunks are deleted by their single consumer.
    broadcast: bool,
}

/// One expected incoming chunk of a download stream.
struct Incoming {
    key: String,
    lo: usize,
    hi: usize,
    producer: usize,
    seq: usize,
}

/// FuncPipe's pipelined scatter-reduce on the unified engine.
pub struct PipelinedScatterReduce;

impl Collective for PipelinedScatterReduce {
    fn name(&self) -> &'static str {
        "pipelined-scatter-reduce"
    }

    fn all_reduce<'a>(
        &'a self,
        ctx: &'a CollectiveCtx,
        round: u64,
        grads: &'a mut [f32],
        merge: Option<&'a MergeFn<'a>>,
    ) -> CollectiveFuture<'a> {
        Box::pin(run(ctx, round, grads, merge))
    }
}

async fn run(
    ctx: &CollectiveCtx,
    round: u64,
    grads: &mut [f32],
    merge: Option<&MergeFn<'_>>,
) -> Result<()> {
    let (n, rank) = (ctx.n, ctx.rank);
    if n == 1 {
        return Ok(());
    }
    let native: &MergeFn = &native_merge;
    let merge = merge.unwrap_or(native);
    let ranges = split_ranges(grads.len(), n);
    let plan = ChunkPlan::new(&ranges, &ctx.chunking);
    let windowed = ctx.chunking.is_chunked();
    let window = ctx.pool().in_flight();
    let group = ctx.group.as_str();
    let pool = ctx.pool();
    let (mylo, myhi) = ranges[rank];

    // ---- the full upload plan: reduce steps, then the broadcast ----
    let mut planned: Vec<Planned> = Vec::new();
    for k in 1..n {
        let split = (rank + k) % n;
        for (c, &(lo, hi)) in plan.chunks[split].iter().enumerate() {
            planned.push(Planned {
                key: reduce_key(group, round, split, rank, c),
                lo,
                hi,
                ackers: vec![split],
                broadcast: false,
            });
        }
    }
    let n_reduce = planned.len();
    debug_assert_eq!(n_reduce, plan.total_reduce(rank, n));
    for (c, &(lo, hi)) in plan.chunks[rank].iter().enumerate() {
        planned.push(Planned {
            key: merged_chunk_key(group, round, rank, c),
            lo: lo - mylo,
            hi: hi - mylo,
            ackers: (0..n).filter(|&d| d != rank).collect(),
            broadcast: true,
        });
    }

    // window gate for planned[q]: wait until chunk q-W was consumed
    let gate_for = |q: usize| -> Option<Gate> {
        if !windowed || q < window {
            return None;
        }
        let p = &planned[q - window];
        Some(Gate {
            wait_acks: p
                .ackers
                .iter()
                .map(|&d| ack_key(group, round, rank, q - window, d))
                .collect(),
            delete_after: p.broadcast.then(|| p.key.clone()),
            timeout: ctx.timeout,
        })
    };
    // one planned upload, serialized lazily from `data` (the gradient
    // during the reduce phase, the merged buffer during broadcast)
    let job_for = |q: usize, data: &[f32]| -> PutJob {
        let p = &planned[q];
        PutJob {
            key: p.key.clone(),
            data: f32s_to_bytes(&data[p.lo..p.hi]),
            gate: gate_for(q),
        }
    };
    // fill the upload window without ever suspending: the acks a gate
    // waits on may be ours to produce via the download loop
    let fill = |data: &[f32],
                limit: usize,
                next_put: &mut usize,
                parked: &mut Option<PutJob>| {
        loop {
            let job = match parked.take() {
                Some(j) => j,
                None if *next_put < limit => {
                    let j = job_for(*next_put, data);
                    *next_put += 1;
                    j
                }
                None => return,
            };
            if let Err(j) = pool.try_put(job) {
                *parked = Some(j);
                return;
            }
        }
    };

    // ---- reduce phase: stream uploads while merging our own split --
    let mut merged = grads[mylo..myhi].to_vec();
    let mut incoming: Vec<Incoming> = Vec::new();
    for k in 2..=n {
        let src = (rank + n - (k - 1)) % n;
        let base = plan.reduce_seq_base(src, rank, n);
        for (c, &(lo, hi)) in plan.chunks[rank].iter().enumerate() {
            incoming.push(Incoming {
                key: reduce_key(group, round, rank, src, c),
                lo,
                hi,
                producer: src,
                seq: base + c,
            });
        }
    }
    let mut rx = pool.stream(
        incoming.iter().map(|i| i.key.clone()).collect(),
        ctx.timeout,
    );
    let mut next_put = 0usize;
    let mut parked: Option<PutJob> = None;
    for inc in &incoming {
        fill(grads, n_reduce, &mut next_put, &mut parked);
        let bytes = rx.recv().await.context("reduce stream closed")??;
        merge(
            &mut merged[inc.lo - mylo..inc.hi - mylo],
            &bytes_to_f32s(&bytes),
        );
        ctx.store.delete(&inc.key); // single reader: consume
        if windowed {
            ctx.store
                .put_async(
                    &ack_key(group, round, inc.producer, inc.seq, rank),
                    Vec::new(),
                )
                .await
                .context("reduce ack")?;
        }
    }
    // after our own downloads are done, suspending on the window is safe:
    // the gates' acks come from other, still-active consumers
    if let Some(j) = parked.take() {
        pool.put(j).await?;
    }
    while next_put < n_reduce {
        pool.put(job_for(next_put, grads)).await?;
        next_put += 1;
    }

    // ---- broadcast phase: publish merged chunks, gather the rest ---
    grads[mylo..myhi].copy_from_slice(&merged);
    let mut incoming: Vec<Incoming> = Vec::new();
    for j in 0..n {
        if j == rank {
            continue;
        }
        let base = plan.total_reduce(j, n);
        for (c, &(lo, hi)) in plan.chunks[j].iter().enumerate() {
            incoming.push(Incoming {
                key: merged_chunk_key(group, round, j, c),
                lo,
                hi,
                producer: j,
                seq: base + c,
            });
        }
    }
    let mut rx = pool.stream(
        incoming.iter().map(|i| i.key.clone()).collect(),
        ctx.timeout,
    );
    for inc in &incoming {
        fill(&merged, planned.len(), &mut next_put, &mut parked);
        let bytes = rx.recv().await.context("broadcast stream closed")??;
        grads[inc.lo..inc.hi].copy_from_slice(&bytes_to_f32s(&bytes));
        if windowed {
            ctx.store
                .put_async(
                    &ack_key(group, round, inc.producer, inc.seq, rank),
                    Vec::new(),
                )
                .await
                .context("broadcast ack")?;
        }
    }
    if let Some(j) = parked.take() {
        pool.put(j).await?;
    }
    while next_put < planned.len() {
        pool.put(job_for(next_put, &merged)).await?;
        next_put += 1;
    }
    pool.flush().await.context("upload flush")?;

    // ---- close the window tail: collect outstanding acks ----------
    if windowed {
        let tail = planned.len().saturating_sub(window);
        for (q, p) in planned.iter().enumerate().skip(tail) {
            for &d in &p.ackers {
                let key = ack_key(group, round, rank, q, d);
                ctx.store
                    .get_async(&key, ctx.timeout)
                    .await
                    .context("tail ack")?;
                ctx.store.delete(&key);
            }
            if p.broadcast {
                ctx.store.delete(&p.key);
            }
        }
    }
    ctx.mark_done(round).await
}

/// Pipelined scatter-reduce. Blocking; on return `grads` holds the
/// elementwise sum across all `n` replicas.
pub fn pipelined_scatter_reduce(
    store: &Arc<dyn ObjectStore>,
    group: &str,
    round: u64,
    rank: usize,
    n: usize,
    grads: &mut [f32],
    merge: Option<&MergeFn>,
    timeout: Duration,
) -> Result<()> {
    pipelined_scatter_reduce_chunked(
        store,
        group,
        round,
        rank,
        n,
        grads,
        merge,
        timeout,
        Chunking::NONE,
    )
}

/// Chunked variant: duplex at chunk granularity with a bounded in-flight
/// window (see the module docs for the storage-occupancy guarantee).
#[allow(clippy::too_many_arguments)]
pub fn pipelined_scatter_reduce_chunked(
    store: &Arc<dyn ObjectStore>,
    group: &str,
    round: u64,
    rank: usize,
    n: usize,
    grads: &mut [f32],
    merge: Option<&MergeFn>,
    timeout: Duration,
    chunking: Chunking,
) -> Result<()> {
    let ctx = CollectiveCtx::new(store.clone(), group, rank, n, timeout)
        .with_chunking(chunking);
    block_on(run(&ctx, round, grads, merge))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::{MemStore, ThrottledStore};

    fn run_n(n: usize, len: usize, chunking: Chunking) -> Vec<Vec<f32>> {
        let store: Arc<dyn ObjectStore> = Arc::new(MemStore::new());
        let mut handles = Vec::new();
        for rank in 0..n {
            let store = store.clone();
            handles.push(std::thread::spawn(move || {
                let mut grads: Vec<f32> =
                    (0..len).map(|i| ((rank + 1) * (i + 1)) as f32).collect();
                pipelined_scatter_reduce_chunked(
                    &store,
                    "pg",
                    0,
                    rank,
                    n,
                    &mut grads,
                    None,
                    Duration::from_secs(10),
                    chunking,
                )
                .unwrap();
                grads
            }));
        }
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    }

    #[test]
    fn all_workers_get_the_sum() {
        for n in [2usize, 3, 5, 8] {
            let len = 97;
            let results = run_n(n, len, Chunking::NONE);
            let expect: Vec<f32> = (0..len)
                .map(|i| {
                    (0..n).map(|r| ((r + 1) * (i + 1)) as f32).sum::<f32>()
                })
                .collect();
            for (r, res) in results.iter().enumerate() {
                assert_eq!(res, &expect, "rank {r} of n={n}");
            }
        }
    }

    #[test]
    fn chunked_matches_unchunked() {
        for n in [2usize, 4, 6] {
            let len = 10_000 + n; // uneven split sizes
            let plain = run_n(n, len, Chunking::NONE);
            for (chunk_bytes, in_flight) in [(64usize, 1), (256, 3), (4096, 8)]
            {
                let chunked =
                    run_n(n, len, Chunking::new(chunk_bytes, in_flight));
                assert_eq!(
                    plain, chunked,
                    "n={n} chunk={chunk_bytes} w={in_flight}"
                );
            }
        }
    }

    #[test]
    fn agrees_with_plain_scatter_reduce() {
        use crate::collective::scatter_reduce::scatter_reduce;
        let n = 4;
        let len = 64;
        let mk = |rank: usize| -> Vec<f32> {
            (0..len).map(|i| ((rank * 31 + i * 7) % 13) as f32).collect()
        };
        let store_a: Arc<dyn ObjectStore> = Arc::new(MemStore::new());
        let store_b: Arc<dyn ObjectStore> = Arc::new(MemStore::new());
        let mut ha = Vec::new();
        let mut hb = Vec::new();
        for rank in 0..n {
            let (sa, sb) = (store_a.clone(), store_b.clone());
            let (ga, gb) = (mk(rank), mk(rank));
            ha.push(std::thread::spawn(move || {
                let mut g = ga;
                scatter_reduce(
                    &sa,
                    "a",
                    0,
                    rank,
                    n,
                    &mut g,
                    None,
                    Duration::from_secs(10),
                )
                .unwrap();
                g
            }));
            hb.push(std::thread::spawn(move || {
                let mut g = gb;
                pipelined_scatter_reduce(
                    &sb,
                    "b",
                    0,
                    rank,
                    n,
                    &mut g,
                    None,
                    Duration::from_secs(10),
                )
                .unwrap();
                g
            }));
        }
        let ra: Vec<_> = ha.into_iter().map(|h| h.join().unwrap()).collect();
        let rb: Vec<_> = hb.into_iter().map(|h| h.join().unwrap()).collect();
        assert_eq!(ra, rb);
    }

    /// With chunking, consumed chunks are deleted and the uploader windows
    /// on acks, so the store's high-water mark stays within the chunk
    /// budget; the unchunked run (whole splits + retained merged splits)
    /// blows straight through it.
    #[test]
    fn chunked_run_bounds_store_high_water_mark() {
        let n = 4;
        let len = 4096 * n; // 64 KB of gradient per worker
        let chunk_bytes = 1024;
        let in_flight = 2;
        let run = |chunking: Chunking| -> u64 {
            let store: Arc<dyn ObjectStore> = Arc::new(MemStore::new());
            let handles: Vec<_> = (0..n)
                .map(|rank| {
                    let store = store.clone();
                    std::thread::spawn(move || {
                        let mut g = vec![rank as f32 + 0.5; len];
                        pipelined_scatter_reduce_chunked(
                            &store, "hw", 0, rank, n, &mut g, None,
                            Duration::from_secs(30), chunking,
                        )
                        .unwrap();
                    })
                })
                .collect();
            for h in handles {
                h.join().unwrap();
            }
            store.high_water_bytes()
        };
        // budget: every worker may have at most `in_flight` un-consumed
        // chunks alive, plus one chunk mid-upload each
        let budget = (n * (in_flight + 1) * chunk_bytes) as u64;
        let hwm_chunked = run(Chunking::new(chunk_bytes, in_flight));
        assert!(
            hwm_chunked <= budget,
            "chunked HWM {hwm_chunked} exceeds budget {budget}"
        );
        let hwm_plain = run(Chunking::NONE);
        assert!(
            hwm_plain > budget,
            "unchunked HWM {hwm_plain} unexpectedly under budget {budget}"
        );
    }

    /// The wall-clock benefit exists in the *real* implementation too:
    /// with symmetric per-worker throttling, duplex beats serial phases.
    /// De-flaked: best-of-3 per variant with a tolerance margin, so a
    /// single descheduled thread cannot fail CI; the deterministic version
    /// of this property lives in the FlowSim tests
    /// (`sim::pipelined_beats_plain_in_sim`).
    #[test]
    fn pipelined_is_faster_on_throttled_store() {
        use crate::collective::scatter_reduce::scatter_reduce;
        let n = 4;
        let len = 40_000; // 160 KB per worker
        let bw = 2.0e6; // 2 MB/s each direction
        let run = |pipelined: bool| -> f64 {
            let inner: Arc<dyn ObjectStore> = Arc::new(MemStore::new());
            let start = std::time::Instant::now();
            let mut handles = Vec::new();
            for rank in 0..n {
                let store: Arc<dyn ObjectStore> = Arc::new(ThrottledStore::new(
                    inner.clone(),
                    bw,
                    bw,
                    Duration::from_millis(1),
                ));
                handles.push(std::thread::spawn(move || {
                    let mut g = vec![rank as f32; len];
                    let timeout = Duration::from_secs(30);
                    if pipelined {
                        pipelined_scatter_reduce(
                            &store, "t", 0, rank, n, &mut g, None, timeout,
                        )
                        .unwrap();
                    } else {
                        scatter_reduce(
                            &store, "t", 0, rank, n, &mut g, None, timeout,
                        )
                        .unwrap();
                    }
                }));
            }
            for h in handles {
                h.join().unwrap();
            }
            start.elapsed().as_secs_f64()
        };
        // structural gap at n=4 is (3-2/4)/2 = 1.25x; require at least a
        // 3% win on best-of-3 so the test still catches duplex breaking
        // (ratio -> 1.0) while scheduler noise on the min cannot flip a
        // 25% gap
        let best = |pipelined: bool| {
            (0..3).map(|_| run(pipelined)).fold(f64::INFINITY, f64::min)
        };
        let t_plain = best(false);
        let t_piped = best(true);
        assert!(
            t_piped < t_plain * 0.97,
            "pipelined {t_piped:.3}s not meaningfully faster than plain \
             {t_plain:.3}s"
        );
    }
}
