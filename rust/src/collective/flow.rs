//! The multi-flow transfer engine: one persistent uploader thread and one
//! persistent downloader thread per worker, shared by every collective
//! call on a [`CollectiveCtx`](super::CollectiveCtx) and reused across
//! rounds — the paper's duplex insight (§3.3) realized as a reusable flow
//! pool instead of the original per-call `mpsc` + `thread::spawn`.
//!
//! * **Uploads** are queued on a bounded channel whose capacity equals
//!   the in-flight window, so at most `in_flight` serialized chunks are
//!   resident on the producer side at any time. A job may carry a
//!   [`Gate`]: the uploader then first waits for the ack objects of an
//!   earlier chunk (the sliding window that bounds the *store's*
//!   occupancy) and deletes a broadcast chunk whose readers have all
//!   acked.
//! * **Downloads** are requested as ordered key streams; the downloader
//!   prefetches up to `in_flight` chunks ahead of the consumer through a
//!   bounded result channel.
//!
//! Both threads exit when the pool is dropped.

use std::sync::mpsc::{self, Receiver, SyncSender, TrySendError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{anyhow, Context, Result};

use crate::platform::ObjectStore;

/// Window gate executed by the uploader *before* its `put`: wait until
/// every listed ack object exists (consuming them), then optionally
/// delete an earlier broadcast chunk whose readers have now all acked.
pub(crate) struct Gate {
    pub wait_acks: Vec<String>,
    pub delete_after: Option<String>,
    pub timeout: Duration,
}

/// One upload job: serialized chunk plus its optional window gate.
pub(crate) struct PutJob {
    pub key: String,
    pub data: Vec<u8>,
    pub gate: Option<Gate>,
}

enum UpJob {
    Put(PutJob),
    Flush(SyncSender<Result<()>>),
}

struct DownStream {
    keys: Vec<String>,
    timeout: Duration,
    out: SyncSender<Result<Arc<Vec<u8>>>>,
}

/// The reusable per-worker flow pool.
pub(crate) struct FlowPool {
    up_tx: Option<SyncSender<UpJob>>,
    down_tx: Option<SyncSender<DownStream>>,
    uploader: Option<JoinHandle<()>>,
    downloader: Option<JoinHandle<()>>,
    in_flight: usize,
}

impl FlowPool {
    pub fn new(store: Arc<dyn ObjectStore>, in_flight: usize) -> Self {
        let in_flight = in_flight.max(1);
        let (up_tx, up_rx) = mpsc::sync_channel::<UpJob>(in_flight);
        let (down_tx, down_rx) = mpsc::sync_channel::<DownStream>(2);

        let up_store = store.clone();
        let uploader = std::thread::Builder::new()
            .name("flow-uploader".into())
            .spawn(move || {
                let mut failed: Option<anyhow::Error> = None;
                while let Ok(job) = up_rx.recv() {
                    match job {
                        UpJob::Put(put) => {
                            if failed.is_some() {
                                continue; // drain; error surfaces on flush
                            }
                            if let Err(e) = run_put(&up_store, put) {
                                failed = Some(e);
                            }
                        }
                        UpJob::Flush(reply) => {
                            let res = match failed.take() {
                                Some(e) => Err(e),
                                None => Ok(()),
                            };
                            let _ = reply.send(res);
                        }
                    }
                }
            })
            .expect("spawn uploader");

        let downloader = std::thread::Builder::new()
            .name("flow-downloader".into())
            .spawn(move || {
                while let Ok(stream) = down_rx.recv() {
                    for key in &stream.keys {
                        match store.get_blocking(key, stream.timeout) {
                            Ok(bytes) => {
                                if stream.out.send(Ok(bytes)).is_err() {
                                    break; // consumer gone
                                }
                            }
                            Err(e) => {
                                let _ = stream.out.send(Err(
                                    e.context(format!("downloading {key}")),
                                ));
                                break;
                            }
                        }
                    }
                }
            })
            .expect("spawn downloader");

        Self {
            up_tx: Some(up_tx),
            down_tx: Some(down_tx),
            uploader: Some(uploader),
            downloader: Some(downloader),
            in_flight,
        }
    }

    pub fn in_flight(&self) -> usize {
        self.in_flight
    }

    /// Queue an upload, blocking if the window is full. Only safe when
    /// the uploader cannot be gate-blocked on an ack *this* thread would
    /// produce (plain phases; post-download tails).
    pub fn put_blocking(&self, job: PutJob) -> Result<()> {
        self.up_tx
            .as_ref()
            .expect("pool alive")
            .send(UpJob::Put(job))
            .map_err(|_| anyhow!("uploader thread gone"))
    }

    /// Non-blocking queue attempt; hands the job back when the window is
    /// full so the caller can make download progress first.
    pub fn try_put(&self, job: PutJob) -> std::result::Result<(), PutJob> {
        match self
            .up_tx
            .as_ref()
            .expect("pool alive")
            .try_send(UpJob::Put(job))
        {
            Ok(()) => Ok(()),
            Err(TrySendError::Full(UpJob::Put(j))) => Err(j),
            Err(TrySendError::Disconnected(UpJob::Put(j))) => Err(j),
            Err(_) => unreachable!("only Put jobs are tried"),
        }
    }

    /// Wait for every queued upload to finish; returns the first error.
    pub fn flush(&self) -> Result<()> {
        let (tx, rx) = mpsc::sync_channel(1);
        self.up_tx
            .as_ref()
            .expect("pool alive")
            .send(UpJob::Flush(tx))
            .map_err(|_| anyhow!("uploader thread gone"))?;
        rx.recv().context("uploader thread gone")?
    }

    /// Start an ordered download stream; chunks arrive on the returned
    /// receiver with an `in_flight`-deep prefetch window.
    pub fn stream(
        &self,
        keys: Vec<String>,
        timeout: Duration,
    ) -> Receiver<Result<Arc<Vec<u8>>>> {
        let (tx, rx) = mpsc::sync_channel(self.in_flight);
        let _ = self
            .down_tx
            .as_ref()
            .expect("pool alive")
            .send(DownStream { keys, timeout, out: tx });
        rx
    }
}

fn run_put(store: &Arc<dyn ObjectStore>, put: PutJob) -> Result<()> {
    if let Some(gate) = put.gate {
        for ack in &gate.wait_acks {
            store
                .get_blocking(ack, gate.timeout)
                .with_context(|| format!("window gate on {ack}"))?;
            store.delete(ack);
        }
        if let Some(spent) = &gate.delete_after {
            store.delete(spent);
        }
    }
    store.put(&put.key, put.data).context("chunk upload")
}

impl Drop for FlowPool {
    fn drop(&mut self) {
        // closing the channels ends both loops
        drop(self.up_tx.take());
        drop(self.down_tx.take());
        if let Some(h) = self.uploader.take() {
            let _ = h.join();
        }
        if let Some(h) = self.downloader.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::MemStore;

    fn mem() -> Arc<dyn ObjectStore> {
        Arc::new(MemStore::new())
    }

    #[test]
    fn uploads_land_and_flush_reports_ok() {
        let store = mem();
        let pool = FlowPool::new(store.clone(), 2);
        for i in 0..5 {
            pool.put_blocking(PutJob {
                key: format!("k/{i}"),
                data: vec![i as u8; 3],
                gate: None,
            })
            .unwrap();
        }
        pool.flush().unwrap();
        assert_eq!(store.list("k/").len(), 5);
    }

    #[test]
    fn stream_preserves_order() {
        let store = mem();
        let pool = FlowPool::new(store.clone(), 2);
        for i in 0..6 {
            store.put(&format!("s/{i}"), vec![i as u8]).unwrap();
        }
        let keys: Vec<String> = (0..6).map(|i| format!("s/{i}")).collect();
        let rx = pool.stream(keys, Duration::from_secs(5));
        for i in 0..6 {
            let b = rx.recv().unwrap().unwrap();
            assert_eq!(*b, vec![i as u8]);
        }
    }

    #[test]
    fn gate_blocks_until_ack_exists() {
        let store = mem();
        let pool = FlowPool::new(store.clone(), 1);
        pool.put_blocking(PutJob {
            key: "gated".into(),
            data: vec![1],
            gate: Some(Gate {
                wait_acks: vec!["ack/0".into()],
                delete_after: Some("old-chunk".into()),
                timeout: Duration::from_secs(5),
            }),
        })
        .unwrap();
        store.put("old-chunk", vec![9, 9]).unwrap();
        assert!(store.get("gated").is_none(), "gate should hold the put");
        store.put("ack/0", Vec::new()).unwrap();
        pool.flush().unwrap();
        assert!(store.get("gated").is_some());
        assert!(store.get("ack/0").is_none(), "ack consumed");
        assert!(store.get("old-chunk").is_none(), "spent chunk deleted");
    }

    #[test]
    fn upload_errors_surface_on_flush() {
        let store = mem();
        let pool = FlowPool::new(store.clone(), 1);
        pool.put_blocking(PutJob {
            key: "x".into(),
            data: vec![],
            gate: Some(Gate {
                wait_acks: vec!["never".into()],
                delete_after: None,
                timeout: Duration::from_millis(30),
            }),
        })
        .unwrap();
        assert!(pool.flush().is_err());
        // pool stays usable after an error
        pool.put_blocking(PutJob { key: "y".into(), data: vec![1], gate: None })
            .unwrap();
        pool.flush().unwrap();
        assert!(store.get("y").is_some());
    }

    #[test]
    fn stream_propagates_timeout_error() {
        let store = mem();
        let pool = FlowPool::new(store, 1);
        let rx =
            pool.stream(vec!["missing".into()], Duration::from_millis(30));
        assert!(rx.recv().unwrap().is_err());
    }
}
