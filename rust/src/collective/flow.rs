//! The multi-flow transfer engine: one persistent uploader *task* per
//! pool plus per-stream downloader tasks, shared by every collective
//! call on a [`CollectiveCtx`](super::CollectiveCtx) and reused across
//! rounds — the paper's duplex insight (§3.3). Historically this was a
//! pair of dedicated OS threads per worker; the pool is now a set of
//! state machines on the shared bounded executor ([`crate::exec`]), so
//! dp=1024 costs tasks, not threads.
//!
//! * **Uploads** are queued on a bounded channel whose capacity equals
//!   the in-flight window, so at most `in_flight` serialized chunks are
//!   resident on the producer side at any time. A job may carry a
//!   [`Gate`]: the uploader then first waits for the ack objects of an
//!   earlier chunk (the sliding window that bounds the *store's*
//!   occupancy) and deletes a broadcast chunk whose readers have all
//!   acked.
//! * **Downloads** are requested as ordered key streams; each stream's
//!   task prefetches up to `in_flight` chunks ahead of the consumer
//!   through a bounded result channel.
//!
//! The uploader task exits when the pool is dropped (after draining its
//! queue); stream tasks exit when their keys are exhausted or their
//! consumer is gone.

use std::sync::Arc;
use std::time::Duration;

use anyhow::{anyhow, Context, Result};

use crate::exec;
use crate::exec::sync::{channel, oneshot, Receiver, TrySendError};
use crate::platform::ObjectStore;

/// Window gate executed by the uploader *before* its `put`: wait until
/// every listed ack object exists (consuming them), then optionally
/// delete an earlier broadcast chunk whose readers have now all acked.
pub(crate) struct Gate {
    pub wait_acks: Vec<String>,
    pub delete_after: Option<String>,
    pub timeout: Duration,
}

/// One upload job: serialized chunk plus its optional window gate.
pub(crate) struct PutJob {
    pub key: String,
    pub data: Vec<u8>,
    pub gate: Option<Gate>,
}

enum UpJob {
    Put(PutJob),
    Flush(exec::sync::OnceSender<Result<()>>),
}

/// The reusable per-worker flow pool.
pub(crate) struct FlowPool {
    up_tx: Option<exec::sync::Sender<UpJob>>,
    store: Arc<dyn ObjectStore>,
    in_flight: usize,
}

impl FlowPool {
    pub fn new(store: Arc<dyn ObjectStore>, in_flight: usize) -> Self {
        let in_flight = in_flight.max(1);
        let (up_tx, mut up_rx) = channel::<UpJob>(in_flight);

        let up_store = store.clone();
        exec::spawn(async move {
            let mut failed: Option<anyhow::Error> = None;
            while let Some(job) = up_rx.recv().await {
                match job {
                    UpJob::Put(put) => {
                        if failed.is_some() {
                            continue; // drain; error surfaces on flush
                        }
                        if let Err(e) = run_put(&up_store, put).await {
                            failed = Some(e);
                        }
                    }
                    UpJob::Flush(reply) => {
                        let res = match failed.take() {
                            Some(e) => Err(e),
                            None => Ok(()),
                        };
                        reply.send(res);
                    }
                }
            }
        });

        Self { up_tx: Some(up_tx), store, in_flight }
    }

    pub fn in_flight(&self) -> usize {
        self.in_flight
    }

    /// Queue an upload, waiting if the window is full. Only safe when
    /// the uploader cannot be gate-blocked on an ack *this* state
    /// machine would produce (plain phases; post-download tails).
    pub async fn put(&self, job: PutJob) -> Result<()> {
        self.up_tx
            .as_ref()
            .expect("pool alive")
            .send(UpJob::Put(job))
            .await
            .map_err(|_| anyhow!("uploader thread gone"))
    }

    /// Non-blocking queue attempt; hands the job back when the window is
    /// full so the caller can make download progress first.
    pub fn try_put(&self, job: PutJob) -> std::result::Result<(), PutJob> {
        match self
            .up_tx
            .as_ref()
            .expect("pool alive")
            .try_send(UpJob::Put(job))
        {
            Ok(()) => Ok(()),
            Err(TrySendError::Full(UpJob::Put(j)))
            | Err(TrySendError::Disconnected(UpJob::Put(j))) => Err(j),
            Err(_) => unreachable!("only Put jobs are tried"),
        }
    }

    /// Wait for every queued upload to finish; returns the first error.
    pub async fn flush(&self) -> Result<()> {
        let (tx, rx) = oneshot();
        self.up_tx
            .as_ref()
            .expect("pool alive")
            .send(UpJob::Flush(tx))
            .await
            .map_err(|_| anyhow!("uploader thread gone"))?;
        rx.await.map_err(|_| anyhow!("uploader thread gone"))?
    }

    /// Start an ordered download stream; chunks arrive on the returned
    /// receiver with an `in_flight`-deep prefetch window.
    pub fn stream(
        &self,
        keys: Vec<String>,
        timeout: Duration,
    ) -> Receiver<Result<Arc<Vec<u8>>>> {
        let (tx, rx) = channel(self.in_flight);
        let store = self.store.clone();
        exec::spawn(async move {
            for key in &keys {
                match store.get_async(key, timeout).await {
                    Ok(bytes) => {
                        if tx.send(Ok(bytes)).await.is_err() {
                            break; // consumer gone
                        }
                    }
                    Err(e) => {
                        let _ = tx
                            .send(Err(e.context(format!("downloading {key}"))))
                            .await;
                        break;
                    }
                }
            }
        });
        rx
    }
}

async fn run_put(store: &Arc<dyn ObjectStore>, put: PutJob) -> Result<()> {
    if let Some(gate) = put.gate {
        for ack in &gate.wait_acks {
            store
                .get_async(ack, gate.timeout)
                .await
                .with_context(|| format!("window gate on {ack}"))?;
            store.delete(ack);
        }
        if let Some(spent) = &gate.delete_after {
            store.delete(spent);
        }
    }
    store.put_async(&put.key, put.data).await.context("chunk upload")
}

impl Drop for FlowPool {
    fn drop(&mut self) {
        // closing the channel ends the uploader task once it drains;
        // callers that need completion ordering flush first (all the
        // collective algorithms do)
        drop(self.up_tx.take());
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::block_on;
    use crate::platform::MemStore;

    fn mem() -> Arc<dyn ObjectStore> {
        Arc::new(MemStore::new())
    }

    #[test]
    fn uploads_land_and_flush_reports_ok() {
        let store = mem();
        let pool = FlowPool::new(store.clone(), 2);
        block_on(async {
            for i in 0..5 {
                pool.put(PutJob {
                    key: format!("k/{i}"),
                    data: vec![i as u8; 3],
                    gate: None,
                })
                .await
                .unwrap();
            }
            pool.flush().await.unwrap();
        });
        assert_eq!(store.list("k/").len(), 5);
    }

    #[test]
    fn stream_preserves_order() {
        let store = mem();
        let pool = FlowPool::new(store.clone(), 2);
        for i in 0..6 {
            store.put(&format!("s/{i}"), vec![i as u8]).unwrap();
        }
        let keys: Vec<String> = (0..6).map(|i| format!("s/{i}")).collect();
        let mut rx = pool.stream(keys, Duration::from_secs(5));
        block_on(async {
            for i in 0..6 {
                let b = rx.recv().await.unwrap().unwrap();
                assert_eq!(*b, vec![i as u8]);
            }
        });
    }

    #[test]
    fn gate_blocks_until_ack_exists() {
        let store = mem();
        let pool = FlowPool::new(store.clone(), 1);
        block_on(pool.put(PutJob {
            key: "gated".into(),
            data: vec![1],
            gate: Some(Gate {
                wait_acks: vec!["ack/0".into()],
                delete_after: Some("old-chunk".into()),
                timeout: Duration::from_secs(5),
            }),
        }))
        .unwrap();
        store.put("old-chunk", vec![9, 9]).unwrap();
        assert!(store.get("gated").is_none(), "gate should hold the put");
        store.put("ack/0", Vec::new()).unwrap();
        block_on(pool.flush()).unwrap();
        assert!(store.get("gated").is_some());
        assert!(store.get("ack/0").is_none(), "ack consumed");
        assert!(store.get("old-chunk").is_none(), "spent chunk deleted");
    }

    #[test]
    fn upload_errors_surface_on_flush() {
        let store = mem();
        let pool = FlowPool::new(store.clone(), 1);
        block_on(pool.put(PutJob {
            key: "x".into(),
            data: vec![],
            gate: Some(Gate {
                wait_acks: vec!["never".into()],
                delete_after: None,
                timeout: Duration::from_millis(30),
            }),
        }))
        .unwrap();
        assert!(block_on(pool.flush()).is_err());
        // pool stays usable after an error
        block_on(pool.put(PutJob { key: "y".into(), data: vec![1], gate: None }))
            .unwrap();
        block_on(pool.flush()).unwrap();
        assert!(store.get("y").is_some());
    }

    #[test]
    fn stream_propagates_timeout_error() {
        let store = mem();
        let pool = FlowPool::new(store, 1);
        let mut rx =
            pool.stream(vec!["missing".into()], Duration::from_millis(30));
        assert!(block_on(rx.recv()).unwrap().is_err());
    }
}
