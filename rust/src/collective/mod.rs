//! Storage-based communication collectives (§3.3).
//!
//! Serverless functions cannot open sockets to each other; every transfer
//! is relayed through object storage. This module implements the paper's
//! synchronization algorithms in three mutually-validating forms:
//!
//! * **analytic** — the closed-form times of eqs. (1)/(2) used inside the
//!   planner's performance model;
//! * **simulated** — flow schedules on the max-min-fair [`FlowSim`]
//!   network, used by Fig. 8 / Table 3 reproductions;
//! * **real** — threaded implementations over an [`ObjectStore`] that move
//!   actual `f32` gradients, used by the end-to-end trainer.
//!
//! The three agree by construction and by test (`collective_equiv.rs`).
//!
//! [`FlowSim`]: crate::platform::FlowSim
//! [`ObjectStore`]: crate::platform::ObjectStore

pub mod analytic;
pub mod parameter_server;
pub mod pipelined;
pub mod scatter_reduce;
pub mod sendrecv;
pub mod sim;

pub use analytic::{ps_sync_time, sync_time, SyncAlgorithm};

/// Serialize f32 gradients little-endian (the wire format of every
/// storage object; matches the artifacts' raw `.f32` convention).
pub fn f32s_to_bytes(xs: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(xs.len() * 4);
    for x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

pub fn bytes_to_f32s(bytes: &[u8]) -> Vec<f32> {
    assert_eq!(bytes.len() % 4, 0, "byte length {} not 4-aligned", bytes.len());
    bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect()
}

/// Contiguous near-equal split ranges of a length-`n` vector into `k`
/// parts: the first `n % k` parts get one extra element.
pub fn split_ranges(n: usize, k: usize) -> Vec<(usize, usize)> {
    assert!(k >= 1);
    let base = n / k;
    let extra = n % k;
    let mut out = Vec::with_capacity(k);
    let mut lo = 0;
    for i in 0..k {
        let len = base + usize::from(i < extra);
        out.push((lo, lo + len));
        lo += len;
    }
    out
}

/// Elementwise in-place accumulate: `acc += delta`.
pub fn add_assign(acc: &mut [f32], delta: &[f32]) {
    assert_eq!(acc.len(), delta.len());
    for (a, d) in acc.iter_mut().zip(delta) {
        *a += d;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wire_roundtrip() {
        let xs = vec![0.0f32, -1.5, 3.25e7, f32::MIN_POSITIVE];
        assert_eq!(bytes_to_f32s(&f32s_to_bytes(&xs)), xs);
    }

    #[test]
    fn split_ranges_cover_exactly() {
        for n in [1usize, 7, 100, 1023] {
            for k in [1usize, 2, 3, 8] {
                let r = split_ranges(n, k);
                assert_eq!(r.len(), k);
                assert_eq!(r[0].0, 0);
                assert_eq!(r[k - 1].1, n);
                for w in r.windows(2) {
                    assert_eq!(w[0].1, w[1].0);
                }
                let sizes: Vec<usize> = r.iter().map(|(a, b)| b - a).collect();
                let max = sizes.iter().max().unwrap();
                let min = sizes.iter().min().unwrap();
                assert!(max - min <= 1);
            }
        }
    }

    #[test]
    fn add_assign_adds() {
        let mut a = vec![1.0f32, 2.0];
        add_assign(&mut a, &[0.5, -2.0]);
        assert_eq!(a, vec![1.5, 0.0]);
    }
}
