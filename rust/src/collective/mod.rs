//! Storage-based communication collectives (§3.3).
//!
//! Serverless functions cannot open sockets to each other; every transfer
//! is relayed through object storage. This module implements the paper's
//! synchronization algorithms in three mutually-validating forms:
//!
//! * **analytic** — the closed-form times of eqs. (1)/(2) used inside the
//!   planner's performance model, plus chunked variants that account for
//!   the per-chunk storage-latency overhead;
//! * **simulated** — declarative [`FlowGraph`] emitters per algorithm
//!   ([`sim`]), executed by the unified max-min-fair
//!   [`simcore`](crate::simcore) engine; chunked and unchunked are the
//!   same graph at different granularity. Used by Fig. 8 / Table 3
//!   reproductions;
//! * **real** — the unified engine below, which moves actual `f32`
//!   gradients through an [`ObjectStore`] and is used by the end-to-end
//!   trainer.
//!
//! # The unified engine
//!
//! Every real algorithm implements the [`Collective`] trait and runs on a
//! shared [`CollectiveCtx`]: the store handle, the `(group, round)` key
//! namespace, the merge operator and the [`Chunking`] policy. Transfers go
//! through a per-worker [`flow::FlowPool`] — a persistent uploader state
//! machine plus per-stream downloaders on the shared bounded executor
//! ([`crate::exec`]), reused across rounds — so uplink and downlink
//! genuinely overlap just as in the flow model, at O(cores) threads
//! total instead of two OS threads per worker.
//!
//! With chunking enabled, gradients are split into fixed-size chunks that
//! are uploaded, downloaded and merged as independent flows. Consumers
//! delete single-reader chunks on merge and post tiny ack objects; the
//! uploader window-gates chunk `q` on the ack of chunk `q − in_flight`,
//! so at most `in_flight` un-consumed chunks per worker exist in storage
//! at any instant. That bounds both the worker's resident serialization
//! memory and the store's high-water mark by
//! `chunks_in_flight × chunk_bytes` (× `n` workers store-side) instead of
//! the full gradient — see `ObjectStore::high_water_bytes`.
//!
//! The three forms agree by construction and by test
//! (`collective_equiv.rs`, `simcore_equiv.rs`).
//!
//! [`FlowGraph`]: crate::simcore::FlowGraph
//! [`ObjectStore`]: crate::platform::ObjectStore

pub mod analytic;
pub mod flow;
pub mod parameter_server;
pub mod pipelined;
pub mod scatter_reduce;
pub mod sendrecv;
pub mod sim;

use std::future::Future;
use std::pin::Pin;
use std::sync::Arc;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::platform::ObjectStore;

pub use analytic::{
    ps_sync_time, sync_time, sync_time_chunked, SyncAlgorithm,
};

/// Merge operator: `acc += delta`. Injected so the trainer can route the
/// reduction through the AOT `merge2` executable (L1 Pallas kernel).
/// `Send + Sync` because the collectives are worker *state machines* on
/// the shared executor: the closure may be polled from any pool thread.
pub type MergeFn<'a> = dyn Fn(&mut [f32], &[f32]) + Send + Sync + 'a;

/// Boxed future a [`Collective`] round returns (object-safe async).
pub type CollectiveFuture<'a> = Pin<Box<dyn Future<Output = Result<()>> + Send + 'a>>;

pub(crate) fn native_merge(acc: &mut [f32], delta: &[f32]) {
    add_assign(acc, delta);
}

/// Serialize f32 gradients little-endian (the wire format of every
/// storage object; matches the artifacts' raw `.f32` convention).
pub fn f32s_to_bytes(xs: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(xs.len() * 4);
    for x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

pub fn bytes_to_f32s(bytes: &[u8]) -> Vec<f32> {
    assert_eq!(bytes.len() % 4, 0, "byte length {} not 4-aligned", bytes.len());
    bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect()
}

/// Contiguous near-equal split ranges of a length-`n` vector into `k`
/// parts: the first `n % k` parts get one extra element.
pub fn split_ranges(n: usize, k: usize) -> Vec<(usize, usize)> {
    assert!(k >= 1);
    let base = n / k;
    let extra = n % k;
    let mut out = Vec::with_capacity(k);
    let mut lo = 0;
    for i in 0..k {
        let len = base + usize::from(i < extra);
        out.push((lo, lo + len));
        lo += len;
    }
    out
}

/// Elementwise in-place accumulate: `acc += delta`.
pub fn add_assign(acc: &mut [f32], delta: &[f32]) {
    assert_eq!(acc.len(), delta.len());
    for (a, d) in acc.iter_mut().zip(delta) {
        *a += d;
    }
}

// ---------------------------------------------------------------------------
// Chunking policy
// ---------------------------------------------------------------------------

/// How a gradient split is cut into independently-flowing chunks.
///
/// `chunk_bytes == 0` disables chunking: each split travels as one object
/// and no ack/window machinery runs (the original behaviour). Otherwise
/// each split is cut into ⌈split/chunk⌉ chunks and at most `in_flight`
/// un-consumed chunks per worker exist at any time.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Chunking {
    /// Chunk size in bytes (f32-aligned internally); 0 = unchunked.
    pub chunk_bytes: usize,
    /// Window of in-flight (uploaded but un-consumed) chunks per worker.
    pub in_flight: usize,
}

impl Chunking {
    /// Unchunked (one flow per split, no windows).
    pub const NONE: Chunking = Chunking { chunk_bytes: 0, in_flight: 4 };

    pub fn new(chunk_bytes: usize, in_flight: usize) -> Self {
        Self { chunk_bytes, in_flight: in_flight.max(1) }
    }

    pub fn is_chunked(&self) -> bool {
        self.chunk_bytes > 0
    }

    /// Elements per chunk; `None` = whole split in one flow.
    pub fn chunk_elems(&self) -> Option<usize> {
        self.is_chunked().then_some((self.chunk_bytes / 4).max(1))
    }

    /// Number of chunks covering `elems` elements (0 for an empty split).
    pub fn chunks_in(&self, elems: usize) -> usize {
        if elems == 0 {
            return 0;
        }
        match self.chunk_elems() {
            None => 1,
            Some(ce) => elems.div_ceil(ce),
        }
    }
}

impl Default for Chunking {
    fn default() -> Self {
        Chunking::NONE
    }
}

/// Absolute element ranges of the chunks covering `[lo, hi)`.
pub fn chunk_ranges(
    lo: usize,
    hi: usize,
    chunk_elems: Option<usize>,
) -> Vec<(usize, usize)> {
    if hi <= lo {
        return Vec::new();
    }
    match chunk_elems {
        None => vec![(lo, hi)],
        Some(ce) => {
            let ce = ce.max(1);
            (lo..hi)
                .step_by(ce)
                .map(|s| (s, (s + ce).min(hi)))
                .collect()
        }
    }
}

/// Per-split chunk layout shared by producers and consumers of one
/// all-reduce round — both sides derive identical sequence numbers from
/// it, which is what lets consumers name the ack objects the producer's
/// window gate waits for.
pub(crate) struct ChunkPlan {
    /// Absolute `(lo, hi)` element ranges of every chunk, per split.
    pub chunks: Vec<Vec<(usize, usize)>>,
}

impl ChunkPlan {
    pub fn new(ranges: &[(usize, usize)], chunking: &Chunking) -> Self {
        let chunks = ranges
            .iter()
            .map(|&(lo, hi)| chunk_ranges(lo, hi, chunking.chunk_elems()))
            .collect();
        Self { chunks }
    }

    pub fn count(&self, split: usize) -> usize {
        self.chunks[split].len()
    }

    /// Producer `p` uploads splits `(p+1)%n, (p+2)%n, …` in step order
    /// during the reduce phase; sequence number of the first chunk of
    /// `split` within that order.
    pub fn reduce_seq_base(&self, producer: usize, split: usize, n: usize) -> usize {
        let k = (split + n - producer) % n; // step index 1..n-1
        debug_assert!(k >= 1 && k < n);
        (1..k)
            .map(|j| self.count((producer + j) % n))
            .sum()
    }

    /// Total reduce-phase chunks producer `p` uploads (= all splits but
    /// its own); phase-3 sequence numbers start here.
    pub fn total_reduce(&self, producer: usize, n: usize) -> usize {
        (0..n).filter(|&s| s != producer).map(|s| self.count(s)).sum()
    }
}

// ---------------------------------------------------------------------------
// Key namespace
// ---------------------------------------------------------------------------

pub(crate) fn done_key(group: &str, round: u64, rank: usize) -> String {
    format!("{group}/r{round}/done/f{rank}")
}

pub(crate) fn ack_key(
    group: &str,
    round: u64,
    producer: usize,
    seq: usize,
    consumer: usize,
) -> String {
    format!("{group}/r{round}/ack/f{producer}/q{seq}/d{consumer}")
}

/// Merged-split (all-gather) chunk key — shared by both scatter-reduce
/// variants; their reduce phases use algorithm-private prefixes.
pub(crate) fn merged_chunk_key(
    group: &str,
    round: u64,
    split: usize,
    chunk: usize,
) -> String {
    format!("{group}/r{round}/ag/s{split}/c{chunk}")
}

// ---------------------------------------------------------------------------
// The unified collective engine
// ---------------------------------------------------------------------------

/// Shared context of every collective call: the store handle, key
/// namespace, timeout, chunking policy, and the reusable flow pool whose
/// uploader/downloader threads persist across rounds.
pub struct CollectiveCtx {
    pub store: Arc<dyn ObjectStore>,
    pub group: String,
    pub rank: usize,
    pub n: usize,
    pub timeout: Duration,
    pub chunking: Chunking,
    pool: flow::FlowPool,
}

impl CollectiveCtx {
    pub fn new(
        store: Arc<dyn ObjectStore>,
        group: impl Into<String>,
        rank: usize,
        n: usize,
        timeout: Duration,
    ) -> Self {
        assert!(n >= 1 && rank < n, "rank {rank} out of range for n={n}");
        let pool = flow::FlowPool::new(store.clone(), Chunking::NONE.in_flight);
        Self {
            store,
            group: group.into(),
            rank,
            n,
            timeout,
            chunking: Chunking::NONE,
            pool,
        }
    }

    /// Enable chunked streaming. The pool is rebuilt only when the
    /// queue depth actually changes, so the common wrapper path spawns
    /// one uploader/downloader pair, not two.
    pub fn with_chunking(mut self, chunking: Chunking) -> Self {
        self.chunking = chunking;
        if chunking.in_flight != self.pool.in_flight() {
            self.pool =
                flow::FlowPool::new(self.store.clone(), chunking.in_flight);
        }
        self
    }

    pub(crate) fn pool(&self) -> &flow::FlowPool {
        &self.pool
    }

    /// Run one all-reduce round with the algorithm selected by `alg`. On
    /// return `grads` holds the elementwise sum over all `n` workers.
    pub async fn all_reduce(
        &self,
        alg: SyncAlgorithm,
        round: u64,
        grads: &mut [f32],
        merge: Option<&MergeFn<'_>>,
    ) -> Result<()> {
        let c: &dyn Collective = match alg {
            SyncAlgorithm::ScatterReduce => &scatter_reduce::PlainScatterReduce,
            SyncAlgorithm::PipelinedScatterReduce => {
                &pipelined::PipelinedScatterReduce
            }
        };
        c.all_reduce(self, round, grads, merge)
            .await
            .with_context(|| format!("{} round {round}", c.name()))
    }

    /// Blocking convenience over [`Self::all_reduce`] for sync callers
    /// (tests, benches, examples that drive ranks from OS threads).
    pub fn all_reduce_blocking(
        &self,
        alg: SyncAlgorithm,
        round: u64,
        grads: &mut [f32],
        merge: Option<&MergeFn<'_>>,
    ) -> Result<()> {
        crate::exec::block_on(self.all_reduce(alg, round, grads, merge))
    }

    /// Publish this rank's end-of-round marker (the cleanup barrier).
    pub(crate) async fn mark_done(&self, round: u64) -> Result<()> {
        self.store
            .put_async(&done_key(&self.group, round, self.rank), Vec::new())
            .await
            .context("done marker")
    }
}

/// One storage-relayed all-reduce algorithm over the unified engine.
pub trait Collective: Send + Sync {
    fn name(&self) -> &'static str;

    /// Resolves once every rank's `grads` holds the elementwise sum
    /// across the `ctx.n` participants of `(ctx.group, round)`.
    fn all_reduce<'a>(
        &'a self,
        ctx: &'a CollectiveCtx,
        round: u64,
        grads: &'a mut [f32],
        merge: Option<&'a MergeFn<'a>>,
    ) -> CollectiveFuture<'a>;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wire_roundtrip() {
        let xs = vec![0.0f32, -1.5, 3.25e7, f32::MIN_POSITIVE];
        assert_eq!(bytes_to_f32s(&f32s_to_bytes(&xs)), xs);
    }

    #[test]
    fn split_ranges_cover_exactly() {
        for n in [1usize, 7, 100, 1023] {
            for k in [1usize, 2, 3, 8] {
                let r = split_ranges(n, k);
                assert_eq!(r.len(), k);
                assert_eq!(r[0].0, 0);
                assert_eq!(r[k - 1].1, n);
                for w in r.windows(2) {
                    assert_eq!(w[0].1, w[1].0);
                }
                let sizes: Vec<usize> = r.iter().map(|(a, b)| b - a).collect();
                let max = sizes.iter().max().unwrap();
                let min = sizes.iter().min().unwrap();
                assert!(max - min <= 1);
            }
        }
    }

    #[test]
    fn add_assign_adds() {
        let mut a = vec![1.0f32, 2.0];
        add_assign(&mut a, &[0.5, -2.0]);
        assert_eq!(a, vec![1.5, 0.0]);
    }

    #[test]
    fn chunk_ranges_cover_and_bound() {
        let r = chunk_ranges(10, 107, Some(16));
        assert_eq!(r.first().unwrap().0, 10);
        assert_eq!(r.last().unwrap().1, 107);
        for w in r.windows(2) {
            assert_eq!(w[0].1, w[1].0);
        }
        assert!(r.iter().all(|(a, b)| b - a <= 16));
        assert_eq!(chunk_ranges(5, 5, Some(16)), Vec::new());
        assert_eq!(chunk_ranges(0, 40, None), vec![(0, 40)]);
    }

    #[test]
    fn chunking_counts() {
        let c = Chunking::new(64, 4); // 16 elems per chunk
        assert_eq!(c.chunk_elems(), Some(16));
        assert_eq!(c.chunks_in(0), 0);
        assert_eq!(c.chunks_in(1), 1);
        assert_eq!(c.chunks_in(16), 1);
        assert_eq!(c.chunks_in(17), 2);
        assert_eq!(Chunking::NONE.chunks_in(1_000_000), 1);
        assert_eq!(Chunking::NONE.chunks_in(0), 0);
    }

    #[test]
    fn chunk_plan_sequences_are_consistent() {
        let n = 4;
        let ranges = split_ranges(103, n);
        let plan = ChunkPlan::new(&ranges, &Chunking::new(40, 2)); // 10 elems
        for p in 0..n {
            // producer p's reduce sequence covers each foreign split once,
            // in step order, with bases that tile [0, total)
            let mut seen = vec![false; plan.total_reduce(p, n)];
            for k in 1..n {
                let split = (p + k) % n;
                let base = plan.reduce_seq_base(p, split, n);
                for c in 0..plan.count(split) {
                    assert!(!seen[base + c]);
                    seen[base + c] = true;
                }
            }
            assert!(seen.iter().all(|&x| x));
        }
    }
}
