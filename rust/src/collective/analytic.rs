//! Closed-form synchronization times — eqs. (1) and (2) of §3.3 plus the
//! parameter-server formula used by the HybridPS baseline model.

/// Which synchronization algorithm a stage's replicas use.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SyncAlgorithm {
    /// LambdaML's 3-phase storage scatter-reduce (eq. (1)).
    ScatterReduce,
    /// FuncPipe's pipelined scatter-reduce (eq. (2)).
    PipelinedScatterReduce,
}

impl SyncAlgorithm {
    /// Stable wire name — the `"sync"` value in configs and plan
    /// artifacts. `parse` is its inverse.
    pub fn as_str(&self) -> &'static str {
        match self {
            SyncAlgorithm::ScatterReduce => "scatter-reduce",
            SyncAlgorithm::PipelinedScatterReduce => "pipelined",
        }
    }

    pub fn parse(s: &str) -> Option<SyncAlgorithm> {
        match s {
            "scatter-reduce" => Some(SyncAlgorithm::ScatterReduce),
            "pipelined" => Some(SyncAlgorithm::PipelinedScatterReduce),
            _ => None,
        }
    }

    /// The (γ, δ) parameters of eq. (9): `t_s = γ·s/W + δ·t_lat`.
    ///
    /// Pipelined: γ=2, δ=2+n. Non-pipelined (from eq. (1)): γ=3−2/n, δ=4.
    pub fn gamma_delta(&self, n: usize) -> (f64, f64) {
        match self {
            SyncAlgorithm::PipelinedScatterReduce => (2.0, 2.0 + n as f64),
            SyncAlgorithm::ScatterReduce => {
                (3.0 - 2.0 / n as f64, 4.0)
            }
        }
    }
}

/// Synchronization time of `grad_bytes` among `n` workers of per-worker
/// bandwidth `w_bps` via `alg`, with storage latency `t_lat`.
///
/// `n == 1` needs no synchronization and returns 0.
pub fn sync_time(
    alg: SyncAlgorithm,
    grad_bytes: f64,
    n: usize,
    w_bps: f64,
    t_lat: f64,
) -> f64 {
    if n <= 1 {
        return 0.0;
    }
    let (gamma, delta) = alg.gamma_delta(n);
    gamma * grad_bytes / w_bps + delta * t_lat
}

/// Chunked synchronization time: the transfer term of eqs. (1)/(2) is
/// unchanged (the same bytes cross the same links), but every per-phase
/// storage operation becomes ⌈split/chunk⌉ serialized operations on its
/// link, so the latency term multiplies by the per-split chunk count.
/// `chunk_bytes == 0` (unchunked) reduces exactly to [`sync_time`].
///
/// This deliberately ignores the chunked engine's finer pipeline fill
/// (chunk-level duplex lets downloads start one chunk — not one split —
/// after the first upload), so it is a mild upper bound; the FlowSim
/// chunked schedules model the fill exactly and sit at or below this
/// value (see `collective_equiv.rs`).
pub fn sync_time_chunked(
    alg: SyncAlgorithm,
    grad_bytes: f64,
    n: usize,
    w_bps: f64,
    t_lat: f64,
    chunk_bytes: usize,
) -> f64 {
    if n <= 1 {
        return 0.0;
    }
    let (gamma, delta) = alg.gamma_delta(n);
    let chunks_per_split = if chunk_bytes == 0 {
        1.0
    } else {
        (grad_bytes / n as f64 / chunk_bytes as f64).ceil().max(1.0)
    };
    gamma * grad_bytes / w_bps + delta * t_lat * chunks_per_split
}

/// Server-side aggregation throughput: deserializing + merging each
/// replica's gradients burdens the single VM (§5.2 "the server node in
/// this centralized structure can be heavily burdened") — this is why
/// HybridPS falls behind LambdaML at scale despite its fat NIC.
pub const PS_SERVER_PROC_BPS: f64 = 1.0e9;

/// Parameter-server synchronization (HybridPS): all `n` workers upload
/// gradients to the VM and download updated parameters. The wall time is
/// bounded by either the worker NIC (`2·s/w`) or the server NIC carrying
/// all replicas (`2·s·n/w_ps`), plus the server-side aggregation time and
/// two round trips.
pub fn ps_sync_time(
    grad_bytes: f64,
    n: usize,
    w_worker_bps: f64,
    w_server_bps: f64,
    rtt: f64,
) -> f64 {
    if n == 0 {
        return 0.0;
    }
    let worker_bound = 2.0 * grad_bytes / w_worker_bps;
    let server_bound = 2.0 * grad_bytes * n as f64 / w_server_bps;
    let server_proc = if n > 1 {
        grad_bytes * n as f64 / PS_SERVER_PROC_BPS
    } else {
        0.0
    };
    worker_bound.max(server_bound) + server_proc + 2.0 * rtt
}

#[cfg(test)]
mod tests {
    use super::*;

    const MB: f64 = 1.0e6;

    #[test]
    fn paper_example_280mb_8_workers() {
        // §3.3: "synchronizing a 280 MB model among 8 workers [at 70 MB/s]
        // can be reduced by 27%, from 11 s to 8 s" (transfer time only).
        let s = 280.0 * MB;
        let w = 70.0 * MB;
        let plain = sync_time(SyncAlgorithm::ScatterReduce, s, 8, w, 0.0);
        let piped =
            sync_time(SyncAlgorithm::PipelinedScatterReduce, s, 8, w, 0.0);
        assert!((plain - 11.0).abs() < 0.01, "plain {plain}");
        assert!((piped - 8.0).abs() < 0.01, "piped {piped}");
        let cut = 1.0 - piped / plain;
        assert!((cut - 0.27).abs() < 0.01, "reduction {cut}");
    }

    #[test]
    fn pipelined_always_fast_er_in_transfer() {
        for n in 2..64 {
            let a = sync_time(SyncAlgorithm::ScatterReduce, 1e8, n, 7e7, 0.0);
            let b = sync_time(
                SyncAlgorithm::PipelinedScatterReduce,
                1e8,
                n,
                7e7,
                0.0,
            );
            // at n=2 the transfer terms coincide (3-2/2 == 2); strictly
            // better from n=3 on
            if n == 2 {
                assert!((b - a).abs() < 1e-9, "n=2: {b} vs {a}");
            } else {
                assert!(b < a, "n={n}: {b} !< {a}");
            }
        }
    }

    #[test]
    fn pipelined_latency_grows_with_n() {
        // eq. (2): δ = 2+n — latency term scales with workers, but stays
        // far below the transfer term for realistic sizes (§3.3).
        let t = |n| {
            sync_time(SyncAlgorithm::PipelinedScatterReduce, 280.0 * MB, n, 70.0 * MB, 0.04)
        };
        assert!(t(16) > t(8));
        let transfer = 2.0 * 280.0 / 70.0;
        assert!(t(16) - transfer < 1.0); // latency portion < 1 s
    }

    #[test]
    fn max_theoretical_reduction_is_one_third() {
        // (1) -> (2): transfer drops from 3−2/n to 2; as n→∞ the cut
        // approaches 1/3 (§5.5 "up to 33%").
        let cut = |n: usize| {
            let a = sync_time(SyncAlgorithm::ScatterReduce, 1e9, n, 1e8, 0.0);
            let b = sync_time(
                SyncAlgorithm::PipelinedScatterReduce,
                1e9,
                n,
                1e8,
                0.0,
            );
            1.0 - b / a
        };
        assert!(cut(1024) > 0.33);
        assert!(cut(1024) < 0.334);
        assert!(cut(2) < cut(32));
    }

    #[test]
    fn chunked_formula_reduces_to_unchunked() {
        for alg in [
            SyncAlgorithm::ScatterReduce,
            SyncAlgorithm::PipelinedScatterReduce,
        ] {
            for n in [2usize, 8, 32] {
                // chunk_bytes = 0 is the unchunked formula, exactly
                let a = sync_time(alg, 280.0 * MB, n, 70.0 * MB, 0.04);
                let b = sync_time_chunked(alg, 280.0 * MB, n, 70.0 * MB, 0.04, 0);
                assert_eq!(a, b);
                // at zero latency chunking costs nothing
                let c = sync_time_chunked(alg, 280.0 * MB, n, 70.0 * MB, 0.0, 1 << 20);
                let d = sync_time(alg, 280.0 * MB, n, 70.0 * MB, 0.0);
                assert!((c - d).abs() < 1e-9 * d);
            }
        }
    }

    #[test]
    fn chunk_latency_overhead_grows_with_chunk_count() {
        let t = |chunk: usize| {
            sync_time_chunked(
                SyncAlgorithm::PipelinedScatterReduce,
                280.0 * MB,
                8,
                70.0 * MB,
                0.04,
                chunk,
            )
        };
        // smaller chunks -> more per-op latency; unchunked is the floor
        assert!(t(1 << 20) > t(0));
        assert!(t(1 << 18) > t(1 << 20));
        // transfer term dominates for sane chunk sizes: 4 MB chunks on a
        // 35 MB split add (9-1) * delta * t_lat = 3.2 s against an 8 s
        // transfer
        let overhead = t(4 << 20) - t(0);
        assert!(overhead > 0.0 && overhead < 4.0, "overhead {overhead}");
    }

    #[test]
    fn single_worker_needs_no_sync() {
        assert_eq!(
            sync_time(SyncAlgorithm::PipelinedScatterReduce, 1e9, 1, 1e6, 1.0),
            0.0
        );
    }

    #[test]
    fn ps_server_becomes_bottleneck() {
        // few workers: worker NIC bound; many: server NIC + aggregation
        let few = ps_sync_time(1e8, 2, 7e7, 1.25e9, 0.0);
        let few_expected = 2.0 * 1e8 / 7e7 + 2.0 * 1e8 / PS_SERVER_PROC_BPS;
        assert!((few - few_expected).abs() < 1e-6, "{few} vs {few_expected}");
        let many = ps_sync_time(1e8, 64, 7e7, 1.25e9, 0.0);
        let many_expected = 2.0 * 1e8 * 64.0 / 1.25e9
            + 64.0 * 1e8 / PS_SERVER_PROC_BPS;
        assert!((many - many_expected).abs() < 1e-6);
        assert!(many > few);
        // per-worker sync time grows with n — the paper's scaling pain
        assert!(many / 64.0 > few / 64.0);
    }
}
