//! Flow-level simulations of the synchronization algorithms on the
//! max-min-fair network — the "measured" counterpart to the closed forms
//! in [`analytic`](super::analytic). Used by the Fig. 8 reproduction and
//! by Table 3's model-accuracy check.
//!
//! Since the simcore refactor each algorithm *emits* a declarative
//! [`FlowGraph`] that the unified engine executes; the historical
//! `simulate_*` entry points are thin wrappers over
//! `emit_*` + [`execute`](crate::simcore::execute). Chunked and
//! unchunked are the same graph at different granularity: the unchunked
//! schedule is the chunked emitter with one chunk per split, so per-link
//! serialization, per-chunk dependency gating and the per-operation
//! latency term all come from one code path.

use crate::platform::network::BandwidthModel;
use crate::simcore::{execute, FlowGraph, Node, NodeId};

fn chunks_per_split(split_bytes: f64, chunk_bytes: f64) -> usize {
    if chunk_bytes <= 0.0 {
        return 1;
    }
    ((split_bytes / chunk_bytes).ceil() as usize).max(1)
}

/// LambdaML's 3-phase scatter-reduce (Fig. 4(a)) as a flow graph, at
/// chunk granularity (`chunk_bytes <= 0` = whole splits).
///
/// Phase 1: each worker uploads its n−1 foreign splits, chunks
/// serialized on its uplink. Phase 2 starts only after the relevant
/// upload exists; download of split i's chunk from worker j depends on
/// j's phase-1 upload of that chunk. Uploads and downloads of one
/// worker do NOT overlap across phases — the serialization the paper
/// identifies as the inefficiency — enforced with cross-phase
/// dependencies.
pub fn emit_scatter_reduce(
    n: usize,
    grad_bytes: f64,
    model: &BandwidthModel,
    chunk_bytes: f64,
) -> FlowGraph {
    assert!(n >= 2);
    let split = grad_bytes / n as f64;
    let nc = chunks_per_split(split, chunk_bytes);
    let chunk = split / nc as f64;
    let mut g = FlowGraph::with_network(model);

    // phase 1: worker i's uplink carries its (n-1)*nc foreign-split
    // chunks, serialized; up1[i][j][c] indexed per split then chunk
    let mut up1 = vec![vec![vec![NodeId::MAX; nc]; n]; n];
    let mut last_up = vec![None::<NodeId>; n];
    for i in 0..n {
        for j in 0..n {
            if j == i {
                continue;
            }
            for c in 0..nc {
                let deps = last_up[i].map(|p| vec![p]).unwrap_or_default();
                let id = g.add(Node::transfer(i, true, chunk).after(deps));
                up1[i][j][c] = id;
                last_up[i] = Some(id);
            }
        }
    }
    // phase 2: strictly after the worker's own phase-1 uploads (the
    // serialization of the plain algorithm), chunk flows serialized on
    // the downlink, each gated on the producing upload chunk
    let mut last_down = vec![None::<NodeId>; n];
    for i in 0..n {
        for j in 0..n {
            if j == i {
                continue;
            }
            for c in 0..nc {
                let mut deps = vec![last_up[i].expect("n>=2"), up1[j][i][c]];
                if let Some(p) = last_down[i] {
                    deps.push(p);
                }
                last_down[i] =
                    Some(g.add(Node::transfer(i, false, chunk).after(deps)));
            }
        }
    }
    // phase 3: merged-split chunks after the merge completes, then the
    // gathers, gated per chunk on the producing upload
    let mut up3 = vec![vec![NodeId::MAX; nc]; n];
    for i in 0..n {
        let mut prev = last_down[i];
        for c in 0..nc {
            let mut deps = vec![last_down[i].expect("n>=2")];
            if let Some(p) = prev {
                deps.push(p);
            }
            up3[i][c] = g.add(Node::transfer(i, true, chunk).after(deps));
            prev = Some(up3[i][c]);
        }
    }
    for i in 0..n {
        let mut prev = Some(*up3[i].last().expect("nc>=1"));
        for j in 0..n {
            if j == i {
                continue;
            }
            for c in 0..nc {
                let mut deps = vec![up3[j][c]];
                if let Some(p) = prev {
                    deps.push(p);
                }
                prev = Some(g.add(Node::transfer(i, false, chunk).after(deps)));
            }
        }
    }
    g
}

/// FuncPipe's pipelined scatter-reduce (Fig. 4(b), §3.3) as a flow
/// graph: chunk-granular duplex — download chunk `c` of step `k` needs
/// only upload chunk `c` of step `k-1`, so the fill is one *chunk*
/// rather than one split, exactly like the real chunked engine (ack
/// windows are not modelled; with symmetric bandwidth they never bind).
/// `chunk_bytes <= 0` = whole splits: the classic schedule where at
/// step k worker i uploads split i+k while downloading its own split
/// uploaded by worker i−(k−1) at step k−1.
pub fn emit_pipelined_scatter_reduce(
    n: usize,
    grad_bytes: f64,
    model: &BandwidthModel,
    chunk_bytes: f64,
) -> FlowGraph {
    assert!(n >= 2);
    let split = grad_bytes / n as f64;
    let nc = chunks_per_split(split, chunk_bytes);
    let chunk = split / nc as f64;
    let mut g = FlowGraph::with_network(model);

    // reduce uploads: steps k=1..n-1, chunks serialized on the uplink
    let mut up = vec![vec![vec![NodeId::MAX; nc]; n]; n];
    let mut last_up = vec![None::<NodeId>; n];
    for i in 0..n {
        for k in 1..n {
            for c in 0..nc {
                let deps = last_up[i].map(|p| vec![p]).unwrap_or_default();
                let id = g.add(Node::transfer(i, true, chunk).after(deps));
                up[i][k][c] = id;
                last_up[i] = Some(id);
            }
        }
    }
    // reduce downloads: at step k worker i pulls its own split's chunk c
    // uploaded by (i-(k-1)) at step k-1 — duplex at chunk granularity
    let mut last_down = vec![None::<NodeId>; n];
    for i in 0..n {
        for k in 2..=n {
            let src = (i + n - (k - 1)) % n;
            for c in 0..nc {
                let mut deps = vec![up[src][k - 1][c]];
                if let Some(p) = last_down[i] {
                    deps.push(p);
                }
                last_down[i] =
                    Some(g.add(Node::transfer(i, false, chunk).after(deps)));
            }
        }
    }
    // broadcast: merged chunks after the merge, then the gathers
    let mut up3 = vec![vec![NodeId::MAX; nc]; n];
    for i in 0..n {
        let mut prev = last_up[i];
        for c in 0..nc {
            let mut deps = vec![last_down[i].expect("n>=2")];
            if let Some(p) = prev {
                deps.push(p);
            }
            up3[i][c] = g.add(Node::transfer(i, true, chunk).after(deps));
            prev = Some(up3[i][c]);
        }
    }
    for i in 0..n {
        let mut prev = last_down[i];
        for j in 0..n {
            if j == i {
                continue;
            }
            for c in 0..nc {
                let mut deps = vec![up3[j][c]];
                if let Some(p) = prev {
                    deps.push(p);
                }
                prev = Some(g.add(Node::transfer(i, false, chunk).after(deps)));
            }
        }
    }
    g
}

/// HybridPS synchronization as a flow graph: workers push gradients
/// directly to a VM parameter server (worker index `n` in the model)
/// and pull updated parameters back.
pub fn emit_parameter_server(
    n: usize,
    grad_bytes: f64,
    model: &BandwidthModel,
) -> FlowGraph {
    assert!(model.n_workers() >= n + 1, "need server as worker n");
    let server = n;
    let mut g = FlowGraph::with_network(model);
    let ups: Vec<NodeId> =
        (0..n).map(|i| g.add(Node::direct(i, server, grad_bytes))).collect();
    // server applies update after all pushes, then each worker pulls.
    for i in 0..n {
        g.add(Node::direct(server, i, grad_bytes).after(ups.clone()));
    }
    g
}

/// LambdaML's 3-phase scatter-reduce, whole-split flows.
pub fn simulate_scatter_reduce(
    n: usize,
    grad_bytes: f64,
    model: &BandwidthModel,
) -> f64 {
    execute(&emit_scatter_reduce(n, grad_bytes, model, 0.0)).makespan
}

/// FuncPipe's pipelined scatter-reduce, whole-split flows.
pub fn simulate_pipelined_scatter_reduce(
    n: usize,
    grad_bytes: f64,
    model: &BandwidthModel,
) -> f64 {
    execute(&emit_pipelined_scatter_reduce(n, grad_bytes, model, 0.0)).makespan
}

/// Chunked 3-phase scatter-reduce: every split travels as
/// ⌈split/chunk⌉ flows serialized on their link, mirroring the real
/// chunked engine. With `latency == 0` this converges to the unchunked
/// makespan (same bytes on the same links behind the same barriers);
/// with latency it exposes the per-chunk operation overhead that
/// [`sync_time_chunked`](super::analytic::sync_time_chunked) models.
pub fn simulate_scatter_reduce_chunked(
    n: usize,
    grad_bytes: f64,
    model: &BandwidthModel,
    chunk_bytes: f64,
) -> f64 {
    execute(&emit_scatter_reduce(n, grad_bytes, model, chunk_bytes)).makespan
}

/// Chunked pipelined scatter-reduce (chunk-granular duplex fill).
pub fn simulate_pipelined_scatter_reduce_chunked(
    n: usize,
    grad_bytes: f64,
    model: &BandwidthModel,
    chunk_bytes: f64,
) -> f64 {
    execute(&emit_pipelined_scatter_reduce(n, grad_bytes, model, chunk_bytes))
        .makespan
}

/// HybridPS synchronization through the VM parameter server.
pub fn simulate_parameter_server(
    n: usize,
    grad_bytes: f64,
    model: &BandwidthModel,
) -> f64 {
    execute(&emit_parameter_server(n, grad_bytes, model)).makespan
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collective::analytic::{
        ps_sync_time, sync_time, SyncAlgorithm,
    };

    const MB: f64 = 1.0e6;

    fn storage_model(n: usize, w: f64, lat: f64) -> BandwidthModel {
        BandwidthModel::uniform(n, w, lat)
    }

    #[test]
    fn plain_matches_eq1() {
        for n in [2usize, 4, 8] {
            let model = storage_model(n, 70.0 * MB, 0.0);
            let sim_t = simulate_scatter_reduce(n, 280.0 * MB, &model);
            let formula =
                sync_time(SyncAlgorithm::ScatterReduce, 280.0 * MB, n, 70.0 * MB, 0.0);
            let err = (sim_t - formula).abs() / formula;
            assert!(err < 0.12, "n={n}: sim {sim_t} vs eq(1) {formula}");
        }
    }

    #[test]
    fn pipelined_matches_eq2() {
        for n in [2usize, 4, 8, 16] {
            let model = storage_model(n, 70.0 * MB, 0.0);
            let sim_t =
                simulate_pipelined_scatter_reduce(n, 280.0 * MB, &model);
            let formula = sync_time(
                SyncAlgorithm::PipelinedScatterReduce,
                280.0 * MB,
                n,
                70.0 * MB,
                0.0,
            );
            let err = (sim_t - formula).abs() / formula;
            assert!(err < 0.12, "n={n}: sim {sim_t} vs eq(2) {formula}");
        }
    }

    #[test]
    fn pipelined_beats_plain_in_sim() {
        for n in [4usize, 8, 16] {
            let model = storage_model(n, 70.0 * MB, 0.02);
            let a = simulate_scatter_reduce(n, 300.0 * MB, &model);
            let b = simulate_pipelined_scatter_reduce(n, 300.0 * MB, &model);
            assert!(b < a, "n={n}: pipelined {b} !< plain {a}");
        }
    }

    #[test]
    fn ps_matches_formula_when_server_bound() {
        let n = 16;
        let mut model = storage_model(n + 1, 70.0 * MB, 0.0);
        model.up_bps[n] = 1.25e9;
        model.down_bps[n] = 1.25e9;
        let sim_t = simulate_parameter_server(n, 100.0 * MB, &model);
        // the flow sim models transfers only; subtract the analytic
        // server-side aggregation term before comparing
        let agg = n as f64 * 100.0 * MB
            / crate::collective::analytic::PS_SERVER_PROC_BPS;
        let formula = ps_sync_time(100.0 * MB, n, 70.0 * MB, 1.25e9, 0.0) - agg;
        let err = (sim_t - formula).abs() / formula;
        assert!(err < 0.15, "sim {sim_t} vs formula {formula}");
    }

    #[test]
    fn chunked_schedules_match_unchunked_at_zero_latency() {
        // same bytes, same links, same barriers: chunking must cost
        // nothing when storage operations are free
        for n in [2usize, 4, 8] {
            let model = storage_model(n, 70.0 * MB, 0.0);
            let s = 280.0 * MB;
            let plain = simulate_scatter_reduce(n, s, &model);
            for chunk in [4.0e6, 16.0e6] {
                let chunked =
                    simulate_scatter_reduce_chunked(n, s, &model, chunk);
                let err = (chunked - plain).abs() / plain;
                assert!(
                    err < 1e-5,
                    "plain n={n} chunk={chunk}: {chunked} vs {plain}"
                );
            }
        }
    }

    #[test]
    fn chunked_pipelined_is_never_slower_and_respects_occupancy() {
        for n in [2usize, 4, 8] {
            let model = storage_model(n, 70.0 * MB, 0.0);
            let s = 280.0 * MB;
            let unchunked = simulate_pipelined_scatter_reduce(n, s, &model);
            for chunk in [2.0e6, 8.0e6] {
                let chunked = simulate_pipelined_scatter_reduce_chunked(
                    n, s, &model, chunk,
                );
                // finer fill can only help...
                assert!(
                    chunked <= unchunked * (1.0 + 1e-9),
                    "n={n} chunk={chunk}: {chunked} > {unchunked}"
                );
                // ...but every worker still moves s bytes up its link
                let occupancy_floor = s / (70.0 * MB);
                assert!(chunked >= occupancy_floor * (1.0 - 1e-9));
            }
        }
    }

    #[test]
    fn chunk_latency_overhead_visible_in_sim() {
        // with real per-operation latency, smaller chunks mean more
        // serialized storage ops on each link
        let n = 4;
        let model = storage_model(n, 70.0 * MB, 0.02);
        let s = 80.0 * MB;
        let coarse =
            simulate_pipelined_scatter_reduce_chunked(n, s, &model, 10.0e6);
        let fine =
            simulate_pipelined_scatter_reduce_chunked(n, s, &model, 1.0e6);
        assert!(fine > coarse, "fine {fine} !> coarse {coarse}");
    }

    #[test]
    fn aggregate_cap_slows_scatter_reduce() {
        let n = 8;
        let free = storage_model(n, 100.0 * MB, 0.0);
        let capped = storage_model(n, 100.0 * MB, 0.0)
            .with_aggregate_cap(200.0 * MB);
        let a = simulate_pipelined_scatter_reduce(n, 100.0 * MB, &free);
        let b = simulate_pipelined_scatter_reduce(n, 100.0 * MB, &capped);
        assert!(b > a * 1.5, "cap should slow things: {a} vs {b}");
    }

    // wrapper == emit + execute delegation is pinned (for every
    // algorithm, including the parameter server) by
    // `rust/tests/simcore_equiv.rs::wrappers_delegate_to_emitted_graphs`.
}
