//! Flow-level simulations of the synchronization algorithms on the
//! max-min-fair network — the "measured" counterpart to the closed forms
//! in [`analytic`](super::analytic). Used by the Fig. 8 reproduction and
//! by Table 3's model-accuracy check.

use crate::platform::network::{BandwidthModel, Dir, FlowSim};

/// LambdaML's 3-phase scatter-reduce (Fig. 4(a)) as a flow schedule.
///
/// Phase 1: each worker uploads its n−1 foreign splits (concurrently on
/// its uplink). Phase 2 starts only after the relevant upload exists;
/// download of split i from worker j depends on j's phase-1 upload of
/// split i. Uploads and downloads of one worker do NOT overlap across
/// phases — the serialization the paper identifies as the inefficiency —
/// which we enforce with cross-phase dependencies.
pub fn simulate_scatter_reduce(
    n: usize,
    grad_bytes: f64,
    model: &BandwidthModel,
) -> f64 {
    assert!(n >= 2);
    let split = grad_bytes / n as f64;
    let mut sim = FlowSim::new(model.clone());

    // phase 1 uploads: up1[i][j] = worker i uploads split j (j != i)
    let mut up1 = vec![vec![usize::MAX; n]; n];
    for i in 0..n {
        for j in 0..n {
            if j != i {
                up1[i][j] = sim.add_flow(i, Dir::Up, split, 0.0);
            }
        }
    }
    // phase 2 downloads: worker i downloads split i from each j != i,
    // gated on ALL of i's phase-1 uploads (phases are serial per worker).
    let mut down2 = vec![vec![usize::MAX; n]; n];
    for i in 0..n {
        let mut gate: Vec<usize> =
            (0..n).filter(|&j| j != i).map(|j| up1[i][j]).collect();
        for j in 0..n {
            if j == i {
                continue;
            }
            let mut deps = gate.clone();
            deps.push(up1[j][i]); // the data must exist
            down2[i][j] = sim.add_flow_after(i, Dir::Down, split, deps, 0.0);
        }
        gate.clear();
    }
    // phase 3: upload merged split i (after all phase-2 downloads),
    // then download all other merged splits.
    let mut up3 = vec![usize::MAX; n];
    for i in 0..n {
        let deps: Vec<usize> =
            (0..n).filter(|&j| j != i).map(|j| down2[i][j]).collect();
        up3[i] = sim.add_flow_after(i, Dir::Up, split, deps, 0.0);
    }
    for i in 0..n {
        for j in 0..n {
            if j != i {
                sim.add_flow_after(i, Dir::Down, split, vec![up3[j], up3[i]], 0.0);
            }
        }
    }
    sim.run()
}

/// FuncPipe's pipelined scatter-reduce (Fig. 4(b), §3.3) as a flow
/// schedule: at step k worker i uploads split i+k while downloading its
/// own split uploaded by worker i−(k−1) at step k−1 — duplex.
pub fn simulate_pipelined_scatter_reduce(
    n: usize,
    grad_bytes: f64,
    model: &BandwidthModel,
) -> f64 {
    assert!(n >= 2);
    let split = grad_bytes / n as f64;
    let mut sim = FlowSim::new(model.clone());

    // uploads: up[i][k] for steps k = 1..=n-1 (upload split (i+k) mod n),
    // serialized on worker i's uplink in step order.
    let mut up = vec![vec![usize::MAX; n]; n];
    for i in 0..n {
        let mut prev: Option<usize> = None;
        for k in 1..n {
            let deps = prev.map(|p| vec![p]).unwrap_or_default();
            let id = if deps.is_empty() {
                sim.add_flow(i, Dir::Up, split, 0.0)
            } else {
                sim.add_flow_after(i, Dir::Up, split, deps, 0.0)
            };
            up[i][k] = id;
            prev = Some(id);
        }
    }
    // downloads: at step k (2..=n) worker i downloads split i uploaded by
    // worker (i - (k-1)) mod n at step k-1; serialized on i's downlink.
    let mut last = vec![usize::MAX; n];
    for i in 0..n {
        let mut prev: Option<usize> = None;
        for k in 2..=n {
            let src = (i + n - (k - 1)) % n;
            let mut deps = vec![up[src][k - 1]];
            if let Some(p) = prev {
                deps.push(p);
            }
            let id = sim.add_flow_after(i, Dir::Down, split, deps, 0.0);
            prev = Some(id);
            last[i] = id;
        }
    }
    // phase 3 (unchanged by the pipelining): upload merged split, then
    // fetch the n-1 other merged splits.
    let mut up3 = vec![usize::MAX; n];
    for i in 0..n {
        up3[i] = sim.add_flow_after(i, Dir::Up, split, vec![last[i]], 0.0);
    }
    for i in 0..n {
        for j in 0..n {
            if j != i {
                sim.add_flow_after(i, Dir::Down, split, vec![up3[j]], 0.0);
            }
        }
    }
    sim.run()
}

/// Chunked 3-phase scatter-reduce: the same schedule as
/// [`simulate_scatter_reduce`], but every split travels as
/// ⌈split/chunk⌉ flows serialized on their link, mirroring the real
/// chunked engine. With `latency == 0` this converges to the unchunked
/// makespan (same bytes on the same links behind the same barriers);
/// with latency it exposes the per-chunk operation overhead that
/// [`sync_time_chunked`](super::analytic::sync_time_chunked) models.
pub fn simulate_scatter_reduce_chunked(
    n: usize,
    grad_bytes: f64,
    model: &BandwidthModel,
    chunk_bytes: f64,
) -> f64 {
    assert!(n >= 2);
    let split = grad_bytes / n as f64;
    let nc = chunks_per_split(split, chunk_bytes);
    let chunk = split / nc as f64;
    let mut sim = FlowSim::new(model.clone());

    // phase 1: worker i's uplink carries its (n-1)*nc foreign-split
    // chunks, serialized; up1[i][j][c] indexed per split then chunk
    let mut up1 = vec![vec![vec![usize::MAX; nc]; n]; n];
    let mut last_up = vec![None::<usize>; n];
    for i in 0..n {
        for j in 0..n {
            if j == i {
                continue;
            }
            for c in 0..nc {
                let deps = last_up[i].map(|p| vec![p]).unwrap_or_default();
                let id = if deps.is_empty() {
                    sim.add_flow(i, Dir::Up, chunk, 0.0)
                } else {
                    sim.add_flow_after(i, Dir::Up, chunk, deps, 0.0)
                };
                up1[i][j][c] = id;
                last_up[i] = Some(id);
            }
        }
    }
    // phase 2: strictly after the worker's own phase-1 uploads (the
    // serialization of the plain algorithm), chunk flows serialized on
    // the downlink, each gated on the producing upload chunk
    let mut last_down = vec![None::<usize>; n];
    for i in 0..n {
        for j in 0..n {
            if j == i {
                continue;
            }
            for c in 0..nc {
                let mut deps = vec![last_up[i].expect("n>=2"), up1[j][i][c]];
                if let Some(p) = last_down[i] {
                    deps.push(p);
                }
                last_down[i] =
                    Some(sim.add_flow_after(i, Dir::Down, chunk, deps, 0.0));
            }
        }
    }
    // phase 3: merged-split chunks after the merge completes, then the
    // gathers, gated per chunk on the producing upload
    let mut up3 = vec![vec![usize::MAX; nc]; n];
    for i in 0..n {
        let mut prev = last_down[i];
        for c in 0..nc {
            let mut deps = vec![last_down[i].expect("n>=2")];
            if let Some(p) = prev {
                deps.push(p);
            }
            up3[i][c] = sim.add_flow_after(i, Dir::Up, chunk, deps, 0.0);
            prev = Some(up3[i][c]);
        }
    }
    for i in 0..n {
        let mut prev = Some(*up3[i].last().expect("nc>=1"));
        for j in 0..n {
            if j == i {
                continue;
            }
            for c in 0..nc {
                let mut deps = vec![up3[j][c]];
                if let Some(p) = prev {
                    deps.push(p);
                }
                prev = Some(sim.add_flow_after(i, Dir::Down, chunk, deps, 0.0));
            }
        }
    }
    sim.run()
}

/// Chunked pipelined scatter-reduce: chunk-granular duplex — download
/// chunk `c` of step `k` needs only upload chunk `c` of step `k-1`, so
/// the fill is one *chunk* rather than one split, exactly like the real
/// chunked engine (ack windows are not modelled; with symmetric
/// bandwidth they never bind).
pub fn simulate_pipelined_scatter_reduce_chunked(
    n: usize,
    grad_bytes: f64,
    model: &BandwidthModel,
    chunk_bytes: f64,
) -> f64 {
    assert!(n >= 2);
    let split = grad_bytes / n as f64;
    let nc = chunks_per_split(split, chunk_bytes);
    let chunk = split / nc as f64;
    let mut sim = FlowSim::new(model.clone());

    // reduce uploads: steps k=1..n-1, chunks serialized on the uplink
    let mut up = vec![vec![vec![usize::MAX; nc]; n]; n];
    let mut last_up = vec![None::<usize>; n];
    for i in 0..n {
        for k in 1..n {
            for c in 0..nc {
                let deps = last_up[i].map(|p| vec![p]).unwrap_or_default();
                let id = if deps.is_empty() {
                    sim.add_flow(i, Dir::Up, chunk, 0.0)
                } else {
                    sim.add_flow_after(i, Dir::Up, chunk, deps, 0.0)
                };
                up[i][k][c] = id;
                last_up[i] = Some(id);
            }
        }
    }
    // reduce downloads: at step k worker i pulls its own split's chunk c
    // uploaded by (i-(k-1)) at step k-1 — duplex at chunk granularity
    let mut last_down = vec![None::<usize>; n];
    for i in 0..n {
        for k in 2..=n {
            let src = (i + n - (k - 1)) % n;
            for c in 0..nc {
                let mut deps = vec![up[src][k - 1][c]];
                if let Some(p) = last_down[i] {
                    deps.push(p);
                }
                last_down[i] =
                    Some(sim.add_flow_after(i, Dir::Down, chunk, deps, 0.0));
            }
        }
    }
    // broadcast: merged chunks after the merge, then the gathers
    let mut up3 = vec![vec![usize::MAX; nc]; n];
    for i in 0..n {
        let mut prev = last_up[i];
        for c in 0..nc {
            let mut deps = vec![last_down[i].expect("n>=2")];
            if let Some(p) = prev {
                deps.push(p);
            }
            up3[i][c] = sim.add_flow_after(i, Dir::Up, chunk, deps, 0.0);
            prev = Some(up3[i][c]);
        }
    }
    for i in 0..n {
        let mut prev = last_down[i];
        for j in 0..n {
            if j == i {
                continue;
            }
            for c in 0..nc {
                let mut deps = vec![up3[j][c]];
                if let Some(p) = prev {
                    deps.push(p);
                }
                prev = Some(sim.add_flow_after(i, Dir::Down, chunk, deps, 0.0));
            }
        }
    }
    sim.run()
}

fn chunks_per_split(split_bytes: f64, chunk_bytes: f64) -> usize {
    if chunk_bytes <= 0.0 {
        return 1;
    }
    ((split_bytes / chunk_bytes).ceil() as usize).max(1)
}

/// HybridPS synchronization: workers push gradients directly to a VM
/// parameter server (worker index `n` in the model) and pull updated
/// parameters back.
pub fn simulate_parameter_server(
    n: usize,
    grad_bytes: f64,
    model: &BandwidthModel,
) -> f64 {
    assert!(model.n_workers() >= n + 1, "need server as worker n");
    let server = n;
    let mut sim = FlowSim::new(model.clone());
    let ups: Vec<usize> = (0..n)
        .map(|i| sim.add_direct_flow_after(i, server, grad_bytes, vec![], 0.0))
        .collect();
    // server applies update after all pushes, then each worker pulls.
    for i in 0..n {
        sim.add_direct_flow_after(server, i, grad_bytes, ups.clone(), 0.0);
    }
    sim.run()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collective::analytic::{
        ps_sync_time, sync_time, SyncAlgorithm,
    };

    const MB: f64 = 1.0e6;

    fn storage_model(n: usize, w: f64, lat: f64) -> BandwidthModel {
        BandwidthModel::uniform(n, w, lat)
    }

    #[test]
    fn plain_matches_eq1() {
        for n in [2usize, 4, 8] {
            let model = storage_model(n, 70.0 * MB, 0.0);
            let sim_t = simulate_scatter_reduce(n, 280.0 * MB, &model);
            let formula =
                sync_time(SyncAlgorithm::ScatterReduce, 280.0 * MB, n, 70.0 * MB, 0.0);
            let err = (sim_t - formula).abs() / formula;
            assert!(err < 0.12, "n={n}: sim {sim_t} vs eq(1) {formula}");
        }
    }

    #[test]
    fn pipelined_matches_eq2() {
        for n in [2usize, 4, 8, 16] {
            let model = storage_model(n, 70.0 * MB, 0.0);
            let sim_t =
                simulate_pipelined_scatter_reduce(n, 280.0 * MB, &model);
            let formula = sync_time(
                SyncAlgorithm::PipelinedScatterReduce,
                280.0 * MB,
                n,
                70.0 * MB,
                0.0,
            );
            let err = (sim_t - formula).abs() / formula;
            assert!(err < 0.12, "n={n}: sim {sim_t} vs eq(2) {formula}");
        }
    }

    #[test]
    fn pipelined_beats_plain_in_sim() {
        for n in [4usize, 8, 16] {
            let model = storage_model(n, 70.0 * MB, 0.02);
            let a = simulate_scatter_reduce(n, 300.0 * MB, &model);
            let b = simulate_pipelined_scatter_reduce(n, 300.0 * MB, &model);
            assert!(b < a, "n={n}: pipelined {b} !< plain {a}");
        }
    }

    #[test]
    fn ps_matches_formula_when_server_bound() {
        let n = 16;
        let mut model = storage_model(n + 1, 70.0 * MB, 0.0);
        model.up_bps[n] = 1.25e9;
        model.down_bps[n] = 1.25e9;
        let sim_t = simulate_parameter_server(n, 100.0 * MB, &model);
        // the flow sim models transfers only; subtract the analytic
        // server-side aggregation term before comparing
        let agg = n as f64 * 100.0 * MB
            / crate::collective::analytic::PS_SERVER_PROC_BPS;
        let formula = ps_sync_time(100.0 * MB, n, 70.0 * MB, 1.25e9, 0.0) - agg;
        let err = (sim_t - formula).abs() / formula;
        assert!(err < 0.15, "sim {sim_t} vs formula {formula}");
    }

    #[test]
    fn chunked_schedules_match_unchunked_at_zero_latency() {
        // same bytes, same links, same barriers: chunking must cost
        // nothing when storage operations are free
        for n in [2usize, 4, 8] {
            let model = storage_model(n, 70.0 * MB, 0.0);
            let s = 280.0 * MB;
            let plain = simulate_scatter_reduce(n, s, &model);
            for chunk in [4.0e6, 16.0e6] {
                let chunked =
                    simulate_scatter_reduce_chunked(n, s, &model, chunk);
                let err = (chunked - plain).abs() / plain;
                assert!(
                    err < 1e-5,
                    "plain n={n} chunk={chunk}: {chunked} vs {plain}"
                );
            }
        }
    }

    #[test]
    fn chunked_pipelined_is_never_slower_and_respects_occupancy() {
        for n in [2usize, 4, 8] {
            let model = storage_model(n, 70.0 * MB, 0.0);
            let s = 280.0 * MB;
            let unchunked = simulate_pipelined_scatter_reduce(n, s, &model);
            for chunk in [2.0e6, 8.0e6] {
                let chunked = simulate_pipelined_scatter_reduce_chunked(
                    n, s, &model, chunk,
                );
                // finer fill can only help...
                assert!(
                    chunked <= unchunked * (1.0 + 1e-9),
                    "n={n} chunk={chunk}: {chunked} > {unchunked}"
                );
                // ...but every worker still moves s bytes up its link
                let occupancy_floor = s / (70.0 * MB);
                assert!(chunked >= occupancy_floor * (1.0 - 1e-9));
            }
        }
    }

    #[test]
    fn chunk_latency_overhead_visible_in_sim() {
        // with real per-operation latency, smaller chunks mean more
        // serialized storage ops on each link
        let n = 4;
        let model = storage_model(n, 70.0 * MB, 0.02);
        let s = 80.0 * MB;
        let coarse =
            simulate_pipelined_scatter_reduce_chunked(n, s, &model, 10.0e6);
        let fine =
            simulate_pipelined_scatter_reduce_chunked(n, s, &model, 1.0e6);
        assert!(fine > coarse, "fine {fine} !> coarse {coarse}");
    }

    #[test]
    fn aggregate_cap_slows_scatter_reduce() {
        let n = 8;
        let free = storage_model(n, 100.0 * MB, 0.0);
        let capped = storage_model(n, 100.0 * MB, 0.0)
            .with_aggregate_cap(200.0 * MB);
        let a = simulate_pipelined_scatter_reduce(n, 100.0 * MB, &free);
        let b = simulate_pipelined_scatter_reduce(n, 100.0 * MB, &capped);
        assert!(b > a * 1.5, "cap should slow things: {a} vs {b}");
    }
}
