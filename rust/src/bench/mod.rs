//! Figure/table regeneration harness — one function per table AND figure
//! of the paper's evaluation (§5). Each *returns* the same rows/series the
//! paper reports as [`Table`] values, rendered through the CLI's
//! `Report` path (`experiment::TableSet`) — so `funcpipe fig <id>
//! --format table|json`, `cargo bench` and library callers all consume
//! identical output through one path. DESIGN.md §5 maps ids→modules.

use crate::baselines::{evaluate_baseline, BaselineKind};
use crate::collective::{self, SyncAlgorithm};
use crate::model::{merge_layers, zoo, MergeCriterion, ModelProfile, Plan};
use crate::pipeline::rel_err_pct;
use crate::pipeline::simulate::simulate_iteration_noisy;
use crate::planner::{
    solve_request, PerfModel, PlanCandidate, PlanOutcome, PlanRequest,
    DEFAULT_WEIGHTS,
};
use crate::platform::network::BandwidthModel;
use crate::platform::pricing::{C5_9XLARGE, P3_2XLARGE, R7_2XLARGE};
use crate::platform::PlatformSpec;
use crate::serve::{serve_plan, ServeOptions, TrafficSpec};
use crate::util::humansize::{secs, usd};
use crate::util::table::{pct_change, speedup, Table};

fn model_for(name: &str, platform: &PlatformSpec, layers: usize) -> ModelProfile {
    merge_layers(
        &zoo::by_name(name, platform).expect("zoo model"),
        layers,
        MergeCriterion::Compute,
    )
}

/// Solve the default weight sweep through the strategy registry — how
/// every figure reproduction plans since the `Planner` redesign (the
/// paper's own numbers come from the exact `bnb` co-optimizer).
fn strategy_outcome(
    name: &str,
    model: &ModelProfile,
    platform: &PlatformSpec,
    global_batch: usize,
    weights: &[(f64, f64)],
) -> PlanOutcome {
    let perf = PerfModel::new(model, platform);
    let mut req = PlanRequest::new(global_batch / zoo::MICRO_BATCH);
    req.weights = weights.to_vec();
    solve_request(name, &perf, &req).expect("registry strategy")
}

fn funcpipe_plan(
    model: &ModelProfile,
    platform: &PlatformSpec,
    global_batch: usize,
) -> PlanOutcome {
    strategy_outcome("bnb", model, platform, global_batch, &DEFAULT_WEIGHTS)
}

/// The single best candidate of `strategy` under one weight pair.
fn strategy_best(
    name: &str,
    model: &ModelProfile,
    platform: &PlatformSpec,
    global_batch: usize,
    alpha: (f64, f64),
) -> Option<PlanCandidate> {
    strategy_outcome(name, model, platform, global_batch, &[alpha])
        .candidates
        .into_iter()
        .next()
}

/// Fig. 1: (a) LambdaML's communication bottleneck on AmoebaNet-D36 with
/// 8 workers; (b) three configurations (TPDMP=B1, Bayes=B2, FuncPipe).
pub fn fig1() -> Vec<Table> {
    let mut out = Vec::new();
    let p = PlatformSpec::aws_lambda();
    let m = zoo::amoebanet_d36(&p);

    let mut t = Table::new(
        "Fig 1(a) — LambdaML on AmoebaNet-D36, 8 workers (per iteration)",
    )
    .header(["local batch", "computation", "communication", "comm/comp"]);
    for (gb, n) in [(64usize, 8usize), (256, 8)] {
        // force 8 workers as in the figure
        let local = gb / n;
        let tier = p.max_tier();
        let per_micro = m.total_fwd_s(tier) + m.total_bwd_s(tier);
        let compute = p.beta * per_micro * local as f64 / zoo::MICRO_BATCH as f64;
        let comm = collective::sync_time(
            SyncAlgorithm::ScatterReduce,
            m.total_param_bytes() as f64,
            n,
            p.effective_bandwidth(tier, n),
            p.storage.latency_s,
        );
        t.row([
            local.to_string(),
            secs(compute),
            secs(comm),
            format!("{:.2}", comm / compute),
        ]);
    }
    out.push(t);

    let mb = merge_layers(&m, 8, MergeCriterion::Compute);
    let alpha = (1.0, 2e-4);
    let gb = 64;
    let mut t = Table::new("Fig 1(b) — optimized configurations, D36 batch 64")
        .header(["config", "iter time", "iter cost"]);
    for (label, strategy) in
        [("B1 (TPDMP)", "tpdmp"), ("B2 (Bayes)", "bayes"), ("FuncPipe", "bnb")]
    {
        if let Some(c) = strategy_best(strategy, &mb, &p, gb, alpha) {
            t.row([
                label.to_string(),
                secs(c.perf.t_iter),
                usd(c.perf.c_iter),
            ]);
        }
    }
    out.push(t);
    out
}

/// Fig. 5: overall performance — 4 models × batch {16, 64, 256},
/// FuncPipe Pareto points + recommendation vs the four baselines.
pub fn fig5() -> Vec<Table> {
    let mut out = Vec::new();
    let p = PlatformSpec::aws_lambda();
    for name in zoo::MODEL_NAMES {
        let zoo_m = zoo::by_name(name, &p).unwrap();
        let m = model_for(name, &p, 8);
        for gb in [16usize, 64, 256] {
            let mut t = Table::new(format!(
                "Fig 5 — {name}, global batch {gb} (AWS)"
            ))
            .header(["design", "t_iter", "c_iter", "vs best baseline"]);
            let mut best_base: Option<f64> = None;
            for kind in BaselineKind::ALL {
                if let Some(r) =
                    evaluate_baseline(kind, &zoo_m, &p, gb, C5_9XLARGE)
                {
                    best_base = Some(
                        best_base.map_or(r.t_iter, |b: f64| b.min(r.t_iter)),
                    );
                    t.row([
                        kind.name().to_string(),
                        secs(r.t_iter),
                        usd(r.c_iter),
                        String::new(),
                    ]);
                } else {
                    t.row([
                        kind.name().to_string(),
                        "OOM".into(),
                        String::new(),
                        String::new(),
                    ]);
                }
            }
            let outcome = funcpipe_plan(&m, &p, gb);
            let rec = outcome.recommend_idx();
            let flags = outcome.frontier_flags();
            for (i, pt) in outcome
                .candidates
                .iter()
                .enumerate()
                .filter(|(i, _)| flags[*i])
            {
                let is_rec = rec == Some(i);
                let cmp = if is_rec {
                    best_base
                        .map(|b| speedup(b, pt.perf.t_iter))
                        .unwrap_or_default()
                } else {
                    String::new()
                };
                t.row([
                    if is_rec {
                        "FuncPipe (recommended)".to_string()
                    } else {
                        format!(
                            "FuncPipe (α2={})",
                            pt.weights.1
                        )
                    },
                    secs(pt.perf.t_iter),
                    usd(pt.perf.c_iter),
                    cmp,
                ]);
            }
            out.push(t);
        }
    }
    out
}

/// Fig. 6: training-time breakdown (computation / pipeline flush /
/// synchronization).
pub fn fig6() -> Vec<Table> {
    let mut out = Vec::new();
    let p = PlatformSpec::aws_lambda();
    let cases = [
        ("bert-large", 16usize),
        ("resnet101", 64),
        ("bert-large", 64),
        ("amoebanet-d36", 64),
    ];
    for (name, gb) in cases {
        let zoo_m = zoo::by_name(name, &p).unwrap();
        let m = model_for(name, &p, 8);
        let mut t = Table::new(format!("Fig 6 — breakdown, {name} batch {gb}"))
            .header(["design", "compute", "flush", "sync", "total"]);
        let outcome = funcpipe_plan(&m, &p, gb);
        for pt in outcome.frontier() {
            t.row([
                format!("FuncPipe α2={}", pt.weights.1),
                secs(pt.perf.compute_s),
                secs(pt.perf.flush_s),
                secs(pt.perf.sync_s),
                secs(pt.perf.t_iter),
            ]);
        }
        for kind in [BaselineKind::LambdaML, BaselineKind::HybridPS] {
            if let Some(r) = evaluate_baseline(kind, &zoo_m, &p, gb, C5_9XLARGE)
            {
                t.row([
                    kind.name().to_string(),
                    secs(r.compute_s),
                    "-".to_string(),
                    secs(r.sync_s),
                    secs(r.t_iter),
                ]);
            }
        }
        out.push(t);
    }
    out
}

/// Fig. 7: scalability — normalized throughput vs total allocated memory
/// as the global batch grows, FuncPipe vs LambdaML.
pub fn fig7() -> Vec<Table> {
    let mut out = Vec::new();
    let p = PlatformSpec::aws_lambda();
    for name in ["amoebanet-d18", "amoebanet-d36"] {
        let zoo_m = zoo::by_name(name, &p).unwrap();
        let m = model_for(name, &p, 8);
        let mut t = Table::new(format!("Fig 7 — scalability, {name}"))
            .header([
                "global batch",
                "design",
                "total mem (GB)",
                "throughput (samples/s)",
                "normalized",
            ]);
        let mut norm: Option<f64> = None;
        for gb in [32usize, 64, 128, 256, 512, 1024] {
            if let Some(r) = evaluate_baseline(
                BaselineKind::LambdaML,
                &zoo_m,
                &p,
                gb,
                C5_9XLARGE,
            ) {
                let thr = r.throughput(gb);
                let n0 = *norm.get_or_insert(thr);
                t.row([
                    gb.to_string(),
                    "LambdaML".into(),
                    format!(
                        "{:.0}",
                        r.n_workers as f64 * p.tier(r.tier).mem_gb()
                    ),
                    format!("{thr:.2}"),
                    format!("{:.2}", thr / n0),
                ]);
            }
            let outcome = funcpipe_plan(&m, &p, gb);
            if let Some(rec) = outcome.recommended() {
                let thr = rec.perf.throughput(gb);
                let n0 = *norm.get_or_insert(thr);
                t.row([
                    gb.to_string(),
                    "FuncPipe".into(),
                    format!("{:.0}", rec.perf.total_mem_gb),
                    format!("{thr:.2}"),
                    format!("{:.2}", thr / n0),
                ]);
            }
        }
        out.push(t);
    }
    out
}

/// Fig. 8: pipelined vs non-pipelined scatter-reduce as the data-parallel
/// degree grows (D18, 3-stage plan) — training throughput and sync time,
/// plus the chunked engine's model/flowsim columns (4 MB chunks).
pub fn fig8() -> Vec<Table> {
    let p = PlatformSpec::aws_lambda();
    let m = model_for("amoebanet-d18", &p, 6);
    // the recommended 3-stage shape from §5.5 (d starts at 2)
    let cuts = vec![1usize, 3];
    let tiers = vec![p.max_tier(); 3];
    let chunk_bytes = 4usize << 20;
    let mut t = Table::new(
        "Fig 8 — scatter-reduce: pipelined vs plain (D18, 3 stages; chunked = 4 MB flows)",
    )
    .header([
        "dp",
        "sync plain (model)",
        "sync piped (model)",
        "sync piped-chunked (model)",
        "sync plain (flowsim)",
        "sync piped (flowsim)",
        "sync piped-chunked (flowsim)",
        "sync cut",
        "throughput gain",
    ]);
    for dp in [2usize, 4, 8, 16, 32] {
        let plan = Plan {
            cuts: cuts.clone(),
            dp,
            stage_tiers: tiers.clone(),
            n_micro_global: 8 * dp, // batch grows with dp (§5.5)
        };
        let pm_plain =
            PerfModel::new(&m, &p).with_sync(SyncAlgorithm::ScatterReduce);
        let pm_piped = PerfModel::new(&m, &p);
        let pm_chunked = PerfModel::new(&m, &p).with_chunk_bytes(chunk_bytes);
        let perf_plain = pm_plain.evaluate(&plan);
        let perf_piped = pm_piped.evaluate(&plan);
        let perf_chunked = pm_chunked.evaluate(&plan);

        // flow-level simulation of the biggest stage's sync
        let (lo, hi) = plan.stage_ranges(m.n_layers())[2];
        let grad = m.range_param_bytes(lo, hi) as f64;
        let w = p.effective_bandwidth(p.max_tier(), plan.n_workers());
        let net = BandwidthModel::uniform(dp, w, p.storage.latency_s);
        let sim_plain =
            collective::sim::simulate_scatter_reduce(dp, grad, &net);
        let sim_piped =
            collective::sim::simulate_pipelined_scatter_reduce(dp, grad, &net);
        let sim_chunked =
            collective::sim::simulate_pipelined_scatter_reduce_chunked(
                dp,
                grad,
                &net,
                chunk_bytes as f64,
            );

        t.row([
            dp.to_string(),
            secs(perf_plain.sync_s),
            secs(perf_piped.sync_s),
            secs(perf_chunked.sync_s),
            secs(sim_plain),
            secs(sim_piped),
            secs(sim_chunked),
            pct_change(perf_plain.sync_s, perf_piped.sync_s),
            // throughput gain = t_plain / t_piped
            speedup(perf_plain.t_iter, perf_piped.t_iter),
        ]);
    }
    vec![t]
}

/// Fig. 9 + §5.6: co-optimization vs TPDMP vs Bayes (batch 64), with
/// solution times.
pub fn fig9() -> Vec<Table> {
    let mut out = Vec::new();
    let p = PlatformSpec::aws_lambda();
    let alpha_list = DEFAULT_WEIGHTS;
    let mut solve_times = (0.0f64, 0.0f64, 0.0f64);
    for name in zoo::MODEL_NAMES {
        let m = model_for(name, &p, 8);
        let n_micro = 64 / zoo::MICRO_BATCH;
        let mut t = Table::new(format!("Fig 9 — co-opt comparison, {name} batch 64"))
            .header(["optimizer", "weights α2", "t_iter", "c_iter"]);
        let gb = n_micro * zoo::MICRO_BATCH;
        for alpha in alpha_list {
            for (slot, label, strategy) in
                [(0, "FuncPipe", "bnb"), (1, "TPDMP", "tpdmp"), (2, "Bayes", "bayes")]
            {
                let t0 = std::time::Instant::now();
                if let Some(c) = strategy_best(strategy, &m, &p, gb, alpha) {
                    t.row([
                        label.to_string(),
                        format!("{}", alpha.1),
                        secs(c.perf.t_iter),
                        usd(c.perf.c_iter),
                    ]);
                }
                let dt = t0.elapsed().as_secs_f64();
                match slot {
                    0 => solve_times.0 += dt,
                    1 => solve_times.1 += dt,
                    _ => solve_times.2 += dt,
                }
            }
        }
        out.push(t);
    }
    let n = (zoo::MODEL_NAMES.len() * alpha_list.len()) as f64;
    let mut t = Table::new("§5.6 — average solution time per configuration")
        .header(["optimizer", "avg solve time"]);
    t.row(["FuncPipe (B&B)".to_string(), secs(solve_times.0 / n)]);
    t.row(["TPDMP (grid)".to_string(), secs(solve_times.1 / n)]);
    t.row(["Bayes (100 rounds)".to_string(), secs(solve_times.2 / n)]);
    out.push(t);
    out
}

/// Fig. 10: Alibaba Cloud — shared 10 Gb/s OSS cap; ResNet101 & D36 at
/// batch 64/256; HybridPS is the strongest baseline there (§5.7).
pub fn fig10() -> Vec<Table> {
    let mut out = Vec::new();
    let p = PlatformSpec::alibaba_fc();
    for name in ["resnet101", "amoebanet-d36"] {
        let zoo_m = zoo::by_name(name, &p).unwrap();
        let m = model_for(name, &p, 8);
        for gb in [64usize, 256] {
            let mut t = Table::new(format!(
                "Fig 10 — Alibaba FC, {name} batch {gb}"
            ))
            .header(["design", "t_iter", "c_iter"]);
            for kind in BaselineKind::ALL {
                if let Some(r) =
                    evaluate_baseline(kind, &zoo_m, &p, gb, R7_2XLARGE)
                {
                    t.row([
                        kind.name().to_string(),
                        secs(r.t_iter),
                        usd(r.c_iter),
                    ]);
                }
            }
            let outcome = funcpipe_plan(&m, &p, gb);
            if let Some(rec) = outcome.recommended() {
                t.row([
                    "FuncPipe (recommended)".to_string(),
                    secs(rec.perf.t_iter),
                    usd(rec.perf.c_iter),
                ]);
            }
            out.push(t);
        }
    }
    // serving replay on the same platform: the recommended ResNet101
    // plan driven by the authored Alibaba minute-level trace
    // (`serve::arrivals::ALIBABA_TRACE_PER_MIN` — the ONE source
    // `serve --traffic alibaba` replays byte-identically)
    let m = model_for("resnet101", &p, 8);
    let outcome = funcpipe_plan(&m, &p, 64);
    if let Some(rec) = outcome.recommended() {
        let perf = PerfModel::new(&m, &p);
        let mut t = Table::new(
            "Fig 10 (serving) — ResNet101 plan replayed under the \
             Alibaba trace",
        )
        .header([
            "traffic", "seed", "p50", "p99", "achieved req/min", "cold %",
            "$/1k req",
        ]);
        for mean in [600.0f64, 2400.0] {
            let mut opts = ServeOptions::new(
                TrafficSpec::Alibaba { mean_per_min: mean },
                7,
            );
            opts.duration_s = 30.0;
            if let Ok(o) = serve_plan(&perf, &rec.plan, &opts) {
                t.row([
                    opts.traffic.name(),
                    opts.seed.to_string(),
                    format!("{:.1}ms", o.p50_ms),
                    format!("{:.1}ms", o.p99_ms),
                    format!("{:.0}", o.achieved_rpm),
                    format!("{:.1}%", o.cold_start_rate * 100.0),
                    usd(o.cost_per_1k_usd),
                ]);
            }
        }
        out.push(t);
    }
    out
}

/// Fig. 11: iteration time/cost as function bandwidth scales 1×..20×,
/// plus the GPU reference points.
pub fn fig11() -> Vec<Table> {
    let mut out = Vec::new();
    for name in zoo::MODEL_NAMES {
        let mut t = Table::new(format!(
            "Fig 11 — bandwidth sweep, {name} batch 64"
        ))
        .header(["bandwidth", "design", "t_iter", "c_iter"]);
        for scale in [1.0f64, 2.0, 4.0, 8.0, 20.0] {
            let p = PlatformSpec::aws_lambda().with_bandwidth_scale(scale);
            let zoo_m = zoo::by_name(name, &p).unwrap();
            let m = model_for(name, &p, 8);
            if let Some(r) = evaluate_baseline(
                BaselineKind::LambdaML,
                &zoo_m,
                &p,
                64,
                C5_9XLARGE,
            ) {
                t.row([
                    format!("{scale}x"),
                    "LambdaML".into(),
                    secs(r.t_iter),
                    usd(r.c_iter),
                ]);
            }
            let outcome = funcpipe_plan(&m, &p, 64);
            if let Some(rec) = outcome.recommended() {
                t.row([
                    format!("{scale}x"),
                    "FuncPipe".into(),
                    secs(rec.perf.t_iter),
                    usd(rec.perf.c_iter),
                ]);
            }
        }
        // GPU reference points: V100 VM + (announced) GPU function pricing.
        // A V100 processes ~20x the samples/s of a 6-vCPU function for
        // these models (paper: per-sample cost gap "tens of times").
        let p = PlatformSpec::aws_lambda();
        let zoo_m = zoo::by_name(name, &p).unwrap();
        let per_micro =
            zoo_m.total_fwd_s(p.max_tier()) + zoo_m.total_bwd_s(p.max_tier());
        let gpu_t = per_micro * (64 / zoo::MICRO_BATCH) as f64 / 20.0;
        t.row([
            "—".into(),
            "VM GPU (V100, grad-accum)".into(),
            secs(gpu_t),
            usd(P3_2XLARGE.cost(gpu_t)),
        ]);
        t.row([
            "—".into(),
            "GPU function (est.)".into(),
            secs(gpu_t * 1.1),
            usd(P3_2XLARGE.cost(gpu_t) * 1.3),
        ]);
        out.push(t);
    }
    out
}

/// Table 3: performance-model prediction error, validated against the
/// discrete-event simulator on the recommended plans.
pub fn table3() -> Vec<Table> {
    let p = PlatformSpec::aws_lambda();
    let mut t = Table::new(
        "Table 3 — perf-model vs DES prediction error (t_iter)",
    )
    .header(["model", "bs16", "bs64", "bs256", "average"]);
    let mut grand = Vec::new();
    for name in zoo::MODEL_NAMES {
        let m = model_for(name, &p, 8);
        let mut row = vec![name.to_string()];
        let mut errs = Vec::new();
        for gb in [16usize, 64, 256] {
            // average over every Pareto-sweep plan (single-worker plans
            // match the DES trivially; multi-stage/multi-dp ones are the
            // interesting prediction targets)
            let outcome = funcpipe_plan(&m, &p, gb);
            if outcome.candidates.is_empty() {
                row.push("-".into());
                continue;
            }
            let mut cell_errs = Vec::new();
            for (i, pt) in outcome.candidates.iter().enumerate() {
                // jittered DES = "measured" (σ=15% bandwidth variation,
                // the phenomenon the paper blames for its errors)
                let sim = simulate_iteration_noisy(
                    &m,
                    &p,
                    &pt.plan,
                    SyncAlgorithm::PipelinedScatterReduce,
                    Some((0xBEEF ^ (gb as u64) << 8 ^ i as u64, 0.15)),
                );
                cell_errs.push(rel_err_pct(pt.perf.t_iter, sim.t_iter));
            }
            let err =
                cell_errs.iter().sum::<f64>() / cell_errs.len() as f64;
            errs.push(err);
            grand.push(err);
            row.push(format!("{err:.1}%"));
        }
        row.push(format!(
            "{:.1}%",
            errs.iter().sum::<f64>() / errs.len().max(1) as f64
        ));
        t.row(row);
    }
    t.row(vec![
        "average".to_string(),
        String::new(),
        String::new(),
        String::new(),
        format!(
            "{:.1}%",
            grand.iter().sum::<f64>() / grand.len().max(1) as f64
        ),
    ]);
    vec![t]
}

/// Fleet demo (`funcpipe fig fleet` — no paper counterpart): a mixed
/// multi-tenant roster of two training jobs and one serving deployment,
/// all ResNet101 plans, contending for ONE shared AWS platform under
/// the cold-start-storm lens. Shows queueing (staggered submits),
/// cross-tenant bandwidth contention and per-tenant accounting through
/// the same [`FleetReport`](crate::experiment::FleetReport) path the
/// `fleet` subcommand renders; deterministic per (roster, scenario,
/// seed) like every other table here.
pub fn fleet_demo() -> Vec<Table> {
    use crate::config::ExperimentConfig;
    use crate::experiment::{Experiment, Report};
    use crate::fleet::{FleetSpec, TenantKind, TenantSpec};
    use crate::simcore::ScenarioSpec;

    let artifact = |batch: usize| {
        let cfg = ExperimentConfig {
            model: "resnet101".into(),
            global_batch: batch,
            merge_layers: 4,
            ..ExperimentConfig::default()
        };
        Experiment::new(cfg)
            .expect("session")
            .plan()
            .expect("plan")
            .recommended()
            .expect("recommended plan")
            .artifact
            .clone()
    };
    let a16 = artifact(16);
    let a64 = artifact(64);
    let spec = FleetSpec {
        tenants: vec![
            TenantSpec {
                name: "train-a".into(),
                kind: TenantKind::Train { steps: 30 },
                artifact: a16.clone(),
                submit_s: 0.0,
            },
            TenantSpec {
                name: "train-b".into(),
                kind: TenantKind::Train { steps: 20 },
                artifact: a64,
                submit_s: 5.0,
            },
            TenantSpec {
                name: "serve-a".into(),
                kind: TenantKind::Serve {
                    traffic: TrafficSpec::parse("poisson:600")
                        .expect("traffic spec"),
                    duration_s: 20.0,
                    seed: 7,
                },
                artifact: a16,
                submit_s: 10.0,
            },
        ],
        max_concurrency: None,
    };
    let scenario =
        ScenarioSpec::parse("cold-start-storm").expect("scenario spec");
    Experiment::fleet(&spec, &scenario, 7)
        .expect("fleet run")
        .to_tables()
}

/// Quick sanity used by tests: the headline Fig 5 comparison for one case.
pub fn headline_comparison(
    name: &str,
    gb: usize,
) -> Option<(f64, f64, f64, f64)> {
    let p = PlatformSpec::aws_lambda();
    let zoo_m = zoo::by_name(name, &p)?;
    let m = model_for(name, &p, 8);
    let base = evaluate_baseline(BaselineKind::LambdaML, &zoo_m, &p, gb, C5_9XLARGE)?;
    let outcome = funcpipe_plan(&m, &p, gb);
    let rec = outcome.recommended()?;
    Some((base.t_iter, base.c_iter, rec.perf.t_iter, rec.perf.c_iter))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn headline_speedup_in_paper_band() {
        // Fig 5: 1.3x-2.2x speedup and cost reduction vs LambdaML on the
        // larger models/batches — check the *shape*: FuncPipe faster and
        // cheaper on D36/BERT at batch 256.
        for name in ["amoebanet-d36", "bert-large"] {
            let (bt, bc, ft, fc) = headline_comparison(name, 256).unwrap();
            let sp = bt / ft;
            assert!(sp > 1.2, "{name}: speedup only {sp:.2}");
            assert!(fc < bc, "{name}: cost {fc} !< {bc}");
        }
    }

    #[test]
    fn small_batch_is_comparable() {
        // Fig 5 second observation: at batch 16 existing designs are
        // already cost-efficient; FuncPipe should be comparable (not
        // dramatically cheaper).
        let (_, bc, _, fc) = headline_comparison("resnet101", 16).unwrap();
        assert!(fc <= bc * 1.25, "FuncPipe {fc} ≫ LambdaML {bc}");
    }

}
