//! Synthetic byte-level corpus with Zipfian unigram statistics and a
//! deterministic next-token structure, standing in for Wikitext-2
//! (DESIGN.md §3: the paper's metric is per-iteration time/cost, which is
//! data-independent; the corpus only needs to make the LM loss fall).
//!
//! Token stream: a degree-2 Markov chain over the vocabulary whose
//! transition rows are Zipf-distributed with deterministic per-state
//! permutations — compressible structure a small transformer learns
//! quickly, generated identically on every worker from (seed, step,
//! replica, micro-batch) without any data movement.

use crate::util::rng::{Rng, ZipfSampler};

#[derive(Debug, Clone)]
pub struct Corpus {
    vocab: usize,
    seq_len: usize,
    micro_batch: usize,
    seed: u64,
    zipf: ZipfSampler,
}

impl Corpus {
    pub fn new(vocab: usize, seq_len: usize, micro_batch: usize, seed: u64) -> Self {
        Self {
            vocab,
            seq_len,
            micro_batch,
            seed,
            zipf: ZipfSampler::new(vocab, 2.0),
        }
    }

    /// Deterministic (tokens, targets) for a micro-batch. Targets are the
    /// next-token shift of the sequence.
    pub fn batch(
        &self,
        step: usize,
        replica: usize,
        mb: usize,
    ) -> (Vec<i32>, Vec<i32>) {
        let mut tokens = Vec::with_capacity(self.micro_batch * self.seq_len);
        let mut targets = Vec::with_capacity(self.micro_batch * self.seq_len);
        for row in 0..self.micro_batch {
            let mut rng = Rng::new(
                self.seed
                    ^ (step as u64) << 32
                    ^ (replica as u64) << 20
                    ^ (mb as u64) << 10
                    ^ row as u64,
            );
            let mut seq = Vec::with_capacity(self.seq_len + 1);
            let mut state = rng.index(self.vocab);
            for _ in 0..=self.seq_len {
                seq.push(state as i32);
                // markov step: rank from zipf, mapped through a per-state
                // deterministic permutation (multiplicative hash)
                let rank = self.zipf.sample(&mut rng);
                state = (state
                    .wrapping_mul(31)
                    .wrapping_add(rank.wrapping_mul(17))
                    .wrapping_add(7))
                    % self.vocab;
            }
            tokens.extend_from_slice(&seq[..self.seq_len]);
            targets.extend_from_slice(&seq[1..=self.seq_len]);
        }
        (tokens, targets)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_calls() {
        let c = Corpus::new(256, 32, 4, 42);
        assert_eq!(c.batch(3, 1, 2), c.batch(3, 1, 2));
        assert_ne!(c.batch(3, 1, 2), c.batch(4, 1, 2));
        assert_ne!(c.batch(3, 1, 2), c.batch(3, 0, 2));
    }

    #[test]
    fn targets_are_shifted_tokens() {
        let c = Corpus::new(64, 16, 2, 1);
        let (tok, tgt) = c.batch(0, 0, 0);
        assert_eq!(tok.len(), 32);
        // within each row, target[i] == token[i+1]
        for row in 0..2 {
            for i in 0..15 {
                assert_eq!(tgt[row * 16 + i], tok[row * 16 + i + 1]);
            }
        }
    }

    #[test]
    fn tokens_in_vocab_range() {
        let c = Corpus::new(100, 8, 2, 9);
        let (tok, tgt) = c.batch(5, 0, 1);
        assert!(tok.iter().chain(tgt.iter()).all(|&t| (0..100).contains(&t)));
    }

    #[test]
    fn bigrams_are_predictable() {
        // the learnable signal is conditional: given the current token,
        // the most frequent successor should dominate (Zipf-2 ranks make
        // rank-0 the clear mode), even though unigram marginals stay flat
        let c = Corpus::new(64, 64, 4, 3);
        let mut bigram = vec![vec![0usize; 64]; 64];
        for step in 0..200 {
            let (tok, tgt) = c.batch(step, 0, 0);
            for (a, b) in tok.iter().zip(&tgt) {
                bigram[*a as usize][*b as usize] += 1;
            }
        }
        let mut top = 0usize;
        let mut total = 0usize;
        for row in &bigram {
            let s: usize = row.iter().sum();
            if s >= 20 {
                top += row.iter().max().unwrap();
                total += s;
            }
        }
        assert!(total > 0);
        let frac = top as f64 / total as f64;
        assert!(frac > 0.4, "bigrams not predictable: {frac:.3}");
    }

}
