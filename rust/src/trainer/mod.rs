//! End-to-end training driver: configuration, synthetic corpus and the
//! public `train()` entry point. The distributed execution itself lives
//! in [`coordinator`](crate::coordinator).
//!
//! [`TrainConfig`] is the trainer's *internal* runtime configuration.
//! Users drive training through the unified
//! [`ExperimentConfig`](crate::config::ExperimentConfig) and the
//! [`Experiment`](crate::experiment::Experiment) facade, which derives a
//! `TrainConfig` from the config plus the plan artifact
//! (`Experiment::train_config`): `dp`/`mu` come from the plan, the
//! session knobs (steps, lr, lifetime, throttle, chunking) from the
//! config, and explicit overrides win over both.

pub mod data;

use std::path::PathBuf;
use std::sync::Arc;

use anyhow::Result;

use crate::collective::{Chunking, SyncAlgorithm};
use crate::coordinator::leader::run_training;
use crate::coordinator::worker::WorkerStats;
use crate::platform::MemStore;
use crate::simcore::ScenarioSpec;

/// Configuration for a real training run over the AOT artifacts.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub artifacts_dir: PathBuf,
    /// Data-parallel degree (uniform across stages, §3.4.1).
    pub dp: usize,
    /// Micro-batches per worker per iteration (μ).
    pub mu: usize,
    pub steps: usize,
    pub lr: f32,
    pub seed: u64,
    /// Per-worker storage throttle: (bytes/s, latency seconds). `None` =
    /// full speed (pure-compute runs).
    pub throttle: Option<(f64, f64)>,
    /// Simulated function lifetime; workers checkpoint+restart when their
    /// remaining lifetime drops below the margin (§3.1 step 8).
    pub lifetime_s: f64,
    pub checkpoint_margin_s: f64,
    pub sync_alg: SyncAlgorithm,
    /// Chunked streaming policy for the gradient collectives
    /// (`Chunking::NONE` = whole splits, the classic behaviour).
    pub chunking: Chunking,
    /// Scenario lens for the real path (the same seeded draws the
    /// simulator applies): per-worker storage perturbation + cold-start
    /// delays through the [`Injector`](crate::scenario::Injector).
    pub scenario: ScenarioSpec,
    /// Seed for the scenario draws (independent of the data `seed`, so
    /// changing the lens never changes the corpus).
    pub scenario_seed: u64,
    /// Base cold-start charge per function generation, seconds — the
    /// platform tier's `cold_start_s` when driven through
    /// [`Experiment::train_config`](crate::experiment::Experiment::train_config)
    /// (replaces the historical hardcoded 10 ms sleep; the default
    /// matches the local-sim tier).
    pub cold_start_s: f64,
    /// When set, the function lifecycle and the reported timeline run
    /// on a deterministic virtual clock: each iteration advances every
    /// worker's age by the pipeline-gated tick — `virtual_iter_s ×` the
    /// slowest worker's compute lens, the same duration the report logs
    /// per step — instead of wall time, so restart counts, generations
    /// and the whole report replay bit-identically under a fixed
    /// `(scenario, seed)`. `Experiment::train_config` enables this
    /// whenever a scenario is active, seeding it with the plan's
    /// predicted `t_iter`.
    pub virtual_iter_s: Option<f64>,
    /// Contiguous manifest-layer range `[lo, hi)` each pipeline stage
    /// executes. Empty = the historical 1:1 grouping (one manifest
    /// layer per stage). An elastic migration re-groups layers here so
    /// a new plan's stage count can differ from the manifest's.
    pub layer_groups: Vec<(usize, usize)>,
    /// Global step number of this segment's first local step. Elastic
    /// re-planning splits a run into per-plan segments; the offset
    /// keeps the corpus schedule, boundary keys and report step numbers
    /// continuous across the migration.
    pub step_offset: usize,
    /// Plan generation of this segment (0 = the initial plan). Key
    /// namespace of the layer-addressed checkpoint shards; a segment
    /// with `plan_generation > 0` restores the previous generation's
    /// migration shards before spawning workers (and consumes them).
    pub plan_generation: u64,
    /// When set, `virtual_iter_s` is already the calibrated
    /// pipeline-gated tick (observed-time based): the per-step virtual
    /// advance uses it verbatim instead of re-stretching the base by
    /// the scenario lens. Post-migration segments run calibrated —
    /// their tick came from measured times, which subsume the lens.
    pub calibrated_tick: bool,
    /// Quiesce for migration at the end of this segment: after the last
    /// step, replica 0 of every stage writes its layers' parameters as
    /// migration shards (`ckpt/g{plan_generation}/l{layer}`).
    pub migrate_out: bool,
    /// Record a [`StageObservations`](crate::replan::StageObservations)
    /// ring of the given window into the report (virtual-clock,
    /// non-calibrated runs only) — the drift detector's input.
    pub observe: Option<usize>,
}

impl TrainConfig {
    pub fn new(artifacts_dir: impl Into<PathBuf>) -> Self {
        Self {
            artifacts_dir: artifacts_dir.into(),
            dp: 1,
            mu: 2,
            steps: 20,
            lr: 0.2,
            seed: 7,
            throttle: None,
            lifetime_s: f64::INFINITY,
            checkpoint_margin_s: 2.0,
            sync_alg: SyncAlgorithm::PipelinedScatterReduce,
            chunking: Chunking::NONE,
            scenario: ScenarioSpec::deterministic(),
            scenario_seed: 0,
            cold_start_s: 0.01,
            virtual_iter_s: None,
            layer_groups: Vec::new(),
            step_offset: 0,
            plan_generation: 0,
            calibrated_tick: false,
            migrate_out: false,
            observe: None,
        }
    }

    pub fn global_batch(&self, micro_batch: usize) -> usize {
        self.dp * self.mu * micro_batch
    }
}

/// One iteration's record (written by the monitor daemon).
#[derive(Debug, Clone)]
pub struct IterLog {
    pub step: usize,
    pub loss: f32,
    pub iter_s: f64,
}

/// Result of a training run.
#[derive(Debug)]
pub struct TrainReport {
    pub logs: Vec<IterLog>,
    pub restarts: usize,
    pub wall_s: f64,
    pub store_put_gets: (u64, u64),
    /// Per-worker lifecycle/lens stats, sorted by worker id.
    pub workers: Vec<WorkerStats>,
    /// The coordinator's per-stage observation ring (recorded when
    /// `TrainConfig::observe` is set on a virtual-clock run).
    pub observations: Option<crate::replan::StageObservations>,
}

impl TrainReport {
    pub fn first_loss(&self) -> f32 {
        self.logs.first().map(|l| l.loss).unwrap_or(f32::NAN)
    }

    pub fn last_loss(&self) -> f32 {
        self.logs.last().map(|l| l.loss).unwrap_or(f32::NAN)
    }

    pub fn mean_iter_s(&self) -> f64 {
        if self.logs.is_empty() {
            return 0.0;
        }
        self.logs.iter().map(|l| l.iter_s).sum::<f64>() / self.logs.len() as f64
    }

    /// Total cold-start seconds charged across all workers/generations.
    pub fn cold_start_total_s(&self) -> f64 {
        self.workers.iter().map(|w| w.cold_start_s).sum()
    }

    /// Total function generations launched (workers + restarts).
    pub fn generations(&self) -> u64 {
        self.workers.iter().map(|w| w.generations as u64).sum()
    }
}

/// Train the AOT transformer across simulated serverless workers.
pub fn train(cfg: &TrainConfig) -> Result<TrainReport> {
    let store = Arc::new(MemStore::new());
    let mut report = run_training(cfg, store.clone())?;
    report.store_put_gets = store.stats();
    Ok(report)
}

/// Variant with a caller-provided store (tests inject throttled stores).
pub fn train_with_store(
    cfg: &TrainConfig,
    store: Arc<MemStore>,
) -> Result<TrainReport> {
    run_training(cfg, store)
}
