//! The **serving tier**: pipelined forward-only execution of a frozen
//! [`Plan`] as a simulated serverless inference deployment.
//!
//! FuncPipe partitions a model across serverless functions to fit
//! memory/bandwidth caps; MOPAR (arxiv 2404.02445) shows the same idea
//! carries to *inference*. This module runs that workload on a
//! deterministic virtual-clock event loop:
//!
//! * a **request router** accumulates arrivals into micro-batches —
//!   a batch dispatches when it reaches the plan's `mu` requests or
//!   when the batching window closes, whichever first;
//! * each pipeline stage owns a FIFO **batch queue** and an
//!   autoscaled pool of [`FunctionInstance`]s: scale *up* when queued
//!   batches exceed the instances already cold-starting (SMLT-style
//!   load tracking, arxiv 2205.01853), scale *down* on an idle
//!   timeout, every launch paying a (scenario-scalable) cold start
//!   and every instance aging on the virtual clock until the platform
//!   lifetime expires it;
//! * **activation hand-off** between stages is priced through the
//!   same storage model the trainer uses: per-access latency plus
//!   boundary bytes over [`PlatformSpec::effective_bandwidth`] at the
//!   *current* live-instance count (autoscaling feeds back into
//!   storage contention);
//! * **billing** is serverless-faithful: every instance accrues
//!   `tier.mem_gb() × alive_seconds × price_per_gb_s` from launch
//!   (cold start included) to retirement.
//!
//! Determinism: arrivals are pre-drawn by [`arrivals`] in time order;
//! the event loop breaks time ties by insertion sequence; scenario
//! lens draws key on the global launch ordinal. A `(plan, traffic,
//! seed, scenario)` tuple therefore replays byte-identically.

pub mod arrivals;

use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::collections::VecDeque;

use anyhow::{bail, Result};

use crate::model::Plan;
use crate::planner::PerfModel;
use crate::platform::FunctionInstance;
use crate::scenario::{Injector, WorkerLens};
use crate::simcore::ScenarioSpec;
use crate::util::stats::percentile;

pub use arrivals::{
    TrafficSpec, ALIBABA_TRACE_PER_MIN, ARRIVAL_TAG, TRAFFIC_SYNTAX,
};

/// Knobs of one serving replay. Everything that can change a byte of
/// the outcome is in here (plus the plan and the perf model).
#[derive(Debug, Clone)]
pub struct ServeOptions {
    pub traffic: TrafficSpec,
    /// Seeds the arrival stream (`seed ^ ARRIVAL_TAG`) *and* the
    /// scenario lens (component tags), mirroring `--seed` elsewhere.
    pub seed: u64,
    /// Simulated arrival horizon, seconds; the deployment then drains.
    pub duration_s: f64,
    /// Router batching window: a partial batch dispatches at most this
    /// long after its first request arrived.
    pub batch_window_s: f64,
    /// An idle instance retires after this long without work.
    pub idle_timeout_s: f64,
    /// Per-stage autoscaler ceiling.
    pub max_instances: usize,
    /// Scenario lens composed over the deployment (deterministic =
    /// identity).
    pub scenario: ScenarioSpec,
}

impl ServeOptions {
    pub fn new(traffic: TrafficSpec, seed: u64) -> Self {
        Self {
            traffic,
            seed,
            duration_s: 60.0,
            batch_window_s: 0.01,
            idle_timeout_s: 10.0,
            max_instances: 64,
            scenario: ScenarioSpec::deterministic(),
        }
    }

    pub fn validate(&self) -> Result<()> {
        if !self.duration_s.is_finite() || self.duration_s <= 0.0 {
            bail!("serve duration must be positive, got {}", self.duration_s);
        }
        if !self.batch_window_s.is_finite() || self.batch_window_s < 0.0 {
            bail!(
                "batch window must be >= 0, got {}",
                self.batch_window_s
            );
        }
        if !self.idle_timeout_s.is_finite() || self.idle_timeout_s <= 0.0 {
            bail!(
                "idle timeout must be positive, got {}",
                self.idle_timeout_s
            );
        }
        if self.max_instances == 0 {
            bail!("max instances per stage must be >= 1");
        }
        // same bound every seed-accepting surface enforces
        crate::config::validate_seed(self.seed)?;
        Ok(())
    }
}

/// Per-stage outcome of a serving replay.
#[derive(Debug, Clone, PartialEq)]
pub struct StageStats {
    pub stage: usize,
    pub tier: usize,
    /// Instances launched (every one pays a cold start).
    pub launches: usize,
    /// Launches that hit the platform lifetime and were retired while
    /// still in demand.
    pub expiries: usize,
    /// High-water mark of simultaneously alive instances.
    pub peak_instances: usize,
    pub batches: usize,
    pub mean_batch: f64,
    /// busy_s / alive_s over all instances of the stage.
    pub utilization: f64,
    pub busy_s: f64,
    pub alive_s: f64,
}

/// Raw numbers of one serving replay (the typed `ServeReport` in
/// `experiment::report` renders these).
#[derive(Debug, Clone, PartialEq)]
pub struct ServeOutcome {
    pub requests: usize,
    pub completed: usize,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    /// Mean offered load over the arrival horizon, req/min.
    pub offered_rpm: f64,
    /// completed / makespan, req/min.
    pub achieved_rpm: f64,
    /// First arrival to last completion, seconds.
    pub makespan_s: f64,
    /// Fraction of completed requests whose batch was an instance's
    /// first work item (i.e. waited on a cold start somewhere).
    pub cold_start_rate: f64,
    pub cost_usd: f64,
    pub cost_per_1k_usd: f64,
    pub stages: Vec<StageStats>,
}

#[derive(Debug, Clone, Copy, PartialEq)]
enum Ev {
    /// Request `req` reaches the router.
    Arrive(usize),
    /// The router's batching window for accumulation `epoch` closes.
    WindowClose(u64),
    /// Instance finished its cold start.
    Ready { stage: usize, inst: usize },
    /// Instance finished computing a batch.
    Done { stage: usize, inst: usize, batch: usize },
    /// A batch's activations landed in stage `stage`'s queue.
    BatchAt { stage: usize, batch: usize },
    /// Idle-timeout probe (valid only if the instance's idle epoch
    /// still matches).
    IdleCheck { stage: usize, inst: usize, epoch: u64 },
}

/// Heap entry: ascending time, ties broken by insertion sequence so
/// the loop is a pure function of its inputs.
#[derive(Debug, Clone, Copy)]
struct Scheduled {
    t: f64,
    seq: u64,
    ev: Ev,
}

impl PartialEq for Scheduled {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == std::cmp::Ordering::Equal
    }
}
impl Eq for Scheduled {}
impl PartialOrd for Scheduled {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Scheduled {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // Event times are finite by construction (validated inputs).
        self.t
            .partial_cmp(&other.t)
            .expect("event times are never NaN")
            .then(self.seq.cmp(&other.seq))
    }
}

#[derive(Debug, Clone, Copy, PartialEq)]
enum InstState {
    Starting,
    Idle,
    Busy,
    Retired,
}

struct Inst {
    func: FunctionInstance,
    state: InstState,
    lens: WorkerLens,
    launch_t: f64,
    last_touch: f64,
    retire_t: Option<f64>,
    busy_s: f64,
    served_batches: usize,
    idle_epoch: u64,
}

struct StageRt {
    tier: usize,
    /// Per-micro-batch (= per-request) forward seconds at this tier.
    fwd_s: f64,
    /// Boundary activation bytes per request toward the next stage.
    out_bytes: f64,
    queue: VecDeque<usize>,
    insts: Vec<Inst>,
    /// Incremental counters (the event loop touches these per event —
    /// no O(instances) scans on the hot path).
    alive_now: usize,
    starting_now: usize,
    launches: usize,
    expiries: usize,
    peak_alive: usize,
    batches: usize,
    batched_reqs: usize,
}

struct Sim<'a> {
    perf: &'a PerfModel<'a>,
    opts: &'a ServeOptions,
    injector: Injector,
    lens_n: usize,
    heap: BinaryHeap<Reverse<Scheduled>>,
    seq: u64,
    now: f64,
    stages: Vec<StageRt>,
    batch_cap: usize,
    /// Router accumulation for the next batch (request ids).
    pending: Vec<usize>,
    window_epoch: u64,
    batches: Vec<Vec<usize>>,
    arrival: Vec<f64>,
    done: Vec<Option<f64>>,
    launch_ordinal: usize,
    completed: usize,
    cold_hit_reqs: usize,
    last_done_t: f64,
    first_arrival_t: f64,
    cost_usd: f64,
}

impl<'a> Sim<'a> {
    fn push(&mut self, t: f64, ev: Ev) {
        let seq = self.seq;
        self.seq += 1;
        self.heap.push(Reverse(Scheduled { t, seq, ev }));
    }

    fn total_alive(&self) -> usize {
        self.stages.iter().map(|s| s.alive_now).sum()
    }

    fn cold_start_base_s(&self, tier: usize) -> f64 {
        let p = self.perf.platform;
        p.tier(tier).cold_start_s.max(p.cold_start_s)
    }

    /// Launch one instance for `stage`, paying a (scenario-scaled)
    /// cold start keyed on the global launch ordinal.
    fn launch(&mut self, stage: usize) {
        let ordinal = self.launch_ordinal;
        self.launch_ordinal += 1;
        let lens_worker = ordinal % self.lens_n;
        let generation = (ordinal / self.lens_n) as u32;
        let st = &self.stages[stage];
        let base = self.cold_start_base_s(st.tier);
        let cold_s = self.injector.cold_start_s(lens_worker, generation, base);
        let lens = self.injector.worker(lens_worker);
        let st = &mut self.stages[stage];
        let replica = st.insts.len();
        let mut func = FunctionInstance::launch(
            ordinal,
            stage,
            replica,
            st.tier,
            self.perf.platform.function_lifetime_s,
        );
        // Pin the lifecycle to the virtual clock from birth.
        func.advance_virtual(0.0);
        let inst = Inst {
            func,
            state: InstState::Starting,
            lens,
            launch_t: self.now,
            last_touch: self.now,
            retire_t: None,
            busy_s: 0.0,
            served_batches: 0,
            idle_epoch: 0,
        };
        st.insts.push(inst);
        st.launches += 1;
        st.alive_now += 1;
        st.starting_now += 1;
        st.peak_alive = st.peak_alive.max(st.alive_now);
        let t_ready = self.now + cold_s;
        self.push(t_ready, Ev::Ready { stage, inst: replica });
    }

    fn retire(&mut self, stage: usize, inst: usize) {
        let now = self.now;
        let price = self.perf.platform.price_per_gb_s;
        let mem_gb = {
            let st = &self.stages[stage];
            self.perf.platform.tier(st.tier).mem_gb()
        };
        let i = &mut self.stages[stage].insts[inst];
        i.func.advance_virtual(now - i.last_touch);
        i.last_touch = now;
        i.state = InstState::Retired;
        i.retire_t = Some(now);
        self.cost_usd += (now - i.launch_t) * mem_gb * price;
        self.stages[stage].alive_now -= 1;
    }

    /// Form a batch from the router's pending requests and enqueue it
    /// at stage 0.
    fn form_batch(&mut self) {
        if self.pending.is_empty() {
            return;
        }
        self.window_epoch += 1;
        let reqs = std::mem::take(&mut self.pending);
        let id = self.batches.len();
        self.batches.push(reqs);
        self.stages[0].queue.push_back(id);
        self.dispatch(0);
    }

    /// Assign queued batches to idle instances (lowest index first),
    /// then scale up if batches still outnumber starting instances.
    fn dispatch(&mut self, stage: usize) {
        loop {
            if self.stages[stage].queue.is_empty() {
                break;
            }
            let idle = self.stages[stage]
                .insts
                .iter()
                .position(|i| i.state == InstState::Idle);
            let Some(idx) = idle else { break };
            let batch = self.stages[stage].queue.pop_front().unwrap();
            let b = self.batches[batch].len();
            let now = self.now;
            let st = &mut self.stages[stage];
            let inst = &mut st.insts[idx];
            if inst.served_batches == 0 {
                self.cold_hit_reqs += b;
            }
            inst.served_batches += 1;
            inst.func.advance_virtual(now - inst.last_touch);
            inst.last_touch = now;
            inst.state = InstState::Busy;
            let service_s = st.fwd_s * b as f64 * inst.lens.compute_mult;
            inst.busy_s += service_s;
            st.batches += 1;
            st.batched_reqs += b;
            self.push(now + service_s, Ev::Done { stage, inst: idx, batch });
        }
        // Scale-up: every queued batch not already covered by a
        // cold-starting instance asks for one more, up to the ceiling.
        let queued = self.stages[stage].queue.len();
        let starting = self.stages[stage].starting_now;
        let mut deficit = queued.saturating_sub(starting);
        while deficit > 0
            && self.stages[stage].alive_now < self.opts.max_instances
        {
            self.launch(stage);
            deficit -= 1;
        }
    }

    fn on_idle(&mut self, stage: usize, inst: usize) {
        let now = self.now;
        let i = &mut self.stages[stage].insts[inst];
        i.state = InstState::Idle;
        i.idle_epoch += 1;
        let epoch = i.idle_epoch;
        self.push(
            now + self.opts.idle_timeout_s,
            Ev::IdleCheck { stage, inst, epoch },
        );
        self.dispatch(stage);
    }

    fn handle(&mut self, ev: Ev) {
        match ev {
            Ev::Arrive(req) => {
                if self.pending.is_empty() {
                    let epoch = self.window_epoch;
                    self.push(
                        self.now + self.opts.batch_window_s,
                        Ev::WindowClose(epoch),
                    );
                }
                self.pending.push(req);
                if self.pending.len() >= self.batch_cap {
                    self.form_batch();
                }
            }
            Ev::WindowClose(epoch) => {
                if epoch == self.window_epoch {
                    self.form_batch();
                }
            }
            Ev::Ready { stage, inst } => {
                let now = self.now;
                self.stages[stage].starting_now -= 1;
                let i = &mut self.stages[stage].insts[inst];
                i.func.advance_virtual(now - i.last_touch);
                i.last_touch = now;
                i.func.mark_running();
                self.on_idle(stage, inst);
            }
            Ev::Done { stage, inst, batch } => {
                let b = self.batches[batch].len();
                let now = self.now;
                let last = stage + 1 == self.stages.len();
                let lens = self.stages[stage].insts[inst].lens;
                if last {
                    for &req in &self.batches[batch] {
                        self.done[req] = Some(now);
                    }
                    self.completed += b;
                    self.last_done_t = now;
                } else {
                    // Activation hand-off through storage: one upload
                    // on this stage's tier, one download on the next,
                    // both under the live-instance contention the
                    // autoscaler currently causes.
                    let p = self.perf.platform;
                    let n = self.total_alive().max(1);
                    let bytes = self.stages[stage].out_bytes * b as f64;
                    let up = p.effective_bandwidth(
                        self.stages[stage].tier,
                        n,
                    ) * lens.bandwidth_mult;
                    let down = p.effective_bandwidth(
                        self.stages[stage + 1].tier,
                        n,
                    );
                    let transfer_s = 2.0
                        * p.storage.latency_s
                        * lens.latency_mult
                        + bytes / up
                        + bytes / down;
                    self.push(
                        now + transfer_s,
                        Ev::BatchAt { stage: stage + 1, batch },
                    );
                }
                let expired = {
                    let i = &mut self.stages[stage].insts[inst];
                    i.func.advance_virtual(now - i.last_touch);
                    i.last_touch = now;
                    i.func.expired()
                };
                if expired {
                    self.stages[stage].expiries += 1;
                    self.retire(stage, inst);
                    // The pool shrank mid-demand: let the scaler react.
                    self.dispatch(stage);
                } else {
                    self.on_idle(stage, inst);
                }
            }
            Ev::BatchAt { stage, batch } => {
                self.stages[stage].queue.push_back(batch);
                self.dispatch(stage);
            }
            Ev::IdleCheck { stage, inst, epoch } => {
                let i = &self.stages[stage].insts[inst];
                if i.state == InstState::Idle && i.idle_epoch == epoch {
                    self.retire(stage, inst);
                }
            }
        }
    }
}

/// Seed- and traffic-independent setup of one plan's serving pipeline:
/// per-stage tier, service time, and boundary bytes, plus the router's
/// batch cap. Deriving these walks the plan and the stage-term cache;
/// N-seed SLO scoring does it ONCE per plan via [`prepare_serve`] and
/// replays each seed with [`serve_prepared`].
#[derive(Debug, Clone)]
pub struct ServePrep {
    stages: Vec<(usize, f64, f64)>, // (tier, fwd_s, out_bytes)
    batch_cap: usize,
}

/// Derive the per-plan serving invariants (validating the plan's
/// stage/tier shape against the model).
pub fn prepare_serve(perf: &PerfModel, plan: &Plan) -> Result<ServePrep> {
    let m = perf.model;
    let ranges = plan.stage_ranges(m.n_layers());
    if ranges.len() != plan.stage_tiers.len() {
        bail!(
            "plan has {} stages but {} stage tiers",
            ranges.len(),
            plan.stage_tiers.len()
        );
    }
    let stages = ranges
        .iter()
        .zip(plan.stage_tiers.iter())
        .map(|(&(lo, hi), &tier)| {
            let terms = perf.stage_terms(lo, hi, tier);
            (tier, terms.fwd_s, m.layers[hi].out_bytes as f64)
        })
        .collect();
    Ok(ServePrep { stages, batch_cap: plan.mu().max(1) })
}

/// Run one serving replay of `plan` under `opts`. Pure function of its
/// arguments — same inputs, byte-identical [`ServeOutcome`].
pub fn serve_plan(
    perf: &PerfModel,
    plan: &Plan,
    opts: &ServeOptions,
) -> Result<ServeOutcome> {
    let prep = prepare_serve(perf, plan)?;
    serve_prepared(perf, &prep, opts)
}

/// Run one serving replay from pre-derived plan invariants. Same bytes
/// as [`serve_plan`] on the plan that produced `prep`.
pub fn serve_prepared(
    perf: &PerfModel,
    prep: &ServePrep,
    opts: &ServeOptions,
) -> Result<ServeOutcome> {
    opts.validate()?;
    let stages: Vec<StageRt> = prep
        .stages
        .iter()
        .map(|&(tier, fwd_s, out_bytes)| StageRt {
            tier,
            fwd_s,
            out_bytes,
            queue: VecDeque::new(),
            insts: Vec::new(),
            alive_now: 0,
            starting_now: 0,
            launches: 0,
            expiries: 0,
            peak_alive: 0,
            batches: 0,
            batched_reqs: 0,
        })
        .collect();

    let arrival = opts.traffic.generate(opts.seed, opts.duration_s);
    let requests = arrival.len();
    let lens_n = (stages.len() * opts.max_instances).max(1);
    let injector = Injector::new(&opts.scenario, opts.seed, lens_n);
    let batch_cap = prep.batch_cap;

    let mut sim = Sim {
        perf,
        opts,
        injector,
        lens_n,
        heap: BinaryHeap::new(),
        seq: 0,
        now: 0.0,
        stages,
        batch_cap,
        pending: Vec::new(),
        window_epoch: 0,
        batches: Vec::new(),
        arrival: arrival.clone(),
        done: vec![None; requests],
        launch_ordinal: 0,
        completed: 0,
        cold_hit_reqs: 0,
        last_done_t: 0.0,
        first_arrival_t: arrival.first().copied().unwrap_or(0.0),
        cost_usd: 0.0,
    };
    for (req, &t) in arrival.iter().enumerate() {
        sim.push(t, Ev::Arrive(req));
    }
    while let Some(Reverse(sch)) = sim.heap.pop() {
        sim.now = sch.t;
        sim.handle(sch.ev);
    }

    let mut lat_ms: Vec<f64> = Vec::with_capacity(requests);
    for (req, d) in sim.done.iter().enumerate() {
        if let Some(t) = d {
            lat_ms.push((t - sim.arrival[req]) * 1000.0);
        }
    }
    let pct = |q: f64| -> f64 {
        if lat_ms.is_empty() {
            0.0
        } else {
            percentile(&lat_ms, q)
        }
    };
    let completed = sim.completed;
    let makespan_s = if completed > 0 {
        sim.last_done_t - sim.first_arrival_t
    } else {
        0.0
    };
    let stage_rows: Vec<StageStats> = sim
        .stages
        .iter()
        .enumerate()
        .map(|(s, st)| {
            let alive_s: f64 = st
                .insts
                .iter()
                .map(|i| i.retire_t.unwrap_or(i.last_touch) - i.launch_t)
                .sum();
            let busy_s: f64 = st.insts.iter().map(|i| i.busy_s).sum();
            StageStats {
                stage: s,
                tier: st.tier,
                launches: st.launches,
                expiries: st.expiries,
                peak_instances: st.peak_alive,
                batches: st.batches,
                mean_batch: if st.batches > 0 {
                    st.batched_reqs as f64 / st.batches as f64
                } else {
                    0.0
                },
                utilization: if alive_s > 0.0 { busy_s / alive_s } else { 0.0 },
                busy_s,
                alive_s,
            }
        })
        .collect();
    Ok(ServeOutcome {
        requests,
        completed,
        p50_ms: pct(50.0),
        p95_ms: pct(95.0),
        p99_ms: pct(99.0),
        offered_rpm: requests as f64 / opts.duration_s * 60.0,
        achieved_rpm: if makespan_s > 0.0 {
            completed as f64 / makespan_s * 60.0
        } else {
            0.0
        },
        makespan_s,
        cold_start_rate: if completed > 0 {
            sim.cold_hit_reqs as f64 / completed as f64
        } else {
            0.0
        },
        cost_usd: sim.cost_usd,
        cost_per_1k_usd: if completed > 0 {
            sim.cost_usd / completed as f64 * 1000.0
        } else {
            0.0
        },
        stages: stage_rows,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;
    use crate::platform::PlatformSpec;

    fn setup() -> (crate::model::ModelProfile, PlatformSpec) {
        let p = PlatformSpec::aws_lambda();
        let m = zoo::resnet101(&p);
        (m, p)
    }

    fn plan(p: &PlatformSpec, m: &crate::model::ModelProfile) -> Plan {
        let top = p.max_tier();
        let l = m.n_layers();
        Plan {
            cuts: vec![l / 2 - 1],
            dp: 1,
            stage_tiers: vec![top, top],
            n_micro_global: 4,
        }
    }

    #[test]
    fn replay_is_byte_deterministic_and_seed_sensitive() {
        let (m, p) = setup();
        let perf = PerfModel::new(&m, &p);
        let plan = plan(&p, &m);
        let mut opts = ServeOptions::new(
            TrafficSpec::parse("poisson:1200").unwrap(),
            7,
        );
        opts.duration_s = 20.0;
        let a = serve_plan(&perf, &plan, &opts).unwrap();
        let b = serve_plan(&perf, &plan, &opts).unwrap();
        assert_eq!(a, b);
        opts.seed = 8;
        let c = serve_plan(&perf, &plan, &opts).unwrap();
        assert_ne!(a.requests, 0);
        assert_ne!(a, c, "a new seed must change the replay");
    }

    #[test]
    fn all_requests_complete_and_are_billed() {
        let (m, p) = setup();
        let perf = PerfModel::new(&m, &p);
        let plan = plan(&p, &m);
        let mut opts = ServeOptions::new(
            TrafficSpec::parse("diurnal:600:0.5:60").unwrap(),
            3,
        );
        opts.duration_s = 20.0;
        let out = serve_plan(&perf, &plan, &opts).unwrap();
        assert_eq!(out.completed, out.requests);
        assert!(out.requests > 50, "20 s at ~10 req/s draws arrivals");
        assert!(out.p99_ms >= out.p95_ms && out.p95_ms >= out.p50_ms);
        assert!(out.p50_ms > 0.0);
        assert!(out.cost_usd > 0.0);
        assert!(out.cost_per_1k_usd > 0.0);
        assert!(out.cold_start_rate > 0.0, "scale-from-zero pays colds");
        for st in &out.stages {
            assert!(st.launches >= 1);
            assert!(st.peak_instances >= 1);
            assert!(st.utilization > 0.0 && st.utilization <= 1.0);
        }
    }

    #[test]
    fn autoscaler_respects_the_per_stage_ceiling() {
        let (m, p) = setup();
        let perf = PerfModel::new(&m, &p);
        let plan = plan(&p, &m);
        let mut opts = ServeOptions::new(
            // A hard burst: far more offered load than two instances
            // per stage can clear.
            TrafficSpec::parse("poisson:20000").unwrap(),
            11,
        );
        opts.duration_s = 5.0;
        opts.max_instances = 2;
        let out = serve_plan(&perf, &plan, &opts).unwrap();
        assert_eq!(out.completed, out.requests, "overload still drains");
        for st in &out.stages {
            assert!(
                st.peak_instances <= 2,
                "stage {} peaked at {}",
                st.stage,
                st.peak_instances
            );
        }
        assert!(out.p99_ms > out.p50_ms);
    }

    #[test]
    fn batches_respect_the_mu_cap() {
        let (m, p) = setup();
        let perf = PerfModel::new(&m, &p);
        let mut pl = plan(&p, &m);
        pl.n_micro_global = 4; // dp=1 ⇒ mu = 4
        let mut opts = ServeOptions::new(
            TrafficSpec::parse("poisson:30000").unwrap(),
            5,
        );
        opts.duration_s = 2.0;
        let out = serve_plan(&perf, &plan(&p, &m), &opts).unwrap();
        let router = &out.stages[0];
        assert!(router.mean_batch <= pl.mu() as f64 + 1e-9);
        assert!(
            router.mean_batch > 1.2,
            "a 500 req/s burst should actually batch, got mean {}",
            router.mean_batch
        );
    }
}
