//! Trace-driven arrival processes for the serving tier.
//!
//! Three load sources, all drawn from xor-tagged [`util::rng`] streams
//! (the same discipline as `simcore::scenario`):
//!
//! * `poisson:RATE` — homogeneous Poisson arrivals at `RATE` req/min;
//! * `diurnal[:BASE[:AMP[:PERIOD_S]]]` — sinusoidal-rate Poisson
//!   (Lewis–Shedler thinning against the peak rate), the classic
//!   day/night load curve compressed to `PERIOD_S`;
//! * `alibaba[:MEAN]` — replay of the embedded per-minute Alibaba-style
//!   production trace (the one the `fig10` bench consumes), scaled so
//!   the mean rate is `MEAN` req/min, as a piecewise-constant-rate
//!   Poisson process.
//!
//! Determinism contract: every draw comes from `Rng::new(seed ^
//! ARRIVAL_TAG)` **strictly in arrival-time order** — one sequential
//! stream per generation call, no per-thread state — so a `(traffic,
//! seed)` pair replays byte-identically regardless of host, and a
//! different seed changes every inter-arrival gap.
//!
//! [`util::rng`]: crate::util::rng

use anyhow::{bail, Result};

use crate::util::rng::Rng;

/// Stream tag for arrival draws (`seed ^ ARRIVAL_TAG`), following the
/// `simcore::scenario` xor-tag idiom so arrival draws never collide
/// with scenario-lens draws made from the same user seed.
pub const ARRIVAL_TAG: u64 = 0xA221_4A15;

/// Relative per-minute request weights of the embedded Alibaba-style
/// trace: a one-hour window with a morning ramp, a midday plateau, two
/// flash-crowd spikes and a tail-off — the bursty shape serverless
/// autoscaling exists for. Shared verbatim by `bench::fig10` and the
/// `alibaba` traffic source so both replay one byte-identical trace.
pub const ALIBABA_TRACE_PER_MIN: [f64; 60] = [
    0.42, 0.44, 0.47, 0.52, 0.58, 0.66, 0.75, 0.86, 0.97, 1.08, //
    1.18, 1.26, 1.31, 1.33, 1.32, 1.29, 1.25, 1.22, 1.20, 1.19, //
    1.20, 1.23, 1.28, 1.36, 2.10, 2.85, 2.40, 1.70, 1.38, 1.27, //
    1.22, 1.19, 1.17, 1.16, 1.15, 1.14, 1.13, 1.12, 1.10, 1.08, //
    1.05, 1.02, 0.98, 0.95, 0.93, 0.92, 1.55, 2.20, 1.85, 1.30, //
    1.05, 0.92, 0.83, 0.76, 0.70, 0.64, 0.58, 0.52, 0.47, 0.43,
];

/// Mean of [`ALIBABA_TRACE_PER_MIN`] — the factor that normalizes the
/// trace weights to a target mean rate.
pub fn alibaba_trace_mean() -> f64 {
    let s: f64 = ALIBABA_TRACE_PER_MIN.iter().sum();
    s / ALIBABA_TRACE_PER_MIN.len() as f64
}

/// A parsed `--traffic` specification.
#[derive(Debug, Clone, PartialEq)]
pub enum TrafficSpec {
    /// Homogeneous Poisson at `rate_per_min` req/min.
    Poisson { rate_per_min: f64 },
    /// Sinusoidal-rate Poisson: instantaneous rate
    /// `base * (1 + amplitude * sin(2π t / period_s))` req/min.
    Diurnal { base_per_min: f64, amplitude: f64, period_s: f64 },
    /// Piecewise-constant-rate replay of the embedded Alibaba-style
    /// per-minute trace, scaled to `mean_per_min` req/min on average.
    Alibaba { mean_per_min: f64 },
}

/// CLI syntax for `--traffic` / `--slo-traffic` values.
pub const TRAFFIC_SYNTAX: &str =
    "poisson:RATE | diurnal[:BASE[:AMP[:PERIOD_S]]] | alibaba[:MEAN] \
     (rates in req/min)";

fn parse_rate(what: &str, s: &str) -> Result<f64> {
    let v: f64 = s
        .parse()
        .map_err(|_| anyhow::anyhow!("{what}: not a number: {s:?}"))?;
    if !v.is_finite() || v <= 0.0 {
        bail!("{what}: must be a positive finite number, got {s}");
    }
    Ok(v)
}

impl TrafficSpec {
    /// Parse a `--traffic` value. Unknown sources and malformed
    /// rates are hard errors — a typo'd traffic spec must never
    /// silently fall back to a default load.
    pub fn parse(s: &str) -> Result<TrafficSpec> {
        let mut parts = s.split(':');
        let kind = parts.next().unwrap_or("");
        let rest: Vec<&str> = parts.collect();
        match kind {
            "poisson" => {
                let [rate] = rest.as_slice() else {
                    bail!(
                        "traffic `poisson` needs exactly one rate: \
                         poisson:RATE (req/min), got {s:?}"
                    );
                };
                Ok(TrafficSpec::Poisson {
                    rate_per_min: parse_rate("poisson rate", rate)?,
                })
            }
            "diurnal" => {
                if rest.len() > 3 {
                    bail!(
                        "traffic `diurnal` takes at most \
                         diurnal:BASE:AMP:PERIOD_S, got {s:?}"
                    );
                }
                let base = match rest.first() {
                    Some(v) => parse_rate("diurnal base rate", v)?,
                    None => 1000.0,
                };
                let amplitude = match rest.get(1) {
                    Some(v) => {
                        let a: f64 = v.parse().map_err(|_| {
                            anyhow::anyhow!(
                                "diurnal amplitude: not a number: {v:?}"
                            )
                        })?;
                        if !(0.0..=1.0).contains(&a) {
                            bail!(
                                "diurnal amplitude must be in [0, 1], \
                                 got {v}"
                            );
                        }
                        a
                    }
                    None => 0.5,
                };
                let period_s = match rest.get(2) {
                    Some(v) => parse_rate("diurnal period", v)?,
                    None => 3600.0,
                };
                Ok(TrafficSpec::Diurnal {
                    base_per_min: base,
                    amplitude,
                    period_s,
                })
            }
            "alibaba" => {
                if rest.len() > 1 {
                    bail!(
                        "traffic `alibaba` takes at most alibaba:MEAN, \
                         got {s:?}"
                    );
                }
                let mean = match rest.first() {
                    Some(v) => parse_rate("alibaba mean rate", v)?,
                    None => 1000.0,
                };
                Ok(TrafficSpec::Alibaba { mean_per_min: mean })
            }
            _ => bail!(
                "unknown traffic source {s:?} (expected {TRAFFIC_SYNTAX})"
            ),
        }
    }

    /// Canonical rendering (re-parses to an equal spec) — what reports
    /// echo so a replay can be reconstructed from the JSON alone.
    pub fn name(&self) -> String {
        match self {
            TrafficSpec::Poisson { rate_per_min } => {
                format!("poisson:{}", fmt_rate(*rate_per_min))
            }
            TrafficSpec::Diurnal { base_per_min, amplitude, period_s } => {
                format!(
                    "diurnal:{}:{}:{}",
                    fmt_rate(*base_per_min),
                    fmt_rate(*amplitude),
                    fmt_rate(*period_s)
                )
            }
            TrafficSpec::Alibaba { mean_per_min } => {
                format!("alibaba:{}", fmt_rate(*mean_per_min))
            }
        }
    }

    /// Mean offered rate in req/min (exact for poisson/alibaba; the
    /// sinusoid's mean is its base rate).
    pub fn mean_rate_per_min(&self) -> f64 {
        match self {
            TrafficSpec::Poisson { rate_per_min } => *rate_per_min,
            TrafficSpec::Diurnal { base_per_min, .. } => *base_per_min,
            TrafficSpec::Alibaba { mean_per_min } => *mean_per_min,
        }
    }

    /// Generate the arrival times (seconds, ascending, in
    /// `[0, duration_s)`) for this spec under `seed`. All randomness
    /// comes from one sequential `seed ^ ARRIVAL_TAG` stream in
    /// arrival order, so the result is a pure function of
    /// `(self, seed, duration_s)`.
    pub fn generate(&self, seed: u64, duration_s: f64) -> Vec<f64> {
        let mut rng = Rng::new(seed ^ ARRIVAL_TAG);
        let mut out = Vec::new();
        match self {
            TrafficSpec::Poisson { rate_per_min } => {
                let lambda = rate_per_min / 60.0;
                let mut t = 0.0;
                loop {
                    t += rng.exponential(lambda);
                    if t >= duration_s {
                        break;
                    }
                    out.push(t);
                }
            }
            TrafficSpec::Diurnal { base_per_min, amplitude, period_s } => {
                // Lewis–Shedler thinning against the peak rate: every
                // candidate draw consumes stream state whether accepted
                // or not, keeping the stream position a function of the
                // candidate count alone.
                let base = base_per_min / 60.0;
                let peak = base * (1.0 + amplitude);
                let mut t = 0.0;
                loop {
                    t += rng.exponential(peak);
                    if t >= duration_s {
                        break;
                    }
                    let phase =
                        2.0 * std::f64::consts::PI * t / period_s;
                    let rate = base * (1.0 + amplitude * phase.sin());
                    if rng.chance(rate / peak) {
                        out.push(t);
                    }
                }
            }
            TrafficSpec::Alibaba { mean_per_min } => {
                // Piecewise-constant-rate Poisson over the per-minute
                // trace, one exponential stream walked window by
                // window in time order.
                let norm = alibaba_trace_mean();
                let n = ALIBABA_TRACE_PER_MIN.len();
                let mut t = 0.0;
                while t < duration_s {
                    let minute = (t / 60.0) as usize;
                    let window_end =
                        ((minute + 1) as f64 * 60.0).min(duration_s);
                    let w = ALIBABA_TRACE_PER_MIN[minute % n];
                    let lambda = mean_per_min * w / norm / 60.0;
                    t += rng.exponential(lambda);
                    if t < window_end {
                        out.push(t);
                    } else {
                        // The gap overshot the window: restart the
                        // walk at the boundary under the next
                        // minute's rate (memorylessness makes the
                        // truncation exact).
                        t = window_end;
                    }
                }
            }
        }
        out
    }
}

/// Deterministic minimal float rendering for canonical spec names:
/// integers print without a fractional part.
fn fmt_rate(x: f64) -> String {
    if x.fract() == 0.0 && x.abs() < 9e15 {
        format!("{}", x as i64)
    } else {
        format!("{x}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_accepts_the_three_sources_and_rejects_junk() {
        assert_eq!(
            TrafficSpec::parse("poisson:600").unwrap(),
            TrafficSpec::Poisson { rate_per_min: 600.0 }
        );
        assert_eq!(
            TrafficSpec::parse("diurnal").unwrap(),
            TrafficSpec::Diurnal {
                base_per_min: 1000.0,
                amplitude: 0.5,
                period_s: 3600.0
            }
        );
        assert_eq!(
            TrafficSpec::parse("diurnal:200:0.3:120").unwrap(),
            TrafficSpec::Diurnal {
                base_per_min: 200.0,
                amplitude: 0.3,
                period_s: 120.0
            }
        );
        assert_eq!(
            TrafficSpec::parse("alibaba:5000").unwrap(),
            TrafficSpec::Alibaba { mean_per_min: 5000.0 }
        );
        for bad in [
            "poisson",
            "poisson:-3",
            "poisson:abc",
            "poisson:1:2",
            "diurnal:100:1.5",
            "diurnal:100:0.5:60:9",
            "alibaba:0",
            "uniform:10",
            "",
        ] {
            assert!(
                TrafficSpec::parse(bad).is_err(),
                "{bad:?} should be rejected"
            );
        }
    }

    #[test]
    fn canonical_name_round_trips() {
        for s in ["poisson:600", "diurnal:200:0.3:120", "alibaba:5000"] {
            let spec = TrafficSpec::parse(s).unwrap();
            let again = TrafficSpec::parse(&spec.name()).unwrap();
            assert_eq!(spec, again, "{s} via {}", spec.name());
        }
    }

    #[test]
    fn generation_is_seed_deterministic_and_seed_sensitive() {
        for s in ["poisson:6000", "diurnal:6000:0.5:60", "alibaba:6000"] {
            let spec = TrafficSpec::parse(s).unwrap();
            let a = spec.generate(7, 30.0);
            let b = spec.generate(7, 30.0);
            assert_eq!(a, b, "{s}: same seed must replay exactly");
            let c = spec.generate(8, 30.0);
            assert_ne!(a, c, "{s}: a new seed must change the draws");
            assert!(!a.is_empty(), "{s}: 30 s at 100 req/s draws arrivals");
            assert!(
                a.windows(2).all(|w| w[0] <= w[1]),
                "{s}: arrivals are time-ordered"
            );
            assert!(a.iter().all(|&t| (0.0..30.0).contains(&t)));
        }
    }

    #[test]
    fn poisson_rate_is_roughly_honoured() {
        let spec = TrafficSpec::parse("poisson:60000").unwrap();
        let n = spec.generate(3, 60.0).len() as f64;
        // 60 s at 1000 req/s ⇒ 60k ± a few percent.
        assert!((n - 60_000.0).abs() < 3_000.0, "got {n}");
    }

    #[test]
    fn alibaba_trace_is_bursty_and_shared() {
        // The embedded trace must keep its flash-crowd spikes — fig10
        // and the serving replay both key off this exact shape.
        let peak = ALIBABA_TRACE_PER_MIN
            .iter()
            .cloned()
            .fold(f64::NEG_INFINITY, f64::max);
        let trough = ALIBABA_TRACE_PER_MIN
            .iter()
            .cloned()
            .fold(f64::INFINITY, f64::min);
        assert!(peak / trough > 4.0, "trace lost its burstiness");
        assert!((alibaba_trace_mean() - 1.0).abs() < 0.25);
    }
}
