//! The Model Profiler (§3.1 step 3): measures per-stage compute times at
//! each memory tier through the real PJRT path, plus the storage
//! substrate's latency/bandwidth — producing a [`ModelProfile`] that the
//! Partition/Resource Optimizer consumes, exactly the startup flow of the
//! paper.
//!
//! On this testbed all tiers share the host CPU, so tier times are derived
//! by measuring the reference execution and scaling by the tier's
//! effective speed (the same Amdahl model the zoo uses) — the measured
//! part is the *relative layer weights*, which is what partitioning needs.

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::model::{LayerProfile, ModelProfile};
use crate::platform::{ObjectStore, PlatformSpec};
use crate::runtime::{Manifest, Runtime};
use crate::trainer::data::Corpus;

/// Measured storage characteristics.
#[derive(Debug, Clone)]
pub struct StorageProfile {
    pub latency_s: f64,
    pub bandwidth_bps: f64,
}

/// Profile the artifacts' stages by running fwd/bwd through PJRT.
pub fn profile_stages(
    artifacts_dir: &std::path::Path,
    platform: &PlatformSpec,
    reps: usize,
) -> Result<ModelProfile> {
    let manifest = Manifest::load(artifacts_dir)?;
    let rt = Arc::new(Runtime::cpu()?);
    let corpus = Corpus::new(
        manifest.vocab,
        manifest.seq_len,
        manifest.micro_batch,
        1234,
    );
    let (tokens, targets) = corpus.batch(0, 0, 0);

    let amdahl = |vcpus: f64| -> f64 {
        let p = 0.88;
        1.0 / ((1.0 - p) + p / vcpus.max(0.2))
    };

    let mut layers = Vec::new();
    let mut h: Vec<f32> = Vec::new();
    for (i, entry) in manifest.stages.iter().enumerate() {
        let stage = rt.load_stage(&manifest, entry)?;
        let is_first = i == 0;
        let is_last = i == manifest.n_stages - 1;

        // measure fwd
        let x_in = h.clone();
        let mut fwd_t = f64::INFINITY;
        let mut out: Vec<f32> = Vec::new();
        for _ in 0..reps.max(1) {
            let t0 = Instant::now();
            out = if is_first {
                stage.fwd_tokens(&tokens)?
            } else if is_last {
                vec![stage.fwd_loss(&x_in, &targets)?]
            } else {
                stage.fwd_acts(&x_in)?
            };
            fwd_t = fwd_t.min(t0.elapsed().as_secs_f64());
        }

        // measure bwd
        let gy = vec![1e-3f32; out.len()];
        let mut bwd_t = f64::INFINITY;
        for _ in 0..reps.max(1) {
            let t0 = Instant::now();
            if is_first {
                let _ = stage.bwd_tokens(&tokens, &gy)?;
            } else if is_last {
                let _ = stage.bwd_loss(&x_in, &targets)?;
            } else {
                let _ = stage.bwd_acts(&x_in, &gy)?;
            }
            bwd_t = bwd_t.min(t0.elapsed().as_secs_f64());
        }

        let out_bytes = if is_last { 64 } else { (out.len() * 4) as u64 };
        let act_bytes = (entry
            .input_shape
            .iter()
            .product::<usize>()
            .max(out.len())
            * 4) as u64;
        layers.push(LayerProfile {
            name: entry.name.clone(),
            param_bytes: (entry.flat_param_size * 4) as u64,
            act_bytes,
            out_bytes,
            grad_bytes: act_bytes,
            fwd_s: platform
                .tiers
                .iter()
                .map(|t| fwd_t / amdahl(t.compute_speed) * amdahl(1.0))
                .collect(),
            bwd_s: platform
                .tiers
                .iter()
                .map(|t| bwd_t / amdahl(t.compute_speed) * amdahl(1.0))
                .collect(),
        });
        if !is_last {
            h = out;
        }
    }
    Ok(ModelProfile { name: "aot-transformer".into(), layers })
}

/// Measure the storage substrate: latency from small objects, bandwidth
/// from a large one.
pub fn profile_storage(store: &Arc<dyn ObjectStore>) -> Result<StorageProfile> {
    // latency: median of small put+get round trips
    let mut lats = Vec::new();
    for i in 0..9 {
        let key = format!("probe/lat/{i}");
        let t0 = Instant::now();
        store.put(&key, vec![0u8; 64])?;
        let _ = store.get_blocking(&key, Duration::from_secs(5))?;
        lats.push(t0.elapsed().as_secs_f64() / 2.0);
        store.delete(&key);
    }
    lats.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let latency_s = lats[lats.len() / 2];

    // bandwidth: 4 MB object
    let payload = vec![7u8; 4 << 20];
    let t0 = Instant::now();
    store.put("probe/bw", payload)?;
    let up = t0.elapsed().as_secs_f64();
    let t1 = Instant::now();
    let _ = store.get_blocking("probe/bw", Duration::from_secs(30))?;
    let down = t1.elapsed().as_secs_f64();
    store.delete("probe/bw");
    let bandwidth_bps =
        (4u64 << 20) as f64 / ((up + down) / 2.0 - latency_s).max(1e-9);
    Ok(StorageProfile { latency_s, bandwidth_bps })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::{MemStore, ThrottledStore};
    use std::path::PathBuf;

    #[test]
    fn profiles_real_artifacts() {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: no artifacts");
            return;
        }
        let p = PlatformSpec::aws_lambda();
        let prof = profile_stages(&dir, &p, 2).unwrap();
        prof.validate().unwrap();
        assert!(prof.n_layers() >= 3);
        for l in &prof.layers {
            assert!(l.fwd_s[0] > 0.0);
            assert!(l.fwd_s[0] >= l.fwd_s[p.max_tier()]);
        }
    }

    #[test]
    fn storage_profile_recovers_throttle() {
        let inner = Arc::new(MemStore::new());
        let store: Arc<dyn ObjectStore> = Arc::new(ThrottledStore::new(
            inner,
            50.0e6, // 50 MB/s
            50.0e6,
            Duration::from_millis(5),
        ));
        let sp = profile_storage(&store).unwrap();
        assert!(
            (sp.bandwidth_bps - 50.0e6).abs() / 50.0e6 < 0.5,
            "bw {:.1} MB/s",
            sp.bandwidth_bps / 1e6
        );
        assert!(sp.latency_s > 0.003, "lat {}", sp.latency_s);
    }
}
