//! Paper-style ASCII table rendering for the bench harness: every figure /
//! table reproduction prints rows through this module so output is uniform
//! and diffable.

/// A simple column-aligned table.
#[derive(Debug, Default, Clone)]
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: impl Into<String>) -> Self {
        Self { title: title.into(), header: Vec::new(), rows: Vec::new() }
    }

    pub fn header<I, S>(mut self, cols: I) -> Self
    where
        I: IntoIterator<Item = S>,
        S: Into<String>,
    {
        self.header = cols.into_iter().map(Into::into).collect();
        self
    }

    pub fn row<I, S>(&mut self, cols: I)
    where
        I: IntoIterator<Item = S>,
        S: Into<String>,
    {
        self.rows.push(cols.into_iter().map(Into::into).collect());
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    // structured accessors, used by the JSON side of the `Report` path
    pub fn title(&self) -> &str {
        &self.title
    }

    pub fn header_cols(&self) -> &[String] {
        &self.header
    }

    pub fn rows(&self) -> &[Vec<String>] {
        &self.rows
    }

    pub fn render(&self) -> String {
        let ncols = self
            .header
            .len()
            .max(self.rows.iter().map(Vec::len).max().unwrap_or(0));
        let mut widths = vec![0usize; ncols];
        for (i, h) in self.header.iter().enumerate() {
            widths[i] = widths[i].max(h.chars().count());
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.chars().count());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("== {} ==\n", self.title));
        }
        let sep: String = widths
            .iter()
            .map(|w| format!("+{}", "-".repeat(w + 2)))
            .collect::<String>()
            + "+\n";
        let fmt_row = |cols: &[String]| -> String {
            let mut line = String::new();
            for (i, w) in widths.iter().enumerate() {
                let cell = cols.get(i).map(String::as_str).unwrap_or("");
                line.push_str(&format!("| {cell:<w$} "));
            }
            line.push_str("|\n");
            line
        };
        out.push_str(&sep);
        if !self.header.is_empty() {
            out.push_str(&fmt_row(&self.header));
            out.push_str(&sep);
        }
        for row in &self.rows {
            out.push_str(&fmt_row(row));
        }
        out.push_str(&sep);
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format helper: "1.85x" speedup strings.
pub fn speedup(base: f64, new: f64) -> String {
    if new <= 0.0 {
        return "inf".into();
    }
    format!("{:.2}x", base / new)
}

/// Format helper: "-23.5%" change strings (negative = reduction).
pub fn pct_change(base: f64, new: f64) -> String {
    if base.abs() < 1e-12 {
        return "n/a".into();
    }
    format!("{:+.1}%", (new - base) / base * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo").header(["name", "value"]);
        t.row(["alpha", "1"]);
        t.row(["b", "10000"]);
        let s = t.render();
        assert!(s.contains("== demo =="));
        assert!(s.contains("| alpha | 1     |"));
        assert!(s.contains("| b     | 10000 |"));
    }

    #[test]
    fn helpers() {
        assert_eq!(speedup(2.0, 1.0), "2.00x");
        assert_eq!(pct_change(100.0, 77.0), "-23.0%");
    }
}
