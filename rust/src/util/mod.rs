//! Shared substrates: deterministic RNG, JSON, statistics, logging,
//! human-readable units, table rendering and a mini property-testing
//! harness.
//!
//! These exist because the offline registry carries none of the usual
//! crates (serde, rand, proptest, criterion); see DESIGN.md §3.

pub mod humansize;
pub mod json;
pub mod logging;
pub mod quickcheck;
pub mod rng;
pub mod stats;
pub mod table;
