//! Minimal JSON parser/serializer (serde is unavailable offline).
//!
//! Covers the full JSON grammar (RFC 8259) minus some escape exotica we do
//! not need: enough to read `artifacts/manifest.json`, configs, and to dump
//! experiment results. Numbers are kept as `f64` with an integer fast path.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub enum JsonError {
    Eof(usize),
    Unexpected(usize, char),
    BadNumber(usize),
    BadEscape(usize),
    MissingField(String),
    TypeMismatch(String, &'static str),
    UnknownKey(String),
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            JsonError::Eof(i) => {
                write!(f, "unexpected end of input at byte {i}")
            }
            JsonError::Unexpected(i, c) => {
                write!(f, "unexpected character {c:?} at byte {i}")
            }
            JsonError::BadNumber(i) => write!(f, "invalid number at byte {i}"),
            JsonError::BadEscape(i) => write!(f, "invalid escape at byte {i}"),
            JsonError::MissingField(k) => write!(f, "field {k:?} missing"),
            JsonError::TypeMismatch(k, want) => {
                write!(f, "type mismatch for {k:?}: wanted {want}")
            }
            JsonError::UnknownKey(k) => write!(f, "unknown key {k:?}"),
        }
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(JsonError::Unexpected(p.i, p.peek_char()));
        }
        Ok(v)
    }

    // -- typed accessors -------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|x| x as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|x| {
            if x >= 0.0 && x.fract() == 0.0 {
                Some(x as usize)
            } else {
                None
            }
        })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// `obj["key"]` with a descriptive error (for manifest parsing).
    pub fn field(&self, key: &str) -> Result<&Json, JsonError> {
        self.get(key)
            .ok_or_else(|| JsonError::MissingField(key.to_string()))
    }

    pub fn field_usize(&self, key: &str) -> Result<usize, JsonError> {
        self.field(key)?
            .as_usize()
            .ok_or(JsonError::TypeMismatch(key.to_string(), "usize"))
    }

    pub fn field_f64(&self, key: &str) -> Result<f64, JsonError> {
        self.field(key)?
            .as_f64()
            .ok_or(JsonError::TypeMismatch(key.to_string(), "f64"))
    }

    pub fn field_str(&self, key: &str) -> Result<&str, JsonError> {
        self.field(key)?
            .as_str()
            .ok_or(JsonError::TypeMismatch(key.to_string(), "str"))
    }

    pub fn field_arr(&self, key: &str) -> Result<&[Json], JsonError> {
        self.field(key)?
            .as_arr()
            .ok_or(JsonError::TypeMismatch(key.to_string(), "array"))
    }

    /// Strict-object check: error unless `self` is an object whose keys
    /// all appear in `known`. Parsers that own a JSON level use this so
    /// a typo'd or misplaced key fails loudly (the unknown-CLI-flag
    /// policy, applied to files).
    pub fn check_keys(&self, known: &[&str]) -> Result<(), JsonError> {
        let obj = self
            .as_obj()
            .ok_or(JsonError::TypeMismatch("<root>".to_string(), "object"))?;
        for key in obj.keys() {
            if !known.contains(&key.as_str()) {
                return Err(JsonError::UnknownKey(key.clone()));
            }
        }
        Ok(())
    }

    // -- builders ---------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(
            pairs
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect(),
        )
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn num<T: Into<f64>>(x: T) -> Json {
        Json::Num(x.into())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    // -- pretty printing ---------------------------------------------------

    /// Pretty-print with two-space indentation. Output is deterministic
    /// (`Obj` is a `BTreeMap`, so keys are sorted), which is what makes
    /// the plan-artifact round-trip (`serialize → parse → re-serialize`)
    /// an identity on the text as well as the value.
    pub fn pretty(&self) -> String {
        let mut out = String::new();
        self.pretty_into(&mut out, 0);
        out
    }

    fn pretty_into(&self, out: &mut String, indent: usize) {
        const PAD: &str = "  ";
        match self {
            Json::Arr(a) if !a.is_empty() => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    out.push_str(if i == 0 { "\n" } else { ",\n" });
                    out.push_str(&PAD.repeat(indent + 1));
                    v.pretty_into(out, indent + 1);
                }
                out.push('\n');
                out.push_str(&PAD.repeat(indent));
                out.push(']');
            }
            Json::Obj(o) if !o.is_empty() => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    out.push_str(if i == 0 { "\n" } else { ",\n" });
                    out.push_str(&PAD.repeat(indent + 1));
                    out.push_str(&Json::Str(k.clone()).to_string());
                    out.push_str(": ");
                    v.pretty_into(out, indent + 1);
                }
                out.push('\n');
                out.push_str(&PAD.repeat(indent));
                out.push('}');
            }
            // scalars and empty containers reuse the compact form
            other => out.push_str(&other.to_string()),
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9e15 {
                    write!(f, "{}", *x as i64)
                } else {
                    write!(f, "{x}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(o) => {
                write!(f, "{{")?;
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn peek_char(&self) -> char {
        self.b.get(self.i).map(|&c| c as char).unwrap_or('\0')
    }

    fn skip_ws(&mut self) {
        while self
            .b
            .get(self.i)
            .map(|c| matches!(c, b' ' | b'\t' | b'\n' | b'\r'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.b.get(self.i) == Some(&c) {
            self.i += 1;
            Ok(())
        } else if self.i >= self.b.len() {
            Err(JsonError::Eof(self.i))
        } else {
            Err(JsonError::Unexpected(self.i, self.peek_char()))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.b.get(self.i) {
            None => Err(JsonError::Eof(self.i)),
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(_) => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(JsonError::Unexpected(self.i, self.peek_char()))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.b.get(self.i) == Some(&b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.b.get(self.i) {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                None => return Err(JsonError::Eof(self.i)),
                Some(_) => {
                    return Err(JsonError::Unexpected(self.i, self.peek_char()))
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.b.get(self.i) == Some(&b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.b.get(self.i) {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                None => return Err(JsonError::Eof(self.i)),
                Some(_) => {
                    return Err(JsonError::Unexpected(self.i, self.peek_char()))
                }
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.b.get(self.i) {
                None => return Err(JsonError::Eof(self.i)),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.b.get(self.i) {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{0008}'),
                        Some(b'f') => out.push('\u{000C}'),
                        Some(b'u') => {
                            let hex = self
                                .b
                                .get(self.i + 1..self.i + 5)
                                .ok_or(JsonError::Eof(self.i))?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex)
                                    .map_err(|_| JsonError::BadEscape(self.i))?,
                                16,
                            )
                            .map_err(|_| JsonError::BadEscape(self.i))?;
                            out.push(
                                char::from_u32(code)
                                    .ok_or(JsonError::BadEscape(self.i))?,
                            );
                            self.i += 4;
                        }
                        _ => return Err(JsonError::BadEscape(self.i)),
                    }
                    self.i += 1;
                }
                Some(&c) => {
                    // UTF-8 passthrough: copy the full multi-byte sequence.
                    let len = utf8_len(c);
                    let bytes = self
                        .b
                        .get(self.i..self.i + len)
                        .ok_or(JsonError::Eof(self.i))?;
                    out.push_str(
                        std::str::from_utf8(bytes)
                            .map_err(|_| JsonError::BadEscape(self.i))?,
                    );
                    self.i += len;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.b.get(self.i) == Some(&b'-') {
            self.i += 1;
        }
        while self
            .b
            .get(self.i)
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i])
            .map_err(|_| JsonError::BadNumber(start))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| JsonError::BadNumber(start))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for text in ["null", "true", "false", "0", "-12", "3.5", "\"hi\""] {
            let v = Json::parse(text).unwrap();
            assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
        }
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(
            r#"{"a": [1, 2, {"b": "x\ny", "c": null}], "d": -1e3}"#,
        )
        .unwrap();
        assert_eq!(v.field_f64("d").unwrap(), -1000.0);
        let arr = v.field_arr("a").unwrap();
        assert_eq!(arr[2].field_str("b").unwrap(), "x\ny");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn unicode_and_escapes() {
        let v = Json::parse(r#""é café 日本""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "é café 日本");
        let round = v.to_string();
        assert_eq!(Json::parse(&round).unwrap(), v);
    }

    #[test]
    fn display_integers_exactly() {
        assert_eq!(Json::num(42.0).to_string(), "42");
        assert_eq!(Json::num(0.5).to_string(), "0.5");
    }

    #[test]
    fn check_keys_rejects_strays() {
        let v = Json::parse(r#"{"a": 1, "b": 2}"#).unwrap();
        v.check_keys(&["a", "b", "c"]).unwrap();
        assert!(matches!(
            v.check_keys(&["a"]),
            Err(JsonError::UnknownKey(k)) if k == "b"
        ));
        assert!(Json::parse("[]").unwrap().check_keys(&["a"]).is_err());
    }

    #[test]
    fn pretty_roundtrips_and_is_stable() {
        let v = Json::parse(
            r#"{"b": [1, 2.5, "x"], "a": {"k": null, "j": []}, "c": true}"#,
        )
        .unwrap();
        let p1 = v.pretty();
        let reparsed = Json::parse(&p1).unwrap();
        assert_eq!(reparsed, v);
        assert_eq!(reparsed.pretty(), p1);
        // keys come out sorted, nested structures indented
        assert!(p1.starts_with("{\n  \"a\": {"), "{p1}");
        assert!(p1.contains("\"j\": []"), "{p1}");
    }

    #[test]
    fn manifest_shape() {
        let text = r#"{"n_stages": 2, "stages": [{"name": "embed",
            "flat_param_size": 100}, {"name": "head",
            "flat_param_size": 7}]}"#;
        let m = Json::parse(text).unwrap();
        assert_eq!(m.field_usize("n_stages").unwrap(), 2);
        let stages = m.field_arr("stages").unwrap();
        assert_eq!(stages[1].field_str("name").unwrap(), "head");
    }
}
