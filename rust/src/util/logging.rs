//! Tiny leveled logger backing the `log` crate facade.
//!
//! `FUNCPIPE_LOG={error|warn|info|debug|trace}` selects the level
//! (default `info`). Timestamps are relative to process start so training
//! logs read like an iteration trace.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

use log::{Level, LevelFilter, Metadata, Record};

static START: OnceLock<Instant> = OnceLock::new();
static INSTALLED: AtomicBool = AtomicBool::new(false);

fn start() -> Instant {
    *START.get_or_init(Instant::now)
}

struct Logger;

impl log::Log for Logger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        metadata.level() <= log::max_level()
    }

    fn log(&self, record: &Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let t = start().elapsed().as_secs_f64();
        let lvl = match record.level() {
            Level::Error => "ERR ",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DBG ",
            Level::Trace => "TRC ",
        };
        eprintln!("[{t:9.3}s {lvl} {}] {}", record.target(), record.args());
    }

    fn flush(&self) {}
}

static LOGGER: Logger = Logger;

/// Install the logger (idempotent).
pub fn init() {
    if INSTALLED.swap(true, Ordering::SeqCst) {
        return;
    }
    let _ = start(); // pin t=0 to init time
    let level = match std::env::var("FUNCPIPE_LOG").as_deref() {
        Ok("error") => LevelFilter::Error,
        Ok("warn") => LevelFilter::Warn,
        Ok("debug") => LevelFilter::Debug,
        Ok("trace") => LevelFilter::Trace,
        _ => LevelFilter::Info,
    };
    let _ = log::set_logger(&LOGGER);
    log::set_max_level(level);
}

#[cfg(test)]
mod tests {
    #[test]
    fn init_is_idempotent() {
        super::init();
        super::init();
        log::info!("logging smoke test");
    }
}
