//! Summary statistics used by the profiler, the bench harness and the
//! performance-model validation (Table 3).

/// Arithmetic mean. Returns 0.0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation (n-1 denominator).
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / (xs.len() - 1) as f64)
        .sqrt()
}

/// Percentile by linear interpolation, `q` in [0, 100].
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = (q / 100.0) * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (v[hi] - v[lo]) * (pos - lo as f64)
    }
}

pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Relative error |a-b| / |b|, guarding b≈0.
pub fn rel_err(measured: f64, truth: f64) -> f64 {
    if truth.abs() < 1e-12 {
        return if measured.abs() < 1e-12 { 0.0 } else { f64::INFINITY };
    }
    (measured - truth).abs() / truth.abs()
}

/// Ordinary least squares fit y = a + b*x; returns (a, b).
pub fn linear_fit(xs: &[f64], ys: &[f64]) -> (f64, f64) {
    assert_eq!(xs.len(), ys.len());
    let _n = xs.len() as f64;
    if xs.is_empty() {
        return (0.0, 0.0);
    }
    let mx = mean(xs);
    let my = mean(ys);
    let mut num = 0.0;
    let mut den = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        num += (x - mx) * (y - my);
        den += (x - mx) * (x - mx);
    }
    if den.abs() < 1e-300 {
        return (my, 0.0);
    }
    let b = num / den;
    (my - b * mx, b)
}

/// Welford online mean/variance accumulator.
#[derive(Debug, Default, Clone)]
pub struct Running {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Running {
    pub fn new() -> Self {
        Self { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_stddev() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((stddev(&xs) - 2.138089935299395).abs() < 1e-9);
    }

    #[test]
    fn percentiles() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((median(&xs) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn fit_recovers_line() {
        let xs: Vec<f64> = (0..50).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 3.0 + 2.0 * x).collect();
        let (a, b) = linear_fit(&xs, &ys);
        assert!((a - 3.0).abs() < 1e-9);
        assert!((b - 2.0).abs() < 1e-9);
    }

    #[test]
    fn running_matches_batch() {
        let xs = [1.0, 5.0, 2.5, -3.0, 10.0];
        let mut r = Running::new();
        for &x in &xs {
            r.push(x);
        }
        assert!((r.mean() - mean(&xs)).abs() < 1e-12);
        assert!((r.std() - stddev(&xs)).abs() < 1e-12);
        assert_eq!(r.min(), -3.0);
        assert_eq!(r.max(), 10.0);
    }

    #[test]
    fn rel_err_cases() {
        assert!((rel_err(110.0, 100.0) - 0.1).abs() < 1e-12);
        assert_eq!(rel_err(0.0, 0.0), 0.0);
    }
}
