//! Mini property-based testing harness (proptest is unavailable offline).
//!
//! A property runs against `N` generated cases from a deterministic RNG; on
//! failure the harness re-runs a bounded shrink loop that retries with
//! "smaller" cases drawn from the failing case's neighborhood, then panics
//! with the smallest failing case's debug representation and the seed to
//! reproduce.

use crate::util::rng::Rng;

/// Configuration for a property run.
#[derive(Debug, Clone)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
    pub max_shrink_iters: usize,
}

impl Default for Config {
    fn default() -> Self {
        Self { cases: 128, seed: 0xF00D, max_shrink_iters: 200 }
    }
}

/// A generator produces a value from the RNG; `shrink` proposes smaller
/// candidates (default: none).
pub trait Gen {
    type Value: std::fmt::Debug + Clone;

    fn generate(&self, rng: &mut Rng) -> Self::Value;

    fn shrink(&self, value: &Self::Value) -> Vec<Self::Value> {
        let _ = value;
        Vec::new()
    }
}

/// Run `prop` against `cases` generated values. Panics on failure with the
/// minimal (post-shrink) counterexample.
pub fn check<G, F>(gen: &G, prop: F)
where
    G: Gen,
    F: Fn(&G::Value) -> bool,
{
    check_with(Config::default(), gen, prop)
}

pub fn check_with<G, F>(cfg: Config, gen: &G, prop: F)
where
    G: Gen,
    F: Fn(&G::Value) -> bool,
{
    let mut rng = Rng::new(cfg.seed);
    for case in 0..cfg.cases {
        let value = gen.generate(&mut rng);
        if prop(&value) {
            continue;
        }
        // shrink
        let mut smallest = value.clone();
        let mut iters = 0;
        'outer: loop {
            for cand in gen.shrink(&smallest) {
                iters += 1;
                if iters > cfg.max_shrink_iters {
                    break 'outer;
                }
                if !prop(&cand) {
                    smallest = cand;
                    continue 'outer;
                }
            }
            break;
        }
        panic!(
            "property failed at case {case} (seed {:#x})\n\
             original: {value:?}\nshrunk:   {smallest:?}",
            cfg.seed
        );
    }
}

// ---------------------------------------------------------------------------
// common generators
// ---------------------------------------------------------------------------

/// Uniform usize in [lo, hi].
pub struct UsizeRange(pub usize, pub usize);

impl Gen for UsizeRange {
    type Value = usize;

    fn generate(&self, rng: &mut Rng) -> usize {
        rng.gen_range(self.0 as u64, self.1 as u64 + 1) as usize
    }

    fn shrink(&self, v: &usize) -> Vec<usize> {
        let mut out = Vec::new();
        if *v > self.0 {
            out.push(self.0);
            out.push(self.0 + (*v - self.0) / 2);
            out.push(v - 1);
        }
        out.dedup();
        out
    }
}

/// Uniform f64 in [lo, hi).
pub struct F64Range(pub f64, pub f64);

impl Gen for F64Range {
    type Value = f64;

    fn generate(&self, rng: &mut Rng) -> f64 {
        rng.uniform(self.0, self.1)
    }

    fn shrink(&self, v: &f64) -> Vec<f64> {
        let mid = (self.0 + v) / 2.0;
        if (*v - self.0).abs() > 1e-9 {
            vec![self.0, mid]
        } else {
            vec![]
        }
    }
}

/// Vector of `len` values from an inner generator; shrinks by halving length.
pub struct VecOf<G: Gen> {
    pub inner: G,
    pub min_len: usize,
    pub max_len: usize,
}

impl<G: Gen> Gen for VecOf<G> {
    type Value = Vec<G::Value>;

    fn generate(&self, rng: &mut Rng) -> Vec<G::Value> {
        let n = rng.gen_range(self.min_len as u64, self.max_len as u64 + 1)
            as usize;
        (0..n).map(|_| self.inner.generate(rng)).collect()
    }

    fn shrink(&self, v: &Vec<G::Value>) -> Vec<Vec<G::Value>> {
        let mut out = Vec::new();
        if v.len() > self.min_len {
            let half = self.min_len.max(v.len() / 2);
            out.push(v[..half].to_vec());
            out.push(v[1..].to_vec());
        }
        out
    }
}

/// Pair of independent generators.
pub struct PairOf<A: Gen, B: Gen>(pub A, pub B);

impl<A: Gen, B: Gen> Gen for PairOf<A, B> {
    type Value = (A::Value, B::Value);

    fn generate(&self, rng: &mut Rng) -> Self::Value {
        (self.0.generate(rng), self.1.generate(rng))
    }

    fn shrink(&self, (a, b): &Self::Value) -> Vec<Self::Value> {
        let mut out: Vec<Self::Value> = self
            .0
            .shrink(a)
            .into_iter()
            .map(|a2| (a2, b.clone()))
            .collect();
        out.extend(self.1.shrink(b).into_iter().map(|b2| (a.clone(), b2)));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        check(&UsizeRange(1, 100), |&n| n >= 1 && n <= 100);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_shrunk_case() {
        check(&UsizeRange(0, 1000), |&n| n < 500);
    }

    #[test]
    fn vec_generator_respects_bounds() {
        check(
            &VecOf { inner: F64Range(0.0, 1.0), min_len: 2, max_len: 10 },
            |v| v.len() >= 2 && v.len() <= 10 && v.iter().all(|x| *x < 1.0),
        );
    }

    #[test]
    fn pair_generator() {
        check(&PairOf(UsizeRange(0, 5), F64Range(-1.0, 1.0)), |(n, x)| {
            *n <= 5 && x.abs() <= 1.0
        });
    }
}
