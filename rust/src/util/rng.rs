//! Deterministic pseudo-random number generation.
//!
//! SplitMix64 for seeding + xoshiro256** as the workhorse generator —
//! the standard pairing (Blackman & Vigna). Deterministic across runs and
//! platforms, which the simulator and the property-test harness rely on.

/// SplitMix64: used to expand a single `u64` seed into generator state.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256**: fast, high-quality 64-bit PRNG.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)`.
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[0, 1)` as f32.
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in `[lo, hi)` (half-open). Panics if `lo >= hi`.
    pub fn gen_range(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo < hi, "gen_range: empty range [{lo}, {hi})");
        lo + self.next_u64() % (hi - lo)
    }

    /// Uniform usize in `[0, n)`.
    pub fn index(&mut self, n: usize) -> usize {
        self.gen_range(0, n as u64) as usize
    }

    /// Uniform in `[lo, hi)`.
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-300);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal with given mean/std.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Bernoulli with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Exponential with rate `lambda`.
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        -self.next_f64().max(1e-300).ln() / lambda
    }

    /// Zipf-like rank sampler over `[0, n)` with exponent `s` (rejection-
    /// free inverse-CDF over the truncated harmonic series; used by the
    /// synthetic corpus generator).
    pub fn zipf(&mut self, n: usize, s: f64) -> usize {
        debug_assert!(n > 0);
        // Precomputing the CDF per call is wasteful; callers that need bulk
        // sampling should use `ZipfSampler`.
        ZipfSampler::new(n, s).sample(self)
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.index(xs.len())]
    }
}

/// Precomputed-CDF Zipf sampler.
#[derive(Debug, Clone)]
pub struct ZipfSampler {
    cdf: Vec<f64>,
}

impl ZipfSampler {
    pub fn new(n: usize, s: f64) -> Self {
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for k in 1..=n {
            acc += 1.0 / (k as f64).powf(s);
            cdf.push(acc);
        }
        let total = acc;
        for v in &mut cdf {
            *v /= total;
        }
        Self { cdf }
    }

    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.next_f64();
        match self
            .cdf
            .binary_search_by(|p| p.partial_cmp(&u).unwrap())
        {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut rng = Rng::new(3);
        for _ in 0..10_000 {
            let x = rng.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn gen_range_bounds() {
        let mut rng = Rng::new(4);
        for _ in 0..10_000 {
            let x = rng.gen_range(5, 17);
            assert!((5..17).contains(&x));
        }
    }

    #[test]
    fn normal_moments() {
        let mut rng = Rng::new(5);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>()
            / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn zipf_is_monotone_decreasing() {
        let mut rng = Rng::new(6);
        let sampler = ZipfSampler::new(50, 1.1);
        let mut counts = vec![0usize; 50];
        for _ in 0..200_000 {
            counts[sampler.sample(&mut rng)] += 1;
        }
        assert!(counts[0] > counts[10]);
        assert!(counts[10] > counts[40]);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Rng::new(8);
        let mut xs: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
