//! Byte/second/dollar formatting helpers for logs and bench tables.

/// Format a byte count with binary units ("3.2 GiB").
pub fn bytes(n: u64) -> String {
    const UNITS: [&str; 6] = ["B", "KiB", "MiB", "GiB", "TiB", "PiB"];
    let mut v = n as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{n} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

/// Megabytes (SI, as the paper uses) to bytes.
pub const fn mb(n: u64) -> u64 {
    n * 1_000_000
}

/// Mebibytes to bytes (function memory tiers are binary MB).
pub const fn mib(n: u64) -> u64 {
    n * 1024 * 1024
}

/// Format a duration in seconds adaptively ("431 ms", "12.3 s", "2.1 min").
pub fn secs(t: f64) -> String {
    if t < 1e-3 {
        format!("{:.1} µs", t * 1e6)
    } else if t < 1.0 {
        format!("{:.1} ms", t * 1e3)
    } else if t < 120.0 {
        format!("{t:.2} s")
    } else {
        format!("{:.1} min", t / 60.0)
    }
}

/// Format a dollar amount ("$0.00412").
pub fn usd(x: f64) -> String {
    if x >= 0.01 {
        format!("${x:.4}")
    } else {
        format!("${x:.6}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn byte_units() {
        assert_eq!(bytes(512), "512 B");
        assert_eq!(bytes(2048), "2.00 KiB");
        assert_eq!(bytes(3 * 1024 * 1024), "3.00 MiB");
    }

    #[test]
    fn seconds_ranges() {
        assert!(secs(0.0000005).contains("µs"));
        assert!(secs(0.02).contains("ms"));
        assert!(secs(5.0).contains("s"));
        assert!(secs(600.0).contains("min"));
    }

    #[test]
    fn mb_mib() {
        assert_eq!(mb(70), 70_000_000);
        assert_eq!(mib(1), 1_048_576);
    }
}
