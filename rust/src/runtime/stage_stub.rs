//! Stub runtime used when the `xla-rt` feature is off (the default in the
//! offline build): mirrors the public surface of [`stage`](super::stage)
//! so every caller compiles, while `Runtime::cpu()` fails fast with a
//! clear message. Artifact-gated tests and the trainer check for the
//! manifest before reaching this path, so the default test suite skips
//! rather than fails.

use std::sync::Arc;

use anyhow::{bail, Result};

use super::artifact::{Manifest, StageEntry};

const UNAVAILABLE: &str =
    "PJRT runtime unavailable: rebuild with `--features xla-rt` (requires \
     the xla bindings; see runtime/stage.rs)";

/// Stand-in for the process-wide PJRT client.
pub struct Runtime {}

impl Runtime {
    pub fn cpu() -> Result<Self> {
        bail!(UNAVAILABLE);
    }

    pub fn load_stage(
        self: &Arc<Self>,
        _manifest: &Manifest,
        _entry: &StageEntry,
    ) -> Result<StageExec> {
        bail!(UNAVAILABLE);
    }
}

/// Stand-in for a loaded stage. Never constructed (loading requires a
/// [`Runtime`], whose constructor errors), but the full method surface is
/// here so `coordinator::worker` and the profiler type-check unchanged.
pub struct StageExec {
    pub entry: StageEntry,
    pub micro_batch: usize,
    pub seq_len: usize,
    /// Parameter tensors (f32, row-major) in manifest order.
    pub params: Vec<Vec<f32>>,
}

impl StageExec {
    pub fn fwd_acts(&self, _x: &[f32]) -> Result<Vec<f32>> {
        bail!(UNAVAILABLE);
    }

    pub fn fwd_tokens(&self, _tokens: &[i32]) -> Result<Vec<f32>> {
        bail!(UNAVAILABLE);
    }

    pub fn fwd_loss(&self, _x: &[f32], _targets: &[i32]) -> Result<f32> {
        bail!(UNAVAILABLE);
    }

    pub fn bwd_acts(
        &self,
        _x: &[f32],
        _gy: &[f32],
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        bail!(UNAVAILABLE);
    }

    pub fn bwd_tokens(&self, _tokens: &[i32], _gy: &[f32]) -> Result<Vec<f32>> {
        bail!(UNAVAILABLE);
    }

    pub fn bwd_loss(
        &self,
        _x: &[f32],
        _targets: &[i32],
    ) -> Result<(Vec<f32>, Vec<f32>, f32)> {
        bail!(UNAVAILABLE);
    }

    pub fn sgd_step(&mut self, _flat_grads: &[f32], _lr: f32) -> Result<()> {
        bail!(UNAVAILABLE);
    }

    pub fn merge_grads(&self, _a: &[f32], _b: &[f32]) -> Result<Vec<f32>> {
        bail!(UNAVAILABLE);
    }

    pub fn flat_params(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.entry.flat_param_size);
        for p in &self.params {
            out.extend_from_slice(p);
        }
        out
    }

    pub fn set_flat_params(&mut self, flat: &[f32]) -> Result<()> {
        if flat.len() != self.entry.flat_param_size {
            bail!("param size {} != {}", flat.len(), self.entry.flat_param_size);
        }
        let mut off = 0;
        for (i, spec) in self.entry.params.iter().enumerate() {
            self.params[i].copy_from_slice(&flat[off..off + spec.numel]);
            off += spec.numel;
        }
        Ok(())
    }
}
