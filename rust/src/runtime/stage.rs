//! Loaded pipeline stages: HLO text → PJRT executable → typed execution
//! helpers. Mirrors /opt/xla-example/load_hlo (text interchange — see
//! aot.py for why serialized protos are rejected by xla_extension 0.5.1).

use std::path::Path;
use std::sync::Arc;

use anyhow::{bail, Context, Result};
use xla::{ElementType, Literal, PjRtClient, PjRtLoadedExecutable};

use super::artifact::{Manifest, StageEntry};

/// Process-wide PJRT CPU client (one per process; stages share it).
pub struct Runtime {
    pub client: PjRtClient,
}

impl Runtime {
    pub fn cpu() -> Result<Self> {
        Ok(Self { client: PjRtClient::cpu().context("PJRT CPU client")? })
    }

    fn load_exe(&self, path: &Path) -> Result<PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))
    }

    /// Load all of a stage's executables + initial parameters.
    pub fn load_stage(
        self: &Arc<Self>,
        manifest: &Manifest,
        entry: &StageEntry,
    ) -> Result<StageExec> {
        let dir = &manifest.dir;
        Ok(StageExec {
            entry: entry.clone(),
            micro_batch: manifest.micro_batch,
            seq_len: manifest.seq_len,
            fwd: self.load_exe(&dir.join(&entry.fwd_file))?,
            bwd: self.load_exe(&dir.join(&entry.bwd_file))?,
            sgd: self.load_exe(&dir.join(&entry.sgd_file))?,
            merge2: self.load_exe(&dir.join(&entry.merge2_file))?,
            params: manifest.load_init_params(entry)?,
        })
    }
}

/// A stage resident on one worker: executables + live parameters.
pub struct StageExec {
    pub entry: StageEntry,
    pub micro_batch: usize,
    pub seq_len: usize,
    fwd: PjRtLoadedExecutable,
    bwd: PjRtLoadedExecutable,
    sgd: PjRtLoadedExecutable,
    merge2: PjRtLoadedExecutable,
    /// Parameter tensors (f32, row-major) in manifest order.
    pub params: Vec<Vec<f32>>,
}

fn lit_f32(data: &[f32], shape: &[usize]) -> Result<Literal> {
    let bytes: &[u8] = unsafe {
        std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
    };
    Literal::create_from_shape_and_untyped_data(ElementType::F32, shape, bytes)
        .context("building f32 literal")
}

fn lit_i32(data: &[i32], shape: &[usize]) -> Result<Literal> {
    let bytes: &[u8] = unsafe {
        std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
    };
    Literal::create_from_shape_and_untyped_data(ElementType::S32, shape, bytes)
        .context("building i32 literal")
}

impl StageExec {
    fn param_literals(&self) -> Result<Vec<Literal>> {
        self.entry
            .params
            .iter()
            .zip(&self.params)
            .map(|(spec, data)| lit_f32(data, &spec.shape))
            .collect()
    }

    fn run(
        exe: &PjRtLoadedExecutable,
        args: Vec<Literal>,
        kept: &[usize],
    ) -> Result<Vec<Literal>> {
        // keep only the entry arguments the lowering retained (aot.py
        // records jax.jit's dead-argument pruning in the manifest)
        let args: Vec<Literal> = args
            .into_iter()
            .enumerate()
            .filter(|(i, _)| kept.contains(i))
            .map(|(_, l)| l)
            .collect();
        let out = exe.execute::<Literal>(&args).context("execute")?;
        let lit = out[0][0].to_literal_sync().context("to_literal")?;
        lit.to_tuple().context("detuple")
    }

    /// Forward for a non-head stage: input activations (or tokens for the
    /// embed stage are passed via `fwd_tokens`) → output activations.
    pub fn fwd_acts(&self, x: &[f32]) -> Result<Vec<f32>> {
        if self.entry.kind == "embed" {
            bail!("embed stage takes tokens; use fwd_tokens");
        }
        let mut args = self.param_literals()?;
        args.push(lit_f32(x, &self.entry.input_shape)?);
        let out = Self::run(&self.fwd, args, &self.entry.fwd_kept)?;
        out[0].to_vec::<f32>().context("fwd output")
    }

    /// Forward for the embed stage.
    pub fn fwd_tokens(&self, tokens: &[i32]) -> Result<Vec<f32>> {
        let mut args = self.param_literals()?;
        args.push(lit_i32(tokens, &self.entry.input_shape)?);
        let out = Self::run(&self.fwd, args, &self.entry.fwd_kept)?;
        out[0].to_vec::<f32>().context("embed output")
    }

    /// Forward for the head stage → scalar loss.
    pub fn fwd_loss(&self, x: &[f32], targets: &[i32]) -> Result<f32> {
        let mut args = self.param_literals()?;
        args.push(lit_f32(x, &self.entry.input_shape)?);
        args.push(lit_i32(targets, &[self.micro_batch, self.seq_len])?);
        let out = Self::run(&self.fwd, args, &self.entry.fwd_kept)?;
        Ok(out[0].to_vec::<f32>().context("loss")?[0])
    }

    /// Backward of a blocks stage: (x, gy) → (flat grads, gx).
    pub fn bwd_acts(&self, x: &[f32], gy: &[f32]) -> Result<(Vec<f32>, Vec<f32>)> {
        let mut args = self.param_literals()?;
        args.push(lit_f32(x, &self.entry.input_shape)?);
        args.push(lit_f32(gy, &self.entry.output_shape)?);
        let out = Self::run(&self.bwd, args, &self.entry.bwd_kept)?;
        let n = self.entry.params.len();
        let grads = flatten_grads(&out[..n])?;
        let gx = out[n].to_vec::<f32>().context("gx")?;
        Ok((grads, gx))
    }

    /// Backward of the embed stage: (tokens, gy) → flat grads.
    pub fn bwd_tokens(&self, tokens: &[i32], gy: &[f32]) -> Result<Vec<f32>> {
        let mut args = self.param_literals()?;
        args.push(lit_i32(tokens, &self.entry.input_shape)?);
        args.push(lit_f32(gy, &self.entry.output_shape)?);
        let out = Self::run(&self.bwd, args, &self.entry.bwd_kept)?;
        flatten_grads(&out[..self.entry.params.len()])
    }

    /// Backward of the head stage: (x, targets) → (flat grads, gx, loss).
    pub fn bwd_loss(
        &self,
        x: &[f32],
        targets: &[i32],
    ) -> Result<(Vec<f32>, Vec<f32>, f32)> {
        let mut args = self.param_literals()?;
        args.push(lit_f32(x, &self.entry.input_shape)?);
        args.push(lit_i32(targets, &[self.micro_batch, self.seq_len])?);
        let out = Self::run(&self.bwd, args, &self.entry.bwd_kept)?;
        let n = self.entry.params.len();
        let grads = flatten_grads(&out[..n])?;
        let gx = out[n].to_vec::<f32>().context("gx")?;
        let loss = out[n + 1].to_vec::<f32>().context("loss")?[0];
        Ok((grads, gx, loss))
    }

    /// SGD update: `params ← params − lr·grads` through the AOT executable
    /// (L1 `sgd_apply` kernel). `flat_grads` in manifest order.
    pub fn sgd_step(&mut self, flat_grads: &[f32], lr: f32) -> Result<()> {
        if flat_grads.len() != self.entry.flat_param_size {
            bail!(
                "grad size {} != {}",
                flat_grads.len(),
                self.entry.flat_param_size
            );
        }
        let mut args = self.param_literals()?;
        let mut off = 0;
        for spec in &self.entry.params {
            args.push(lit_f32(&flat_grads[off..off + spec.numel], &spec.shape)?);
            off += spec.numel;
        }
        args.push(lit_f32(&[lr], &[])?);
        let out = Self::run(&self.sgd, args, &self.entry.sgd_kept)?;
        for (i, spec) in self.entry.params.iter().enumerate() {
            let updated = out[i].to_vec::<f32>().context("updated param")?;
            debug_assert_eq!(updated.len(), spec.numel);
            self.params[i] = updated;
        }
        Ok(())
    }

    /// Pairwise gradient merge through the AOT `merge2` executable (the
    /// L1 Pallas `grad_merge` kernel): `a + b`.
    pub fn merge_grads(&self, a: &[f32], b: &[f32]) -> Result<Vec<f32>> {
        let n = self.entry.flat_param_size;
        if a.len() != n || b.len() != n {
            bail!("merge sizes {}/{} != {}", a.len(), b.len(), n);
        }
        let args = vec![lit_f32(a, &[n])?, lit_f32(b, &[n])?];
        let out = Self::run(&self.merge2, args, &self.entry.merge2_kept)?;
        out[0].to_vec::<f32>().context("merged")
    }

    /// Flatten current params (for checkpointing / sync).
    pub fn flat_params(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.entry.flat_param_size);
        for p in &self.params {
            out.extend_from_slice(p);
        }
        out
    }

    /// Restore params from a flat vector (checkpoint restore).
    pub fn set_flat_params(&mut self, flat: &[f32]) -> Result<()> {
        if flat.len() != self.entry.flat_param_size {
            bail!("param size {} != {}", flat.len(), self.entry.flat_param_size);
        }
        let mut off = 0;
        for (i, spec) in self.entry.params.iter().enumerate() {
            self.params[i].copy_from_slice(&flat[off..off + spec.numel]);
            off += spec.numel;
        }
        Ok(())
    }
}

fn flatten_grads(lits: &[Literal]) -> Result<Vec<f32>> {
    let mut out = Vec::new();
    for l in lits {
        out.extend(l.to_vec::<f32>().context("grad tensor")?);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn manifest() -> Option<Manifest> {
        let d = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        d.join("manifest.json")
            .exists()
            .then(|| Manifest::load(&d).unwrap())
    }

    #[test]
    fn full_stage_roundtrip_through_pjrt() {
        let Some(m) = manifest() else {
            eprintln!("skipping: run `make artifacts` first");
            return;
        };
        let rt = Arc::new(Runtime::cpu().unwrap());
        let embed = rt.load_stage(&m, &m.stages[0]).unwrap();
        let blocks = rt.load_stage(&m, &m.stages[1]).unwrap();
        let head = rt.load_stage(&m, m.stages.last().unwrap()).unwrap();

        let b = m.micro_batch;
        let t = m.seq_len;
        let tokens: Vec<i32> = (0..b * t).map(|i| (i % m.vocab) as i32).collect();
        let targets: Vec<i32> =
            (0..b * t).map(|i| ((i + 1) % m.vocab) as i32).collect();

        // forward chain
        let h0 = embed.fwd_tokens(&tokens).unwrap();
        assert_eq!(h0.len(), b * t * m.d_model);
        let mut h = h0.clone();
        for s in 1..m.n_stages - 1 {
            let stage = rt.load_stage(&m, &m.stages[s]).unwrap();
            h = stage.fwd_acts(&h).unwrap();
        }
        let loss = head.fwd_loss(&h, &targets).unwrap();
        // random init → loss ≈ ln(vocab)
        let expect = (m.vocab as f32).ln();
        assert!(
            (loss - expect).abs() < 1.0,
            "loss {loss} vs ln(V) {expect}"
        );

        // backward chain on the last micro-batch
        let (g_head, gx, loss2) = head.bwd_loss(&h, &targets).unwrap();
        assert_eq!(g_head.len(), head.entry.flat_param_size);
        assert!((loss2 - loss).abs() < 1e-5);
        let (g_blocks, gx2) = blocks.bwd_acts(&h0, &gx).unwrap();
        assert_eq!(g_blocks.len(), blocks.entry.flat_param_size);
        let g_embed = embed.bwd_tokens(&tokens, &gx2).unwrap();
        assert_eq!(g_embed.len(), embed.entry.flat_param_size);
        assert!(g_embed.iter().any(|&x| x != 0.0));
    }

    #[test]
    fn sgd_and_merge_executables_work() {
        let Some(m) = manifest() else {
            return;
        };
        let rt = Arc::new(Runtime::cpu().unwrap());
        let mut head = rt.load_stage(&m, m.stages.last().unwrap()).unwrap();
        let n = head.entry.flat_param_size;

        // merge2 == elementwise add
        let a = vec![1.5f32; n];
        let b: Vec<f32> = (0..n).map(|i| (i % 7) as f32).collect();
        let merged = head.merge_grads(&a, &b).unwrap();
        for i in 0..n {
            assert!((merged[i] - (1.5 + (i % 7) as f32)).abs() < 1e-6);
        }

        // sgd: p' = p - lr*g
        let before = head.flat_params();
        let grads = vec![2.0f32; n];
        head.sgd_step(&grads, 0.1).unwrap();
        let after = head.flat_params();
        for i in 0..n {
            assert!((after[i] - (before[i] - 0.2)).abs() < 1e-5);
        }
    }

    #[test]
    fn set_flat_params_roundtrip() {
        let Some(m) = manifest() else {
            return;
        };
        let rt = Arc::new(Runtime::cpu().unwrap());
        let mut s = rt.load_stage(&m, &m.stages[0]).unwrap();
        let flat: Vec<f32> =
            (0..s.entry.flat_param_size).map(|i| i as f32 * 0.5).collect();
        s.set_flat_params(&flat).unwrap();
        assert_eq!(s.flat_params(), flat);
    }
}
