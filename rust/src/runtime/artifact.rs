//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! rust runtime. See aot.py's module docstring for the file inventory.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// One parameter tensor's layout.
#[derive(Debug, Clone, PartialEq)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub numel: usize,
}

/// One pipeline stage's artifacts.
#[derive(Debug, Clone)]
pub struct StageEntry {
    pub index: usize,
    pub name: String,
    /// "embed" | "blocks" | "head"
    pub kind: String,
    pub params: Vec<ParamSpec>,
    pub flat_param_size: usize,
    pub input_shape: Vec<usize>,
    pub input_dtype: String,
    pub output_shape: Vec<usize>,
    pub fwd_file: String,
    pub bwd_file: String,
    pub sgd_file: String,
    pub merge2_file: String,
    pub init_file: String,
    /// Entry-argument indices each executable kept (jax.jit prunes args
    /// that cannot affect the outputs - see aot.py `kept_args`).
    pub fwd_kept: Vec<usize>,
    pub bwd_kept: Vec<usize>,
    pub sgd_kept: Vec<usize>,
    pub merge2_kept: Vec<usize>,
}

fn kept_vec(entry: &Json, key: &str) -> Result<Vec<usize>> {
    Ok(entry
        .field("kept_args")?
        .field_arr(key)?
        .iter()
        .map(|x| x.as_usize().unwrap_or(usize::MAX))
        .collect())
}

/// Parsed `manifest.json`.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub n_stages: usize,
    pub total_params: usize,
    pub micro_batch: usize,
    pub seq_len: usize,
    pub vocab: usize,
    pub d_model: usize,
    pub stages: Vec<StageEntry>,
}

/// Sentinel `artifacts_dir` selecting the built-in tiny model that the
/// native (no-`xla-rt`) runtime executes in pure rust — the path that
/// lets `train` run end-to-end in the default offline build (CI smoke,
/// scenario-replay tests) without `make artifacts`.
pub const BUILTIN_TINY: &str = "builtin:tiny";

/// File-name marker for stages the native runtime executes (no AOT
/// files on disk).
pub const NATIVE_FILE: &str = "native";

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        if dir.to_str() == Some(BUILTIN_TINY) {
            // only the native executor understands the marker files; the
            // PJRT build would otherwise chase a literal "native" path
            #[cfg(feature = "xla-rt")]
            bail!(
                "{BUILTIN_TINY} runs on the native executor; build \
                 without --features xla-rt to use it"
            );
            #[cfg(not(feature = "xla-rt"))]
            return Ok(Self::builtin_tiny());
        }
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("reading {}/manifest.json", dir.display()))?;
        let j = Json::parse(&text).context("parsing manifest.json")?;

        let cfg = j.field("config")?;
        let mut stages = Vec::new();
        for e in j.field_arr("stages")? {
            let files = e.field("files")?;
            let params = e
                .field_arr("params")?
                .iter()
                .map(|p| -> Result<ParamSpec> {
                    Ok(ParamSpec {
                        name: p.field_str("name")?.to_string(),
                        shape: p
                            .field_arr("shape")?
                            .iter()
                            .map(|x| x.as_usize().unwrap_or(0))
                            .collect(),
                        numel: p.field_usize("numel")?,
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            stages.push(StageEntry {
                index: e.field_usize("index")?,
                name: e.field_str("name")?.to_string(),
                kind: e.field_str("kind")?.to_string(),
                params,
                flat_param_size: e.field_usize("flat_param_size")?,
                input_shape: e
                    .field_arr("input_shape")?
                    .iter()
                    .map(|x| x.as_usize().unwrap_or(0))
                    .collect(),
                input_dtype: e.field_str("input_dtype")?.to_string(),
                output_shape: e
                    .field_arr("output_shape")?
                    .iter()
                    .map(|x| x.as_usize().unwrap_or(0))
                    .collect(),
                fwd_file: files.field_str("fwd")?.to_string(),
                bwd_file: files.field_str("bwd")?.to_string(),
                sgd_file: files.field_str("sgd")?.to_string(),
                merge2_file: files.field_str("merge2")?.to_string(),
                init_file: files.field_str("init")?.to_string(),
                fwd_kept: kept_vec(e, "fwd")?,
                bwd_kept: kept_vec(e, "bwd")?,
                sgd_kept: kept_vec(e, "sgd")?,
                merge2_kept: kept_vec(e, "merge2")?,
            });
        }
        let m = Self {
            dir,
            n_stages: j.field_usize("n_stages")?,
            total_params: j.field_usize("total_params")?,
            micro_batch: cfg.field_usize("micro_batch")?,
            seq_len: cfg.field_usize("seq_len")?,
            vocab: cfg.field_usize("vocab")?,
            d_model: cfg.field_usize("d_model")?,
            stages,
        };
        m.validate()?;
        Ok(m)
    }

    /// The built-in tiny LM: embed → blocks → head over a 64-token
    /// vocabulary with d_model 16 — the exact three-stage shape of the
    /// real AOT artifacts, small enough that the native executor's
    /// pure-rust linear algebra trains it in milliseconds. Every file
    /// reference is the [`NATIVE_FILE`] marker; initial parameters are
    /// generated deterministically by the native runtime instead of
    /// being read from `init` files.
    pub fn builtin_tiny() -> Self {
        let (vocab, d) = (64usize, 16usize);
        let mk = |index: usize,
                  name: &str,
                  kind: &str,
                  p_name: &str,
                  rows: usize,
                  cols: usize,
                  input_shape: Vec<usize>,
                  input_dtype: &str,
                  output_shape: Vec<usize>| {
            StageEntry {
                index,
                name: name.to_string(),
                kind: kind.to_string(),
                params: vec![ParamSpec {
                    name: p_name.to_string(),
                    shape: vec![rows, cols],
                    numel: rows * cols,
                }],
                flat_param_size: rows * cols,
                input_shape,
                input_dtype: input_dtype.to_string(),
                output_shape,
                fwd_file: NATIVE_FILE.into(),
                bwd_file: NATIVE_FILE.into(),
                sgd_file: NATIVE_FILE.into(),
                merge2_file: NATIVE_FILE.into(),
                init_file: NATIVE_FILE.into(),
                fwd_kept: Vec::new(),
                bwd_kept: Vec::new(),
                sgd_kept: Vec::new(),
                merge2_kept: Vec::new(),
            }
        };
        let m = Self {
            dir: PathBuf::from(BUILTIN_TINY),
            n_stages: 3,
            total_params: vocab * d + d * d + d * vocab,
            micro_batch: 2,
            seq_len: 8,
            vocab,
            d_model: d,
            stages: vec![
                mk(0, "embed", "embed", "emb", vocab, d, vec![2, 8], "i32", vec![2, 8, d]),
                mk(1, "blocks", "blocks", "w", d, d, vec![2, 8, d], "f32", vec![2, 8, d]),
                mk(2, "head", "head", "wo", d, vocab, vec![2, 8, d], "f32", vec![2, 8, vocab]),
            ],
        };
        debug_assert!(m.validate().is_ok());
        m
    }

    fn validate(&self) -> Result<()> {
        if self.stages.len() != self.n_stages {
            bail!(
                "manifest stage count mismatch: {} vs {}",
                self.stages.len(),
                self.n_stages
            );
        }
        let total: usize = self.stages.iter().map(|s| s.flat_param_size).sum();
        if total != self.total_params {
            bail!("param total mismatch: {} vs {}", total, self.total_params);
        }
        for s in &self.stages {
            let sum: usize = s.params.iter().map(|p| p.numel).sum();
            if sum != s.flat_param_size {
                bail!("stage {} param sizes inconsistent", s.index);
            }
        }
        Ok(())
    }

    /// Load a stage's initial parameters (raw little-endian f32), split
    /// into per-tensor vectors in spec order.
    pub fn load_init_params(&self, stage: &StageEntry) -> Result<Vec<Vec<f32>>> {
        let path = self.dir.join(&stage.init_file);
        let bytes = std::fs::read(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        if bytes.len() != 4 * stage.flat_param_size {
            bail!(
                "init file {} has {} bytes, want {}",
                stage.init_file,
                bytes.len(),
                4 * stage.flat_param_size
            );
        }
        let flat: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        let mut out = Vec::with_capacity(stage.params.len());
        let mut off = 0;
        for p in &stage.params {
            out.push(flat[off..off + p.numel].to_vec());
            off += p.numel;
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> Option<PathBuf> {
        let d = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        d.join("manifest.json").exists().then_some(d)
    }

    #[test]
    fn loads_real_manifest() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("artifacts/ missing; run `make artifacts`");
            return;
        };
        let m = Manifest::load(&dir).unwrap();
        assert!(m.n_stages >= 3);
        assert_eq!(m.stages[0].kind, "embed");
        assert_eq!(m.stages.last().unwrap().kind, "head");
        for s in &m.stages {
            assert!(dir.join(&s.fwd_file).exists());
            assert!(dir.join(&s.bwd_file).exists());
            assert!(dir.join(&s.sgd_file).exists());
            assert!(dir.join(&s.merge2_file).exists());
        }
    }

    #[test]
    fn init_params_split_correctly() {
        let Some(dir) = artifacts_dir() else {
            return;
        };
        let m = Manifest::load(&dir).unwrap();
        let s = &m.stages[0];
        let params = m.load_init_params(s).unwrap();
        assert_eq!(params.len(), s.params.len());
        for (p, spec) in params.iter().zip(&s.params) {
            assert_eq!(p.len(), spec.numel);
        }
        // embedding init is non-degenerate
        let flat: f32 = params[0].iter().map(|x| x.abs()).sum();
        assert!(flat > 0.0);
    }

    #[test]
    fn builtin_tiny_is_a_valid_native_manifest() {
        let m = Manifest::load(BUILTIN_TINY).unwrap();
        // every stage carries the native marker load_stage gates on
        assert!(m.stages.iter().all(|s| s.fwd_file == NATIVE_FILE));
        assert_eq!(m.n_stages, 3);
        assert_eq!(m.stages[0].kind, "embed");
        assert_eq!(m.stages.last().unwrap().kind, "head");
        assert_eq!(
            m.total_params,
            m.stages.iter().map(|s| s.flat_param_size).sum::<usize>()
        );
        assert_eq!(m.vocab, 64);
        assert_eq!(m.micro_batch * m.seq_len, 16);
    }

    #[test]
    fn rejects_bad_manifest() {
        let tmp = std::env::temp_dir().join("funcpipe_bad_manifest");
        std::fs::create_dir_all(&tmp).unwrap();
        std::fs::write(
            tmp.join("manifest.json"),
            r#"{"n_stages": 2, "total_params": 0, "config": {"micro_batch": 1,
                "seq_len": 1, "vocab": 1, "d_model": 1}, "stages": []}"#,
        )
        .unwrap();
        assert!(Manifest::load(&tmp).is_err());
    }
}
