//! PJRT runtime: load AOT-compiled HLO-text artifacts and execute them on
//! the request path — python is never involved after `make artifacts`.
//!
//! * [`artifact`] — `manifest.json` parsing + raw `.f32` initial params;
//! * [`stage`] — a loaded stage: fwd/bwd/sgd/merge2 executables plus
//!   the parameter tensors, with flat-vector views for the collectives.

pub mod artifact;

/// Real PJRT execution; needs the `xla` bindings, which the offline
/// registry cannot provide. Built only with `--features xla-rt`; the
/// default build substitutes [`stage_stub`] whose `Runtime::cpu()` fails
/// fast, so everything artifact-gated (trainer, profiler, e2e tests)
/// skips itself cleanly.
#[cfg(feature = "xla-rt")]
pub mod stage;

#[cfg(not(feature = "xla-rt"))]
#[path = "stage_stub.rs"]
pub mod stage;

pub use artifact::{Manifest, ParamSpec, StageEntry};
pub use stage::{Runtime, StageExec};
