//! PJRT runtime: load AOT-compiled HLO-text artifacts and execute them on
//! the request path — python is never involved after `make artifacts`.
//!
//! * [`artifact`] — `manifest.json` parsing + raw `.f32` initial params;
//! * [`stage`] — a loaded stage: fwd/bwd/sgd/merge2 executables plus
//!   the parameter tensors, with flat-vector views for the collectives.

pub mod artifact;
pub mod stage;

pub use artifact::{Manifest, ParamSpec, StageEntry};
pub use stage::{Runtime, StageExec};
