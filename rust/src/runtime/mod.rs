//! PJRT runtime: load AOT-compiled HLO-text artifacts and execute them on
//! the request path — python is never involved after `make artifacts`.
//!
//! * [`artifact`] — `manifest.json` parsing + raw `.f32` initial params;
//! * [`stage`] — a loaded stage: fwd/bwd/sgd/merge2 executables plus
//!   the parameter tensors, with flat-vector views for the collectives.

pub mod artifact;

/// Real PJRT execution; needs the `xla` bindings, which the offline
/// registry cannot provide. Built only with `--features xla-rt`; the
/// default build substitutes [`stage_native`], which executes the
/// built-in tiny model (`--artifacts builtin:tiny`) in pure rust so
/// `train` runs end-to-end offline, and fails fast with a clear message
/// when pointed at real AOT artifacts.
#[cfg(feature = "xla-rt")]
pub mod stage;

#[cfg(not(feature = "xla-rt"))]
#[path = "stage_native.rs"]
pub mod stage;

pub use artifact::{Manifest, ParamSpec, StageEntry, BUILTIN_TINY};
pub use stage::{Runtime, StageExec};
