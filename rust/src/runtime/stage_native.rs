//! Native runtime used when the `xla-rt` feature is off (the default in
//! the offline build). It mirrors the public surface of
//! [`stage`](super::stage) so every caller compiles, and — new since the
//! scenario-injector work — it *executes* manifests whose stages carry
//! the [`NATIVE_FILE`](super::artifact::NATIVE_FILE) marker (the
//! [`builtin_tiny`](super::artifact::Manifest::builtin_tiny) model) in
//! pure rust, so `train` runs end-to-end in the default build: the CI
//! smoke and the train-path scenario-replay tests exercise the real
//! coordinator/storage/collective stack without `make artifacts`.
//!
//! The native model is a linear LM with the same three-stage shape as
//! the AOT artifacts: `embed` (a vocab×d table lookup), `blocks` (one
//! d×d linear map, identity-initialized), `head` (d×vocab logits +
//! softmax cross-entropy). Everything is single-threaded f32 loops in a
//! fixed order with deterministically seeded initial parameters, so two
//! independent runs produce bit-identical losses — the property the
//! deterministic train-replay contract stands on. Real AOT artifacts
//! still require `--features xla-rt`; loading them here fails fast with
//! the historical message.

use std::sync::Arc;

use anyhow::{bail, Result};

use super::artifact::{Manifest, StageEntry, NATIVE_FILE};
use crate::util::rng::Rng;

const UNAVAILABLE: &str =
    "PJRT runtime unavailable: rebuild with `--features xla-rt` (requires \
     the xla bindings; see runtime/stage.rs). The native fallback only \
     executes the built-in model (`--artifacts builtin:tiny`)";

/// Stand-in for the process-wide PJRT client: a handle to the native
/// executor.
pub struct Runtime {}

impl Runtime {
    pub fn cpu() -> Result<Self> {
        Ok(Self {})
    }

    pub fn load_stage(
        self: &Arc<Self>,
        manifest: &Manifest,
        entry: &StageEntry,
    ) -> Result<StageExec> {
        if entry.fwd_file != NATIVE_FILE {
            bail!(UNAVAILABLE);
        }
        Ok(StageExec::native(manifest, entry))
    }
}

/// A loaded native stage: parameters plus the pure-rust executables.
pub struct StageExec {
    pub entry: StageEntry,
    pub micro_batch: usize,
    pub seq_len: usize,
    /// Parameter tensors (f32, row-major) in manifest order.
    pub params: Vec<Vec<f32>>,
    vocab: usize,
    d_model: usize,
}

impl StageExec {
    /// Deterministically initialized native stage. The seed is a fixed
    /// function of the stage index so every replica (and every run)
    /// starts from identical parameters.
    fn native(manifest: &Manifest, entry: &StageEntry) -> Self {
        let (vocab, d) = (manifest.vocab, manifest.d_model);
        let mut rng = Rng::new(0xF1A7_1A7E ^ ((entry.index as u64) << 8));
        let init: Vec<f32> = match entry.kind.as_str() {
            // embeddings: the feature scale driving every gradient
            "embed" => (0..entry.flat_param_size)
                .map(|_| rng.uniform(-1.0, 1.0) as f32)
                .collect(),
            // identity map so the signal (and its gradient) flows
            // through the middle stage from step 0
            "blocks" => (0..entry.flat_param_size)
                .map(|i| if i % (d + 1) == 0 { 1.0 } else { 0.0 })
                .collect(),
            // near-zero logits: initial loss is ~ln(vocab)
            _ => (0..entry.flat_param_size)
                .map(|_| rng.uniform(-0.1, 0.1) as f32)
                .collect(),
        };
        Self {
            entry: entry.clone(),
            micro_batch: manifest.micro_batch,
            seq_len: manifest.seq_len,
            params: vec![init],
            vocab,
            d_model: d,
        }
    }

    fn weights(&self) -> &[f32] {
        &self.params[0]
    }

    /// embed forward: `out[i, :] = emb[tokens[i], :]`.
    pub fn fwd_tokens(&self, tokens: &[i32]) -> Result<Vec<f32>> {
        let d = self.d_model;
        let emb = self.weights();
        let mut out = vec![0.0f32; tokens.len() * d];
        for (i, &t) in tokens.iter().enumerate() {
            let t = t as usize;
            if t >= self.vocab {
                bail!("token {t} out of vocab {}", self.vocab);
            }
            out[i * d..(i + 1) * d].copy_from_slice(&emb[t * d..(t + 1) * d]);
        }
        Ok(out)
    }

    /// embed backward: scatter-add of the upstream gradient rows.
    pub fn bwd_tokens(&self, tokens: &[i32], gy: &[f32]) -> Result<Vec<f32>> {
        let d = self.d_model;
        if gy.len() != tokens.len() * d {
            bail!("embed bwd shape: {} vs {}", gy.len(), tokens.len() * d);
        }
        let mut g = vec![0.0f32; self.entry.flat_param_size];
        for (i, &t) in tokens.iter().enumerate() {
            let t = t as usize;
            if t >= self.vocab {
                bail!("token {t} out of vocab {}", self.vocab);
            }
            for j in 0..d {
                g[t * d + j] += gy[i * d + j];
            }
        }
        Ok(g)
    }

    /// blocks forward: `y = x · W` per position.
    pub fn fwd_acts(&self, x: &[f32]) -> Result<Vec<f32>> {
        let d = self.d_model;
        if x.len() % d != 0 {
            bail!("blocks fwd shape: {} not a multiple of {d}", x.len());
        }
        let w = self.weights();
        let n = x.len() / d;
        let mut y = vec![0.0f32; x.len()];
        for i in 0..n {
            for a in 0..d {
                let xv = x[i * d + a];
                if xv == 0.0 {
                    continue;
                }
                for b in 0..d {
                    y[i * d + b] += xv * w[a * d + b];
                }
            }
        }
        Ok(y)
    }

    /// blocks backward: `(gW, gx)` with `gW = xᵀ·gy`, `gx = gy·Wᵀ`.
    pub fn bwd_acts(&self, x: &[f32], gy: &[f32]) -> Result<(Vec<f32>, Vec<f32>)> {
        let d = self.d_model;
        if x.len() != gy.len() || x.len() % d != 0 {
            bail!("blocks bwd shape: {} vs {}", x.len(), gy.len());
        }
        let w = self.weights();
        let n = x.len() / d;
        let mut gw = vec![0.0f32; d * d];
        let mut gx = vec![0.0f32; x.len()];
        for i in 0..n {
            for a in 0..d {
                let xv = x[i * d + a];
                let mut acc = 0.0f32;
                for b in 0..d {
                    gw[a * d + b] += xv * gy[i * d + b];
                    acc += gy[i * d + b] * w[a * d + b];
                }
                gx[i * d + a] = acc;
            }
        }
        Ok((gw, gx))
    }

    /// head: per-position softmax cross-entropy over the vocabulary.
    /// Returns the mean loss and, in the bwd variant, mean gradients.
    fn head_pass(
        &self,
        x: &[f32],
        targets: &[i32],
        want_grads: bool,
    ) -> Result<(Vec<f32>, Vec<f32>, f32)> {
        let (d, v) = (self.d_model, self.vocab);
        let n = targets.len();
        if x.len() != n * d {
            bail!("head shape: {} vs {}", x.len(), n * d);
        }
        let wo = self.weights();
        let inv_n = 1.0f32 / n as f32;
        let mut gwo = vec![0.0f32; if want_grads { d * v } else { 0 }];
        let mut gx = vec![0.0f32; if want_grads { n * d } else { 0 }];
        let mut loss = 0.0f32;
        let mut logits = vec![0.0f32; v];
        for i in 0..n {
            let xi = &x[i * d..(i + 1) * d];
            logits.iter_mut().for_each(|l| *l = 0.0);
            for (a, &xv) in xi.iter().enumerate() {
                if xv == 0.0 {
                    continue;
                }
                let row = &wo[a * v..(a + 1) * v];
                for (l, &wv) in logits.iter_mut().zip(row) {
                    *l += xv * wv;
                }
            }
            let max = logits.iter().fold(f32::NEG_INFINITY, |m, &l| m.max(l));
            let mut z = 0.0f32;
            let mut probs = logits.clone();
            for p in &mut probs {
                *p = (*p - max).exp();
                z += *p;
            }
            let t = targets[i] as usize;
            if t >= v {
                bail!("target {t} out of vocab {v}");
            }
            loss += -(probs[t] / z).max(1e-30).ln();
            if want_grads {
                // dl = (softmax − onehot) / n
                for p in &mut probs {
                    *p = *p / z * inv_n;
                }
                probs[t] -= inv_n;
                for (a, &xv) in xi.iter().enumerate() {
                    let row = &wo[a * v..(a + 1) * v];
                    let mut acc = 0.0f32;
                    for (b, (&dl, &wv)) in probs.iter().zip(row).enumerate() {
                        gwo[a * v + b] += xv * dl;
                        acc += dl * wv;
                    }
                    gx[i * d + a] = acc;
                }
            }
        }
        Ok((gwo, gx, loss * inv_n))
    }

    pub fn fwd_loss(&self, x: &[f32], targets: &[i32]) -> Result<f32> {
        Ok(self.head_pass(x, targets, false)?.2)
    }

    pub fn bwd_loss(
        &self,
        x: &[f32],
        targets: &[i32],
    ) -> Result<(Vec<f32>, Vec<f32>, f32)> {
        self.head_pass(x, targets, true)
    }

    /// Plain SGD over the flat parameter vector.
    pub fn sgd_step(&mut self, flat_grads: &[f32], lr: f32) -> Result<()> {
        if flat_grads.len() != self.entry.flat_param_size {
            bail!(
                "sgd grad size {} != {}",
                flat_grads.len(),
                self.entry.flat_param_size
            );
        }
        let mut off = 0;
        for p in &mut self.params {
            for (w, &g) in p.iter_mut().zip(&flat_grads[off..off + p.len()]) {
                *w -= lr * g;
            }
            off += p.len();
        }
        Ok(())
    }

    /// The grad_merge kernel's semantics: elementwise sum.
    pub fn merge_grads(&self, a: &[f32], b: &[f32]) -> Result<Vec<f32>> {
        if a.len() != b.len() {
            bail!("merge_grads size {} != {}", a.len(), b.len());
        }
        Ok(a.iter().zip(b).map(|(x, y)| x + y).collect())
    }

    pub fn flat_params(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.entry.flat_param_size);
        for p in &self.params {
            out.extend_from_slice(p);
        }
        out
    }

    pub fn set_flat_params(&mut self, flat: &[f32]) -> Result<()> {
        if flat.len() != self.entry.flat_param_size {
            bail!("param size {} != {}", flat.len(), self.entry.flat_param_size);
        }
        let mut off = 0;
        for (i, spec) in self.entry.params.iter().enumerate() {
            self.params[i].copy_from_slice(&flat[off..off + spec.numel]);
            off += spec.numel;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stages() -> (Manifest, Vec<StageExec>) {
        let m = Manifest::builtin_tiny();
        let rt = Arc::new(Runtime::cpu().unwrap());
        let s = m
            .stages
            .iter()
            .map(|e| rt.load_stage(&m, e).unwrap())
            .collect();
        (m, s)
    }

    #[test]
    fn non_native_manifests_still_fail_fast() {
        let m = Manifest::builtin_tiny();
        let mut entry = m.stages[0].clone();
        entry.fwd_file = "stage0_fwd.hlo".into();
        let rt = Arc::new(Runtime::cpu().unwrap());
        assert!(rt.load_stage(&m, &entry).is_err());
    }

    #[test]
    fn init_is_deterministic_across_loads() {
        let (_, a) = stages();
        let (_, b) = stages();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.flat_params(), y.flat_params());
        }
    }

    #[test]
    fn forward_shapes_chain() {
        let (m, s) = stages();
        let tokens: Vec<i32> = (0..(m.micro_batch * m.seq_len) as i32).map(|i| i % 64).collect();
        let h0 = s[0].fwd_tokens(&tokens).unwrap();
        assert_eq!(h0.len(), tokens.len() * m.d_model);
        let h1 = s[1].fwd_acts(&h0).unwrap();
        assert_eq!(h1.len(), h0.len());
        let targets: Vec<i32> = tokens.iter().map(|t| (t + 1) % 64).collect();
        let loss = s[2].fwd_loss(&h1, &targets).unwrap();
        // near-zero logits ⇒ loss ≈ ln(64)
        assert!((loss - 64f32.ln()).abs() < 0.5, "loss {loss}");
    }

    #[test]
    fn identity_blocks_pass_through() {
        let (_, s) = stages();
        let x: Vec<f32> = (0..32).map(|i| i as f32 * 0.1).collect();
        let y = s[1].fwd_acts(&x).unwrap();
        assert_eq!(x, y);
    }

    #[test]
    fn head_gradients_match_finite_differences() {
        let (m, mut s) = stages();
        let head = &mut s[2];
        let n = 3usize;
        let d = m.d_model;
        let x: Vec<f32> = (0..n * d).map(|i| ((i * 7 % 13) as f32 - 6.0) * 0.1).collect();
        let targets: Vec<i32> = vec![5, 40, 63];
        let (gwo, gx, base) = head.bwd_loss(&x, &targets).unwrap();
        let eps = 1e-3f32;
        // parameter gradient: bump one weight
        for &idx in &[0usize, d * 64 / 2 + 5, d * 64 - 1] {
            let mut bumped = head.flat_params();
            bumped[idx] += eps;
            head.set_flat_params(&bumped).unwrap();
            let plus = head.fwd_loss(&x, &targets).unwrap();
            bumped[idx] -= 2.0 * eps;
            head.set_flat_params(&bumped).unwrap();
            let minus = head.fwd_loss(&x, &targets).unwrap();
            bumped[idx] += eps;
            head.set_flat_params(&bumped).unwrap();
            let fd = (plus - minus) / (2.0 * eps);
            assert!(
                (fd - gwo[idx]).abs() < 5e-3,
                "gwo[{idx}]: fd {fd} vs analytic {}",
                gwo[idx]
            );
        }
        // input gradient: bump one activation
        let mut xp = x.clone();
        xp[4] += eps;
        let plus = head.fwd_loss(&xp, &targets).unwrap();
        xp[4] -= 2.0 * eps;
        let minus = head.fwd_loss(&xp, &targets).unwrap();
        let fd = (plus - minus) / (2.0 * eps);
        assert!((fd - gx[4]).abs() < 5e-3, "gx[4]: fd {fd} vs {}", gx[4]);
        assert!(base.is_finite());
    }

    #[test]
    fn blocks_gradients_match_finite_differences() {
        let (m, mut s) = stages();
        let d = m.d_model;
        let x: Vec<f32> = (0..2 * d).map(|i| (i as f32 * 0.37).sin()).collect();
        let gy: Vec<f32> = (0..2 * d).map(|i| (i as f32 * 0.11).cos()).collect();
        let (gw, gx) = s[1].bwd_acts(&x, &gy).unwrap();
        // loss L = <y, gy>; dL/dW and dL/dx must match finite differences
        let loss_of = |stage: &StageExec, x: &[f32]| -> f32 {
            stage
                .fwd_acts(x)
                .unwrap()
                .iter()
                .zip(&gy)
                .map(|(a, b)| a * b)
                .sum()
        };
        let eps = 1e-2f32;
        let idx = d + 3; // W[1][3]
        let mut w = s[1].flat_params();
        w[idx] += eps;
        s[1].set_flat_params(&w).unwrap();
        let plus = loss_of(&s[1], &x);
        w[idx] -= 2.0 * eps;
        s[1].set_flat_params(&w).unwrap();
        let minus = loss_of(&s[1], &x);
        w[idx] += eps;
        s[1].set_flat_params(&w).unwrap();
        let fd = (plus - minus) / (2.0 * eps);
        assert!((fd - gw[idx]).abs() < 1e-2, "gw: fd {fd} vs {}", gw[idx]);

        let mut xp = x.clone();
        xp[7] += eps;
        let plus = loss_of(&s[1], &xp);
        xp[7] -= 2.0 * eps;
        let minus = loss_of(&s[1], &xp);
        let fd = (plus - minus) / (2.0 * eps);
        assert!((fd - gx[7]).abs() < 1e-2, "gx: fd {fd} vs {}", gx[7]);
    }

    #[test]
    fn sgd_descends_the_head_loss() {
        let (m, mut s) = stages();
        let tokens: Vec<i32> =
            (0..(m.micro_batch * m.seq_len) as i32).map(|i| (i * 5) % 64).collect();
        let targets: Vec<i32> = tokens.iter().map(|t| (t * 3 + 1) % 64).collect();
        let x = s[0].fwd_tokens(&tokens).unwrap();
        let h = s[1].fwd_acts(&x).unwrap();
        let mut last = f32::INFINITY;
        for _ in 0..20 {
            let (g, _, loss) = s[2].bwd_loss(&h, &targets).unwrap();
            assert!(loss <= last + 1e-4, "loss rose: {last} -> {loss}");
            last = loss;
            s[2].sgd_step(&g, 0.5).unwrap();
        }
        assert!(last < 64f32.ln() * 0.9, "no learning: {last}");
    }

    #[test]
    fn merge_grads_is_elementwise_sum() {
        let (_, s) = stages();
        let a = vec![1.0f32, 2.0, 3.0];
        let b = vec![0.5f32, -2.0, 1.0];
        assert_eq!(s[0].merge_grads(&a, &b).unwrap(), vec![1.5, 0.0, 4.0]);
        assert!(s[0].merge_grads(&a, &b[..2]).is_err());
    }
}
