//! One serverless worker: executes its stage's share of every iteration.
//!
//! The loop follows the §3.2 schedule: μ forward micro-batches (download
//! input → compute → upload output), then μ backward micro-batches in
//! reverse order, then intra-stage scatter-reduce (if d > 1) and the SGD
//! update through the AOT executable. Uploads stream through the flow
//! pool's uploader task so uplink and compute/downlink overlap — the
//! paper's Task-Executor DAG, specialized to the fixed GPipe order.
//!
//! A worker is an **async state machine**, not a thread: [`run_worker`]
//! is an `async fn` the leader spawns onto the shared bounded executor
//! ([`crate::exec`]), so a dp=1024 local run costs
//! `available_parallelism` OS threads, not thousands. Every store wait
//! suspends the task instead of parking a thread; compute (the AOT/native
//! executables) runs inline on the pool, which is exactly the serverless
//! model — one vCPU share per function.
//!
//! Since the elastic re-planning refactor a worker owns a contiguous
//! **group** of manifest layers (`TrainConfig::layer_groups`), not
//! exactly one: a mid-run migration can re-partition the same manifest
//! into fewer, fatter stages, and the forward/backward waves below walk
//! the group's layer executables in order. The historical one-layer-
//! per-stage behaviour is the empty-grouping default and is
//! byte-identical to the pre-refactor worker.
//!
//! The Function Manager half lives here too: after each iteration the
//! worker checks its remaining lifetime and, if below the margin,
//! checkpoints its parameters to storage, "restarts" (new generation,
//! charging the tier's cold start), and restores — exercising the
//! §3.1-step-8 path that real platforms force every 15 minutes.
//! Checkpoints are **layer-addressed** (`ckpt/g{gen}/l{layer}[/r{rep}]`)
//! so a different partitioning can restore them after a migration.
//!
//! The scenario [`Injector`] perturbs this path exactly where the
//! simulator's lenses act: the worker's throttled store handle is
//! scaled by its bandwidth/latency lens, every generation's cold start
//! is the tier base plus the scenario draw, and — when
//! `TrainConfig::virtual_iter_s` is set — the lifecycle ages on a
//! deterministic virtual clock so the checkpoint/restart schedule (and
//! therefore the whole report) replays bit-identically per seed.

use std::sync::mpsc::Sender;
use std::sync::Arc;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::collective::sendrecv::{
    boundary_key, recv_chunked_consume_async, recv_consume_async, send_async,
    send_chunked_async,
};
use crate::collective::{Chunking, CollectiveCtx};
use crate::platform::function::FunctionInstance;
use crate::platform::{ObjectStore, ThrottledStore};
use crate::runtime::{Manifest, Runtime, StageExec};
use crate::scenario::{Injector, WorkerLens};
use crate::trainer::data::Corpus;
use crate::trainer::TrainConfig;

const RECV_TIMEOUT: Duration = Duration::from_secs(120);

/// Message from the head worker to the monitor.
pub struct IterMsg {
    pub step: usize,
    pub loss: f32,
    pub replica: usize,
}

/// Per-worker lifecycle and scenario-lens stats, returned to the leader
/// and surfaced as the `TrainReport`'s scenario columns.
#[derive(Debug, Clone)]
pub struct WorkerStats {
    pub worker_id: usize,
    pub stage: usize,
    pub replica: usize,
    /// Plan generation this worker ran under (0 before any re-plan).
    pub plan_generation: u64,
    /// Checkpoint/restart cycles performed.
    pub restarts: usize,
    /// Function generations launched (`restarts + 1`).
    pub generations: u32,
    /// Cold-start seconds charged, exactly once per generation.
    pub cold_start_s: f64,
    /// The scenario lens this worker ran under.
    pub lens: WorkerLens,
    /// Deterministic elapsed seconds on the virtual clock (0 in
    /// wall-clock mode).
    pub virtual_elapsed_s: f64,
    /// Transient `get_blocking` drops injected by the `flaky-network`
    /// lens (each one absorbed by a retry; 0 under every other lens).
    pub flaky_timeouts: u64,
}

pub struct WorkerCtx {
    pub cfg: TrainConfig,
    /// Index of this worker's pipeline stage (its layer group).
    pub stage_idx: usize,
    /// Contiguous manifest-layer range `[lo, hi)` this stage executes.
    pub group: (usize, usize),
    /// Total pipeline stages in this segment.
    pub n_groups: usize,
    pub replica: usize,
    pub base_store: Arc<dyn ObjectStore>,
    pub monitor: Option<Sender<IterMsg>>,
    /// Shared seeded perturbation provider (identity when inactive).
    pub injector: Arc<Injector>,
    /// Post-migration restore: per-manifest-layer parameters read (and
    /// consumed) from the previous generation's migration shards by the
    /// leader, shared across all workers.
    pub init_params: Option<Arc<Vec<Vec<f32>>>>,
}

/// Boundary tensors ride the same chunking policy as the gradient
/// collectives: with chunking on, activations/gradients relay as
/// bounded chunk flows instead of one blob per micro-batch.
async fn send_boundary(
    store: &Arc<dyn ObjectStore>,
    chunking: Chunking,
    key: &str,
    data: &[f32],
) -> Result<()> {
    if chunking.is_chunked() {
        send_chunked_async(store, key, data, chunking).await
    } else {
        send_async(store, key, data).await
    }
}

async fn recv_boundary(
    store: &Arc<dyn ObjectStore>,
    chunking: Chunking,
    key: &str,
) -> Result<Vec<f32>> {
    if chunking.is_chunked() {
        recv_chunked_consume_async(store, key, RECV_TIMEOUT).await
    } else {
        recv_consume_async(store, key, RECV_TIMEOUT).await
    }
}

/// Entry point of a worker state machine (the leader spawns one task per
/// stage × replica). Returns the worker's lifecycle stats (restart
/// count, generations, cold-start charges, lens).
pub async fn run_worker(ctx: WorkerCtx) -> Result<WorkerStats> {
    let cfg = &ctx.cfg;
    let worker_id = ctx.stage_idx * cfg.dp + ctx.replica;
    let lens = ctx.injector.worker(worker_id);
    // per-worker throttled view of the shared bucket (its own "NIC"),
    // scaled by the worker's scenario lens
    let store: Arc<dyn ObjectStore> = match cfg.throttle {
        Some((bps, lat)) => Arc::new(
            ThrottledStore::new(
                ctx.base_store.clone(),
                bps,
                bps,
                Duration::from_secs_f64(lat),
            )
            .scaled(lens.bandwidth_mult, lens.latency_mult),
        ),
        None => ctx.base_store.clone(),
    };
    // flaky-network lens: seeded transient get_blocking drops injected
    // below a bounded-retry middleware. A drop fails instantly, hits a
    // key at most once and costs exactly one retry, so the run stays
    // deterministic and the report observes the retry path
    // (`flaky_timeouts`).
    let (store, flaky_counter): (Arc<dyn ObjectStore>, _) =
        match ctx.injector.flaky() {
            Some((prob, _timeout_s)) => {
                let flaky = crate::scenario::FlakyStore::new(
                    store,
                    cfg.scenario_seed,
                    worker_id,
                    prob,
                );
                let counter = flaky.timeout_counter();
                let retry =
                    crate::platform::RetryStore::new(Arc::new(flaky), 2);
                (Arc::new(retry), Some(counter))
            }
            None => (store, None),
        };

    let manifest = Manifest::load(&cfg.artifacts_dir)?;
    let rt = Arc::new(Runtime::cpu()?);
    let (lo, hi) = ctx.group;
    let n_layers = manifest.n_stages;
    let mut stages: Vec<StageExec> = Vec::with_capacity(hi - lo);
    for l in lo..hi {
        stages.push(rt.load_stage(&manifest, &manifest.stages[l])?);
    }
    if let Some(init) = &ctx.init_params {
        for (k, l) in (lo..hi).enumerate() {
            stages[k]
                .set_flat_params(&init[l])
                .with_context(|| format!("migration restore of layer {l}"))?;
        }
    }
    let is_first = lo == 0;
    let is_last = hi == n_layers;
    let corpus = Corpus::new(
        manifest.vocab,
        manifest.seq_len,
        manifest.micro_batch,
        cfg.seed,
    );

    let mut func = FunctionInstance::launch(
        worker_id,
        ctx.stage_idx,
        ctx.replica,
        0,
        cfg.lifetime_s,
    );
    let mut stats = WorkerStats {
        worker_id,
        stage: ctx.stage_idx,
        replica: ctx.replica,
        plan_generation: cfg.plan_generation,
        restarts: 0,
        generations: 1,
        cold_start_s: 0.0,
        lens,
        virtual_elapsed_s: 0.0,
        flaky_timeouts: 0,
    };
    // every generation — the initial launch included — charges a cold
    // start: the tier's base plus the scenario's per-generation draw
    charge_cold_start(cfg, &ctx.injector, &mut func, &mut stats).await;
    func.mark_running();

    // flat gradient layout: the group's layers concatenated in order
    let grad_lens: Vec<usize> =
        stages.iter().map(|s| s.entry.flat_param_size).collect();
    let grad_offs: Vec<usize> = grad_lens
        .iter()
        .scan(0usize, |acc, &len| {
            let off = *acc;
            *acc += len;
            Some(off)
        })
        .collect();
    let grad_len_total: usize = grad_lens.iter().sum();
    let lr_scale = 1.0 / (cfg.mu * cfg.dp) as f32;

    // Persistent collective context for the intra-stage sync: its flow
    // pool's uploader/downloader tasks live for the whole training run
    // and are reused every round.
    let sync_ctx = (cfg.dp > 1).then(|| {
        CollectiveCtx::new(
            store.clone(),
            format!("sync/s{}", ctx.stage_idx),
            ctx.replica,
            cfg.dp,
            RECV_TIMEOUT,
        )
        .with_chunking(cfg.chunking)
    });

    // Pipeline-gated virtual tick (loop-invariant): a pipelined
    // iteration is gated by the slowest worker, so EVERY function ages
    // by the slowest lens-stretched tick — the same duration the leader
    // logs per step, keeping the checkpoint schedule consistent with
    // the report's own timeline (a fast worker idles at the boundary,
    // but its container keeps aging). A calibrated (post-migration)
    // segment's base is already the measured gated tick, so it is used
    // verbatim instead of re-stretching by the lens.
    let virtual_tick = cfg.virtual_iter_s.map(|base| {
        if cfg.calibrated_tick {
            base
        } else {
            ctx.injector.max_iter_virtual_s(base)
        }
    });

    for step in 0..cfg.steps {
        // global step: corpus schedule, boundary keys and sync rounds
        // stay continuous (and collision-free) across migrations
        let gstep = cfg.step_offset + step;
        let round = gstep as u64;
        let mut grads_acc = vec![0.0f32; grad_len_total];
        // saved inputs for the backward passes, per local layer per
        // micro-batch (stage-level remat keeps only each layer's input,
        // §3.2 memory model); the embed layer saves tokens instead
        let mut saved: Vec<Vec<Vec<f32>>> = vec![Vec::new(); stages.len()];
        let mut saved_tok: Vec<Vec<i32>> = Vec::with_capacity(cfg.mu);
        let mut losses = 0.0f32;

        // ---- forward wave ------------------------------------------------
        for mb in 0..cfg.mu {
            let mut cur: Option<Vec<f32>>;
            let start_k;
            if is_first {
                let (tokens, _) = corpus.batch(gstep, ctx.replica, mb);
                cur =
                    Some(stages[0].fwd_tokens(&tokens).context("embed fwd")?);
                saved_tok.push(tokens);
                start_k = 1;
            } else {
                cur = Some(
                    recv_boundary(
                        &store,
                        cfg.chunking,
                        &boundary_key(
                            "fwd",
                            round,
                            ctx.stage_idx - 1,
                            ctx.replica,
                            mb,
                        ),
                    )
                    .await?,
                );
                start_k = 0;
            }
            for k in start_k..stages.len() {
                let x = cur.take().expect("activation");
                if lo + k == n_layers - 1 {
                    // head: loss computed in backward; save input only
                    saved[k].push(x);
                } else {
                    let out = stages[k].fwd_acts(&x).context("blocks fwd")?;
                    saved[k].push(x);
                    cur = Some(out);
                }
            }
            if !is_last {
                let out = cur.take().expect("boundary activation");
                send_boundary(
                    &store,
                    cfg.chunking,
                    &boundary_key("fwd", round, ctx.stage_idx, ctx.replica, mb),
                    &out,
                )
                .await?;
            }
        }

        // ---- backward wave (reverse micro order) ------------------------
        for mb in (0..cfg.mu).rev() {
            let mut gy: Vec<f32>;
            // highest local layer still owing a backward pass
            let top_k: Option<usize>;
            if is_last {
                let (_, targets) = corpus.batch(gstep, ctx.replica, mb);
                let k_head = stages.len() - 1;
                let x = &saved[k_head][mb];
                let (g, gx, loss) =
                    stages[k_head].bwd_loss(x, &targets).context("head bwd")?;
                crate::collective::add_assign(
                    &mut grads_acc
                        [grad_offs[k_head]..grad_offs[k_head] + grad_lens[k_head]],
                    &g,
                );
                losses += loss;
                gy = gx;
                top_k = k_head.checked_sub(1);
            } else {
                gy = recv_boundary(
                    &store,
                    cfg.chunking,
                    &boundary_key(
                        "bwd",
                        round,
                        ctx.stage_idx + 1,
                        ctx.replica,
                        mb,
                    ),
                )
                .await?;
                top_k = Some(stages.len() - 1);
            }
            if let Some(top) = top_k {
                for k in (0..=top).rev() {
                    if lo + k == 0 {
                        let g = stages[0]
                            .bwd_tokens(&saved_tok[mb], &gy)
                            .context("embed bwd")?;
                        crate::collective::add_assign(
                            &mut grads_acc
                                [grad_offs[0]..grad_offs[0] + grad_lens[0]],
                            &g,
                        );
                    } else {
                        let (g, gx) = stages[k]
                            .bwd_acts(&saved[k][mb], &gy)
                            .context("blocks bwd")?;
                        crate::collective::add_assign(
                            &mut grads_acc
                                [grad_offs[k]..grad_offs[k] + grad_lens[k]],
                            &g,
                        );
                        gy = gx;
                    }
                }
            }
            if !is_first {
                send_boundary(
                    &store,
                    cfg.chunking,
                    &boundary_key("bwd", round, ctx.stage_idx, ctx.replica, mb),
                    &gy,
                )
                .await?;
            }
        }

        // ---- intra-stage sync (scatter-reduce over the d replicas) -------
        if let Some(sync) = &sync_ctx {
            // route the merge through the AOT merge2 executable (the L1
            // Pallas grad_merge kernel) when split sizes allow; fall back
            // to the native add for partial splits/chunks and for
            // multi-layer groups (their flat layout spans executables).
            let merge = |acc: &mut [f32], delta: &[f32]| {
                if stages.len() == 1 && acc.len() == grad_len_total {
                    if let Ok(merged) = stages[0].merge_grads(acc, delta) {
                        acc.copy_from_slice(&merged);
                        return;
                    }
                }
                crate::collective::add_assign(acc, delta);
            };
            sync.all_reduce(cfg.sync_alg, round, &mut grads_acc, Some(&merge))
                .await?;
            // garbage-collect an older round's sync objects; cleanup's
            // done-marker barrier is already satisfied (every replica
            // passed round-2 to reach here), so this never suspends long
            // and a straggler can never lose objects it still needs.
            // Bounded to this segment's rounds: a previous segment's dp
            // may differ, so its leftovers are never touched here.
            if step >= 2 && ctx.replica == 0 {
                crate::collective::scatter_reduce::cleanup_async(
                    &store,
                    &sync.group,
                    round - 2,
                    cfg.dp,
                    RECV_TIMEOUT,
                )
                .await?;
            }
        }

        // ---- SGD update through the AOT executables -----------------------
        for g in grads_acc.iter_mut() {
            *g *= lr_scale;
        }
        for k in 0..stages.len() {
            stages[k]
                .sgd_step(
                    &grads_acc[grad_offs[k]..grad_offs[k] + grad_lens[k]],
                    cfg.lr,
                )
                .context("sgd")?;
        }

        // ---- monitor ------------------------------------------------------
        if is_last {
            if let Some(tx) = &ctx.monitor {
                let _ = tx.send(IterMsg {
                    step,
                    loss: losses / cfg.mu as f32,
                    replica: ctx.replica,
                });
            }
        }

        // ---- Function Manager: lifetime bookkeeping ----------------------
        if let Some(dt) = virtual_tick {
            func.advance_virtual(dt);
            stats.virtual_elapsed_s += dt;
        }
        if func.should_checkpoint(cfg.checkpoint_margin_s) {
            for (k, l) in (lo..hi).enumerate() {
                let key =
                    crate::replan::restart_key(cfg.plan_generation, l, ctx.replica);
                store
                    .put_async(
                        &key,
                        crate::collective::f32s_to_bytes(
                            &stages[k].flat_params(),
                        ),
                    )
                    .await?;
            }
            func.restart();
            // cold start of the replacement container: the tier's
            // cold_start_s, scenario-scaled — charged once per generation
            charge_cold_start(cfg, &ctx.injector, &mut func, &mut stats).await;
            for (k, l) in (lo..hi).enumerate() {
                let key =
                    crate::replan::restart_key(cfg.plan_generation, l, ctx.replica);
                let bytes = store
                    .get_async(&key, RECV_TIMEOUT)
                    .await
                    .context("checkpoint restore")?;
                stages[k]
                    .set_flat_params(&crate::collective::bytes_to_f32s(&bytes))?;
                // the checkpoint is consumed: leaving the object behind
                // would grow the bucket (and its high-water mark) with
                // every generation for the rest of the run
                store.delete(&key);
            }
            func.mark_running();
            stats.restarts += 1;
            stats.generations += 1;
            log::info!(
                "worker s{}r{} restarted (generation {})",
                ctx.stage_idx,
                ctx.replica,
                func.generation
            );
        }
    }

    // ---- migration quiesce: persist this stage's layers as shards -------
    // Written once (replica 0 owns the synced parameters — replicas are
    // identical after the final all-reduce) so the next generation's
    // leader can restore an arbitrary re-partitioning from them.
    if cfg.migrate_out && ctx.replica == 0 {
        for (k, l) in (lo..hi).enumerate() {
            store
                .put_async(
                    &crate::replan::migration_key(cfg.plan_generation, l),
                    crate::collective::f32s_to_bytes(&stages[k].flat_params()),
                )
                .await?;
        }
    }

    if let Some(counter) = &flaky_counter {
        stats.flaky_timeouts =
            counter.load(std::sync::atomic::Ordering::Relaxed);
    }
    Ok(stats)
}

/// Charge the current generation's cold start: the configured tier base
/// plus the scenario's seeded draw. In virtual mode the charge advances
/// the deterministic clock; in wall-clock mode the task actually waits
/// it out (an async timer, not a parked thread), modelling the
/// replacement container's provisioning.
async fn charge_cold_start(
    cfg: &TrainConfig,
    injector: &Injector,
    func: &mut FunctionInstance,
    stats: &mut WorkerStats,
) {
    let cold = injector.cold_start_s(
        stats.worker_id,
        func.generation,
        cfg.cold_start_s,
    );
    stats.cold_start_s += cold;
    if cfg.virtual_iter_s.is_some() {
        func.advance_virtual(cold);
        stats.virtual_elapsed_s += cold;
    } else if cold > 0.0 {
        crate::exec::sleep(Duration::from_secs_f64(cold)).await;
    }
}
