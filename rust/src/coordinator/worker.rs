//! One serverless worker: executes its stage's share of every iteration.
//!
//! The loop follows the §3.2 schedule: μ forward micro-batches (download
//! input → compute → upload output), then μ backward micro-batches in
//! reverse order, then intra-stage scatter-reduce (if d > 1) and the SGD
//! update through the AOT executable. Uploads stream through the flow
//! pool's uploader task so uplink and compute/downlink overlap — the
//! paper's Task-Executor DAG, specialized to the fixed GPipe order.
//!
//! A worker is an **async state machine**, not a thread: [`run_worker`]
//! is an `async fn` the leader spawns onto the shared bounded executor
//! ([`crate::exec`]), so a dp=1024 local run costs
//! `available_parallelism` OS threads, not thousands. Every store wait
//! suspends the task instead of parking a thread; compute (the AOT/native
//! executables) runs inline on the pool, which is exactly the serverless
//! model — one vCPU share per function.
//!
//! The Function Manager half lives here too: after each iteration the
//! worker checks its remaining lifetime and, if below the margin,
//! checkpoints its parameters to storage, "restarts" (new generation,
//! charging the tier's cold start), and restores — exercising the
//! §3.1-step-8 path that real platforms force every 15 minutes.
//!
//! The scenario [`Injector`] perturbs this path exactly where the
//! simulator's lenses act: the worker's throttled store handle is
//! scaled by its bandwidth/latency lens, every generation's cold start
//! is the tier base plus the scenario draw, and — when
//! `TrainConfig::virtual_iter_s` is set — the lifecycle ages on a
//! deterministic virtual clock so the checkpoint/restart schedule (and
//! therefore the whole report) replays bit-identically per seed.

use std::sync::mpsc::Sender;
use std::sync::Arc;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::collective::sendrecv::{
    boundary_key, recv_chunked_consume_async, recv_consume_async, send_async,
    send_chunked_async,
};
use crate::collective::{Chunking, CollectiveCtx};
use crate::platform::function::FunctionInstance;
use crate::platform::{ObjectStore, ThrottledStore};
use crate::runtime::{Manifest, Runtime};
use crate::scenario::{Injector, WorkerLens};
use crate::trainer::data::Corpus;
use crate::trainer::TrainConfig;

const RECV_TIMEOUT: Duration = Duration::from_secs(120);

/// Message from the head worker to the monitor.
pub struct IterMsg {
    pub step: usize,
    pub loss: f32,
    pub replica: usize,
}

/// Per-worker lifecycle and scenario-lens stats, returned to the leader
/// and surfaced as the `TrainReport`'s scenario columns.
#[derive(Debug, Clone)]
pub struct WorkerStats {
    pub worker_id: usize,
    pub stage: usize,
    pub replica: usize,
    /// Checkpoint/restart cycles performed.
    pub restarts: usize,
    /// Function generations launched (`restarts + 1`).
    pub generations: u32,
    /// Cold-start seconds charged, exactly once per generation.
    pub cold_start_s: f64,
    /// The scenario lens this worker ran under.
    pub lens: WorkerLens,
    /// Deterministic elapsed seconds on the virtual clock (0 in
    /// wall-clock mode).
    pub virtual_elapsed_s: f64,
    /// Transient `get_blocking` drops injected by the `flaky-network`
    /// lens (each one absorbed by a retry; 0 under every other lens).
    pub flaky_timeouts: u64,
}

pub struct WorkerCtx {
    pub cfg: TrainConfig,
    pub stage_idx: usize,
    pub replica: usize,
    pub base_store: Arc<dyn ObjectStore>,
    pub monitor: Option<Sender<IterMsg>>,
    /// Shared seeded perturbation provider (identity when inactive).
    pub injector: Arc<Injector>,
}

/// Boundary tensors ride the same chunking policy as the gradient
/// collectives: with chunking on, activations/gradients relay as
/// bounded chunk flows instead of one blob per micro-batch.
async fn send_boundary(
    store: &Arc<dyn ObjectStore>,
    chunking: Chunking,
    key: &str,
    data: &[f32],
) -> Result<()> {
    if chunking.is_chunked() {
        send_chunked_async(store, key, data, chunking).await
    } else {
        send_async(store, key, data).await
    }
}

async fn recv_boundary(
    store: &Arc<dyn ObjectStore>,
    chunking: Chunking,
    key: &str,
) -> Result<Vec<f32>> {
    if chunking.is_chunked() {
        recv_chunked_consume_async(store, key, RECV_TIMEOUT).await
    } else {
        recv_consume_async(store, key, RECV_TIMEOUT).await
    }
}

/// Entry point of a worker state machine (the leader spawns one task per
/// stage × replica). Returns the worker's lifecycle stats (restart
/// count, generations, cold-start charges, lens).
pub async fn run_worker(ctx: WorkerCtx) -> Result<WorkerStats> {
    let cfg = &ctx.cfg;
    let worker_id = ctx.stage_idx * cfg.dp + ctx.replica;
    let lens = ctx.injector.worker(worker_id);
    // per-worker throttled view of the shared bucket (its own "NIC"),
    // scaled by the worker's scenario lens
    let store: Arc<dyn ObjectStore> = match cfg.throttle {
        Some((bps, lat)) => Arc::new(
            ThrottledStore::new(
                ctx.base_store.clone(),
                bps,
                bps,
                Duration::from_secs_f64(lat),
            )
            .scaled(lens.bandwidth_mult, lens.latency_mult),
        ),
        None => ctx.base_store.clone(),
    };
    // flaky-network lens: seeded transient get_blocking drops injected
    // below a bounded-retry middleware. A drop fails instantly, hits a
    // key at most once and costs exactly one retry, so the run stays
    // deterministic and the report observes the retry path
    // (`flaky_timeouts`).
    let (store, flaky_counter): (Arc<dyn ObjectStore>, _) =
        match ctx.injector.flaky() {
            Some((prob, _timeout_s)) => {
                let flaky = crate::scenario::FlakyStore::new(
                    store,
                    cfg.scenario_seed,
                    worker_id,
                    prob,
                );
                let counter = flaky.timeout_counter();
                let retry =
                    crate::platform::RetryStore::new(Arc::new(flaky), 2);
                (Arc::new(retry), Some(counter))
            }
            None => (store, None),
        };

    let manifest = Manifest::load(&cfg.artifacts_dir)?;
    let rt = Arc::new(Runtime::cpu()?);
    let entry = &manifest.stages[ctx.stage_idx];
    let mut stage = rt.load_stage(&manifest, entry)?;
    let n_stages = manifest.n_stages;
    let is_first = ctx.stage_idx == 0;
    let is_last = ctx.stage_idx == n_stages - 1;
    let corpus = Corpus::new(
        manifest.vocab,
        manifest.seq_len,
        manifest.micro_batch,
        cfg.seed,
    );

    let mut func = FunctionInstance::launch(
        worker_id,
        ctx.stage_idx,
        ctx.replica,
        0,
        cfg.lifetime_s,
    );
    let mut stats = WorkerStats {
        worker_id,
        stage: ctx.stage_idx,
        replica: ctx.replica,
        restarts: 0,
        generations: 1,
        cold_start_s: 0.0,
        lens,
        virtual_elapsed_s: 0.0,
        flaky_timeouts: 0,
    };
    // every generation — the initial launch included — charges a cold
    // start: the tier's base plus the scenario's per-generation draw
    charge_cold_start(cfg, &ctx.injector, &mut func, &mut stats).await;
    func.mark_running();

    let grad_len = stage.entry.flat_param_size;
    let lr_scale = 1.0 / (cfg.mu * cfg.dp) as f32;

    // Persistent collective context for the intra-stage sync: its flow
    // pool's uploader/downloader tasks live for the whole training run
    // and are reused every round.
    let sync_ctx = (cfg.dp > 1).then(|| {
        CollectiveCtx::new(
            store.clone(),
            format!("sync/s{}", ctx.stage_idx),
            ctx.replica,
            cfg.dp,
            RECV_TIMEOUT,
        )
        .with_chunking(cfg.chunking)
    });

    // Pipeline-gated virtual tick (loop-invariant): a pipelined
    // iteration is gated by the slowest worker, so EVERY function ages
    // by the slowest lens-stretched tick — the same duration the leader
    // logs per step, keeping the checkpoint schedule consistent with
    // the report's own timeline (a fast worker idles at the boundary,
    // but its container keeps aging).
    let virtual_tick =
        cfg.virtual_iter_s.map(|base| ctx.injector.max_iter_virtual_s(base));

    for step in 0..cfg.steps {
        let round = step as u64;
        let mut grads_acc = vec![0.0f32; grad_len];
        // saved inputs for the backward passes (stage-level remat keeps
        // only the boundary input per micro-batch, §3.2 memory model)
        let mut saved_f32: Vec<Vec<f32>> = Vec::with_capacity(cfg.mu);
        let mut saved_tok: Vec<Vec<i32>> = Vec::with_capacity(cfg.mu);
        let mut losses = 0.0f32;

        // ---- forward wave ------------------------------------------------
        for mb in 0..cfg.mu {
            if is_first {
                let (tokens, _) = corpus.batch(step, ctx.replica, mb);
                let out = stage.fwd_tokens(&tokens).context("embed fwd")?;
                send_boundary(
                    &store,
                    cfg.chunking,
                    &boundary_key("fwd", round, 0, ctx.replica, mb),
                    &out,
                )
                .await?;
                saved_tok.push(tokens);
            } else {
                let x = recv_boundary(
                    &store,
                    cfg.chunking,
                    &boundary_key(
                        "fwd",
                        round,
                        ctx.stage_idx - 1,
                        ctx.replica,
                        mb,
                    ),
                )
                .await?;
                if is_last {
                    // loss computed in backward; save input only
                    saved_f32.push(x);
                } else {
                    let out = stage.fwd_acts(&x).context("blocks fwd")?;
                    send_boundary(
                        &store,
                        cfg.chunking,
                        &boundary_key("fwd", round, ctx.stage_idx, ctx.replica, mb),
                        &out,
                    )
                    .await?;
                    saved_f32.push(x);
                }
            }
        }

        // ---- backward wave (reverse micro order) ------------------------
        for mb in (0..cfg.mu).rev() {
            if is_last {
                let (_, targets) = corpus.batch(step, ctx.replica, mb);
                let x = &saved_f32[mb];
                let (g, gx, loss) =
                    stage.bwd_loss(x, &targets).context("head bwd")?;
                crate::collective::add_assign(&mut grads_acc, &g);
                losses += loss;
                if n_stages > 1 {
                    send_boundary(
                        &store,
                        cfg.chunking,
                        &boundary_key("bwd", round, ctx.stage_idx, ctx.replica, mb),
                        &gx,
                    )
                    .await?;
                }
            } else {
                let gy = recv_boundary(
                    &store,
                    cfg.chunking,
                    &boundary_key(
                        "bwd",
                        round,
                        ctx.stage_idx + 1,
                        ctx.replica,
                        mb,
                    ),
                )
                .await?;
                if is_first {
                    let g = stage
                        .bwd_tokens(&saved_tok[mb], &gy)
                        .context("embed bwd")?;
                    crate::collective::add_assign(&mut grads_acc, &g);
                } else {
                    let (g, gx) = stage
                        .bwd_acts(&saved_f32[mb], &gy)
                        .context("blocks bwd")?;
                    crate::collective::add_assign(&mut grads_acc, &g);
                    send_boundary(
                        &store,
                        cfg.chunking,
                        &boundary_key("bwd", round, ctx.stage_idx, ctx.replica, mb),
                        &gx,
                    )
                    .await?;
                }
            }
        }

        // ---- intra-stage sync (scatter-reduce over the d replicas) -------
        if let Some(sync) = &sync_ctx {
            // route the merge through the AOT merge2 executable (the L1
            // Pallas grad_merge kernel) when split sizes allow; fall back
            // to the native add for partial splits/chunks.
            let merge = |acc: &mut [f32], delta: &[f32]| {
                if acc.len() == grad_len {
                    if let Ok(merged) = stage.merge_grads(acc, delta) {
                        acc.copy_from_slice(&merged);
                        return;
                    }
                }
                crate::collective::add_assign(acc, delta);
            };
            sync.all_reduce(cfg.sync_alg, round, &mut grads_acc, Some(&merge))
                .await?;
            // garbage-collect an older round's sync objects; cleanup's
            // done-marker barrier is already satisfied (every replica
            // passed round-2 to reach here), so this never suspends long
            // and a straggler can never lose objects it still needs
            if step >= 2 && ctx.replica == 0 {
                crate::collective::scatter_reduce::cleanup_async(
                    &store,
                    &sync.group,
                    round - 2,
                    cfg.dp,
                    RECV_TIMEOUT,
                )
                .await?;
            }
        }

        // ---- SGD update through the AOT executable ------------------------
        for g in grads_acc.iter_mut() {
            *g *= lr_scale;
        }
        stage.sgd_step(&grads_acc, cfg.lr).context("sgd")?;

        // ---- monitor ------------------------------------------------------
        if is_last {
            if let Some(tx) = &ctx.monitor {
                let _ = tx.send(IterMsg {
                    step,
                    loss: losses / cfg.mu as f32,
                    replica: ctx.replica,
                });
            }
        }

        // ---- Function Manager: lifetime bookkeeping ----------------------
        if let Some(dt) = virtual_tick {
            func.advance_virtual(dt);
            stats.virtual_elapsed_s += dt;
        }
        if func.should_checkpoint(cfg.checkpoint_margin_s) {
            let key = format!("ckpt/s{}/r{}", ctx.stage_idx, ctx.replica);
            store
                .put_async(
                    &key,
                    crate::collective::f32s_to_bytes(&stage.flat_params()),
                )
                .await?;
            func.restart();
            // cold start of the replacement container: the tier's
            // cold_start_s, scenario-scaled — charged once per generation
            charge_cold_start(cfg, &ctx.injector, &mut func, &mut stats).await;
            let bytes = store
                .get_async(&key, RECV_TIMEOUT)
                .await
                .context("checkpoint restore")?;
            stage.set_flat_params(&crate::collective::bytes_to_f32s(&bytes))?;
            // the checkpoint is consumed: leaving the object behind
            // would grow the bucket (and its high-water mark) with
            // every generation for the rest of the run
            store.delete(&key);
            func.mark_running();
            stats.restarts += 1;
            stats.generations += 1;
            log::info!(
                "worker s{}r{} restarted (generation {})",
                ctx.stage_idx,
                ctx.replica,
                func.generation
            );
        }
    }
    if let Some(counter) = &flaky_counter {
        stats.flaky_timeouts =
            counter.load(std::sync::atomic::Ordering::Relaxed);
    }
    Ok(stats)
}

/// Charge the current generation's cold start: the configured tier base
/// plus the scenario's seeded draw. In virtual mode the charge advances
/// the deterministic clock; in wall-clock mode the task actually waits
/// it out (an async timer, not a parked thread), modelling the
/// replacement container's provisioning.
async fn charge_cold_start(
    cfg: &TrainConfig,
    injector: &Injector,
    func: &mut FunctionInstance,
    stats: &mut WorkerStats,
) {
    let cold = injector.cold_start_s(
        stats.worker_id,
        func.generation,
        cfg.cold_start_s,
    );
    stats.cold_start_s += cold;
    if cfg.virtual_iter_s.is_some() {
        func.advance_virtual(cold);
        stats.virtual_elapsed_s += cold;
    } else if cold > 0.0 {
        crate::exec::sleep(Duration::from_secs_f64(cold)).await;
    }
}
