//! The leader: launches the worker "functions", runs the monitor daemon,
//! aggregates the training report (§3.1's startup flow, with the
//! Partition/Resource Optimizer applied beforehand by the caller).
//!
//! Workers are async state machines spawned onto the shared bounded
//! executor, so worker count scales independently of OS thread count:
//! a dp=256 job still runs on `available_parallelism` pool threads.
//! The monitor daemon stays a plain blocking loop on the calling thread
//! (worker → monitor messages ride a std unbounded channel, whose sends
//! never block a pool task).

use std::sync::mpsc;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::coordinator::worker::{run_worker, IterMsg, WorkerCtx};
use crate::platform::{MemStore, ObjectStore};
use crate::runtime::Manifest;
use crate::scenario::Injector;
use crate::trainer::{IterLog, TrainConfig, TrainReport};

/// Run a full training job: one executor task per worker
/// (stage × replica). A stage is a contiguous group of manifest layers
/// (`TrainConfig::layer_groups`; empty = one layer per stage), so a
/// post-migration segment can run the same manifest under a different
/// partitioning.
pub fn run_training(
    cfg: &TrainConfig,
    store: Arc<MemStore>,
) -> Result<TrainReport> {
    let manifest = Manifest::load(&cfg.artifacts_dir)
        .context("loading artifacts (run `make artifacts`?)")?;
    let n_layers = manifest.n_stages;
    if cfg.dp == 0 || cfg.mu == 0 || cfg.steps == 0 {
        bail!("dp, mu and steps must be positive");
    }
    let groups: Vec<(usize, usize)> = if cfg.layer_groups.is_empty() {
        crate::replan::identity_groups(n_layers)
    } else {
        cfg.layer_groups.clone()
    };
    crate::replan::validate_groups(&groups, n_layers)?;
    let n_groups = groups.len();

    // one injector for the whole job: every worker reads its lens (and
    // its cold-start draws) from the same seeded construction, so the
    // run is a function of (scenario, seed) alone
    let injector = Arc::new(Injector::new(
        &cfg.scenario,
        cfg.scenario_seed,
        n_groups * cfg.dp,
    ));

    // post-migration restore: read the previous generation's
    // layer-addressed migration shards ONCE, before any worker spawns,
    // and consume them — superseded shards must never accumulate in the
    // bucket across repeated re-plans
    let init_params: Option<Arc<Vec<Vec<f32>>>> = if cfg.plan_generation > 0 {
        let mut layers = Vec::with_capacity(n_layers);
        for l in 0..n_layers {
            let key = crate::replan::migration_key(cfg.plan_generation - 1, l);
            let bytes = store.get(&key).with_context(|| {
                format!("missing migration shard {key} for restore")
            })?;
            layers.push(crate::collective::bytes_to_f32s(&bytes));
            store.delete(&key);
        }
        Some(Arc::new(layers))
    } else {
        None
    };

    let start = Instant::now();
    let (tx, rx) = mpsc::channel::<IterMsg>();

    let mut handles = Vec::new();
    for (stage_idx, &group) in groups.iter().enumerate() {
        for replica in 0..cfg.dp {
            let ctx = WorkerCtx {
                cfg: cfg.clone(),
                stage_idx,
                group,
                n_groups,
                replica,
                base_store: store.clone() as Arc<dyn crate::platform::ObjectStore>,
                monitor: (stage_idx == n_groups - 1).then(|| tx.clone()),
                injector: injector.clone(),
                init_params: init_params.clone(),
            };
            handles.push(crate::exec::spawn(run_worker(ctx)));
        }
    }
    drop(tx);

    // ---- monitor daemon: aggregate per-step losses across replicas ----
    // losses land in per-replica slots so the average is summed in
    // replica order regardless of message arrival order — one less
    // source of cross-run drift in the replayable report
    let mut step_losses: Vec<Vec<Option<f32>>> =
        vec![vec![None; cfg.dp]; cfg.steps];
    let mut step_done_at: Vec<Option<f64>> = vec![None; cfg.steps];
    while let Ok(msg) = rx.recv() {
        step_losses[msg.step][msg.replica] = Some(msg.loss);
        if step_losses[msg.step].iter().all(Option::is_some) {
            step_done_at[msg.step] = Some(start.elapsed().as_secs_f64());
            log::info!(
                "step {:>4}  loss {:.4}",
                msg.step,
                step_losses[msg.step].iter().flatten().sum::<f32>()
                    / cfg.dp as f32
            );
        }
    }

    let mut workers = Vec::with_capacity(handles.len());
    for h in handles {
        workers.push(
            crate::exec::block_on(h)
                .map_err(|_| anyhow::anyhow!("worker panicked"))??,
        );
    }
    workers.sort_by_key(|w| w.worker_id);
    let restarts = workers.iter().map(|w| w.restarts).sum();

    // per-iteration durations: measured wall deltas, or — under the
    // deterministic virtual clock — the slowest worker's lens-stretched
    // virtual iteration, which is what gates a pipelined step (a
    // calibrated segment's base is already that gated tick)
    let virtual_iter = cfg.virtual_iter_s.map(|base| {
        if cfg.calibrated_tick {
            base
        } else {
            injector.max_iter_virtual_s(base)
        }
    });
    let mut logs = Vec::with_capacity(cfg.steps);
    let mut prev_t = 0.0f64;
    for step in 0..cfg.steps {
        let losses = &step_losses[step];
        if losses.iter().any(Option::is_none) {
            bail!("no loss recorded for step {step}");
        }
        let loss =
            losses.iter().flatten().sum::<f32>() / losses.len() as f32;
        let iter_s = match virtual_iter {
            Some(v) => v,
            None => {
                let t = step_done_at[step].unwrap_or(prev_t);
                let dt = (t - prev_t).max(0.0);
                prev_t = t;
                dt
            }
        };
        logs.push(IterLog { step: cfg.step_offset + step, loss, iter_s });
    }

    let wall_s = match cfg.virtual_iter_s {
        // virtual timeline: the slowest worker's deterministic elapsed
        Some(_) => workers
            .iter()
            .map(|w| w.virtual_elapsed_s)
            .fold(0.0, f64::max),
        None => start.elapsed().as_secs_f64(),
    };

    // the drift detector's input: per-stage observed times, derived
    // from the exact lens draws the virtual clock charged above — a
    // pure function of (scenario, seed, grouping), so it replays
    let observations = match (cfg.observe, cfg.virtual_iter_s) {
        (Some(window), Some(base)) if !cfg.calibrated_tick => {
            let mut obs = crate::replan::StageObservations::new(
                groups.clone(),
                n_layers,
                window,
                base,
            );
            for _ in 0..cfg.steps {
                let (stage_obs, gated, bw_mult) = crate::replan::observe_step(
                    &injector,
                    &groups,
                    cfg.dp,
                    base,
                );
                obs.push_step(stage_obs, gated, bw_mult);
            }
            Some(obs)
        }
        _ => None,
    };

    Ok(TrainReport {
        logs,
        restarts,
        wall_s,
        store_put_gets: (0, 0),
        workers,
        observations,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn artifacts() -> Option<PathBuf> {
        let d = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        d.join("manifest.json").exists().then_some(d)
    }

    #[test]
    fn single_worker_pipeline_trains() {
        let Some(dir) = artifacts() else {
            eprintln!("skipping: no artifacts");
            return;
        };
        let mut cfg = TrainConfig::new(dir);
        cfg.steps = 12;
        cfg.mu = 2;
        cfg.lr = 0.2;
        let report = crate::trainer::train(&cfg).unwrap();
        assert_eq!(report.logs.len(), 12);
        assert!(
            report.last_loss() < report.first_loss(),
            "loss did not fall: {} -> {}",
            report.first_loss(),
            report.last_loss()
        );
    }

    #[test]
    fn data_parallel_training_matches_loss_trajectory_shape() {
        let Some(dir) = artifacts() else {
            return;
        };
        let mut cfg = TrainConfig::new(dir);
        cfg.steps = 6;
        cfg.dp = 2;
        cfg.mu = 1;
        let report = crate::trainer::train(&cfg).unwrap();
        assert_eq!(report.logs.len(), 6);
        assert!(report.last_loss() < report.first_loss());
    }

    #[test]
    fn lifetime_forces_checkpoint_restart() {
        let Some(dir) = artifacts() else {
            return;
        };
        let mut cfg = TrainConfig::new(dir);
        cfg.steps = 6;
        cfg.mu = 1;
        cfg.lifetime_s = 0.05; // force a restart almost every step
        cfg.checkpoint_margin_s = 0.04;
        let report = crate::trainer::train(&cfg).unwrap();
        assert!(report.restarts > 0, "no restarts happened");
        assert!(report.last_loss() < report.first_loss() + 0.5);
    }

    // ---- native built-in model: these run in every build ----------------

    #[test]
    fn builtin_tiny_pipeline_trains() {
        let mut cfg = TrainConfig::new(crate::runtime::BUILTIN_TINY);
        cfg.steps = 12;
        cfg.mu = 2;
        cfg.lr = 0.5;
        let report = crate::trainer::train(&cfg).unwrap();
        assert_eq!(report.logs.len(), 12);
        assert!(
            report.last_loss() < report.first_loss(),
            "loss did not fall: {} -> {}",
            report.first_loss(),
            report.last_loss()
        );
        // one generation per worker, each charged exactly one cold start
        assert_eq!(report.restarts, 0);
        assert_eq!(report.generations(), 3);
        assert!(
            (report.cold_start_total_s() - 3.0 * cfg.cold_start_s).abs()
                < 1e-9
        );
    }

    #[test]
    fn builtin_tiny_data_parallel_trains() {
        let mut cfg = TrainConfig::new(crate::runtime::BUILTIN_TINY);
        cfg.steps = 8;
        cfg.dp = 2;
        cfg.mu = 1;
        cfg.lr = 0.5;
        let report = crate::trainer::train(&cfg).unwrap();
        assert_eq!(report.logs.len(), 8);
        assert_eq!(report.workers.len(), 6);
        assert!(report.last_loss() < report.first_loss());
        assert!(report.logs.iter().all(|l| l.loss.is_finite()));
    }

    #[test]
    fn builtin_tiny_losses_replay_bit_identically() {
        let run = || {
            let mut cfg = TrainConfig::new(crate::runtime::BUILTIN_TINY);
            cfg.steps = 5;
            cfg.mu = 2;
            crate::trainer::train(&cfg)
                .unwrap()
                .logs
                .iter()
                .map(|l| l.loss.to_bits())
                .collect::<Vec<u32>>()
        };
        assert_eq!(run(), run(), "native numerics drifted across runs");
    }

    #[test]
    fn virtual_lifetime_forces_deterministic_restarts() {
        let mut cfg = TrainConfig::new(crate::runtime::BUILTIN_TINY);
        cfg.steps = 8;
        cfg.mu = 1;
        cfg.virtual_iter_s = Some(1.0);
        cfg.lifetime_s = 3.0;
        cfg.checkpoint_margin_s = 0.5;
        cfg.cold_start_s = 0.25;
        let report = crate::trainer::train(&cfg).unwrap();
        // generation timeline per worker: cold 0.25 + k iterations; the
        // margin trips when remaining 3.0 − age ≤ 0.5, i.e. after the
        // 3rd iteration of each generation (age 3.25) — 8 steps ⇒
        // restarts after steps 2 and 5 ⇒ exactly 2 per worker
        assert_eq!(report.restarts, 6, "{:?}", report.workers);
        for w in &report.workers {
            assert_eq!(w.restarts, 2);
            assert_eq!(w.generations, 3);
            // a restart charges a cold start once per generation
            assert!((w.cold_start_s - 3.0 * 0.25).abs() < 1e-9);
        }
        assert!(
            (report.cold_start_total_s() - 3.0 * 3.0 * 0.25).abs() < 1e-9
        );
        // virtual wall clock: 8 iterations + 3 cold starts per worker
        assert!((report.wall_s - (8.0 + 0.75)).abs() < 1e-9);
        // and the run replays exactly
        let again = crate::trainer::train(&cfg).unwrap();
        assert_eq!(again.restarts, 6);
        assert_eq!(again.wall_s.to_bits(), report.wall_s.to_bits());
    }

    #[test]
    fn checkpoints_are_consumed_after_restore() {
        let mut cfg = TrainConfig::new(crate::runtime::BUILTIN_TINY);
        cfg.steps = 6;
        cfg.mu = 1;
        cfg.virtual_iter_s = Some(1.0);
        cfg.lifetime_s = 2.0;
        cfg.checkpoint_margin_s = 0.5;
        cfg.cold_start_s = 0.0;
        let store = std::sync::Arc::new(crate::platform::MemStore::new());
        let report =
            crate::trainer::train_with_store(&cfg, store.clone()).unwrap();
        assert!(report.restarts > 0, "test needs the restart path");
        assert!(
            store.list("ckpt/").is_empty(),
            "checkpoint keys leaked: {:?}",
            store.list("ckpt/")
        );
        // the bucket drains completely once boundary tensors, sync
        // objects and checkpoints are all consume-once
        let leaked: Vec<String> = store.list("");
        assert!(
            leaked.is_empty(),
            "objects left in the bucket: {leaked:?}"
        );
    }
}
