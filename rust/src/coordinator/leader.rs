//! The leader: launches the worker "functions", runs the monitor daemon,
//! aggregates the training report (§3.1's startup flow, with the
//! Partition/Resource Optimizer applied beforehand by the caller).

use std::sync::mpsc;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::coordinator::worker::{run_worker, IterMsg, WorkerCtx};
use crate::platform::MemStore;
use crate::runtime::Manifest;
use crate::trainer::{IterLog, TrainConfig, TrainReport};

/// Run a full training job: one thread per worker (stage × replica).
pub fn run_training(
    cfg: &TrainConfig,
    store: Arc<MemStore>,
) -> Result<TrainReport> {
    let manifest = Manifest::load(&cfg.artifacts_dir)
        .context("loading artifacts (run `make artifacts`?)")?;
    let n_stages = manifest.n_stages;
    if cfg.dp == 0 || cfg.mu == 0 || cfg.steps == 0 {
        bail!("dp, mu and steps must be positive");
    }

    let start = Instant::now();
    let (tx, rx) = mpsc::channel::<IterMsg>();

    let mut handles = Vec::new();
    for stage_idx in 0..n_stages {
        for replica in 0..cfg.dp {
            let ctx = WorkerCtx {
                cfg: cfg.clone(),
                stage_idx,
                replica,
                base_store: store.clone() as Arc<dyn crate::platform::ObjectStore>,
                monitor: (stage_idx == n_stages - 1).then(|| tx.clone()),
            };
            handles.push(
                std::thread::Builder::new()
                    .name(format!("worker-s{stage_idx}r{replica}"))
                    .spawn(move || run_worker(ctx))
                    .context("spawning worker")?,
            );
        }
    }
    drop(tx);

    // ---- monitor daemon: aggregate per-step losses across replicas ----
    let mut step_losses: Vec<Vec<f32>> = vec![Vec::new(); cfg.steps];
    let mut step_done_at: Vec<Option<f64>> = vec![None; cfg.steps];
    while let Ok(msg) = rx.recv() {
        step_losses[msg.step].push(msg.loss);
        if step_losses[msg.step].len() == cfg.dp {
            step_done_at[msg.step] = Some(start.elapsed().as_secs_f64());
            log::info!(
                "step {:>4}  loss {:.4}",
                msg.step,
                step_losses[msg.step].iter().sum::<f32>() / cfg.dp as f32
            );
        }
    }

    let mut restarts = 0usize;
    for h in handles {
        restarts += h
            .join()
            .map_err(|_| anyhow::anyhow!("worker panicked"))??;
    }

    // build logs with per-iteration durations
    let mut logs = Vec::with_capacity(cfg.steps);
    let mut prev_t = 0.0f64;
    for step in 0..cfg.steps {
        let losses = &step_losses[step];
        if losses.is_empty() {
            bail!("no loss recorded for step {step}");
        }
        let t = step_done_at[step].unwrap_or(prev_t);
        logs.push(IterLog {
            step,
            loss: losses.iter().sum::<f32>() / losses.len() as f32,
            iter_s: (t - prev_t).max(0.0),
        });
        prev_t = t;
    }

    Ok(TrainReport {
        logs,
        restarts,
        wall_s: start.elapsed().as_secs_f64(),
        store_put_gets: (0, 0),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn artifacts() -> Option<PathBuf> {
        let d = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        d.join("manifest.json").exists().then_some(d)
    }

    #[test]
    fn single_worker_pipeline_trains() {
        let Some(dir) = artifacts() else {
            eprintln!("skipping: no artifacts");
            return;
        };
        let mut cfg = TrainConfig::new(dir);
        cfg.steps = 12;
        cfg.mu = 2;
        cfg.lr = 0.2;
        let report = crate::trainer::train(&cfg).unwrap();
        assert_eq!(report.logs.len(), 12);
        assert!(
            report.last_loss() < report.first_loss(),
            "loss did not fall: {} -> {}",
            report.first_loss(),
            report.last_loss()
        );
    }

    #[test]
    fn data_parallel_training_matches_loss_trajectory_shape() {
        let Some(dir) = artifacts() else {
            return;
        };
        let mut cfg = TrainConfig::new(dir);
        cfg.steps = 6;
        cfg.dp = 2;
        cfg.mu = 1;
        let report = crate::trainer::train(&cfg).unwrap();
        assert_eq!(report.logs.len(), 6);
        assert!(report.last_loss() < report.first_loss());
    }

    #[test]
    fn lifetime_forces_checkpoint_restart() {
        let Some(dir) = artifacts() else {
            return;
        };
        let mut cfg = TrainConfig::new(dir);
        cfg.steps = 6;
        cfg.mu = 1;
        cfg.lifetime_s = 0.05; // force a restart almost every step
        cfg.checkpoint_margin_s = 0.04;
        let report = crate::trainer::train(&cfg).unwrap();
        assert!(report.restarts > 0, "no restarts happened");
        assert!(report.last_loss() < report.first_loss() + 0.5);
    }
}
