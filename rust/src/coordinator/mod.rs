//! The L3 coordinator: leader/worker topology for real pipelined training
//! over storage-relayed communication (§3.1's runtime components).
//!
//! * [`leader`] — launches one thread per serverless "function" (worker),
//!   owns the monitor daemon, collects the training report;
//! * [`worker`] — the per-worker loop: GPipe-ordered forward/backward over
//!   the AOT stage executables, boundary send/recv, (pipelined)
//!   scatter-reduce sync, SGD update, and the Function-Manager
//!   checkpoint/restart cycle before lifetime expiry.

pub mod leader;
pub mod worker;

pub use leader::run_training;
pub use worker::WorkerStats;
