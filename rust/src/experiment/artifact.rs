//! The serializable plan artifact — FuncPipe's deployable unit.
//!
//! `funcpipe plan --out plan.json` freezes the co-optimizer's decision
//! (partition cuts, per-stage tiers, data-parallel degree, micro-batch
//! layout) *together with the config that produced it* (model, platform,
//! sync algorithm, chunking policy, trainer knobs), so
//! `simulate --plan plan.json` and `train --plan plan.json` reconstruct
//! the exact session without the user re-deriving `--dp`/`--mu` by hand
//! — the §3.1 profile → optimize → deploy → train loop as one file.
//!
//! Serialization is a strict round-trip: `to_json_text` →
//! [`PlanArtifact::from_json_text`] → `to_json_text` is the identity on
//! the text (deterministic key order, shortest-round-trip float
//! formatting); `rust/tests/plan_artifact.rs` property-tests this.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::config::ExperimentConfig;
use crate::model::Plan;
use crate::util::json::Json;

/// Bumped when the on-disk layout changes incompatibly; loaders reject
/// versions they do not understand instead of misreading them.
pub const PLAN_SCHEMA_VERSION: usize = 1;

/// A frozen plan plus everything needed to act on it.
#[derive(Debug, Clone, PartialEq)]
pub struct PlanArtifact {
    pub version: usize,
    /// The unified config the planner ran with (and the trainer will
    /// run with) — model, platform, batch layout, sync/chunking policy,
    /// trainer knobs.
    pub config: ExperimentConfig,
    /// The §3.4 decision variable.
    pub plan: Plan,
    /// The (α1, α2) weight pair whose solve produced this plan.
    pub weights: (f64, f64),
    /// Perf-model prediction at plan time. Informational: `simulate`
    /// and `train` recompute from the config, so a hand-edited artifact
    /// cannot smuggle in stale numbers.
    pub predicted_t_iter: f64,
    pub predicted_c_iter: f64,
}

impl PlanArtifact {
    pub fn new(
        config: ExperimentConfig,
        plan: Plan,
        weights: (f64, f64),
        predicted_t_iter: f64,
        predicted_c_iter: f64,
    ) -> Self {
        Self {
            version: PLAN_SCHEMA_VERSION,
            config,
            plan,
            weights,
            predicted_t_iter,
            predicted_c_iter,
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("version", Json::Num(self.version as f64)),
            ("config", self.config.to_json()),
            ("plan", self.plan.to_json()),
            (
                "weights",
                Json::Arr(vec![
                    Json::Num(self.weights.0),
                    Json::Num(self.weights.1),
                ]),
            ),
            (
                "predicted",
                Json::obj(vec![
                    ("t_iter", Json::Num(self.predicted_t_iter)),
                    ("c_iter", Json::Num(self.predicted_c_iter)),
                ]),
            ),
        ])
    }

    /// Strict parse: unknown keys at any level we own are errors, like
    /// unknown CLI flags and unknown config keys — a hand-edited
    /// artifact with a misplaced or typo'd key must fail loudly, not
    /// silently run the old policy.
    pub fn from_json(j: &Json) -> Result<Self> {
        j.check_keys(&["version", "config", "plan", "weights", "predicted"])
            .context("plan artifact")?;
        let version = j.field_usize("version").context("plan artifact")?;
        if version != PLAN_SCHEMA_VERSION {
            bail!(
                "unsupported plan artifact version {version} \
                 (this build reads version {PLAN_SCHEMA_VERSION})"
            );
        }
        let config = ExperimentConfig::from_json(j.field("config")?)
            .context("plan artifact config")?;
        let plan =
            Plan::from_json(j.field("plan")?).context("plan artifact plan")?;
        let w = j.field_arr("weights")?;
        if w.len() != 2 {
            bail!("plan artifact weights must be [α1, α2]");
        }
        let predicted = j.field("predicted")?;
        predicted
            .check_keys(&["t_iter", "c_iter"])
            .context("plan artifact predicted")?;
        Ok(Self {
            version,
            config,
            plan,
            weights: (
                w[0].as_f64().context("weight α1")?,
                w[1].as_f64().context("weight α2")?,
            ),
            predicted_t_iter: predicted.field_f64("t_iter")?,
            predicted_c_iter: predicted.field_f64("c_iter")?,
        })
    }

    /// Pretty JSON text, newline-terminated (the `--out` file format).
    pub fn to_json_text(&self) -> String {
        let mut s = self.to_json().pretty();
        s.push('\n');
        s
    }

    pub fn from_json_text(text: &str) -> Result<Self> {
        Self::from_json(&Json::parse(text).context("parsing plan artifact")?)
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        std::fs::write(path, self.to_json_text())
            .with_context(|| format!("writing plan artifact {}", path.display()))
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading plan artifact {}", path.display()))?;
        Self::from_json_text(&text)
            .with_context(|| format!("parsing plan artifact {}", path.display()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> PlanArtifact {
        PlanArtifact::new(
            ExperimentConfig::default(),
            Plan {
                cuts: vec![1, 3],
                dp: 2,
                stage_tiers: vec![7, 7, 7],
                n_micro_global: 16,
            },
            (1.0, 2e-4),
            3.25,
            0.000715,
        )
    }

    #[test]
    fn text_roundtrip_is_identity() {
        let a = sample();
        let t1 = a.to_json_text();
        let b = PlanArtifact::from_json_text(&t1).unwrap();
        assert_eq!(b, a);
        assert_eq!(b.to_json_text(), t1);
    }

    #[test]
    fn rejects_future_versions_and_garbage() {
        let mut j = sample().to_json();
        if let Json::Obj(o) = &mut j {
            o.insert("version".into(), Json::Num(99.0));
        }
        assert!(PlanArtifact::from_json(&j).is_err());
        assert!(PlanArtifact::from_json_text("{}").is_err());
        assert!(PlanArtifact::from_json_text("not json").is_err());
    }

    #[test]
    fn rejects_unknown_keys_at_every_owned_level() {
        // a misplaced config knob at the artifact's top level
        let mut top = sample().to_json();
        if let Json::Obj(o) = &mut top {
            o.insert("chunk_bytes".into(), Json::Num(1048576.0));
        }
        assert!(PlanArtifact::from_json(&top).is_err());

        // a typo'd key inside the plan object
        let mut nested = sample().to_json();
        if let Json::Obj(o) = &mut nested {
            let Some(Json::Obj(p)) = o.get_mut("plan") else {
                panic!("plan object missing")
            };
            p.insert("mu".into(), Json::Num(4.0));
        }
        assert!(PlanArtifact::from_json(&nested).is_err());
    }
}
