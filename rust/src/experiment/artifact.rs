//! The serializable plan artifact — FuncPipe's deployable unit.
//!
//! `funcpipe plan --out plan.json` freezes the co-optimizer's decision
//! (partition cuts, per-stage tiers, data-parallel degree, micro-batch
//! layout) *together with the config that produced it* (model, platform,
//! sync algorithm, chunking policy, trainer knobs), so
//! `simulate --plan plan.json` and `train --plan plan.json` reconstruct
//! the exact session without the user re-deriving `--dp`/`--mu` by hand
//! — the §3.1 profile → optimize → deploy → train loop as one file.
//!
//! Serialization is a strict round-trip: `to_json_text` →
//! [`PlanArtifact::from_json_text`] → `to_json_text` is the identity on
//! the text (deterministic key order, shortest-round-trip float
//! formatting); `rust/tests/plan_artifact.rs` property-tests this.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::config::ExperimentConfig;
use crate::model::Plan;
use crate::util::json::Json;

/// Bumped when the on-disk layout changes incompatibly; loaders reject
/// versions they do not understand instead of misreading them. Version
/// history:
///
/// * **1** — config + plan + weights + predicted t/c;
/// * **2** — adds `strategy` (the registry key of the planner strategy
///   that produced the plan). Version-1 artifacts still load: the
///   `strategy` key is absent there and defaults to `"bnb"`, the only
///   solver `plan` ever ran before the strategy registry; loaders
///   normalize to the current version, so re-saving upgrades the file.
pub const PLAN_SCHEMA_VERSION: usize = 2;

/// The provenance recorded for version-1 artifacts (pre-registry, when
/// branch-and-bound was the only `plan` solver).
pub const V1_STRATEGY: &str = "bnb";

/// A frozen plan plus everything needed to act on it.
#[derive(Debug, Clone, PartialEq)]
pub struct PlanArtifact {
    pub version: usize,
    /// The unified config the planner ran with (and the trainer will
    /// run with) — model, platform, batch layout, sync/chunking policy,
    /// trainer knobs.
    pub config: ExperimentConfig,
    /// The §3.4 decision variable.
    pub plan: Plan,
    /// The (α1, α2) weight pair whose solve produced this plan.
    pub weights: (f64, f64),
    /// Perf-model prediction at plan time. Informational: `simulate`
    /// and `train` recompute from the config, so a hand-edited artifact
    /// cannot smuggle in stale numbers.
    pub predicted_t_iter: f64,
    pub predicted_c_iter: f64,
    /// Registry key of the plan strategy that produced this plan
    /// (`bnb`, `miqp`, `bayes`, `tpdmp`, `sweep`) — provenance, not
    /// behaviour: simulate/train act on the plan, not on how it was
    /// found.
    pub strategy: String,
}

impl PlanArtifact {
    pub fn new(
        config: ExperimentConfig,
        plan: Plan,
        weights: (f64, f64),
        predicted_t_iter: f64,
        predicted_c_iter: f64,
        strategy: impl Into<String>,
    ) -> Self {
        Self {
            version: PLAN_SCHEMA_VERSION,
            config,
            plan,
            weights,
            predicted_t_iter,
            predicted_c_iter,
            strategy: strategy.into(),
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("version", Json::Num(self.version as f64)),
            ("config", self.config.to_json()),
            ("plan", self.plan.to_json()),
            (
                "weights",
                Json::Arr(vec![
                    Json::Num(self.weights.0),
                    Json::Num(self.weights.1),
                ]),
            ),
            (
                "predicted",
                Json::obj(vec![
                    ("t_iter", Json::Num(self.predicted_t_iter)),
                    ("c_iter", Json::Num(self.predicted_c_iter)),
                ]),
            ),
            ("strategy", Json::str(self.strategy.as_str())),
        ])
    }

    /// Strict parse: unknown keys at any level we own are errors, like
    /// unknown CLI flags and unknown config keys — a hand-edited
    /// artifact with a misplaced or typo'd key must fail loudly, not
    /// silently run the old policy.
    pub fn from_json(j: &Json) -> Result<Self> {
        j.check_keys(&[
            "version", "config", "plan", "weights", "predicted", "strategy",
        ])
        .context("plan artifact")?;
        let version = j.field_usize("version").context("plan artifact")?;
        if version == 0 || version > PLAN_SCHEMA_VERSION {
            bail!(
                "unsupported plan artifact version {version} \
                 (this build reads versions 1..={PLAN_SCHEMA_VERSION})"
            );
        }
        let strategy = match j.get("strategy") {
            Some(v) => {
                if version < 2 {
                    bail!(
                        "plan artifact version {version} predates the \
                         strategy field; remove it or bump the version"
                    );
                }
                let s = v.as_str().context("plan artifact strategy")?;
                if s.is_empty() {
                    bail!("plan artifact strategy must be non-empty");
                }
                s.to_string()
            }
            None => {
                if version >= 2 {
                    bail!("plan artifact version {version} requires a strategy");
                }
                V1_STRATEGY.to_string()
            }
        };
        let config = ExperimentConfig::from_json(j.field("config")?)
            .context("plan artifact config")?;
        let plan =
            Plan::from_json(j.field("plan")?).context("plan artifact plan")?;
        let w = j.field_arr("weights")?;
        if w.len() != 2 {
            bail!("plan artifact weights must be [α1, α2]");
        }
        let predicted = j.field("predicted")?;
        predicted
            .check_keys(&["t_iter", "c_iter"])
            .context("plan artifact predicted")?;
        Ok(Self {
            // loaders normalize old versions up: re-saving writes the
            // current schema (with the defaulted strategy provenance)
            version: PLAN_SCHEMA_VERSION,
            config,
            plan,
            weights: (
                w[0].as_f64().context("weight α1")?,
                w[1].as_f64().context("weight α2")?,
            ),
            predicted_t_iter: predicted.field_f64("t_iter")?,
            predicted_c_iter: predicted.field_f64("c_iter")?,
            strategy,
        })
    }

    /// Pretty JSON text, newline-terminated (the `--out` file format).
    pub fn to_json_text(&self) -> String {
        let mut s = self.to_json().pretty();
        s.push('\n');
        s
    }

    pub fn from_json_text(text: &str) -> Result<Self> {
        Self::from_json(&Json::parse(text).context("parsing plan artifact")?)
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        std::fs::write(path, self.to_json_text())
            .with_context(|| format!("writing plan artifact {}", path.display()))
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading plan artifact {}", path.display()))?;
        Self::from_json_text(&text)
            .with_context(|| format!("parsing plan artifact {}", path.display()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> PlanArtifact {
        PlanArtifact::new(
            ExperimentConfig::default(),
            Plan {
                cuts: vec![1, 3],
                dp: 2,
                stage_tiers: vec![7, 7, 7],
                n_micro_global: 16,
            },
            (1.0, 2e-4),
            3.25,
            0.000715,
            "bnb",
        )
    }

    #[test]
    fn text_roundtrip_is_identity() {
        let a = sample();
        let t1 = a.to_json_text();
        let b = PlanArtifact::from_json_text(&t1).unwrap();
        assert_eq!(b, a);
        assert_eq!(b.to_json_text(), t1);
    }

    #[test]
    fn v1_artifacts_load_with_default_provenance() {
        // a version-1 file: current shape minus the strategy key
        let mut j = sample().to_json();
        if let Json::Obj(o) = &mut j {
            o.insert("version".into(), Json::Num(1.0));
            o.remove("strategy");
        }
        let a = PlanArtifact::from_json(&j).unwrap();
        assert_eq!(a.strategy, V1_STRATEGY);
        // normalized up: re-saving writes the current schema
        assert_eq!(a.version, PLAN_SCHEMA_VERSION);
        let re = a.to_json();
        assert_eq!(re.field_usize("version").unwrap(), PLAN_SCHEMA_VERSION);
        assert_eq!(re.field_str("strategy").unwrap(), "bnb");

        // a v1 file carrying a strategy key is contradictory
        let mut bad = sample().to_json();
        if let Json::Obj(o) = &mut bad {
            o.insert("version".into(), Json::Num(1.0));
        }
        assert!(PlanArtifact::from_json(&bad).is_err());

        // v2 without a strategy is likewise rejected
        let mut missing = sample().to_json();
        if let Json::Obj(o) = &mut missing {
            o.remove("strategy");
        }
        assert!(PlanArtifact::from_json(&missing).is_err());
        // and an empty provenance string is meaningless
        let mut empty = sample().to_json();
        if let Json::Obj(o) = &mut empty {
            o.insert("strategy".into(), Json::str(""));
        }
        assert!(PlanArtifact::from_json(&empty).is_err());
    }

    #[test]
    fn rejects_future_versions_and_garbage() {
        let mut j = sample().to_json();
        if let Json::Obj(o) = &mut j {
            o.insert("version".into(), Json::Num(99.0));
        }
        assert!(PlanArtifact::from_json(&j).is_err());
        assert!(PlanArtifact::from_json_text("{}").is_err());
        assert!(PlanArtifact::from_json_text("not json").is_err());
    }

    #[test]
    fn rejects_unknown_keys_at_every_owned_level() {
        // a misplaced config knob at the artifact's top level
        let mut top = sample().to_json();
        if let Json::Obj(o) = &mut top {
            o.insert("chunk_bytes".into(), Json::Num(1048576.0));
        }
        assert!(PlanArtifact::from_json(&top).is_err());

        // a typo'd key inside the plan object
        let mut nested = sample().to_json();
        if let Json::Obj(o) = &mut nested {
            let Some(Json::Obj(p)) = o.get_mut("plan") else {
                panic!("plan object missing")
            };
            p.insert("mu".into(), Json::Num(4.0));
        }
        assert!(PlanArtifact::from_json(&nested).is_err());
    }
}
