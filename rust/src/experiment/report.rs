//! Typed reports: every lifecycle stage returns a structured value that
//! renders through ONE path — [`Report::render`] — as either the classic
//! paper-style ASCII table or machine-readable JSON. The CLI
//! (`--format table|json`), the `bench::fig*` generators and library
//! callers all go through these types, so there is exactly one place
//! where numbers become output.

use anyhow::{bail, Result};

use crate::baselines::BaselineResult;
use crate::coordinator::WorkerStats;
use crate::fleet::FleetOutcome;
use crate::model::Plan;
use crate::pipeline::{rel_err_pct, SimResult};
use crate::planner::{
    PlanPerf, RobustRank, RobustScore, RobustSpec, SloScore, SloSpec,
};
use crate::replan::ReplanEvent;
use crate::serve::ServeOutcome;
use crate::simcore::ScenarioSpec;
use crate::trainer::IterLog;
use crate::util::humansize::{bytes, secs, usd};
use crate::util::json::Json;
use crate::util::table::Table;

use super::artifact::PlanArtifact;

/// Output format selected by `--format` (table is the default).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Format {
    #[default]
    Table,
    Json,
}

impl Format {
    pub fn parse(s: &str) -> Result<Format> {
        match s {
            "table" => Ok(Format::Table),
            "json" => Ok(Format::Json),
            other => bail!("unknown format {other:?} (expected table|json)"),
        }
    }
}

/// A renderable result. `to_tables` is the human form, `to_json` the
/// structured form; `render` is the single switch every surface uses.
pub trait Report {
    fn to_tables(&self) -> Vec<Table>;
    fn to_json(&self) -> Json;

    fn render(&self, format: Format) -> String {
        match format {
            Format::Table => {
                let mut out = String::new();
                for t in self.to_tables() {
                    out.push_str(&t.render());
                }
                out
            }
            Format::Json => {
                let mut s = self.to_json().pretty();
                s.push('\n');
                s
            }
        }
    }

    fn print(&self, format: Format) {
        print!("{}", self.render(format));
    }
}

fn table_json(t: &Table) -> Json {
    Json::obj(vec![
        ("title", Json::str(t.title())),
        (
            "columns",
            Json::Arr(
                t.header_cols()
                    .iter()
                    .map(|h| Json::str(h.as_str()))
                    .collect(),
            ),
        ),
        (
            "rows",
            Json::Arr(
                t.rows()
                    .iter()
                    .map(|r| {
                        Json::Arr(
                            r.iter().map(|c| Json::str(c.as_str())).collect(),
                        )
                    })
                    .collect(),
            ),
        ),
    ])
}

/// A bundle of plain tables behind the same render path — how the
/// `bench::fig*` generators (which emit `Vec<Table>`) ride the CLI's
/// `--format` switch. Deliberately NOT `impl Report for Table`:
/// `Table`'s inherent zero-arg `render()`/`print()` would shadow the
/// trait's `render(Format)`/`print(Format)` and every call site would
/// need UFCS.
#[derive(Debug, Clone, Default)]
pub struct TableSet(pub Vec<Table>);

impl Report for TableSet {
    fn to_tables(&self) -> Vec<Table> {
        self.0.clone()
    }

    fn to_json(&self) -> Json {
        Json::Arr(self.0.iter().map(table_json).collect())
    }
}

// ---------------------------------------------------------------------------
// plan
// ---------------------------------------------------------------------------

/// One evaluated configuration from a planning solve.
#[derive(Debug, Clone)]
pub struct PlanPoint {
    /// The deployable artifact (config + plan + prediction + strategy
    /// provenance).
    pub artifact: PlanArtifact,
    /// Full perf-model evaluation (with the Fig. 6 breakdown).
    pub perf: PlanPerf,
    /// Human summary (`[0..7]@4096MB | … d=2 μ=8 workers=6`).
    pub describe: String,
    /// Selected by the paper's δ ≥ 0.8 recommendation rule (under the
    /// robust metric when the request asked for one).
    pub recommended: bool,
    /// On the Pareto frontier under the ranking metric.
    pub on_frontier: bool,
    /// Seeded scenario scores; present iff the request was robust.
    pub robust: Option<RobustScore>,
    /// Seeded serving-replay scores; present iff the request carried an
    /// [`SloSpec`].
    pub slo: Option<SloScore>,
}

fn robust_spec_json(spec: &RobustSpec) -> Json {
    Json::obj(vec![
        ("scenario", Json::str(spec.scenario.name().as_str())),
        ("seeds", Json::Num(spec.seeds as f64)),
        ("rank", Json::str(spec.rank.as_str())),
    ])
}

fn slo_spec_json(spec: &SloSpec) -> Json {
    Json::obj(vec![
        ("p99_ms", Json::Num(spec.p99_ms)),
        ("traffic", Json::str(spec.traffic.name().as_str())),
        ("seeds", Json::Num(spec.seeds as f64)),
    ])
}

/// The SLO columns appended to a point's table row (empty when the
/// request carried no [`SloSpec`]).
fn slo_cells(slo: Option<&SloScore>) -> Vec<String> {
    match slo {
        Some(s) => vec![
            format!("{:.1}ms", s.p99_ms),
            usd(s.cost_per_1k_usd),
            if s.feasible { "ok".into() } else { "MISS".into() },
        ],
        None => vec![String::new(), String::new(), String::new()],
    }
}

fn point_json(p: &PlanPoint) -> Json {
    let mut fields = vec![
        (
            "weights",
            Json::Arr(vec![
                Json::Num(p.artifact.weights.0),
                Json::Num(p.artifact.weights.1),
            ]),
        ),
        ("plan", p.artifact.plan.to_json()),
        ("describe", Json::str(p.describe.as_str())),
        ("strategy", Json::str(p.artifact.strategy.as_str())),
        ("t_iter", Json::Num(p.perf.t_iter)),
        ("c_iter", Json::Num(p.perf.c_iter)),
        ("compute_s", Json::Num(p.perf.compute_s)),
        ("flush_s", Json::Num(p.perf.flush_s)),
        ("sync_s", Json::Num(p.perf.sync_s)),
        ("total_mem_gb", Json::Num(p.perf.total_mem_gb)),
        ("frontier", Json::Bool(p.on_frontier)),
        ("recommended", Json::Bool(p.recommended)),
    ];
    if let Some(r) = &p.robust {
        fields.push((
            "robust",
            Json::obj(vec![
                ("worst_t", Json::Num(r.worst_t)),
                ("worst_c", Json::Num(r.worst_c)),
                ("mean_t", Json::Num(r.mean_t)),
                ("mean_c", Json::Num(r.mean_c)),
            ]),
        ));
    }
    if let Some(s) = &p.slo {
        fields.push((
            "slo",
            Json::obj(vec![
                ("p99_ms", Json::Num(s.p99_ms)),
                ("cost_per_1k_usd", Json::Num(s.cost_per_1k_usd)),
                ("feasible", Json::Bool(s.feasible)),
            ]),
        ));
    }
    Json::obj(fields)
}

/// The robust columns appended to a point's table row (empty when the
/// request was not robust).
fn robust_cells(robust: Option<&RobustScore>, rank: RobustRank) -> Vec<String> {
    match robust {
        Some(r) => {
            let (t, c) = match rank {
                RobustRank::Worst => (r.worst_t, r.worst_c),
                RobustRank::Mean => (r.mean_t, r.mean_c),
            };
            vec![secs(t), usd(c)]
        }
        None => vec![String::new(), String::new()],
    }
}

/// Result of [`Experiment::plan`](super::Experiment::plan): every
/// deduped candidate of the strategy's sweep, frontier-flagged, with
/// the δ ≥ 0.8 recommendation marked.
#[derive(Debug, Clone)]
pub struct PlanReport {
    pub model: String,
    pub platform: String,
    pub global_batch: usize,
    /// Registry key of the strategy that produced the points.
    pub strategy: String,
    /// The scenario-robust selection spec, when one was requested.
    pub robust: Option<RobustSpec>,
    /// The serving-SLO selection spec, when one was requested.
    pub slo: Option<SloSpec>,
    /// All candidates, cheapest weights first.
    pub points: Vec<PlanPoint>,
}

impl PlanReport {
    pub fn recommended(&self) -> Option<&PlanPoint> {
        self.points.iter().find(|p| p.recommended)
    }

    /// The Pareto-frontier points, in sweep order.
    pub fn frontier(&self) -> Vec<&PlanPoint> {
        self.points.iter().filter(|p| p.on_frontier).collect()
    }
}

impl Report for PlanReport {
    fn to_tables(&self) -> Vec<Table> {
        let mut header = vec![
            "weights".to_string(),
            "plan".to_string(),
            "t_iter".to_string(),
            "c_iter".to_string(),
        ];
        if let Some(spec) = &self.robust {
            header.push(format!("{} t [{}]", spec.rank.as_str(), spec.scenario.name()));
            header.push(format!("{} c", spec.rank.as_str()));
        }
        if let Some(spec) = &self.slo {
            header.push(format!("p99 [{}]", spec.traffic.name()));
            header.push("$/1k req".to_string());
            header.push(format!("slo {:.0}ms", spec.p99_ms));
        }
        header.push("front".to_string());
        header.push("rec".to_string());
        let mut t = Table::new(format!(
            "FuncPipe plans [{}] — {} on {}, global batch {}",
            self.strategy, self.model, self.platform, self.global_batch
        ))
        .header(header);
        for p in &self.points {
            let mut row = vec![
                format!(
                    "({}, {})",
                    p.artifact.weights.0, p.artifact.weights.1
                ),
                p.describe.clone(),
                secs(p.perf.t_iter),
                usd(p.perf.c_iter),
            ];
            if let Some(spec) = &self.robust {
                row.extend(robust_cells(p.robust.as_ref(), spec.rank));
            }
            if self.slo.is_some() {
                row.extend(slo_cells(p.slo.as_ref()));
            }
            row.push(if p.on_frontier { "*".into() } else { String::new() });
            row.push(if p.recommended {
                "<- recommended".into()
            } else {
                String::new()
            });
            t.row(row);
        }
        vec![t]
    }

    fn to_json(&self) -> Json {
        let mut fields = vec![
            ("model", Json::str(self.model.as_str())),
            ("platform", Json::str(self.platform.as_str())),
            ("global_batch", Json::Num(self.global_batch as f64)),
            ("strategy", Json::str(self.strategy.as_str())),
            (
                "plans",
                Json::Arr(self.points.iter().map(point_json).collect()),
            ),
        ];
        if let Some(spec) = &self.robust {
            fields.push(("robust", robust_spec_json(spec)));
        }
        if let Some(spec) = &self.slo {
            fields.push(("slo", slo_spec_json(spec)));
        }
        Json::obj(fields)
    }
}

/// Result of [`Experiment::plan_race`](super::Experiment::plan_race)
/// (`plan --strategy all`): one row per registry strategy plus the
/// pooled winner — the δ ≥ 0.8 recommendation over the union of every
/// strategy's candidates, credited to the strategy that found it.
///
/// Deliberately carries NO wall-clock columns and NO search-node
/// counts: the race's output must byte-replay (a CI `cmp` pins this).
/// Candidate/frontier counts and recommended plans are deterministic,
/// but node counts under the parallel branch-and-bound depend on the
/// timing of shared-bound tightening (see
/// [`SolveStats`](crate::planner::SolveStats)), so they stay in
/// [`PlanOutcome::stats`](crate::planner::PlanOutcome) as diagnostics
/// and never reach a rendered report.
#[derive(Debug, Clone)]
pub struct StrategyRow {
    pub strategy: String,
    /// Deduped candidates the strategy produced.
    pub candidates: usize,
    /// Of those, on the strategy's own frontier.
    pub frontier: usize,
    /// The strategy's own δ ≥ 0.8 recommendation.
    pub recommended: Option<PlanPoint>,
}

#[derive(Debug, Clone)]
pub struct PlanCompareReport {
    pub model: String,
    pub platform: String,
    pub global_batch: usize,
    pub robust: Option<RobustSpec>,
    pub slo: Option<SloSpec>,
    pub rows: Vec<StrategyRow>,
    /// The pooled recommendation across all strategies' candidates; its
    /// artifact records the winning strategy's provenance.
    pub winner: Option<PlanPoint>,
}

impl Report for PlanCompareReport {
    fn to_tables(&self) -> Vec<Table> {
        let mut header = vec![
            "strategy".to_string(),
            "plans".to_string(),
            "front".to_string(),
            "recommended plan".to_string(),
            "t_iter".to_string(),
            "c_iter".to_string(),
        ];
        if let Some(spec) = &self.robust {
            header.push(format!("{} t [{}]", spec.rank.as_str(), spec.scenario.name()));
            header.push(format!("{} c", spec.rank.as_str()));
        }
        if let Some(spec) = &self.slo {
            header.push(format!("p99 [{}]", spec.traffic.name()));
            header.push("$/1k req".to_string());
            header.push(format!("slo {:.0}ms", spec.p99_ms));
        }
        header.push("race".to_string());
        let mut t = Table::new(format!(
            "plan strategy race — {} on {}, global batch {}",
            self.model, self.platform, self.global_batch
        ))
        .header(header);
        for row in &self.rows {
            let win = self
                .winner
                .as_ref()
                .map(|w| w.artifact.strategy == row.strategy)
                .unwrap_or(false);
            let mut cells = vec![
                row.strategy.clone(),
                row.candidates.to_string(),
                row.frontier.to_string(),
            ];
            match &row.recommended {
                Some(p) => {
                    cells.push(p.describe.clone());
                    cells.push(secs(p.perf.t_iter));
                    cells.push(usd(p.perf.c_iter));
                    if let Some(spec) = &self.robust {
                        cells.extend(robust_cells(p.robust.as_ref(), spec.rank));
                    }
                    if self.slo.is_some() {
                        cells.extend(slo_cells(p.slo.as_ref()));
                    }
                }
                None => {
                    cells.push("(no feasible plan)".into());
                    cells.push(String::new());
                    cells.push(String::new());
                    if self.robust.is_some() {
                        cells.push(String::new());
                        cells.push(String::new());
                    }
                    if self.slo.is_some() {
                        cells.extend(slo_cells(None));
                    }
                }
            }
            cells.push(if win { "<- winner".into() } else { String::new() });
            t.row(cells);
        }
        vec![t]
    }

    fn to_json(&self) -> Json {
        let mut fields = vec![
            ("model", Json::str(self.model.as_str())),
            ("platform", Json::str(self.platform.as_str())),
            ("global_batch", Json::Num(self.global_batch as f64)),
            (
                "strategies",
                Json::Arr(
                    self.rows
                        .iter()
                        .map(|row| {
                            let mut f = vec![
                                ("strategy", Json::str(row.strategy.as_str())),
                                (
                                    "candidates",
                                    Json::Num(row.candidates as f64),
                                ),
                                ("frontier", Json::Num(row.frontier as f64)),
                            ];
                            if let Some(p) = &row.recommended {
                                f.push(("recommended", point_json(p)));
                            }
                            Json::obj(f)
                        })
                        .collect(),
                ),
            ),
        ];
        if let Some(spec) = &self.robust {
            fields.push(("robust", robust_spec_json(spec)));
        }
        if let Some(spec) = &self.slo {
            fields.push(("slo", slo_spec_json(spec)));
        }
        if let Some(w) = &self.winner {
            fields.push(("winner", point_json(w)));
            fields.push((
                "winner_strategy",
                Json::str(w.artifact.strategy.as_str()),
            ));
        }
        Json::obj(fields)
    }
}

// ---------------------------------------------------------------------------
// simulate
// ---------------------------------------------------------------------------

/// Closed-form prediction vs discrete-event simulation of one plan,
/// plus (when the session selects one) the seeded scenario pass.
#[derive(Debug, Clone)]
pub struct SimReport {
    pub plan: Plan,
    pub describe: String,
    pub predicted: PlanPerf,
    /// Deterministic DES — the Table-3 "measured" reference.
    pub sim: SimResult,
    /// The session's scenario lens and its seed.
    pub scenario: ScenarioSpec,
    pub seed: u64,
    /// DES under the scenario; `None` when it is `deterministic`.
    pub scenario_sim: Option<SimResult>,
}

impl SimReport {
    /// Table-3-style relative t_iter error, percent (model vs the
    /// deterministic DES — scenario noise is reported separately).
    pub fn error_pct(&self) -> f64 {
        rel_err_pct(self.predicted.t_iter, self.sim.t_iter)
    }

    /// Scenario-induced slowdown over the deterministic DES, percent.
    pub fn scenario_overhead_pct(&self) -> Option<f64> {
        self.scenario_sim
            .as_ref()
            .map(|s| (s.t_iter / self.sim.t_iter - 1.0) * 100.0)
    }
}

impl Report for SimReport {
    fn to_tables(&self) -> Vec<Table> {
        let mut t = Table::new(format!("model vs DES simulation — {}", self.describe))
            .header(["source", "t_iter", "c_iter"]);
        t.row([
            "perf model".to_string(),
            secs(self.predicted.t_iter),
            usd(self.predicted.c_iter),
        ]);
        t.row([
            "DES sim".to_string(),
            secs(self.sim.t_iter),
            usd(self.sim.c_iter),
        ]);
        t.row([
            "error".to_string(),
            format!("{:.1}%", self.error_pct()),
            String::new(),
        ]);
        if let Some(s) = &self.scenario_sim {
            t.row([
                format!(
                    "DES sim [{} seed={}]",
                    self.scenario.name(),
                    self.seed
                ),
                secs(s.t_iter),
                usd(s.c_iter),
            ]);
            t.row([
                "scenario overhead".to_string(),
                format!("{:+.1}%", self.scenario_overhead_pct().unwrap_or(0.0)),
                String::new(),
            ]);
        }
        vec![t]
    }

    fn to_json(&self) -> Json {
        let kind = self.scenario.name();
        let mut scenario = vec![
            ("kind", Json::str(kind.as_str())),
            ("seed", Json::Num(self.seed as f64)),
        ];
        if let Some(s) = &self.scenario_sim {
            scenario.push(("t_iter", Json::Num(s.t_iter)));
            scenario.push(("c_iter", Json::Num(s.c_iter)));
            scenario.push((
                "overhead_pct",
                Json::Num(self.scenario_overhead_pct().unwrap_or(0.0)),
            ));
        }
        Json::obj(vec![
            ("plan", self.plan.to_json()),
            ("describe", Json::str(self.describe.as_str())),
            (
                "predicted",
                Json::obj(vec![
                    ("t_iter", Json::Num(self.predicted.t_iter)),
                    ("c_iter", Json::Num(self.predicted.c_iter)),
                ]),
            ),
            (
                "simulated",
                Json::obj(vec![
                    ("t_iter", Json::Num(self.sim.t_iter)),
                    ("c_iter", Json::Num(self.sim.c_iter)),
                ]),
            ),
            ("scenario", Json::obj(scenario)),
            ("error_pct", Json::Num(self.error_pct())),
        ])
    }
}

// ---------------------------------------------------------------------------
// train
// ---------------------------------------------------------------------------

/// Structured summary of a real training run, including the scenario
/// lens it ran under — the same `kind`/`seed` columns as [`SimReport`],
/// so one frozen plan replayed by `simulate` and `train` under the same
/// `--scenario`/`--seed` is comparable line for line.
#[derive(Debug, Clone)]
pub struct TrainReport {
    pub steps: usize,
    pub dp: usize,
    pub mu: usize,
    pub first_loss: f32,
    pub last_loss: f32,
    pub mean_iter_s: f64,
    pub wall_s: f64,
    pub restarts: usize,
    pub store_puts: u64,
    pub store_gets: u64,
    pub logs: Vec<IterLog>,
    /// The scenario lens and its seed (mirrors `SimReport`).
    pub scenario: ScenarioSpec,
    pub seed: u64,
    /// Cold-start seconds charged across all generations.
    pub cold_start_total_s: f64,
    /// The platform/tier base cold-start charge per generation (what an
    /// unperturbed run would have paid).
    pub cold_start_base_s: f64,
    /// The deterministic virtual tick (scenario runs); `None` = the
    /// wall-clock lifecycle.
    pub virtual_iter_s: Option<f64>,
    /// Per-worker lifecycle + lens stats, in worker-id order (plan
    /// generation 0 first, then each migrated generation's workers).
    pub workers: Vec<WorkerStats>,
    /// `--replan` was active: the report carries the re-plan event log
    /// (possibly empty — no sustained drift).
    pub replan_enabled: bool,
    /// Mid-run re-plan decisions, in trigger order (adopted or not).
    pub replan: Vec<ReplanEvent>,
    /// `train --plan` reset the artifact's embedded scenario lens to
    /// deterministic and no explicit `--scenario` opted back in.
    pub lens_reset: bool,
}

impl TrainReport {
    pub(crate) fn from_raw(
        cfg: &crate::trainer::TrainConfig,
        raw: crate::trainer::TrainReport,
    ) -> Self {
        Self {
            replan_enabled: false,
            replan: Vec::new(),
            lens_reset: false,
            steps: cfg.steps,
            dp: cfg.dp,
            mu: cfg.mu,
            first_loss: raw.first_loss(),
            last_loss: raw.last_loss(),
            mean_iter_s: raw.mean_iter_s(),
            wall_s: raw.wall_s,
            restarts: raw.restarts,
            store_puts: raw.store_put_gets.0,
            store_gets: raw.store_put_gets.1,
            scenario: cfg.scenario.clone(),
            seed: cfg.scenario_seed,
            cold_start_total_s: raw.cold_start_total_s(),
            cold_start_base_s: cfg.cold_start_s,
            virtual_iter_s: cfg.virtual_iter_s,
            workers: raw.workers,
            logs: raw.logs,
        }
    }

    /// Transient `get_blocking` drops injected by the `flaky-network`
    /// lens across all workers (each absorbed by a retry).
    pub fn flaky_timeouts_total(&self) -> u64 {
        self.workers.iter().map(|w| w.flaky_timeouts).sum()
    }

    /// Observed scenario slowdown over the unperturbed timeline,
    /// percent — the train-path analogue of
    /// [`SimReport::scenario_overhead_pct`]. Defined on the virtual
    /// clock (scenario runs). `wall_s` is the slowest worker's elapsed
    /// time, so the baseline is **that same worker's** unperturbed
    /// timeline — `steps × tick` plus the base cold-start charges of
    /// its own generations — isolating what the scenario added (lens
    /// stretch + drawn delays) without billing the platform's ordinary
    /// cold starts to the scenario or mixing two different workers'
    /// timelines.
    pub fn scenario_overhead_pct(&self) -> Option<f64> {
        self.virtual_iter_s.map(|tick| {
            let gating = self
                .workers
                .iter()
                .max_by(|a, b| {
                    a.virtual_elapsed_s.total_cmp(&b.virtual_elapsed_s)
                })
                .map(|w| w.generations as f64)
                .unwrap_or(0.0);
            let baseline =
                self.steps as f64 * tick + gating * self.cold_start_base_s;
            (self.wall_s / baseline - 1.0) * 100.0
        })
    }
}

impl Report for TrainReport {
    fn to_tables(&self) -> Vec<Table> {
        let mut t = Table::new(format!(
            "training run — {} steps, dp={} μ={}",
            self.steps, self.dp, self.mu
        ))
        .header(["metric", "value"]);
        t.row(["loss".to_string(), format!("{:.4} -> {:.4}", self.first_loss, self.last_loss)]);
        t.row(["iter time".to_string(), format!("{:.1} ms", self.mean_iter_s * 1e3)]);
        t.row(["wall time".to_string(), secs(self.wall_s)]);
        t.row(["restarts".to_string(), self.restarts.to_string()]);
        t.row([
            "store put/get".to_string(),
            format!("{}/{}", self.store_puts, self.store_gets),
        ]);
        t.row([
            "scenario".to_string(),
            format!("{} seed={}", self.scenario.name(), self.seed),
        ]);
        t.row([
            "cold-start charged".to_string(),
            secs(self.cold_start_total_s),
        ]);
        if let Some(pct) = self.scenario_overhead_pct() {
            t.row([
                "scenario overhead".to_string(),
                format!("{pct:+.1}%"),
            ]);
        }
        if self.flaky_timeouts_total() > 0 {
            t.row([
                "flaky timeouts (retried)".to_string(),
                self.flaky_timeouts_total().to_string(),
            ]);
        }
        if self.replan_enabled {
            t.row([
                "re-plan".to_string(),
                match self.replan.len() {
                    0 => "enabled (no sustained drift)".to_string(),
                    n => format!("{n} event(s)"),
                },
            ]);
        }
        let mut tables = vec![t];
        if !self.replan.is_empty() {
            let mut ev = Table::new("re-plan events").header([
                "trigger", "observed", "predicted", "old plan", "new plan",
                "strategy", "new tick", "migration", "adopted",
            ]);
            for e in &self.replan {
                ev.row([
                    format!("step {}", e.trigger_step),
                    secs(e.observed_iter_s),
                    secs(e.predicted_iter_s),
                    format!(
                        "{}st d={} μ={}",
                        e.old_stages, e.old_dp, e.old_mu
                    ),
                    format!(
                        "{}st d={} μ={}",
                        e.new_stages, e.new_dp, e.new_mu
                    ),
                    e.strategy.clone(),
                    secs(e.new_iter_s),
                    secs(e.migration_s),
                    if e.adopted { "yes".into() } else { "no".into() },
                ]);
            }
            tables.push(ev);
        }
        if !self.scenario.is_deterministic() {
            let mut lens = Table::new("scenario lens (per worker)").header([
                "worker", "stage", "rep", "plan", "gens", "cold",
                "compute×", "bandwidth×", "flaky",
            ]);
            for w in &self.workers {
                lens.row([
                    w.worker_id.to_string(),
                    w.stage.to_string(),
                    w.replica.to_string(),
                    w.plan_generation.to_string(),
                    w.generations.to_string(),
                    secs(w.cold_start_s),
                    format!("{:.3}", w.lens.compute_mult),
                    format!("{:.3}", w.lens.bandwidth_mult),
                    w.flaky_timeouts.to_string(),
                ]);
            }
            tables.push(lens);
        }
        tables
    }

    fn to_json(&self) -> Json {
        let kind = self.scenario.name();
        let mut scenario = vec![
            ("kind", Json::str(kind.as_str())),
            ("seed", Json::Num(self.seed as f64)),
        ];
        if !self.scenario.is_deterministic() {
            scenario.push((
                "cold_start_total_s",
                Json::Num(self.cold_start_total_s),
            ));
            scenario.push((
                "flaky_timeouts",
                Json::Num(self.flaky_timeouts_total() as f64),
            ));
            if let Some(pct) = self.scenario_overhead_pct() {
                scenario.push(("overhead_pct", Json::Num(pct)));
            }
            scenario.push((
                "workers",
                Json::Arr(
                    self.workers
                        .iter()
                        .map(|w| {
                            Json::obj(vec![
                                ("worker", Json::Num(w.worker_id as f64)),
                                ("stage", Json::Num(w.stage as f64)),
                                ("replica", Json::Num(w.replica as f64)),
                                (
                                    "plan_generation",
                                    Json::Num(w.plan_generation as f64),
                                ),
                                ("restarts", Json::Num(w.restarts as f64)),
                                (
                                    "generations",
                                    Json::Num(w.generations as f64),
                                ),
                                ("cold_start_s", Json::Num(w.cold_start_s)),
                                (
                                    "compute_mult",
                                    Json::Num(w.lens.compute_mult),
                                ),
                                (
                                    "bandwidth_mult",
                                    Json::Num(w.lens.bandwidth_mult),
                                ),
                                (
                                    "latency_mult",
                                    Json::Num(w.lens.latency_mult),
                                ),
                                (
                                    "flaky_timeouts",
                                    Json::Num(w.flaky_timeouts as f64),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ));
        }
        let mut fields = vec![
            ("steps", Json::Num(self.steps as f64)),
            ("dp", Json::Num(self.dp as f64)),
            ("mu", Json::Num(self.mu as f64)),
            ("first_loss", Json::Num(self.first_loss as f64)),
            ("last_loss", Json::Num(self.last_loss as f64)),
            ("mean_iter_s", Json::Num(self.mean_iter_s)),
            ("wall_s", Json::Num(self.wall_s)),
            ("restarts", Json::Num(self.restarts as f64)),
            (
                "store",
                Json::obj(vec![
                    ("puts", Json::Num(self.store_puts as f64)),
                    ("gets", Json::Num(self.store_gets as f64)),
                ]),
            ),
            ("scenario", Json::obj(scenario)),
            (
                "loss_curve",
                Json::Arr(
                    self.logs
                        .iter()
                        .map(|l| {
                            Json::obj(vec![
                                ("step", Json::Num(l.step as f64)),
                                ("loss", Json::Num(l.loss as f64)),
                                ("iter_s", Json::Num(l.iter_s)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ];
        if self.replan_enabled {
            fields.push((
                "replan",
                Json::Arr(self.replan.iter().map(replan_event_json).collect()),
            ));
        }
        if self.lens_reset {
            fields.push(("lens_reset", Json::Bool(true)));
        }
        Json::obj(fields)
    }
}

/// One re-plan decision as rendered into the report JSON — the full
/// audit trail of a migration (or of the choice not to migrate).
fn replan_event_json(e: &ReplanEvent) -> Json {
    Json::obj(vec![
        ("trigger_step", Json::Num(e.trigger_step as f64)),
        ("observed_iter_s", Json::Num(e.observed_iter_s)),
        ("predicted_iter_s", Json::Num(e.predicted_iter_s)),
        (
            "stage_mults",
            Json::Arr(e.stage_mults.iter().map(|&m| Json::Num(m)).collect()),
        ),
        (
            "old",
            Json::obj(vec![
                ("stages", Json::Num(e.old_stages as f64)),
                ("dp", Json::Num(e.old_dp as f64)),
                ("mu", Json::Num(e.old_mu as f64)),
            ]),
        ),
        (
            "new",
            Json::obj(vec![
                ("stages", Json::Num(e.new_stages as f64)),
                ("dp", Json::Num(e.new_dp as f64)),
                ("mu", Json::Num(e.new_mu as f64)),
            ]),
        ),
        ("strategy", Json::str(e.strategy.as_str())),
        ("new_iter_s", Json::Num(e.new_iter_s)),
        ("migration_s", Json::Num(e.migration_s)),
        ("adopted", Json::Bool(e.adopted)),
    ])
}

// ---------------------------------------------------------------------------
// baselines
// ---------------------------------------------------------------------------

/// One evaluated §5.1 baseline (`None` result = OOM, as the paper reports).
#[derive(Debug, Clone)]
pub struct BaselineRow {
    pub name: &'static str,
    /// Worker memory in MB for the chosen tier (when feasible).
    pub mem_mb: Option<u64>,
    pub result: Option<BaselineResult>,
}

/// Result of [`Experiment::baselines`](super::Experiment::baselines).
#[derive(Debug, Clone)]
pub struct BaselineReport {
    pub model: String,
    pub platform: String,
    pub global_batch: usize,
    pub rows: Vec<BaselineRow>,
}

impl Report for BaselineReport {
    fn to_tables(&self) -> Vec<Table> {
        let mut t = Table::new(format!(
            "baselines — {} on {}, batch {}",
            self.model, self.platform, self.global_batch
        ))
        .header(["design", "workers", "mem", "t_iter", "c_iter"]);
        for row in &self.rows {
            match (&row.result, row.mem_mb) {
                (Some(r), Some(mb)) => t.row([
                    row.name.to_string(),
                    r.n_workers.to_string(),
                    format!("{mb}MB"),
                    secs(r.t_iter),
                    usd(r.c_iter),
                ]),
                _ => t.row([
                    row.name.to_string(),
                    "OOM".into(),
                    String::new(),
                    String::new(),
                    String::new(),
                ]),
            }
        }
        vec![t]
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("model", Json::str(self.model.as_str())),
            ("platform", Json::str(self.platform.as_str())),
            ("global_batch", Json::Num(self.global_batch as f64)),
            (
                "baselines",
                Json::Arr(
                    self.rows
                        .iter()
                        .map(|row| match (&row.result, row.mem_mb) {
                            (Some(r), Some(mb)) => Json::obj(vec![
                                ("design", Json::str(row.name)),
                                ("feasible", Json::Bool(true)),
                                ("workers", Json::Num(r.n_workers as f64)),
                                ("mem_mb", Json::Num(mb as f64)),
                                ("local_batch", Json::Num(r.local_batch as f64)),
                                ("t_iter", Json::Num(r.t_iter)),
                                ("c_iter", Json::Num(r.c_iter)),
                                ("compute_s", Json::Num(r.compute_s)),
                                ("sync_s", Json::Num(r.sync_s)),
                            ]),
                            _ => Json::obj(vec![
                                ("design", Json::str(row.name)),
                                ("feasible", Json::Bool(false)),
                            ]),
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

// ---------------------------------------------------------------------------
// profile
// ---------------------------------------------------------------------------

/// One profiled AOT stage (per micro-batch, at the platform's top tier,
/// viewed through the session scenario's per-worker compute lens).
#[derive(Debug, Clone)]
pub struct ProfileRow {
    pub name: String,
    pub param_bytes: u64,
    pub fwd_s: f64,
    pub bwd_s: f64,
    /// The scenario lens multiplier already applied to `fwd_s`/`bwd_s`
    /// (1.0 under the deterministic scenario).
    pub compute_mult: f64,
}

/// Result of [`Experiment::profile`](super::Experiment::profile).
#[derive(Debug, Clone)]
pub struct ProfileReport {
    /// Scenario the times are viewed through ("deterministic" = raw).
    pub scenario: String,
    pub rows: Vec<ProfileRow>,
}

impl Report for ProfileReport {
    fn to_tables(&self) -> Vec<Table> {
        let mut t = Table::new(format!(
            "AOT stage profile (per micro-batch, scenario: {})",
            self.scenario
        ))
        .header(["stage", "params", "fwd@top", "bwd@top", "lens"]);
        for r in &self.rows {
            t.row([
                r.name.clone(),
                bytes(r.param_bytes),
                secs(r.fwd_s),
                secs(r.bwd_s),
                format!("{:.3}x", r.compute_mult),
            ]);
        }
        vec![t]
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("scenario", Json::str(self.scenario.as_str())),
            (
                "stages",
                Json::Arr(
                    self.rows
                        .iter()
                        .map(|r| {
                            Json::obj(vec![
                                ("stage", Json::str(r.name.as_str())),
                                (
                                    "param_bytes",
                                    Json::Num(r.param_bytes as f64),
                                ),
                                ("fwd_s", Json::Num(r.fwd_s)),
                                ("bwd_s", Json::Num(r.bwd_s)),
                                ("compute_mult", Json::Num(r.compute_mult)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

// ---------------------------------------------------------------------------
// serve
// ---------------------------------------------------------------------------

/// Result of [`Experiment::serve`](super::Experiment::serve): one
/// trace-driven serving replay of a frozen plan. Carries NO wall-clock
/// values — every number derives from the virtual clock and the seeded
/// arrival/scenario streams, so the same (plan, traffic, seed) renders
/// byte-identically (a CI `cmp` pins this).
#[derive(Debug, Clone, PartialEq)]
pub struct ServeReport {
    pub model: String,
    pub platform: String,
    /// Canonical traffic spec (`TrafficSpec::name`).
    pub traffic: String,
    pub seed: u64,
    /// Scenario lens the replay ran under ("deterministic" = none).
    pub scenario: String,
    /// Arrival-window length the trace was generated for.
    pub duration_s: f64,
    /// Micro-batch formation window (echoed knob).
    pub batch_window_s: f64,
    /// Autoscaler scale-down idle timeout (echoed knob).
    pub idle_timeout_s: f64,
    /// Autoscaler per-stage instance ceiling (echoed knob).
    pub max_instances: usize,
    /// Requests per batch cap — the frozen plan's μ.
    pub batch_cap: usize,
    /// The replay's measured outcome.
    pub outcome: ServeOutcome,
}

impl Report for ServeReport {
    fn to_tables(&self) -> Vec<Table> {
        let o = &self.outcome;
        let mut t = Table::new(format!(
            "serving replay — {} on {} [{} seed={}]",
            self.model, self.platform, self.traffic, self.seed
        ))
        .header(["metric", "value"]);
        t.row(["requests".to_string(), format!("{} offered / {} served", o.requests, o.completed)]);
        t.row([
            "latency".to_string(),
            format!(
                "p50 {:.1}ms  p95 {:.1}ms  p99 {:.1}ms",
                o.p50_ms, o.p95_ms, o.p99_ms
            ),
        ]);
        t.row([
            "throughput".to_string(),
            format!(
                "{:.0} req/min offered, {:.0} req/min achieved",
                o.offered_rpm, o.achieved_rpm
            ),
        ]);
        t.row(["makespan".to_string(), secs(o.makespan_s)]);
        t.row([
            "cold-start rate".to_string(),
            format!("{:.1}%", o.cold_start_rate * 100.0),
        ]);
        t.row([
            "cost".to_string(),
            format!("{} ({} / 1k req)", usd(o.cost_usd), usd(o.cost_per_1k_usd)),
        ]);
        t.row([
            "scenario".to_string(),
            format!("{} seed={}", self.scenario, self.seed),
        ]);
        t.row([
            "knobs".to_string(),
            format!(
                "window {:.0}ms, idle {:.0}s, ≤{} inst/stage, batch ≤{}",
                self.batch_window_s * 1e3,
                self.idle_timeout_s,
                self.max_instances,
                self.batch_cap
            ),
        ]);
        let mut stages = Table::new("per-stage autoscaling").header([
            "stage", "tier", "launches", "expiries", "peak", "batches",
            "mean batch", "util", "busy", "alive",
        ]);
        for s in &o.stages {
            stages.row([
                s.stage.to_string(),
                s.tier.to_string(),
                s.launches.to_string(),
                s.expiries.to_string(),
                s.peak_instances.to_string(),
                s.batches.to_string(),
                format!("{:.2}", s.mean_batch),
                format!("{:.1}%", s.utilization * 100.0),
                secs(s.busy_s),
                secs(s.alive_s),
            ]);
        }
        vec![t, stages]
    }

    fn to_json(&self) -> Json {
        let o = &self.outcome;
        Json::obj(vec![
            ("model", Json::str(self.model.as_str())),
            ("platform", Json::str(self.platform.as_str())),
            ("traffic", Json::str(self.traffic.as_str())),
            ("seed", Json::Num(self.seed as f64)),
            ("scenario", Json::str(self.scenario.as_str())),
            ("duration_s", Json::Num(self.duration_s)),
            (
                "knobs",
                Json::obj(vec![
                    ("batch_window_s", Json::Num(self.batch_window_s)),
                    ("idle_timeout_s", Json::Num(self.idle_timeout_s)),
                    ("max_instances", Json::Num(self.max_instances as f64)),
                    ("batch_cap", Json::Num(self.batch_cap as f64)),
                ]),
            ),
            ("requests", Json::Num(o.requests as f64)),
            ("completed", Json::Num(o.completed as f64)),
            (
                "latency_ms",
                Json::obj(vec![
                    ("p50", Json::Num(o.p50_ms)),
                    ("p95", Json::Num(o.p95_ms)),
                    ("p99", Json::Num(o.p99_ms)),
                ]),
            ),
            ("offered_rpm", Json::Num(o.offered_rpm)),
            ("achieved_rpm", Json::Num(o.achieved_rpm)),
            ("makespan_s", Json::Num(o.makespan_s)),
            ("cold_start_rate", Json::Num(o.cold_start_rate)),
            ("cost_usd", Json::Num(o.cost_usd)),
            ("cost_per_1k_usd", Json::Num(o.cost_per_1k_usd)),
            (
                "stages",
                Json::Arr(
                    o.stages
                        .iter()
                        .map(|s| {
                            Json::obj(vec![
                                ("stage", Json::Num(s.stage as f64)),
                                ("tier", Json::Num(s.tier as f64)),
                                ("launches", Json::Num(s.launches as f64)),
                                ("expiries", Json::Num(s.expiries as f64)),
                                (
                                    "peak_instances",
                                    Json::Num(s.peak_instances as f64),
                                ),
                                ("batches", Json::Num(s.batches as f64)),
                                ("mean_batch", Json::Num(s.mean_batch)),
                                ("utilization", Json::Num(s.utilization)),
                                ("busy_s", Json::Num(s.busy_s)),
                                ("alive_s", Json::Num(s.alive_s)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

// ---------------------------------------------------------------------------
// fleet
// ---------------------------------------------------------------------------

/// Result of [`Experiment::fleet`](super::Experiment::fleet): one
/// multi-tenant run of several frozen plans against a shared platform.
/// Carries NO wall-clock values — every number derives from the shared
/// virtual clock and the seeded scenario streams, so the same
/// (fleet config, scenario, seed) renders byte-identically
/// (`tests/fleet_replay.rs` and a CI `cmp` pin this).
#[derive(Debug, Clone, PartialEq)]
pub struct FleetReport {
    /// The scheduler's raw accounting.
    pub outcome: FleetOutcome,
}

impl Report for FleetReport {
    fn to_tables(&self) -> Vec<Table> {
        let o = &self.outcome;
        let mut t = Table::new(format!(
            "fleet — {} tenants on {} [{} seed={}]",
            o.tenants.len(),
            o.platform,
            o.scenario,
            o.seed
        ))
        .header(["metric", "value"]);
        t.row([
            "concurrency".to_string(),
            format!("peak {} of {} workers", o.peak_workers, o.max_concurrency),
        ]);
        t.row([
            "utilization".to_string(),
            format!("{:.1}%", o.utilization * 100.0),
        ]);
        t.row([
            "contention".to_string(),
            format!("{:.3}x mean stretch", o.mean_contention),
        ]);
        t.row(["makespan".to_string(), secs(o.makespan_s)]);
        t.row(["cost".to_string(), usd(o.total_cost_usd)]);
        t.row(["admission order".to_string(), o.admissions.join(", ")]);
        let mut tenants = Table::new("per-tenant accounting").header([
            "tenant", "kind", "workers", "units", "submit", "wait", "busy",
            "finish", "admits", "revokes", "contention", "cost",
        ]);
        for ten in &o.tenants {
            tenants.row([
                ten.name.clone(),
                ten.kind.clone(),
                ten.workers.to_string(),
                ten.units.to_string(),
                secs(ten.submit_s),
                secs(ten.wait_s),
                secs(ten.busy_s),
                secs(ten.finish_s),
                ten.admissions.to_string(),
                ten.revocations.to_string(),
                format!("{:.3}x", ten.mean_contention),
                usd(ten.cost_usd),
            ]);
        }
        vec![t, tenants]
    }

    fn to_json(&self) -> Json {
        let o = &self.outcome;
        Json::obj(vec![
            ("platform", Json::str(o.platform.as_str())),
            ("scenario", Json::str(o.scenario.as_str())),
            ("seed", Json::Num(o.seed as f64)),
            ("max_concurrency", Json::Num(o.max_concurrency as f64)),
            ("peak_workers", Json::Num(o.peak_workers as f64)),
            ("utilization", Json::Num(o.utilization)),
            ("mean_contention", Json::Num(o.mean_contention)),
            ("makespan_s", Json::Num(o.makespan_s)),
            ("total_cost_usd", Json::Num(o.total_cost_usd)),
            (
                "admissions",
                Json::Arr(
                    o.admissions.iter().map(|n| Json::str(n.as_str())).collect(),
                ),
            ),
            (
                "tenants",
                Json::Arr(
                    o.tenants
                        .iter()
                        .map(|ten| {
                            Json::obj(vec![
                                ("name", Json::str(ten.name.as_str())),
                                ("kind", Json::str(ten.kind.as_str())),
                                ("workers", Json::Num(ten.workers as f64)),
                                ("units", Json::Num(ten.units as f64)),
                                ("submit_s", Json::Num(ten.submit_s)),
                                ("admit_s", Json::Num(ten.admit_s)),
                                ("wait_s", Json::Num(ten.wait_s)),
                                ("busy_s", Json::Num(ten.busy_s)),
                                ("finish_s", Json::Num(ten.finish_s)),
                                ("admissions", Json::Num(ten.admissions as f64)),
                                (
                                    "revocations",
                                    Json::Num(ten.revocations as f64),
                                ),
                                (
                                    "mean_contention",
                                    Json::Num(ten.mean_contention),
                                ),
                                ("cost_usd", Json::Num(ten.cost_usd)),
                                ("units_per_s", Json::Num(ten.units_per_s)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn format_parses() {
        assert_eq!(Format::parse("table").unwrap(), Format::Table);
        assert_eq!(Format::parse("json").unwrap(), Format::Json);
        assert!(Format::parse("yaml").is_err());
    }

    #[test]
    fn table_report_json_shape() {
        let mut t = Table::new("demo").header(["a", "b"]);
        t.row(["1", "2"]);
        let j = table_json(&t);
        assert_eq!(j.field_str("title").unwrap(), "demo");
        assert_eq!(j.field_arr("rows").unwrap().len(), 1);
        // the render path emits parseable JSON
        let rendered = TableSet(vec![t]).render(Format::Json);
        Json::parse(rendered.trim()).unwrap();
    }

    #[test]
    fn tableset_renders_both_formats() {
        let mut t = Table::new("x").header(["c"]);
        t.row(["v"]);
        let set = TableSet(vec![t.clone(), t]);
        assert!(set.render(Format::Table).contains("== x =="));
        let j = Json::parse(set.render(Format::Json).trim()).unwrap();
        assert_eq!(j.as_arr().unwrap().len(), 2);
    }
}
