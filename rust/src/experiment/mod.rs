//! The `Experiment` session API — one object that owns the resolved
//! model + platform and exposes the whole FuncPipe lifecycle (§3.1:
//! profile → co-optimize → deploy → train) programmatically:
//!
//! ```text
//! let exp = Experiment::new(cfg)?;            // one unified config
//! let plans = exp.plan()?;                    // PlanReport (Pareto front)
//! let rec = plans.recommended().unwrap();
//! rec.artifact.save("plan.json")?;            // serializable artifact
//! let sim = exp.simulate(&rec.artifact)?;     // SimReport
//! let run = exp.train(Some(&rec.artifact), &TrainOverrides::default())?;
//! let base = exp.baselines()?;                // BaselineReport
//! ```
//!
//! The CLI (`rust/src/main.rs`), the `bench::fig*` generators and the
//! integration tests are thin shells over this module, so every surface
//! exercises identical code. The [`PlanArtifact`] makes the planner's
//! decision a file: `funcpipe plan --out plan.json` solves once and
//! `simulate|train --plan plan.json` replay it — the trainer derives
//! `dp`/`mu`/chunking from the plan instead of hand-copied flags.

pub mod artifact;
pub mod report;

pub use artifact::{PlanArtifact, PLAN_SCHEMA_VERSION};
pub use report::{
    BaselineReport, BaselineRow, FleetReport, Format, PlanCompareReport,
    PlanPoint, PlanReport, ProfileReport, ProfileRow, Report, ServeReport,
    SimReport, StrategyRow, TableSet, TrainReport,
};

use std::path::Path;
use std::sync::Arc;

use anyhow::{bail, ensure, Context, Result};

use crate::baselines::{evaluate_baseline, BaselineKind};
use crate::collective::Chunking;
use crate::config::ExperimentConfig;
use crate::model::{zoo, ModelProfile, Plan};
use crate::pipeline::{simulate_iteration, simulate_iteration_scenario};
use crate::planner::{
    race, solve_request, PerfModel, PlanCandidate, PlanKey, PlanOutcome,
    PlanRequest, STRATEGIES,
};
use crate::platform::pricing::{C5_9XLARGE, R7_2XLARGE};
use crate::platform::{MemStore, PlatformSpec};
use crate::replan::{
    even_groups, identity_groups, observe_step, DriftDetector,
    MeasuredProfile, ReplanEvent, ReplanSpec, StageObs, StageObservations,
};
use crate::serve::{serve_plan, ServeOptions};
use crate::trainer;

/// The default plan strategy (`Experiment::plan`, bare `funcpipe plan`).
pub const DEFAULT_STRATEGY: &str = "bnb";

/// Explicit per-run overrides for [`Experiment::train`]: every field
/// defaults to "take it from the plan/config". CLI flags map 1:1 onto
/// these, which is what keeps `--dp`/`--mu` available as *overrides*
/// while the plan artifact supplies them normally.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TrainOverrides {
    pub dp: Option<usize>,
    pub mu: Option<usize>,
    pub steps: Option<usize>,
    pub lr: Option<f64>,
    pub lifetime_s: Option<f64>,
    pub chunk_bytes: Option<usize>,
    pub chunks_in_flight: Option<usize>,
    pub artifacts_dir: Option<String>,
}

/// One experiment session: a unified config plus the model and platform
/// it resolves to, with the full lifecycle as methods.
pub struct Experiment {
    cfg: ExperimentConfig,
    platform: PlatformSpec,
    model: ModelProfile,
}

impl Experiment {
    pub fn new(cfg: ExperimentConfig) -> Result<Self> {
        cfg.validate()?;
        let platform = cfg.resolve_platform()?;
        let model = cfg.resolve_model(&platform)?;
        Ok(Self { cfg, platform, model })
    }

    /// Reconstruct the session a plan artifact was produced by (the
    /// `simulate|train --plan plan.json` path). The embedded plan is
    /// validated against the re-resolved model and platform, so a stale
    /// or hand-mangled artifact fails here instead of mid-run.
    pub fn from_artifact(artifact: &PlanArtifact) -> Result<Self> {
        let exp = Self::new(artifact.config.clone())?;
        exp.check_artifact(artifact)?;
        Ok(exp)
    }

    pub fn config(&self) -> &ExperimentConfig {
        &self.cfg
    }

    pub fn model(&self) -> &ModelProfile {
        &self.model
    }

    pub fn platform(&self) -> &PlatformSpec {
        &self.platform
    }

    /// An artifact is only meaningful for the session that matches its
    /// embedded config; verify before acting on its plan.
    fn check_artifact(&self, artifact: &PlanArtifact) -> Result<()> {
        if artifact.config.model != self.cfg.model
            || artifact.config.platform != self.cfg.platform
        {
            bail!(
                "plan artifact is for {} on {}, but this session resolves {} on {}",
                artifact.config.model,
                artifact.config.platform,
                self.cfg.model,
                self.cfg.platform
            );
        }
        // full-config equality: merge/batch/sync/chunking drift changes
        // what the plan's cuts and tiers mean, so acting on the artifact
        // under a different config would silently compute the wrong
        // session (per-run deltas belong in TrainOverrides). The
        // scenario lens (`scenario`/`seed`) is normalized away first:
        // it changes how a simulation is *perturbed*, never what the
        // plan means, so one artifact can be simulated under many
        // scenarios (`simulate --plan p.json --scenario straggler`).
        let mut theirs = artifact.config.clone();
        theirs.scenario = self.cfg.scenario.clone();
        theirs.seed = self.cfg.seed;
        if theirs != self.cfg {
            bail!(
                "plan artifact's embedded config differs from this \
                 session's config; rebuild the session with \
                 Experiment::from_artifact or re-run `plan`"
            );
        }
        if artifact.plan.n_micro_global != self.cfg.n_micro_global() {
            bail!(
                "plan artifact covers {} micro-batches but the config's \
                 batch layout gives {}",
                artifact.plan.n_micro_global,
                self.cfg.n_micro_global()
            );
        }
        artifact
            .plan
            .validate(&self.model, &self.platform)
            .context("plan artifact incompatible with the resolved model/platform")?;
        Ok(())
    }

    /// The session's closed-form performance model: the config's sync
    /// algorithm and chunking policy, over the resolved model/platform.
    /// Every plan strategy (and every racing thread) reads this one
    /// model, so its [`StageCache`](crate::planner::StageCache) warms
    /// once per session.
    pub fn perf_model(&self) -> PerfModel<'_> {
        PerfModel::new(&self.model, &self.platform)
            .with_sync(self.cfg.sync_alg)
            .with_chunk_bytes(self.cfg.chunk_bytes)
    }

    /// The default [`PlanRequest`] this session's config describes:
    /// batch layout, weight sweep and dp options from the config,
    /// default budgets, no robustness. Callers layer request-only
    /// options (robust spec, budgets) on top before solving.
    pub fn plan_request(&self) -> PlanRequest {
        let mut req = PlanRequest::new(self.cfg.n_micro_global());
        req.weights = self.cfg.weights.clone();
        req.dp_options = self.cfg.dp_options.clone();
        req
    }

    fn plan_point(
        &self,
        cand: &PlanCandidate,
        strategy: &str,
        recommended: bool,
        on_frontier: bool,
    ) -> PlanPoint {
        PlanPoint {
            describe: cand.plan.describe(&self.model, &self.platform),
            artifact: PlanArtifact::new(
                self.cfg.clone(),
                cand.plan.clone(),
                cand.weights,
                cand.perf.t_iter,
                cand.perf.c_iter,
                strategy,
            ),
            perf: cand.perf.clone(),
            recommended,
            on_frontier,
            robust: cand.robust,
            slo: cand.slo,
        }
    }

    fn report_from_outcome(&self, outcome: &PlanOutcome) -> PlanReport {
        let flags = outcome.frontier_flags();
        let rec = outcome.recommend_idx();
        let points = outcome
            .candidates
            .iter()
            .enumerate()
            .map(|(i, cand)| {
                self.plan_point(
                    cand,
                    &outcome.strategy,
                    rec == Some(i),
                    flags[i],
                )
            })
            .collect();
        PlanReport {
            model: self.cfg.model.clone(),
            platform: self.cfg.platform.clone(),
            global_batch: self.cfg.global_batch,
            strategy: outcome.strategy.clone(),
            robust: outcome.robust.clone(),
            slo: outcome.slo.clone(),
            points,
        }
    }

    /// Co-optimize partition + resources over the config's weight sweep
    /// (§3.4) with the default `bnb` strategy. Returns every candidate
    /// with the Pareto frontier flagged and the paper's δ ≥ 0.8
    /// recommendation marked; each point carries a deployable
    /// [`PlanArtifact`].
    pub fn plan(&self) -> Result<PlanReport> {
        self.plan_with(DEFAULT_STRATEGY, &self.plan_request())
    }

    /// Like [`Experiment::plan`] but with an explicit registry strategy
    /// (`bnb`, `miqp`, `bayes`, `tpdmp`, `sweep`) and a caller-shaped
    /// request (robust spec, budgets, dp/weight overrides).
    pub fn plan_with(
        &self,
        strategy: &str,
        req: &PlanRequest,
    ) -> Result<PlanReport> {
        let perf = self.perf_model();
        let outcome = solve_request(strategy, &perf, req)?;
        Ok(self.report_from_outcome(&outcome))
    }

    /// Race EVERY registry strategy in parallel threads over one shared
    /// perf model (`plan --strategy all`): per-strategy rows plus the
    /// pooled δ ≥ 0.8 winner across all candidates, credited to the
    /// strategy that found it first (registry order breaks ties, so the
    /// report is deterministic).
    pub fn plan_race(&self, req: &PlanRequest) -> Result<PlanCompareReport> {
        let perf = self.perf_model();
        let outcomes = race(&perf, req, &STRATEGIES)?;

        // pool all candidates (deduped across strategies, registry
        // order) and recommend over the pooled frontier; the hashed
        // [`PlanKey`] makes the dedup O(1) per candidate instead of a
        // linear scan with full plan comparisons
        let rank = req.robust.as_ref().map(|r| r.rank);
        let mut seen = std::collections::HashSet::new();
        let mut pooled: Vec<(usize, &PlanCandidate)> = Vec::new();
        for (si, out) in outcomes.iter().enumerate() {
            for cand in &out.candidates {
                if seen.insert(PlanKey::of(&cand.plan)) {
                    pooled.push((si, cand));
                }
            }
        }
        let metrics: Vec<(f64, f64)> =
            pooled.iter().map(|(_, c)| c.metric(rank)).collect();
        let flags = crate::planner::pareto_flags(&metrics);
        let front: Vec<usize> = flags
            .iter()
            .enumerate()
            .filter(|(_, f)| **f)
            .map(|(i, _)| i)
            .collect();
        let winner = crate::planner::recommend_among(&metrics, &front).map(
            |i| {
                let (si, cand) = pooled[i];
                self.plan_point(cand, &outcomes[si].strategy, true, true)
            },
        );

        let rows = outcomes
            .iter()
            .map(|out| {
                let rec = out.recommend_idx().map(|i| {
                    self.plan_point(
                        &out.candidates[i],
                        &out.strategy,
                        true,
                        true,
                    )
                });
                StrategyRow {
                    strategy: out.strategy.clone(),
                    candidates: out.candidates.len(),
                    frontier: out.frontier().len(),
                    recommended: rec,
                }
            })
            .collect();
        Ok(PlanCompareReport {
            model: self.cfg.model.clone(),
            platform: self.cfg.platform.clone(),
            global_batch: self.cfg.global_batch,
            robust: req.robust.clone(),
            slo: req.slo.clone(),
            rows,
            winner,
        })
    }

    /// Cross-check a plan: closed-form perf model (§3.4.2) vs the
    /// discrete-event simulator, both using this session's sync
    /// algorithm. The chunking policy is priced only on the model side
    /// (the DES executes the unchunked flow schedule — same byte
    /// volume, no per-chunk latency term), so with `chunk_bytes > 0`
    /// the reported error includes the priced chunk overhead, not pure
    /// model error.
    ///
    /// When the config selects a [`ScenarioModel`] other than
    /// `deterministic`, the report additionally carries a second DES
    /// pass with the seeded perturbation applied (cold starts,
    /// stragglers, bandwidth jitter) — the scenario-lab columns. Both
    /// passes are deterministic functions of (artifact, scenario,
    /// seed): the same inputs always yield the bit-identical report
    /// (the `plan --out` → `simulate --plan` equivalence and the
    /// replay test pin this down).
    ///
    /// [`ScenarioModel`]: crate::simcore::ScenarioModel
    pub fn simulate(&self, artifact: &PlanArtifact) -> Result<SimReport> {
        self.check_artifact(artifact)?;
        let predicted = PerfModel::new(&self.model, &self.platform)
            .with_sync(self.cfg.sync_alg)
            .with_chunk_bytes(self.cfg.chunk_bytes)
            .evaluate(&artifact.plan);
        let sim = simulate_iteration(
            &self.model,
            &self.platform,
            &artifact.plan,
            self.cfg.sync_alg,
        );
        let scenario_sim = (!self.cfg.scenario.is_deterministic()).then(|| {
            simulate_iteration_scenario(
                &self.model,
                &self.platform,
                &artifact.plan,
                self.cfg.sync_alg,
                &self.cfg.scenario,
                self.cfg.seed,
            )
        });
        Ok(SimReport {
            describe: artifact.plan.describe(&self.model, &self.platform),
            plan: artifact.plan.clone(),
            predicted,
            sim,
            scenario: self.cfg.scenario.clone(),
            seed: self.cfg.seed,
            scenario_sim,
        })
    }

    /// Derive the trainer configuration: unified config → plan-supplied
    /// `dp`/`mu` → explicit overrides, in that precedence order. Public
    /// so tests (and curious users) can inspect the derivation without
    /// running a training job.
    pub fn train_config(
        &self,
        artifact: Option<&PlanArtifact>,
        overrides: &TrainOverrides,
    ) -> Result<trainer::TrainConfig> {
        if let Some(a) = artifact {
            self.check_artifact(a)?;
        }
        let cfg = &self.cfg;
        let mut tc = trainer::TrainConfig::new(cfg.artifacts_dir.clone());
        tc.steps = cfg.steps;
        tc.lr = cfg.lr as f32;
        tc.lifetime_s = cfg.lifetime_s;
        tc.throttle = cfg.throttle;
        tc.sync_alg = cfg.sync_alg;
        tc.chunking = cfg.chunking();
        // scenario lens: the trainer's Injector draws from the same
        // seeded streams the simulator applies, and the function
        // lifecycle runs on the deterministic virtual clock so a
        // scenario run replays bit-identically — each tick is the
        // plan's predicted t_iter (a unit tick with no plan),
        // lens-stretched per worker.
        tc.scenario = cfg.scenario.clone();
        tc.scenario_seed = cfg.seed;
        if !cfg.scenario.is_deterministic() {
            tc.virtual_iter_s = Some(
                artifact
                    .map(|a| a.predicted_t_iter)
                    .filter(|t| t.is_finite() && *t > 0.0)
                    .unwrap_or(1.0),
            );
        }
        // the Function Manager charges the platform tier's cold start
        // (the restart path's historical hardcoded 10 ms); with a plan,
        // the slowest (largest) stage tier is the conservative charge
        tc.cold_start_s = match artifact {
            Some(a) => a
                .plan
                .stage_tiers
                .iter()
                .map(|&t| self.platform.tier(t).cold_start_s)
                .fold(self.platform.cold_start_s, f64::max),
            None => self.platform.cold_start_s,
        };
        if let Some(a) = artifact {
            tc.dp = a.plan.dp;
            tc.mu = a.plan.mu();
        }
        if let Some(d) = overrides.dp {
            tc.dp = d;
        }
        if let Some(m) = overrides.mu {
            tc.mu = m;
        }
        if let Some(s) = overrides.steps {
            tc.steps = s;
        }
        if let Some(lr) = overrides.lr {
            tc.lr = lr as f32;
        }
        if let Some(l) = overrides.lifetime_s {
            tc.lifetime_s = l;
        }
        if overrides.chunk_bytes.is_some() || overrides.chunks_in_flight.is_some()
        {
            tc.chunking = Chunking::new(
                overrides.chunk_bytes.unwrap_or(cfg.chunk_bytes),
                overrides.chunks_in_flight.unwrap_or(cfg.chunks_in_flight),
            );
        }
        if let Some(dir) = &overrides.artifacts_dir {
            tc.artifacts_dir = std::path::PathBuf::from(dir);
        }
        ensure!(
            tc.dp >= 1 && tc.mu >= 1 && tc.steps >= 1,
            "dp, mu and steps must be positive"
        );
        // overrides bypass ExperimentConfig::validate, so re-check the
        // float knobs here (NaN lr fails the > 0 comparison)
        ensure!(
            tc.lr.is_finite() && tc.lr > 0.0,
            "lr must be a positive finite number"
        );
        ensure!(
            !tc.lifetime_s.is_nan() && tc.lifetime_s > 0.0,
            "lifetime_s must be positive"
        );
        Ok(tc)
    }

    /// Real end-to-end training over the AOT artifacts, driven by the
    /// plan (when given) instead of hand-derived `--dp`/`--mu`.
    pub fn train(
        &self,
        artifact: Option<&PlanArtifact>,
        overrides: &TrainOverrides,
    ) -> Result<TrainReport> {
        let tc = self.train_config(artifact, overrides)?;
        let raw = trainer::train(&tc)?;
        Ok(TrainReport::from_raw(&tc, raw))
    }

    /// Re-plan under a measured observation ring: project the observed
    /// per-stage multipliers onto the planner's layer axis and race the
    /// whole strategy registry over the overlaid perf model. The
    /// returned artifact records `replan:<strategy>` provenance.
    pub fn replan(&self, obs: &StageObservations) -> Result<PlanArtifact> {
        let profile =
            MeasuredProfile::from_observations(obs, self.model.n_layers(), 1);
        self.replan_measured(&profile)
    }

    /// Like [`Experiment::replan`] but from an explicit
    /// [`MeasuredProfile`] overlay (library callers that build their own
    /// measurements).
    pub fn replan_measured(
        &self,
        profile: &MeasuredProfile,
    ) -> Result<PlanArtifact> {
        let perf = self.perf_model().with_overlay(profile.clone());
        let outcomes = race(&perf, &self.plan_request(), &STRATEGIES)?;
        let (strategy, cand) = best_candidate(&outcomes).context(
            "re-planning found no feasible plan under the measured profile",
        )?;
        Ok(PlanArtifact::new(
            self.cfg.clone(),
            cand.plan.clone(),
            cand.weights,
            cand.perf.t_iter,
            cand.perf.c_iter,
            format!("replan:{strategy}"),
        ))
    }

    /// The plan the drift is measured against. A planless scenario run
    /// ticks at the unit rate over the manifest's 1:1 staging, so its
    /// equivalent plan is an even partition of the planner layers into
    /// (up to) one stage per runtime layer at the top tier — the same
    /// shape the trainer actually executes.
    fn equivalent_plan(
        &self,
        artifact: Option<&PlanArtifact>,
        n_rt: usize,
        dp: usize,
    ) -> Plan {
        if let Some(a) = artifact {
            return a.plan.clone();
        }
        let lp = self.model.n_layers();
        let groups = even_groups(lp, n_rt.min(lp));
        let cuts = groups[..groups.len() - 1]
            .iter()
            .map(|&(_, hi)| hi - 1)
            .collect();
        Plan {
            cuts,
            dp,
            stage_tiers: vec![self.platform.max_tier(); groups.len()],
            n_micro_global: self.cfg.n_micro_global(),
        }
    }

    /// Elastic training: run on the virtual clock, detect sustained
    /// drift between the observed and predicted iteration time, and —
    /// when a measured re-plan wins back its migration cost over the
    /// remaining steps — migrate to the new plan at a function-
    /// generation boundary (quiesce, layer-addressed checkpoint,
    /// re-partition, restore, continue). The detector RE-ARMS after
    /// every adopted migration, so one run can chain g0 → g1 → g2 …
    /// up to `spec.max_replans` migrations (`--replan-max`, default 4).
    /// Every re-plan decision is recorded in the report, adopted or not;
    /// a rejected re-plan ends the chain (the run stays on its current
    /// plan — re-triggering on the same sustained drift would just
    /// re-reject).
    ///
    /// The whole decision chain is a pure function of `(config,
    /// artifact, scenario, seed, spec)`: the observations the detector
    /// consumes are the deterministic lens draws (static per-worker
    /// draws plus the per-step time-varying stretch of lenses like
    /// `bandwidth-decay` and `cold-start-storm`), so every trigger step
    /// and adoption verdict is computed *before* any training runs and
    /// the same invocation replays byte-identically. Under purely
    /// static lenses a chain terminates after one migration: the
    /// calibrated tick subsumes the static stretch, so generation ≥ 1
    /// only drifts when a time-varying lens keeps stretching.
    pub fn train_replan(
        &self,
        artifact: Option<&PlanArtifact>,
        overrides: &TrainOverrides,
        spec: &ReplanSpec,
    ) -> Result<TrainReport> {
        spec.validate()?;
        let tc0 = self.train_config(artifact, overrides)?;
        if tc0.scenario.is_deterministic() {
            bail!(
                "--replan has no effect without a scenario lens: the \
                 deterministic virtual-clock run matches the prediction \
                 exactly, so drift can never trigger (pass --scenario)"
            );
        }
        let base0 = tc0
            .virtual_iter_s
            .context("scenario runs tick on the virtual clock")?;
        let manifest = crate::runtime::Manifest::load(&tc0.artifacts_dir)?;
        let n_rt = manifest.n_stages;
        let total = tc0.steps;

        // Per-generation state. `g_base` is the prediction drift is
        // measured against: the plan's tick for generation 0, the
        // calibrated tick afterwards (which already subsumes the static
        // lens stretch — only time-varying drift can re-trigger).
        let mut g_groups = identity_groups(n_rt);
        let mut g_n_groups = n_rt;
        let mut g_dp = tc0.dp;
        let mut g_mu = tc0.mu;
        let mut g_base = base0;
        let mut g_tick = base0; // trainer tick of the current generation
        let mut g_cold = tc0.cold_start_s;
        let mut g_plan = self.equivalent_plan(artifact, n_rt, tc0.dp);
        let mut step = 0usize;
        let mut adopted_count = 0usize;
        let mut events: Vec<ReplanEvent> = Vec::new();
        let mut segments: Vec<trainer::TrainConfig> = Vec::new();

        // Build one trainer segment covering [start, end) on the
        // current generation; generation 0 keeps tc0's shape and
        // records the observation ring, later generations run on the
        // calibrated tick.
        let seg = |start: usize,
                   end: usize,
                   migrate_out: bool,
                   gen: usize,
                   groups: &[(usize, usize)],
                   dp: usize,
                   mu: usize,
                   tick: f64,
                   cold: f64,
                   tc0: &trainer::TrainConfig,
                   window: usize| {
            let mut tc = tc0.clone();
            tc.steps = end - start;
            tc.step_offset = start;
            tc.migrate_out = migrate_out;
            if gen == 0 {
                tc.observe = Some(window);
            } else {
                tc.dp = dp;
                tc.mu = mu;
                tc.plan_generation = gen as u64;
                tc.layer_groups = groups.to_vec();
                tc.calibrated_tick = true;
                tc.virtual_iter_s = Some(tick);
                tc.cold_start_s = cold;
                tc.observe = None;
            }
            tc
        };

        loop {
            // Drift pre-pass for the current generation: the
            // observations are the same pure function of the injector
            // the coordinator records, so the trigger step falls out
            // without running a single training step. A fresh detector
            // per generation is what re-arms the chain.
            let n_workers = g_n_groups * g_dp;
            let injector = crate::scenario::Injector::new(
                &tc0.scenario,
                tc0.scenario_seed,
                n_workers,
            );
            let mut obs = StageObservations::new(
                g_groups.clone(),
                n_rt,
                spec.window,
                g_base,
            );
            let mut detector = DriftDetector::new(spec);
            let mut trigger_step = None;
            for s in step..total {
                let (tv_mult, extra_s) =
                    injector.step_stretch(0, n_workers, s);
                if adopted_count == 0 {
                    let (stage_obs, gated, bw) =
                        observe_step(&injector, obs.groups(), g_dp, g_base);
                    obs.push_step(stage_obs, gated * tv_mult + extra_s, bw);
                } else {
                    // generation ≥ 1: the calibrated tick subsumes the
                    // static draws; only the time-varying stretch is
                    // observed, attributed uniformly across stages
                    let t = (g_base * tv_mult + extra_s)
                        / g_n_groups.max(1) as f64;
                    let stage_obs = (0..g_n_groups)
                        .map(|_| StageObs {
                            fwd_s: t / 3.0,
                            bwd_s: 2.0 * t / 3.0,
                            sync_s: 0.0,
                        })
                        .collect();
                    obs.push_step(stage_obs, g_base * tv_mult + extra_s, 1.0);
                }
                if detector.observe(obs.ewma_iter_s(), g_base) {
                    trigger_step = Some(s);
                    break;
                }
            }
            let Some(trigger) = trigger_step else {
                // no sustained drift: the generation runs to completion
                segments.push(seg(
                    step, total, false, adopted_count, &g_groups, g_dp,
                    g_mu, g_tick, g_cold, &tc0, spec.window,
                ));
                break;
            };

            // Re-plan under the measured overlay and calibrate the new
            // tick against the observed pace: tick' = pace × t̂(new)/
            // t̂(old), where t̂ is the overlay-evaluated model and pace
            // is the EWMA at the trigger (under static lenses exactly
            // the lens-stretched tick) — the lens stretch is subsumed
            // by the measured multipliers, so the ratio transfers the
            // observation onto the new plan.
            let pace = obs.ewma_iter_s();
            let profile = MeasuredProfile::from_observations(
                &obs,
                self.model.n_layers(),
                adopted_count as u64 + 1,
            );
            let perf = self.perf_model().with_overlay(profile.clone());
            let t_old = perf.evaluate(&g_plan).t_iter;
            ensure!(
                t_old.is_finite() && t_old > 0.0,
                "overlay evaluation of the running plan degenerated ({t_old})"
            );
            let outcomes = race(&perf, &self.plan_request(), &STRATEGIES)?;
            let (strategy, cand) = best_candidate(&outcomes).context(
                "re-planning found no feasible plan under the measured profile",
            )?;
            let plan1 = cand.plan.clone();
            let tick1 = pace * (cand.perf.t_iter / t_old);
            ensure!(
                tick1.is_finite() && tick1 > 0.0,
                "calibrated re-plan tick degenerated ({tick1})"
            );

            // Migration cost: the new generation's workers all
            // cold-start (worst worker gates, same virtual-clock
            // arithmetic the trainer charges).
            let n_groups1 = plan1.n_stages().min(n_rt);
            let (dp1, mu1) = (plan1.dp, plan1.mu());
            let cold1 = plan1
                .stage_tiers
                .iter()
                .map(|&t| self.platform.tier(t).cold_start_s)
                .fold(self.platform.cold_start_s, f64::max);
            let injector1 = crate::scenario::Injector::new(
                &tc0.scenario,
                tc0.scenario_seed,
                n_groups1 * dp1,
            );
            let migration_s = (0..n_groups1 * dp1)
                .map(|w| {
                    injector1.cold_start_s(w, adopted_count as u32, cold1)
                })
                .fold(0.0, f64::max);

            let boundary = trigger + 1;
            let rem = total - boundary;
            let adopted =
                tick1 * rem as f64 + migration_s < pace * rem as f64;
            events.push(ReplanEvent {
                trigger_step: trigger,
                observed_iter_s: pace,
                predicted_iter_s: g_base,
                stage_mults: obs.stage_mults(),
                old_stages: g_n_groups,
                old_dp: g_dp,
                old_mu: g_mu,
                new_stages: n_groups1,
                new_dp: dp1,
                new_mu: mu1,
                strategy: strategy.to_string(),
                new_iter_s: tick1,
                migration_s,
                adopted,
            });

            if !adopted {
                // the decision is recorded but the chain ends — wall
                // clock identical to the generation running statically
                segments.push(seg(
                    step, total, false, adopted_count, &g_groups, g_dp,
                    g_mu, g_tick, g_cold, &tc0, spec.window,
                ));
                break;
            }

            // Adopted: the current generation quiesces at the boundary
            // into layer-addressed migration shards; the next one
            // restores them and continues on the calibrated tick.
            segments.push(seg(
                step, boundary, true, adopted_count, &g_groups, g_dp,
                g_mu, g_tick, g_cold, &tc0, spec.window,
            ));
            adopted_count += 1;
            step = boundary;
            g_plan = plan1;
            g_groups = even_groups(n_rt, n_groups1);
            g_n_groups = n_groups1;
            g_dp = dp1;
            g_mu = mu1;
            g_base = tick1;
            g_tick = tick1;
            g_cold = cold1;
            if adopted_count >= spec.max_replans {
                // cap reached: the final generation runs out the
                // remaining steps un-observed
                segments.push(seg(
                    step, total, false, adopted_count, &g_groups, g_dp,
                    g_mu, g_tick, g_cold, &tc0, spec.window,
                ));
                break;
            }
        }

        // Execute the segments: a single segment is a plain (observed)
        // run; a chain shares one store so the layer-addressed shards
        // carry the parameters across every migration boundary.
        let raw = if segments.len() == 1 {
            trainer::train(&segments[0])?
        } else {
            let store = Arc::new(MemStore::new());
            let mut raw =
                trainer::train_with_store(&segments[0], store.clone())?;
            for tc in &segments[1..] {
                let raw_b = trainer::train_with_store(tc, store.clone())?;
                raw.logs.extend(raw_b.logs);
                raw.restarts += raw_b.restarts;
                raw.wall_s += raw_b.wall_s;
                raw.workers.extend(raw_b.workers);
            }
            raw.store_put_gets = store.stats();
            raw
        };
        let mut report = TrainReport::from_raw(&tc0, raw);
        report.replan_enabled = true;
        report.replan = events;
        Ok(report)
    }

    /// Evaluate the §5.1 baselines on this session's (unmerged) model.
    /// The parameter-server VM matches the platform, as in the paper
    /// (c5.9xlarge on AWS, r7.2xlarge on Alibaba, §5.7).
    pub fn baselines(&self) -> Result<BaselineReport> {
        let zoo_m = zoo::by_name(&self.cfg.model, &self.platform)
            .with_context(|| format!("unknown model {:?}", self.cfg.model))?;
        let vm = if self.platform.name == "alibaba-fc" {
            R7_2XLARGE
        } else {
            C5_9XLARGE
        };
        let rows = BaselineKind::ALL
            .iter()
            .map(|&kind| {
                let result = evaluate_baseline(
                    kind,
                    &zoo_m,
                    &self.platform,
                    self.cfg.global_batch,
                    vm,
                );
                let mem_mb =
                    result.as_ref().map(|r| self.platform.tier(r.tier).mem_mb);
                BaselineRow { name: kind.name(), mem_mb, result }
            })
            .collect();
        Ok(BaselineReport {
            model: self.cfg.model.clone(),
            platform: self.cfg.platform.clone(),
            global_batch: self.cfg.global_batch,
            rows,
        })
    }

    /// Replay a frozen plan as a pipelined serving deployment: stages
    /// execute forward-only behind per-stage autoscaled function pools,
    /// driven by the seeded arrival trace in `opts.traffic`. The replay
    /// is a deterministic function of (artifact, options) — the same
    /// inputs always render the byte-identical [`ServeReport`] (the
    /// serve replay test and a CI `cmp` pin this). Note the plan's `dp`
    /// is a *training* knob and is ignored here: replication is owned
    /// by the autoscaler, while `μ` caps the serving micro-batch.
    pub fn serve(
        &self,
        artifact: &PlanArtifact,
        opts: &ServeOptions,
    ) -> Result<ServeReport> {
        self.check_artifact(artifact)?;
        let perf = self.perf_model();
        let outcome = serve_plan(&perf, &artifact.plan, opts)?;
        Ok(ServeReport {
            model: self.cfg.model.clone(),
            platform: self.cfg.platform.clone(),
            traffic: opts.traffic.name(),
            seed: opts.seed,
            scenario: opts.scenario.name(),
            duration_s: opts.duration_s,
            batch_window_s: opts.batch_window_s,
            idle_timeout_s: opts.idle_timeout_s,
            max_instances: opts.max_instances,
            batch_cap: artifact.plan.mu().max(1),
            outcome,
        })
    }

    /// Run a multi-tenant fleet: every tenant's frozen plan (training
    /// jobs and serving deployments alike) executes against ONE shared
    /// simulated platform on a single virtual clock — FIFO admission
    /// against `max_concurrency`, cross-tenant storage-bandwidth
    /// contention, per-tenant cost/throughput accounting. Associated
    /// function rather than a method: each tenant carries its own
    /// embedded session config, which [`fleet::run`](crate::fleet::run)
    /// re-resolves per tenant (the platform must agree across tenants).
    /// The run is a pure function of `(spec, scenario, seed)` and the
    /// report renders byte-identically across sessions
    /// (`tests/fleet_replay.rs` and a CI `cmp` pin this).
    pub fn fleet(
        spec: &crate::fleet::FleetSpec,
        scenario: &crate::simcore::ScenarioSpec,
        seed: u64,
    ) -> Result<FleetReport> {
        let outcome = crate::fleet::run(spec, scenario, seed)?;
        Ok(FleetReport { outcome })
    }

    /// Profile the AOT stages through PJRT (§3.1 step 3). When the
    /// session config carries a scenario, the measured times are viewed
    /// through the same seeded [`WorkerLens`](crate::scenario::WorkerLens)
    /// draws the simulator and trainer apply — stage *i* through worker
    /// *i*'s compute multiplier — so a profile taken under
    /// `--scenario straggler --seed 7` predicts exactly the stage times
    /// that `train` will exhibit under that lens.
    pub fn profile(&self, reps: usize) -> Result<ProfileReport> {
        let prof = crate::profiler::profile_stages(
            Path::new(&self.cfg.artifacts_dir),
            &self.platform,
            reps,
        )?;
        let top = self.platform.max_tier();
        let injector = crate::scenario::Injector::new(
            &self.cfg.scenario,
            self.cfg.seed,
            prof.layers.len(),
        );
        Ok(ProfileReport {
            scenario: self.cfg.scenario.name(),
            rows: prof
                .layers
                .iter()
                .enumerate()
                .map(|(i, l)| {
                    let m = injector.worker(i).compute_mult;
                    ProfileRow {
                        name: l.name.clone(),
                        param_bytes: l.param_bytes,
                        fwd_s: l.fwd_s[top] * m,
                        bwd_s: l.bwd_s[top] * m,
                        compute_mult: m,
                    }
                })
                .collect(),
        })
    }
}

/// The fastest deduped candidate across every strategy's outcome:
/// minimal `t_iter` (tie: minimal `c_iter`; further ties keep the first
/// finder in registry order, so the pick is deterministic).
fn best_candidate(
    outcomes: &[PlanOutcome],
) -> Option<(&str, &PlanCandidate)> {
    let mut seen = std::collections::HashSet::new();
    let mut best: Option<(&str, &PlanCandidate)> = None;
    for out in outcomes {
        for cand in &out.candidates {
            if !seen.insert(PlanKey::of(&cand.plan)) {
                continue;
            }
            let better = match best {
                None => true,
                Some((_, b)) => {
                    cand.perf.t_iter < b.perf.t_iter
                        || (cand.perf.t_iter == b.perf.t_iter
                            && cand.perf.c_iter < b.perf.c_iter)
                }
            };
            if better {
                best = Some((out.strategy.as_str(), cand));
            }
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg() -> ExperimentConfig {
        ExperimentConfig {
            model: "resnet101".into(),
            global_batch: 16,
            merge_layers: 4,
            ..ExperimentConfig::default()
        }
    }

    #[test]
    fn profile_applies_scenario_lens() {
        let mut cfg = small_cfg();
        cfg.artifacts_dir = crate::runtime::BUILTIN_TINY.into();
        let base = Experiment::new(cfg.clone()).unwrap().profile(1).unwrap();
        assert_eq!(base.scenario, "deterministic");
        assert!(base.rows.iter().all(|r| r.compute_mult == 1.0));
        assert!(base.rows.iter().all(|r| r.fwd_s > 0.0 && r.bwd_s > 0.0));

        // pick a seed whose straggler draws actually perturb one of the
        // builtin stages (each worker straggles with probability 0.2,
        // so some seeds draw an all-identity lens)
        let spec = crate::simcore::ScenarioSpec::parse("straggler").unwrap();
        let n = base.rows.len();
        let seed = (0u64..64)
            .find(|&s| {
                let inj = crate::scenario::Injector::new(&spec, s, n);
                (0..n).any(|w| inj.worker(w).compute_mult > 1.0)
            })
            .expect("some seed under 64 draws a straggler");
        cfg.scenario = spec.clone();
        cfg.seed = seed;
        let lensed = Experiment::new(cfg).unwrap().profile(1).unwrap();
        assert_eq!(lensed.scenario, "straggler");
        // the straggler lens only slows workers down, and slows at least
        // one stage measurably
        assert!(lensed.rows.iter().all(|r| r.compute_mult >= 1.0));
        assert!(
            lensed.rows.iter().any(|r| r.compute_mult > 1.0),
            "{lensed:?}"
        );
        // the multipliers are the injector's own draws for this seed
        let inj =
            crate::scenario::Injector::new(&spec, seed, lensed.rows.len());
        for (i, r) in lensed.rows.iter().enumerate() {
            assert_eq!(
                r.compute_mult.to_bits(),
                inj.worker(i).compute_mult.to_bits()
            );
        }
    }

    #[test]
    fn plan_marks_exactly_one_recommendation() {
        let exp = Experiment::new(small_cfg()).unwrap();
        let report = exp.plan().unwrap();
        assert!(!report.points.is_empty());
        assert_eq!(
            report.points.iter().filter(|p| p.recommended).count(),
            1,
            "{report:?}"
        );
        let rec = report.recommended().unwrap();
        rec.artifact
            .plan
            .validate(exp.model(), exp.platform())
            .unwrap();
    }

    #[test]
    fn simulate_rejects_foreign_artifacts() {
        let exp = Experiment::new(small_cfg()).unwrap();
        let report = exp.plan().unwrap();
        let mut artifact = report.recommended().unwrap().artifact.clone();
        artifact.config.model = "bert-large".into();
        assert!(exp.simulate(&artifact).is_err());

        // any embedded-config drift is rejected, not just model/platform
        let mut drifted = report.recommended().unwrap().artifact.clone();
        drifted.config.merge_layers += 1;
        assert!(exp.simulate(&drifted).is_err());
    }

    #[test]
    fn train_config_precedence_config_plan_overrides() {
        let exp = Experiment::new(small_cfg()).unwrap();
        let rec = exp.plan().unwrap().recommended().unwrap().clone();

        // no plan, no overrides: unified-config defaults
        let tc = exp
            .train_config(None, &TrainOverrides::default())
            .unwrap();
        assert_eq!(tc.steps, exp.config().steps);
        assert_eq!((tc.dp, tc.mu), (1, 2));

        // plan supplies dp/mu
        let tc = exp
            .train_config(Some(&rec.artifact), &TrainOverrides::default())
            .unwrap();
        assert_eq!(tc.dp, rec.artifact.plan.dp);
        assert_eq!(tc.mu, rec.artifact.plan.mu());
        assert_eq!(tc.sync_alg, exp.config().sync_alg);

        // explicit overrides beat the plan
        let ov = TrainOverrides {
            dp: Some(1),
            mu: Some(1),
            steps: Some(3),
            chunk_bytes: Some(4096),
            ..TrainOverrides::default()
        };
        let tc = exp.train_config(Some(&rec.artifact), &ov).unwrap();
        assert_eq!((tc.dp, tc.mu, tc.steps), (1, 1, 3));
        assert_eq!(tc.chunking.chunk_bytes, 4096);
        assert_eq!(
            tc.chunking.in_flight,
            exp.config().chunks_in_flight
        );

        // overrides cannot smuggle in values the config path rejects
        let bad =
            TrainOverrides { lifetime_s: Some(0.0), ..Default::default() };
        assert!(exp.train_config(None, &bad).is_err());
        let bad = TrainOverrides { lr: Some(-1.0), ..Default::default() };
        assert!(exp.train_config(None, &bad).is_err());
        let bad = TrainOverrides { lr: Some(f64::NAN), ..Default::default() };
        assert!(exp.train_config(None, &bad).is_err());
    }

    #[test]
    fn train_config_carries_the_scenario_lens() {
        use crate::simcore::ScenarioSpec;
        let mut cfg = small_cfg();
        cfg.scenario = ScenarioSpec::parse("straggler").unwrap();
        cfg.seed = 7;
        let exp = Experiment::new(cfg).unwrap();
        let rec = exp.plan().unwrap().recommended().unwrap().clone();
        let tc = exp
            .train_config(Some(&rec.artifact), &TrainOverrides::default())
            .unwrap();
        assert_eq!(tc.scenario.name(), "straggler");
        assert_eq!(tc.scenario_seed, 7);
        // scenario active ⇒ deterministic virtual lifecycle, ticking at
        // the plan's predicted iteration time
        assert_eq!(tc.virtual_iter_s, Some(rec.artifact.predicted_t_iter));
        // the cold start is the platform tier's, not a hardcoded number
        assert!(
            (tc.cold_start_s - exp.platform().cold_start_s).abs() < 1e-12
        );
        // planless scenario sessions tick at the documented unit rate
        let tc = exp.train_config(None, &TrainOverrides::default()).unwrap();
        assert_eq!(tc.virtual_iter_s, Some(1.0));
        // deterministic sessions keep the wall-clock lifecycle
        let det = Experiment::new(small_cfg()).unwrap();
        let tc = det.train_config(None, &TrainOverrides::default()).unwrap();
        assert!(tc.scenario.is_deterministic());
        assert_eq!(tc.virtual_iter_s, None);
    }

    #[test]
    fn baselines_report_all_kinds() {
        let exp = Experiment::new(small_cfg()).unwrap();
        let report = exp.baselines().unwrap();
        assert_eq!(report.rows.len(), BaselineKind::ALL.len());
    }

    #[test]
    fn every_strategy_plans_through_the_one_api() {
        let exp = Experiment::new(small_cfg()).unwrap();
        let req = exp.plan_request();
        for name in STRATEGIES {
            let report = exp.plan_with(name, &req).unwrap();
            assert_eq!(report.strategy, name);
            assert!(!report.points.is_empty(), "{name}");
            assert_eq!(
                report.points.iter().filter(|p| p.recommended).count(),
                1,
                "{name}"
            );
            let rec = report.recommended().unwrap();
            assert!(rec.on_frontier, "{name}: recommendation off frontier");
            // provenance travels in the artifact
            assert_eq!(rec.artifact.strategy, name);
            rec.artifact
                .plan
                .validate(exp.model(), exp.platform())
                .unwrap();
        }
        assert!(exp.plan_with("chaos", &req).is_err());
    }

    #[test]
    fn default_plan_is_the_bnb_strategy() {
        let exp = Experiment::new(small_cfg()).unwrap();
        let a = exp.plan().unwrap();
        let b = exp.plan_with(DEFAULT_STRATEGY, &exp.plan_request()).unwrap();
        assert_eq!(a.strategy, DEFAULT_STRATEGY);
        assert_eq!(a.points.len(), b.points.len());
        for (pa, pb) in a.points.iter().zip(&b.points) {
            assert_eq!(pa.artifact.plan, pb.artifact.plan);
            assert_eq!(pa.recommended, pb.recommended);
        }
    }

    #[test]
    fn race_reports_every_strategy_and_a_winner() {
        let exp = Experiment::new(small_cfg()).unwrap();
        let report = exp.plan_race(&exp.plan_request()).unwrap();
        assert_eq!(report.rows.len(), STRATEGIES.len());
        for (row, name) in report.rows.iter().zip(STRATEGIES) {
            assert_eq!(row.strategy, name);
            assert!(row.candidates > 0, "{name} found nothing");
        }
        let winner = report.winner.as_ref().expect("pooled winner");
        assert!(STRATEGIES.contains(&winner.artifact.strategy.as_str()));
        // the race renders deterministically (the CI byte-compares it)
        let again = exp.plan_race(&exp.plan_request()).unwrap();
        assert_eq!(
            report.render(Format::Json),
            again.render(Format::Json),
            "race output drifted between runs"
        );
    }

    #[test]
    fn robust_request_flows_into_the_report() {
        use crate::planner::{RobustRank, RobustSpec};
        use crate::simcore::ScenarioSpec;
        let exp = Experiment::new(small_cfg()).unwrap();
        let mut req = exp.plan_request();
        req.robust = Some(RobustSpec {
            scenario: ScenarioSpec::parse("straggler+jitter").unwrap(),
            seeds: 4,
            rank: RobustRank::Worst,
        });
        let report = exp.plan_with("bnb", &req).unwrap();
        assert!(report.robust.is_some());
        for p in &report.points {
            let r = p.robust.expect("every point scored");
            assert!(r.worst_t.is_finite() && r.worst_t > 0.0);
        }
        // exactly one recommendation under the robust metric too
        assert_eq!(
            report.points.iter().filter(|p| p.recommended).count(),
            1
        );
        // and the JSON names the spec
        let json = report.render(Format::Json);
        assert!(json.contains("\"robust\""), "{json}");
        assert!(json.contains("cold-start") || json.contains("straggler"));
    }

    #[test]
    fn slo_request_flows_into_the_report() {
        use crate::planner::SloSpec;
        use crate::serve::TrafficSpec;
        let exp = Experiment::new(small_cfg()).unwrap();
        let mut req = exp.plan_request();
        req.slo = Some(SloSpec {
            p99_ms: 120_000.0,
            traffic: TrafficSpec::parse("poisson:300").unwrap(),
            seeds: 1,
        });
        let report = exp.plan_with("bnb", &req).unwrap();
        assert!(report.slo.is_some());
        for p in &report.points {
            let s = p.slo.expect("every point replay-scored");
            assert!(s.p99_ms.is_finite() && s.p99_ms > 0.0);
            assert!(s.cost_per_1k_usd > 0.0);
        }
        assert_eq!(
            report.points.iter().filter(|p| p.recommended).count(),
            1
        );
        // the JSON names the spec and scores every plan
        let json = report.render(Format::Json);
        assert!(json.contains("\"slo\""), "{json}");
        assert!(json.contains("poisson:300"), "{json}");
    }

    #[test]
    fn serve_replays_a_frozen_plan_through_the_session_api() {
        use crate::serve::TrafficSpec;
        let exp = Experiment::new(small_cfg()).unwrap();
        let rec = exp.plan().unwrap().recommended().unwrap().clone();
        let mut opts = ServeOptions::new(
            TrafficSpec::parse("poisson:600").unwrap(),
            7,
        );
        opts.duration_s = 10.0;
        let a = exp.serve(&rec.artifact, &opts).unwrap();
        let b = exp.serve(&rec.artifact, &opts).unwrap();
        assert_eq!(a, b);
        assert_eq!(
            a.render(Format::Json),
            b.render(Format::Json),
            "serve output drifted between runs"
        );
        assert!(a.outcome.completed > 0);
        assert_eq!(a.batch_cap, rec.artifact.plan.mu());
        // foreign artifacts are rejected on the serve path too
        let mut foreign = rec.artifact.clone();
        foreign.config.model = "bert-large".into();
        assert!(exp.serve(&foreign, &opts).is_err());
    }

    #[test]
    fn plan_request_honors_config_dp_options() {
        let mut cfg = small_cfg();
        cfg.dp_options = vec![1, 2];
        let exp = Experiment::new(cfg).unwrap();
        let req = exp.plan_request();
        assert_eq!(req.dp_options, vec![1, 2]);
        for name in STRATEGIES {
            let report = exp.plan_with(name, &req).unwrap();
            for p in &report.points {
                assert!(
                    p.artifact.plan.dp <= 2,
                    "{name} searched outside dp_options: {:?}",
                    p.artifact.plan
                );
            }
        }
    }
}
