//! CLI plumbing for the `funcpipe` binary: strict flag parsing (unknown
//! flags are errors, not silent no-ops) and the flag → unified-config /
//! train-override mappings. Lives in the library so the behaviour is
//! testable; `main.rs` is a thin dispatcher over this module and
//! [`experiment`](crate::experiment).

use std::collections::HashMap;

use anyhow::{bail, Context, Result};

use crate::collective::SyncAlgorithm;
use crate::config::{validate_seed, ExperimentConfig};
use crate::experiment::{Format, PlanArtifact, TrainOverrides};
use crate::model::MergeCriterion;
use crate::planner::{
    PlanRequest, RobustRank, RobustSpec, SloSpec, STRATEGIES,
};
use crate::replan::ReplanSpec;
use crate::serve::{ServeOptions, TrafficSpec, TRAFFIC_SYNTAX};
use crate::simcore::ScenarioSpec;

/// Flags that shape the unified [`ExperimentConfig`]; accepted by every
/// config-driven subcommand.
pub const CONFIG_FLAGS: &[&str] = &[
    "config",
    "model",
    "platform",
    "batch",
    "micro-batch",
    "merge-layers",
    "merge-criterion",
    "sync",
    "bandwidth-scale",
    "dp-options",
    "chunk-bytes",
    "chunks-in-flight",
    "steps",
    "lr",
    "lifetime",
    "artifacts",
    "format",
];

/// Config-shaping flags that clash with `--plan`: the artifact already
/// froze them, so overriding them silently would betray the plan.
/// `--scenario`/`--seed` are deliberately absent: they are a lens on
/// execution, not part of the plan's identity (and only the `simulate`,
/// `train` and `profile` subcommands accept them at all — a scenario
/// flag on a command that cannot honor it would be a silent no-op).
pub const PLAN_EXCLUSIVE_FLAGS: &[&str] = &[
    "config",
    "model",
    "platform",
    "batch",
    "micro-batch",
    "merge-layers",
    "merge-criterion",
    "sync",
    "bandwidth-scale",
    "dp-options",
];

/// The flag allowlist for a subcommand; `None` = unknown subcommand.
pub fn flags_for(cmd: &str) -> Option<Vec<&'static str>> {
    let extra: &[&str] = match cmd {
        "plan" => &[
            "out",
            "strategy",
            "search",
            "robust-scenario",
            "robust-seeds",
            "robust-rank",
            "slo-p99-ms",
            "slo-traffic",
            "slo-seeds",
        ],
        "simulate" => &["plan", "scenario", "seed"],
        "train" => &[
            "plan",
            "dp",
            "mu",
            "scenario",
            "seed",
            "replan",
            "replan-threshold",
            "replan-window",
            "replan-max",
        ],
        "baseline" => &[],
        // serve is artifact-driven like `simulate --plan`: the frozen
        // plan is the whole model/platform input, so the config-shaping
        // flags are deliberately absent
        "serve" => {
            return Some(vec![
                "plan",
                "traffic",
                "seed",
                "duration",
                "batch-window-ms",
                "idle-timeout-s",
                "max-instances",
                "scenario",
                "format",
            ])
        }
        // profile honors the scenario lens: measured stage times are
        // viewed through the per-worker compute multiplier, the same
        // draws the simulator and trainer apply
        "profile" => {
            return Some(vec!["artifacts", "format", "scenario", "seed"])
        }
        "fig" => return Some(vec!["format"]),
        // fleet is config-file-driven: every tenant embeds its own
        // session config via its plan artifact, so the config-shaping
        // flags are deliberately absent
        "fleet" => {
            return Some(vec!["config", "scenario", "seed", "format"])
        }
        _ => return None,
    };
    let mut all = extra.to_vec();
    all.extend_from_slice(CONFIG_FLAGS);
    Some(all)
}

/// Flags that are boolean switches: present = on, and they take NO
/// value (a trailing word after one is a stray positional and errors,
/// same strictness as everywhere else).
pub const BOOL_FLAGS: &[&str] = &["replan"];

/// Minimal flag parser: `--key value` pairs (boolean switches in
/// [`BOOL_FLAGS`] take no value). Strict on every failure mode that
/// used to be a silent no-op: a flag not in `allowed` (the
/// `--chunk-byte` typo class), a duplicated flag, a flag without a
/// value, and stray positional arguments (a forgotten `--plan` must not
/// silently run a different experiment) are all errors.
pub fn parse_flags(
    cmd: &str,
    args: &[String],
    allowed: &[&str],
) -> Result<HashMap<String, String>> {
    let mut map = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        let Some(key) = args[i].strip_prefix("--") else {
            bail!(
                "unexpected argument {:?} for `{cmd}` (flags are `--key value`)",
                args[i]
            );
        };
        if !allowed.contains(&key) {
            bail!(
                "unknown flag --{key} for `{cmd}` (supported: {})",
                allowed
                    .iter()
                    .map(|f| format!("--{f}"))
                    .collect::<Vec<_>>()
                    .join(" ")
            );
        }
        if map.contains_key(key) {
            bail!("flag --{key} given more than once");
        }
        if BOOL_FLAGS.contains(&key) {
            map.insert(key.to_string(), "true".to_string());
            i += 1;
        } else if i + 1 < args.len() && !args[i + 1].starts_with("--") {
            map.insert(key.to_string(), args[i + 1].clone());
            i += 2;
        } else {
            bail!("flag --{key} requires a value");
        }
    }
    Ok(map)
}

/// When `--plan` is present, flags that would re-shape the frozen config
/// are rejected with a pointer at the right fix.
pub fn check_plan_conflicts(flags: &HashMap<String, String>) -> Result<()> {
    if !flags.contains_key("plan") {
        return Ok(());
    }
    for f in PLAN_EXCLUSIVE_FLAGS {
        if flags.contains_key(*f) {
            bail!(
                "--{f} conflicts with --plan: the artifact already fixes it \
                 (edit the artifact's config or re-run `plan`)"
            );
        }
    }
    Ok(())
}

/// Restrict to a subset (e.g. `simulate --plan` takes nothing else: the
/// artifact is the whole input).
pub fn only_flags(
    flags: &HashMap<String, String>,
    allowed: &[&str],
    what: &str,
) -> Result<()> {
    for key in flags.keys() {
        if !allowed.contains(&key.as_str()) {
            bail!(
                "--{key} is not meaningful with {what} (allowed: {})",
                allowed
                    .iter()
                    .map(|f| format!("--{f}"))
                    .collect::<Vec<_>>()
                    .join(" ")
            );
        }
    }
    Ok(())
}

/// Build the unified config from `--config` (file) plus flag overrides.
pub fn config_from_flags(
    flags: &HashMap<String, String>,
) -> Result<ExperimentConfig> {
    let mut cfg = if let Some(path) = flags.get("config") {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {path}"))?;
        ExperimentConfig::from_json_text(&text)?
    } else {
        ExperimentConfig::default()
    };
    if let Some(m) = flags.get("model") {
        cfg.model = m.clone();
    }
    if let Some(p) = flags.get("platform") {
        cfg.platform = p.clone();
    }
    if let Some(b) = flags.get("batch") {
        cfg.global_batch = b.parse().context("--batch")?;
    }
    if let Some(b) = flags.get("micro-batch") {
        cfg.micro_batch = b.parse().context("--micro-batch")?;
    }
    if let Some(l) = flags.get("merge-layers") {
        cfg.merge_layers = l.parse().context("--merge-layers")?;
    }
    if let Some(c) = flags.get("merge-criterion") {
        cfg.merge_criterion = MergeCriterion::parse(c).with_context(|| {
            format!("--merge-criterion {c:?} (compute|params|activations)")
        })?;
    }
    if let Some(s) = flags.get("sync") {
        cfg.sync_alg = SyncAlgorithm::parse(s).with_context(|| {
            format!("--sync {s:?} (pipelined|scatter-reduce)")
        })?;
    }
    if let Some(s) = flags.get("bandwidth-scale") {
        cfg.bandwidth_scale = s.parse().context("--bandwidth-scale")?;
    }
    if let Some(s) = flags.get("dp-options") {
        cfg.dp_options = s
            .split(',')
            .map(|t| {
                t.trim().parse::<usize>().with_context(|| {
                    format!("--dp-options entry {t:?} (comma-separated list)")
                })
            })
            .collect::<Result<Vec<_>>>()?;
    }
    if let Some(s) = flags.get("chunk-bytes") {
        cfg.chunk_bytes = s.parse().context("--chunk-bytes")?;
    }
    if let Some(s) = flags.get("chunks-in-flight") {
        cfg.chunks_in_flight = s.parse().context("--chunks-in-flight")?;
    }
    if let Some(s) = flags.get("steps") {
        cfg.steps = s.parse().context("--steps")?;
    }
    if let Some(s) = flags.get("lr") {
        cfg.lr = s.parse().context("--lr")?;
    }
    if let Some(s) = flags.get("lifetime") {
        cfg.lifetime_s = s.parse().context("--lifetime")?;
    }
    if let Some(dir) = flags.get("artifacts") {
        cfg.artifacts_dir = dir.clone();
    }
    apply_scenario_flags(&mut cfg, flags)?;
    cfg.validate()?;
    Ok(cfg)
}

/// Apply `--scenario`/`--seed` onto a config — shared by the normal
/// config path and the `simulate|train --plan` paths (where the rest of
/// the config is frozen by the artifact but the execution lens stays
/// selectable per call). Accepts composites (`cold-start+jitter`) with
/// the same strict rules as single scenarios.
pub fn apply_scenario_flags(
    cfg: &mut ExperimentConfig,
    flags: &HashMap<String, String>,
) -> Result<()> {
    if let Some(s) = flags.get("scenario") {
        cfg.scenario = ScenarioSpec::parse(s).with_context(|| {
            format!("--scenario {s:?} (expected {})", ScenarioSpec::SYNTAX)
        })?;
    }
    if let Some(s) = flags.get("seed") {
        cfg.seed = parse_seed(s)?;
        // strict-flag contract: a seed nothing will draw from is the
        // same silent-no-op class as an unknown flag
        if cfg.scenario.is_deterministic() {
            bail!(
                "--seed has no effect under the deterministic scenario; \
                 pass --scenario (accepted: {}) or set `scenario` in \
                 the config",
                ScenarioSpec::SYNTAX
            );
        }
    }
    Ok(())
}

/// The standalone scenario lens for subcommands that have no
/// [`ExperimentConfig`] of their own (`fleet`): same parse,
/// bound-check and seed-without-scenario rules as
/// [`apply_scenario_flags`], defaulting to (deterministic, 0).
pub fn scenario_from_flags(
    flags: &HashMap<String, String>,
) -> Result<(ScenarioSpec, u64)> {
    let mut cfg = ExperimentConfig::default();
    apply_scenario_flags(&mut cfg, flags)?;
    Ok((cfg.scenario, cfg.seed))
}

/// Parse and bound-check a `--seed` value. ONE validator for every
/// flag surface that accepts a seed (the scenario lens on
/// `simulate|train|profile` — including the `--plan` paths, which skip
/// `ExperimentConfig::validate` — and `serve`'s arrival seed), applying
/// the same ≤ 2^53 bound [`ExperimentConfig::validate`] enforces on
/// config files. Historically `--seed` on a `--plan` path bypassed the
/// bound and the report JSON silently rounded the seed.
pub fn parse_seed(s: &str) -> Result<u64> {
    let seed: u64 = s.parse().context("--seed")?;
    validate_seed(seed).context("--seed")?;
    Ok(seed)
}

/// Rebuild the session config from a plan artifact for an execution
/// subcommand: whatever lens the planning session happened to embed is
/// metadata, not a request — it resets to deterministic, and only
/// explicit `--scenario`/`--seed` flags opt back in. ONE policy shared
/// by `simulate --plan` and `train --plan`, so the two engines can
/// never drift on it.
pub fn lens_config_from_artifact(
    artifact: &PlanArtifact,
    flags: &HashMap<String, String>,
) -> Result<ExperimentConfig> {
    let mut cfg = artifact.config.clone();
    cfg.scenario = ScenarioSpec::deterministic();
    cfg.seed = 0;
    apply_scenario_flags(&mut cfg, flags)?;
    Ok(cfg)
}

/// Per-run trainer overrides from flags (all optional; absent = derive
/// from the plan/config).
pub fn train_overrides_from_flags(
    flags: &HashMap<String, String>,
) -> Result<TrainOverrides> {
    let mut ov = TrainOverrides::default();
    if let Some(v) = flags.get("dp") {
        ov.dp = Some(v.parse().context("--dp")?);
    }
    if let Some(v) = flags.get("mu") {
        ov.mu = Some(v.parse().context("--mu")?);
    }
    if let Some(v) = flags.get("steps") {
        ov.steps = Some(v.parse().context("--steps")?);
    }
    if let Some(v) = flags.get("lr") {
        ov.lr = Some(v.parse().context("--lr")?);
    }
    if let Some(v) = flags.get("lifetime") {
        ov.lifetime_s = Some(v.parse().context("--lifetime")?);
    }
    if let Some(v) = flags.get("chunk-bytes") {
        ov.chunk_bytes = Some(v.parse().context("--chunk-bytes")?);
    }
    if let Some(v) = flags.get("chunks-in-flight") {
        ov.chunks_in_flight = Some(v.parse().context("--chunks-in-flight")?);
    }
    if let Some(v) = flags.get("artifacts") {
        ov.artifacts_dir = Some(v.clone());
    }
    Ok(ov)
}

/// `train --replan [--replan-threshold x] [--replan-window k]
/// [--replan-max n]` → the
/// elastic re-planning spec. The strict-flag contract applies: the
/// tuning knobs without `--replan` itself would be silent no-ops and
/// are rejected, mirroring `--robust-seeds` without `--robust-scenario`.
pub fn replan_from_flags(
    flags: &HashMap<String, String>,
) -> Result<Option<ReplanSpec>> {
    if !flags.contains_key("replan") {
        if flags.contains_key("replan-threshold")
            || flags.contains_key("replan-window")
            || flags.contains_key("replan-max")
        {
            bail!(
                "--replan-threshold/--replan-window/--replan-max have no \
                 effect without --replan"
            );
        }
        return Ok(None);
    }
    let mut spec = ReplanSpec::default();
    if let Some(v) = flags.get("replan-threshold") {
        spec.threshold = v.parse().context("--replan-threshold")?;
    }
    if let Some(v) = flags.get("replan-window") {
        spec.window = v.parse().context("--replan-window")?;
    }
    if let Some(v) = flags.get("replan-max") {
        spec.max_replans = v.parse().context("--replan-max")?;
    }
    spec.validate()?;
    Ok(Some(spec))
}

/// `--format table|json` (default: table).
pub fn format_from_flags(flags: &HashMap<String, String>) -> Result<Format> {
    match flags.get("format") {
        Some(s) => Format::parse(s),
        None => Ok(Format::Table),
    }
}

/// `plan --strategy <name|all>` (default: the `bnb` registry default).
/// Unknown names are rejected here with the full registry listed, so a
/// typo cannot fall through to a less helpful error deeper down.
pub fn strategy_from_flags(flags: &HashMap<String, String>) -> Result<String> {
    match flags.get("strategy") {
        None => Ok(crate::experiment::DEFAULT_STRATEGY.to_string()),
        Some(s) if s == "all" || STRATEGIES.contains(&s.as_str()) => {
            Ok(s.clone())
        }
        Some(s) => bail!(
            "unknown strategy {s:?} (expected all or one of: {})",
            STRATEGIES.join(" ")
        ),
    }
}

/// `plan --robust-scenario <spec> [--robust-seeds n] [--robust-rank
/// worst|mean]` → the request's [`RobustSpec`]. The strict-flag
/// contract applies: `--robust-seeds`/`--robust-rank` without a
/// scenario would be silent no-ops and are rejected, as is a
/// deterministic robust scenario (nothing to be robust against).
pub fn robust_from_flags(
    flags: &HashMap<String, String>,
) -> Result<Option<RobustSpec>> {
    let scenario = flags.get("robust-scenario");
    if scenario.is_none() {
        if flags.contains_key("robust-seeds") || flags.contains_key("robust-rank")
        {
            bail!(
                "--robust-seeds/--robust-rank have no effect without \
                 --robust-scenario"
            );
        }
        return Ok(None);
    }
    let s = scenario.unwrap();
    let scenario = ScenarioSpec::parse(s).with_context(|| {
        format!("--robust-scenario {s:?} (expected {})", ScenarioSpec::SYNTAX)
    })?;
    let seeds = match flags.get("robust-seeds") {
        Some(v) => v.parse().context("--robust-seeds")?,
        None => 8,
    };
    let rank = match flags.get("robust-rank") {
        Some(v) => RobustRank::parse(v).with_context(|| {
            format!("--robust-rank {v:?} (expected worst|mean)")
        })?,
        None => RobustRank::Worst,
    };
    let spec = RobustSpec { scenario, seeds, rank };
    spec.validate()?;
    Ok(Some(spec))
}

/// `plan --slo-p99-ms <ms> --slo-traffic <spec> [--slo-seeds n]` → the
/// request's [`SloSpec`]: finalists are re-scored under seeded serving
/// replays and ranked by $/1k-requests subject to the p99 target. The
/// strict-flag contract applies: `--slo-traffic`/`--slo-seeds` without
/// a target (or a target without traffic to replay) would be silent
/// no-ops and are rejected.
pub fn slo_from_flags(
    flags: &HashMap<String, String>,
) -> Result<Option<SloSpec>> {
    let Some(p99) = flags.get("slo-p99-ms") else {
        if flags.contains_key("slo-traffic") || flags.contains_key("slo-seeds")
        {
            bail!(
                "--slo-traffic/--slo-seeds have no effect without \
                 --slo-p99-ms"
            );
        }
        return Ok(None);
    };
    let p99_ms: f64 = p99.parse().context("--slo-p99-ms")?;
    let Some(t) = flags.get("slo-traffic") else {
        bail!(
            "--slo-p99-ms requires --slo-traffic (expected {TRAFFIC_SYNTAX})"
        );
    };
    let traffic =
        TrafficSpec::parse(t).with_context(|| format!("--slo-traffic {t:?}"))?;
    let seeds = match flags.get("slo-seeds") {
        Some(v) => v.parse().context("--slo-seeds")?,
        None => 4,
    };
    let spec = SloSpec { p99_ms, traffic, seeds };
    spec.validate()?;
    Ok(Some(spec))
}

/// Build the [`ServeOptions`] for `serve --plan … --traffic …` (every
/// knob optional except the traffic source; defaults mirror
/// [`ServeOptions::new`]).
pub fn serve_options_from_flags(
    flags: &HashMap<String, String>,
) -> Result<ServeOptions> {
    let Some(t) = flags.get("traffic") else {
        bail!("serve requires --traffic (expected {TRAFFIC_SYNTAX})");
    };
    let traffic =
        TrafficSpec::parse(t).with_context(|| format!("--traffic {t:?}"))?;
    let seed = match flags.get("seed") {
        Some(s) => parse_seed(s)?,
        None => 0,
    };
    let mut opts = ServeOptions::new(traffic, seed);
    if let Some(v) = flags.get("duration") {
        opts.duration_s = v.parse().context("--duration")?;
    }
    if let Some(v) = flags.get("batch-window-ms") {
        let ms: f64 = v.parse().context("--batch-window-ms")?;
        opts.batch_window_s = ms / 1e3;
    }
    if let Some(v) = flags.get("idle-timeout-s") {
        opts.idle_timeout_s = v.parse().context("--idle-timeout-s")?;
    }
    if let Some(v) = flags.get("max-instances") {
        opts.max_instances = v.parse().context("--max-instances")?;
    }
    if let Some(s) = flags.get("scenario") {
        opts.scenario = ScenarioSpec::parse(s).with_context(|| {
            format!("--scenario {s:?} (expected {})", ScenarioSpec::SYNTAX)
        })?;
    }
    opts.validate()?;
    Ok(opts)
}

/// Shape the session's [`PlanRequest`] from the `plan` flags (robust
/// and SLO specs on top of the config-derived defaults, plus the
/// `--search serial|parallel` branch-and-bound mode). `parallel` is
/// the default; `serial` pins the exact single-threaded search (exact
/// node counts, reproducible truncation under a binding node budget).
pub fn apply_plan_flags(
    req: &mut PlanRequest,
    flags: &HashMap<String, String>,
) -> Result<()> {
    if let Some(mode) = flags.get("search") {
        match mode.as_str() {
            "serial" => req.serial_search = true,
            "parallel" => req.serial_search = false,
            other => bail!(
                "--search {other:?} (expected serial|parallel)"
            ),
        }
    }
    if let Some(spec) = robust_from_flags(flags)? {
        req.robust = Some(spec);
    }
    if let Some(spec) = slo_from_flags(flags)? {
        req.slo = Some(spec);
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(args: &[&str]) -> Vec<String> {
        args.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn rejects_unknown_and_duplicate_flags() {
        let allowed = flags_for("plan").unwrap();
        // the motivating typo: --chunk-byte (missing "s")
        assert!(parse_flags("plan", &argv(&["--chunk-byte", "1"]), &allowed)
            .is_err());
        assert!(parse_flags(
            "plan",
            &argv(&["--model", "a", "--model", "b"]),
            &allowed
        )
        .is_err());
        let ok =
            parse_flags("plan", &argv(&["--chunk-bytes", "1024"]), &allowed)
                .unwrap();
        assert_eq!(ok.get("chunk-bytes").unwrap(), "1024");
    }

    #[test]
    fn rejects_positionals_and_missing_values() {
        let allowed = flags_for("simulate").unwrap();
        // forgotten `--plan`: the file name must not be silently dropped
        assert!(
            parse_flags("simulate", &argv(&["plan.json"]), &allowed).is_err()
        );
        // a flag swallowing the next flag instead of a value
        assert!(parse_flags(
            "simulate",
            &argv(&["--plan", "--format", "json"]),
            &allowed
        )
        .is_err());
        // trailing flag without a value
        assert!(
            parse_flags("simulate", &argv(&["--plan"]), &allowed).is_err()
        );
        // negative numbers are values, not flags
        let ok = parse_flags(
            "simulate",
            &argv(&["--bandwidth-scale", "-1"]),
            &allowed,
        )
        .unwrap();
        assert_eq!(ok.get("bandwidth-scale").unwrap(), "-1");
    }

    #[test]
    fn new_config_flags_flow_through() {
        let allowed = flags_for("plan").unwrap();
        let flags = parse_flags(
            "plan",
            &argv(&[
                "--sync",
                "scatter-reduce",
                "--micro-batch",
                "2",
                "--merge-criterion",
                "params",
                "--steps",
                "7",
            ]),
            &allowed,
        )
        .unwrap();
        let cfg = config_from_flags(&flags).unwrap();
        assert_eq!(cfg.sync_alg, SyncAlgorithm::ScatterReduce);
        assert_eq!(cfg.micro_batch, 2);
        assert_eq!(cfg.merge_criterion, MergeCriterion::ParamSize);
        assert_eq!(cfg.steps, 7);
    }

    #[test]
    fn plan_conflicts_are_rejected() {
        let mut flags = HashMap::new();
        flags.insert("plan".to_string(), "p.json".to_string());
        check_plan_conflicts(&flags).unwrap();
        flags.insert("model".to_string(), "bert-large".to_string());
        assert!(check_plan_conflicts(&flags).is_err());
    }

    #[test]
    fn overrides_parse() {
        let mut flags = HashMap::new();
        flags.insert("dp".to_string(), "4".to_string());
        flags.insert("lifetime".to_string(), "30".to_string());
        let ov = train_overrides_from_flags(&flags).unwrap();
        assert_eq!(ov.dp, Some(4));
        assert_eq!(ov.lifetime_s, Some(30.0));
        assert_eq!(ov.mu, None);
    }

    #[test]
    fn scenario_flags_flow_through() {
        // every surface that can honor the lens accepts it with
        // identical rules
        for cmd in ["simulate", "train", "profile"] {
            let allowed = flags_for(cmd).unwrap();
            let flags = parse_flags(
                cmd,
                &argv(&["--scenario", "straggler", "--seed", "7"]),
                &allowed,
            )
            .unwrap();
            let cfg = config_from_flags(&flags).unwrap();
            assert_eq!(cfg.scenario.name(), "straggler");
            assert_eq!(cfg.seed, 7);
            // --seed alone would be a silent no-op (nothing draws from
            // it under the deterministic default): hard error
            let seed_only =
                parse_flags(cmd, &argv(&["--seed", "7"]), &allowed).unwrap();
            assert!(config_from_flags(&seed_only).is_err());
            // unknown scenario names are hard errors (strict-flag
            // contract)
            let bad = parse_flags(
                cmd,
                &argv(&["--scenario", "chaos-monkey"]),
                &allowed,
            )
            .unwrap();
            assert!(config_from_flags(&bad).is_err());
            // composites (with the `jitter` shorthand) parse on both
            let composite = parse_flags(
                cmd,
                &argv(&["--scenario", "cold-start+jitter", "--seed", "3"]),
                &allowed,
            )
            .unwrap();
            let cfg = config_from_flags(&composite).unwrap();
            assert_eq!(cfg.scenario.name(), "cold-start+bandwidth-jitter");
        }
        // scenario does not conflict with --plan (it is a lens, not a
        // config-shaping flag)
        let mut with_plan = HashMap::new();
        with_plan.insert("plan".to_string(), "p.json".to_string());
        with_plan.insert("scenario".to_string(), "straggler".to_string());
        check_plan_conflicts(&with_plan).unwrap();
        // ...but only simulate/train/profile can honor it: everywhere
        // else the flag would be a silent no-op, so it is rejected
        // outright
        for cmd in ["plan", "baseline"] {
            let allowed = flags_for(cmd).unwrap();
            assert!(
                parse_flags(cmd, &argv(&["--scenario", "straggler"]), &allowed)
                    .is_err(),
                "{cmd} accepted --scenario"
            );
            assert!(
                parse_flags(cmd, &argv(&["--seed", "7"]), &allowed).is_err(),
                "{cmd} accepted --seed"
            );
        }
    }

    #[test]
    fn strategy_flag_parses_and_rejects() {
        let allowed = flags_for("plan").unwrap();
        // default is bnb
        assert_eq!(
            strategy_from_flags(&HashMap::new()).unwrap(),
            crate::experiment::DEFAULT_STRATEGY
        );
        for name in STRATEGIES.iter().chain(&["all"]) {
            let flags =
                parse_flags("plan", &argv(&["--strategy", name]), &allowed)
                    .unwrap();
            assert_eq!(strategy_from_flags(&flags).unwrap(), *name);
        }
        let flags =
            parse_flags("plan", &argv(&["--strategy", "gurobi"]), &allowed)
                .unwrap();
        assert!(strategy_from_flags(&flags).is_err());
        // --strategy belongs to `plan` alone: on the execution commands
        // (where --plan lives) it would contradict the frozen artifact
        for cmd in ["simulate", "train", "baseline", "profile"] {
            let allowed = flags_for(cmd).unwrap();
            assert!(
                parse_flags(cmd, &argv(&["--strategy", "bnb"]), &allowed)
                    .is_err(),
                "{cmd} accepted --strategy"
            );
        }
    }

    #[test]
    fn search_flag_parses_and_rejects() {
        let allowed = flags_for("plan").unwrap();
        // default: parallel search
        let mut req = PlanRequest::new(8);
        apply_plan_flags(&mut req, &HashMap::new()).unwrap();
        assert!(!req.serial_search);
        for (mode, serial) in [("serial", true), ("parallel", false)] {
            let flags =
                parse_flags("plan", &argv(&["--search", mode]), &allowed)
                    .unwrap();
            let mut req = PlanRequest::new(8);
            apply_plan_flags(&mut req, &flags).unwrap();
            assert_eq!(req.serial_search, serial, "{mode}");
        }
        // unknown modes are hard errors (strict-flag contract)
        let flags =
            parse_flags("plan", &argv(&["--search", "threads"]), &allowed)
                .unwrap();
        let mut req = PlanRequest::new(8);
        assert!(apply_plan_flags(&mut req, &flags).is_err());
        // --search belongs to `plan` alone
        for cmd in ["simulate", "train", "baseline", "profile", "serve"] {
            let allowed = flags_for(cmd).unwrap();
            assert!(
                parse_flags(cmd, &argv(&["--search", "serial"]), &allowed)
                    .is_err(),
                "{cmd} accepted --search"
            );
        }
    }

    #[test]
    fn robust_flags_parse_and_reject() {
        let allowed = flags_for("plan").unwrap();
        let flags = parse_flags(
            "plan",
            &argv(&[
                "--robust-scenario",
                "straggler+jitter",
                "--robust-seeds",
                "4",
                "--robust-rank",
                "mean",
            ]),
            &allowed,
        )
        .unwrap();
        let spec = robust_from_flags(&flags).unwrap().unwrap();
        assert_eq!(spec.scenario.name(), "straggler+bandwidth-jitter");
        assert_eq!(spec.seeds, 4);
        assert_eq!(spec.rank, RobustRank::Mean);
        // defaults: 8 seeds, worst-case ranking
        let flags = parse_flags(
            "plan",
            &argv(&["--robust-scenario", "cold-start"]),
            &allowed,
        )
        .unwrap();
        let spec = robust_from_flags(&flags).unwrap().unwrap();
        assert_eq!((spec.seeds, spec.rank), (8, RobustRank::Worst));
        // silent no-ops and no-op scenarios are hard errors
        for bad in [
            vec!["--robust-seeds", "4"],
            vec!["--robust-rank", "worst"],
            vec!["--robust-scenario", "deterministic"],
            vec!["--robust-scenario", "chaos-monkey"],
            vec!["--robust-scenario", "straggler", "--robust-rank", "p99"],
            vec!["--robust-scenario", "straggler", "--robust-seeds", "0"],
        ] {
            let flags = parse_flags("plan", &argv(&bad), &allowed).unwrap();
            assert!(robust_from_flags(&flags).is_err(), "{bad:?} accepted");
        }
    }

    #[test]
    fn slo_flags_parse_and_reject() {
        let allowed = flags_for("plan").unwrap();
        let flags = parse_flags(
            "plan",
            &argv(&[
                "--slo-p99-ms",
                "250",
                "--slo-traffic",
                "poisson:1000",
                "--slo-seeds",
                "2",
            ]),
            &allowed,
        )
        .unwrap();
        let spec = slo_from_flags(&flags).unwrap().unwrap();
        assert_eq!(spec.p99_ms, 250.0);
        assert_eq!(spec.traffic.name(), "poisson:1000");
        assert_eq!(spec.seeds, 2);
        // defaults: 4 seeds
        let flags = parse_flags(
            "plan",
            &argv(&["--slo-p99-ms", "250", "--slo-traffic", "alibaba"]),
            &allowed,
        )
        .unwrap();
        let spec = slo_from_flags(&flags).unwrap().unwrap();
        assert_eq!(spec.seeds, 4);
        assert!(slo_from_flags(&HashMap::new()).unwrap().is_none());
        // silent no-ops, missing traffic and bad values are hard errors
        for bad in [
            vec!["--slo-traffic", "poisson:1000"],
            vec!["--slo-seeds", "4"],
            vec!["--slo-p99-ms", "250"],
            vec!["--slo-p99-ms", "0", "--slo-traffic", "poisson:1000"],
            vec!["--slo-p99-ms", "abc", "--slo-traffic", "poisson:1000"],
            vec!["--slo-p99-ms", "250", "--slo-traffic", "uniform:10"],
            vec![
                "--slo-p99-ms",
                "250",
                "--slo-traffic",
                "poisson:1000",
                "--slo-seeds",
                "0",
            ],
        ] {
            let flags = parse_flags("plan", &argv(&bad), &allowed).unwrap();
            assert!(slo_from_flags(&flags).is_err(), "{bad:?} accepted");
        }
        // the SLO knobs belong to `plan` alone
        for cmd in ["simulate", "train", "baseline", "profile", "serve"] {
            let allowed = flags_for(cmd).unwrap();
            assert!(
                parse_flags(cmd, &argv(&["--slo-p99-ms", "250"]), &allowed)
                    .is_err(),
                "{cmd} accepted --slo-p99-ms"
            );
        }
    }

    #[test]
    fn serve_flags_parse_and_reject() {
        let allowed = flags_for("serve").unwrap();
        let flags = parse_flags(
            "serve",
            &argv(&[
                "--plan",
                "p.json",
                "--traffic",
                "diurnal:1000:0.5",
                "--seed",
                "7",
                "--duration",
                "30",
                "--batch-window-ms",
                "20",
                "--idle-timeout-s",
                "5",
                "--max-instances",
                "16",
                "--scenario",
                "cold-start+straggler",
            ]),
            &allowed,
        )
        .unwrap();
        let opts = serve_options_from_flags(&flags).unwrap();
        assert_eq!(opts.traffic.name(), "diurnal:1000:0.5:3600");
        assert_eq!(opts.seed, 7);
        assert_eq!(opts.duration_s, 30.0);
        assert_eq!(opts.batch_window_s, 0.02);
        assert_eq!(opts.idle_timeout_s, 5.0);
        assert_eq!(opts.max_instances, 16);
        assert_eq!(opts.scenario.name(), "cold-start+straggler");
        // defaults mirror ServeOptions::new; a bare seed IS meaningful
        // here (it drives the arrival draws, not just a scenario lens)
        let mut min = HashMap::new();
        min.insert("traffic".to_string(), "poisson:1000".to_string());
        let opts = serve_options_from_flags(&min).unwrap();
        assert_eq!(opts.seed, 0);
        assert!(opts.scenario.is_deterministic());
        // strict rejections: no traffic, unknown traffic, bad knobs
        for bad in [
            vec!["--plan", "p.json"],
            vec!["--traffic", "uniform:10"],
            vec!["--traffic", "poisson"],
            vec!["--traffic", "poisson:1000", "--duration", "0"],
            vec!["--traffic", "poisson:1000", "--max-instances", "0"],
            vec!["--traffic", "poisson:1000", "--batch-window-ms", "-1"],
            vec!["--traffic", "poisson:1000", "--scenario", "chaos"],
        ] {
            let flags = parse_flags("serve", &argv(&bad), &allowed).unwrap();
            assert!(
                serve_options_from_flags(&flags).is_err(),
                "{bad:?} accepted"
            );
        }
        // config-shaping flags are not accepted at all (artifact-driven)
        for f in ["--model", "--platform", "--batch"] {
            assert!(
                parse_flags("serve", &argv(&[f, "x"]), &allowed).is_err(),
                "serve accepted {f}"
            );
        }
    }

    #[test]
    fn seed_bound_is_enforced_on_every_flag_surface() {
        let over = format!("{}", (1u64 << 53) + 1);
        // the shared parser itself
        assert_eq!(parse_seed("7").unwrap(), 7);
        assert!(parse_seed(&over).is_err());
        assert!(parse_seed("-1").is_err());
        // the scenario-lens surfaces (config path)
        for cmd in ["simulate", "train", "profile"] {
            let allowed = flags_for(cmd).unwrap();
            let flags = parse_flags(
                cmd,
                &argv(&["--scenario", "straggler", "--seed", &over]),
                &allowed,
            )
            .unwrap();
            assert!(
                config_from_flags(&flags).is_err(),
                "{cmd} accepted an over-bound --seed"
            );
        }
        // the --plan lens path (bypasses ExperimentConfig::validate)
        let artifact_cfg = ExperimentConfig::default();
        let mut flags = HashMap::new();
        flags.insert("scenario".to_string(), "straggler".to_string());
        flags.insert("seed".to_string(), over.clone());
        let mut cfg = artifact_cfg;
        assert!(
            apply_scenario_flags(&mut cfg, &flags).is_err(),
            "--plan lens path accepted an over-bound --seed"
        );
        // the serve path
        let allowed = flags_for("serve").unwrap();
        let flags = parse_flags(
            "serve",
            &argv(&["--traffic", "poisson:1000", "--seed", &over]),
            &allowed,
        )
        .unwrap();
        assert!(serve_options_from_flags(&flags).is_err());
        // the exact boundary is accepted everywhere
        let edge = format!("{}", 1u64 << 53);
        assert_eq!(parse_seed(&edge).unwrap(), 1u64 << 53);
    }

    #[test]
    fn dp_options_flag_flows_into_the_config() {
        let allowed = flags_for("plan").unwrap();
        let flags = parse_flags(
            "plan",
            &argv(&["--dp-options", "1,2,8"]),
            &allowed,
        )
        .unwrap();
        let cfg = config_from_flags(&flags).unwrap();
        assert_eq!(cfg.dp_options, vec![1, 2, 8]);
        for bad in ["1,two", "", "4,2", "0,1"] {
            let flags =
                parse_flags("plan", &argv(&["--dp-options", bad]), &allowed)
                    .unwrap();
            assert!(config_from_flags(&flags).is_err(), "{bad:?} accepted");
        }
        // config-shaping: conflicts with --plan like its siblings
        let mut with_plan = HashMap::new();
        with_plan.insert("plan".to_string(), "p.json".to_string());
        with_plan.insert("dp-options".to_string(), "1,2".to_string());
        assert!(check_plan_conflicts(&with_plan).is_err());
    }

    #[test]
    fn replan_flags_parse_and_reject() {
        let allowed = flags_for("train").unwrap();
        // --replan is a boolean switch: no value consumed
        let flags = parse_flags(
            "train",
            &argv(&[
                "--replan",
                "--replan-threshold",
                "1.5",
                "--replan-window",
                "2",
                "--replan-max",
                "2",
                "--scenario",
                "straggler",
            ]),
            &allowed,
        )
        .unwrap();
        let spec = replan_from_flags(&flags).unwrap().unwrap();
        assert_eq!(spec.threshold, 1.5);
        assert_eq!(spec.window, 2);
        assert_eq!(spec.max_replans, 2);
        // defaults when only the switch is given
        let flags =
            parse_flags("train", &argv(&["--replan"]), &allowed).unwrap();
        let spec = replan_from_flags(&flags).unwrap().unwrap();
        assert_eq!(spec, ReplanSpec::default());
        // absent switch → no spec
        assert!(replan_from_flags(&HashMap::new()).unwrap().is_none());
        // a word after the switch is a stray positional, not its value
        assert!(parse_flags(
            "train",
            &argv(&["--replan", "true"]),
            &allowed
        )
        .is_err());
        // tuning knobs without the switch are silent no-ops → hard error
        for bad in [
            vec!["--replan-threshold", "1.5"],
            vec!["--replan-window", "2"],
            vec!["--replan-max", "2"],
        ] {
            let flags = parse_flags("train", &argv(&bad), &allowed).unwrap();
            assert!(replan_from_flags(&flags).is_err(), "{bad:?} accepted");
        }
        // degenerate knob values are rejected through ReplanSpec
        for bad in [
            vec!["--replan", "--replan-threshold", "1.0"],
            vec!["--replan", "--replan-threshold", "abc"],
            vec!["--replan", "--replan-window", "0"],
            vec!["--replan", "--replan-max", "0"],
            vec!["--replan", "--replan-max", "abc"],
        ] {
            let flags = parse_flags("train", &argv(&bad), &allowed).unwrap();
            assert!(replan_from_flags(&flags).is_err(), "{bad:?} accepted");
        }
        // --replan belongs to `train` alone
        for cmd in ["simulate", "plan", "baseline", "profile", "serve"] {
            let allowed = flags_for(cmd).unwrap();
            assert!(
                parse_flags(cmd, &argv(&["--replan"]), &allowed).is_err(),
                "{cmd} accepted --replan"
            );
        }
    }

    #[test]
    fn fleet_allowlist_is_strict() {
        let allowed = flags_for("fleet").unwrap();
        let flags = parse_flags(
            "fleet",
            &argv(&[
                "--config",
                "fleet.json",
                "--scenario",
                "cold-start-storm",
                "--seed",
                "7",
                "--format",
                "json",
            ]),
            &allowed,
        )
        .unwrap();
        assert_eq!(flags.get("config").unwrap(), "fleet.json");
        // config-shaping and artifact flags are deliberately absent:
        // the fleet config file owns the whole tenant roster
        for bad in [
            vec!["--model", "resnet101"],
            vec!["--plan", "p.json"],
            vec!["--batch", "16"],
            vec!["--traffic", "poisson:600"],
        ] {
            assert!(
                parse_flags("fleet", &argv(&bad), &allowed).is_err(),
                "{bad:?} accepted"
            );
        }
    }

    #[test]
    fn format_flag() {
        let mut flags = HashMap::new();
        assert_eq!(format_from_flags(&flags).unwrap(), Format::Table);
        flags.insert("format".to_string(), "json".to_string());
        assert_eq!(format_from_flags(&flags).unwrap(), Format::Json);
        flags.insert("format".to_string(), "xml".to_string());
        assert!(format_from_flags(&flags).is_err());
    }
}
