//! `funcpipe` CLI — a thin shell over the [`experiment`] session API.
//!
//! Subcommands:
//!   plan     — co-optimize partition + resources; `--out plan.json`
//!              writes the recommended plan as a serializable artifact
//!   simulate — DES-simulate a plan (`--plan plan.json` or re-plan)
//!   train    — real end-to-end training; `--plan plan.json` supplies
//!              dp/μ/chunking (flags remain as explicit overrides)
//!   serve    — replay a frozen plan as a pipelined serving deployment
//!              under a seeded arrival trace (`--plan` + `--traffic`)
//!   fleet    — run a multi-tenant roster of frozen plans (train and
//!              serve tenants) against ONE shared platform with FIFO
//!              admission and bandwidth contention (`--config`)
//!   profile  — profile the AOT stages through PJRT
//!   baseline — evaluate the §5.1 baselines
//!   fig      — regenerate a paper figure/table (fig1 fig5 ... table3)
//!
//! Every subcommand takes `--format table|json`; JSON goes to stdout
//! unmixed with status chatter (which goes to stderr), so output pipes
//! cleanly into other tools.
//!
//! [`experiment`]: funcpipe::experiment

use std::collections::HashMap;

use anyhow::{bail, Context, Result};
use funcpipe::cli;
use funcpipe::experiment::{
    Experiment, Format, PlanArtifact, Report, TableSet,
};
use funcpipe::util::logging;

fn main() {
    logging::init();
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    let rest = &args[1.min(args.len())..];

    match cmd {
        "fig" => return cmd_fig(rest),
        "help" | "--help" | "-h" => {
            print_help();
            return Ok(());
        }
        _ => {}
    }

    let allowed = cli::flags_for(cmd)
        .with_context(|| format!("unknown command {cmd:?}; try `funcpipe help`"))?;
    let flags = cli::parse_flags(cmd, rest, &allowed)?;
    let format = cli::format_from_flags(&flags)?;

    match cmd {
        "plan" => cmd_plan(&flags, format),
        "simulate" => cmd_simulate(&flags, format),
        "train" => cmd_train(&flags, format),
        "serve" => cmd_serve(&flags, format),
        "fleet" => cmd_fleet(&flags, format),
        "profile" => cmd_profile(&flags, format),
        "baseline" => cmd_baseline(&flags, format),
        _ => unreachable!("flags_for gated the command set"),
    }
}

fn print_help() {
    println!(
        "funcpipe — pipelined serverless training (FuncPipe reproduction)

USAGE: funcpipe <command> [--flags]

Every command accepts --format table|json (default: table). The
config-driven commands (plan, simulate, train, baseline) also accept
the unified config flags (--config file.json --model <name>
--batch <n> --micro-batch <n> --platform aws|alibaba
--merge-layers <n> --merge-criterion compute|params|activations
--sync pipelined|scatter-reduce --bandwidth-scale <x>
--dp-options 1,2,4 --chunk-bytes <n> --chunks-in-flight <n>
--steps <n> --lr <x> --lifetime <s> --artifacts <dir>); simulate,
train and fleet add the scenario lens (--scenario
deterministic|cold-start|straggler|bandwidth-jitter|flaky-network
|bandwidth-decay|cold-start-storm|spot-revocation, composable as e.g.
cold-start+jitter, --seed <n>); profile takes just --artifacts, fig
just --format. Unknown flags are errors.

COMMANDS:
  plan      [--strategy bnb|miqp|bayes|tpdmp|sweep|all] [--out plan.json]
            [--robust-scenario <spec>] [--robust-seeds <n>]
            [--robust-rank worst|mean]
            co-optimize partition + resources through the strategy
            registry (default bnb, the exact branch-and-bound); prints
            every candidate with the Pareto frontier flagged and the
            δ>=0.8 recommendation marked, and optionally writes the
            recommended plan artifact. --strategy all races every
            strategy in parallel threads over one shared perf model and
            prints a cross-strategy comparison (--out then writes the
            pooled winner). --robust-scenario re-scores candidates
            under seeded scenario replays (e.g. straggler+jitter,
            --robust-seeds 8) and ranks by worst-case (or --robust-rank
            mean) scenario time/cost instead of the deterministic
            point estimate. --slo-p99-ms <ms> --slo-traffic <spec>
            [--slo-seeds <n>] re-scores finalists under seeded serving
            replays and recommends the cheapest plan per 1k requests
            whose replayed p99 latency meets the target
  simulate  [--plan plan.json] [--scenario <name>] [--seed <n>]
            DES-simulate a plan vs the closed-form model; with --plan
            the artifact is the whole input except the scenario lens
            (--scenario/--seed perturb the simulation, deterministic
            per seed: cold starts, stragglers, bandwidth jitter)
  train     [--plan plan.json] [--dp n] [--mu n]
            [--scenario <name>] [--seed <n>]
            [--replan] [--replan-threshold x] [--replan-window k]
            [--replan-max n]
            real end-to-end training over the AOT artifacts (or the
            built-in model: --artifacts builtin:tiny); --plan derives
            dp/μ/sync/chunking from the artifact, flags are explicit
            overrides; --scenario threads the same seeded draws the
            simulator uses into the real path (per-worker storage
            lens, scenario-scaled cold starts, deterministic virtual
            lifecycle — the report replays byte-identically per seed);
            --replan adds elastic mid-run re-planning: when the
            observed iteration time exceeds the prediction by the
            threshold ratio (default 1.2) for k consecutive steps
            (default 3), the planner re-races under the measured
            profile and — if the new plan wins back its migration
            cost — the run migrates at a function-generation boundary
            via layer-addressed checkpoints; the detector re-arms
            after every adopted migration, chaining up to
            --replan-max migrations (default 4) when a time-varying
            lens keeps drifting (requires a --scenario; the report
            logs every re-plan decision)
  serve     --plan plan.json --traffic <spec> [--seed <n>]
            [--duration <s>] [--batch-window-ms <ms>]
            [--idle-timeout-s <s>] [--max-instances <n>]
            [--scenario <name>]
            replay the frozen plan as a pipelined serving deployment:
            forward-only stages behind autoscaled per-stage function
            pools, driven by a seeded arrival trace (--traffic
            poisson:RATE | diurnal[:BASE[:AMP[:PERIOD_S]]] |
            alibaba[:MEAN], rates in req/min); reports p50/p95/p99
            latency, throughput, cold-start rate, per-stage
            utilization and $/1k-requests, byte-identical per
            (plan, traffic, seed)
  fleet     --config fleet.json [--scenario <name>] [--seed <n>]
            run a multi-tenant roster (train jobs and serve
            deployments, each a frozen plan artifact) against ONE
            shared platform on a single virtual clock: FIFO admission
            against max_concurrency, cross-tenant storage-bandwidth
            contention, per-tenant cost/wait/throughput accounting;
            the time-varying lenses (bandwidth-decay,
            cold-start-storm, spot-revocation) draw per
            (tenant, worker, step) and replay byte-identically
  profile   [--artifacts dir]
            profile AOT stages through PJRT
  baseline  evaluate LambdaML / HybridPS (+GA) baselines
  fig       <fig1|fig5|fig6|fig7|fig8|fig9|fig10|fig11|table3|fleet>
            regenerate a paper figure/table (also: cargo bench);
            `fleet` is the multi-tenant demo roster, no paper
            counterpart

The plan artifact closes the paper's §3.1 loop in one file, and one
frozen plan replays under both engines through an identical lens:
  funcpipe plan --model amoebanet-d18 --batch 64 --out plan.json
  funcpipe simulate --plan plan.json --scenario straggler --seed 7
  funcpipe train --plan plan.json --scenario straggler --seed 7 \\
      --artifacts builtin:tiny       # no manual --dp/--mu"
    );
}

fn cmd_plan(flags: &HashMap<String, String>, format: Format) -> Result<()> {
    let strategy = cli::strategy_from_flags(flags)?;
    let cfg = cli::config_from_flags(flags)?;
    let exp = Experiment::new(cfg)?;
    let mut req = exp.plan_request();
    cli::apply_plan_flags(&mut req, flags)?;
    if strategy == "all" {
        // race every registry strategy over one shared perf model;
        // --out writes the pooled winner (its artifact records which
        // strategy found it)
        let report = exp.plan_race(&req)?;
        if let Some(path) = flags.get("out") {
            let win = report.winner.as_ref().context(
                "no feasible plan to write (try other weights/batch)",
            )?;
            win.artifact.save(path)?;
            eprintln!(
                "wrote race-winning plan artifact ({}) to {path}",
                win.artifact.strategy
            );
        }
        report.print(format);
        return Ok(());
    }
    let report = exp.plan_with(&strategy, &req)?;
    if let Some(path) = flags.get("out") {
        let rec = report
            .recommended()
            .context("no feasible plan to write (try other weights/batch)")?;
        rec.artifact.save(path)?;
        eprintln!("wrote recommended plan artifact to {path}");
    }
    report.print(format);
    Ok(())
}

fn cmd_simulate(flags: &HashMap<String, String>, format: Format) -> Result<()> {
    let report = if let Some(path) = flags.get("plan") {
        // the artifact freezes the config; the scenario lens stays
        // selectable per simulation (reset-then-apply: a plain
        // `simulate --plan` gives the deterministic Table-3 reference)
        cli::only_flags(
            flags,
            &["plan", "format", "scenario", "seed"],
            "simulate --plan",
        )?;
        let artifact = PlanArtifact::load(path)?;
        let exp =
            Experiment::new(cli::lens_config_from_artifact(&artifact, flags)?)?;
        exp.simulate(&artifact)?
    } else {
        let exp = Experiment::new(cli::config_from_flags(flags)?)?;
        let plans = exp.plan()?;
        let rec = plans.recommended().context("no feasible plan")?;
        exp.simulate(&rec.artifact)?
    };
    report.print(format);
    Ok(())
}

fn cmd_train(flags: &HashMap<String, String>, format: Format) -> Result<()> {
    cli::check_plan_conflicts(flags)?;
    let overrides = cli::train_overrides_from_flags(flags)?;
    let replan = cli::replan_from_flags(flags)?;
    let (exp, artifact, lens_reset) = if let Some(path) = flags.get("plan") {
        // same lens policy as `simulate --plan`: a plain `train --plan`
        // runs unperturbed, only explicit flags opt into the injector —
        // and when that drops a lens the artifact embedded, the reset
        // is announced instead of silent (notice on the table path,
        // `lens_reset` in the JSON)
        let a = PlanArtifact::load(path)?;
        let lens_reset = !a.config.scenario.is_deterministic()
            && !flags.contains_key("scenario");
        let exp =
            Experiment::new(cli::lens_config_from_artifact(&a, flags)?)?;
        (exp, Some(a), lens_reset)
    } else {
        (Experiment::new(cli::config_from_flags(flags)?)?, None, false)
    };
    if lens_reset && format == Format::Table {
        eprintln!(
            "note: the plan artifact embeds scenario lens {:?}; it was \
             reset to deterministic (pass --scenario/--seed to opt back in)",
            artifact.as_ref().unwrap().config.scenario.name()
        );
    }
    let mut report = match &replan {
        Some(spec) => exp.train_replan(artifact.as_ref(), &overrides, spec)?,
        None => exp.train(artifact.as_ref(), &overrides)?,
    };
    report.lens_reset = lens_reset;
    report.print(format);
    Ok(())
}

fn cmd_serve(flags: &HashMap<String, String>, format: Format) -> Result<()> {
    // artifact-driven like `simulate --plan`: the frozen plan supplies
    // the model/platform; the traffic, seed and autoscaler knobs are
    // the serving session's own inputs
    let Some(path) = flags.get("plan") else {
        bail!("serve requires --plan plan.json (from `plan --out`)");
    };
    let opts = cli::serve_options_from_flags(flags)?;
    let artifact = PlanArtifact::load(path)?;
    let exp = Experiment::from_artifact(&artifact)?;
    let report = exp.serve(&artifact, &opts)?;
    report.print(format);
    Ok(())
}

fn cmd_fleet(flags: &HashMap<String, String>, format: Format) -> Result<()> {
    // config-file-driven: the roster file names every tenant's frozen
    // plan artifact; the scenario lens and seed stay CLI-selectable so
    // one roster replays under many conditions
    let Some(path) = flags.get("config") else {
        bail!(
            "fleet requires --config fleet.json (a tenant roster; see \
             the README quickstart and examples/fleet.json)"
        );
    };
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading fleet config {path}"))?;
    let spec = funcpipe::fleet::FleetSpec::from_json_text(&text)
        .with_context(|| format!("fleet config {path}"))?;
    let (scenario, seed) = cli::scenario_from_flags(flags)?;
    let report = Experiment::fleet(&spec, &scenario, seed)?;
    report.print(format);
    Ok(())
}

fn cmd_profile(flags: &HashMap<String, String>, format: Format) -> Result<()> {
    let mut cfg = funcpipe::config::ExperimentConfig::default();
    if let Some(dir) = flags.get("artifacts") {
        cfg.artifacts_dir = dir.clone();
    }
    // the profile honors the same execution lens as simulate/train:
    // measured stage times are viewed through the scenario's per-worker
    // compute multipliers
    funcpipe::cli::apply_scenario_flags(&mut cfg, flags)?;
    let exp = Experiment::new(cfg)?;
    let report = exp.profile(3)?;
    report.print(format);
    Ok(())
}

fn cmd_baseline(flags: &HashMap<String, String>, format: Format) -> Result<()> {
    let exp = Experiment::new(cli::config_from_flags(flags)?)?;
    let report = exp.baselines()?;
    report.print(format);
    Ok(())
}

fn cmd_fig(args: &[String]) -> Result<()> {
    let which = args.first().map(String::as_str).unwrap_or("");
    if which.is_empty() || which.starts_with("--") {
        bail!(
            "missing figure id (usage: funcpipe fig \
             <fig1|fig5|fig6|fig7|fig8|fig9|fig10|fig11|table3|fleet> \
             [--format table|json])"
        );
    }
    let flag_args = &args[1..];
    let flags = cli::parse_flags("fig", flag_args, &["format"])?;
    let format = cli::format_from_flags(&flags)?;
    let tables = match which {
        "fig1" => funcpipe::bench::fig1(),
        "fig5" => funcpipe::bench::fig5(),
        "fig6" => funcpipe::bench::fig6(),
        "fig7" => funcpipe::bench::fig7(),
        "fig8" => funcpipe::bench::fig8(),
        "fig9" => funcpipe::bench::fig9(),
        "fig10" => funcpipe::bench::fig10(),
        "fig11" => funcpipe::bench::fig11(),
        "table3" => funcpipe::bench::table3(),
        "fleet" => funcpipe::bench::fleet_demo(),
        other => bail!("unknown figure {other:?}"),
    };
    TableSet(tables).print(format);
    Ok(())
}
