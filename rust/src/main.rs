//! `funcpipe` CLI — the leader entrypoint.
//!
//! Subcommands:
//!   plan     — co-optimize partition + resources for a zoo model
//!   simulate — DES-simulate a plan and compare with the perf model
//!   train    — real end-to-end training over the AOT artifacts
//!   profile  — profile the AOT stages through PJRT
//!   baseline — evaluate the §5.1 baselines
//!   fig      — regenerate a paper figure/table (fig1 fig5 ... table3)

use std::collections::HashMap;

use anyhow::{bail, Context, Result};
use funcpipe::baselines::{evaluate_baseline, BaselineKind};
use funcpipe::config::ExperimentConfig;
use funcpipe::planner::{pareto_front, recommend, sweep, CoOptimizer};
use funcpipe::util::humansize::{secs, usd};
use funcpipe::util::logging;
use funcpipe::util::table::Table;

fn main() {
    logging::init();
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Minimal flag parser: `--key value` pairs after the subcommand.
fn parse_flags(args: &[String]) -> HashMap<String, String> {
    let mut map = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(key) = args[i].strip_prefix("--") {
            if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                map.insert(key.to_string(), args[i + 1].clone());
                i += 2;
            } else {
                map.insert(key.to_string(), "true".into());
                i += 1;
            }
        } else {
            i += 1;
        }
    }
    map
}

fn config_from_flags(flags: &HashMap<String, String>) -> Result<ExperimentConfig> {
    let mut cfg = if let Some(path) = flags.get("config") {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {path}"))?;
        ExperimentConfig::from_json_text(&text)?
    } else {
        ExperimentConfig::default()
    };
    if let Some(m) = flags.get("model") {
        cfg.model = m.clone();
    }
    if let Some(p) = flags.get("platform") {
        cfg.platform = p.clone();
    }
    if let Some(b) = flags.get("batch") {
        cfg.global_batch = b.parse().context("--batch")?;
    }
    if let Some(l) = flags.get("merge-layers") {
        cfg.merge_layers = l.parse().context("--merge-layers")?;
    }
    if let Some(s) = flags.get("bandwidth-scale") {
        cfg.bandwidth_scale = s.parse().context("--bandwidth-scale")?;
    }
    if let Some(s) = flags.get("chunk-bytes") {
        cfg.chunk_bytes = s.parse().context("--chunk-bytes")?;
    }
    cfg.validate()?;
    Ok(cfg)
}

fn run() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    let flags = parse_flags(&args[1.min(args.len())..]);

    match cmd {
        "plan" => cmd_plan(&flags),
        "simulate" => cmd_simulate(&flags),
        "train" => cmd_train(&flags),
        "profile" => cmd_profile(&flags),
        "baseline" => cmd_baseline(&flags),
        "fig" => cmd_fig(&args),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => bail!("unknown command {other:?}; try `funcpipe help`"),
    }
}

fn print_help() {
    println!(
        "funcpipe — pipelined serverless training (FuncPipe reproduction)

USAGE: funcpipe <command> [--flags]

COMMANDS:
  plan      --model <name> --batch <n> [--platform aws|alibaba]
            [--chunk-bytes n]
            co-optimize partition + resources; prints the Pareto sweep
  simulate  --model <name> --batch <n> [--chunk-bytes n]
            DES-simulate the recommended plan vs the closed-form model
  train     [--dp n] [--mu n] [--steps n] [--artifacts dir]
            [--chunk-bytes n] [--chunks-in-flight n]
            real end-to-end training over the AOT artifacts; chunk flags
            stream gradients as bounded-memory chunk flows
  profile   [--artifacts dir]
            profile AOT stages through PJRT
  baseline  --model <name> --batch <n>
            evaluate LambdaML / HybridPS (+GA) baselines
  fig       <fig1|fig5|fig6|fig7|fig8|fig9|fig10|fig11|table3>
            regenerate a paper figure/table (also: cargo bench)"
    );
}

fn cmd_plan(flags: &HashMap<String, String>) -> Result<()> {
    let cfg = config_from_flags(flags)?;
    let platform = cfg.resolve_platform()?;
    let model = cfg.resolve_model(&platform)?;
    let mut opt = CoOptimizer::new(&model, &platform);
    opt.perf.chunk_bytes = cfg.chunk_bytes;
    let points = sweep(&cfg.weights, |w| {
        opt.solve(cfg.n_micro_global(), w)
            .map(|(plan, perf, _)| (plan, perf))
    });
    let front = pareto_front(&points);

    let mut t = Table::new(format!(
        "FuncPipe plans — {} on {}, global batch {}",
        cfg.model, cfg.platform, cfg.global_batch
    ))
    .header(["weights", "plan", "t_iter", "c_iter", "rec"]);
    let rec = recommend(&front);
    for p in &front {
        let is_rec = rec
            .as_ref()
            .map(|r| r.plan == p.plan)
            .unwrap_or(false);
        t.row([
            format!("({}, {})", p.weights.0, p.weights.1),
            p.plan.describe(&model, &platform),
            secs(p.perf.t_iter),
            usd(p.perf.c_iter),
            if is_rec { "<- recommended".into() } else { String::new() },
        ]);
    }
    t.print();
    Ok(())
}

fn cmd_simulate(flags: &HashMap<String, String>) -> Result<()> {
    let cfg = config_from_flags(flags)?;
    let platform = cfg.resolve_platform()?;
    let model = cfg.resolve_model(&platform)?;
    let mut opt = CoOptimizer::new(&model, &platform);
    opt.perf.chunk_bytes = cfg.chunk_bytes;
    let points = sweep(&cfg.weights, |w| {
        opt.solve(cfg.n_micro_global(), w)
            .map(|(plan, perf, _)| (plan, perf))
    });
    let rec = recommend(&points).context("no feasible plan")?;
    let sim = funcpipe::pipeline::simulate_iteration(
        &model,
        &platform,
        &rec.plan,
        cfg.sync_alg,
    );
    let mut t = Table::new("model vs DES simulation")
        .header(["source", "t_iter", "c_iter"]);
    t.row(["perf model".to_string(), secs(rec.perf.t_iter), usd(rec.perf.c_iter)]);
    t.row(["DES sim".to_string(), secs(sim.t_iter), usd(sim.c_iter)]);
    t.row([
        "error".to_string(),
        format!(
            "{:.1}%",
            (sim.t_iter - rec.perf.t_iter).abs() / sim.t_iter * 100.0
        ),
        String::new(),
    ]);
    t.print();
    Ok(())
}

fn cmd_train(flags: &HashMap<String, String>) -> Result<()> {
    let dir = flags
        .get("artifacts")
        .cloned()
        .unwrap_or_else(|| "artifacts".into());
    let mut cfg = funcpipe::trainer::TrainConfig::new(dir);
    if let Some(v) = flags.get("dp") {
        cfg.dp = v.parse()?;
    }
    if let Some(v) = flags.get("mu") {
        cfg.mu = v.parse()?;
    }
    if let Some(v) = flags.get("steps") {
        cfg.steps = v.parse()?;
    }
    if let Some(v) = flags.get("lr") {
        cfg.lr = v.parse()?;
    }
    if let Some(v) = flags.get("lifetime") {
        cfg.lifetime_s = v.parse()?;
    }
    // the two chunking flags are independent: --chunks-in-flight alone
    // still sizes the flow pool's queues for the unchunked path
    let chunk_bytes: Option<usize> = flags
        .get("chunk-bytes")
        .map(|s| s.parse().context("--chunk-bytes"))
        .transpose()?;
    let in_flight: Option<usize> = flags
        .get("chunks-in-flight")
        .map(|s| s.parse().context("--chunks-in-flight"))
        .transpose()?;
    if chunk_bytes.is_some() || in_flight.is_some() {
        cfg.chunking = funcpipe::collective::Chunking::new(
            chunk_bytes.unwrap_or(0),
            in_flight.unwrap_or(funcpipe::collective::Chunking::NONE.in_flight),
        );
    }
    let report = funcpipe::trainer::train(&cfg)?;
    println!(
        "trained {} steps: loss {:.4} -> {:.4}, {:.1} ms/iter, {} restarts",
        cfg.steps,
        report.first_loss(),
        report.last_loss(),
        report.mean_iter_s() * 1e3,
        report.restarts
    );
    Ok(())
}

fn cmd_profile(flags: &HashMap<String, String>) -> Result<()> {
    let dir = flags
        .get("artifacts")
        .cloned()
        .unwrap_or_else(|| "artifacts".into());
    let platform = funcpipe::platform::PlatformSpec::aws_lambda();
    let prof = funcpipe::profiler::profile_stages(
        std::path::Path::new(&dir),
        &platform,
        3,
    )?;
    let mut t = Table::new("AOT stage profile (per micro-batch)")
        .header(["stage", "params", "fwd@top", "bwd@top"]);
    for l in &prof.layers {
        t.row([
            l.name.clone(),
            funcpipe::util::humansize::bytes(l.param_bytes),
            secs(l.fwd_s[platform.max_tier()]),
            secs(l.bwd_s[platform.max_tier()]),
        ]);
    }
    t.print();
    Ok(())
}

fn cmd_baseline(flags: &HashMap<String, String>) -> Result<()> {
    let cfg = config_from_flags(flags)?;
    let platform = cfg.resolve_platform()?;
    let model = funcpipe::model::zoo::by_name(&cfg.model, &platform)
        .context("unknown model")?;
    let mut t = Table::new(format!(
        "baselines — {} batch {}",
        cfg.model, cfg.global_batch
    ))
    .header(["design", "workers", "mem", "t_iter", "c_iter"]);
    for kind in BaselineKind::ALL {
        match evaluate_baseline(
            kind,
            &model,
            &platform,
            cfg.global_batch,
            funcpipe::platform::pricing::C5_9XLARGE,
        ) {
            Some(r) => t.row([
                kind.name().to_string(),
                r.n_workers.to_string(),
                format!("{}MB", platform.tier(r.tier).mem_mb),
                secs(r.t_iter),
                usd(r.c_iter),
            ]),
            None => t.row([
                kind.name().to_string(),
                "OOM".into(),
                String::new(),
                String::new(),
                String::new(),
            ]),
        }
    }
    t.print();
    Ok(())
}

fn cmd_fig(args: &[String]) -> Result<()> {
    let which = args.get(1).map(String::as_str).unwrap_or("");
    match which {
        "fig1" => funcpipe::bench::fig1(),
        "fig5" => funcpipe::bench::fig5(),
        "fig6" => funcpipe::bench::fig6(),
        "fig7" => funcpipe::bench::fig7(),
        "fig8" => funcpipe::bench::fig8(),
        "fig9" => funcpipe::bench::fig9(),
        "fig10" => funcpipe::bench::fig10(),
        "fig11" => funcpipe::bench::fig11(),
        "table3" => funcpipe::bench::table3(),
        other => bail!("unknown figure {other:?}"),
    }
    Ok(())
}
