//! # FuncPipe
//!
//! A pipelined serverless framework for fast and cost-efficient training of
//! deep learning models — reproduction of Liu et al., *Proc. ACM Meas.
//! Anal. Comput. Syst.* 6(3):47, 2022 (DOI 10.1145/3570607).
//!
//! Architecture (three layers, python never on the hot path):
//! * **L3 (this crate)** — the rust coordinator: serverless-platform
//!   substrate, pipeline scheduler, storage-based collectives including the
//!   paper's pipelined scatter-reduce, the MIQP partition/resource
//!   co-optimizer, profiler, function manager and trainer — all fronted by
//!   the [`experiment`] session API (`Experiment` + serializable
//!   `PlanArtifact` + typed `Report`s), which the CLI and the figure
//!   generators are thin shells over.
//! * **L2** — `python/compile/model.py`: staged transformer fwd/bwd in JAX,
//!   AOT-lowered once to HLO text in `artifacts/`.
//! * **L1** — `python/compile/kernels/`: Pallas kernels (fused linear,
//!   gradient merge) called from L2.
//!
//! See DESIGN.md for the module inventory and the experiment index.

// The collective call signatures mirror the paper's parameter lists
// (store, group, round, rank, n, grads, merge, timeout, …); bundling them
// would only add indirection for the CLI and tests.
#![allow(clippy::too_many_arguments)]

pub mod baselines;
pub mod bench;
pub mod cli;
pub mod collective;
pub mod config;
pub mod coordinator;
pub mod exec;
pub mod experiment;
pub mod fleet;
pub mod model;
pub mod pipeline;
pub mod planner;
pub mod platform;
pub mod profiler;
pub mod replan;
pub mod runtime;
pub mod scenario;
pub mod serve;
pub mod simcore;
pub mod trainer;
pub mod util;
