//! Elastic mid-run re-planning (SMLT, arxiv 2205.01853): the drift
//! observation layer, the measured-profile overlay fed back into the
//! planner, and the layer-addressed checkpoint format that lets a
//! *different* partitioning restore a run's parameters.
//!
//! Everything here lives on the deterministic virtual clock: the
//! observed per-stage times are the exact lens-stretched durations the
//! trainer charges (`Injector::iter_virtual_s`), so a re-plan decision
//! is a pure function of `(scenario, seed, plan)` and replays
//! byte-identically. The migration loop itself is driven by
//! [`Experiment::train_replan`](crate::experiment::Experiment), which
//! splits a run into per-plan segments over one shared store:
//!
//! ```text
//! observe -> drift? -> quiesce at the generation boundary
//!         -> checkpoint layer shards (ckpt/g{gen}/l{layer})
//!         -> re-plan under the MeasuredProfile overlay
//!         -> re-partition workers -> restore shards -> continue
//! ```

use std::collections::VecDeque;

use anyhow::{bail, Result};

use crate::scenario::Injector;

/// Smoothing factor of the iteration-time EWMA (recent-biased: the
/// detector should react within a handful of steps, not an epoch).
pub const EWMA_ALPHA: f64 = 0.5;

/// User-facing re-planning knobs (`train --replan --replan-threshold
/// --replan-window --replan-max`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ReplanSpec {
    /// Drift trigger ratio: re-plan when the EWMA of the observed
    /// iteration time exceeds `threshold ×` the plan's prediction.
    pub threshold: f64,
    /// Consecutive drifting steps required before triggering (K), and
    /// the capacity of the observation ring.
    pub window: usize,
    /// Maximum number of migrations in one run: the [`DriftDetector`]
    /// re-arms after each adopted migration, so checkpoint generations
    /// can chain `g0 → g1 → g2 …` up to this cap. Static lenses drift
    /// once and stabilize on the calibrated tick (one boundary); the
    /// time-varying lenses can keep drifting, which is what the cap
    /// bounds.
    pub max_replans: usize,
}

impl Default for ReplanSpec {
    fn default() -> Self {
        Self { threshold: 1.2, window: 3, max_replans: 4 }
    }
}

impl ReplanSpec {
    pub fn validate(&self) -> Result<()> {
        if !self.threshold.is_finite() || self.threshold <= 1.0 {
            bail!(
                "--replan-threshold must be a finite ratio > 1.0 (got {})",
                self.threshold
            );
        }
        if self.window == 0 {
            bail!("--replan-window must be >= 1");
        }
        if self.max_replans == 0 {
            bail!("--replan-max must be >= 1");
        }
        Ok(())
    }
}

/// One pipeline stage's observed times for one step (virtual seconds).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StageObs {
    pub fwd_s: f64,
    pub bwd_s: f64,
    pub sync_s: f64,
}

impl StageObs {
    pub fn total_s(&self) -> f64 {
        self.fwd_s + self.bwd_s + self.sync_s
    }
}

/// Ring of per-stage observed fwd/bwd/sync seconds plus an EWMA of the
/// pipeline-gated iteration time — the drift detector's input and the
/// measured-profile's source. Recorded by the coordinator when
/// `TrainConfig::observe` is set (virtual-clock runs only).
#[derive(Debug, Clone)]
pub struct StageObservations {
    /// The runtime layer range `[lo, hi)` each pipeline stage executes.
    groups: Vec<(usize, usize)>,
    /// Total runtime (manifest) layers across all groups.
    n_layers: usize,
    /// Ring capacity (= the drift window K).
    window: usize,
    /// The plan's predicted iteration time the observations are
    /// measured against.
    predicted_iter_s: f64,
    ring: VecDeque<Vec<StageObs>>,
    ewma_iter_s: f64,
    steps_seen: usize,
    /// Worst (smallest) bandwidth lens multiplier seen on any worker.
    min_bandwidth_mult: f64,
}

impl StageObservations {
    pub fn new(
        groups: Vec<(usize, usize)>,
        n_layers: usize,
        window: usize,
        predicted_iter_s: f64,
    ) -> Self {
        Self {
            groups,
            n_layers,
            window: window.max(1),
            predicted_iter_s,
            ring: VecDeque::new(),
            ewma_iter_s: predicted_iter_s,
            steps_seen: 0,
            min_bandwidth_mult: 1.0,
        }
    }

    /// Record one step: per-stage observed times, the pipeline-gated
    /// iteration time, and the worst bandwidth multiplier of the step.
    pub fn push_step(
        &mut self,
        stage_obs: Vec<StageObs>,
        gated_iter_s: f64,
        bandwidth_mult: f64,
    ) {
        if self.ring.len() == self.window {
            self.ring.pop_front();
        }
        self.ring.push_back(stage_obs);
        self.ewma_iter_s = if self.steps_seen == 0 {
            gated_iter_s
        } else {
            EWMA_ALPHA * gated_iter_s + (1.0 - EWMA_ALPHA) * self.ewma_iter_s
        };
        self.steps_seen += 1;
        if bandwidth_mult.is_finite() && bandwidth_mult > 0.0 {
            self.min_bandwidth_mult = self.min_bandwidth_mult.min(bandwidth_mult);
        }
    }

    pub fn groups(&self) -> &[(usize, usize)] {
        &self.groups
    }

    pub fn n_layers(&self) -> usize {
        self.n_layers
    }

    pub fn steps_seen(&self) -> usize {
        self.steps_seen
    }

    pub fn predicted_iter_s(&self) -> f64 {
        self.predicted_iter_s
    }

    pub fn ewma_iter_s(&self) -> f64 {
        self.ewma_iter_s
    }

    pub fn min_bandwidth_mult(&self) -> f64 {
        self.min_bandwidth_mult
    }

    /// Mean observed-over-predicted compute multiplier per stage, from
    /// the ring's window. The prediction apportions the plan's iteration
    /// uniformly across stages (the same convention `observe_step`
    /// records with, so an identity lens yields exactly 1.0).
    pub fn stage_mults(&self) -> Vec<f64> {
        let n = self.groups.len();
        let share = self.predicted_iter_s / n as f64;
        let mut mults = vec![1.0; n];
        if self.ring.is_empty() || share <= 0.0 {
            return mults;
        }
        for (g, m) in mults.iter_mut().enumerate() {
            let mean: f64 = self
                .ring
                .iter()
                .map(|step| step[g].total_s())
                .sum::<f64>()
                / self.ring.len() as f64;
            *m = mean / share;
        }
        mults
    }
}

/// Sustained-drift detector: fires once the (EWMA-smoothed) observed
/// iteration time has exceeded `threshold × predicted` for `window`
/// consecutive steps.
#[derive(Debug, Clone)]
pub struct DriftDetector {
    threshold: f64,
    window: usize,
    consecutive: usize,
}

impl DriftDetector {
    pub fn new(spec: &ReplanSpec) -> Self {
        Self {
            threshold: spec.threshold,
            window: spec.window.max(1),
            consecutive: 0,
        }
    }

    /// Feed one step's observation; returns `true` when the drift has
    /// been sustained for the full window (trigger).
    pub fn observe(&mut self, observed_iter_s: f64, predicted_iter_s: f64) -> bool {
        if observed_iter_s > self.threshold * predicted_iter_s {
            self.consecutive += 1;
        } else {
            self.consecutive = 0;
        }
        self.consecutive >= self.window
    }
}

/// Measured overrides the planner's `PerfModel` substitutes for the
/// profiled values: per-(merged)-layer compute multipliers and a global
/// link-bandwidth multiplier, tagged with an overlay `epoch` so the
/// stage cache can never serve a stale entry across re-plans (epoch 0
/// is reserved for the profile-only model).
#[derive(Debug, Clone, PartialEq)]
pub struct MeasuredProfile {
    pub epoch: u64,
    /// Observed/profiled compute ratio per planner layer (1.0 = on
    /// profile). Layers beyond the vector default to 1.0.
    pub compute_mult: Vec<f64>,
    /// Observed/profiled link bandwidth ratio (< 1.0 = slower links).
    pub bandwidth_mult: f64,
}

impl MeasuredProfile {
    /// Project runtime-stage observations onto the planner's (merged)
    /// layer axis: planner layer `l` maps to the runtime layer at the
    /// same relative depth, and inherits its group's measured
    /// multiplier.
    pub fn from_observations(
        obs: &StageObservations,
        n_planner_layers: usize,
        epoch: u64,
    ) -> Self {
        let stage_mults = obs.stage_mults();
        let n_rt = obs.n_layers().max(1);
        let mut compute_mult = Vec::with_capacity(n_planner_layers);
        for l in 0..n_planner_layers {
            let rl = (l * n_rt) / n_planner_layers.max(1);
            let g = obs
                .groups()
                .iter()
                .position(|&(lo, hi)| rl >= lo && rl < hi)
                .unwrap_or(0);
            compute_mult.push(stage_mults.get(g).copied().unwrap_or(1.0));
        }
        Self {
            epoch: epoch.max(1),
            compute_mult,
            bandwidth_mult: obs.min_bandwidth_mult(),
        }
    }

    pub fn mult_for_layer(&self, layer: usize) -> f64 {
        self.compute_mult.get(layer).copied().unwrap_or(1.0)
    }
}

/// One recorded re-plan decision, surfaced verbatim in `TrainReport`
/// (table + JSON) so every migration is auditable and replayable.
#[derive(Debug, Clone)]
pub struct ReplanEvent {
    /// Global step after which the migration boundary was placed.
    pub trigger_step: usize,
    /// EWMA-observed iteration time at the trigger (virtual seconds).
    pub observed_iter_s: f64,
    /// The old plan's predicted iteration time.
    pub predicted_iter_s: f64,
    /// Measured per-stage compute multipliers at the trigger.
    pub stage_mults: Vec<f64>,
    pub old_stages: usize,
    pub old_dp: usize,
    pub old_mu: usize,
    pub new_stages: usize,
    pub new_dp: usize,
    pub new_mu: usize,
    /// Winning strategy of the overlay re-plan race.
    pub strategy: String,
    /// Calibrated post-migration iteration time (virtual seconds).
    pub new_iter_s: f64,
    /// Migration cost charged on the virtual clock (worst worker
    /// cold start of the new generation).
    pub migration_s: f64,
    /// Whether the new plan was adopted (it must win back its migration
    /// cost over the remaining steps) or the run continued statically.
    pub adopted: bool,
}

/// The deterministic per-step observation, derived from the same seeded
/// lenses that drive the trainer's virtual clock: each stage's observed
/// time is its uniform share of the base iteration stretched by the
/// slowest lens among its replicas, and the gated iteration time is the
/// global pipeline tick (`Injector::max_iter_virtual_s`). Returns
/// `(per-stage observations, gated iteration seconds, min bandwidth
/// multiplier across workers)`.
pub fn observe_step(
    injector: &Injector,
    groups: &[(usize, usize)],
    dp: usize,
    base_iter_s: f64,
) -> (Vec<StageObs>, f64, f64) {
    let n_groups = groups.len().max(1);
    let share = base_iter_s / n_groups as f64;
    let mut stage_obs = Vec::with_capacity(n_groups);
    let mut min_bw = 1.0f64;
    for g in 0..groups.len() {
        let mut mult = 1.0f64;
        for r in 0..dp {
            let lens = injector.worker(g * dp + r);
            mult = mult.max(lens.compute_mult);
            if lens.bandwidth_mult.is_finite() && lens.bandwidth_mult > 0.0 {
                min_bw = min_bw.min(lens.bandwidth_mult);
            }
        }
        let t = share * mult;
        // fwd/bwd split by the 1:2 compute convention of the zoo
        // profiles; sync time is folded into the gated tick, not
        // attributed per stage.
        stage_obs.push(StageObs {
            fwd_s: t / 3.0,
            bwd_s: 2.0 * t / 3.0,
            sync_s: 0.0,
        });
    }
    (stage_obs, injector.max_iter_virtual_s(base_iter_s), min_bw)
}

// ---- layer groups ------------------------------------------------------

/// The historical 1:1 grouping: one runtime layer per pipeline stage.
pub fn identity_groups(n_layers: usize) -> Vec<(usize, usize)> {
    (0..n_layers).map(|i| (i, i + 1)).collect()
}

/// Split `n_layers` runtime layers into `n_groups` contiguous groups as
/// evenly as possible (earlier groups take the remainder).
pub fn even_groups(n_layers: usize, n_groups: usize) -> Vec<(usize, usize)> {
    let k = n_groups.clamp(1, n_layers.max(1));
    let base = n_layers / k;
    let rem = n_layers % k;
    let mut groups = Vec::with_capacity(k);
    let mut lo = 0;
    for g in 0..k {
        let len = base + usize::from(g < rem);
        groups.push((lo, lo + len));
        lo += len;
    }
    groups
}

/// A valid grouping is a contiguous, non-empty partition of
/// `0..n_layers`.
pub fn validate_groups(groups: &[(usize, usize)], n_layers: usize) -> Result<()> {
    if groups.is_empty() {
        bail!("layer grouping is empty");
    }
    let mut expect = 0;
    for &(lo, hi) in groups {
        if lo != expect || hi <= lo {
            bail!(
                "layer grouping {groups:?} is not a contiguous partition of \
                 0..{n_layers}"
            );
        }
        expect = hi;
    }
    if expect != n_layers {
        bail!("layer grouping {groups:?} does not cover 0..{n_layers}");
    }
    Ok(())
}

// ---- layer-addressed checkpoint keys -----------------------------------

/// Migration shard: one layer's parameters at a plan-generation
/// boundary, written once (by replica 0 of the owning stage) and
/// consumed once by the next generation's leader.
pub fn migration_key(generation: u64, layer: usize) -> String {
    format!("ckpt/g{generation}/l{layer}")
}

/// Intra-generation restart shard: one layer's parameters for one
/// replica's checkpoint/restart cycle (lifetime expiry). Consumed on
/// restore like every other checkpoint.
pub fn restart_key(generation: u64, layer: usize, replica: usize) -> String {
    format!("ckpt/g{generation}/l{layer}/r{replica}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn detector_requires_sustained_drift() {
        let spec = ReplanSpec { threshold: 1.2, window: 3, max_replans: 4 };
        let mut det = DriftDetector::new(&spec);
        assert!(!det.observe(1.5, 1.0));
        assert!(!det.observe(1.5, 1.0));
        // a single on-prediction step resets the streak
        assert!(!det.observe(1.0, 1.0));
        assert!(!det.observe(1.5, 1.0));
        assert!(!det.observe(1.5, 1.0));
        assert!(det.observe(1.5, 1.0));
    }

    #[test]
    fn detector_ignores_drift_below_threshold() {
        let mut det = DriftDetector::new(&ReplanSpec::default());
        for _ in 0..10 {
            assert!(!det.observe(1.19, 1.0));
        }
    }

    #[test]
    fn even_groups_partition_all_layers() {
        for n_layers in 1..12 {
            for n_groups in 1..8 {
                let g = even_groups(n_layers, n_groups);
                validate_groups(&g, n_layers).unwrap();
                assert_eq!(g.len(), n_groups.min(n_layers));
            }
        }
    }

    #[test]
    fn validate_groups_rejects_gaps_and_overlaps() {
        assert!(validate_groups(&[(0, 1), (2, 3)], 3).is_err());
        assert!(validate_groups(&[(0, 2), (1, 3)], 3).is_err());
        assert!(validate_groups(&[(0, 2)], 3).is_err());
        assert!(validate_groups(&[], 3).is_err());
        validate_groups(&identity_groups(3), 3).unwrap();
    }

    #[test]
    fn observations_track_ewma_and_stage_mults() {
        let groups = identity_groups(3);
        let mut obs = StageObservations::new(groups, 3, 3, 1.0);
        let step = vec![
            StageObs { fwd_s: 1.0 / 9.0, bwd_s: 2.0 / 9.0, sync_s: 0.0 },
            StageObs { fwd_s: 1.0 / 9.0, bwd_s: 2.0 / 9.0, sync_s: 0.0 },
            StageObs { fwd_s: 2.0 / 9.0, bwd_s: 4.0 / 9.0, sync_s: 0.0 },
        ];
        for _ in 0..4 {
            obs.push_step(step.clone(), 2.0, 0.5);
        }
        // ring is capped at the window
        assert_eq!(obs.steps_seen(), 4);
        let mults = obs.stage_mults();
        assert!((mults[0] - 1.0).abs() < 1e-9);
        assert!((mults[2] - 2.0).abs() < 1e-9, "{mults:?}");
        // constant stream: EWMA converges onto the observation
        assert!((obs.ewma_iter_s() - 2.0).abs() < 1e-6);
        assert!((obs.min_bandwidth_mult() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn measured_profile_projects_groups_onto_planner_layers() {
        // 3 runtime layers grouped [0,2) + [2,3); 6 planner layers
        let mut obs =
            StageObservations::new(vec![(0, 2), (2, 3)], 3, 2, 1.0);
        obs.push_step(
            vec![
                StageObs { fwd_s: 0.5 / 3.0, bwd_s: 1.0 / 3.0, sync_s: 0.0 },
                StageObs { fwd_s: 1.0 / 3.0, bwd_s: 2.0 / 3.0, sync_s: 0.0 },
            ],
            2.0,
            1.0,
        );
        let p = MeasuredProfile::from_observations(&obs, 6, 1);
        // planner layers 0..4 map to runtime layers 0..2 (group 0,
        // mult 1.0), layers 4..6 to runtime layer 2 (group 1, mult 2.0)
        assert_eq!(p.compute_mult.len(), 6);
        assert!((p.mult_for_layer(0) - 1.0).abs() < 1e-9);
        assert!((p.mult_for_layer(3) - 1.0).abs() < 1e-9);
        assert!((p.mult_for_layer(4) - 2.0).abs() < 1e-9);
        assert!((p.mult_for_layer(5) - 2.0).abs() < 1e-9);
        // epoch 0 is reserved: normalized up
        let p0 = MeasuredProfile::from_observations(&obs, 6, 0);
        assert_eq!(p0.epoch, 1);
    }

    #[test]
    fn replan_spec_validation() {
        assert!(ReplanSpec::default().validate().is_ok());
        assert_eq!(ReplanSpec::default().max_replans, 4);
        let ok = ReplanSpec::default();
        assert!(ReplanSpec { threshold: 1.0, ..ok }.validate().is_err());
        assert!(ReplanSpec { threshold: f64::NAN, ..ok }.validate().is_err());
        assert!(ReplanSpec { window: 0, ..ok }.validate().is_err());
        assert!(ReplanSpec { max_replans: 0, ..ok }.validate().is_err());
    }

    #[test]
    fn checkpoint_keys_are_layer_addressed() {
        assert_eq!(migration_key(0, 2), "ckpt/g0/l2");
        assert_eq!(restart_key(3, 1, 4), "ckpt/g3/l1/r4");
    }
}
