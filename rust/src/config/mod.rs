//! The unified experiment configuration: ONE config drives the whole
//! session lifecycle — `plan`, `simulate`, `train`, `baseline` — through
//! the [`Experiment`](crate::experiment::Experiment) facade. Everything
//! the CLI accepts can also be given as a config file
//! (`funcpipe plan --config exp.json`), and the config serializes back
//! out ([`ExperimentConfig::to_json`]) so it can travel inside a plan
//! artifact (`funcpipe plan --out plan.json`).
//!
//! Historically the trainer had its own disjoint
//! [`TrainConfig`](crate::trainer::TrainConfig) and the chunking knob
//! meant different things on each side; the trainer knobs (`steps`,
//! `lr`, `lifetime_s`, `throttle`, chunking) now live here and
//! `TrainConfig` is derived from this struct (plus the plan) by
//! [`Experiment::train_config`](crate::experiment::Experiment::train_config).

use anyhow::{bail, Context, Result};

use crate::collective::{Chunking, SyncAlgorithm};
use crate::model::{zoo, MergeCriterion, ModelProfile};
use crate::platform::PlatformSpec;
use crate::simcore::ScenarioSpec;
use crate::util::json::Json;

/// Shared validator for every seed-accepting surface — the config file,
/// each subcommand's `--seed` flag, and the serve/SLO replay paths.
/// ONE definition of the bound: a seed must fit a JSON number exactly
/// (≤ 2^53) so reports and artifacts round-trip the value losslessly.
/// Historically only the config path enforced this and a `--seed` on
/// `simulate --plan` slipped past it.
pub fn validate_seed(seed: u64) -> Result<()> {
    if seed > (1u64 << 53) {
        bail!("seed must fit a JSON number exactly (<= 2^53), got {seed}");
    }
    Ok(())
}

/// A fully-resolved experiment configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct ExperimentConfig {
    pub model: String,
    pub platform: String,
    pub global_batch: usize,
    pub micro_batch: usize,
    pub merge_layers: usize,
    pub merge_criterion: MergeCriterion,
    pub sync_alg: SyncAlgorithm,
    pub bandwidth_scale: f64,
    /// Collective chunk size in bytes (0 = unchunked). One knob for the
    /// whole session: the planner's sync model prices it and the trainer
    /// streams gradients with it, so plans are costed with the policy
    /// they will actually run under.
    pub chunk_bytes: usize,
    /// Window of in-flight (uploaded but un-consumed) chunks per worker.
    pub chunks_in_flight: usize,
    pub weights: Vec<(f64, f64)>,
    /// Candidate data-parallel degrees every plan strategy searches
    /// (config key `dp_options`, flag `--dp-options 1,2,4`). Strictly
    /// increasing; each degree must stay within the platform's
    /// concurrency cap (the planner cannot price replicas the platform
    /// will not launch).
    pub dp_options: Vec<usize>,
    // -- trainer session knobs (formerly TrainConfig-only) ---------------
    /// Directory of the AOT artifacts the trainer/profiler execute.
    pub artifacts_dir: String,
    pub steps: usize,
    pub lr: f64,
    /// Simulated function lifetime in seconds (infinite = no restarts).
    /// Omitted from JSON when infinite.
    pub lifetime_s: f64,
    /// Per-worker storage throttle `(bytes/s, latency seconds)`.
    pub throttle: Option<(f64, f64)>,
    // -- scenario lens (simulate AND train) ------------------------------
    /// Serverless scenario applied by the DES on `simulate` and by the
    /// runtime [`Injector`](crate::scenario::Injector) on `train`:
    /// `deterministic` | `cold-start` | `straggler` |
    /// `bandwidth-jitter` | `flaky-network`, or a `+`-joined composite
    /// such as `cold-start+jitter`. A *lens* on execution, not part of the
    /// plan's identity: artifact drift checks ignore it, so one plan can
    /// be replayed under many scenarios on both paths.
    pub scenario: ScenarioSpec,
    /// Seed for the scenario's draws; same seed + scenario ⇒
    /// bit-identical `SimReport`/`TrainReport`.
    pub seed: u64,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        Self {
            model: "amoebanet-d18".into(),
            platform: "aws-lambda".into(),
            global_batch: 64,
            micro_batch: zoo::MICRO_BATCH,
            merge_layers: 8,
            merge_criterion: MergeCriterion::Compute,
            sync_alg: SyncAlgorithm::PipelinedScatterReduce,
            bandwidth_scale: 1.0,
            chunk_bytes: 0,
            chunks_in_flight: Chunking::NONE.in_flight,
            weights: crate::planner::DEFAULT_WEIGHTS.to_vec(),
            dp_options: crate::planner::DEFAULT_DP_OPTIONS.to_vec(),
            artifacts_dir: "artifacts".into(),
            steps: 20,
            lr: 0.2,
            lifetime_s: f64::INFINITY,
            throttle: None,
            scenario: ScenarioSpec::deterministic(),
            seed: 0,
        }
    }
}

impl ExperimentConfig {
    pub fn from_json_text(text: &str) -> Result<Self> {
        Self::from_json(&Json::parse(text).context("parsing config JSON")?)
    }

    /// Parse from an already-parsed JSON object (used directly by the
    /// plan artifact, which embeds the config). Unknown keys are
    /// rejected so config typos fail loudly, like unknown CLI flags.
    pub fn from_json(j: &Json) -> Result<Self> {
        const KNOWN: [&str; 19] = [
            "model",
            "platform",
            "global_batch",
            "micro_batch",
            "merge_layers",
            "merge_criterion",
            "sync",
            "bandwidth_scale",
            "chunk_bytes",
            "chunks_in_flight",
            "weights",
            "dp_options",
            "artifacts_dir",
            "steps",
            "lr",
            "lifetime_s",
            "throttle",
            "scenario",
            "seed",
        ];
        j.check_keys(&KNOWN).context("config")?;
        let mut cfg = Self::default();
        if let Some(v) = j.get("model") {
            cfg.model = v.as_str().context("model must be a string")?.into();
        }
        if let Some(v) = j.get("platform") {
            cfg.platform = v.as_str().context("platform string")?.into();
        }
        if let Some(v) = j.get("global_batch") {
            cfg.global_batch = v.as_usize().context("global_batch")?;
        }
        if let Some(v) = j.get("micro_batch") {
            cfg.micro_batch = v.as_usize().context("micro_batch")?;
        }
        if let Some(v) = j.get("merge_layers") {
            cfg.merge_layers = v.as_usize().context("merge_layers")?;
        }
        if let Some(v) = j.get("merge_criterion") {
            let s = v.as_str().context("merge_criterion string")?;
            cfg.merge_criterion = MergeCriterion::parse(s)
                .with_context(|| format!("unknown merge_criterion {s:?}"))?;
        }
        if let Some(v) = j.get("sync") {
            let s = v.as_str().context("sync string")?;
            cfg.sync_alg = SyncAlgorithm::parse(s)
                .with_context(|| format!("unknown sync {s:?}"))?;
        }
        if let Some(v) = j.get("bandwidth_scale") {
            cfg.bandwidth_scale = v.as_f64().context("bandwidth_scale")?;
        }
        if let Some(v) = j.get("chunk_bytes") {
            cfg.chunk_bytes = v.as_usize().context("chunk_bytes")?;
        }
        if let Some(v) = j.get("chunks_in_flight") {
            cfg.chunks_in_flight = v.as_usize().context("chunks_in_flight")?;
        }
        if let Some(v) = j.get("weights") {
            cfg.weights = v
                .as_arr()
                .context("weights array")?
                .iter()
                .map(|pair| -> Result<(f64, f64)> {
                    let a = pair.as_arr().context("weight pair")?;
                    if a.len() != 2 {
                        bail!("weight pair must have two entries");
                    }
                    Ok((
                        a[0].as_f64().context("w0")?,
                        a[1].as_f64().context("w1")?,
                    ))
                })
                .collect::<Result<Vec<_>>>()?;
        }
        if let Some(v) = j.get("dp_options") {
            cfg.dp_options = v
                .as_arr()
                .context("dp_options array")?
                .iter()
                .map(|d| d.as_usize().context("dp_options entry"))
                .collect::<Result<Vec<_>>>()?;
        }
        if let Some(v) = j.get("artifacts_dir") {
            cfg.artifacts_dir =
                v.as_str().context("artifacts_dir string")?.into();
        }
        if let Some(v) = j.get("steps") {
            cfg.steps = v.as_usize().context("steps")?;
        }
        if let Some(v) = j.get("lr") {
            cfg.lr = v.as_f64().context("lr")?;
        }
        if let Some(v) = j.get("lifetime_s") {
            cfg.lifetime_s = v.as_f64().context("lifetime_s")?;
        }
        if let Some(v) = j.get("throttle") {
            let a = v.as_arr().context("throttle must be [bytes/s, lat_s]")?;
            if a.len() != 2 {
                bail!("throttle must be [bytes/s, lat_s]");
            }
            cfg.throttle = Some((
                a[0].as_f64().context("throttle bytes/s")?,
                a[1].as_f64().context("throttle lat_s")?,
            ));
        }
        if let Some(v) = j.get("scenario") {
            let s = v.as_str().context("scenario string")?;
            cfg.scenario = ScenarioSpec::parse(s).with_context(|| {
                format!(
                    "unknown scenario {s:?} (expected {})",
                    ScenarioSpec::SYNTAX
                )
            })?;
        }
        if let Some(v) = j.get("seed") {
            cfg.seed = v.as_usize().context("seed")? as u64;
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Serialize; exact inverse of [`ExperimentConfig::from_json`].
    /// Non-finite `lifetime_s` (the "no restarts" default) is expressed
    /// by omitting the key, since JSON has no infinity.
    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("model", Json::str(self.model.as_str())),
            ("platform", Json::str(self.platform.as_str())),
            ("global_batch", Json::Num(self.global_batch as f64)),
            ("micro_batch", Json::Num(self.micro_batch as f64)),
            ("merge_layers", Json::Num(self.merge_layers as f64)),
            ("merge_criterion", Json::str(self.merge_criterion.as_str())),
            ("sync", Json::str(self.sync_alg.as_str())),
            ("bandwidth_scale", Json::Num(self.bandwidth_scale)),
            ("chunk_bytes", Json::Num(self.chunk_bytes as f64)),
            ("chunks_in_flight", Json::Num(self.chunks_in_flight as f64)),
            (
                "weights",
                Json::Arr(
                    self.weights
                        .iter()
                        .map(|&(a, b)| {
                            Json::Arr(vec![Json::Num(a), Json::Num(b)])
                        })
                        .collect(),
                ),
            ),
            (
                "dp_options",
                Json::Arr(
                    self.dp_options
                        .iter()
                        .map(|&d| Json::Num(d as f64))
                        .collect(),
                ),
            ),
            ("artifacts_dir", Json::str(self.artifacts_dir.as_str())),
            ("steps", Json::Num(self.steps as f64)),
            ("lr", Json::Num(self.lr)),
            ("scenario", Json::str(self.scenario.name().as_str())),
            ("seed", Json::Num(self.seed as f64)),
        ];
        if self.lifetime_s.is_finite() {
            pairs.push(("lifetime_s", Json::Num(self.lifetime_s)));
        }
        if let Some((bps, lat)) = self.throttle {
            pairs.push((
                "throttle",
                Json::Arr(vec![Json::Num(bps), Json::Num(lat)]),
            ));
        }
        Json::obj(pairs)
    }

    pub fn validate(&self) -> Result<()> {
        if self.global_batch == 0 || self.micro_batch == 0 {
            bail!("batch sizes must be positive");
        }
        if self.global_batch % self.micro_batch != 0 {
            bail!(
                "global_batch {} not divisible by micro_batch {}",
                self.global_batch,
                self.micro_batch
            );
        }
        if self.merge_layers == 0 {
            bail!("merge_layers must be >= 1");
        }
        if !self.bandwidth_scale.is_finite() || self.bandwidth_scale <= 0.0 {
            bail!("bandwidth_scale must be a positive finite number");
        }
        if self.chunks_in_flight == 0 {
            bail!("chunks_in_flight must be >= 1");
        }
        if self.steps == 0 {
            bail!("steps must be >= 1");
        }
        if !(self.lr.is_finite() && self.lr > 0.0) {
            bail!("lr must be a positive finite number");
        }
        // NaN must fail too, so compare through the negation
        if self.lifetime_s.is_nan() || self.lifetime_s <= 0.0 {
            bail!("lifetime_s must be positive");
        }
        if let Some((bps, lat)) = self.throttle {
            if !(bps > 0.0 && lat >= 0.0) {
                bail!("throttle must be (bytes/s > 0, lat_s >= 0)");
            }
        }
        validate_seed(self.seed)?;
        // the wire format carries only the scenario's name, so a config
        // holding hand-tuned parameters (or a non-canonical component
        // order) would serialize lossily and replay with different
        // noise than the session that wrote it — reject it here instead
        // (callers wanting custom parameters use
        // `simulate_iteration_scenario` directly, not the config)
        if ScenarioSpec::parse(&self.scenario.name()).as_ref()
            != Some(&self.scenario)
        {
            bail!(
                "config scenario must use the canonical parameters of {:?} \
                 (select scenarios by name)",
                self.scenario.name()
            );
        }
        let platform = self.resolve_platform()?;
        // the dp search space is shared by every plan strategy; the ONE
        // invariant lives in the planner so config and request layers
        // can never drift
        crate::planner::strategy::validate_dp_options(
            &self.dp_options,
            &platform,
        )?;
        Ok(())
    }

    pub fn resolve_platform(&self) -> Result<PlatformSpec> {
        let p = match self.platform.as_str() {
            "aws-lambda" | "aws" => PlatformSpec::aws_lambda(),
            "alibaba-fc" | "alibaba" => PlatformSpec::alibaba_fc(),
            "local" | "local-sim" => PlatformSpec::local_sim(),
            other => bail!("unknown platform {other:?}"),
        };
        Ok(p.with_bandwidth_scale(self.bandwidth_scale))
    }

    pub fn resolve_model(&self, platform: &PlatformSpec) -> Result<ModelProfile> {
        let m = zoo::by_name(&self.model, platform)
            .with_context(|| format!("unknown model {:?}", self.model))?;
        Ok(crate::model::merge_layers(
            &m,
            self.merge_layers,
            self.merge_criterion,
        ))
    }

    pub fn n_micro_global(&self) -> usize {
        self.global_batch / self.micro_batch
    }

    /// The session's chunked-streaming policy (`Chunking::NONE` when
    /// `chunk_bytes` is 0).
    pub fn chunking(&self) -> Chunking {
        Chunking::new(self.chunk_bytes, self.chunks_in_flight)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_config() {
        let cfg = ExperimentConfig::from_json_text(
            r#"{"model": "bert-large", "platform": "alibaba",
                "global_batch": 256, "merge_layers": 6,
                "merge_criterion": "params", "sync": "scatter-reduce",
                "bandwidth_scale": 4.0, "chunk_bytes": 1048576,
                "weights": [[1, 0], [1, 0.001]]}"#,
        )
        .unwrap();
        assert_eq!(cfg.model, "bert-large");
        assert_eq!(cfg.chunk_bytes, 1 << 20);
        assert_eq!(cfg.weights.len(), 2);
        let p = cfg.resolve_platform().unwrap();
        assert_eq!(p.name, "alibaba-fc");
        let m = cfg.resolve_model(&p).unwrap();
        assert_eq!(m.n_layers(), 6);
    }

    #[test]
    fn parses_trainer_knobs() {
        let cfg = ExperimentConfig::from_json_text(
            r#"{"steps": 7, "lr": 0.05, "lifetime_s": 30.5,
                "throttle": [40000000, 0.002], "chunks_in_flight": 8,
                "artifacts_dir": "my-artifacts"}"#,
        )
        .unwrap();
        assert_eq!(cfg.steps, 7);
        assert_eq!(cfg.lr, 0.05);
        assert_eq!(cfg.lifetime_s, 30.5);
        assert_eq!(cfg.throttle, Some((40.0e6, 0.002)));
        assert_eq!(cfg.chunks_in_flight, 8);
        assert_eq!(cfg.artifacts_dir, "my-artifacts");
        assert_eq!(cfg.chunking().in_flight, 8);
        assert!(!cfg.chunking().is_chunked());
    }

    #[test]
    fn defaults_are_valid() {
        ExperimentConfig::default().validate().unwrap();
    }

    #[test]
    fn seed_bound_is_shared_and_exact() {
        validate_seed(0).unwrap();
        validate_seed(1u64 << 53).unwrap();
        assert!(validate_seed((1u64 << 53) + 1).is_err());
        assert!(validate_seed(u64::MAX).is_err());
        // the config path goes through the same validator
        let mut cfg = ExperimentConfig::default();
        cfg.seed = (1u64 << 53) + 1;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn dp_options_parse_and_validate() {
        let cfg = ExperimentConfig::from_json_text(
            r#"{"dp_options": [1, 2, 8]}"#,
        )
        .unwrap();
        assert_eq!(cfg.dp_options, vec![1, 2, 8]);
        // round-trips like every other knob
        let back = ExperimentConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back, cfg);
        // rejected: empty, zero, duplicates/unsorted, beyond the
        // platform's concurrency cap
        for bad in [
            r#"{"dp_options": []}"#,
            r#"{"dp_options": [0, 2]}"#,
            r#"{"dp_options": [2, 2]}"#,
            r#"{"dp_options": [4, 2]}"#,
            r#"{"dp_options": [1, 100000]}"#,
        ] {
            assert!(
                ExperimentConfig::from_json_text(bad).is_err(),
                "{bad} accepted"
            );
        }
        // the cap is per platform: 300 on alibaba, 1000 on aws
        assert!(ExperimentConfig::from_json_text(
            r#"{"platform": "alibaba", "dp_options": [1, 512]}"#
        )
        .is_err());
        ExperimentConfig::from_json_text(
            r#"{"platform": "aws", "dp_options": [1, 512]}"#,
        )
        .unwrap();
    }

    #[test]
    fn parses_scenario_and_seed() {
        let cfg = ExperimentConfig::from_json_text(
            r#"{"scenario": "straggler", "seed": 7}"#,
        )
        .unwrap();
        assert_eq!(cfg.scenario.name(), "straggler");
        assert_eq!(cfg.seed, 7);
        // round-trips through JSON like every other knob
        let back = ExperimentConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back, cfg);
        // unknown scenario names fail loudly, like unknown flags
        assert!(ExperimentConfig::from_json_text(
            r#"{"scenario": "chaos-monkey"}"#
        )
        .is_err());
        // seeds beyond exact-JSON range are rejected
        assert!(ExperimentConfig::from_json_text(
            r#"{"seed": 36028797018963970}"#
        )
        .is_err());
    }

    #[test]
    fn parses_composite_scenarios() {
        // the `jitter` shorthand and a non-canonical order both
        // normalize to the canonical wire name...
        let cfg = ExperimentConfig::from_json_text(
            r#"{"scenario": "jitter+cold-start", "seed": 3}"#,
        )
        .unwrap();
        assert_eq!(cfg.scenario.name(), "cold-start+bandwidth-jitter");
        assert_eq!(cfg.scenario.components().len(), 2);
        // ...and the normalized name round-trips losslessly
        let back = ExperimentConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back, cfg);
        // contradictions and duplicates are rejected like typos
        for bad in [
            r#"{"scenario": "deterministic+cold-start"}"#,
            r#"{"scenario": "cold-start+cold-start"}"#,
            r#"{"scenario": "cold-start+chaos"}"#,
        ] {
            assert!(
                ExperimentConfig::from_json_text(bad).is_err(),
                "{bad} accepted"
            );
        }
    }

    #[test]
    fn json_roundtrip_is_identity() {
        let cfg = ExperimentConfig {
            model: "resnet101".into(),
            chunk_bytes: 1 << 20,
            throttle: Some((0.5e6, 0.01)),
            lifetime_s: 42.0,
            ..ExperimentConfig::default()
        };
        let j = cfg.to_json();
        let back = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(back, cfg);
        // and the default (infinite lifetime, no throttle) omits both
        let d = ExperimentConfig::default();
        let dj = d.to_json();
        assert!(dj.get("lifetime_s").is_none());
        assert!(dj.get("throttle").is_none());
        assert_eq!(ExperimentConfig::from_json(&dj).unwrap(), d);
    }

    #[test]
    fn rejects_bad_configs() {
        assert!(ExperimentConfig::from_json_text(r#"{"global_batch": 0}"#)
            .is_err());
        assert!(ExperimentConfig::from_json_text(
            r#"{"platform": "azure"}"#
        )
        .is_err());
        assert!(ExperimentConfig::from_json_text(
            r#"{"global_batch": 10, "micro_batch": 4}"#
        )
        .is_err());
        // unknown keys fail loudly, like unknown CLI flags
        assert!(ExperimentConfig::from_json_text(
            r#"{"chunk_byte": 1024}"#
        )
        .is_err());
        assert!(ExperimentConfig::from_json_text(r#"{"steps": 0}"#).is_err());
        for bad in ["0", "-1", "1e400"] {
            assert!(
                ExperimentConfig::from_json_text(&format!(
                    r#"{{"bandwidth_scale": {bad}}}"#
                ))
                .is_err(),
                "bandwidth_scale {bad} accepted"
            );
        }
    }
}
