//! JSON experiment configuration: everything the CLI accepts can also be
//! given as a config file (`funcpipe plan --config exp.json`), the
//! "config system" a downstream user drives sweeps with.

use anyhow::{bail, Context, Result};

use crate::collective::SyncAlgorithm;
use crate::model::{zoo, MergeCriterion, ModelProfile};
use crate::platform::PlatformSpec;
use crate::util::json::Json;

/// A fully-resolved experiment configuration.
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    pub model: String,
    pub platform: String,
    pub global_batch: usize,
    pub micro_batch: usize,
    pub merge_layers: usize,
    pub merge_criterion: MergeCriterion,
    pub sync_alg: SyncAlgorithm,
    pub bandwidth_scale: f64,
    /// Collective chunk size in bytes (0 = unchunked); flows into the
    /// planner's sync model (`plan`/`simulate`). The trainer takes its
    /// chunking from the `train` CLI flags (`--chunk-bytes`,
    /// `--chunks-in-flight`), not from this experiment config.
    pub chunk_bytes: usize,
    pub weights: Vec<(f64, f64)>,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        Self {
            model: "amoebanet-d18".into(),
            platform: "aws-lambda".into(),
            global_batch: 64,
            micro_batch: zoo::MICRO_BATCH,
            merge_layers: 8,
            merge_criterion: MergeCriterion::Compute,
            sync_alg: SyncAlgorithm::PipelinedScatterReduce,
            bandwidth_scale: 1.0,
            chunk_bytes: 0,
            weights: crate::planner::DEFAULT_WEIGHTS.to_vec(),
        }
    }
}

impl ExperimentConfig {
    pub fn from_json_text(text: &str) -> Result<Self> {
        let j = Json::parse(text).context("parsing config JSON")?;
        let mut cfg = Self::default();
        if let Some(v) = j.get("model") {
            cfg.model = v.as_str().context("model must be a string")?.into();
        }
        if let Some(v) = j.get("platform") {
            cfg.platform = v.as_str().context("platform string")?.into();
        }
        if let Some(v) = j.get("global_batch") {
            cfg.global_batch = v.as_usize().context("global_batch")?;
        }
        if let Some(v) = j.get("micro_batch") {
            cfg.micro_batch = v.as_usize().context("micro_batch")?;
        }
        if let Some(v) = j.get("merge_layers") {
            cfg.merge_layers = v.as_usize().context("merge_layers")?;
        }
        if let Some(v) = j.get("merge_criterion") {
            cfg.merge_criterion = match v.as_str() {
                Some("compute") => MergeCriterion::Compute,
                Some("params") => MergeCriterion::ParamSize,
                Some("activations") => MergeCriterion::ActivationSize,
                other => bail!("unknown merge_criterion {other:?}"),
            };
        }
        if let Some(v) = j.get("sync") {
            cfg.sync_alg = match v.as_str() {
                Some("pipelined") => SyncAlgorithm::PipelinedScatterReduce,
                Some("scatter-reduce") => SyncAlgorithm::ScatterReduce,
                other => bail!("unknown sync {other:?}"),
            };
        }
        if let Some(v) = j.get("bandwidth_scale") {
            cfg.bandwidth_scale = v.as_f64().context("bandwidth_scale")?;
        }
        if let Some(v) = j.get("chunk_bytes") {
            cfg.chunk_bytes = v.as_usize().context("chunk_bytes")?;
        }
        if let Some(v) = j.get("weights") {
            cfg.weights = v
                .as_arr()
                .context("weights array")?
                .iter()
                .map(|pair| -> Result<(f64, f64)> {
                    let a = pair.as_arr().context("weight pair")?;
                    Ok((
                        a[0].as_f64().context("w0")?,
                        a[1].as_f64().context("w1")?,
                    ))
                })
                .collect::<Result<Vec<_>>>()?;
        }
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn validate(&self) -> Result<()> {
        if self.global_batch == 0 || self.micro_batch == 0 {
            bail!("batch sizes must be positive");
        }
        if self.global_batch % self.micro_batch != 0 {
            bail!(
                "global_batch {} not divisible by micro_batch {}",
                self.global_batch,
                self.micro_batch
            );
        }
        if self.merge_layers == 0 {
            bail!("merge_layers must be >= 1");
        }
        self.resolve_platform()?;
        Ok(())
    }

    pub fn resolve_platform(&self) -> Result<PlatformSpec> {
        let p = match self.platform.as_str() {
            "aws-lambda" | "aws" => PlatformSpec::aws_lambda(),
            "alibaba-fc" | "alibaba" => PlatformSpec::alibaba_fc(),
            "local" | "local-sim" => PlatformSpec::local_sim(),
            other => bail!("unknown platform {other:?}"),
        };
        Ok(p.with_bandwidth_scale(self.bandwidth_scale))
    }

    pub fn resolve_model(&self, platform: &PlatformSpec) -> Result<ModelProfile> {
        let m = zoo::by_name(&self.model, platform)
            .with_context(|| format!("unknown model {:?}", self.model))?;
        Ok(crate::model::merge_layers(
            &m,
            self.merge_layers,
            self.merge_criterion,
        ))
    }

    pub fn n_micro_global(&self) -> usize {
        self.global_batch / self.micro_batch
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_config() {
        let cfg = ExperimentConfig::from_json_text(
            r#"{"model": "bert-large", "platform": "alibaba",
                "global_batch": 256, "merge_layers": 6,
                "merge_criterion": "params", "sync": "scatter-reduce",
                "bandwidth_scale": 4.0, "chunk_bytes": 1048576,
                "weights": [[1, 0], [1, 0.001]]}"#,
        )
        .unwrap();
        assert_eq!(cfg.model, "bert-large");
        assert_eq!(cfg.chunk_bytes, 1 << 20);
        assert_eq!(cfg.weights.len(), 2);
        let p = cfg.resolve_platform().unwrap();
        assert_eq!(p.name, "alibaba-fc");
        let m = cfg.resolve_model(&p).unwrap();
        assert_eq!(m.n_layers(), 6);
    }

    #[test]
    fn defaults_are_valid() {
        ExperimentConfig::default().validate().unwrap();
    }

    #[test]
    fn rejects_bad_configs() {
        assert!(ExperimentConfig::from_json_text(r#"{"global_batch": 0}"#)
            .is_err());
        assert!(ExperimentConfig::from_json_text(
            r#"{"platform": "azure"}"#
        )
        .is_err());
        assert!(ExperimentConfig::from_json_text(
            r#"{"global_batch": 10, "micro_batch": 4}"#
        )
        .is_err());
    }
}
