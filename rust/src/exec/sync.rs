//! Async synchronization primitives for the executor: a bounded MPSC
//! channel (the backpressure spine of the async `FlowPool`) and a
//! oneshot cell (flush acknowledgements, join results).
//!
//! Both use the register-then-check-under-one-lock protocol: waker
//! registration and state inspection happen under the same mutex, so a
//! producer/consumer that races a registration always observes the
//! waker it must wake — no lost wakeups. Capacity wakes are broadcast
//! (every parked sender re-polls) because channels here are small
//! (`in_flight` ≈ 4–16) and correctness beats elegance.

use std::collections::VecDeque;
use std::future::poll_fn;
use std::sync::{Arc, Mutex};
use std::task::{Poll, Waker};

/// `try_send` failure: the channel is full or the receiver is gone.
/// Carries the value back like `std::sync::mpsc::TrySendError`.
#[derive(Debug)]
pub enum TrySendError<T> {
    Full(T),
    Disconnected(T),
}

struct ChanInner<T> {
    queue: VecDeque<T>,
    cap: usize,
    senders: usize,
    rx_alive: bool,
    send_wakers: Vec<Waker>,
    recv_waker: Option<Waker>,
}

impl<T> ChanInner<T> {
    fn wake_senders(&mut self) -> Vec<Waker> {
        std::mem::take(&mut self.send_wakers)
    }
}

/// Sending half (clonable).
pub struct Sender<T> {
    chan: Arc<Mutex<ChanInner<T>>>,
}

/// Receiving half (single consumer).
pub struct Receiver<T> {
    chan: Arc<Mutex<ChanInner<T>>>,
}

/// Bounded async MPSC channel of capacity `cap` (≥ 1).
pub fn channel<T>(cap: usize) -> (Sender<T>, Receiver<T>) {
    let chan = Arc::new(Mutex::new(ChanInner {
        queue: VecDeque::new(),
        cap: cap.max(1),
        senders: 1,
        rx_alive: true,
        send_wakers: Vec::new(),
        recv_waker: None,
    }));
    (Sender { chan: chan.clone() }, Receiver { chan })
}

impl<T> Clone for Sender<T> {
    fn clone(&self) -> Self {
        self.chan.lock().unwrap().senders += 1;
        Sender { chan: self.chan.clone() }
    }
}

impl<T> Drop for Sender<T> {
    fn drop(&mut self) {
        let waker = {
            let mut g = self.chan.lock().unwrap();
            g.senders -= 1;
            if g.senders == 0 {
                g.recv_waker.take()
            } else {
                None
            }
        };
        if let Some(w) = waker {
            w.wake();
        }
    }
}

impl<T> Sender<T> {
    /// Non-blocking send; returns the value on a full or closed channel.
    pub fn try_send(&self, v: T) -> Result<(), TrySendError<T>> {
        let waker = {
            let mut g = self.chan.lock().unwrap();
            if !g.rx_alive {
                return Err(TrySendError::Disconnected(v));
            }
            if g.queue.len() >= g.cap {
                return Err(TrySendError::Full(v));
            }
            g.queue.push_back(v);
            g.recv_waker.take()
        };
        if let Some(w) = waker {
            w.wake();
        }
        Ok(())
    }

    /// Send, waiting for capacity. `Err(v)` if the receiver is gone.
    pub async fn send(&self, v: T) -> Result<(), T> {
        let mut slot = Some(v);
        poll_fn(move |cx| {
            let waker = {
                let mut g = self.chan.lock().unwrap();
                if !g.rx_alive {
                    return Poll::Ready(Err(slot.take().expect("polled after done")));
                }
                if g.queue.len() >= g.cap {
                    g.send_wakers.push(cx.waker().clone());
                    return Poll::Pending;
                }
                g.queue.push_back(slot.take().expect("polled after done"));
                g.recv_waker.take()
            };
            if let Some(w) = waker {
                w.wake();
            }
            Poll::Ready(Ok(()))
        })
        .await
    }
}

impl<T> Receiver<T> {
    /// Receive the next value; `None` once every sender is dropped and
    /// the queue is drained.
    pub async fn recv(&mut self) -> Option<T> {
        poll_fn(|cx| {
            let (out, wakers) = {
                let mut g = self.chan.lock().unwrap();
                match g.queue.pop_front() {
                    Some(v) => (Poll::Ready(Some(v)), g.wake_senders()),
                    None if g.senders == 0 => (Poll::Ready(None), Vec::new()),
                    None => {
                        g.recv_waker = Some(cx.waker().clone());
                        (Poll::Pending, Vec::new())
                    }
                }
            };
            for w in wakers {
                w.wake();
            }
            out
        })
        .await
    }
}

impl<T> Drop for Receiver<T> {
    fn drop(&mut self) {
        let wakers = {
            let mut g = self.chan.lock().unwrap();
            g.rx_alive = false;
            g.queue.clear();
            g.wake_senders()
        };
        for w in wakers {
            w.wake();
        }
    }
}

struct OnceInner<T> {
    value: Option<T>,
    tx_alive: bool,
    waker: Option<Waker>,
}

/// Sending half of a oneshot cell.
pub struct OnceSender<T> {
    cell: Arc<Mutex<OnceInner<T>>>,
}

/// Receiving half of a oneshot cell: a future yielding `Err(())` if the
/// sender was dropped without sending.
pub struct OnceReceiver<T> {
    cell: Arc<Mutex<OnceInner<T>>>,
}

/// Single-value rendezvous cell.
pub fn oneshot<T>() -> (OnceSender<T>, OnceReceiver<T>) {
    let cell = Arc::new(Mutex::new(OnceInner {
        value: None,
        tx_alive: true,
        waker: None,
    }));
    (OnceSender { cell: cell.clone() }, OnceReceiver { cell })
}

impl<T> OnceSender<T> {
    pub fn send(self, v: T) {
        let waker = {
            let mut g = self.cell.lock().unwrap();
            g.value = Some(v);
            g.waker.take()
        };
        if let Some(w) = waker {
            w.wake();
        }
    }
}

impl<T> Drop for OnceSender<T> {
    fn drop(&mut self) {
        let waker = {
            let mut g = self.cell.lock().unwrap();
            g.tx_alive = false;
            g.waker.take()
        };
        if let Some(w) = waker {
            w.wake();
        }
    }
}

impl<T> std::future::Future for OnceReceiver<T> {
    type Output = Result<T, ()>;

    fn poll(
        self: std::pin::Pin<&mut Self>,
        cx: &mut std::task::Context<'_>,
    ) -> Poll<Self::Output> {
        let mut g = self.cell.lock().unwrap();
        if let Some(v) = g.value.take() {
            return Poll::Ready(Ok(v));
        }
        if !g.tx_alive {
            return Poll::Ready(Err(()));
        }
        g.waker = Some(cx.waker().clone());
        Poll::Pending
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::{block_on, spawn};

    #[test]
    fn bounded_channel_backpressures_and_drains() {
        let (tx, mut rx) = channel::<usize>(2);
        let producer = spawn(async move {
            for i in 0..50 {
                tx.send(i).await.expect("receiver alive");
            }
        });
        let got = block_on(async move {
            let mut got = Vec::new();
            while let Some(v) = rx.recv().await {
                got.push(v);
            }
            got
        });
        block_on(producer).unwrap();
        assert_eq!(got, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn try_send_reports_full_then_recovers() {
        let (tx, mut rx) = channel::<u8>(1);
        tx.try_send(1).unwrap();
        match tx.try_send(2) {
            Err(TrySendError::Full(2)) => {}
            other => panic!("expected Full(2), got {other:?}"),
        }
        assert_eq!(block_on(rx.recv()), Some(1));
        tx.try_send(2).unwrap();
        drop(tx);
        assert_eq!(block_on(rx.recv()), Some(2));
        assert_eq!(block_on(rx.recv()), None);
    }

    #[test]
    fn dropped_receiver_disconnects_senders() {
        let (tx, rx) = channel::<u8>(1);
        drop(rx);
        match tx.try_send(9) {
            Err(TrySendError::Disconnected(9)) => {}
            other => panic!("expected Disconnected(9), got {other:?}"),
        }
        assert!(block_on(tx.send(9)).is_err());
    }

    #[test]
    fn oneshot_delivers_and_reports_drops() {
        let (tx, rx) = oneshot::<u32>();
        tx.send(5);
        assert_eq!(block_on(rx), Ok(5));
        let (tx2, rx2) = oneshot::<u32>();
        drop(tx2);
        assert_eq!(block_on(rx2), Err(()));
    }
}
