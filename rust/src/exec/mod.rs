//! `exec` — the shared bounded executor behind the real execution path.
//!
//! The real path used to burn two OS threads per worker (`FlowPool`'s
//! uploader/downloader pair) plus a coordinator thread per worker, so a
//! dp=1024 local run wanted ~3000 threads. This module replaces that
//! with the std-only equivalent of a minimal async runtime: a global
//! pool of [`available_parallelism`](std::thread::available_parallelism)
//! worker threads driving per-worker *state machines* (plain `async`
//! futures), so thread count is O(cores) regardless of dp.
//!
//! Design constraints, in order:
//!
//! 1. **No dependencies.** The offline registry carries no crates
//!    (DESIGN.md §3), so this is built from `std::task::Wake`,
//!    `Condvar` and `BinaryHeap` — the same discipline as the simcore
//!    engine, which is the in-repo idiom for event-driven scheduling.
//! 2. **Determinism lives above the executor.** Task interleaving is
//!    scheduler-dependent; every deterministic quantity in the trainer
//!    (virtual clock, lens draws, replica-slot aggregation, store
//!    counters) is keyed by worker/replica/generation ids and commutes
//!    across interleavings — see DESIGN.md §12.
//! 3. **Blocking compatibility.** Every historical blocking entry point
//!    survives as a [`block_on`] wrapper, so tests and examples that
//!    spawn OS threads keep working unchanged.
//!
//! Pieces: [`spawn`]/[`JoinHandle`] (task submission), [`block_on`]
//! (sync↔async bridge, safe on any non-pool thread), [`sleep`] (timer
//! wheel thread), and the [`sync`] primitives (bounded MPSC channel +
//! oneshot) the async `FlowPool` is built from.

pub mod sync;
pub mod timer;

pub use timer::{sleep, sleep_until};

use std::collections::VecDeque;
use std::future::Future;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::pin::Pin;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::task::{Context, Poll, Wake, Waker};
use std::thread;

/// A spawned task panicked; carries the panic payload.
pub struct Panicked(pub Box<dyn std::any::Any + Send + 'static>);

impl std::fmt::Debug for Panicked {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str("Panicked")
    }
}

type BoxFuture = Pin<Box<dyn Future<Output = ()> + Send + 'static>>;

struct Pool {
    queue: Mutex<VecDeque<Arc<Task>>>,
    available: Condvar,
    size: usize,
}

/// One schedulable unit. The future lives under its own mutex: a task
/// re-queued by a wake that raced an in-progress poll simply blocks on
/// the slot until the poll finishes, then polls again (a benign
/// spurious poll) — no lost wakeups, no double polls.
struct Task {
    fut: Mutex<Option<BoxFuture>>,
    queued: AtomicBool,
    pool: &'static Pool,
}

impl Wake for Task {
    fn wake(self: Arc<Self>) {
        if !self.queued.swap(true, Ordering::AcqRel) {
            let pool = self.pool;
            pool.queue.lock().unwrap().push_back(self);
            pool.available.notify_one();
        }
    }
}

fn worker_loop(pool: &'static Pool) {
    loop {
        let task = {
            let mut q = pool.queue.lock().unwrap();
            loop {
                if let Some(t) = q.pop_front() {
                    break t;
                }
                q = pool.available.wait(q).unwrap();
            }
        };
        // clear `queued` before polling so wakes arriving mid-poll
        // re-queue the task instead of being swallowed
        task.queued.store(false, Ordering::Release);
        let waker = Waker::from(task.clone());
        let mut cx = Context::from_waker(&waker);
        let mut slot = task.fut.lock().unwrap();
        if let Some(fut) = slot.as_mut() {
            if fut.as_mut().poll(&mut cx).is_ready() {
                *slot = None;
            }
        }
    }
}

fn pool() -> &'static Pool {
    static POOL: OnceLock<&'static Pool> = OnceLock::new();
    POOL.get_or_init(|| {
        let size = thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(4)
            .max(2);
        let pool: &'static Pool = Box::leak(Box::new(Pool {
            queue: Mutex::new(VecDeque::new()),
            available: Condvar::new(),
            size,
        }));
        for k in 0..size {
            thread::Builder::new()
                .name(format!("exec-{k}"))
                .spawn(move || worker_loop(pool))
                .expect("spawn executor worker");
        }
        pool
    })
}

/// Number of pool threads (== `available_parallelism`, min 2). The
/// dp=256 stress test asserts peak process thread count stays O(this).
pub fn pool_size() -> usize {
    pool().size
}

struct JoinInner<T> {
    result: Option<Result<T, Panicked>>,
    waker: Option<Waker>,
}

/// Handle to a spawned task; awaiting it yields the task's output (or
/// [`Panicked`] if the task panicked — the pool thread survives).
pub struct JoinHandle<T> {
    state: Arc<Mutex<JoinInner<T>>>,
}

impl<T> Future for JoinHandle<T> {
    type Output = Result<T, Panicked>;

    fn poll(self: Pin<&mut Self>, cx: &mut Context<'_>) -> Poll<Self::Output> {
        let mut g = self.state.lock().unwrap();
        match g.result.take() {
            Some(r) => Poll::Ready(r),
            None => {
                g.waker = Some(cx.waker().clone());
                Poll::Pending
            }
        }
    }
}

/// Submit a future to the shared pool. Panics inside the task are
/// caught at the poll boundary and surface through the handle.
pub fn spawn<F>(fut: F) -> JoinHandle<F::Output>
where
    F: Future + Send + 'static,
    F::Output: Send + 'static,
{
    let state = Arc::new(Mutex::new(JoinInner { result: None, waker: None }));
    let s2 = state.clone();
    let wrapped = async move {
        let mut fut = Box::pin(fut);
        let result = std::future::poll_fn(move |cx| {
            match catch_unwind(AssertUnwindSafe(|| fut.as_mut().poll(cx))) {
                Ok(Poll::Ready(v)) => Poll::Ready(Ok(v)),
                Ok(Poll::Pending) => Poll::Pending,
                Err(p) => Poll::Ready(Err(Panicked(p))),
            }
        })
        .await;
        let waker = {
            let mut g = s2.lock().unwrap();
            g.result = Some(result);
            g.waker.take()
        };
        if let Some(w) = waker {
            w.wake();
        }
    };
    let task = Arc::new(Task {
        fut: Mutex::new(Some(Box::pin(wrapped))),
        queued: AtomicBool::new(false),
        pool: pool(),
    });
    Waker::from(task).wake();
    JoinHandle { state }
}

struct ThreadWaker(thread::Thread);

impl Wake for ThreadWaker {
    fn wake(self: Arc<Self>) {
        self.0.unpark();
    }
}

/// Drive `fut` to completion on the calling thread (parking between
/// polls). This is the sync↔async bridge every historical blocking API
/// is built on. Call it from OS threads you own — never from inside a
/// pool task, where it would pin a pool slot for the full duration.
pub fn block_on<F: Future>(fut: F) -> F::Output {
    let waker = Waker::from(Arc::new(ThreadWaker(thread::current())));
    let mut cx = Context::from_waker(&waker);
    let mut fut = std::pin::pin!(fut);
    loop {
        match fut.as_mut().poll(&mut cx) {
            Poll::Ready(v) => return v,
            Poll::Pending => thread::park(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::{Duration, Instant};

    #[test]
    fn spawn_and_join_roundtrip() {
        let h = spawn(async { 21 * 2 });
        assert_eq!(block_on(h).unwrap(), 42);
    }

    #[test]
    fn tasks_interleave_beyond_pool_size() {
        // 4 × pool_size tasks that each await a timer: with blocking
        // threads this would need 4× the threads; here they multiplex
        let n = pool_size() * 4;
        let handles: Vec<_> = (0..n)
            .map(|i| {
                spawn(async move {
                    sleep(Duration::from_millis(20)).await;
                    i
                })
            })
            .collect();
        let start = Instant::now();
        let mut sum = 0usize;
        for h in handles {
            sum += block_on(h).unwrap();
        }
        assert_eq!(sum, n * (n - 1) / 2);
        assert!(
            start.elapsed() < Duration::from_secs(5),
            "timer tasks serialized instead of multiplexing"
        );
    }

    #[test]
    fn panics_surface_through_the_handle() {
        let h = spawn(async {
            panic!("boom");
            #[allow(unreachable_code)]
            ()
        });
        assert!(block_on(h).is_err());
        // the pool survives the panic
        let h2 = spawn(async { 7 });
        assert_eq!(block_on(h2).unwrap(), 7);
    }

    #[test]
    fn sleep_waits_roughly_the_requested_time() {
        let start = Instant::now();
        block_on(sleep(Duration::from_millis(50)));
        let dt = start.elapsed();
        assert!(dt >= Duration::from_millis(45), "woke early: {dt:?}");
    }
}
