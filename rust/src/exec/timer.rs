//! The executor's timer: one OS thread owning a min-heap of deadlines.
//!
//! [`sleep`]/[`sleep_until`] futures register `(deadline, waker)` pairs;
//! the timer thread waits on a `Condvar` until the earliest deadline
//! (or a new, earlier registration) and wakes the due tasks. Re-polling
//! a not-yet-due `Sleep` re-registers it — duplicate entries fire as
//! harmless spurious wakes, which the task model tolerates by design.

use std::cmp::{Ordering, Reverse};
use std::collections::BinaryHeap;
use std::future::Future;
use std::pin::Pin;
use std::sync::{Condvar, Mutex, OnceLock};
use std::task::{Context, Poll, Waker};
use std::time::{Duration, Instant};

struct Entry {
    at: Instant,
    seq: u64,
    waker: Waker,
}

impl PartialEq for Entry {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}

impl Eq for Entry {}

impl PartialOrd for Entry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Entry {
    fn cmp(&self, other: &Self) -> Ordering {
        (self.at, self.seq).cmp(&(other.at, other.seq))
    }
}

struct TimerState {
    heap: BinaryHeap<Reverse<Entry>>,
    seq: u64,
}

struct Timer {
    state: Mutex<TimerState>,
    cond: Condvar,
}

impl Timer {
    fn run(&self) {
        let mut g = self.state.lock().unwrap();
        loop {
            let now = Instant::now();
            let mut due: Vec<Waker> = Vec::new();
            while let Some(Reverse(e)) = g.heap.peek() {
                if e.at <= now {
                    due.push(g.heap.pop().unwrap().0.waker);
                } else {
                    break;
                }
            }
            if !due.is_empty() {
                drop(g);
                for w in due {
                    w.wake();
                }
                g = self.state.lock().unwrap();
                continue;
            }
            let wait =
                g.heap.peek().map(|Reverse(e)| e.at.saturating_duration_since(now));
            g = match wait {
                Some(d) => self.cond.wait_timeout(g, d).unwrap().0,
                None => self.cond.wait(g).unwrap(),
            };
        }
    }
}

fn timer() -> &'static Timer {
    static T: OnceLock<&'static Timer> = OnceLock::new();
    T.get_or_init(|| {
        let t: &'static Timer = Box::leak(Box::new(Timer {
            state: Mutex::new(TimerState { heap: BinaryHeap::new(), seq: 0 }),
            cond: Condvar::new(),
        }));
        std::thread::Builder::new()
            .name("exec-timer".into())
            .spawn(move || t.run())
            .expect("spawn executor timer");
        t
    })
}

/// Arm a one-shot wake at `at` for `waker`. Used by deadline-bearing
/// futures (e.g. the async store fetches) that want a timeout wake
/// without re-registering on every poll.
pub fn register(at: Instant, waker: Waker) {
    let t = timer();
    let mut g = t.state.lock().unwrap();
    let seq = g.seq;
    g.seq += 1;
    g.heap.push(Reverse(Entry { at, seq, waker }));
    drop(g);
    t.cond.notify_one();
}

/// Future resolving once `Instant::now() >= at`.
pub struct Sleep {
    at: Instant,
}

impl Future for Sleep {
    type Output = ();

    fn poll(self: Pin<&mut Self>, cx: &mut Context<'_>) -> Poll<()> {
        if Instant::now() >= self.at {
            Poll::Ready(())
        } else {
            register(self.at, cx.waker().clone());
            Poll::Pending
        }
    }
}

/// Asynchronously wait for `d` without occupying a pool thread.
pub fn sleep(d: Duration) -> Sleep {
    Sleep { at: Instant::now() + d }
}

/// Asynchronously wait until the absolute instant `at`.
pub fn sleep_until(at: Instant) -> Sleep {
    Sleep { at }
}
