//! The §5.1 baselines: LambdaML, HybridPS and their gradient-accumulation
//! variants, with the resource-allocation strategies the paper describes
//! and analytic iteration-time/cost models consistent with the FuncPipe
//! performance model (same compute profiles, same bandwidth substrate).

use crate::collective::{ps_sync_time, sync_time, SyncAlgorithm};
use crate::model::zoo::MICRO_BATCH;
use crate::model::ModelProfile;
use crate::platform::pricing::VmType;
use crate::platform::PlatformSpec;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BaselineKind {
    /// Pure serverless DP: max memory tier, max local batch (LambdaML).
    LambdaML,
    /// Hybrid parameter-server (Cirrus-style): same workers + a VM PS.
    HybridPS,
    /// LambdaML + gradient accumulation at batch 1: same worker count,
    /// minimum memory that fits.
    LambdaMLGA,
    /// HybridPS + gradient accumulation.
    HybridPSGA,
}

impl BaselineKind {
    pub const ALL: [BaselineKind; 4] = [
        BaselineKind::LambdaML,
        BaselineKind::HybridPS,
        BaselineKind::LambdaMLGA,
        BaselineKind::HybridPSGA,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            BaselineKind::LambdaML => "LambdaML",
            BaselineKind::HybridPS => "HybridPS",
            BaselineKind::LambdaMLGA => "LambdaML-GA",
            BaselineKind::HybridPSGA => "HybridPS-GA",
        }
    }

    fn uses_ps(&self) -> bool {
        matches!(self, BaselineKind::HybridPS | BaselineKind::HybridPSGA)
    }

    fn uses_ga(&self) -> bool {
        matches!(self, BaselineKind::LambdaMLGA | BaselineKind::HybridPSGA)
    }
}

/// Evaluated baseline configuration.
#[derive(Debug, Clone)]
pub struct BaselineResult {
    pub kind: BaselineKind,
    pub n_workers: usize,
    pub tier: usize,
    pub local_batch: usize,
    pub t_iter: f64,
    pub c_iter: f64,
    pub compute_s: f64,
    pub sync_s: f64,
}

impl BaselineResult {
    pub fn throughput(&self, global_batch: usize) -> f64 {
        global_batch as f64 / self.t_iter
    }
}

/// Memory needed by a DP worker training the *whole* model with `local`
/// samples per iteration — same accounting as constraint (3b) with one
/// stage covering all layers.
fn dp_worker_mem_bytes(
    model: &ModelProfile,
    platform: &PlatformSpec,
    local: usize,
    n_workers: usize,
) -> u64 {
    let act_per_sample = model.total_act_bytes() / MICRO_BATCH as u64;
    let copies = if n_workers == 1 { 2 } else { 4 };
    act_per_sample * local as u64
        + copies * model.total_param_bytes()
        + platform.base_mem_mb * 1024 * 1024
}

/// GA variant: only one accumulation micro-step (batch 1) resident.
fn ga_worker_mem_bytes(
    model: &ModelProfile,
    platform: &PlatformSpec,
    n_workers: usize,
) -> u64 {
    dp_worker_mem_bytes(model, platform, 1, n_workers)
}

/// Largest local batch that fits on `tier` (0 if even batch-1 OOMs).
pub fn max_local_batch(
    model: &ModelProfile,
    platform: &PlatformSpec,
    tier: usize,
    global_batch: usize,
    n_workers: usize,
) -> usize {
    let cap = platform.tier(tier).mem_bytes();
    let mut best = 0;
    for local in 1..=global_batch {
        if dp_worker_mem_bytes(model, platform, local, n_workers) <= cap {
            best = local;
        } else {
            break;
        }
    }
    best
}

/// Evaluate a baseline on (model, platform, global batch). Returns `None`
/// when no feasible configuration exists (the OOM failures §5.1 reports).
pub fn evaluate_baseline(
    kind: BaselineKind,
    model: &ModelProfile,
    platform: &PlatformSpec,
    global_batch: usize,
    ps_vm: VmType,
) -> Option<BaselineResult> {
    let tier = platform.max_tier();

    // LambdaML strategy: max memory, max local batch => fewest workers.
    // Find the smallest worker count n (dividing the batch) whose local
    // batch fits.
    let mut chosen: Option<(usize, usize)> = None; // (n, local)
    for n in divisors(global_batch) {
        let local = global_batch / n;
        if dp_worker_mem_bytes(model, platform, local, n)
            <= platform.tier(tier).mem_bytes()
        {
            chosen = Some((n, local));
            break; // divisors ascending => fewest workers first
        }
    }
    let (n, local) = chosen?;

    // GA variants keep the worker count but shrink memory to the
    // batch-1 footprint and allocate the smallest tier that fits.
    let (tier, eff_speed_tier) = if kind.uses_ga() {
        let need = ga_worker_mem_bytes(model, platform, n);
        let t = (0..platform.n_tiers())
            .find(|&j| platform.tier(j).mem_bytes() >= need)?;
        (t, t)
    } else {
        (tier, tier)
    };

    // compute: per-sample forward+backward at the worker's tier
    let per_micro =
        model.total_fwd_s(eff_speed_tier) + model.total_bwd_s(eff_speed_tier);
    let per_sample = per_micro / MICRO_BATCH as f64;
    let beta = if n > 1 { platform.beta } else { 1.0 };
    let compute_s = beta * per_sample * local as f64;

    // sync: full-model gradients among n workers
    let w = platform.effective_bandwidth(tier, n);
    let grad_bytes = model.total_param_bytes() as f64;
    let sync_s = if n == 1 {
        0.0
    } else if kind.uses_ps() {
        ps_sync_time(grad_bytes, n, w, ps_vm.bandwidth_bps, 0.01)
    } else {
        sync_time(
            SyncAlgorithm::ScatterReduce,
            grad_bytes,
            n,
            w,
            platform.storage.latency_s,
        )
    };

    let t_iter = compute_s + sync_s;
    let mem_gb = platform.tier(tier).mem_gb() * n as f64;
    let mut c_iter = platform.price_per_gb_s * mem_gb * t_iter;
    if kind.uses_ps() && n > 1 {
        c_iter += ps_vm.cost(t_iter);
    }
    Some(BaselineResult {
        kind,
        n_workers: n,
        tier,
        local_batch: local,
        t_iter,
        c_iter,
        compute_s,
        sync_s,
    })
}

fn divisors(n: usize) -> Vec<usize> {
    (1..=n).filter(|d| n % d == 0).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;
    use crate::platform::pricing::C5_9XLARGE;

    #[test]
    fn lambda_ml_small_batch_single_worker() {
        // bs 16 fits on one 10 GB worker for ResNet101 => no sync time
        let p = PlatformSpec::aws_lambda();
        let m = zoo::resnet101(&p);
        let r = evaluate_baseline(BaselineKind::LambdaML, &m, &p, 16, C5_9XLARGE)
            .unwrap();
        assert_eq!(r.n_workers, 1);
        assert_eq!(r.sync_s, 0.0);
    }

    #[test]
    fn big_model_big_batch_needs_many_workers_and_syncs() {
        let p = PlatformSpec::aws_lambda();
        let m = zoo::amoebanet_d36(&p);
        let r = evaluate_baseline(BaselineKind::LambdaML, &m, &p, 256, C5_9XLARGE)
            .unwrap();
        assert!(r.n_workers > 4, "{r:?}");
        // Fig 1(a): communication dominates compute for D36
        assert!(r.sync_s > r.compute_s, "{r:?}");
    }

    #[test]
    fn ga_uses_less_memory_but_more_time() {
        let p = PlatformSpec::aws_lambda();
        let m = zoo::amoebanet_d18(&p);
        let base = evaluate_baseline(BaselineKind::LambdaML, &m, &p, 64, C5_9XLARGE)
            .unwrap();
        let ga =
            evaluate_baseline(BaselineKind::LambdaMLGA, &m, &p, 64, C5_9XLARGE)
                .unwrap();
        assert!(ga.tier < base.tier);
        assert!(ga.t_iter > base.t_iter);
        assert_eq!(ga.n_workers, base.n_workers);
    }

    #[test]
    fn hybrid_ps_server_bottleneck_at_scale() {
        // §5.2 third observation: PS lags LambdaML for big models/batches
        let p = PlatformSpec::aws_lambda();
        let m = zoo::bert_large(&p);
        let ps = evaluate_baseline(BaselineKind::HybridPS, &m, &p, 256, C5_9XLARGE)
            .unwrap();
        let sr = evaluate_baseline(BaselineKind::LambdaML, &m, &p, 256, C5_9XLARGE)
            .unwrap();
        assert!(ps.n_workers > 8);
        assert!(ps.sync_s > sr.sync_s * 0.8, "ps {ps:?} vs sr {sr:?}");
    }

    #[test]
    fn divisors_ascending() {
        assert_eq!(divisors(12), vec![1, 2, 3, 4, 6, 12]);
    }
}
