//! Task DAG vocabulary for one training iteration.
//!
//! Workers are numbered `stage * dp + replica`. Each task belongs to one
//! worker; dependencies encode both data availability (upload before
//! download) and per-channel serialization (a worker's uplink sends in
//! schedule order), exactly the DAG the paper's *Task Executor* threads
//! consume (§4 "Pipeline task overlap").

/// What a task does. `mb` is the micro-batch index within the worker's
/// share (0..μ).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TaskKind {
    /// Forward compute of micro-batch `mb` on `stage`.
    FwdCompute { stage: usize, mb: usize },
    /// Backward compute (includes the stage-internal rematerialization).
    BwdCompute { stage: usize, mb: usize },
    /// Upload stage output (activation) toward `stage+1`.
    FwdUpload { stage: usize, mb: usize },
    /// Download the previous stage's output into `stage`.
    FwdDownload { stage: usize, mb: usize },
    /// Upload the gradient toward `stage-1`.
    BwdUpload { stage: usize, mb: usize },
    /// Download the next stage's gradient into `stage`.
    BwdDownload { stage: usize, mb: usize },
    /// Intra-stage gradient synchronization across the dp replicas.
    Sync { stage: usize },
}

#[derive(Debug, Clone)]
pub struct Task {
    pub id: usize,
    /// Flat worker id = stage * dp + replica.
    pub worker: usize,
    pub replica: usize,
    pub kind: TaskKind,
    pub deps: Vec<usize>,
}

/// A complete one-iteration schedule.
#[derive(Debug, Clone)]
pub struct Schedule {
    pub tasks: Vec<Task>,
    pub n_stages: usize,
    pub dp: usize,
    pub mu: usize,
}

impl Schedule {
    pub fn n_workers(&self) -> usize {
        self.n_stages * self.dp
    }

    /// Tasks of one worker in creation (= execution) order.
    pub fn worker_tasks(&self, worker: usize) -> Vec<&Task> {
        self.tasks.iter().filter(|t| t.worker == worker).collect()
    }

    /// Sanity: the DAG is acyclic with edges only to lower ids (by
    /// construction), every dep exists, and every worker's compute tasks
    /// are serialized by a dependency chain.
    pub fn validate(&self) -> Result<(), String> {
        for t in &self.tasks {
            for &d in &t.deps {
                if d >= t.id {
                    return Err(format!(
                        "task {} depends on later task {}",
                        t.id, d
                    ));
                }
            }
        }
        // per-worker: each compute task (after the first) must depend
        // (directly) on the previous compute task of that worker
        for w in 0..self.n_workers() {
            let computes: Vec<&Task> = self
                .tasks
                .iter()
                .filter(|t| {
                    t.worker == w
                        && matches!(
                            t.kind,
                            TaskKind::FwdCompute { .. }
                                | TaskKind::BwdCompute { .. }
                        )
                })
                .collect();
            for pair in computes.windows(2) {
                if !pair[1].deps.contains(&pair[0].id) {
                    return Err(format!(
                        "worker {w}: compute {} not chained to {}",
                        pair[1].id, pair[0].id
                    ));
                }
            }
        }
        Ok(())
    }
}
