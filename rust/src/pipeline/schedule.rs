//! Builds the §3.2 schedule: all micro-batches traverse every stage
//! forward, then return in reverse order for backward; boundary transfers
//! are explicit tasks chained per channel so the executor/simulator can
//! overlap them with compute (the paper's communication-as-a-stage).

use crate::model::Plan;
use crate::pipeline::task::{Schedule, Task, TaskKind};

/// Build the one-iteration task DAG for `plan`.
///
/// Per replica lane r (0..d) and stage s, with μ micro-batches per worker:
///   F(s,m)  deps: F(s,m−1), FD(s,m) (if s>0)
///   FU(s,m) deps: F(s,m), FU(s,m−1)                       (s < S−1)
///   FD(s,m) deps: FU(s−1,m), FD(s,m−1)                    (s > 0)
///   B(s,m)  deps: B(s,prev), F(s,μ−1), BD(s,m) (if s<S−1)
///     — backward runs in *reverse* micro order (GPipe §3.2 (ii))
///   BU(s,m) deps: B(s,m), BU(s,prev)                      (s > 0)
///   BD(s,m) deps: BU(s+1,m), BD(s,prev)                   (s < S−1)
///   SYNC(s) deps: B(s, last) of this replica              (d > 1)
pub fn build_schedule(plan: &Plan) -> Schedule {
    let s_cnt = plan.n_stages();
    let d = plan.dp;
    let mu = plan.mu();
    let mut tasks: Vec<Task> = Vec::new();

    // task id lookup tables per replica: [stage][mb]
    let idx = |tbl: &Vec<Vec<Vec<usize>>>, r: usize, s: usize, m: usize| tbl[r][s][m];
    let mut f = vec![vec![vec![usize::MAX; mu]; s_cnt]; d];
    let mut fu = vec![vec![vec![usize::MAX; mu]; s_cnt]; d];
    let mut fd = vec![vec![vec![usize::MAX; mu]; s_cnt]; d];
    let mut b = vec![vec![vec![usize::MAX; mu]; s_cnt]; d];
    let mut bu = vec![vec![vec![usize::MAX; mu]; s_cnt]; d];
    let mut bd = vec![vec![vec![usize::MAX; mu]; s_cnt]; d];

    let push = |tasks: &mut Vec<Task>,
                    worker: usize,
                    replica: usize,
                    kind: TaskKind,
                    deps: Vec<usize>|
     -> usize {
        let id = tasks.len();
        tasks.push(Task { id, worker, replica, kind, deps });
        id
    };

    for r in 0..d {
        // ---- forward wave: stage-major then micro (ids increase along
        // dependencies automatically)
        for s in 0..s_cnt {
            let w = s * d + r;
            for m in 0..mu {
                if s > 0 {
                    let mut deps = vec![idx(&fu, r, s - 1, m)];
                    if m > 0 {
                        deps.push(idx(&fd, r, s, m - 1));
                    }
                    fd[r][s][m] = push(
                        &mut tasks,
                        w,
                        r,
                        TaskKind::FwdDownload { stage: s, mb: m },
                        deps,
                    );
                }
                let mut deps = Vec::new();
                if m > 0 {
                    deps.push(idx(&f, r, s, m - 1));
                }
                if s > 0 {
                    deps.push(idx(&fd, r, s, m));
                }
                f[r][s][m] = push(
                    &mut tasks,
                    w,
                    r,
                    TaskKind::FwdCompute { stage: s, mb: m },
                    deps,
                );
                if s < s_cnt - 1 {
                    let mut deps = vec![idx(&f, r, s, m)];
                    if m > 0 {
                        deps.push(idx(&fu, r, s, m - 1));
                    }
                    fu[r][s][m] = push(
                        &mut tasks,
                        w,
                        r,
                        TaskKind::FwdUpload { stage: s, mb: m },
                        deps,
                    );
                }
            }
        }

        // ---- backward wave: reverse stage order, reverse micro order
        for s in (0..s_cnt).rev() {
            let w = s * d + r;
            let order: Vec<usize> = (0..mu).rev().collect();
            for (k, &m) in order.iter().enumerate() {
                if s < s_cnt - 1 {
                    let mut deps = vec![idx(&bu, r, s + 1, m)];
                    if k > 0 {
                        deps.push(idx(&bd, r, s, order[k - 1]));
                    }
                    bd[r][s][m] = push(
                        &mut tasks,
                        w,
                        r,
                        TaskKind::BwdDownload { stage: s, mb: m },
                        deps,
                    );
                }
                let mut deps = vec![idx(&f, r, s, mu - 1)];
                if k > 0 {
                    deps.push(idx(&b, r, s, order[k - 1]));
                }
                if s < s_cnt - 1 {
                    deps.push(idx(&bd, r, s, m));
                }
                b[r][s][m] = push(
                    &mut tasks,
                    w,
                    r,
                    TaskKind::BwdCompute { stage: s, mb: m },
                    deps,
                );
                if s > 0 {
                    let mut deps = vec![idx(&b, r, s, m)];
                    if k > 0 {
                        deps.push(idx(&bu, r, s, order[k - 1]));
                    }
                    bu[r][s][m] = push(
                        &mut tasks,
                        w,
                        r,
                        TaskKind::BwdUpload { stage: s, mb: m },
                        deps,
                    );
                }
            }
        }
    }

    // ---- per-stage sync after each replica's last backward (m = 0)
    if d > 1 {
        for s in 0..s_cnt {
            for r in 0..d {
                let w = s * d + r;
                let deps = vec![idx(&b, r, s, 0)];
                push(&mut tasks, w, r, TaskKind::Sync { stage: s }, deps);
            }
        }
    }

    let sched = Schedule { tasks, n_stages: s_cnt, dp: d, mu };
    debug_assert!(sched.validate().is_ok(), "{:?}", sched.validate());
    sched
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Plan;
    use crate::pipeline::task::TaskKind;

    fn plan(s: usize, d: usize, m: usize) -> Plan {
        Plan {
            cuts: (0..s - 1).collect(),
            dp: d,
            stage_tiers: vec![0; s],
            n_micro_global: m,
        }
    }

    #[test]
    fn counts_are_right() {
        // S stages, d replicas, μ micros:
        //   compute: 2·S·d·μ ; fwd comm: 2·(S-1)·d·μ ; bwd comm same;
        //   sync: S·d (if d>1)
        let sched = build_schedule(&plan(3, 2, 8)); // μ = 4
        let s = 3;
        let d = 2;
        let mu = 4;
        let expect = 2 * s * d * mu + 2 * 2 * (s - 1) * d * mu + s * d;
        assert_eq!(sched.tasks.len(), expect);
        sched.validate().unwrap();
    }

    #[test]
    fn no_sync_when_dp1() {
        let sched = build_schedule(&plan(2, 1, 4));
        assert!(!sched
            .tasks
            .iter()
            .any(|t| matches!(t.kind, TaskKind::Sync { .. })));
        sched.validate().unwrap();
    }

    #[test]
    fn backward_is_reverse_order() {
        let sched = build_schedule(&plan(2, 1, 4));
        let bwd: Vec<usize> = sched
            .tasks
            .iter()
            .filter_map(|t| match t.kind {
                TaskKind::BwdCompute { stage: 1, mb } => Some(mb),
                _ => None,
            })
            .collect();
        assert_eq!(bwd, vec![3, 2, 1, 0]);
    }

    #[test]
    fn forward_download_waits_for_upload() {
        let sched = build_schedule(&plan(2, 1, 2));
        for t in &sched.tasks {
            if let TaskKind::FwdDownload { stage, mb } = t.kind {
                let dep_ok = t.deps.iter().any(|&d| {
                    matches!(
                        sched.tasks[d].kind,
                        TaskKind::FwdUpload { stage: s2, mb: m2 }
                            if s2 + 1 == stage && m2 == mb
                    )
                });
                assert!(dep_ok, "{t:?}");
            }
        }
    }

    #[test]
    fn single_stage_has_no_transfers() {
        let sched = build_schedule(&plan(1, 2, 4));
        assert!(sched.tasks.iter().all(|t| matches!(
            t.kind,
            TaskKind::FwdCompute { .. }
                | TaskKind::BwdCompute { .. }
                | TaskKind::Sync { .. }
        )));
    }

    #[test]
    fn replicas_are_disjoint_workers() {
        let sched = build_schedule(&plan(2, 2, 4));
        for t in &sched.tasks {
            let (s, _) = match t.kind {
                TaskKind::FwdCompute { stage, mb }
                | TaskKind::BwdCompute { stage, mb }
                | TaskKind::FwdUpload { stage, mb }
                | TaskKind::FwdDownload { stage, mb }
                | TaskKind::BwdUpload { stage, mb }
                | TaskKind::BwdDownload { stage, mb } => (stage, mb),
                TaskKind::Sync { stage } => (stage, 0),
            };
            assert_eq!(t.worker, s * 2 + t.replica);
        }
    }
}
