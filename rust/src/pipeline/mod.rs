//! The FuncPipe training pipeline (§3.2): GPipe-style micro-batch
//! schedule with *communication as a pipeline stage* overlapped with
//! computation.
//!
//! * [`task`] — the task DAG vocabulary shared by the simulator and the
//!   real executor (Fwd/Bwd compute, boundary Upload/Download, Sync);
//! * [`schedule`] — builds the §3.2 schedule for a [`Plan`];
//! * [`simulate`] — translates a schedule into a
//!   [`FlowGraph`](crate::simcore::FlowGraph) executed by the unified
//!   [`simcore`](crate::simcore) engine ("measured" side of Table 3),
//!   optionally under a seeded scenario (cold starts, stragglers,
//!   bandwidth jitter).
//!
//! [`Plan`]: crate::model::Plan

pub mod schedule;
pub mod simulate;
pub mod task;

pub use schedule::build_schedule;
pub use simulate::{
    build_flow_graph, rel_err_pct, simulate_iteration,
    simulate_iteration_scenario, SimResult,
};
pub use task::{Schedule, Task, TaskKind};
