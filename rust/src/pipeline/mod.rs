//! The FuncPipe training pipeline (§3.2): GPipe-style micro-batch
//! schedule with *communication as a pipeline stage* overlapped with
//! computation.
//!
//! * [`task`] — the task DAG vocabulary shared by the simulator and the
//!   real executor (Fwd/Bwd compute, boundary Upload/Download, Sync);
//! * [`schedule`] — builds the §3.2 schedule for a [`Plan`];
//! * [`simulate`] — discrete-event execution of a schedule on the
//!   bandwidth-shared platform model ("measured" side of Table 3).
//!
//! [`Plan`]: crate::model::Plan

pub mod schedule;
pub mod simulate;
pub mod task;

pub use schedule::build_schedule;
pub use simulate::{rel_err_pct, simulate_iteration, SimResult};
pub use task::{Schedule, Task, TaskKind};
