//! Discrete-event execution of a pipeline [`Schedule`] on the shared-
//! bandwidth platform model — the "measured" side of the Table 3
//! model-accuracy reproduction and of Fig. 8.
//!
//! Channels: each worker has a CPU (capacity 1 work-unit/s), an uplink and
//! a downlink; the optional storage-side aggregate cap spans all
//! transfers. Rates are allocated max-min fairly (progressive filling)
//! among active tasks, recomputed at every start/finish event; compute
//! tasks never actually share a CPU because the schedule chains them.
//! Sync tasks expand inline into the exact flow schedule of the selected
//! scatter-reduce algorithm (§3.3).

use crate::collective::SyncAlgorithm;
use crate::model::{ModelProfile, Plan};
use crate::pipeline::schedule::build_schedule;
use crate::pipeline::task::TaskKind;
use crate::platform::PlatformSpec;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
enum Chan {
    Cpu(usize),
    Up(usize),
    Down(usize),
}

#[derive(Debug, Clone)]
struct Job {
    /// Work remaining: seconds for CPU jobs, bytes for transfers.
    remaining: f64,
    chans: Vec<Chan>,
    deps: Vec<usize>,
    /// Extra start delay once deps resolve (storage latency per op).
    delay: f64,
    finish: Option<f64>,
    ready: Option<f64>,
}

/// Simulation output.
#[derive(Debug, Clone)]
pub struct SimResult {
    /// Iteration makespan, seconds.
    pub t_iter: f64,
    /// Iteration cost (eq. (6), same accounting as the perf model).
    pub c_iter: f64,
    /// Makespan excluding sync tasks (for breakdown comparisons).
    pub t_nosync: f64,
}

/// Relative prediction error `|predicted − measured| / measured` in
/// percent — the Table 3 / `simulate` accuracy metric, shared by the
/// CLI's `SimReport` and the bench generators so every surface reports
/// the same number.
pub fn rel_err_pct(predicted: f64, measured: f64) -> f64 {
    (predicted - measured).abs() / measured * 100.0
}

/// Simulate one training iteration of `plan` (deterministic durations).
pub fn simulate_iteration(
    model: &ModelProfile,
    platform: &PlatformSpec,
    plan: &Plan,
    sync_alg: SyncAlgorithm,
) -> SimResult {
    simulate_iteration_noisy(model, platform, plan, sync_alg, None)
}

/// Variant with stochastic duration jitter — the realistic "measured"
/// side for Table 3: the paper attributes its prediction error "mainly
/// to unexpected bandwidth variation", so transfers get a lognormal
/// bandwidth factor (σ = `jitter.1`) and compute a smaller one (σ/3).
/// More workers ⇒ more transfers ⇒ larger aggregate deviation, matching
/// the paper's error growth with batch size.
pub fn simulate_iteration_noisy(
    model: &ModelProfile,
    platform: &PlatformSpec,
    plan: &Plan,
    sync_alg: SyncAlgorithm,
    jitter: Option<(u64, f64)>,
) -> SimResult {
    let t_full = run(model, platform, plan, sync_alg, true, jitter);
    let t_nosync = run(model, platform, plan, sync_alg, false, jitter);
    let c_iter =
        platform.price_per_gb_s * plan.total_mem_gb(platform) * t_full;
    SimResult { t_iter: t_full, c_iter, t_nosync }
}

fn run(
    model: &ModelProfile,
    platform: &PlatformSpec,
    plan: &Plan,
    sync_alg: SyncAlgorithm,
    with_sync: bool,
    jitter: Option<(u64, f64)>,
) -> f64 {
    use crate::util::rng::Rng;
    let mut rng = jitter.map(|(seed, _)| Rng::new(seed));
    let sigma = jitter.map(|(_, s)| s).unwrap_or(0.0);
    let sched = build_schedule(plan);
    let ranges = plan.stage_ranges(model.n_layers());
    let n_workers = sched.n_workers();
    let lat = platform.storage.latency_s;
    let has_comm = sched.n_stages > 1 || plan.dp > 1;
    let beta = if has_comm { platform.beta } else { 1.0 };
    let bw = |s: usize| platform.effective_bandwidth(plan.stage_tiers[s], n_workers);

    let mut jobs: Vec<Job> = Vec::with_capacity(sched.tasks.len() * 2);

    // map schedule task id -> job id (sync tasks map to their final job)
    let mut job_of = vec![usize::MAX; sched.tasks.len()];

    for t in &sched.tasks {
        let deps: Vec<usize> = t.deps.iter().map(|&d| job_of[d]).collect();
        let (s, w) = (stage_of(&t.kind), t.worker);
        let job = match t.kind {
            TaskKind::FwdCompute { stage, .. } => Job {
                remaining: beta
                    * model.range_fwd_s(
                        ranges[stage].0,
                        ranges[stage].1,
                        plan.stage_tiers[stage],
                    ),
                chans: vec![Chan::Cpu(w)],
                deps,
                delay: 0.0,
                finish: None,
                ready: None,
            },
            TaskKind::BwdCompute { stage, .. } => Job {
                remaining: beta
                    * model.range_bwd_s(
                        ranges[stage].0,
                        ranges[stage].1,
                        plan.stage_tiers[stage],
                    ),
                chans: vec![Chan::Cpu(w)],
                deps,
                delay: 0.0,
                finish: None,
                ready: None,
            },
            TaskKind::FwdUpload { stage, .. } => Job {
                remaining: model.layers[ranges[stage].1].out_bytes as f64
                    / bw(stage),
                chans: vec![Chan::Up(w)],
                deps,
                delay: lat,
                finish: None,
                ready: None,
            },
            TaskKind::FwdDownload { stage, .. } => Job {
                remaining: model.layers[ranges[stage - 1].1].out_bytes as f64
                    / bw(stage),
                chans: vec![Chan::Down(w)],
                deps,
                delay: lat,
                finish: None,
                ready: None,
            },
            TaskKind::BwdUpload { stage, .. } => Job {
                remaining: model.layers[ranges[stage].0].grad_bytes as f64
                    / bw(stage),
                chans: vec![Chan::Up(w)],
                deps,
                delay: lat,
                finish: None,
                ready: None,
            },
            TaskKind::BwdDownload { stage, .. } => Job {
                remaining: model.layers[ranges[stage + 1].0].grad_bytes as f64
                    / bw(stage),
                chans: vec![Chan::Down(w)],
                deps,
                delay: lat,
                finish: None,
                ready: None,
            },
            TaskKind::Sync { stage } => {
                // modelled as a single channel-exclusive job of the
                // closed-duration given by the algorithm's flow analysis,
                // occupying both links of the worker (duplex use)
                let dur = if with_sync {
                    let (lo, hi) = ranges[stage];
                    crate::collective::sync_time(
                        sync_alg,
                        model.range_param_bytes(lo, hi) as f64,
                        plan.dp,
                        bw(stage),
                        lat,
                    )
                } else {
                    0.0
                };
                Job {
                    // encode as CPU-style fixed-duration job on a virtual
                    // channel pair (up+down), capacity-normalized below
                    remaining: dur,
                    chans: vec![Chan::Cpu(n_workers + w)], // dedicated chan
                    deps,
                    delay: 0.0,
                    finish: None,
                    ready: None,
                }
            }
        };
        let _ = s;
        let mut job = job;
        if let Some(rng) = rng.as_mut() {
            let is_xfer = !matches!(
                t.kind,
                TaskKind::FwdCompute { .. } | TaskKind::BwdCompute { .. }
            );
            let sg = if is_xfer { sigma } else { sigma / 3.0 };
            // lognormal factor around 1 (bandwidth dip => longer transfer)
            job.remaining *= (sg * rng.normal()).exp();
        }
        job_of[t.id] = jobs.len();
        jobs.push(job);
    }

    // ---- event loop: progressive filling over active jobs -------------
    // channel capacities: CPU (incl. virtual sync channels) = 1 unit/s,
    // links = 1 unit/s too because transfer remaining is pre-divided by
    // bandwidth; the aggregate cap is applied as a rate multiplier on all
    // link jobs via effective_bandwidth (already folded in above).
    let n = jobs.len();
    let mut done = 0usize;
    let mut t = 0.0f64;
    let mut makespan = 0.0f64;

    // resolve initial readiness
    for i in 0..n {
        if jobs[i].deps.is_empty() {
            let d = jobs[i].delay;
            jobs[i].ready = Some(d);
        }
    }

    while done < n {
        let active: Vec<usize> = (0..n)
            .filter(|&i| {
                jobs[i].finish.is_none()
                    && jobs[i].ready.map(|r| r <= t + 1e-12).unwrap_or(false)
            })
            .collect();

        // instantly complete zero-work jobs
        let mut completed: Vec<usize> = active
            .iter()
            .copied()
            .filter(|&i| jobs[i].remaining <= 1e-12)
            .collect();
        if completed.is_empty() && !active.is_empty() {
            // rates: each channel shared equally among its active jobs
            let mut load: std::collections::HashMap<Chan, usize> =
                std::collections::HashMap::new();
            for &i in &active {
                for &c in &jobs[i].chans {
                    *load.entry(c).or_insert(0) += 1;
                }
            }
            let rates: Vec<f64> = active
                .iter()
                .map(|&i| {
                    jobs[i]
                        .chans
                        .iter()
                        .map(|c| 1.0 / load[c] as f64)
                        .fold(f64::INFINITY, f64::min)
                })
                .collect();
            let mut dt = f64::INFINITY;
            for (k, &i) in active.iter().enumerate() {
                dt = dt.min(jobs[i].remaining / rates[k]);
            }
            // next activation
            let next_ready = (0..n)
                .filter(|&i| jobs[i].finish.is_none())
                .filter_map(|i| jobs[i].ready)
                .filter(|&r| r > t + 1e-12)
                .fold(f64::INFINITY, f64::min);
            dt = dt.min(next_ready - t);
            assert!(dt.is_finite() && dt > 0.0, "stuck at t={t}");
            for (k, &i) in active.iter().enumerate() {
                jobs[i].remaining -= rates[k] * dt;
            }
            t += dt;
            completed = active
                .iter()
                .copied()
                .filter(|&i| jobs[i].remaining <= 1e-9)
                .collect();
        } else if completed.is_empty() {
            // nothing active: jump to next readiness
            let next_ready = (0..n)
                .filter(|&i| jobs[i].finish.is_none())
                .filter_map(|i| jobs[i].ready)
                .filter(|&r| r > t + 1e-12)
                .fold(f64::INFINITY, f64::min);
            assert!(next_ready.is_finite(), "deadlock with {} left", n - done);
            t = next_ready;
            continue;
        }

        for &i in &completed {
            jobs[i].finish = Some(t);
            makespan = makespan.max(t);
        }
        done += completed.len();

        // resolve newly-ready jobs
        for i in 0..n {
            if jobs[i].ready.is_some() || jobs[i].finish.is_some() {
                continue;
            }
            let mut all = true;
            let mut latest: f64 = 0.0;
            for &d in &jobs[i].deps {
                match jobs[d].finish {
                    Some(f) => latest = latest.max(f),
                    None => {
                        all = false;
                        break;
                    }
                }
            }
            if all {
                jobs[i].ready = Some(latest + jobs[i].delay);
            }
        }
    }
    makespan
}

fn stage_of(kind: &TaskKind) -> usize {
    match *kind {
        TaskKind::FwdCompute { stage, .. }
        | TaskKind::BwdCompute { stage, .. }
        | TaskKind::FwdUpload { stage, .. }
        | TaskKind::FwdDownload { stage, .. }
        | TaskKind::BwdUpload { stage, .. }
        | TaskKind::BwdDownload { stage, .. }
        | TaskKind::Sync { stage } => stage,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{merge_layers, zoo, MergeCriterion};
    use crate::planner::PerfModel;

    fn fixture() -> (ModelProfile, PlatformSpec) {
        let p = PlatformSpec::aws_lambda();
        let m = merge_layers(&zoo::amoebanet_d18(&p), 6, MergeCriterion::Compute);
        (m, p)
    }

    #[test]
    fn single_worker_sim_matches_model_exactly() {
        let (m, p) = fixture();
        let plan = Plan {
            cuts: vec![],
            dp: 1,
            stage_tiers: vec![7],
            n_micro_global: 4,
        };
        let sim = simulate_iteration(&m, &p, &plan, SyncAlgorithm::PipelinedScatterReduce);
        let perf = PerfModel::new(&m, &p).evaluate(&plan);
        let err = (sim.t_iter - perf.t_iter).abs() / perf.t_iter;
        assert!(err < 1e-6, "sim {} vs model {}", sim.t_iter, perf.t_iter);
    }

    #[test]
    fn pipeline_sim_close_to_model() {
        // Table 3: the closed-form model predicts the DES within ~15%
        let (m, p) = fixture();
        let pm = PerfModel::new(&m, &p);
        for plan in [
            Plan { cuts: vec![2], dp: 1, stage_tiers: vec![7, 7], n_micro_global: 8 },
            Plan { cuts: vec![1, 3], dp: 2, stage_tiers: vec![6, 7, 7], n_micro_global: 16 },
        ] {
            plan.validate(&m, &p).unwrap();
            let sim = simulate_iteration(&m, &p, &plan, SyncAlgorithm::PipelinedScatterReduce);
            let perf = pm.evaluate(&plan);
            let err = (sim.t_iter - perf.t_iter).abs() / perf.t_iter;
            assert!(
                err < 0.2,
                "plan {plan:?}: sim {} vs model {} (err {err:.3})",
                sim.t_iter,
                perf.t_iter
            );
        }
    }

    #[test]
    fn more_micro_batches_take_longer() {
        let (m, p) = fixture();
        let mk = |mm| Plan {
            cuts: vec![2],
            dp: 1,
            stage_tiers: vec![7, 7],
            n_micro_global: mm,
        };
        let a = simulate_iteration(&m, &p, &mk(4), SyncAlgorithm::PipelinedScatterReduce);
        let b = simulate_iteration(&m, &p, &mk(8), SyncAlgorithm::PipelinedScatterReduce);
        assert!(b.t_iter > a.t_iter);
        assert!(b.t_iter < 2.0 * a.t_iter); // pipelining amortizes
    }

    #[test]
    fn sync_algorithm_matters_in_sim() {
        let (m, p) = fixture();
        let plan = Plan {
            cuts: vec![2],
            dp: 8,
            stage_tiers: vec![7, 7],
            n_micro_global: 32,
        };
        let piped = simulate_iteration(&m, &p, &plan, SyncAlgorithm::PipelinedScatterReduce);
        let plain = simulate_iteration(&m, &p, &plan, SyncAlgorithm::ScatterReduce);
        assert!(piped.t_iter < plain.t_iter);
        assert_eq!(piped.t_nosync, plain.t_nosync);
    }
}
