//! Discrete-event execution of a pipeline [`Schedule`] on the shared-
//! bandwidth platform model — the "measured" side of the Table 3
//! model-accuracy reproduction and of Fig. 8.
//!
//! Since the simcore refactor this module only *translates*: a
//! [`Schedule`]'s task DAG plus the boundary transfers become a
//! [`FlowGraph`](crate::simcore::FlowGraph) — compute on per-worker CPU
//! resources, transfers on uplink/downlink resources (work pre-divided
//! by effective bandwidth, so the aggregate storage cap is folded in
//! exactly as the closed-form model does), sync as a fixed-duration
//! occupancy of the worker's virtual channel — and the unified
//! [`simcore`](crate::simcore) engine owns time. Because pipeline and
//! collective simulations now share one graph vocabulary and one
//! engine, [`ScenarioModel`] perturbations (cold starts, stragglers,
//! bandwidth jitter) apply to the whole iteration timeline uniformly.

use crate::collective::SyncAlgorithm;
use crate::model::{ModelProfile, Plan};
use crate::pipeline::schedule::build_schedule;
use crate::pipeline::task::TaskKind;
use crate::platform::PlatformSpec;
use crate::simcore::{execute, FlowGraph, Node, ScenarioModel, ScenarioSpec};

/// Simulation output.
#[derive(Debug, Clone)]
pub struct SimResult {
    /// Iteration makespan, seconds.
    pub t_iter: f64,
    /// Iteration cost (eq. (6), same accounting as the perf model).
    pub c_iter: f64,
    /// Makespan excluding sync tasks (for breakdown comparisons).
    pub t_nosync: f64,
}

/// Relative prediction error `|predicted − measured| / measured` in
/// percent — the Table 3 / `simulate` accuracy metric, shared by the
/// CLI's `SimReport` and the bench generators so every surface reports
/// the same number.
pub fn rel_err_pct(predicted: f64, measured: f64) -> f64 {
    (predicted - measured).abs() / measured * 100.0
}

/// Simulate one training iteration of `plan` (deterministic durations).
pub fn simulate_iteration(
    model: &ModelProfile,
    platform: &PlatformSpec,
    plan: &Plan,
    sync_alg: SyncAlgorithm,
) -> SimResult {
    simulate_iteration_scenario(
        model,
        platform,
        plan,
        sync_alg,
        &ScenarioSpec::deterministic(),
        0,
    )
}

/// Simulate one iteration under a seeded [`ScenarioSpec`] (a single
/// [`ScenarioModel`] or a `+`-composite) — the scenario-lab entry point
/// behind `funcpipe simulate --scenario <name> --seed <n>`.
/// Deterministic in `(scenario, seed)`: identical inputs give
/// bit-identical results (the draws happen in worker-/node-id order
/// inside [`ScenarioModel::apply`], never from unordered iteration, and
/// composite components apply in canonical order from independent
/// tagged streams).
pub fn simulate_iteration_scenario(
    model: &ModelProfile,
    platform: &PlatformSpec,
    plan: &Plan,
    sync_alg: SyncAlgorithm,
    scenario: &ScenarioSpec,
    seed: u64,
) -> SimResult {
    let run = |with_sync: bool| -> f64 {
        let mut g =
            build_flow_graph(model, platform, plan, sync_alg, with_sync);
        scenario.apply(&mut g, seed);
        execute(&g).makespan
    };
    let t_full = run(true);
    let t_nosync = run(false);
    let c_iter =
        platform.price_per_gb_s * plan.total_mem_gb(platform) * t_full;
    SimResult { t_iter: t_full, c_iter, t_nosync }
}

/// Variant with stochastic duration jitter — the realistic "measured"
/// side for Table 3: the paper attributes its prediction error "mainly
/// to unexpected bandwidth variation", so transfers get a lognormal
/// bandwidth factor (σ = `jitter.1`) and compute a smaller one (σ/3).
/// More workers ⇒ more transfers ⇒ larger aggregate deviation, matching
/// the paper's error growth with batch size.
///
/// Delegates to [`simulate_iteration_scenario`] with
/// [`ScenarioModel::BandwidthJitter`], which draws strictly in node-id
/// order from the seeded [`Rng`](crate::util::rng::Rng) — closing the
/// latent nondeterminism risk of the old inline implementation (any
/// draw ordered by an unordered container would have broken replay).
pub fn simulate_iteration_noisy(
    model: &ModelProfile,
    platform: &PlatformSpec,
    plan: &Plan,
    sync_alg: SyncAlgorithm,
    jitter: Option<(u64, f64)>,
) -> SimResult {
    let (scenario, seed) = match jitter {
        None => (ScenarioSpec::deterministic(), 0),
        Some((seed, sigma)) => (
            ScenarioSpec::from_model(ScenarioModel::BandwidthJitter { sigma }),
            seed,
        ),
    };
    simulate_iteration_scenario(model, platform, plan, sync_alg, &scenario, seed)
}

/// Translate one iteration of `plan` into a [`FlowGraph`].
///
/// Channel model (identical to the historical hand-rolled event loop):
/// each worker has a CPU (capacity 1 work-unit/s), an uplink and a
/// downlink; transfer work is pre-divided by the stage tier's
/// *effective* bandwidth, which already folds in the storage-side
/// aggregate cap, so links are unit-capacity too. Sync tasks occupy the
/// worker's dedicated virtual channel for the closed-form duration of
/// the selected algorithm (§3.3) — with `with_sync == false` they stay
/// in the graph at zero duration so scenario draws align between the
/// full and no-sync passes.
pub fn build_flow_graph(
    model: &ModelProfile,
    platform: &PlatformSpec,
    plan: &Plan,
    sync_alg: SyncAlgorithm,
    with_sync: bool,
) -> FlowGraph {
    let sched = build_schedule(plan);
    let ranges = plan.stage_ranges(model.n_layers());
    let n_workers = sched.n_workers();
    let lat = platform.storage.latency_s;
    let has_comm = sched.n_stages > 1 || plan.dp > 1;
    let beta = if has_comm { platform.beta } else { 1.0 };
    let bw =
        |s: usize| platform.effective_bandwidth(plan.stage_tiers[s], n_workers);

    let mut g = FlowGraph::new();
    // map schedule task id -> node id
    let mut node_of = vec![usize::MAX; sched.tasks.len()];

    for t in &sched.tasks {
        let deps: Vec<usize> = t.deps.iter().map(|&d| node_of[d]).collect();
        let w = t.worker;
        let node = match t.kind {
            TaskKind::FwdCompute { stage, .. } => Node::compute(
                w,
                beta * model.range_fwd_s(
                    ranges[stage].0,
                    ranges[stage].1,
                    plan.stage_tiers[stage],
                ),
            )
            .after(deps),
            TaskKind::BwdCompute { stage, .. } => Node::compute(
                w,
                beta * model.range_bwd_s(
                    ranges[stage].0,
                    ranges[stage].1,
                    plan.stage_tiers[stage],
                ),
            )
            .after(deps),
            TaskKind::FwdUpload { stage, .. } => Node::transfer(
                w,
                true,
                model.layers[ranges[stage].1].out_bytes as f64 / bw(stage),
            )
            .after(deps)
            .lag(lat),
            TaskKind::FwdDownload { stage, .. } => Node::transfer(
                w,
                false,
                model.layers[ranges[stage - 1].1].out_bytes as f64 / bw(stage),
            )
            .after(deps)
            .lag(lat),
            TaskKind::BwdUpload { stage, .. } => Node::transfer(
                w,
                true,
                model.layers[ranges[stage].0].grad_bytes as f64 / bw(stage),
            )
            .after(deps)
            .lag(lat),
            TaskKind::BwdDownload { stage, .. } => Node::transfer(
                w,
                false,
                model.layers[ranges[stage + 1].0].grad_bytes as f64 / bw(stage),
            )
            .after(deps)
            .lag(lat),
            TaskKind::Sync { stage } => {
                // the closed-form duration of the algorithm's flow
                // analysis, occupying the worker's virtual channel
                // (duplex use of both links)
                let dur = if with_sync {
                    let (lo, hi) = ranges[stage];
                    crate::collective::sync_time(
                        sync_alg,
                        model.range_param_bytes(lo, hi) as f64,
                        plan.dp,
                        bw(stage),
                        lat,
                    )
                } else {
                    0.0
                };
                Node::fixed(w, dur).after(deps)
            }
        };
        node_of[t.id] = g.add(node);
    }
    g
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{merge_layers, zoo, MergeCriterion};
    use crate::planner::PerfModel;

    fn fixture() -> (ModelProfile, PlatformSpec) {
        let p = PlatformSpec::aws_lambda();
        let m = merge_layers(&zoo::amoebanet_d18(&p), 6, MergeCriterion::Compute);
        (m, p)
    }

    #[test]
    fn single_worker_sim_matches_model_exactly() {
        let (m, p) = fixture();
        let plan = Plan {
            cuts: vec![],
            dp: 1,
            stage_tiers: vec![7],
            n_micro_global: 4,
        };
        let sim = simulate_iteration(&m, &p, &plan, SyncAlgorithm::PipelinedScatterReduce);
        let perf = PerfModel::new(&m, &p).evaluate(&plan);
        let err = (sim.t_iter - perf.t_iter).abs() / perf.t_iter;
        assert!(err < 1e-6, "sim {} vs model {}", sim.t_iter, perf.t_iter);
    }

    #[test]
    fn pipeline_sim_close_to_model() {
        // Table 3: the closed-form model predicts the DES within ~15%
        let (m, p) = fixture();
        let pm = PerfModel::new(&m, &p);
        for plan in [
            Plan { cuts: vec![2], dp: 1, stage_tiers: vec![7, 7], n_micro_global: 8 },
            Plan { cuts: vec![1, 3], dp: 2, stage_tiers: vec![6, 7, 7], n_micro_global: 16 },
        ] {
            plan.validate(&m, &p).unwrap();
            let sim = simulate_iteration(&m, &p, &plan, SyncAlgorithm::PipelinedScatterReduce);
            let perf = pm.evaluate(&plan);
            let err = (sim.t_iter - perf.t_iter).abs() / perf.t_iter;
            assert!(
                err < 0.2,
                "plan {plan:?}: sim {} vs model {} (err {err:.3})",
                sim.t_iter,
                perf.t_iter
            );
        }
    }

    #[test]
    fn more_micro_batches_take_longer() {
        let (m, p) = fixture();
        let mk = |mm| Plan {
            cuts: vec![2],
            dp: 1,
            stage_tiers: vec![7, 7],
            n_micro_global: mm,
        };
        let a = simulate_iteration(&m, &p, &mk(4), SyncAlgorithm::PipelinedScatterReduce);
        let b = simulate_iteration(&m, &p, &mk(8), SyncAlgorithm::PipelinedScatterReduce);
        assert!(b.t_iter > a.t_iter);
        assert!(b.t_iter < 2.0 * a.t_iter); // pipelining amortizes
    }

    #[test]
    fn sync_algorithm_matters_in_sim() {
        let (m, p) = fixture();
        let plan = Plan {
            cuts: vec![2],
            dp: 8,
            stage_tiers: vec![7, 7],
            n_micro_global: 32,
        };
        let piped = simulate_iteration(&m, &p, &plan, SyncAlgorithm::PipelinedScatterReduce);
        let plain = simulate_iteration(&m, &p, &plan, SyncAlgorithm::ScatterReduce);
        assert!(piped.t_iter < plain.t_iter);
        assert_eq!(piped.t_nosync, plain.t_nosync);
    }

    #[test]
    fn scenario_replay_is_bit_identical() {
        let (m, p) = fixture();
        let plan = Plan {
            cuts: vec![2],
            dp: 2,
            stage_tiers: vec![7, 7],
            n_micro_global: 8,
        };
        for name in [
            "cold-start",
            "straggler",
            "bandwidth-jitter",
            "cold-start+straggler+bandwidth-jitter",
            "cold-start+flaky-network",
        ] {
            let s = ScenarioSpec::parse(name).unwrap();
            let a = simulate_iteration_scenario(
                &m, &p, &plan, SyncAlgorithm::PipelinedScatterReduce, &s, 7,
            );
            let b = simulate_iteration_scenario(
                &m, &p, &plan, SyncAlgorithm::PipelinedScatterReduce, &s, 7,
            );
            assert_eq!(a.t_iter.to_bits(), b.t_iter.to_bits(), "{name}");
            assert_eq!(a.t_nosync.to_bits(), b.t_nosync.to_bits(), "{name}");
            let c = simulate_iteration_scenario(
                &m, &p, &plan, SyncAlgorithm::PipelinedScatterReduce, &s, 8,
            );
            assert_ne!(
                a.t_iter.to_bits(),
                c.t_iter.to_bits(),
                "{name}: different seeds must differ"
            );
        }
    }

    #[test]
    fn flaky_network_replays_and_only_adds_waiting() {
        // μ = 8 over two stages ⇒ 64 transfer nodes: two seeds drawing
        // the identical drop pattern is a ~1e-8 event, so the
        // seed-sensitivity assertion is safe for a discrete scenario
        let (m, p) = fixture();
        let plan = Plan {
            cuts: vec![2],
            dp: 2,
            stage_tiers: vec![7, 7],
            n_micro_global: 16,
        };
        let s = ScenarioSpec::parse("flaky-network").unwrap();
        let base = simulate_iteration(
            &m, &p, &plan, SyncAlgorithm::PipelinedScatterReduce,
        );
        let a = simulate_iteration_scenario(
            &m, &p, &plan, SyncAlgorithm::PipelinedScatterReduce, &s, 7,
        );
        let b = simulate_iteration_scenario(
            &m, &p, &plan, SyncAlgorithm::PipelinedScatterReduce, &s, 7,
        );
        assert_eq!(a.t_iter.to_bits(), b.t_iter.to_bits());
        // dead attempts only ever add waiting
        assert!(a.t_iter >= base.t_iter);
        let c = simulate_iteration_scenario(
            &m, &p, &plan, SyncAlgorithm::PipelinedScatterReduce, &s, 8,
        );
        assert_ne!(
            a.t_iter.to_bits(),
            c.t_iter.to_bits(),
            "seeds 7 and 8 drew identical flaky drop patterns"
        );
    }

    #[test]
    fn noisy_wrapper_is_the_jitter_scenario() {
        let (m, p) = fixture();
        let plan = Plan {
            cuts: vec![2],
            dp: 2,
            stage_tiers: vec![7, 7],
            n_micro_global: 8,
        };
        let a = simulate_iteration_noisy(
            &m,
            &p,
            &plan,
            SyncAlgorithm::PipelinedScatterReduce,
            Some((11, 0.15)),
        );
        let b = simulate_iteration_scenario(
            &m,
            &p,
            &plan,
            SyncAlgorithm::PipelinedScatterReduce,
            &ScenarioSpec::from_model(ScenarioModel::BandwidthJitter {
                sigma: 0.15,
            }),
            11,
        );
        assert_eq!(a.t_iter.to_bits(), b.t_iter.to_bits());
        // and None means strictly deterministic
        let c = simulate_iteration_noisy(
            &m,
            &p,
            &plan,
            SyncAlgorithm::PipelinedScatterReduce,
            None,
        );
        let d = simulate_iteration(
            &m,
            &p,
            &plan,
            SyncAlgorithm::PipelinedScatterReduce,
        );
        assert_eq!(c.t_iter.to_bits(), d.t_iter.to_bits());
    }
}
