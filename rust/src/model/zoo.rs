//! The evaluation model zoo — analytic layer profiles for the four models
//! of Table 1, calibrated to the paper's published numbers:
//!
//! | model         | params (MB) | act/sample (MB) |
//! |---------------|-------------|------------------|
//! | ResNet101     |  170        | 198              |
//! | AmoebaNet-D18 |  476        | 432              |
//! | AmoebaNet-D36 |  900        | 697              |
//! | BERT-Large    | 1153        | 263              |
//!
//! Compute-time calibration anchor: Fig. 1(a) — AmoebaNet-D36 takes ~6 s
//! of compute per iteration at local batch 8 on a max-memory Lambda
//! worker. Other models are scaled by parameter count with
//! architecture-specific factors.
//!
//! Layer *shape* matters for the partitioner, so profiles encode the
//! architectural skews: CNNs have activation-heavy early layers and
//! parameter-heavy late layers; BERT is uniform blocks with a fat
//! embedding; AmoebaNet cells are roughly homogeneous with reduction
//! cells at 1/3 and 2/3 depth.

use crate::model::layer::{LayerProfile, ModelProfile};
use crate::platform::PlatformSpec;

const MB: f64 = 1.0e6;

/// Micro-batch size used throughout the evaluation (§5.1).
pub const MICRO_BATCH: usize = 4;

/// The paper's Table 1 evaluation set — the figure/table generators
/// iterate exactly these four so the reproduced averages keep matching
/// the paper. [`by_name`] additionally accepts `vgg16`, the
/// parameter-heavy-tail CNN used by the `planner_search` bench (its fc
/// layers concentrate ~89% of the parameters, which stresses the
/// partitioner differently), deliberately NOT part of this set.
pub const MODEL_NAMES: [&str; 4] =
    ["resnet101", "amoebanet-d18", "amoebanet-d36", "bert-large"];

pub fn by_name(name: &str, platform: &PlatformSpec) -> Option<ModelProfile> {
    match name.to_ascii_lowercase().as_str() {
        "resnet101" => Some(resnet101(platform)),
        "amoebanet-d18" | "amoebanetd18" | "d18" => Some(amoebanet_d18(platform)),
        "amoebanet-d36" | "amoebanetd36" | "d36" => Some(amoebanet_d36(platform)),
        "bert-large" | "bert" => Some(bert_large(platform)),
        "vgg16" | "vgg" => Some(vgg16(platform)),
        _ => None,
    }
}

/// Compute-time vector across tiers for a layer whose per-micro-batch time
/// on a 1-vCPU reference worker is `ref_s`. Parallel speedup saturates:
/// a function's training threads stop scaling past ~4 effective vCPUs
/// (PyTorch CPU training observed behaviour).
fn tier_times(platform: &PlatformSpec, ref_s: f64) -> Vec<f64> {
    platform
        .tiers
        .iter()
        .map(|t| {
            let speed = effective_speed(t.compute_speed);
            ref_s / speed
        })
        .collect()
}

fn effective_speed(vcpus: f64) -> f64 {
    // Amdahl-style saturation: serial fraction ~12%.
    let p = 0.88;
    let v = vcpus.max(0.2);
    1.0 / ((1.0 - p) + p / v)
}

struct Shape {
    /// fraction of params in layer i (normalized later)
    param_w: Vec<f64>,
    /// fraction of activation memory
    act_w: Vec<f64>,
    /// fraction of compute
    comp_w: Vec<f64>,
    /// boundary output sizes relative to act of that layer
    out_frac: Vec<f64>,
}

/// Build a model profile from totals + per-layer weight shapes.
fn build(
    name: &str,
    platform: &PlatformSpec,
    total_param_mb: f64,
    total_act_mb_per_sample: f64,
    total_fwd_s_ref: f64, // full fwd pass, one micro-batch, 1-vCPU ref
    bwd_ratio: f64,
    shape: Shape,
) -> ModelProfile {
    let l = shape.param_w.len();
    let norm = |w: &[f64]| {
        let s: f64 = w.iter().sum();
        w.iter().map(|x| x / s).collect::<Vec<f64>>()
    };
    let pw = norm(&shape.param_w);
    let aw = norm(&shape.act_w);
    let cw = norm(&shape.comp_w);

    let layers = (0..l)
        .map(|i| {
            let param_bytes = (total_param_mb * MB * pw[i]) as u64;
            // a_i is per *micro-batch* in our convention
            let act_bytes = (total_act_mb_per_sample
                * MICRO_BATCH as f64
                * MB
                * aw[i]) as u64;
            let out_bytes =
                ((act_bytes as f64) * shape.out_frac[i]).max(64.0) as u64;
            let fwd_ref = total_fwd_s_ref * cw[i];
            LayerProfile {
                name: format!("{name}/l{i}"),
                param_bytes,
                act_bytes,
                out_bytes,
                grad_bytes: out_bytes, // dL/dx has the output's shape
                fwd_s: tier_times(platform, fwd_ref),
                bwd_s: tier_times(platform, fwd_ref * bwd_ratio),
            }
        })
        .collect();
    let m = ModelProfile { name: name.to_string(), layers };
    debug_assert!(m.validate().is_ok());
    m
}

/// Geometric ramp helper: w_i = r^i.
fn ramp(l: usize, r: f64) -> Vec<f64> {
    (0..l).map(|i| r.powi(i as i32)).collect()
}

/// ResNet101 (170 MB params, 198 MB act/sample): early conv layers are
/// activation-heavy/parameter-light, later blocks the reverse. 24 merged
/// layers (the §4 merge keeps compute balanced — so compute weights are
/// near-uniform by construction).
pub fn resnet101(platform: &PlatformSpec) -> ModelProfile {
    let l = 24;
    build(
        "resnet101",
        platform,
        170.0,
        198.0,
        // ResNet101 ~7.8 GFLOPs fwd @224px; CIFAR-scale inputs are ~10x
        // cheaper; calibrated: ~0.55 s per micro-batch on 1 vCPU ref.
        0.55,
        2.0,
        Shape {
            param_w: ramp(l, 1.22),           // params grow with depth
            act_w: ramp(l, 1.0 / 1.18),       // activations shrink
            comp_w: vec![1.0; l],             // merge balanced compute
            out_frac: (0..l)
                .map(|i| if i % 6 == 5 { 0.5 } else { 0.9 })
                .collect(),
        },
    )
}

fn amoebanet(
    name: &str,
    platform: &PlatformSpec,
    cells: usize,
    param_mb: f64,
    act_mb: f64,
    fwd_ref: f64,
) -> ModelProfile {
    // normal cells with reduction cells at 1/3 and 2/3 depth
    let l = cells;
    let mut act_w = vec![1.0; l];
    let mut out_frac = vec![0.85; l];
    for i in 0..l {
        if i == l / 3 || i == 2 * l / 3 {
            out_frac[i] = 0.45; // reduction cell halves spatial dims
        }
        let section = if i < l / 3 { 0 } else if i < 2 * l / 3 { 1 } else { 2 };
        act_w[i] = match section {
            0 => 1.6,
            1 => 1.0,
            _ => 0.6,
        };
    }
    build(
        name,
        platform,
        param_mb,
        act_mb,
        fwd_ref,
        2.1,
        Shape {
            param_w: ramp(l, 1.08),
            act_w,
            comp_w: vec![1.0; l],
            out_frac,
        },
    )
}

/// AmoebaNet-D18 (476 MB params, 432 MB act/sample), 18 normal cells.
pub fn amoebanet_d18(platform: &PlatformSpec) -> ModelProfile {
    amoebanet("amoebanet-d18", platform, 18, 476.0, 432.0, 1.6)
}

/// AmoebaNet-D36 (900 MB params, 697 MB act/sample), 36 normal cells.
///
/// Calibration: Fig. 1(a) — compute ≈ 6 s/iter at local batch 8 (2 micro-
/// batches of 4) on the 10 GB tier (≈5.8 effective vCPU → speed≈3.9):
/// fwd+bwd ref ≈ 6/2*3.9 ≈ 11.7 s per micro-batch ⇒ fwd_ref ≈ 3.8 s.
pub fn amoebanet_d36(platform: &PlatformSpec) -> ModelProfile {
    amoebanet("amoebanet-d36", platform, 36, 900.0, 697.0, 3.8)
}

/// BERT-Large (1153 MB params, 263 MB act/sample): 24 uniform transformer
/// blocks + embedding layer (31 MB vocab table dominates params of l0).
pub fn bert_large(platform: &PlatformSpec) -> ModelProfile {
    let l = 25;
    let mut param_w = vec![1.0; l];
    param_w[0] = 2.8; // embeddings ≈ 31M params vs ~12.6M per block
    let mut act_w = vec![1.0; l];
    act_w[0] = 0.4;
    let mut comp_w = vec![1.0; l];
    comp_w[0] = 0.25; // embedding lookup is cheap
    build(
        "bert-large",
        platform,
        1153.0,
        263.0,
        3.1,
        2.0,
        Shape {
            param_w,
            act_w,
            comp_w,
            out_frac: vec![0.12; l], // (T, H) boundary tensor ≪ act memory
        },
    )
}

/// VGG16 (~552 MB params, ~96 MB act/sample): 13 convolution layers +
/// 3 fully-connected layers. The fc block holds ~89% of the parameters
/// (fc1 alone ≈ 103M of 138M) while the convolutions hold nearly all of
/// the activations and compute — the opposite skew from the Table 1
/// models, which is exactly what makes it a good planner-search stress
/// case: cheap-to-sync conv stages vs one parameter-dense tail stage.
pub fn vgg16(platform: &PlatformSpec) -> ModelProfile {
    // per-layer parameter counts (M), conv1_1..conv5_3 then fc6..fc8
    let param_w = vec![
        0.002, 0.037, // conv1_*
        0.074, 0.148, // conv2_*
        0.295, 0.590, 0.590, // conv3_*
        1.180, 2.360, 2.360, // conv4_*
        2.360, 2.360, 2.360, // conv5_*
        102.8, 16.8, 4.1, // fc6..fc8
    ];
    // activation footprint shrinks with each pooling stage; fc is tiny
    let act_w = vec![
        3.2, 3.2, 1.6, 1.6, 0.8, 0.8, 0.8, 0.4, 0.4, 0.4, 0.1, 0.1, 0.1,
        0.02, 0.004, 0.001,
    ];
    // GFLOPs per layer (fwd): conv-dominated, fc nearly free
    let comp_w = vec![
        0.17, 3.7, 1.85, 3.7, 1.85, 3.7, 3.7, 1.85, 3.7, 3.7, 0.93, 0.93,
        0.93, 0.21, 0.03, 0.01,
    ];
    // boundary tensors halve at every pooling layer
    let out_frac = vec![
        0.9, 0.5, 0.9, 0.5, 0.9, 0.9, 0.5, 0.9, 0.9, 0.5, 0.9, 0.9, 0.5,
        0.5, 0.5, 0.5,
    ];
    build(
        "vgg16",
        platform,
        552.0,
        96.0,
        // ~15.5 GFLOPs fwd @224px, same CIFAR-scale discount and
        // calibration anchor as resnet101 (0.55 s at 7.8 GFLOPs)
        1.1,
        2.0,
        Shape { param_w, act_w, comp_w, out_frac },
    )
}

/// The small AOT transformer actually trained end-to-end (examples/),
/// profiled analytically here for planner tests; the real profiler
/// measures it through PJRT.
pub fn tiny_transformer(platform: &PlatformSpec, n_stages: usize) -> ModelProfile {
    let l = n_stages.max(3);
    build(
        "tiny-transformer",
        platform,
        2.0,
        1.0,
        0.004,
        2.0,
        Shape {
            param_w: vec![1.0; l],
            act_w: vec![1.0; l],
            comp_w: vec![1.0; l],
            out_frac: vec![0.8; l],
        },
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_sizes_match() {
        let p = PlatformSpec::aws_lambda();
        let cases = [
            (resnet101(&p), 170.0, 198.0),
            (amoebanet_d18(&p), 476.0, 432.0),
            (amoebanet_d36(&p), 900.0, 697.0),
            (bert_large(&p), 1153.0, 263.0),
            (vgg16(&p), 552.0, 96.0),
        ];
        for (m, params_mb, act_mb) in cases {
            let got_p = m.total_param_bytes() as f64 / MB;
            let got_a =
                m.total_act_bytes() as f64 / MB / MICRO_BATCH as f64;
            assert!(
                (got_p - params_mb).abs() / params_mb < 0.01,
                "{}: params {got_p} vs {params_mb}",
                m.name
            );
            assert!(
                (got_a - act_mb).abs() / act_mb < 0.01,
                "{}: act {got_a} vs {act_mb}",
                m.name
            );
            m.validate().unwrap();
        }
    }

    #[test]
    fn compute_times_decrease_with_tier() {
        let p = PlatformSpec::aws_lambda();
        let m = amoebanet_d36(&p);
        for l in &m.layers {
            for w in l.fwd_s.windows(2) {
                assert!(w[1] <= w[0] + 1e-12);
            }
        }
    }

    #[test]
    fn fig1a_compute_calibration() {
        // Fig 1(a): AmoebaNet-D36 computation ~6 s/iteration with local
        // batch 8 on a max-memory worker.
        let p = PlatformSpec::aws_lambda();
        let m = amoebanet_d36(&p);
        let top = p.max_tier();
        let per_micro = m.total_fwd_s(top) + m.total_bwd_s(top);
        let iter_s = per_micro * (8 / MICRO_BATCH) as f64;
        assert!(
            (4.0..9.0).contains(&iter_s),
            "calibration off: {iter_s} s/iter"
        );
    }

    #[test]
    fn by_name_lookup() {
        let p = PlatformSpec::aws_lambda();
        for n in MODEL_NAMES {
            assert!(by_name(n, &p).is_some(), "{n}");
        }
        // vgg16 resolves by name but stays out of the Table-1 set the
        // figure generators iterate
        assert!(by_name("vgg16", &p).is_some());
        assert!(!MODEL_NAMES.contains(&"vgg16"));
        assert!(by_name("nope", &p).is_none());
    }

    #[test]
    fn bert_embedding_is_param_heavy() {
        let p = PlatformSpec::aws_lambda();
        let m = bert_large(&p);
        assert!(m.layers[0].param_bytes > m.layers[1].param_bytes * 2);
    }

    #[test]
    fn vgg16_params_concentrate_in_fc() {
        let p = PlatformSpec::aws_lambda();
        let m = vgg16(&p);
        let total: u64 = m.layers.iter().map(|l| l.param_bytes).sum();
        let fc: u64 = m.layers[13..].iter().map(|l| l.param_bytes).sum();
        assert!(
            fc as f64 > 0.85 * total as f64,
            "fc share {:.2}",
            fc as f64 / total as f64
        );
        // while compute lives in the convolutions
        let top = p.max_tier();
        let conv_s: f64 = m.layers[..13].iter().map(|l| l.fwd_s[top]).sum();
        let fc_s: f64 = m.layers[13..].iter().map(|l| l.fwd_s[top]).sum();
        assert!(conv_s > 10.0 * fc_s);
    }

    #[test]
    fn resnet_activations_shrink_with_depth() {
        let p = PlatformSpec::aws_lambda();
        let m = resnet101(&p);
        assert!(m.layers[0].act_bytes > m.layers[23].act_bytes * 4);
        assert!(m.layers[23].param_bytes > m.layers[0].param_bytes * 4);
    }
}
