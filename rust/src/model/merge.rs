//! Layer merging (§4): for models with many layers the MIQP is too slow,
//! so adjacent layers are merged into `target` super-layers before
//! optimization. The paper offers three criteria — balance by computation
//! time, parameter size, or activation size — and reports that balancing
//! computation works best (it is the default everywhere here too).

use crate::model::layer::{LayerProfile, ModelProfile};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MergeCriterion {
    /// Balance summed forward+backward compute time (paper's choice).
    Compute,
    /// Balance summed parameter size.
    ParamSize,
    /// Balance summed activation size.
    ActivationSize,
}

impl MergeCriterion {
    /// Stable wire name — the `"merge_criterion"` value in configs and
    /// plan artifacts. `parse` is its inverse.
    pub fn as_str(&self) -> &'static str {
        match self {
            MergeCriterion::Compute => "compute",
            MergeCriterion::ParamSize => "params",
            MergeCriterion::ActivationSize => "activations",
        }
    }

    pub fn parse(s: &str) -> Option<MergeCriterion> {
        match s {
            "compute" => Some(MergeCriterion::Compute),
            "params" => Some(MergeCriterion::ParamSize),
            "activations" => Some(MergeCriterion::ActivationSize),
            _ => None,
        }
    }
}

fn weight(l: &LayerProfile, c: MergeCriterion) -> f64 {
    match c {
        // tier 0 as the balancing reference — ratios are tier-invariant
        MergeCriterion::Compute => l.fwd_s[0] + l.bwd_s[0],
        MergeCriterion::ParamSize => l.param_bytes as f64,
        MergeCriterion::ActivationSize => l.act_bytes as f64,
    }
}

/// Merge `model` into at most `target` super-layers, balancing `criterion`.
///
/// Greedy block assignment: walk layers accumulating weight; close the
/// current block once it reaches `total/target`, while never leaving more
/// layers than remaining blocks. Merged quantities: sizes and compute
/// times add; the boundary output/grad sizes are those of the block's last
/// layer (partition boundaries can only fall between super-layers).
pub fn merge_layers(
    model: &ModelProfile,
    target: usize,
    criterion: MergeCriterion,
) -> ModelProfile {
    assert!(target >= 1);
    let l = model.layers.len();
    if l <= target {
        return model.clone();
    }
    let weights: Vec<f64> =
        model.layers.iter().map(|x| weight(x, criterion)).collect();
    let total: f64 = weights.iter().sum();

    // Greedy with dynamic re-targeting: each block aims for
    // remaining_total / remaining_blocks, and a layer is included only if
    // that brings the block closer to its target (subject to leaving at
    // least one layer per remaining block).
    let mut blocks: Vec<(usize, usize)> = Vec::with_capacity(target);
    let mut start = 0usize;
    let mut remaining = total;
    let mut i = 0usize;
    while blocks.len() < target - 1 {
        let blocks_left = target - blocks.len();
        let goal = remaining / blocks_left as f64;
        let mut acc = weights[i];
        let mut end = i;
        loop {
            let layers_left_after = l - (end + 1);
            if layers_left_after <= blocks_left - 1 {
                break; // must leave one layer per remaining block
            }
            let next = weights[end + 1];
            // include next layer only if it brings us closer to goal
            if (acc + next - goal).abs() < (acc - goal).abs() {
                end += 1;
                acc += next;
            } else {
                break;
            }
        }
        blocks.push((start, end));
        remaining -= acc;
        start = end + 1;
        i = start;
    }
    blocks.push((start, l - 1));

    let n_tiers = model.layers[0].fwd_s.len();
    let merged = blocks
        .iter()
        .enumerate()
        .map(|(bi, &(lo, hi))| {
            let mut fwd_s = vec![0.0; n_tiers];
            let mut bwd_s = vec![0.0; n_tiers];
            let mut param = 0u64;
            let mut act = 0u64;
            for l in &model.layers[lo..=hi] {
                param += l.param_bytes;
                act += l.act_bytes;
                for j in 0..n_tiers {
                    fwd_s[j] += l.fwd_s[j];
                    bwd_s[j] += l.bwd_s[j];
                }
            }
            let last = &model.layers[hi];
            let first = &model.layers[lo];
            LayerProfile {
                name: format!("{}/m{}[{}..{}]", model.name, bi, lo, hi),
                param_bytes: param,
                act_bytes: act,
                out_bytes: last.out_bytes,
                grad_bytes: first.grad_bytes,
                fwd_s,
                bwd_s,
            }
        })
        .collect();

    ModelProfile { name: model.name.clone(), layers: merged }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;
    use crate::platform::PlatformSpec;

    #[test]
    fn merge_preserves_totals() {
        let p = PlatformSpec::aws_lambda();
        let m = zoo::amoebanet_d36(&p);
        for target in [4, 8, 12] {
            let merged = merge_layers(&m, target, MergeCriterion::Compute);
            assert_eq!(merged.n_layers(), target);
            assert_eq!(merged.total_param_bytes(), m.total_param_bytes());
            assert_eq!(merged.total_act_bytes(), m.total_act_bytes());
            for j in 0..p.n_tiers() {
                assert!(
                    (merged.total_fwd_s(j) - m.total_fwd_s(j)).abs() < 1e-9
                );
            }
        }
    }

    #[test]
    fn merge_balances_compute() {
        let p = PlatformSpec::aws_lambda();
        let m = zoo::bert_large(&p);
        let merged = merge_layers(&m, 8, MergeCriterion::Compute);
        let times: Vec<f64> =
            merged.layers.iter().map(|l| l.fwd_s[0] + l.bwd_s[0]).collect();
        let max = times.iter().cloned().fold(0.0, f64::max);
        let min = times.iter().cloned().fold(f64::INFINITY, f64::min);
        // balanced within 2.5x (BERT's embedding layer skews one block)
        assert!(max / min < 2.5, "imbalance {max}/{min}");
    }

    #[test]
    fn merge_noop_when_small() {
        let p = PlatformSpec::aws_lambda();
        let m = zoo::resnet101(&p);
        let same = merge_layers(&m, 100, MergeCriterion::ParamSize);
        assert_eq!(same, m);
    }

    #[test]
    fn merge_by_params_balances_params() {
        let p = PlatformSpec::aws_lambda();
        let m = zoo::resnet101(&p);
        let merged = merge_layers(&m, 6, MergeCriterion::ParamSize);
        let sizes: Vec<f64> =
            merged.layers.iter().map(|l| l.param_bytes as f64).collect();
        let max = sizes.iter().cloned().fold(0.0, f64::max);
        let min = sizes.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(max / min < 3.0, "imbalance {sizes:?}");
    }

    #[test]
    fn merged_boundaries_use_edge_layers() {
        let p = PlatformSpec::aws_lambda();
        let m = zoo::resnet101(&p);
        let merged = merge_layers(&m, 4, MergeCriterion::Compute);
        // each merged layer's out_bytes equals its last member's
        assert_eq!(
            merged.layers.last().unwrap().out_bytes,
            m.layers.last().unwrap().out_bytes
        );
    }
}
