//! Model metadata: per-layer profiles, the evaluation model zoo (Table 1),
//! layer merging (§4 "MIQP solution") and partition-plan representation.

pub mod layer;
pub mod merge;
pub mod partition;
pub mod zoo;

pub use layer::{LayerProfile, ModelProfile};
pub use merge::{merge_layers, MergeCriterion};
pub use partition::{Plan, PlanError};
